//! Cluster-size tuning sweep (the Fig. 11 experiment as a user-facing
//! tool): for a model/sequence grid, evaluate the fused dataflow at every
//! legal cluster size and report the optimum — the paper's conclusion that
//! "cluster size should be tuned accordingly" as a utility.
//!
//! ```bash
//! cargo run --release --example cluster_size_sweep -- [model]
//! ```

use anyhow::{Context, Result};
use clusterfusion::clustersim::dataflow::{mla, split_token, AttnProblem, CostEnv};
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::metrics::Table;
use clusterfusion::models::{AttnKind, ModelConfig};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model_name = args.first().map(String::as_str).unwrap_or("llama2-7b");
    let model = ModelConfig::by_name(model_name).context("unknown model")?;

    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);

    println!("== cluster-size sweep: {} ==\n", model.name);
    let mut t = Table::new(vec![
        "batch", "seq", "N=1", "N=2", "N=4", "N=8", "N=16", "best", "gain vs N=1",
    ]);
    for batch in [1usize, 4, 16] {
        for seq in [1024usize, 4096, 16384] {
            let p = AttnProblem {
                batch,
                d_model: model.d_model,
                n_heads: model.n_heads,
                head_dim: model.head_dim,
                seq,
                kv_lora_rank: model.kv_lora_rank,
            };
            let lats: Vec<(usize, f64)> = Noc::cluster_sizes()
                .iter()
                .map(|&n| {
                    let env = CostEnv::clusterfusion(&hw, &noc, n);
                    let lat = match model.attn {
                        AttnKind::Mha => split_token::cost(&p, &env).latency,
                        AttnKind::Mla => mla::cost(&p, &env).latency,
                    };
                    (n, lat)
                })
                .collect();
            let best = lats.iter().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap();
            let mut row = vec![batch.to_string(), seq.to_string()];
            row.extend(lats.iter().map(|(_, l)| format!("{:.1}", l * 1e6)));
            row.push(format!("N={}", best.0));
            row.push(format!("{:.2}x", lats[0].1 / best.1));
            t.row(row);
        }
    }
    t.print();
    println!("\n(latencies in us per layer; the best cluster size is workload-dependent,");
    println!(" which is the paper's §4.1 tuning conclusion)");
    Ok(())
}
