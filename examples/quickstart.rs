//! Quickstart: the smallest possible end-to-end check that the stack
//! composes — submit a prompt, run decode steps, print the tokens.
//!
//! With AOT artifacts present (`make artifacts`), this loads the real
//! PJRT runtime and runs one decode step of the compiled tiny-llama.
//! On a fresh checkout (no `artifacts/manifest.json`) it falls back to
//! the deterministic in-memory [`MockBackend`], driving the identical
//! coordinator path: admission → continuous batch → paged KV cache →
//! decode loop → finish reason → metrics.
//!
//! ```bash
//! cargo run --release --example quickstart          # mock backend
//! make artifacts && cargo run --release --example quickstart   # PJRT
//! ```

use anyhow::Result;
use clusterfusion::coordinator::engine::{Engine, MockBackend};
use clusterfusion::coordinator::request::{Event, Request};
use clusterfusion::runtime::{argmax, HostTensor, Runtime};

/// Crate-anchored artifacts dir so the example behaves the same from any
/// working directory (matches the integration tests' probe).
fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn pjrt_quickstart() -> Result<()> {
    let model = "tiny-llama-100m";
    println!("opening {} ...", artifacts_dir());
    let mut rt = Runtime::open(artifacts_dir())?;
    println!("PJRT platform: {}", rt.platform());
    println!("available models: {:?}", rt.manifest.models());

    println!("compiling {model} (batch 1, self-contained interface) ...");
    rt.load(model, 1, false)?;
    let iface = rt.get(model, 1, false)?.iface.clone();
    println!(
        "  {} layers, d_model {}, vocab {}, {:.1} M params",
        iface.n_layers,
        iface.d_model,
        iface.vocab,
        iface.param_elems() as f64 / 1e6
    );

    println!("uploading random parameters (seed 0) ...");
    let params = rt.random_params(&iface, 0)?;

    // empty KV cache; feed token 42 at position 0
    let caches: Vec<HostTensor> =
        iface.cache_specs().iter().map(|s| HostTensor::zeros(&s.shape)).collect();
    let t0 = std::time::Instant::now();
    let exe = rt.get(model, 1, false)?;
    let outs = rt.decode_step(exe, &[42], &[0], &caches, &params)?;
    let dt = t0.elapsed();

    let logits = &outs[0];
    let tok = argmax(&logits.data);
    println!(
        "decode step done in {:.1} ms: argmax token = {tok}, logit = {:.4}",
        dt.as_secs_f64() * 1e3,
        logits.data[tok]
    );
    println!("updated cache tensors returned: {}", outs.len() - 1);
    Ok(())
}

fn mock_quickstart() -> Result<()> {
    println!("using the deterministic in-memory MockBackend");
    println!("(run `make artifacts` with a PJRT-enabled build for the real path)\n");

    let mut engine = Engine::new(MockBackend::tiny(), 64, 4, 1.0);
    engine.submit(Request::new(1, vec![3, 5], 3));
    engine.run_to_completion(100)?;

    let events = engine.take_events();
    let tokens: Vec<i32> = events
        .iter()
        .filter_map(|e| match e {
            Event::FirstToken { token, .. } | Event::Token { token, .. } => Some(*token),
            _ => None,
        })
        .collect();
    println!("prompt [3, 5] -> generated tokens {tokens:?}");
    match events.last() {
        Some(Event::Finished { reason, .. }) => println!("finish reason: {reason:?}"),
        other => anyhow::bail!("expected a Finished event, got {other:?}"),
    }
    println!(
        "engine: {} decode steps, {} tokens out, {} pages still held",
        engine.steps,
        engine.tokens_out,
        engine.pool.used_pages()
    );
    anyhow::ensure!(tokens == vec![6, 8, 11], "mock decode must be deterministic");
    Ok(())
}

fn main() -> Result<()> {
    // Prefer the real PJRT path when artifacts exist and the runtime is
    // available (offline builds stub the `xla` crate — DESIGN.md §PJRT);
    // degrade to the mock backend otherwise so the quickstart always
    // demonstrates a working end-to-end path.
    if clusterfusion::runtime::artifacts_ready(artifacts_dir()) {
        match pjrt_quickstart() {
            Ok(()) => {
                println!("quickstart OK");
                return Ok(());
            }
            Err(e) => eprintln!("PJRT path failed ({e:#}); falling back to the mock backend\n"),
        }
    }
    mock_quickstart()?;
    println!("quickstart OK");
    Ok(())
}
