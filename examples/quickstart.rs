//! Quickstart: the smallest possible end-to-end check that the stack
//! composes — submit a prompt, run decode steps, print the tokens.
//!
//! Default path: the **functional backend** — real full-block decoding
//! (RMSNorm → fused attention dataflow with rotary → residual → SwiGLU
//! MLP → tied-embedding greedy head) of the seeded `micro-llama` through
//! the identical coordinator path: admission → continuous batch → paged
//! KV cache → decode loop → finish reason → metrics. Real numerics, no
//! artifacts, no PJRT.
//!
//! With AOT artifacts present (`make artifacts`) it first tries the PJRT
//! runtime on the compiled tiny-llama. `--mock` forces the deterministic
//! echo backend (demo of the coordinator alone — not real decoding).
//!
//! ```bash
//! cargo run --release --example quickstart            # functional backend
//! cargo run --release --example quickstart -- --mock  # mock coordinator demo
//! make artifacts && cargo run --release --example quickstart   # PJRT
//! ```

use anyhow::Result;
use clusterfusion::coordinator::engine::{Backend, Engine, MockBackend};
use clusterfusion::coordinator::request::{Event, Request};
use clusterfusion::coordinator::FunctionalBackend;
use clusterfusion::runtime::{argmax, HostTensor, Runtime};

/// Crate-anchored artifacts dir so the example behaves the same from any
/// working directory (matches the integration tests' probe).
fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn pjrt_quickstart() -> Result<()> {
    let model = "tiny-llama-100m";
    println!("opening {} ...", artifacts_dir());
    let mut rt = Runtime::open(artifacts_dir())?;
    println!("PJRT platform: {}", rt.platform());
    println!("available models: {:?}", rt.manifest.models());

    println!("compiling {model} (batch 1, self-contained interface) ...");
    rt.load(model, 1, false)?;
    let iface = rt.get(model, 1, false)?.iface.clone();
    println!(
        "  {} layers, d_model {}, vocab {}, {:.1} M params",
        iface.n_layers,
        iface.d_model,
        iface.vocab,
        iface.param_elems() as f64 / 1e6
    );

    println!("uploading random parameters (seed 0) ...");
    let params = rt.random_params(&iface, 0)?;

    // empty KV cache; feed token 42 at position 0
    let caches: Vec<HostTensor> =
        iface.cache_specs().iter().map(|s| HostTensor::zeros(&s.shape)).collect();
    let t0 = std::time::Instant::now();
    let exe = rt.get(model, 1, false)?;
    let outs = rt.decode_step(exe, &[42], &[0], &caches, &params)?;
    let dt = t0.elapsed();

    let logits = &outs[0];
    let tok = argmax(&logits.data);
    println!(
        "decode step done in {:.1} ms: argmax token = {tok}, logit = {:.4}",
        dt.as_secs_f64() * 1e3,
        logits.data[tok]
    );
    println!("updated cache tensors returned: {}", outs.len() - 1);
    Ok(())
}

/// Drive a full greedy decode through the engine and return the token
/// stream (shared by the functional and mock paths).
fn decode_once<B: Backend>(
    engine: &mut Engine<B>,
    prompt: Vec<i32>,
    gen: usize,
) -> Result<Vec<i32>> {
    engine.submit(Request::new(1, prompt, gen));
    engine.run_to_completion(256)?;
    let events = engine.take_events();
    let tokens: Vec<i32> = events
        .iter()
        .filter_map(|e| match e {
            Event::FirstToken { token, .. } | Event::Token { token, .. } => Some(*token),
            _ => None,
        })
        .collect();
    match events.last() {
        Some(Event::Finished { reason, .. }) => println!("finish reason: {reason:?}"),
        other => anyhow::bail!("expected a Finished event, got {other:?}"),
    }
    Ok(tokens)
}

fn functional_quickstart() -> Result<()> {
    // auto-sized worker pool (CLUSTERFUSION_THREADS overrides; on
    // micro-llama the work-size gate resolves to serial — DESIGN.md
    // §Parallel); when it does go wide, the serial re-decode below
    // doubles as a live thread-invariance check
    let backend = FunctionalBackend::from_model_name_on("micro-llama", 42, 2, 0)?;
    let threads = backend.threads();
    println!("backend: {}", backend.describe());
    println!("(real numerics — greedy decode over seeded weights; --mock for the echo demo)\n");

    let prompt = vec![3, 5, 11];
    let t0 = std::time::Instant::now();
    let mut engine = Engine::new(backend, 64, 8, 1.0);
    let tokens = decode_once(&mut engine, prompt.clone(), 8)?;
    let dt = t0.elapsed();
    println!("prompt {prompt:?} -> generated tokens {tokens:?}");
    println!(
        "engine: {} decode steps, {} tokens out in {:.1} ms, {} pages still held",
        engine.steps,
        engine.tokens_out,
        dt.as_secs_f64() * 1e3,
        engine.pool.used_pages()
    );

    // Determinism check: a fresh engine from the same seed — on a
    // *serial* pool — must replay the identical stream (the
    // integration_block contract plus the §Parallel thread-count
    // invariance, exercised live when the first run was threaded).
    let backend2 = FunctionalBackend::from_model_name("micro-llama", 42, 2)?;
    let mut engine2 = Engine::new(backend2, 64, 8, 1.0);
    let again = decode_once(&mut engine2, prompt, 8)?;
    anyhow::ensure!(tokens == again, "functional decode must be seed- and thread-deterministic");
    println!("re-decode, same seed, serial pool ({threads} -> 1 threads): byte-identical ✓");
    Ok(())
}

fn mock_quickstart() -> Result<()> {
    println!("backend: MOCK (deterministic echo — coordinator demo, not real decoding)\n");
    let mut engine = Engine::new(MockBackend::tiny(), 64, 4, 1.0);
    let tokens = decode_once(&mut engine, vec![3, 5], 3)?;
    println!("prompt [3, 5] -> generated tokens {tokens:?}");
    println!(
        "engine: {} decode steps, {} tokens out, {} pages still held",
        engine.steps,
        engine.tokens_out,
        engine.pool.used_pages()
    );
    anyhow::ensure!(tokens == vec![6, 8, 11], "mock decode must be deterministic");
    Ok(())
}

fn main() -> Result<()> {
    let mock = std::env::args().any(|a| a == "--mock");
    if mock {
        mock_quickstart()?;
        println!("quickstart OK (mock)");
        return Ok(());
    }
    // Prefer the real PJRT path when artifacts exist and the runtime is
    // available (offline builds stub the `xla` crate — DESIGN.md §PJRT);
    // otherwise the functional backend decodes for real — the quickstart
    // never silently demos the mock.
    if clusterfusion::runtime::artifacts_ready(artifacts_dir()) {
        match pjrt_quickstart() {
            Ok(()) => {
                println!("quickstart OK");
                return Ok(());
            }
            Err(e) => {
                eprintln!("PJRT path failed ({e:#}); using the functional backend instead\n")
            }
        }
    }
    functional_quickstart()?;
    println!("quickstart OK");
    Ok(())
}
