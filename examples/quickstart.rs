//! Quickstart: load the AOT artifacts, run one decode step through PJRT,
//! and print the sampled token — the smallest possible end-to-end check
//! that the three-layer stack (Pallas kernel → JAX model → HLO text →
//! Rust PJRT) composes.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use clusterfusion::runtime::{argmax, HostTensor, Runtime};

fn main() -> Result<()> {
    let model = "tiny-llama-100m";
    println!("opening artifacts/ ...");
    let mut rt = Runtime::open("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    println!("available models: {:?}", rt.manifest.models());

    println!("compiling {model} (batch 1, self-contained interface) ...");
    rt.load(model, 1, false)?;
    let iface = rt.get(model, 1, false)?.iface.clone();
    println!(
        "  {} layers, d_model {}, vocab {}, {:.1} M params",
        iface.n_layers,
        iface.d_model,
        iface.vocab,
        iface.param_elems() as f64 / 1e6
    );

    println!("uploading random parameters (seed 0) ...");
    let params = rt.random_params(&iface, 0)?;

    // empty KV cache; feed token 42 at position 0
    let caches: Vec<HostTensor> =
        iface.cache_specs().iter().map(|s| HostTensor::zeros(&s.shape)).collect();
    let t0 = std::time::Instant::now();
    let exe = rt.get(model, 1, false)?;
    let outs = rt.decode_step(exe, &[42], &[0], &caches, &params)?;
    let dt = t0.elapsed();

    let logits = &outs[0];
    let tok = argmax(&logits.data);
    println!(
        "decode step done in {:.1} ms: argmax token = {tok}, logit = {:.4}",
        dt.as_secs_f64() * 1e3,
        logits.data[tok]
    );
    println!("updated cache tensors returned: {}", outs.len() - 1);
    println!("quickstart OK");
    Ok(())
}
