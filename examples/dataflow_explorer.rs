//! Dataflow explorer: run every dataflow variant *functionally* on the
//! same randomly generated attention-block problem, verify they all agree
//! with the plain reference, and contrast their executed DSMEM traffic and
//! modelled latency (the Appendix B analysis as a runnable tool).
//!
//! ```bash
//! cargo run --release --example dataflow_explorer
//! ```

use anyhow::Result;
use clusterfusion::clustersim::collective::Transport;
use clusterfusion::clustersim::dataflow::reference::attention_block_ref;
use clusterfusion::clustersim::dataflow::{
    block_isolated, split_head, split_token, AttnProblem, CostEnv,
};
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::metrics::Table;
use clusterfusion::util::rng::Rng;

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn main() -> Result<()> {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);

    // a small but non-trivial functional problem
    let (b, nh, dh, s, d, n) = (2usize, 4usize, 16usize, 64usize, 64usize, 4usize);
    let mut rng = Rng::seed_from_u64(2024);
    let mut v = |len: usize, scale: f32| -> Vec<f32> {
        (0..len).map(|_| (rng.f32() - 0.5) * scale).collect()
    };
    let h = nh * dh;
    let hidden = v(b * d, 2.0);
    let wq = v(d * h, 0.3);
    let wk = v(d * h, 0.3);
    let wv = v(d * h, 0.3);
    let wo = v(h * d, 0.3);
    let k_cache = v(b * s * h, 2.0);
    let v_cache = v(b * s * h, 2.0);
    let pos = vec![37, 12];

    println!("== dataflow explorer: functional equivalence + executed traffic ==");
    println!("problem: B={b} heads={nh} dh={dh} S={s} D={d}, cluster N={n}\n");

    let rref = attention_block_ref(
        &hidden, &wq, &wk, &wv, &wo, &k_cache, &v_cache, &pos, b, d, nh, dh, s,
    );
    let (st, st_rep) = split_token::execute(
        &hidden, &wq, &wk, &wv, &wo, &k_cache, &v_cache, &pos, b, d, nh, dh, s, n,
        Transport::Dsmem, &hw, &noc,
    );
    let (sh, sh_rep) = split_head::execute(
        &hidden, &wq, &wk, &wv, &wo, &k_cache, &v_cache, &pos, b, d, nh, dh, s, n,
        Transport::Dsmem, &hw, &noc,
    );
    let (bi, bi_rep) = block_isolated::execute(
        &hidden, &wq, &wk, &wv, &wo, &k_cache, &v_cache, &pos, b, d, nh, dh, s,
    );

    let mut t = Table::new(vec![
        "dataflow",
        "max |err| vs ref",
        "DSMEM bytes (executed)",
        "gmem intermediates",
        "launches",
    ]);
    t.row(vec![
        "SplitToken (Alg.3)".to_string(),
        format!("{:.2e}", max_abs_diff(&st.out, &rref.out)),
        format!("{:.0}", st_rep.dsmem_bytes),
        "none".to_string(),
        st_rep.launches.to_string(),
    ]);
    t.row(vec![
        "SplitHead (Alg.5)".to_string(),
        format!("{:.2e}", max_abs_diff(&sh.out, &rref.out)),
        format!("{:.0}", sh_rep.dsmem_bytes),
        "none".to_string(),
        sh_rep.launches.to_string(),
    ]);
    t.row(vec![
        "BlockIsolated (Fig.3)".to_string(),
        format!("{:.2e}", max_abs_diff(&bi.out, &rref.out)),
        "0".to_string(),
        format!("{:.0} B", bi_rep.hbm_bytes),
        bi_rep.launches.to_string(),
    ]);
    t.print();

    for (name, out) in [("SplitToken", &st.out), ("SplitHead", &sh.out), ("BlockIsolated", &bi.out)]
    {
        let err = max_abs_diff(out, &rref.out);
        assert!(err < 1e-3, "{name} diverged: {err}");
    }

    // modelled latency on the paper's scale (Llama2-7B dims)
    println!("\nmodelled per-layer latency at Llama2-7B scale, cluster 4:");
    let p = AttnProblem {
        batch: 1, d_model: 4096, n_heads: 32, head_dim: 128, seq: 4096, kv_lora_rank: 0,
    };
    let env = CostEnv::clusterfusion(&hw, &noc, 4);
    let mut t2 = Table::new(vec!["dataflow", "latency (us)", "DSMEM (KB)", "HBM (MB)"]);
    for (name, rep) in [
        ("SplitToken", split_token::cost(&p, &env)),
        ("SplitHead", split_head::cost(&p, &env)),
        ("BlockIsolated", block_isolated::cost(&p, &env)),
    ] {
        t2.row(vec![
            name.to_string(),
            format!("{:.1}", rep.latency * 1e6),
            format!("{:.1}", rep.dsmem_bytes / 1024.0),
            format!("{:.1}", rep.hbm_bytes / 1e6),
        ]);
    }
    t2.print();
    println!("\ndataflow_explorer OK (all variants numerically identical to the reference)");
    Ok(())
}
