//! **End-to-end validation driver** (DESIGN.md): serve a ShareGPT-like
//! request trace against the ~100 M-parameter tiny-llama on the real PJRT
//! runtime — router → continuous batcher → paged KV cache → fused decode
//! executable — and report latency/throughput percentiles.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_trace -- [n_requests] [model]
//! ```
//!
//! The run recorded in EXPERIMENTS.md §End-to-end used the defaults
//! (12 requests, tiny-llama-100m).

use anyhow::Result;
use clusterfusion::coordinator::engine::{Backend, Engine};
use clusterfusion::coordinator::pjrt_backend::PjrtBackend;
use clusterfusion::coordinator::request::{Event, Request};
use clusterfusion::coordinator::router::Router;
use clusterfusion::coordinator::server::Server;
use clusterfusion::metrics::{LatencyRecorder, Table, Throughput};
use clusterfusion::util::rng::Rng;
use clusterfusion::workload::{SeqlenDist, Trace};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(12);
    let model = args.get(1).map(String::as_str).unwrap_or("tiny-llama-100m");

    println!("== serve_trace: end-to-end serving on PJRT ==");
    // Crate-anchored artifacts dir so the example behaves the same from
    // any working directory (matches the integration tests' probe).
    let artifacts = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if !clusterfusion::runtime::artifacts_ready(&artifacts) {
        println!("skipping: missing {artifacts}/manifest.json (run `make artifacts`) or the");
        println!("PJRT runtime is unavailable in this build — see DESIGN.md §PJRT");
        return Ok(());
    }
    println!("loading {model} ...");
    let backend = PjrtBackend::load(&artifacts, model, 0)?;
    println!(
        "platform {}, buckets {:?}, vocab {}",
        backend.platform(),
        backend.buckets(),
        backend.geom().vocab
    );
    let vocab = backend.geom().vocab;
    let engine = Engine::new(backend, 512, 16, 0.5);
    let server = Server::spawn(engine);
    let mut router = Router::new(1, 4096);

    // ShareGPT-like trace, scaled to the demo model's context budget
    let trace = Trace::poisson(n_requests, 8.0, SeqlenDist::ShareGpt, (4, 12), 96, 42);
    println!("trace: {} requests, offered {:.1} rps\n", trace.requests.len(), trace.offered_rps());

    let mut rng = Rng::seed_from_u64(7);
    let t0 = std::time::Instant::now();
    let mut receivers = Vec::new();
    for r in &trace.requests {
        let prompt: Vec<i32> =
            (0..r.prompt_len.clamp(1, 16)).map(|_| rng.below(vocab) as i32).collect();
        let req = Request::new(r.id, prompt, r.gen_len.clamp(4, 12));
        let route = router.route(&req)?;
        router.on_started(route.replica);
        receivers.push((r.id, server.submit(req)?));
    }

    let mut tokens = 0u64;
    let mut first_tokens = 0u64;
    for (id, rx) in receivers {
        for ev in rx.iter() {
            match ev {
                Event::FirstToken { .. } => {
                    first_tokens += 1;
                    tokens += 1;
                }
                Event::Token { .. } => tokens += 1,
                Event::Finished { .. } => router.on_finished(0, id),
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = server.shutdown()?;

    let mut total_lat = LatencyRecorder::new();
    let mut ttft = LatencyRecorder::new();
    let mut gen_tokens = 0usize;
    for t in &report.timings {
        total_lat.record(t.total);
        ttft.record(t.ttft);
        gen_tokens += t.generated;
    }
    let thr = Throughput { tokens, seconds: wall };

    println!("== results ==");
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["requests completed".to_string(), report.timings.len().to_string()]);
    t.row(vec!["tokens generated".to_string(), gen_tokens.to_string()]);
    t.row(vec!["first tokens".to_string(), first_tokens.to_string()]);
    t.row(vec!["wall time (s)".to_string(), format!("{wall:.2}")]);
    t.row(vec!["throughput (tok/s)".to_string(), format!("{:.2}", thr.tokens_per_second())]);
    t.row(vec!["engine steps".to_string(), report.steps.to_string()]);
    t.row(vec![
        "tokens per step".to_string(),
        format!("{:.2}", report.tokens_out as f64 / report.steps.max(1) as f64),
    ]);
    t.row(vec!["preemptions".to_string(), report.preemptions.to_string()]);
    t.print();
    println!("\nrequest latency: {}", total_lat.summary().fmt_ms());
    println!("ttft:            {}", ttft.summary().fmt_ms());

    assert_eq!(report.timings.len(), n_requests, "every request must finish");
    assert!(tokens > 0 && thr.tokens_per_second() > 0.0);
    println!("\nserve_trace OK");
    Ok(())
}
