//! **End-to-end validation driver** (DESIGN.md): serve a ShareGPT-like
//! request trace — router → continuous batcher → paged KV cache → fused
//! decode — with *paced open-loop submission*: each request is submitted
//! at its trace `arrival_us` on the wall clock (loadgen::pace_submit),
//! and the run reports queue/TTFT/TPOT/e2e latency percentiles.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_trace -- [n_requests] [model]
//! ```
//!
//! Without artifacts (or with the PJRT runtime stubbed) the example
//! decodes through the **functional backend** — real full-block numerics
//! over seeded weights (`coordinator::FunctionalBackend`) — so the
//! pacing path always serves genuine tokens on a fresh checkout; the
//! deterministic `MockBackend` echo hides behind `--mock`. The run
//! recorded in EXPERIMENTS.md §End-to-end used the defaults.
//!
//! Every run ends with a **fleet demo**: a 2-replica deterministic
//! replay (`coordinator::fleet`) in which replica 0 stalls for 60 ms
//! mid-trace, the watermark detector fails its work over to replica 1,
//! and every request still completes — byte-identically on any machine.
//! The demo records itself through the `obs` tracing plane: it writes
//! `target/serve_trace_demo.trace.json` (Perfetto-loadable) and
//! `target/serve_trace_demo.prom` (Prometheus text), then prints the
//! run's 5 largest spans.

use anyhow::Result;
use clusterfusion::coordinator::engine::{Backend, Engine, MockBackend, ModelGeom};
use clusterfusion::coordinator::fleet::{FaultPlan, Fleet, FleetOptions};
use clusterfusion::coordinator::pjrt_backend::PjrtBackend;
use clusterfusion::coordinator::request::Event;
use clusterfusion::coordinator::router::Router;
use clusterfusion::coordinator::server::Server;
use clusterfusion::coordinator::FunctionalBackend;
use clusterfusion::loadgen;
use clusterfusion::metrics::{Table, Throughput};
use clusterfusion::obs::{Obs, TracePhase};
use clusterfusion::util::clock::{Clock, WallClock};
use clusterfusion::workload::{SeqlenDist, Trace};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--mock").collect();
    let mock = std::env::args().any(|a| a == "--mock");
    let n_requests: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(12);

    println!("== serve_trace: end-to-end serving with paced trace replay ==");
    if mock {
        println!("backend: MOCK (deterministic echo — demo only, not real decoding)");
        let geom = ModelGeom { vocab: 512, n_layers: 4, row_elems: 32, planes: 2, max_seq: 256 };
        return run(MockBackend::new(geom, vec![1, 4, 8]), n_requests);
    }
    // Crate-anchored artifacts dir so the example behaves the same from
    // any working directory (matches the integration tests' probe).
    let artifacts = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if clusterfusion::runtime::artifacts_ready(&artifacts) {
        let model = args.get(1).map(String::as_str).unwrap_or("tiny-llama-100m");
        println!("loading {model} ...");
        let backend = PjrtBackend::load(&artifacts, model, 0)?;
        println!(
            "backend: PJRT, platform {}, buckets {:?}, vocab {}",
            backend.platform(),
            backend.buckets(),
            backend.geom().vocab
        );
        run(backend, n_requests)
    } else {
        let model = args.get(1).map(String::as_str).unwrap_or("micro-llama");
        // wall-clock pacing: the worker pool auto-sizes (threads = 0 →
        // CLUSTERFUSION_THREADS, else available parallelism); outputs are
        // byte-identical at every pool size (DESIGN.md §Parallel)
        let backend = FunctionalBackend::from_model_name_on(model, 0, 2, 0)?;
        // describe() announces the active thread count alongside the backend
        println!("backend: {}", backend.describe());
        println!("(no artifacts found — functional decoding; `make artifacts` enables PJRT)");
        let params = backend.config().param_count();
        if params > 20_000_000 {
            println!(
                "note: {model} has {:.0} M params — every decode step runs them through \
                 scalar kernels, expect minutes; the PJRT path is the fast one at this size",
                params as f64 / 1e6
            );
        }
        run(backend, n_requests)
    }
}

fn run<B: Backend + Send + 'static>(backend: B, n_requests: usize) -> Result<()> {
    let vocab = backend.geom().vocab;
    let engine = Engine::new(backend, 512, 16, 0.5);
    let server = Server::spawn(engine);
    let mut router = Router::new(1, 4096);

    // ShareGPT-like trace, scaled to the demo model's context budget
    let trace = Trace::poisson(n_requests, 8.0, SeqlenDist::ShareGpt, (4, 12), 96, 42);
    println!(
        "trace: {} requests, offered {:.1} rps over {:.2}s\n",
        trace.requests.len(),
        trace.achieved_rps(),
        trace.span_us() as f64 / 1e6
    );
    let requests = loadgen::synthesize_requests(&trace, vocab, 16, 12, 7);
    for req in &requests {
        router.route(req)?;
        router.on_started(req.id);
    }

    // Paced open-loop submission: honours arrival_us on the wall clock.
    let clock = WallClock::new();
    let paced = loadgen::pace_submit(&server, &requests, &clock)?;

    let mut tokens = 0u64;
    let mut first_tokens = 0u64;
    for (id, rx) in paced.receivers {
        for ev in rx.iter() {
            match ev {
                Event::FirstToken { .. } => {
                    first_tokens += 1;
                    tokens += 1;
                }
                Event::Token { .. } => tokens += 1,
                Event::Finished { .. } => router.on_finished(id),
            }
        }
    }
    let wall = clock.now_us() as f64 / 1e6;
    let report = server.shutdown()?;

    let mut gen_tokens = 0usize;
    for t in &report.timings {
        gen_tokens += t.generated;
    }
    let thr = Throughput { tokens, seconds: wall };

    println!("== results ==");
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["requests completed".to_string(), report.timings.len().to_string()]);
    t.row(vec!["tokens generated".to_string(), gen_tokens.to_string()]);
    t.row(vec!["first tokens".to_string(), first_tokens.to_string()]);
    t.row(vec!["wall time (s)".to_string(), format!("{wall:.2}")]);
    t.row(vec!["throughput (tok/s)".to_string(), format!("{:.2}", thr.tokens_per_second())]);
    t.row(vec!["engine steps".to_string(), report.steps.to_string()]);
    t.row(vec![
        "tokens per step".to_string(),
        format!("{:.2}", report.tokens_out as f64 / report.steps.max(1) as f64),
    ]);
    t.row(vec!["preemptions".to_string(), report.preemptions.to_string()]);
    t.row(vec![
        "first submit (s)".to_string(),
        format!("{:.3}", paced.first_submit_us as f64 / 1e6),
    ]);
    t.row(vec![
        "last submit (s)".to_string(),
        format!("{:.3}", paced.last_submit_us as f64 / 1e6),
    ]);
    t.print();
    println!("\nlatency percentiles (paced, open-loop):");
    print!("{}", loadgen::percentiles(&report.timings).render());

    assert_eq!(report.timings.len(), n_requests, "every request must finish");
    assert!(tokens > 0 && thr.tokens_per_second() > 0.0);
    if n_requests >= 2 {
        // Pacing acceptance: submissions spread over the trace span
        // instead of all landing at t=0 (sleeps only overshoot, so the
        // spread can only shrink by the first submission's jitter).
        let spread = paced.last_submit_us - paced.first_submit_us;
        assert!(
            spread >= trace.span_us() / 2,
            "submissions not paced: spread {spread}µs vs trace span {}µs",
            trace.span_us()
        );
    }
    println!("\nserve_trace OK (paced)");
    fleet_demo()
}

/// Deterministic 2-replica fleet replay surviving one injected stall:
/// replica 0 freezes for 60 ms mid-trace, the step-progress watermark
/// (5 ms threshold) flags it, inflight work is evacuated and re-routed
/// to replica 1, and the stalled replica recovers once the window ends.
/// Runs on the fleet's shared virtual clock, so the printed report is
/// byte-identical on every machine and every pool width — and so are
/// the trace/metrics exports the demo writes under `target/`.
fn fleet_demo() -> Result<()> {
    println!("\n== fleet demo: 2 replicas, one injected 60 ms stall ==");
    let plan = FaultPlan::parse("stall:0@40000+60000")?;
    println!("fault plan: {}  (watermark threshold 5 ms, policy failover)", plan.render());
    let opts = FleetOptions { stall_threshold_us: 5_000, ..FleetOptions::default() };
    let mut fleet = Fleet::build(2, plan, opts, |clock| {
        let geom = ModelGeom { vocab: 64, n_layers: 2, row_elems: 4, planes: 2, max_seq: 64 };
        let backend = MockBackend::new(geom, vec![1, 2, 4, 8]);
        let mut e = Engine::with_clock(backend, 40, 4, 0.5, clock);
        e.set_prefill_chunk(4);
        e
    });
    let obs = Obs::new();
    fleet.set_obs(obs.clone());
    let trace = Trace::poisson(48, 400.0, SeqlenDist::Fixed(24), (8, 8), 64, 42);
    let requests = loadgen::synthesize_requests(&trace, 64, 16, 8, 7);
    let service =
        loadgen::ServiceModel { step_base_us: 200, step_per_seq_us: 50, step_prefill_token_us: 50 };
    let report = fleet.replay(&requests, &service, 1_000_000)?;
    print!("{}", report.render());
    assert!(report.unhealthy_transitions >= 1, "the stall must trip the watermark detector");
    assert!(report.failed.is_empty(), "no request may be lost to the stall");
    assert_eq!(report.completed(), requests.len(), "every request completes despite the stall");

    // The run, as a timeline: write the exports and show where the
    // microseconds went.
    let out_dir = format!("{}/target", env!("CARGO_MANIFEST_DIR"));
    std::fs::create_dir_all(&out_dir)?;
    let trace_path = format!("{out_dir}/serve_trace_demo.trace.json");
    let prom_path = format!("{out_dir}/serve_trace_demo.prom");
    std::fs::write(&trace_path, obs.chrome_trace())?;
    std::fs::write(&prom_path, obs.prometheus())?;
    println!("\ntrace written to {trace_path} (load in chrome://tracing or Perfetto)");
    println!("metrics written to {prom_path}");

    let mut spans: Vec<_> = obs
        .events()
        .into_iter()
        .filter(|e| matches!(e.phase, TracePhase::Span { .. }))
        .collect();
    spans.sort_by_key(|e| (std::cmp::Reverse(e.dur_us()), e.ts_us, e.pid, e.tid));
    println!("5 largest spans:");
    for e in spans.iter().take(5) {
        println!(
            "  {:>10} µs  [{}] {}  (replica {}, track {}, t={} µs)",
            e.dur_us(),
            e.cat,
            e.name,
            e.pid,
            e.tid,
            e.ts_us
        );
    }
    let evacuations =
        obs.events().iter().filter(|e| e.name == "evacuate" && e.cat == "fleet").count() as u64;
    assert_eq!(evacuations, report.evacuated, "trace evacuations must match the report");
    println!("fleet demo OK (stall detected, failed over, zero lost)");
    Ok(())
}
