# Top-level targets. `make tier1` mirrors the ROADMAP tier-1 verify and is
# what CI runs; `make artifacts` needs a JAX-capable Python (layer 1/2).

.PHONY: tier1 tier1-simd build test test-simd test-load test-router test-block test-prefill test-parallel test-fleet test-obs trace-demo bench-compile bench-smoke bench-smoke-simd quickstart artifacts clean

tier1: build test test-load test-router test-block test-prefill test-parallel test-fleet test-obs bench-compile bench-smoke quickstart

# The explicit-SIMD build (`--features simd`, util::linalg lane-group
# kernels): the full tier-1 test surface plus the bench smoke run under
# the feature. CI runs this as its own matrix dimension crossed with the
# pool-width legs.
tier1-simd: test-simd bench-smoke-simd

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q --workspace

# Same surface under the explicit-SIMD linalg kernels. Outputs may differ
# in bits from the default build (the documented lane-group reduction
# order) but must be byte-identical across pool widths and runs — the
# invariance suites assert exactly that in both builds.
test-simd:
	cd rust && cargo test -q --workspace --features simd

# Saturation load tests on the virtual clock (also run by `test`; the
# explicit target keeps the tier-1 intent visible and fails fast on
# pacing/percentile regressions).
test-load:
	cd rust && cargo test -q --test integration_load

# Front-door suite (also run by `test`): latency-targeted admission —
# token budget, SLO projection, growth gate — end to end on the virtual
# clock, plus the router eligibility/ledger regressions.
test-router:
	cd rust && cargo test -q --test integration_router

# Full-block subsystem suite (also run by `test`): functional block
# pipeline vs frozen scalar reference, greedy determinism, fusion-scope
# cost properties, functional-backend replay.
test-block:
	cd rust && cargo test -q --test integration_block

# Prefill differential suite (also run by `test`): chunked/one-shot
# prefill byte-identical to the decode-as-prefill baseline, recompute
# preemption discards fed progress.
test-prefill:
	cd rust && cargo test -q --test integration_prefill

# Thread-count invariance suite (also run by `test`): pooled execution
# byte-identical across pool sizes; util::pool unit semantics.
test-parallel:
	cd rust && cargo test -q --test integration_parallel

# Fleet suite (also run by `test`): replicated serving with deterministic
# fault injection — failover determinism, zero-loss crash recovery, fleet
# deadlines, router token-budget leak property.
test-fleet:
	cd rust && cargo test -q --test integration_fleet

# Observability suite (also run by `test`): byte-stable trace/metrics
# exports across runs and pool widths, registry counters equal to the
# replay/fleet reports, Chrome-trace parse-back with well-formed nesting.
test-obs:
	cd rust && cargo test -q --test integration_obs

# Emit a Chrome/Perfetto trace + Prometheus snapshot of the pinned PR 8
# crash scenario (replica 0 crashes at t=120 ms under 450 rps; failover
# re-routes its work). Load target/trace_demo.json in chrome://tracing.
trace-demo:
	cd rust && cargo run --release -- serve --mock --replicas 2 \
		--fault-plan crash:0@120000 --requests 160 --rps 450 \
		--trace-out target/trace_demo.json --metrics-out target/trace_demo.prom

bench-compile:
	cd rust && cargo bench --no-run

# Execute the hot-path harness with ~20 ms budgets per case: keeps the
# bench harness (incl. the linalg before/after pair and the 1e5 evals/s
# advisory) exercised in CI without burning minutes. Numbers from smoke
# runs are noisy; use `cargo bench --bench hotpath` for EXPERIMENTS.md.
bench-smoke:
	cd rust && cargo bench --bench hotpath -- --smoke

bench-smoke-simd:
	cd rust && cargo bench --bench hotpath --features simd -- --smoke

quickstart:
	cd rust && cargo run --release --example quickstart

# AOT-lower the demo models to HLO text + manifest (python/compile/aot.py).
artifacts:
	cd python && python -m compile.aot --out ../rust/artifacts

clean:
	cd rust && cargo clean
