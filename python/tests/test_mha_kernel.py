"""Fused MHA Pallas kernel vs the pure-jnp oracle (DESIGN.md §4 L1).

hypothesis sweeps shapes/dtypes/cache-fill patterns; every case asserts
allclose between `fused_mha_decode` and `mha_decode_ref`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_decode import fused_mha_decode
from compile.kernels.ref import mha_decode_ref


def make_case(seed, b, d, nh, dh, s, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    hidden = jax.random.normal(ks[0], (b, d), jnp.float32).astype(dtype)
    wq = (jax.random.normal(ks[1], (d, nh, dh)) * 0.2).astype(dtype)
    wk = (jax.random.normal(ks[2], (d, nh, dh)) * 0.2).astype(dtype)
    wv = (jax.random.normal(ks[3], (d, nh, dh)) * 0.2).astype(dtype)
    wo = (jax.random.normal(ks[4], (nh, dh, d)) * 0.2).astype(dtype)
    kc = jax.random.normal(ks[5], (b, s, nh, dh)).astype(dtype)
    vc = jax.random.normal(ks[6], (b, s, nh, dh)).astype(dtype)
    pos = jax.random.randint(ks[7], (b,), 0, s + 1).astype(jnp.int32)
    return hidden, wq, wk, wv, wo, kc, vc, pos


def check(case, chunk, rtol, atol):
    ref = mha_decode_ref(*case)
    out = fused_mha_decode(*case, chunk=chunk)
    for r, o, name in zip(ref, out, ["out", "k_new", "v_new"]):
        np.testing.assert_allclose(
            np.asarray(r, np.float32), np.asarray(o, np.float32),
            rtol=rtol, atol=atol, err_msg=name,
        )


@settings(deadline=None, max_examples=12)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.sampled_from([1, 2, 3]),
    nh=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([4, 8, 16]),
    s_chunks=st.integers(1, 4),
    chunk=st.sampled_from([4, 8]),
)
def test_matches_ref_f32_sweep(seed, b, nh, dh, s_chunks, chunk):
    d = nh * dh  # keep D tied to heads; D is independent below
    case = make_case(seed, b, d, nh, dh, s_chunks * chunk, jnp.float32)
    check(case, chunk, rtol=3e-5, atol=3e-5)


@settings(deadline=None, max_examples=6)
@given(seed=st.integers(0, 2**31 - 1), d=st.sampled_from([24, 40, 96]))
def test_d_model_decoupled_from_heads(seed, d):
    case = make_case(seed, 2, d, 2, 8, 16, jnp.float32)
    check(case, 8, rtol=3e-5, atol=3e-5)


@settings(deadline=None, max_examples=4)
@given(seed=st.integers(0, 2**31 - 1))
def test_bf16_loose(seed):
    case = make_case(seed, 2, 32, 2, 16, 16, jnp.bfloat16)
    check(case, 8, rtol=5e-2, atol=5e-2)


def test_empty_cache_first_token():
    """pos == 0: only the self token participates (first decode step)."""
    case = make_case(0, 2, 32, 2, 16, 16, jnp.float32)
    case = case[:-1] + (jnp.zeros((2,), jnp.int32),)
    check(case, 8, rtol=3e-5, atol=3e-5)


def test_full_cache():
    """pos == S: every cache slot participates."""
    case = make_case(1, 2, 32, 2, 16, 16, jnp.float32)
    case = case[:-1] + (jnp.full((2,), 16, jnp.int32),)
    check(case, 8, rtol=3e-5, atol=3e-5)


def test_masked_slots_do_not_leak():
    """Garbage beyond pos[b] must not change the output (the paper's
    masking of the padded KV segment)."""
    case = make_case(2, 2, 32, 2, 16, 16, jnp.float32)
    hidden, wq, wk, wv, wo, kc, vc, _ = case
    pos = jnp.array([5, 9], jnp.int32)
    out1 = fused_mha_decode(hidden, wq, wk, wv, wo, kc, vc, pos, chunk=8)
    kc2 = kc.at[0, 5:].set(1e4)
    vc2 = vc.at[0, 5:].set(-1e4)
    kc2 = kc2.at[1, 9:].set(333.0)
    out2 = fused_mha_decode(hidden, wq, wk, wv, wo, kc2, vc2, pos, chunk=8)
    for a, b_ in zip(out1, out2):
        np.testing.assert_allclose(a, b_, rtol=1e-6, atol=1e-6)


def test_chunk_invariance():
    """Result must not depend on the KV tile size (the paper's cluster size
    N must not change numerics, only performance)."""
    case = make_case(3, 2, 32, 2, 16, 32, jnp.float32)
    outs = [fused_mha_decode(*case, chunk=c) for c in (4, 8, 16, 32)]
    for o in outs[1:]:
        for a, b_ in zip(outs[0], o):
            np.testing.assert_allclose(a, b_, rtol=2e-5, atol=2e-5)


def test_bad_chunk_raises():
    case = make_case(4, 1, 16, 1, 16, 12, jnp.float32)
    with pytest.raises(ValueError):
        fused_mha_decode(*case, chunk=8)


def test_single_head_single_chunk():
    case = make_case(5, 1, 16, 1, 16, 8, jnp.float32)
    check(case, 8, rtol=3e-5, atol=3e-5)
