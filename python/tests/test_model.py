"""Layer-2 model tests: decode-step semantics, cache handling, and the
kernel/oracle differential (DESIGN.md §4 L2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref as kref

MICRO_MHA = M.ModelConfig(
    name="micro-mha", vocab=64, d_model=32, n_layers=2, n_heads=2,
    head_dim=8, ffn_dim=48, max_seq=16, attn="mha", kv_chunk=8,
)
MICRO_MLA = M.ModelConfig(
    name="micro-mla", vocab=64, d_model=32, n_layers=2, n_heads=2,
    head_dim=8, ffn_dim=48, max_seq=16, attn="mla", kv_lora_rank=12, kv_chunk=8,
)


@pytest.fixture(params=[MICRO_MHA, MICRO_MLA], ids=["mha", "mla"])
def setup(request):
    cfg = request.param
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache = M.init_cache(cfg, 2)
    return cfg, params, cache


def test_kernel_matches_oracle_model(setup):
    cfg, params, cache = setup
    toks = jnp.array([3, 5], jnp.int32)
    pos = jnp.array([0, 4], jnp.int32)
    l1, c1 = M.decode_step(cfg, params, toks, pos, cache, use_kernel=True)
    l2, c2 = M.decode_step(cfg, params, toks, pos, cache, use_kernel=False)
    np.testing.assert_allclose(l1, l2, rtol=3e-5, atol=3e-5)
    for k in c1:
        np.testing.assert_allclose(c1[k], c2[k], rtol=3e-5, atol=3e-5)


def test_cache_append_at_pos(setup):
    cfg, params, cache = setup
    toks = jnp.array([1, 2], jnp.int32)
    pos = jnp.array([0, 7], jnp.int32)
    _, c1 = M.decode_step(cfg, params, toks, pos, cache, use_kernel=True)
    for k, arr in c1.items():
        arr = np.asarray(arr)
        # new entry lands exactly at pos[b], everything else untouched (zeros)
        assert np.abs(arr[:, 0, 0]).sum() > 0, f"{k}: row0 slot0 not written"
        assert np.abs(arr[:, 0, 1:]).sum() == 0
        assert np.abs(arr[:, 1, 7]).sum() > 0, f"{k}: row1 slot7 not written"
        mask = np.ones(cfg.max_seq, bool)
        mask[7] = False
        assert np.abs(arr[:, 1, mask]).sum() == 0


def test_autoregressive_consistency():
    """Decoding token-by-token with the incremental cache must equal
    attention computed over the explicitly accumulated history."""
    cfg = MICRO_MHA
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    cache = M.init_cache(cfg, 1)
    toks = [3, 9, 14, 27]
    logits_steps = []
    pos = jnp.zeros((1,), jnp.int32)
    for i, t in enumerate(toks):
        lg, cache = M.decode_step(
            cfg, params, jnp.array([t], jnp.int32), pos, cache, use_kernel=True
        )
        logits_steps.append(np.asarray(lg))
        pos = pos + 1

    # independent recomputation of the final step with a fresh cache built
    # from the oracle path
    cache2 = M.init_cache(cfg, 1)
    pos2 = jnp.zeros((1,), jnp.int32)
    for t in toks[:-1]:
        _, cache2 = M.decode_step(
            cfg, params, jnp.array([t], jnp.int32), pos2, cache2, use_kernel=False
        )
        pos2 = pos2 + 1
    lg2, _ = M.decode_step(
        cfg, params, jnp.array([toks[-1]], jnp.int32), pos2, cache2, use_kernel=False
    )
    np.testing.assert_allclose(logits_steps[-1], np.asarray(lg2), rtol=2e-4, atol=2e-4)


def test_logits_finite_and_shape(setup):
    cfg, params, cache = setup
    lg, _ = M.decode_step(
        cfg, params, jnp.array([0, 1], jnp.int32), jnp.array([0, 0], jnp.int32),
        cache, use_kernel=True,
    )
    assert lg.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg)))


def test_flat_roundtrip(setup):
    cfg, params, cache = setup
    flat = M.flatten_params(cfg, params)
    assert len(flat) == len(M.param_order(cfg))
    rt = M.unflatten_params(cfg, flat)
    for k in params:
        np.testing.assert_array_equal(params[k], rt[k])


def test_decode_step_flat_matches_dict(setup):
    cfg, params, cache = setup
    cache_keys = ("k", "v") if cfg.attn == "mha" else ("kv",)
    toks = jnp.array([3, 5], jnp.int32)
    pos = jnp.array([2, 0], jnp.int32)
    f = M.decode_step_flat(cfg)
    outs = f(toks, pos, *[cache[k] for k in cache_keys], *M.flatten_params(cfg, params))
    lg_ref, cache_ref = M.decode_step(cfg, params, toks, pos, cache)
    np.testing.assert_allclose(outs[0], lg_ref, rtol=1e-6, atol=1e-6)
    for o, k in zip(outs[1:], cache_keys):
        np.testing.assert_allclose(o, cache_ref[k], rtol=1e-6, atol=1e-6)


def test_param_counts_match_reference_models():
    assert abs(M.TINY_LLAMA_100M.param_count() - 97.5e6) < 2e6
    # paper models: order-of-magnitude sanity (7B, 16B-class MLA lite)
    assert 6.0e9 < M.LLAMA2_7B.param_count() < 7.5e9


def test_rmsnorm_swiglu_shapes():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 16))
    w = jnp.ones((16,))
    y = kref.rmsnorm_ref(x, w)
    assert y.shape == x.shape
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    w1 = jax.random.normal(jax.random.PRNGKey(1), (16, 24)) * 0.1
    w2 = jax.random.normal(jax.random.PRNGKey(2), (16, 24)) * 0.1
    w3 = jax.random.normal(jax.random.PRNGKey(3), (24, 16)) * 0.1
    z = kref.swiglu_ref(x, w1, w2, w3)
    assert z.shape == x.shape


def test_serving_interface_matches_device_append(setup):
    """The host-authoritative serving contract (decode_step_knew returns
    new rows; the host appends) must be exactly equivalent to the
    self-contained decode_step that appends on device — this is the
    invariant the Rust engine's paged KV cache relies on."""
    cfg, params, cache = setup
    toks = jnp.array([3, 5], jnp.int32)
    pos = jnp.array([2, 0], jnp.int32)
    lg_dev, cache_dev = M.decode_step(cfg, params, toks, pos, cache, use_kernel=True)
    lg_srv, new_rows = M.decode_step_knew(cfg, params, toks, pos, cache, use_kernel=True)
    np.testing.assert_allclose(lg_dev, lg_srv, rtol=1e-6, atol=1e-6)
    # host-side append of the returned rows must reconstruct the device cache
    cache_keys = ("k", "v") if cfg.attn == "mha" else ("kv",)
    for key, rows in zip(cache_keys, new_rows):
        host = np.asarray(cache[key]).copy()  # (L, B, S, ...)
        rows = np.asarray(rows)  # (L, B, ...)
        for l in range(cfg.n_layers):
            for b in range(2):
                host[l, b, int(pos[b])] = rows[l, b]
        np.testing.assert_allclose(host, cache_dev[key], rtol=1e-6, atol=1e-6)


def test_multistep_serving_equals_device_path():
    """Three autoregressive steps through the serving interface (host
    appends) equal three steps through the device-append interface."""
    cfg = MICRO_MHA
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    toks = [jnp.array([4], jnp.int32), jnp.array([9], jnp.int32), jnp.array([1], jnp.int32)]

    cache_a = M.init_cache(cfg, 1)
    cache_b = {k: np.asarray(v).copy() for k, v in M.init_cache(cfg, 1).items()}
    logits_a, logits_b = [], []
    for i, t in enumerate(toks):
        pos = jnp.array([i], jnp.int32)
        lg_a, cache_a = M.decode_step(cfg, params, t, pos, cache_a, use_kernel=True)
        logits_a.append(np.asarray(lg_a))
        lg_b, rows = M.decode_step_knew(
            cfg, params, t, pos, {k: jnp.asarray(v) for k, v in cache_b.items()},
            use_kernel=True,
        )
        logits_b.append(np.asarray(lg_b))
        for key, r in zip(("k", "v"), rows):
            cache_b[key][:, 0, i] = np.asarray(r)[:, 0]
    for a, b in zip(logits_a, logits_b):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
