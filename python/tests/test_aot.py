"""AOT path tests: lowering, manifest interface, HLO text sanity."""

import json

import jax.numpy as jnp

from compile import aot, model as M

MICRO = M.ModelConfig(
    name="micro-aot", vocab=64, d_model=32, n_layers=2, n_heads=2,
    head_dim=8, ffn_dim=48, max_seq=16, attn="mha", kv_chunk=8,
)
MICRO_MLA = M.ModelConfig(
    name="micro-aot-mla", vocab=64, d_model=32, n_layers=2, n_heads=2,
    head_dim=8, ffn_dim=48, max_seq=16, attn="mla", kv_lora_rank=12, kv_chunk=8,
)


def test_lower_decode_mha():
    text, iface = aot.lower_decode(MICRO, 2)
    assert text.startswith("HloModule")
    assert iface["n_cache"] == 2
    names = [i["name"] for i in iface["inputs"]]
    assert names[:4] == ["tokens", "pos", "cache_k", "cache_v"]
    assert names[4:] == [f"param_{n}" for n in M.param_order(MICRO)]
    assert iface["outputs"][0]["name"] == "logits"
    assert iface["outputs"][0]["shape"] == [2, 64]
    # cache outputs mirror cache inputs exactly (rotation contract)
    assert iface["outputs"][1]["shape"] == iface["inputs"][2]["shape"]
    assert iface["outputs"][2]["shape"] == iface["inputs"][3]["shape"]


def test_lower_decode_mla():
    text, iface = aot.lower_decode(MICRO_MLA, 1)
    assert text.startswith("HloModule")
    assert iface["n_cache"] == 1
    assert iface["inputs"][2]["name"] == "cache_kv"
    assert iface["inputs"][2]["shape"] == [2, 1, 16, 12]  # (L,B,S,r)


def test_interface_is_json_serialisable():
    _, iface = aot.lower_decode(MICRO, 1)
    json.dumps(iface)


def test_kernel_and_oracle_lower_to_same_interface():
    _, a = aot.lower_decode(MICRO, 1, use_kernel=True)
    _, b = aot.lower_decode(MICRO, 1, use_kernel=False)
    a.pop("file", None), b.pop("file", None)
    assert a == b


def test_dtype_strings():
    _, iface = aot.lower_decode(MICRO, 1)
    for i in iface["inputs"]:
        assert i["dtype"] in ("int32", "float32"), i
