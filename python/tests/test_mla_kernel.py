"""Fused MLA Pallas kernel vs the pure-jnp oracle (paper Alg. 4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.mla_decode import fused_mla_decode
from compile.kernels.ref import mla_decode_ref


def make_case(seed, b, d, nh, l, dh, s, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    hidden = jax.random.normal(ks[0], (b, d), jnp.float32).astype(dtype)
    wq = (jax.random.normal(ks[1], (d, nh, l)) * 0.2).astype(dtype)
    wkv = (jax.random.normal(ks[2], (d, l)) * 0.2).astype(dtype)
    wd = (jax.random.normal(ks[3], (nh, l, dh)) * 0.2).astype(dtype)
    wo = (jax.random.normal(ks[4], (nh, dh, d)) * 0.2).astype(dtype)
    kvc = jax.random.normal(ks[5], (b, s, l)).astype(dtype)
    pos = jax.random.randint(ks[6], (b,), 0, s + 1).astype(jnp.int32)
    return hidden, wq, wkv, wd, wo, kvc, pos


def check(case, chunk, rtol, atol):
    ref = mla_decode_ref(*case)
    out = fused_mla_decode(*case, chunk=chunk)
    for r, o, name in zip(ref, out, ["out", "kv_new"]):
        np.testing.assert_allclose(
            np.asarray(r, np.float32), np.asarray(o, np.float32),
            rtol=rtol, atol=atol, err_msg=name,
        )


@settings(deadline=None, max_examples=12)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.sampled_from([1, 2, 3]),
    nh=st.sampled_from([1, 2, 4]),
    l=st.sampled_from([8, 16, 24]),
    dh=st.sampled_from([4, 8]),
    s_chunks=st.integers(1, 4),
)
def test_matches_ref_f32_sweep(seed, b, nh, l, dh, s_chunks):
    case = make_case(seed, b, 32, nh, l, dh, s_chunks * 8, jnp.float32)
    check(case, 8, rtol=3e-5, atol=3e-5)


@settings(deadline=None, max_examples=4)
@given(seed=st.integers(0, 2**31 - 1))
def test_bf16_loose(seed):
    case = make_case(seed, 2, 32, 2, 16, 8, 16, jnp.bfloat16)
    check(case, 8, rtol=5e-2, atol=5e-2)


def test_empty_cache_first_token():
    case = make_case(0, 2, 32, 2, 16, 8, 16, jnp.float32)
    case = case[:-1] + (jnp.zeros((2,), jnp.int32),)
    check(case, 8, rtol=3e-5, atol=3e-5)


def test_full_cache():
    case = make_case(1, 2, 32, 2, 16, 8, 16, jnp.float32)
    case = case[:-1] + (jnp.full((2,), 16, jnp.int32),)
    check(case, 8, rtol=3e-5, atol=3e-5)


def test_masked_slots_do_not_leak():
    hidden, wq, wkv, wd, wo, kvc, _ = make_case(2, 2, 32, 2, 16, 8, 16, jnp.float32)
    pos = jnp.array([3, 11], jnp.int32)
    out1 = fused_mla_decode(hidden, wq, wkv, wd, wo, kvc, pos, chunk=8)
    kvc2 = kvc.at[0, 3:].set(9e3).at[1, 11:].set(-7e3)
    out2 = fused_mla_decode(hidden, wq, wkv, wd, wo, kvc2, pos, chunk=8)
    for a, b_ in zip(out1, out2):
        np.testing.assert_allclose(a, b_, rtol=1e-6, atol=1e-6)


def test_chunk_invariance():
    case = make_case(3, 2, 32, 2, 16, 8, 32, jnp.float32)
    outs = [fused_mla_decode(*case, chunk=c) for c in (4, 8, 16, 32)]
    for o in outs[1:]:
        for a, b_ in zip(outs[0], o):
            np.testing.assert_allclose(a, b_, rtol=2e-5, atol=2e-5)


def test_kv_new_shared_across_heads():
    """kv_new is head-independent (MQA-style latent cache): computing with
    1 head or 4 heads must give the same kv_new."""
    hidden, wq, wkv, wd, wo, kvc, pos = make_case(4, 2, 32, 4, 16, 8, 16, jnp.float32)
    _, kv4 = fused_mla_decode(hidden, wq, wkv, wd, wo, kvc, pos, chunk=8)
    _, kv1 = fused_mla_decode(
        hidden, wq[:, :1], wkv, wd[:1], wo[:1], kvc, pos, chunk=8
    )
    np.testing.assert_allclose(kv4, kv1, rtol=1e-6, atol=1e-6)


def test_bad_chunk_raises():
    case = make_case(5, 1, 16, 1, 8, 4, 12, jnp.float32)
    with pytest.raises(ValueError):
        fused_mla_decode(*case, chunk=8)
