"""Fused MHA decode kernel (paper Alg. 3, "SplitToken" dataflow) in Pallas.

One `pallas_call` fuses *QKV Projection + Attention + Output Projection* for
a single decode step — the paper's expanded fusion scope — so none of the
Q/K/V vectors, softmax statistics, or per-head attention outputs are ever
materialised to HBM.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's thread
block *cluster* (one per attention head, blocks partitioning the KV
sequence) becomes the Pallas grid `(heads, kv_chunks)`; DSMEM exchange
becomes VMEM scratch carried across the sequential grid:

  * ClusterGather of Q/K/V segments  -> Q/K_new/V_new tiles computed once per
    head into VMEM scratch (grid step c==0) and reused by later chunks.
  * ClusterReduce of softmax stats   -> online-softmax (m, l) accumulators in
    VMEM scratch updated chunk-by-chunk (FlashDecoding-style partials).
  * ClusterReduce of attention out   -> the `acc` VMEM accumulator.
  * atomicAdd of the output tiles    -> `o_ref[...] +=` into a single output
    block revisited by every grid step (zeroed at the first step).

Grid iteration is row-major (head-major), so per-head scratch written at
chunk 0 is live for all chunks of that head.

Must run with interpret=True on CPU; real-TPU lowering of the same kernel is
a compile-only target (Mosaic custom-call).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_BIG = -1e30


def _mha_kernel(
    hidden_ref,  # (B, D)
    wq_ref,  # (D, 1, dh)
    wk_ref,  # (D, 1, dh)
    wv_ref,  # (D, 1, dh)
    wo_ref,  # (1, dh, D)
    k_cache_ref,  # (B, chunk, 1, dh)
    v_cache_ref,  # (B, chunk, 1, dh)
    pos_ref,  # (B,)
    o_ref,  # (B, D)  accumulated across all grid steps
    k_new_ref,  # (B, 1, dh)
    v_new_ref,  # (B, 1, dh)
    q_s,  # scratch (B, dh) f32
    kn_s,  # scratch (B, dh) f32
    vn_s,  # scratch (B, dh) f32
    acc_s,  # scratch (B, dh) f32
    m_s,  # scratch (B, 1) f32
    l_s,  # scratch (B, 1) f32
    *,
    chunk: int,
    num_chunks: int,
    scale: float,
):
    c = pl.program_id(1)
    h_first = pl.program_id(0) == 0

    @pl.when(h_first & (c == 0))
    def _zero_out():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(c == 0)
    def _project_qkv():
        # QKV projection for this head (paper: segment matmul +
        # ClusterGather; here: one VMEM-resident tile per head).
        h = hidden_ref[...].astype(jnp.float32)  # (B, D)
        q_s[...] = h @ wq_ref[:, 0, :].astype(jnp.float32)
        kn_s[...] = h @ wk_ref[:, 0, :].astype(jnp.float32)
        vn_s[...] = h @ wv_ref[:, 0, :].astype(jnp.float32)
        k_new_ref[:, 0, :] = kn_s[...].astype(k_new_ref.dtype)
        v_new_ref[:, 0, :] = vn_s[...].astype(v_new_ref.dtype)
        m_s[...] = jnp.full_like(m_s, _NEG_BIG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    # ---- FlashDecoding-style partial attention over this KV chunk ----
    q = q_s[...]  # (B, dh) f32
    k_chunk = k_cache_ref[:, :, 0, :].astype(jnp.float32)  # (B, chunk, dh)
    v_chunk = v_cache_ref[:, :, 0, :].astype(jnp.float32)
    scores = jnp.einsum("bk,bsk->bs", q, k_chunk) * scale  # (B, chunk)

    pos = pos_ref[...]  # (B,) int32
    idx = c * chunk + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    mask = idx < pos[:, None]
    scores = jnp.where(mask, scores, _NEG_BIG)

    m_prev, l_prev = m_s[...], l_s[...]
    m_cur = jnp.max(scores, axis=-1, keepdims=True)  # (B, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new) * mask.astype(jnp.float32)
    l_s[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jnp.einsum("bs,bsk->bk", p, v_chunk)
    m_s[...] = m_new

    @pl.when(c == num_chunks - 1)
    def _finish_head():
        # Fold in the freshly produced token's own K/V (it is always valid),
        # normalise (paper: ClusterReduce of S_sum/S_max then rescale), and
        # apply this head's slice of the output projection.
        s_self = jnp.sum(q_s[...] * kn_s[...], axis=-1, keepdims=True) * scale
        m_prev2, l_prev2 = m_s[...], l_s[...]
        m_fin = jnp.maximum(m_prev2, s_self)
        alpha2 = jnp.exp(m_prev2 - m_fin)
        p_self = jnp.exp(s_self - m_fin)  # (B, 1)
        l_fin = l_prev2 * alpha2 + p_self
        acc = acc_s[...] * alpha2 + p_self * vn_s[...]
        attn = acc / l_fin  # (B, dh)
        wo = wo_ref[0].astype(jnp.float32)  # (dh, D)
        o_ref[...] += (attn @ wo).astype(o_ref.dtype)


def fused_mha_decode(hidden, wq, wk, wv, wo, k_cache, v_cache, pos, *, chunk=None):
    """Fused single-token MHA decode step.

    Args mirror `ref.mha_decode_ref`; returns (out(B,D), k_new(B,nh,dh),
    v_new(B,nh,dh)). `chunk` is the KV-sequence tile per grid step (the
    paper's per-block KV segment); must divide S.
    """
    b, d = hidden.shape
    _, nh, dh = wq.shape
    s = k_cache.shape[1]
    if chunk is None:
        chunk = min(s, 128)
    if s % chunk != 0:
        raise ValueError(f"S={s} not divisible by chunk={chunk}")
    num_chunks = s // chunk
    scale = 1.0 / float(dh) ** 0.5

    kernel = functools.partial(
        _mha_kernel, chunk=chunk, num_chunks=num_chunks, scale=scale
    )
    grid = (nh, num_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda h, c: (0, 0)),  # hidden
            pl.BlockSpec((d, 1, dh), lambda h, c: (0, h, 0)),  # wq
            pl.BlockSpec((d, 1, dh), lambda h, c: (0, h, 0)),  # wk
            pl.BlockSpec((d, 1, dh), lambda h, c: (0, h, 0)),  # wv
            pl.BlockSpec((1, dh, d), lambda h, c: (h, 0, 0)),  # wo
            pl.BlockSpec((b, chunk, 1, dh), lambda h, c: (0, c, h, 0)),  # k$
            pl.BlockSpec((b, chunk, 1, dh), lambda h, c: (0, c, h, 0)),  # v$
            pl.BlockSpec((b,), lambda h, c: (0,)),  # pos
        ],
        out_specs=[
            pl.BlockSpec((b, d), lambda h, c: (0, 0)),  # out (accumulated)
            pl.BlockSpec((b, 1, dh), lambda h, c: (0, h, 0)),  # k_new
            pl.BlockSpec((b, 1, dh), lambda h, c: (0, h, 0)),  # v_new
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, d), hidden.dtype),
            jax.ShapeDtypeStruct((b, nh, dh), hidden.dtype),
            jax.ShapeDtypeStruct((b, nh, dh), hidden.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((b, dh), jnp.float32),  # q
            pltpu.VMEM((b, dh), jnp.float32),  # k_new
            pltpu.VMEM((b, dh), jnp.float32),  # v_new
            pltpu.VMEM((b, dh), jnp.float32),  # acc
            pltpu.VMEM((b, 1), jnp.float32),  # m (running max)
            pltpu.VMEM((b, 1), jnp.float32),  # l (running sum)
        ],
        interpret=True,
    )(hidden, wq, wk, wv, wo, k_cache, v_cache, pos)
