"""Fused MLA decode kernel (paper Alg. 4) in Pallas.

DeepSeek Multi-head Latent Attention, weight-absorbed decode form (paper
Appendix B.1, rope_dim omitted exactly as the paper does): one `pallas_call`
fuses the absorbed Q projection, the latent KV projection, attention over
the compressed latent cache (shared by all heads, MQA-style), the per-head
down projection, and the output projection.

Cluster -> grid mapping is identical to `fused_decode.py`: grid =
(heads, kv_chunks); the latent cache chunk plays the role of the per-block
KV segment; the new latent entry `kv_new` is computed once (first grid
step) into VMEM scratch and shared by every head — the analogue of the
paper's ClusterGather of the compressed KV.

interpret=True only on CPU (see fused_decode.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_BIG = -1e30


def _mla_kernel(
    hidden_ref,  # (B, D)
    wq_ref,  # (D, 1, l)   absorbed per-head query weights
    wkv_ref,  # (D, l)      latent KV projection (shared)
    w_down_ref,  # (1, l, dh)
    wo_ref,  # (1, dh, D)
    kv_cache_ref,  # (B, chunk, l)
    pos_ref,  # (B,)
    o_ref,  # (B, D)  accumulated
    kv_new_ref,  # (B, l)
    q_s,  # scratch (B, l)
    kv_s,  # scratch (B, l)  new latent entry, shared across heads
    acc_s,  # scratch (B, l)
    m_s,  # scratch (B, 1)
    l_s,  # scratch (B, 1)
    *,
    chunk: int,
    num_chunks: int,
    scale: float,
):
    h_idx = pl.program_id(0)
    c = pl.program_id(1)

    @pl.when((h_idx == 0) & (c == 0))
    def _once():
        # New latent cache entry: computed once, shared by all heads
        # (paper: KV Projection segments + ClusterGather).
        h = hidden_ref[...].astype(jnp.float32)
        kv_s[...] = h @ wkv_ref[...].astype(jnp.float32)
        kv_new_ref[...] = kv_s[...].astype(kv_new_ref.dtype)
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(c == 0)
    def _per_head():
        h = hidden_ref[...].astype(jnp.float32)
        q_s[...] = h @ wq_ref[:, 0, :].astype(jnp.float32)
        m_s[...] = jnp.full_like(m_s, _NEG_BIG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    # ---- partial attention over this latent-cache chunk ----
    q = q_s[...]  # (B, l)
    kv_chunk = kv_cache_ref[...].astype(jnp.float32)  # (B, chunk, l)
    scores = jnp.einsum("bl,bsl->bs", q, kv_chunk) * scale

    pos = pos_ref[...]
    idx = c * chunk + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    mask = idx < pos[:, None]
    scores = jnp.where(mask, scores, _NEG_BIG)

    m_prev, l_prev = m_s[...], l_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new) * mask.astype(jnp.float32)
    l_s[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jnp.einsum("bs,bsl->bl", p, kv_chunk)
    m_s[...] = m_new

    @pl.when(c == num_chunks - 1)
    def _finish_head():
        # Self token (value = the latent entry itself, MQA-style), then
        # down projection and output projection for this head.
        s_self = jnp.sum(q_s[...] * kv_s[...], axis=-1, keepdims=True) * scale
        m_prev2, l_prev2 = m_s[...], l_s[...]
        m_fin = jnp.maximum(m_prev2, s_self)
        alpha2 = jnp.exp(m_prev2 - m_fin)
        p_self = jnp.exp(s_self - m_fin)
        l_fin = l_prev2 * alpha2 + p_self
        attn = (acc_s[...] * alpha2 + p_self * kv_s[...]) / l_fin  # (B, l)
        z = attn @ w_down_ref[0].astype(jnp.float32)  # (B, dh)
        o_ref[...] += (z @ wo_ref[0].astype(jnp.float32)).astype(o_ref.dtype)


def fused_mla_decode(hidden, wq, wkv, w_down, wo, kv_cache, pos, *, chunk=None):
    """Fused single-token MLA decode step.

    Args mirror `ref.mla_decode_ref`; returns (out(B,D), kv_new(B,l)).
    """
    b, d = hidden.shape
    _, nh, l = wq.shape
    dh = w_down.shape[2]
    s = kv_cache.shape[1]
    if chunk is None:
        chunk = min(s, 128)
    if s % chunk != 0:
        raise ValueError(f"S={s} not divisible by chunk={chunk}")
    num_chunks = s // chunk
    scale = 1.0 / float(l) ** 0.5

    kernel = functools.partial(
        _mla_kernel, chunk=chunk, num_chunks=num_chunks, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(nh, num_chunks),
        in_specs=[
            pl.BlockSpec((b, d), lambda h, c: (0, 0)),  # hidden
            pl.BlockSpec((d, 1, l), lambda h, c: (0, h, 0)),  # wq
            pl.BlockSpec((d, l), lambda h, c: (0, 0)),  # wkv
            pl.BlockSpec((1, l, dh), lambda h, c: (h, 0, 0)),  # w_down
            pl.BlockSpec((1, dh, d), lambda h, c: (h, 0, 0)),  # wo
            pl.BlockSpec((b, chunk, l), lambda h, c: (0, c, 0)),  # kv cache
            pl.BlockSpec((b,), lambda h, c: (0,)),  # pos
        ],
        out_specs=[
            pl.BlockSpec((b, d), lambda h, c: (0, 0)),  # out (accumulated)
            pl.BlockSpec((b, l), lambda h, c: (0, 0)),  # kv_new
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, d), hidden.dtype),
            jax.ShapeDtypeStruct((b, l), hidden.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((b, l), jnp.float32),  # q
            pltpu.VMEM((b, l), jnp.float32),  # kv_new
            pltpu.VMEM((b, l), jnp.float32),  # acc
            pltpu.VMEM((b, 1), jnp.float32),  # m
            pltpu.VMEM((b, 1), jnp.float32),  # l
        ],
        interpret=True,
    )(hidden, wq, wkv, w_down, wo, kv_cache, pos)
