"""Pure-jnp correctness oracles for the ClusterFusion fused decode kernels.

These implement the *mathematical* content of the paper's fused dataflows
(Alg. 3 fused MHA decode, Alg. 4 fused MLA decode) with no fusion tricks:
plain projections, masked softmax attention over a padded KV cache, and the
output projection. The Pallas kernels in `fused_decode.py` / `mla_decode.py`
must match these (fp32 tight tolerance).

Shapes (B = batch, D = model dim, nh = heads, dh = head dim, S = padded KV
capacity, l = kv_lora_rank):

  mha_decode_ref(hidden(B,D), wq(D,nh,dh), wk, wv, wo(nh,dh,D),
                 k_cache(B,S,nh,dh), v_cache(B,S,nh,dh), pos(B,))
      -> (out(B,D), k_new(B,nh,dh), v_new(B,nh,dh))

  mla_decode_ref(hidden(B,D), wq(D,nh,l), wkv(D,l), w_down(nh,l,dh),
                 wo(nh,dh,D), kv_cache(B,S,l), pos(B,))
      -> (out(B,D), kv_new(B,l))

`pos[b]` is the number of valid cached tokens for sequence b; the newly
generated token attends to cache[0:pos[b]] plus itself.
"""

from __future__ import annotations

import jax.numpy as jnp


def _masked_softmax_rows(scores, pos, s):
    """Softmax over the last axis of `scores` (rows, S+1) where entries at
    cache index >= pos[row] are masked out. Index S (the last column) is the
    new token itself and is always valid."""
    idx = jnp.arange(s + 1)[None, :]  # (1, S+1)
    valid = (idx < pos[:, None]) | (idx == s)  # (rows, S+1)
    neg = jnp.finfo(scores.dtype).min
    scores = jnp.where(valid, scores, neg)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    e = jnp.where(valid, e, 0.0)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def mha_decode_ref(hidden, wq, wk, wv, wo, k_cache, v_cache, pos):
    """Reference fused QKV-projection + attention + output-projection for a
    single decode step (the computation of paper Alg. 3)."""
    b, d = hidden.shape
    _, nh, dh = wq.shape
    _, s, _, _ = k_cache.shape
    f32 = jnp.float32
    h = hidden.astype(f32)

    # QKV projection (paper: per-cluster segment matmul + ClusterGather).
    q = jnp.einsum("bd,dhk->bhk", h, wq.astype(f32))  # (B, nh, dh)
    k_new = jnp.einsum("bd,dhk->bhk", h, wk.astype(f32))
    v_new = jnp.einsum("bd,dhk->bhk", h, wv.astype(f32))

    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, f32))
    # Scores against the padded cache plus the new token (FlashDecoding
    # partials + ClusterReduce of softmax stats in the paper).
    s_cache = jnp.einsum("bhk,bshk->bhs", q, k_cache.astype(f32)) * scale
    s_self = jnp.einsum("bhk,bhk->bh", q, k_new)[:, :, None] * scale
    scores = jnp.concatenate([s_cache, s_self], axis=-1)  # (B, nh, S+1)

    probs = _masked_softmax_rows(
        scores.reshape(b * nh, s + 1),
        jnp.repeat(pos, nh),
        s,
    ).reshape(b, nh, s + 1)

    attn = jnp.einsum("bhs,bshk->bhk", probs[:, :, :s], v_cache.astype(f32))
    attn = attn + probs[:, :, s][:, :, None] * v_new  # (B, nh, dh)

    # Output projection (paper: per-cluster tile + atomicAdd).
    out = jnp.einsum("bhk,hkd->bd", attn, wo.astype(f32))
    return (
        out.astype(hidden.dtype),
        k_new.astype(hidden.dtype),
        v_new.astype(hidden.dtype),
    )


def mla_decode_ref(hidden, wq, wkv, w_down, wo, kv_cache, pos):
    """Reference fused MLA decode (paper Alg. 4, weight-absorbed form,
    rope_dim omitted exactly as in the paper's appendix).

    Q_h = H @ Wq[:, h]            (B, l)   absorbed query per head
    kv  = H @ Wkv                 (B, l)   new latent cache entry
    A_h = softmax(Q_h kv_cache^T) kv_cache  (B, l)
    z_h = A_h @ W_down[h]         (B, dh)
    out = sum_h z_h @ Wo[h]       (B, D)
    """
    b, d = hidden.shape
    _, nh, l = wq.shape
    _, s, _ = kv_cache.shape
    f32 = jnp.float32
    h = hidden.astype(f32)

    q = jnp.einsum("bd,dhl->bhl", h, wq.astype(f32))  # (B, nh, l)
    kv_new = h @ wkv.astype(f32)  # (B, l)

    scale = 1.0 / jnp.sqrt(jnp.asarray(l, f32))
    s_cache = jnp.einsum("bhl,bsl->bhs", q, kv_cache.astype(f32)) * scale
    s_self = jnp.einsum("bhl,bl->bh", q, kv_new)[:, :, None] * scale
    scores = jnp.concatenate([s_cache, s_self], axis=-1)  # (B, nh, S+1)

    probs = _masked_softmax_rows(
        scores.reshape(b * nh, s + 1), jnp.repeat(pos, nh), s
    ).reshape(b, nh, s + 1)

    attn = jnp.einsum("bhs,bsl->bhl", probs[:, :, :s], kv_cache.astype(f32))
    attn = attn + probs[:, :, s][:, :, None] * kv_new[:, None, :]  # (B, nh, l)

    z = jnp.einsum("bhl,hlk->bhk", attn, w_down.astype(f32))  # (B, nh, dh)
    out = jnp.einsum("bhk,hkd->bd", z, wo.astype(f32))
    return out.astype(hidden.dtype), kv_new.astype(hidden.dtype)


def rmsnorm_ref(x, weight, eps=1e-5):
    """RMSNorm with fp32 accumulation."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * (1.0 / jnp.sqrt(var + eps)) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def swiglu_ref(x, w1, w2, w3):
    """SwiGLU FFN: W3(silu(W1 x) * W2 x) — paper Eq. 2 with sigma = SiLU."""
    xf = x.astype(jnp.float32)
    a = xf @ w1.astype(jnp.float32)
    g = xf @ w2.astype(jnp.float32)
    silu = a * (1.0 / (1.0 + jnp.exp(-a)))
    return ((silu * g) @ w3.astype(jnp.float32)).astype(x.dtype)
