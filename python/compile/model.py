"""Layer-2: JAX decoder model whose attention block is the fused
ClusterFusion kernel (L1). Build-time only — lowered to HLO text by
`aot.py`, executed from Rust via PJRT. Never imported on the request path.

Two architectures, mirroring the paper's evaluation models:
  * "mha" — Llama-style: RMSNorm -> fused(QKV proj + attention + out proj)
    -> residual -> RMSNorm -> SwiGLU FFN -> residual. (Llama2-7B shape.)
  * "mla" — DeepSeek-style Multi-head Latent Attention, weight-absorbed
    decode form with a compressed latent KV cache. (DeepSeek-V2-Lite shape.)

Positions are used only for KV-cache masking/appending (the paper's fused
dataflow omits RoPE's rope_dim in its appendix; we follow it for the fused
scope — see DESIGN.md §Substitutions).

The public entrypoint is `decode_step(cfg, params, tokens, pos, caches)`:
one autoregressive step for a padded batch. All shapes are static; `pos[b]`
carries each sequence's live length. Layers are scanned so the lowered HLO
is one while-loop regardless of depth.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from compile.kernels import ref as kref
from compile.kernels.fused_decode import fused_mha_decode
from compile.kernels.mla_decode import fused_mla_decode


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architectural hyper-parameters (weights are random at run time; the
    decode-latency shape only depends on these dimensions)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    head_dim: int
    ffn_dim: int
    max_seq: int
    attn: Literal["mha", "mla"] = "mha"
    kv_lora_rank: int = 0  # only for attn == "mla"
    kv_chunk: int = 128  # Pallas kernel KV tile (paper: per-block segment)

    def param_count(self) -> int:
        d, f, v, l_ = self.d_model, self.ffn_dim, self.vocab, self.n_layers
        h = self.n_heads * self.head_dim
        if self.attn == "mha":
            attn = d * h * 3 + h * d
        else:
            r = self.kv_lora_rank
            attn = d * self.n_heads * r + d * r + self.n_heads * r * self.head_dim + h * d
        per_layer = attn + 3 * d * f + 2 * d
        return v * d + l_ * per_layer + d


# ---------------------------------------------------------------------------
# Reference model configurations (paper §4 Models + the e2e demo model).
# ---------------------------------------------------------------------------

TINY_LLAMA_100M = ModelConfig(
    name="tiny-llama-100m",
    vocab=16384,
    d_model=768,
    n_layers=12,
    n_heads=12,
    head_dim=64,
    ffn_dim=2048,
    max_seq=512,
    attn="mha",
    kv_chunk=512,
)

TINY_MLA_100M = ModelConfig(
    name="tiny-mla-100m",
    vocab=16384,
    d_model=768,
    n_layers=12,
    n_heads=12,
    head_dim=64,
    ffn_dim=2048,
    max_seq=512,
    attn="mla",
    kv_lora_rank=128,
    kv_chunk=512,
)

# Architectural shapes of the paper's evaluation models (used by the Rust
# simulator for cost modelling; too big to execute live here).
LLAMA2_7B = ModelConfig(
    name="llama2-7b",
    vocab=32000,
    d_model=4096,
    n_layers=32,
    n_heads=32,
    head_dim=128,
    ffn_dim=11008,
    max_seq=16384,
    attn="mha",
)

DEEPSEEK_V2_LITE = ModelConfig(
    name="deepseek-v2-lite",
    vocab=102400,
    d_model=2048,
    n_layers=27,
    n_heads=16,
    head_dim=128,
    ffn_dim=10944,
    max_seq=16384,
    attn="mla",
    kv_lora_rank=512,
)

CONFIGS = {
    c.name: c for c in (TINY_LLAMA_100M, TINY_MLA_100M, LLAMA2_7B, DEEPSEEK_V2_LITE)
}


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    """Random parameters with 1/sqrt(fan_in) scaling; layer weights stacked
    on a leading axis so decode_step can lax.scan over layers."""
    d, f, nh, dh, l_ = cfg.d_model, cfg.ffn_dim, cfg.n_heads, cfg.head_dim, cfg.n_layers
    keys = iter(jax.random.split(key, 16))

    def w(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)

    params = {
        "emb": w(next(keys), (cfg.vocab, d), d),
        "final_norm": jnp.ones((d,), dtype),
        "attn_norm": jnp.ones((l_, d), dtype),
        "ffn_norm": jnp.ones((l_, d), dtype),
        "w1": w(next(keys), (l_, d, f), d),
        "w2": w(next(keys), (l_, d, f), d),
        "w3": w(next(keys), (l_, f, d), f),
    }
    if cfg.attn == "mha":
        params.update(
            wq=w(next(keys), (l_, d, nh, dh), d),
            wk=w(next(keys), (l_, d, nh, dh), d),
            wv=w(next(keys), (l_, d, nh, dh), d),
            wo=w(next(keys), (l_, nh, dh, d), nh * dh),
        )
    else:
        r = cfg.kv_lora_rank
        params.update(
            wq=w(next(keys), (l_, d, nh, r), d),
            wkv=w(next(keys), (l_, d, r), d),
            w_down=w(next(keys), (l_, nh, r, dh), r),
            wo=w(next(keys), (l_, nh, dh, d), nh * dh),
        )
    return params


# Canonical flat ordering of parameters for the AOT interface (must match
# rust/src/runtime manifest handling).
def param_order(cfg: ModelConfig) -> list[str]:
    common_head = ["emb", "final_norm", "attn_norm", "ffn_norm"]
    ffn = ["w1", "w2", "w3"]
    if cfg.attn == "mha":
        return common_head + ["wq", "wk", "wv", "wo"] + ffn
    return common_head + ["wq", "wkv", "w_down", "wo"] + ffn


def flatten_params(cfg: ModelConfig, params) -> list:
    return [params[k] for k in param_order(cfg)]


def unflatten_params(cfg: ModelConfig, flat) -> dict:
    return dict(zip(param_order(cfg), flat))


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    """KV cache pytree. MHA: (k, v) each (L, B, S, nh, dh). MLA: a single
    latent cache (L, B, S, r)."""
    l_, s = cfg.n_layers, cfg.max_seq
    if cfg.attn == "mha":
        shape = (l_, batch, s, cfg.n_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    return {"kv": jnp.zeros((l_, batch, s, cfg.kv_lora_rank), dtype)}


def _append_rows(cache_l, new, pos):
    """Write `new[b]` into cache_l[b, pos[b]] for every batch row.
    cache_l: (B, S, ...), new: (B, ...), pos: (B,) int32."""

    def one(row_cache, row_new, p):
        return jax.lax.dynamic_update_slice_in_dim(row_cache, row_new[None], p, axis=0)

    return jax.vmap(one)(cache_l, new, pos)


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def decode_step(cfg: ModelConfig, params, tokens, pos, cache, *, use_kernel=True):
    """One autoregressive decode step.

    Args:
      tokens: (B,) int32 current input token ids.
      pos: (B,) int32 number of tokens already cached for each row (the new
        token lands at cache index pos[b]).
      cache: pytree from init_cache.
      use_kernel: fused Pallas kernels (True) or the jnp oracle (False) —
        both must produce identical numbers (differential test).

    Returns (logits (B, vocab) f32, new cache).
    """
    x = params["emb"][tokens].astype(jnp.float32)  # (B, D)

    if cfg.attn == "mha":
        layer_xs = (
            params["attn_norm"],
            params["wq"],
            params["wk"],
            params["wv"],
            params["wo"],
            params["ffn_norm"],
            params["w1"],
            params["w2"],
            params["w3"],
            cache["k"],
            cache["v"],
        )

        def body(x, xs):
            an, wq, wk, wv, wo, fn_, w1, w2, w3, kc, vc = xs
            h = kref.rmsnorm_ref(x, an)
            if use_kernel:
                attn, k_new, v_new = fused_mha_decode(
                    h, wq, wk, wv, wo, kc, vc, pos, chunk=min(cfg.kv_chunk, cfg.max_seq)
                )
            else:
                attn, k_new, v_new = kref.mha_decode_ref(h, wq, wk, wv, wo, kc, vc, pos)
            x = x + attn
            h2 = kref.rmsnorm_ref(x, fn_)
            x = x + kref.swiglu_ref(h2, w1, w2, w3)
            kc = _append_rows(kc, k_new, pos)
            vc = _append_rows(vc, v_new, pos)
            return x, (kc, vc)

        x, (k_cache, v_cache) = jax.lax.scan(body, x, layer_xs)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        layer_xs = (
            params["attn_norm"],
            params["wq"],
            params["wkv"],
            params["w_down"],
            params["wo"],
            params["ffn_norm"],
            params["w1"],
            params["w2"],
            params["w3"],
            cache["kv"],
        )

        def body(x, xs):
            an, wq, wkv, wd, wo, fn_, w1, w2, w3, kvc = xs
            h = kref.rmsnorm_ref(x, an)
            if use_kernel:
                attn, kv_new = fused_mla_decode(
                    h, wq, wkv, wd, wo, kvc, pos, chunk=min(cfg.kv_chunk, cfg.max_seq)
                )
            else:
                attn, kv_new = kref.mla_decode_ref(h, wq, wkv, wd, wo, kvc, pos)
            x = x + attn
            h2 = kref.rmsnorm_ref(x, fn_)
            x = x + kref.swiglu_ref(h2, w1, w2, w3)
            kvc = _append_rows(kvc, kv_new, pos)
            return x, (kvc,)

        x, (kv_cache,) = jax.lax.scan(body, x, layer_xs)
        new_cache = {"kv": kv_cache}

    x = kref.rmsnorm_ref(x, params["final_norm"])
    logits = x.astype(jnp.float32) @ params["emb"].astype(jnp.float32).T
    return logits, new_cache


def decode_step_flat(cfg: ModelConfig, *, use_kernel=True):
    """AOT-friendly closure over cfg with a flat signature:
    f(tokens, pos, *cache_arrays, *param_arrays) -> (logits, *new_cache).
    Cache arrays come first so Rust can donate/rotate them cheaply."""
    n_cache = 2 if cfg.attn == "mha" else 1
    cache_keys = ("k", "v") if cfg.attn == "mha" else ("kv",)

    def f(tokens, pos, *rest):
        cache = dict(zip(cache_keys, rest[:n_cache]))
        params = unflatten_params(cfg, rest[n_cache:])
        logits, new_cache = decode_step(cfg, params, tokens, pos, cache, use_kernel=use_kernel)
        return (logits, *[new_cache[k] for k in cache_keys])

    return f


def decode_step_knew(cfg: ModelConfig, params, tokens, pos, cache, *, use_kernel=True):
    """Like `decode_step` but the device does NOT write the cache: it
    returns the per-layer new K/V rows and the host appends them.

    This is the serving interface (see rust/src/coordinator): the paged KV
    cache is host-authoritative so the continuous batcher can recompose
    batches between steps; only the small new rows come back from the
    device. Attention correctness does not depend on the append because the
    fused kernels fold the self token in directly from k_new/v_new.

    Returns (logits, new_rows) with new_rows shapes:
      MHA: (k_new (L,B,nh,dh), v_new (L,B,nh,dh));  MLA: (kv_new (L,B,r),).
    """
    x = params["emb"][tokens].astype(jnp.float32)

    if cfg.attn == "mha":
        layer_xs = (
            params["attn_norm"], params["wq"], params["wk"], params["wv"],
            params["wo"], params["ffn_norm"], params["w1"], params["w2"],
            params["w3"], cache["k"], cache["v"],
        )

        def body(x, xs):
            an, wq, wk, wv, wo, fn_, w1, w2, w3, kc, vc = xs
            h = kref.rmsnorm_ref(x, an)
            if use_kernel:
                attn, k_new, v_new = fused_mha_decode(
                    h, wq, wk, wv, wo, kc, vc, pos, chunk=min(cfg.kv_chunk, cfg.max_seq)
                )
            else:
                attn, k_new, v_new = kref.mha_decode_ref(h, wq, wk, wv, wo, kc, vc, pos)
            x = x + attn
            h2 = kref.rmsnorm_ref(x, fn_)
            x = x + kref.swiglu_ref(h2, w1, w2, w3)
            return x, (k_new, v_new)

        x, new_rows = jax.lax.scan(body, x, layer_xs)
    else:
        layer_xs = (
            params["attn_norm"], params["wq"], params["wkv"], params["w_down"],
            params["wo"], params["ffn_norm"], params["w1"], params["w2"],
            params["w3"], cache["kv"],
        )

        def body(x, xs):
            an, wq, wkv, wd, wo, fn_, w1, w2, w3, kvc = xs
            h = kref.rmsnorm_ref(x, an)
            if use_kernel:
                attn, kv_new = fused_mla_decode(
                    h, wq, wkv, wd, wo, kvc, pos, chunk=min(cfg.kv_chunk, cfg.max_seq)
                )
            else:
                attn, kv_new = kref.mla_decode_ref(h, wq, wkv, wd, wo, kvc, pos)
            x = x + attn
            h2 = kref.rmsnorm_ref(x, fn_)
            x = x + kref.swiglu_ref(h2, w1, w2, w3)
            return x, (kv_new,)

        x, new_rows = jax.lax.scan(body, x, layer_xs)

    x = kref.rmsnorm_ref(x, params["final_norm"])
    logits = x.astype(jnp.float32) @ params["emb"].astype(jnp.float32).T
    return logits, new_rows


def decode_step_knew_flat(cfg: ModelConfig, *, use_kernel=True):
    """Flat-signature serving variant for AOT:
    f(tokens, pos, *cache_arrays, *param_arrays) -> (logits, *new_rows)."""
    n_cache = 2 if cfg.attn == "mha" else 1
    cache_keys = ("k", "v") if cfg.attn == "mha" else ("kv",)

    def f(tokens, pos, *rest):
        cache = dict(zip(cache_keys, rest[:n_cache]))
        params = unflatten_params(cfg, rest[n_cache:])
        logits, new_rows = decode_step_knew(
            cfg, params, tokens, pos, cache, use_kernel=use_kernel
        )
        return (logits, *new_rows)

    return f
