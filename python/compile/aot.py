"""AOT compile path: lower decode_step to HLO *text* + a JSON manifest.

HLO text (NOT `lowered.compiler_ir(...).serialize()`): jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects. The text parser
reassigns ids, so text round-trips cleanly — see /opt/xla-example/README.md.

Usage:
  python -m compile.aot --out ../artifacts [--models tiny-llama-100m,...]
                        [--batches 1,4,8]

Outputs per (model, batch): `decode_<model>_b<batch>.hlo.txt` plus one
`manifest.json` describing the exact flat input/output interface so the
Rust runtime can build buffers without re-deriving shapes.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust-side
    to_tuple unwrap)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(arr_like) -> dict:
    return {"shape": list(arr_like.shape), "dtype": str(arr_like.dtype)}


def lower_decode(cfg: M.ModelConfig, batch: int, *, use_kernel=True, serving=False):
    """Lower one decode-step executable; returns (hlo_text, interface).

    `serving=False`: device appends to the cache and returns it
    (self-contained; used by the quickstart / tests).
    `serving=True`: device returns only the per-layer new K/V rows and the
    host-authoritative paged cache (rust coordinator) appends them — the
    interface the serving engine loads.
    """
    tokens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
    cache = M.init_cache(cfg, batch)
    cache_keys = ("k", "v") if cfg.attn == "mha" else ("kv",)
    cache_specs = [jax.ShapeDtypeStruct(cache[k].shape, cache[k].dtype) for k in cache_keys]
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    flat_params = M.flatten_params(cfg, params)
    param_specs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in flat_params]

    if serving:
        f = M.decode_step_knew_flat(cfg, use_kernel=use_kernel)
    else:
        f = M.decode_step_flat(cfg, use_kernel=use_kernel)
    lowered = jax.jit(f).lower(tokens, pos, *cache_specs, *param_specs)
    text = to_hlo_text(lowered)

    l_, nh, dh = cfg.n_layers, cfg.n_heads, cfg.head_dim
    if serving:
        if cfg.attn == "mha":
            out_rows = [
                {"name": "k_new", "shape": [l_, batch, nh, dh], "dtype": "float32"},
                {"name": "v_new", "shape": [l_, batch, nh, dh], "dtype": "float32"},
            ]
        else:
            out_rows = [
                {"name": "kv_new", "shape": [l_, batch, cfg.kv_lora_rank], "dtype": "float32"},
            ]
    else:
        out_rows = [
            {"name": f"cache_{k}", **_spec(s)} for k, s in zip(cache_keys, cache_specs)
        ]

    interface = {
        "model": cfg.name,
        "batch": batch,
        "attn": cfg.attn,
        "max_seq": cfg.max_seq,
        "vocab": cfg.vocab,
        "n_layers": cfg.n_layers,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "head_dim": cfg.head_dim,
        "kv_lora_rank": cfg.kv_lora_rank,
        "inputs": (
            [{"name": "tokens", **_spec(tokens)}, {"name": "pos", **_spec(pos)}]
            + [{"name": f"cache_{k}", **_spec(s)} for k, s in zip(cache_keys, cache_specs)]
            + [
                {"name": f"param_{n}", **_spec(s)}
                for n, s in zip(M.param_order(cfg), param_specs)
            ]
        ),
        "outputs": (
            [{"name": "logits", "shape": [batch, cfg.vocab], "dtype": "float32"}] + out_rows
        ),
        "serving": serving,
        "n_cache": len(cache_keys),
        "n_params": len(param_specs),
    }
    return text, interface


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="tiny-llama-100m,tiny-mla-100m")
    ap.add_argument("--batches", default="1,4,8")
    ap.add_argument("--no-kernel", action="store_true", help="lower the jnp oracle instead")
    args = ap.parse_args()

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    manifest = {"format": 1, "executables": []}

    for name in args.models.split(","):
        cfg = M.CONFIGS[name.strip()]
        # serving executables (host-authoritative cache) for every bucket,
        # plus one self-contained executable for the quickstart example.
        jobs = [(b, True) for b in (int(x) for x in args.batches.split(","))]
        jobs.append((1, False))
        for b, serving in jobs:
            text, interface = lower_decode(
                cfg, b, use_kernel=not args.no_kernel, serving=serving
            )
            kind = "serve" if serving else "full"
            fname = f"decode_{cfg.name}_{kind}_b{b}.hlo.txt"
            (out / fname).write_text(text)
            interface["file"] = fname
            interface["sha256"] = hashlib.sha256(text.encode()).hexdigest()[:16]
            manifest["executables"].append(interface)
            print(f"wrote {fname}: {len(text) / 1e6:.2f} MB, batch={b}")

    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote manifest.json with {len(manifest['executables'])} executables")


if __name__ == "__main__":
    main()
