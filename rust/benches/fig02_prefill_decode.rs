//! Fig. 2: latency split between prefilling and decoding when generating
//! 256 tokens — the paper measures decoding at > 95 % of total latency
//! (its motivation for optimising the decode path).
//!
//! The closing section measures the *functional* prefill path (real
//! numerics through `FunctionalBackend`, micro-llama): wall-clock prefill
//! vs decode at several chunk sizes, with the token stream asserted
//! byte-identical across chunkings (the integration_prefill contract).

use std::time::Instant;

use clusterfusion::clustersim::e2e::{decode_latency_share, prefill_time};
use clusterfusion::clustersim::frameworks::FrameworkProfile;
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::coordinator::engine::Engine;
use clusterfusion::coordinator::request::{Event, Request};
use clusterfusion::coordinator::FunctionalBackend;
use clusterfusion::metrics::Table;
use clusterfusion::models::ModelConfig;

/// One functional prefill+decode run at a chunk size: (prefill steps,
/// prefill seconds, decode seconds, greedy stream).
fn functional_run(chunk: usize, prompt: &[i32], gen: usize) -> (u64, f64, f64, Vec<i32>) {
    let backend = FunctionalBackend::from_model_name("micro-llama", 42, 2).unwrap();
    let mut engine = Engine::new(backend, 64, 8, 1.0);
    engine.set_prefill_chunk(chunk);
    engine.submit(Request::new(1, prompt.to_vec(), gen));
    let t0 = Instant::now();
    while engine.pool.seq_len(1).unwrap_or(0) < prompt.len() {
        engine.step().unwrap();
    }
    let prefill_steps = engine.steps;
    let prefill_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    engine.run_to_completion(10_000).unwrap();
    let decode_s = t1.elapsed().as_secs_f64();
    let stream: Vec<i32> = engine
        .take_events()
        .iter()
        .filter_map(|ev| match ev {
            Event::FirstToken { token, .. } | Event::Token { token, .. } => Some(*token),
            _ => None,
        })
        .collect();
    (prefill_steps, prefill_s, decode_s, stream)
}

fn main() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let model = ModelConfig::llama2_7b();
    let profile = FrameworkProfile::sglang();

    println!("== Fig. 2: prefill vs decode latency share (Llama2-7B, 256 generated tokens) ==\n");
    let mut t = Table::new(vec!["prompt", "prefill (ms)", "decode share (%)"]);
    for prompt in [128usize, 256, 512, 1024, 2048, 4096] {
        let share = decode_latency_share(&model, prompt, 256, &profile, &hw, &noc);
        t.row(vec![
            prompt.to_string(),
            format!("{:.2}", prefill_time(&model, prompt, &hw) * 1e3),
            format!("{:.1}", share * 100.0),
        ]);
    }
    t.print();
    println!("\nshape check: decode share > 95% across prompt lengths (paper: >95% at 256 tokens).");

    println!("\n== measured functional prefill (micro-llama, prompt 64 + 32 generated) ==\n");
    let prompt: Vec<i32> = (0..64).map(|i| (i * 7 + 3) % 256).collect();
    let mut ft = Table::new(vec!["chunk", "prefill steps", "prefill (ms)", "decode (ms)", "decode share (%)"]);
    let mut reference: Option<Vec<i32>> = None;
    for chunk in [0usize, 4, 16] {
        let (steps, pre_s, dec_s, stream) = functional_run(chunk, &prompt, 32);
        match &reference {
            None => reference = Some(stream),
            Some(r) => assert_eq!(&stream, r, "chunk {chunk} changed the greedy stream"),
        }
        ft.row(vec![
            if chunk == 0 { "one-shot".into() } else { chunk.to_string() },
            steps.to_string(),
            format!("{:.2}", pre_s * 1e3),
            format!("{:.2}", dec_s * 1e3),
            format!("{:.1}", 100.0 * dec_s / (pre_s + dec_s)),
        ]);
    }
    ft.print();
    println!("\ntoken streams byte-identical across chunkings (asserted); step counts differ only.");
}
