//! Fig. 2: latency split between prefilling and decoding when generating
//! 256 tokens — the paper measures decoding at > 95 % of total latency
//! (its motivation for optimising the decode path).

use clusterfusion::clustersim::e2e::{decode_latency_share, prefill_time};
use clusterfusion::clustersim::frameworks::FrameworkProfile;
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::metrics::Table;
use clusterfusion::models::ModelConfig;

fn main() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let model = ModelConfig::llama2_7b();
    let profile = FrameworkProfile::sglang();

    println!("== Fig. 2: prefill vs decode latency share (Llama2-7B, 256 generated tokens) ==\n");
    let mut t = Table::new(vec!["prompt", "prefill (ms)", "decode share (%)"]);
    for prompt in [128usize, 256, 512, 1024, 2048, 4096] {
        let share = decode_latency_share(&model, prompt, 256, &profile, &hw, &noc);
        t.row(vec![
            prompt.to_string(),
            format!("{:.2}", prefill_time(&model, prompt, &hw) * 1e3),
            format!("{:.1}", share * 100.0),
        ]);
    }
    t.print();
    println!("\nshape check: decode share > 95% across prompt lengths (paper: >95% at 256 tokens).");
}
