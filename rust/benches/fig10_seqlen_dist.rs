//! Fig. 10: sequence-length distribution in ShareGPT and Splitwise —
//! the paper's point: real workloads are predominantly < 8 K tokens, the
//! regime where ClusterFusion's gains are largest.

use clusterfusion::metrics::Table;
use clusterfusion::workload::{histogram, sample_lengths, SeqlenDist};

fn main() {
    let n = 50_000;
    let edges = [1024usize, 2048, 4096, 8192, 16384];

    println!("== Fig. 10: sequence length distribution ({n} samples per dataset) ==\n");
    let mut t = Table::new(vec!["bucket", "ShareGPT (%)", "Splitwise (%)"]);
    let sg = sample_lengths(SeqlenDist::ShareGpt, n, 1 << 20, 1);
    let sw = sample_lengths(SeqlenDist::Splitwise, n, 1 << 20, 2);
    let h_sg = histogram(&sg, &edges);
    let h_sw = histogram(&sw, &edges);
    for ((bucket, a), (_, b)) in h_sg.iter().zip(&h_sw) {
        t.row(vec![
            bucket.clone(),
            format!("{:.1}", *a as f64 * 100.0 / n as f64),
            format!("{:.1}", *b as f64 * 100.0 / n as f64),
        ]);
    }
    t.print();

    let below = |v: &[usize]| v.iter().filter(|&&x| x < 8192).count() as f64 * 100.0 / n as f64;
    println!(
        "\nshape check: mass below 8K — ShareGPT {:.1}%, Splitwise {:.1}% (paper: predominantly under 8K).",
        below(&sg),
        below(&sw)
    );
}
