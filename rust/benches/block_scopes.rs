//! Fusion-scope expansion table (the ClusterFusion++ comparison behind
//! EXPERIMENTS.md §Block): one transformer layer's decode cost under the
//! three [`FusionScope`]s — per-op kernels (baseline), attention-scope
//! fusion (the paper), full-block fusion — at the Llama2-7B and
//! DeepSeek-V2-Lite geometries, plus the end-to-end TPOT composition.
//!
//! Also times the *functional* full-block pipeline (the serving
//! backend's real numerics) on the micro models so the decode throughput
//! of `FunctionalBackend` has a recorded number.

use clusterfusion::clustersim::block::{self, BlockProblem, FusionScope};
use clusterfusion::clustersim::dataflow::CostEnv;
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::metrics::Table;
use clusterfusion::models::ModelConfig;
use clusterfusion::util::bench::bench;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let budget_ms = if smoke { 20 } else { 300 };
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let cluster = 4usize;

    println!("== fusion-scope expansion: per-layer block cost (batch 1, N={cluster}) ==\n");
    let mut t = Table::new(vec![
        "model", "seq", "scope", "lat(us)", "HBM(MB)", "DSMEM(KB)", "launches", "GFLOP",
    ]);
    for model in [ModelConfig::llama2_7b(), ModelConfig::deepseek_v2_lite()] {
        for seq in [1024usize, 4096, 16384] {
            let p = BlockProblem::from_model(&model, 1, seq);
            let env = CostEnv::clusterfusion(&hw, &noc, cluster);
            for scope in FusionScope::all() {
                let c = block::cost(&p, scope, &env);
                t.row(vec![
                    model.name.clone(),
                    seq.to_string(),
                    scope.name().to_string(),
                    format!("{:.2}", c.latency * 1e6),
                    format!("{:.2}", c.hbm_bytes / 1e6),
                    format!("{:.1}", c.dsmem_bytes / 1e3),
                    c.launches.to_string(),
                    format!("{:.3}", c.flops / 1e9),
                ]);
            }
        }
    }
    t.print();

    println!("\n== end-to-end decode TPOT (ms), batch 1, N={cluster} ==\n");
    let mut t = Table::new(vec![
        "model", "seq", "isolated", "attn-fused", "full-block", "attn speedup", "full speedup",
    ]);
    for model in [ModelConfig::llama2_7b(), ModelConfig::deepseek_v2_lite()] {
        for seq in [1024usize, 4096, 16384] {
            let tpot = |s| block::decode_tpot(&model, 1, seq, s, cluster, &hw, &noc);
            let (iso, att, ful) = (
                tpot(FusionScope::BlockIsolated),
                tpot(FusionScope::AttentionFused),
                tpot(FusionScope::FullBlockFused),
            );
            assert!(
                ful <= att && att <= iso,
                "{} seq {seq}: fusion-scope ordering violated",
                model.name
            );
            t.row(vec![
                model.name.clone(),
                seq.to_string(),
                format!("{iso:.3}"),
                format!("{att:.3}"),
                format!("{ful:.3}"),
                format!("{:.2}x", iso / att),
                format!("{:.2}x", iso / ful),
            ]);
        }
    }
    t.print();

    println!("\n== functional full-block decode step (the serving backend's numerics) ==\n");
    for cfg in [ModelConfig::micro_llama(), ModelConfig::micro_mla()] {
        let model = block::BlockModel::from_config(&cfg, 42, 2);
        let b = 4usize;
        let (s, re, planes) = (cfg.max_seq, model.row_elems(), model.planes());
        let cache = vec![vec![0f32; cfg.n_layers * b * s * re]; planes];
        let tokens: Vec<i32> = (0..b as i32).collect();
        let pos = vec![0i32; b];
        let r = bench(&format!("decode_step {} (batch {b})", cfg.name), budget_ms, || {
            model.decode_step(&tokens, &pos, &cache, b)
        });
        println!("{}", r.report());
        println!("{}", r.report_rate("steps"));
    }
    println!("\nblock_scopes OK (full <= attn <= isolated at N={cluster} everywhere tested)");
}
