//! Table 1: latency of on-chip ClusterReduce/ClusterGather over DSMEM vs
//! the off-chip (global-memory) implementations, 32–256 KB, cluster 4.
//!
//! Paper reference (H100):
//!   Reduce: 1.18× / 1.36× / 2.01× / 2.44× (speedup grows with size)
//!   Gather: 1.60× / 1.52× / 1.44× / 1.59× (speedup ~flat)
//!
//! The microbenchmark measures a *standalone* collective kernel, so both
//! columns carry the fixed standalone-kernel overhead (launch + cluster
//! barrier setup) on top of the transport cost — that fixed floor is why
//! the paper's on-chip latencies start at ~6.8 µs.

use clusterfusion::clustersim::collective::{gather_cost, reduce_cost, Transport};
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::metrics::Table;

/// Standalone microbenchmark overhead: raw kernel launch + cluster
/// spin-up + timing fence (calibrated to the paper's ~6.5 µs floor).
const STANDALONE_OVERHEAD: f64 = 6.3e-6;

fn main() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let n = 4;

    println!("== Table 1: on-chip vs off-chip collective latency (cluster size {n}) ==\n");
    let mut t = Table::new(vec![
        "Operation",
        "Data Size (KB)",
        "Off-chip (us)",
        "On-chip (us)",
        "Speedup",
        "paper",
    ]);
    let paper_reduce = [1.18, 1.36, 2.01, 2.44];
    let paper_gather = [1.60, 1.52, 1.44, 1.59];
    for (i, kb) in [32.0, 64.0, 128.0, 256.0].iter().enumerate() {
        let bytes = kb * 1024.0;
        let off = reduce_cost(bytes, n, Transport::GlobalMemory, &hw, &noc).latency
            + STANDALONE_OVERHEAD;
        let on = reduce_cost(bytes, n, Transport::Dsmem, &hw, &noc).latency + STANDALONE_OVERHEAD;
        t.row(vec![
            "ClusterReduce".to_string(),
            format!("{kb:.0}"),
            format!("{:.2}", off * 1e6),
            format!("{:.2}", on * 1e6),
            format!("{:.2}x", off / on),
            format!("{:.2}x", paper_reduce[i]),
        ]);
    }
    for (i, kb) in [32.0, 64.0, 128.0, 256.0].iter().enumerate() {
        let bytes = kb * 1024.0;
        let off = gather_cost(bytes, n, Transport::GlobalMemory, &hw, &noc).latency
            + STANDALONE_OVERHEAD;
        let on = gather_cost(bytes, n, Transport::Dsmem, &hw, &noc).latency + STANDALONE_OVERHEAD;
        t.row(vec![
            "ClusterGather".to_string(),
            format!("{kb:.0}"),
            format!("{:.2}", off * 1e6),
            format!("{:.2}", on * 1e6),
            format!("{:.2}x", off / on),
            format!("{:.2}x", paper_gather[i]),
        ]);
    }
    t.print();
    println!("\nshape checks: on-chip always wins; Reduce speedup grows with size; Gather ~flat.");
}
