//! Fig. 5: SM-to-SM access latency (left), bandwidth (middle) and active
//! SMs (right) for cluster sizes 1..16 on the simulated H100.
//!
//! Paper anchors: 190 cycles at N=2 (vs >470-cycle gmem), 2.90 TB/s at
//! N=16 (vs 2.96 TB/s HBM), active SMs shrinking with N.

use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::metrics::Table;

fn main() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);

    println!("== Fig. 5: DSMEM profile vs cluster size ==\n");
    let mut t = Table::new(vec![
        "cluster",
        "latency (cycles)",
        "latency (ns)",
        "bandwidth (TB/s)",
        "active SMs",
    ]);
    for n in Noc::cluster_sizes() {
        t.row(vec![
            n.to_string(),
            format!("{:.0}", noc.latency_cycles(n)),
            format!("{:.1}", noc.latency(n) * 1e9),
            format!("{:.2}", noc.bandwidth(n) / 1e12),
            noc.active_sms(n).to_string(),
        ]);
    }
    t.print();
    println!(
        "\nreference: global memory latency {:.0} cycles ({:.0} ns), HBM bandwidth {:.2} TB/s",
        hw.gmem_latency_cycles,
        hw.gmem_latency() * 1e9,
        hw.hbm_bw / 1e12
    );
    println!("shape checks: latency(2)=190cy < gmem; bw decays to 2.90 TB/s < HBM at N=16.");
}
