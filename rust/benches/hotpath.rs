//! Coordinator + simulator hot-path micro-benchmarks (§Perf pass).
//!
//! Uses the in-tree harness (`util::bench`) — offline build, no criterion.
//! Targets (DESIGN.md §5): coordinator overhead per decode step must be
//! negligible next to executable time; the simulator must evaluate fast
//! enough for dense sweeps (>=1e5 dataflow evals/s).

use clusterfusion::clustersim::collective::{
    cluster_gather, cluster_reduce, ReduceOp, Transport,
};
use clusterfusion::clustersim::dataflow::{split_token, AttnProblem, CostEnv};
use clusterfusion::clustersim::e2e::{decode_step, Engine as SimEngine};
use clusterfusion::clustersim::frameworks::FrameworkProfile;
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::coordinator::engine::{Engine, MockBackend};
use clusterfusion::coordinator::kv_cache::{CacheGeometry, KvPool};
use clusterfusion::coordinator::request::Request;
use clusterfusion::util::bench::bench;

fn main() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let budget = 300; // ms per case

    println!("== hot-path micro-benchmarks ==");

    // --- simulator ---
    let p = AttnProblem {
        batch: 1, d_model: 4096, n_heads: 32, head_dim: 128, seq: 4096, kv_lora_rank: 0,
    };
    let env = CostEnv::clusterfusion(&hw, &noc, 4);
    println!("{}", bench("sim: split_token::cost", budget, || split_token::cost(&p, &env)).report());

    let model = clusterfusion::models::ModelConfig::llama2_7b();
    let prof = FrameworkProfile::clusterfusion();
    println!(
        "{}",
        bench("sim: e2e decode_step estimate", budget, || decode_step(
            &model, 1, 4096, SimEngine::ClusterFusion { cluster_size: 4 }, &prof, &hw, &noc,
        ))
        .report()
    );

    // --- functional collectives ---
    println!(
        "{}",
        bench("collective: reduce 8x1KB f32", budget, || {
            let mut blocks = vec![vec![1.0f32; 256]; 8];
            cluster_reduce(&mut blocks, ReduceOp::Sum, Transport::Dsmem, &hw, &noc)
        })
        .report()
    );
    println!(
        "{}",
        bench("collective: gather 8x1KB f32", budget, || {
            let blocks = vec![vec![1.0f32; 256]; 8];
            cluster_gather(&blocks, Transport::Dsmem, &hw, &noc)
        })
        .report()
    );

    // --- KV pool ---
    let geom = CacheGeometry { n_layers: 12, row_elems: 768, planes: 2, max_seq: 512 };
    {
        let mut pool = KvPool::new(geom, 16, 1024);
        pool.alloc_seq(1).unwrap();
        let row = vec![0.5f32; geom.n_layers * geom.row_elems];
        let mut next = 1u64;
        println!(
            "{}",
            bench("kv: append 1 token (12L x 768 x 2)", budget, || {
                if !pool.can_append(next) {
                    pool.free_seq(next);
                    next += 1;
                    pool.alloc_seq(next).unwrap();
                }
                pool.append(next, &[&row, &row]).unwrap();
            })
            .report()
        );
    }
    {
        let mut pool = KvPool::new(geom, 16, 64);
        let row = vec![0.5f32; geom.n_layers * geom.row_elems];
        for id in 1..=4u64 {
            pool.alloc_seq(id).unwrap();
            for _ in 0..128 {
                pool.append(id, &[&row, &row]).unwrap();
            }
        }
        let g = pool.geometry();
        let mut planes =
            vec![vec![0.0f32; g.n_layers * 4 * g.max_seq * g.row_elems]; g.planes];
        println!(
            "{}",
            bench("kv: gather_into 4 seq x 128 tok -> b4 (hot path)", budget, || {
                pool.gather_batch_into(&[1, 2, 3, 4], 4, &mut planes).unwrap()
            })
            .report()
        );
        println!(
            "{}",
            bench("kv: gather_batch alloc+zero (cold path)", budget, || {
                pool.gather_batch(&[1, 2, 3, 4], 4).unwrap()
            })
            .report()
        );
    }

    // --- coordinator step (mock backend = pure coordinator overhead) ---
    println!(
        "{}",
        bench("engine: full step, mock backend, b4", budget, || {
            let mut e = Engine::new(MockBackend::tiny(), 64, 4, 1.0);
            for id in 0..4 {
                e.submit(Request::new(id, vec![1, 2], 2));
            }
            e.run_to_completion(64).unwrap();
            e.steps
        })
        .report()
    );
}
