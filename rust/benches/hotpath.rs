//! Coordinator + simulator hot-path micro-benchmarks (§Perf pass) — the
//! before/after regression harness for the `util::linalg` microkernel
//! layer.
//!
//! Uses the in-tree harness (`util::bench`) — offline build, no criterion.
//! Targets (DESIGN.md §5): coordinator overhead per decode step must be
//! negligible next to executable time; the simulator must evaluate fast
//! enough for dense sweeps (>= 1e5 dataflow cost evals/s — an advisory
//! prints if the measured rate drops below that) and the functional
//! dataflows must hold their >= 10x win over the pre-refactor scalar
//! loops (the recorded baseline lives in EXPERIMENTS.md §Perf).
//!
//! `--smoke` (the `make bench-smoke` / CI entry) shrinks every budget to
//! ~20 ms per case so the harness itself cannot bitrot without burning CI
//! minutes; absolute numbers from a smoke run are noisy — use the default
//! budgets when recording EXPERIMENTS.md figures.

use clusterfusion::clustersim::collective::{
    cluster_gather, cluster_reduce, ReduceOp, Transport,
};
use clusterfusion::clustersim::dataflow::{
    mla, split_head, split_token, AttnProblem, CostEnv, PackedMhaWeights,
};
use clusterfusion::clustersim::e2e::{decode_step, Engine as SimEngine};
use clusterfusion::clustersim::frameworks::FrameworkProfile;
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::coordinator::engine::{Engine, MockBackend};
use clusterfusion::coordinator::kv_cache::{CacheGeometry, KvPool};
use clusterfusion::coordinator::request::Request;
use clusterfusion::util::bench::{bench, BenchResult};
use clusterfusion::util::linalg::{self, PackedWeight};
use clusterfusion::util::pool::Pool;
use clusterfusion::util::rng::Rng;

/// Pre-refactor `split_token::execute` wall time at the Llama-2-7B
/// geometry below, ms/iter — the seed's column-strided scalar loops,
/// recorded in EXPERIMENTS.md §Perf (seed commit b63f1d4; measured via
/// the C mirror of the exact loop structures on the authoring container,
/// whose DRAM profile — ~2 GB/s streaming, ~20 ns strided loads — is the
/// *least* favourable to the refactor; see the provenance note there).
/// The harness prints the live speedup against it; the acceptance bar is
/// >= 10x on hosts with a conventional latency/bandwidth ratio.
const PRE_REFACTOR_EXECUTE_MS: f64 = 630.0;

fn randv(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.f32() - 0.5) * scale).collect()
}

/// Dense-sweep throughput advisory (DESIGN.md §5). Derives the kernel
/// name from the measurement itself so a trip is actionable — the
/// regressing kernel is named, not guessed.
fn advise_rate(r: &BenchResult) {
    const TARGET: f64 = 1e5;
    if r.per_sec() < TARGET {
        println!(
            "ADVISORY: kernel `{}` at {:.3e} evals/s is below the {TARGET:.0e} evals/s \
             dense-sweep target (DESIGN.md §5)",
            r.name,
            r.per_sec()
        );
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let budget: u64 = if smoke { 20 } else { 300 };
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);

    println!("== hot-path micro-benchmarks ({}) ==", if smoke { "smoke" } else { "full" });

    // --- simulator cost models (the dense-sweep currency) ---
    let p = AttnProblem {
        batch: 1, d_model: 4096, n_heads: 32, head_dim: 128, seq: 4096, kv_lora_rank: 0,
    };
    let env = CostEnv::clusterfusion(&hw, &noc, 4);
    let r = bench("sim: split_token::cost", budget, || split_token::cost(&p, &env));
    println!("{}", r.report_rate("evals"));
    advise_rate(&r);

    let model = clusterfusion::models::ModelConfig::llama2_7b();
    let prof = FrameworkProfile::clusterfusion();
    let r = bench("sim: e2e decode_step estimate", budget, || {
        decode_step(&model, 1, 4096, SimEngine::ClusterFusion { cluster_size: 4 }, &prof, &hw, &noc)
    });
    println!("{}", r.report_rate("evals"));
    advise_rate(&r);

    // --- linalg microkernels: the before/after pair at the Llama-2-7B
    // projection shape (one head's 128 columns of a 4096x4096 weight).
    // This pair is the *same-host* before/after signal: both sides run
    // here and now, so their ratio is meaningful on any machine (unlike
    // the recorded cross-host execute baseline below). ---
    let kernel_speedup = {
        let (d, h, cols) = (4096usize, 4096usize, 128usize);
        let mut rng = Rng::seed_from_u64(2024);
        let x = randv(&mut rng, d, 2.0);
        let w = randv(&mut rng, d * h, 0.4);
        let pw = PackedWeight::pack(&w, d, h);
        let mut out = vec![0f32; cols];
        let packed = bench("linalg: project 128 cols, packed+tiled", budget, || {
            linalg::matmul_rows(&x, 1, d, &pw, 0, 1024, cols, &mut out);
            out[0]
        });
        println!("{}", packed.report_rate("tiles"));
        let strided = bench("linalg: project 128 cols, seed strided", budget, || {
            linalg::matmul_rows_naive_strided(&x, 1, d, &w, h, 1024, cols, &mut out);
            out[0]
        });
        println!("{}", strided.report_rate("tiles"));
        println!(
            "{}",
            bench("linalg: pack 4096x4096 weight", budget, || PackedWeight::pack(&w, d, h))
                .report_rate("packs")
        );
        strided.mean_ns / packed.mean_ns
    };
    println!("     kernel pair same-host speedup (strided/packed): {kernel_speedup:.1}x");

    // --- functional dataflows (the acceptance geometry: Llama-2-7B head
    // config, cluster 4 — ISSUE 3 / EXPERIMENTS.md §Perf) ---
    {
        let (b, d, nh, dh, s, n) = (1usize, 4096usize, 32usize, 128usize, 4096usize, 4usize);
        let h = nh * dh;
        let mut rng = Rng::seed_from_u64(7);
        let hidden = randv(&mut rng, b * d, 2.0);
        let wq = randv(&mut rng, d * h, 0.4);
        let wk = randv(&mut rng, d * h, 0.4);
        let wv = randv(&mut rng, d * h, 0.4);
        let wo = randv(&mut rng, h * d, 0.4);
        let k_cache = randv(&mut rng, b * s * h, 2.0);
        let v_cache = randv(&mut rng, b * s * h, 2.0);
        let pos = vec![s - 1; b];
        // The dense-sweep hot path: weights packed ONCE per sweep
        // (PackedMhaWeights lifetime), every eval runs execute_packed.
        let packed = PackedMhaWeights::pack(&wq, &wk, &wv, &wo, d, h);
        let r = bench("sim: split_token::execute_packed b1 d4096 nh32 dh128 s4096 n4", budget, || {
            split_token::execute_packed(
                &hidden, &packed, &k_cache, &v_cache, &pos, b, d, nh, dh, s, n,
                Transport::Dsmem, &hw, &noc,
            )
        });
        println!("{}", r.report_rate("evals"));
        // Reference comparison against the recorded cross-host baseline
        // (EXPERIMENTS.md §Perf — informational: different machines).
        let recorded = PRE_REFACTOR_EXECUTE_MS / (r.mean_ns / 1e6);
        println!(
            "     vs recorded pre-refactor baseline ({PRE_REFACTOR_EXECUTE_MS:.0} ms/iter, \
             EXPERIMENTS.md §Perf, authoring container): {recorded:.1}x (target >= 10x)"
        );
        // The regression signal proper is the live same-host kernel pair
        // measured above — both sides on this machine, this run.
        if kernel_speedup < 10.0 {
            println!(
                "ADVISORY: packed-vs-strided kernel pair at {kernel_speedup:.1}x is below \
                 the 10x bar on this host (expected only on hosts with unusually cheap \
                 strided DRAM access — see EXPERIMENTS.md §Perf provenance)"
            );
        }
        // Parallel-vs-serial at the acceptance geometry (§Parallel):
        // the cluster blocks (n=4) fan across the worker pool; outputs
        // are byte-identical at every pool size, so this sweep measures
        // wall-clock only. Record the table in EXPERIMENTS.md §Parallel
        // (full budgets; smoke numbers are noisy).
        {
            let mut serial_ns = 0.0f64;
            for threads in [1usize, 2, 4, 8] {
                let pool = Pool::new(threads);
                let r = bench(
                    &format!("sim: split_token::execute_packed_on t{threads} (acceptance)"),
                    budget,
                    || {
                        split_token::execute_packed_on(
                            &pool, &hidden, &packed, &k_cache, &v_cache, &pos, b, d, nh, dh, s,
                            n, Transport::Dsmem, &hw, &noc,
                        )
                    },
                );
                println!("{}", r.report_rate("evals"));
                if threads == 1 {
                    serial_ns = r.mean_ns;
                } else {
                    println!(
                        "     parallel speedup vs 1 thread: {:.2}x at {threads} threads \
                         ({} cores on this host)",
                        serial_ns / r.mean_ns,
                        std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
                    );
                }
            }
        }
        // Dispatch-count story (§Parallel, EXPERIMENTS.md): the coalesced
        // fan-outs post one dispatch per *phase* over the flattened
        // heads×blocks grid, not one per head per phase. Count exactly
        // via Pool::stats — this is the per-step line EXPERIMENTS.md
        // §Parallel records.
        {
            let pool = Pool::new(4);
            let before = pool.stats().dispatches;
            split_token::execute_packed_on(
                &pool, &hidden, &packed, &k_cache, &v_cache, &pos, b, d, nh, dh, s, n,
                Transport::Dsmem, &hw, &noc,
            );
            let per_step = pool.stats().dispatches - before;
            println!(
                "     dispatches per split_token step (nh={nh}, n={n}): {per_step} \
                 (pre-coalescing: {} — one per head per phase)",
                3 * nh
            );
        }
        // One-shot path (pack inside the call) for the repack-cost story;
        // skipped in smoke mode (a single iteration blows the budget).
        if !smoke {
            println!(
                "{}",
                bench("sim: split_token::execute one-shot (packs inside)", budget, || {
                    split_token::execute(
                        &hidden, &wq, &wk, &wv, &wo, &k_cache, &v_cache, &pos, b, d, nh, dh, s, n,
                        Transport::Dsmem, &hw, &noc,
                    )
                })
                .report_rate("evals")
            );
        }
    }
    {
        // smaller geometries keep the per-kernel lines cheap enough for smoke
        let (b, d, nh, dh, s, n) = (1usize, 1024usize, 8usize, 64usize, 512usize, 4usize);
        let h = nh * dh;
        let mut rng = Rng::seed_from_u64(8);
        let hidden = randv(&mut rng, b * d, 2.0);
        let wq = randv(&mut rng, d * h, 0.4);
        let wk = randv(&mut rng, d * h, 0.4);
        let wv = randv(&mut rng, d * h, 0.4);
        let wo = randv(&mut rng, h * d, 0.4);
        let k_cache = randv(&mut rng, b * s * h, 2.0);
        let v_cache = randv(&mut rng, b * s * h, 2.0);
        let pos = vec![s - 1; b];
        println!(
            "{}",
            bench("sim: split_head::execute b1 d1024 nh8 dh64 s512 n4", budget, || {
                split_head::execute(
                    &hidden, &wq, &wk, &wv, &wo, &k_cache, &v_cache, &pos, b, d, nh, dh, s, n,
                    Transport::Dsmem, &hw, &noc,
                )
            })
            .report_rate("evals")
        );
    }
    {
        let (b, d, nh, l, dh, s, n) = (1usize, 1024usize, 8usize, 128usize, 64usize, 512usize, 4usize);
        let mut rng = Rng::seed_from_u64(9);
        let hidden = randv(&mut rng, b * d, 2.0);
        let wq = randv(&mut rng, d * nh * l, 0.4);
        let wkv = randv(&mut rng, d * l, 0.4);
        let w_down = randv(&mut rng, nh * l * dh, 0.4);
        let wo = randv(&mut rng, nh * dh * d, 0.4);
        let kv_cache = randv(&mut rng, b * s * l, 2.0);
        let pos = vec![s - 1; b];
        println!(
            "{}",
            bench("sim: mla::execute b1 d1024 nh8 l128 dh64 s512 n4", budget, || {
                mla::execute(
                    &hidden, &wq, &wkv, &w_down, &wo, &kv_cache, &pos, b, d, nh, l, dh, s, n,
                    Transport::Dsmem, &hw, &noc,
                )
            })
            .report_rate("evals")
        );
    }

    // --- pool dispatch overhead (§Parallel: persistent workers) ---
    {
        let threads = 4usize;
        let persistent = Pool::new(threads);
        let round_trip = bench("pool: empty-job round-trip, persistent t4", budget, || {
            persistent.run(threads, |_| {})
        });
        println!("{}", round_trip.report());
        let spawn = bench("pool: empty-job round-trip, spawn-per-call t4", budget, || {
            // the retired discipline: scope-spawn t−1 threads, run worker
            // 0 inline, join — what every dispatch used to pay
            std::thread::scope(|scope| {
                for _ in 1..threads {
                    scope.spawn(|| {});
                }
            })
        });
        println!("{}", spawn.report());
        println!(
            "     persistent-pool dispatch win: {:.1}x cheaper than spawn-per-call",
            spawn.mean_ns / round_trip.mean_ns
        );

        // Per-step dispatch volume through the full block pipeline (the
        // serving decode hot path) — the other EXPERIMENTS.md §Parallel
        // counter line.
        let cfg = clusterfusion::models::ModelConfig::micro_llama();
        let model = clusterfusion::clustersim::block::BlockModel::from_config(&cfg, 42, 2);
        let plane_len = cfg.n_layers * cfg.max_seq * model.row_elems();
        let planes = vec![vec![0f32; plane_len]; model.planes()];
        let before = persistent.stats().dispatches;
        model.decode_step_on(&persistent, &[7], &[0], &planes, 1);
        let per_step = persistent.stats().dispatches - before;
        println!(
            "     dispatches per full-block decode step (micro-llama, {} layers): {per_step}",
            cfg.n_layers
        );
    }

    // --- functional collectives ---
    println!(
        "{}",
        bench("collective: reduce 8x1KB f32", budget, || {
            let mut blocks = vec![vec![1.0f32; 256]; 8];
            cluster_reduce(&mut blocks, ReduceOp::Sum, Transport::Dsmem, &hw, &noc)
        })
        .report()
    );
    println!(
        "{}",
        bench("collective: gather 8x1KB f32", budget, || {
            let blocks = vec![vec![1.0f32; 256]; 8];
            cluster_gather(&blocks, Transport::Dsmem, &hw, &noc)
        })
        .report()
    );

    // --- KV pool ---
    let geom = CacheGeometry { n_layers: 12, row_elems: 768, planes: 2, max_seq: 512 };
    {
        let mut pool = KvPool::new(geom, 16, 1024);
        pool.alloc_seq(1).unwrap();
        let row = vec![0.5f32; geom.n_layers * geom.row_elems];
        let mut next = 1u64;
        println!(
            "{}",
            bench("kv: append 1 token (12L x 768 x 2)", budget, || {
                if !pool.can_append(next) {
                    pool.free_seq(next);
                    next += 1;
                    pool.alloc_seq(next).unwrap();
                }
                pool.append(next, &[&row, &row]).unwrap();
            })
            .report()
        );
    }
    {
        let mut pool = KvPool::new(geom, 16, 64);
        let row = vec![0.5f32; geom.n_layers * geom.row_elems];
        for id in 1..=4u64 {
            pool.alloc_seq(id).unwrap();
            for _ in 0..128 {
                pool.append(id, &[&row, &row]).unwrap();
            }
        }
        let g = pool.geometry();
        let mut planes =
            vec![vec![0.0f32; g.n_layers * 4 * g.max_seq * g.row_elems]; g.planes];
        println!(
            "{}",
            bench("kv: gather_into 4 seq x 128 tok (plan cached)", budget, || {
                pool.gather_batch_into(&[1, 2, 3, 4], 4, &mut planes).unwrap()
            })
            .report()
        );
        println!(
            "{}",
            bench("kv: gather_batch alloc+zero (cold path)", budget, || {
                pool.gather_batch(&[1, 2, 3, 4], 4).unwrap()
            })
            .report()
        );
        println!(
            "{}",
            bench("kv: gather_plan_runs enumerate", budget, || {
                pool.gather_plan_runs(&[1, 2, 3, 4], 4).unwrap().len()
            })
            .report()
        );
    }

    // --- coordinator step (mock backend = pure coordinator overhead) ---
    println!(
        "{}",
        bench("engine: full step, mock backend, b4", budget, || {
            let mut e = Engine::new(MockBackend::tiny(), 64, 4, 1.0);
            for id in 0..4 {
                e.submit(Request::new(id, vec![1, 2], 2));
            }
            e.run_to_completion(64).unwrap();
            e.steps
        })
        .report()
    );
}
