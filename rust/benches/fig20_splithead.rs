//! Fig. 20: SplitToken vs SplitHead dataflow latency across sequence
//! lengths (+ two representative baselines for context).
//!
//! Paper: minimal difference at short sequences (register residency vs
//! small DSMEM gap), SplitHead degrades as S grows because its DSMEM
//! traffic is Reduce(S) + Reduce(D).

use clusterfusion::clustersim::dataflow::{
    block_isolated, split_head, split_token, AttnProblem, CostEnv,
};
use clusterfusion::clustersim::frameworks::FrameworkProfile;
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::metrics::Table;
use clusterfusion::models::ModelConfig;

fn main() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let model = ModelConfig::llama2_7b();

    println!("== Fig. 20: SplitToken vs SplitHead (Llama2-7B core modules, per layer, cluster 4) ==\n");
    let mut t = Table::new(vec![
        "seq",
        "SplitToken (us)",
        "SplitHead (us)",
        "SH/ST",
        "ST dsmem (KB)",
        "SH dsmem (KB)",
        "SGLang (us)",
        "vLLM (us)",
    ]);
    for seq in [512usize, 1024, 2048, 4096, 8192, 16384] {
        let p = AttnProblem {
            batch: 1,
            d_model: model.d_model,
            n_heads: model.n_heads,
            head_dim: model.head_dim,
            seq,
            kv_lora_rank: 0,
        };
        let env = CostEnv::clusterfusion(&hw, &noc, 4);
        let st = split_token::cost(&p, &env);
        let sh = split_head::cost(&p, &env);
        let mut env_sg = env;
        env_sg.bw_efficiency = FrameworkProfile::sglang().bw_efficiency;
        let sg = block_isolated::cost(&p, &env_sg);
        let mut env_vl = env;
        env_vl.bw_efficiency = FrameworkProfile::vllm().bw_efficiency;
        let vl = block_isolated::cost(&p, &env_vl);
        t.row(vec![
            seq.to_string(),
            format!("{:.1}", st.latency * 1e6),
            format!("{:.1}", sh.latency * 1e6),
            format!("{:.3}", sh.latency / st.latency),
            format!("{:.1}", st.dsmem_bytes / 1024.0),
            format!("{:.1}", sh.dsmem_bytes / 1024.0),
            format!("{:.1}", sg.latency * 1e6),
            format!("{:.1}", vl.latency * 1e6),
        ]);
    }
    t.print();
    println!("\nshape checks: SH/ST ~1 at short seq, grows with seq; SH dsmem ∝ S, ST constant;");
    println!("both fused variants beat the block-isolated baselines.");
}
