//! Fig. 17 (batch 1) and Appendix C Fig. 17 (batch 16): end-to-end TPOT of
//! ClusterFusion vs SGLang / vLLM / TensorRT-LLM / MLC-LLM on Llama2-7B
//! and DeepSeek-V2-Lite, sequence lengths 1K–16K, cluster size 4.
//!
//! Paper average speedups (batch 1): Llama2-7B 1.41/1.39/1.43/2.03x;
//! DeepSeek-V2-Lite 1.34/1.37/1.51/2.39x. Batch 16 shrinks everything to
//! ~1.1–1.3x (Llama) / 1.07–1.84x (DSV2).

use clusterfusion::clustersim::e2e::{decode_step, Engine};
use clusterfusion::clustersim::frameworks::FrameworkProfile;
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::metrics::Table;
use clusterfusion::models::ModelConfig;

fn main() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let seqs = [1024usize, 2048, 4096, 8192, 16384];
    let paper_b1 = [
        ("llama2-7b", [1.41, 1.39, 1.43, 2.03]),
        ("deepseek-v2-lite", [1.34, 1.37, 1.51, 2.39]),
    ];
    let paper_b16 = [
        ("llama2-7b", [1.11, 1.09, 1.12, 1.32]),
        ("deepseek-v2-lite", [1.15, 1.14, 1.07, 1.84]),
    ];

    for batch in [1usize, 16] {
        let fig = if batch == 1 { "Fig. 17" } else { "Appendix C Fig. 17" };
        let paper = if batch == 1 { &paper_b1 } else { &paper_b16 };
        for model in [ModelConfig::llama2_7b(), ModelConfig::deepseek_v2_lite()] {
            println!("== {fig}: TPOT (ms), {}, batch {batch}, cluster 4 ==\n", model.name);
            let mut t = Table::new(vec![
                "seq", "SGLang", "vLLM", "TRT-LLM", "MLC-LLM", "ClusterFusion",
            ]);
            let mut sums = [0.0f64; 4];
            let mut cf_sum = 0.0;
            for &seq in &seqs {
                let cf = decode_step(
                    &model,
                    batch,
                    seq,
                    Engine::ClusterFusion { cluster_size: 4 },
                    &FrameworkProfile::clusterfusion(),
                    &hw,
                    &noc,
                )
                .tpot;
                cf_sum += cf;
                let mut row = vec![seq.to_string()];
                for (i, b) in FrameworkProfile::baselines().iter().enumerate() {
                    let tp = decode_step(&model, batch, seq, Engine::BlockIsolated, b, &hw, &noc)
                        .tpot;
                    sums[i] += tp;
                    row.push(format!("{:.3}", tp * 1e3));
                }
                row.push(format!("{:.3}", cf * 1e3));
                t.row(row);
            }
            t.print();
            let pp = paper.iter().find(|(n, _)| *n == model.name).unwrap().1;
            println!("\navg speedup vs [SGLang vLLM TRT MLC]:");
            print!("  measured: ");
            for s in sums {
                print!("{:.2}x ", s / cf_sum);
            }
            print!("\n  paper:    ");
            for p in pp {
                print!("{p:.2}x ");
            }
            println!("\n");
        }
    }
    println!("shape checks: CF wins everywhere at bs=1; MLC trails most; bs=16 gains shrink.");
}
