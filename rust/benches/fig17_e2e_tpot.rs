//! Fig. 17 (batch 1) and Appendix C Fig. 17 (batch 16): end-to-end TPOT of
//! ClusterFusion vs SGLang / vLLM / TensorRT-LLM / MLC-LLM on Llama2-7B
//! and DeepSeek-V2-Lite, sequence lengths 1K–16K, cluster size 4.
//!
//! Paper average speedups (batch 1): Llama2-7B 1.41/1.39/1.43/2.03x;
//! DeepSeek-V2-Lite 1.34/1.37/1.51/2.39x. Batch 16 shrinks everything to
//! ~1.1–1.3x (Llama) / 1.07–1.84x (DSV2).

use clusterfusion::clustersim::e2e::{decode_step, Engine};
use clusterfusion::clustersim::frameworks::FrameworkProfile;
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::coordinator::engine::{Engine as ServeEngine, MockBackend, ModelGeom};
use clusterfusion::loadgen::{self, ServiceModel};
use clusterfusion::metrics::Table;
use clusterfusion::models::ModelConfig;
use clusterfusion::util::clock::VirtualClock;
use clusterfusion::workload::{SeqlenDist, Trace};

fn main() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let seqs = [1024usize, 2048, 4096, 8192, 16384];
    let paper_b1 = [
        ("llama2-7b", [1.41, 1.39, 1.43, 2.03]),
        ("deepseek-v2-lite", [1.34, 1.37, 1.51, 2.39]),
    ];
    let paper_b16 = [
        ("llama2-7b", [1.11, 1.09, 1.12, 1.32]),
        ("deepseek-v2-lite", [1.15, 1.14, 1.07, 1.84]),
    ];

    for batch in [1usize, 16] {
        let fig = if batch == 1 { "Fig. 17" } else { "Appendix C Fig. 17" };
        let paper = if batch == 1 { &paper_b1 } else { &paper_b16 };
        for model in [ModelConfig::llama2_7b(), ModelConfig::deepseek_v2_lite()] {
            println!("== {fig}: TPOT (ms), {}, batch {batch}, cluster 4 ==\n", model.name);
            let mut t = Table::new(vec![
                "seq", "SGLang", "vLLM", "TRT-LLM", "MLC-LLM", "ClusterFusion",
            ]);
            let mut sums = [0.0f64; 4];
            let mut cf_sum = 0.0;
            for &seq in &seqs {
                let cf = decode_step(
                    &model,
                    batch,
                    seq,
                    Engine::ClusterFusion { cluster_size: 4 },
                    &FrameworkProfile::clusterfusion(),
                    &hw,
                    &noc,
                )
                .tpot;
                cf_sum += cf;
                let mut row = vec![seq.to_string()];
                for (i, b) in FrameworkProfile::baselines().iter().enumerate() {
                    let tp = decode_step(&model, batch, seq, Engine::BlockIsolated, b, &hw, &noc)
                        .tpot;
                    sums[i] += tp;
                    row.push(format!("{:.3}", tp * 1e3));
                }
                row.push(format!("{:.3}", cf * 1e3));
                t.row(row);
            }
            t.print();
            let pp = paper.iter().find(|(n, _)| *n == model.name).unwrap().1;
            println!("\navg speedup vs [SGLang vLLM TRT MLC]:");
            print!("  measured: ");
            for s in sums {
                print!("{:.2}x ", s / cf_sum);
            }
            print!("\n  paper:    ");
            for p in pp {
                print!("{p:.2}x ");
            }
            println!("\n");
        }
    }
    println!("shape checks: CF wins everywhere at bs=1; MLC trails most; bs=16 gains shrink.");
    under_load();
    rps_sweep();
}

/// TPOT/TTFT percentiles under open-loop traffic: each framework's cost
/// model supplies a flat per-step service time and the *same* seeded
/// trace is replayed on a deterministic virtual clock (loadgen::replay).
/// This is the paper's Fig. 17 methodology — latency under load rather
/// than isolated steps; see EXPERIMENTS.md §Fig. 17 under traffic.
fn under_load() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let model = ModelConfig::llama2_7b();
    let (batch, seq) = (8usize, 4096usize);

    let step_tpot = |engine: Engine, p: &FrameworkProfile| {
        decode_step(&model, batch, seq, engine, p, &hw, &noc).tpot
    };
    // Offer 80% of SGLang's saturation throughput — max batch 8, and each
    // request takes 16 prompt + 8 generated − 1 overlapping step = 23
    // steps: comfortably under capacity for ClusterFusion, at or past the
    // knee for the slower baselines.
    let sg_tpot = step_tpot(Engine::BlockIsolated, &FrameworkProfile::sglang());
    let rps = 0.8 * 8.0 / (23.0 * sg_tpot);
    let trace = Trace::poisson(96, rps, SeqlenDist::Fixed(24), (8, 8), 64, 42);

    println!(
        "== Fig. 17 under traffic: llama2-7b, step cost @ (batch {batch}, seq {seq}), \
         {:.1} rps, 96 requests ==\n",
        trace.achieved_rps()
    );
    let mut t = Table::new(vec![
        "framework", "step(ms)", "ttft p50", "ttft p99", "tpot p50", "tpot p99", "e2e p99",
    ]);
    for p in FrameworkProfile::all() {
        let engine_kind = if p.name == "ClusterFusion" {
            Engine::ClusterFusion { cluster_size: 4 }
        } else {
            Engine::BlockIsolated
        };
        let tpot = step_tpot(engine_kind, &p);
        let service = ServiceModel::from_tpot_us((tpot * 1e6) as u64);
        let geom = ModelGeom { vocab: 64, n_layers: 2, row_elems: 4, planes: 2, max_seq: 64 };
        let mut engine = ServeEngine::with_clock(
            MockBackend::new(geom, vec![1, 2, 4, 8]),
            128,
            4,
            0.5,
            VirtualClock::shared(),
        );
        let requests = loadgen::synthesize_requests(&trace, 64, 16, 8, 7);
        let rep = loadgen::replay(&mut engine, &requests, &service, 2_000_000)
            .expect("under-load replay");
        let pct = rep.percentiles;
        t.row(vec![
            p.name.to_string(),
            format!("{:.3}", tpot * 1e3),
            format!("{:.1}", pct.ttft.p50 * 1e3),
            format!("{:.1}", pct.ttft.p99 * 1e3),
            format!("{:.2}", pct.tpot.p50 * 1e3),
            format!("{:.2}", pct.tpot.p99 * 1e3),
            format!("{:.1}", pct.e2e.p99 * 1e3),
        ]);
    }
    t.print();
    println!(
        "\nshape: p50 TPOT tracks the per-step cost; queueing amplifies the gap into the\n\
         TTFT/e2e tails for frameworks past the knee (paper Fig. 17's latency-under-load win)."
    );
}

/// Offered-rps sweep: the full TPOT-vs-load curve (the ROADMAP loadgen
/// follow-up). One seeded Poisson trace per offered rate is replayed per
/// framework on the deterministic virtual clock (`loadgen::replay`), with
/// each framework's flat per-step cost from the batch-8 cost model —
/// identical methodology to [`under_load`], swept across load instead of
/// pinned at 80% of SGLang saturation. Deterministic: trace seed 42,
/// prompt seed 7; tables recorded in EXPERIMENTS.md §TPOT-vs-load.
fn rps_sweep() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let model = ModelConfig::llama2_7b();
    let (batch, seq) = (8usize, 4096usize);

    let step_tpot = |engine: Engine, p: &FrameworkProfile| {
        decode_step(&model, batch, seq, engine, p, &hw, &noc).tpot
    };
    // Load axis: fractions of SGLang's saturation throughput (max batch 8,
    // 23 steps per 16-prompt + 8-gen request), the same reference point
    // under_load() uses so the 0.8 column reproduces its table.
    let sg_tpot = step_tpot(Engine::BlockIsolated, &FrameworkProfile::sglang());
    let sat = 8.0 / (23.0 * sg_tpot);
    let factors = [0.25f64, 0.5, 0.8, 1.0, 1.25, 1.6];

    let frameworks = FrameworkProfile::all();
    let mut header = vec!["load".to_string(), "offered rps".to_string()];
    header.extend(frameworks.iter().map(|p| p.name.to_string()));

    let run = |p: &FrameworkProfile, rps: f64| {
        let engine_kind = if p.name == "ClusterFusion" {
            Engine::ClusterFusion { cluster_size: 4 }
        } else {
            Engine::BlockIsolated
        };
        let tpot = step_tpot(engine_kind, p);
        let service = ServiceModel::from_tpot_us((tpot * 1e6) as u64);
        let geom = ModelGeom { vocab: 64, n_layers: 2, row_elems: 4, planes: 2, max_seq: 64 };
        let mut engine = ServeEngine::with_clock(
            MockBackend::new(geom, vec![1, 2, 4, 8]),
            128,
            4,
            0.5,
            VirtualClock::shared(),
        );
        let trace = Trace::poisson(96, rps, SeqlenDist::Fixed(24), (8, 8), 64, 42);
        let requests = loadgen::synthesize_requests(&trace, 64, 16, 8, 7);
        loadgen::replay(&mut engine, &requests, &service, 2_000_000).expect("sweep replay")
    };

    println!(
        "== TPOT-vs-load sweep: llama2-7b step cost @ (batch {batch}, seq {seq}), \
         96 requests/point, load normalised to SGLang saturation ({sat:.1} rps) ==\n"
    );
    let mut t_tpot = Table::new(header.clone());
    let mut t_ttft = Table::new(header);
    for &f in &factors {
        let rps = f * sat;
        let mut row_tpot = vec![format!("{f:.2}x"), format!("{rps:.1}")];
        let mut row_ttft = row_tpot.clone();
        for p in &frameworks {
            let rep = run(p, rps);
            row_tpot.push(format!("{:.2}", rep.percentiles.tpot.p50 * 1e3));
            row_ttft.push(format!("{:.1}", rep.percentiles.ttft.p99 * 1e3));
        }
        t_tpot.row(row_tpot);
        t_ttft.row(row_ttft);
    }
    println!("-- tpot p50 (ms) vs offered load --");
    t_tpot.print();
    println!("\n-- ttft p99 (ms) vs offered load --");
    t_ttft.print();
    println!(
        "\nshape: below each framework's knee p50 TPOT equals its step cost (flat curve);\n\
         past the knee the queue absorbs the overload — TPOT stays bounded by the step\n\
         cost while p99 TTFT explodes. ClusterFusion's knee sits ~1.27x further right\n\
         than SGLang's and ~2x past MLC-LLM's (the Fig. 17 latency-under-load win as a\n\
         full curve rather than one operating point)."
    );
}
