//! Fig. 18 (batch 1) and Appendix C Fig. 18 (batch 16): latency of the
//! core modules (QKV Projection + Attention + Output Projection, summed
//! over layers) — the fused scope itself, without FFN dilution.
//!
//! Paper average speedups (batch 1): Llama2-7B 1.85/1.73/1.61/3.19x;
//! DeepSeek-V2-Lite 1.66/1.64/1.35/3.5x.

use clusterfusion::clustersim::e2e::{attn_block_cost, Engine};
use clusterfusion::clustersim::frameworks::FrameworkProfile;
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::metrics::Table;
use clusterfusion::models::ModelConfig;

fn main() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let seqs = [1024usize, 2048, 4096, 8192, 16384];
    let paper_b1 = [
        ("llama2-7b", [1.85, 1.73, 1.61, 3.19]),
        ("deepseek-v2-lite", [1.66, 1.64, 1.35, 3.50]),
    ];
    let paper_b16 = [
        ("llama2-7b", [1.14, 1.12, 1.20, 1.41]),
        ("deepseek-v2-lite", [1.19, 1.18, 1.14, 2.04]),
    ];

    for batch in [1usize, 16] {
        let fig = if batch == 1 { "Fig. 18" } else { "Appendix C Fig. 18" };
        let paper = if batch == 1 { &paper_b1 } else { &paper_b16 };
        for model in [ModelConfig::llama2_7b(), ModelConfig::deepseek_v2_lite()] {
            println!(
                "== {fig}: core-module latency (ms, all layers), {}, batch {batch} ==\n",
                model.name
            );
            let mut t = Table::new(vec![
                "seq", "SGLang", "vLLM", "TRT-LLM", "MLC-LLM", "ClusterFusion",
            ]);
            let l = model.n_layers as f64;
            let mut sums = [0.0f64; 4];
            let mut cf_sum = 0.0;
            for &seq in &seqs {
                let cf = attn_block_cost(
                    &model,
                    batch,
                    seq,
                    Engine::ClusterFusion { cluster_size: 4 },
                    &FrameworkProfile::clusterfusion(),
                    &hw,
                    &noc,
                )
                .latency
                    * l;
                cf_sum += cf;
                let mut row = vec![seq.to_string()];
                for (i, b) in FrameworkProfile::baselines().iter().enumerate() {
                    let tp =
                        attn_block_cost(&model, batch, seq, Engine::BlockIsolated, b, &hw, &noc)
                            .latency
                            * l;
                    sums[i] += tp;
                    row.push(format!("{:.3}", tp * 1e3));
                }
                row.push(format!("{:.3}", cf * 1e3));
                t.row(row);
            }
            t.print();
            let pp = paper.iter().find(|(n, _)| *n == model.name).unwrap().1;
            println!("\navg speedup vs [SGLang vLLM TRT MLC]:");
            print!("  measured: ");
            for s in sums {
                print!("{:.2}x ", s / cf_sum);
            }
            print!("\n  paper:    ");
            for p in pp {
                print!("{p:.2}x ");
            }
            println!("\n");
        }
    }
    println!("shape checks: core-module speedups exceed e2e speedups (fusion scope undiluted).");
}
