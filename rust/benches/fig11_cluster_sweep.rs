//! Fig. 11: core-module latency in ClusterFusion for varying cluster
//! sizes and head counts (32/64/128), sequence lengths 4K and 16K.
//!
//! Paper findings: cluster 4 optimal at 32/64 heads; cluster 2 optimal at
//! 128 heads; 8 and 16 always worse (interconnect latency, bandwidth
//! contention, fewer active SMs).

use clusterfusion::clustersim::dataflow::{split_token, AttnProblem, CostEnv};
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::metrics::Table;

fn main() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);

    for seq in [4096usize, 16384] {
        println!("== Fig. 11: fused core-module latency (us), seq = {seq} ==\n");
        let mut t = Table::new(vec!["heads", "N=1", "N=2", "N=4", "N=8", "N=16", "best"]);
        for heads in [32usize, 64, 128] {
            let p = AttnProblem {
                batch: 1,
                d_model: heads * 128,
                n_heads: heads,
                head_dim: 128,
                seq,
                kv_lora_rank: 0,
            };
            let lats: Vec<(usize, f64)> = Noc::cluster_sizes()
                .iter()
                .map(|&n| (n, split_token::cost(&p, &CostEnv::clusterfusion(&hw, &noc, n)).latency))
                .collect();
            let best = lats.iter().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap().0;
            let mut row = vec![heads.to_string()];
            row.extend(lats.iter().map(|(_, l)| format!("{:.1}", l * 1e6)));
            row.push(format!("N={best}"));
            t.row(row);
        }
        t.print();
        println!();
    }
    println!("shape checks: N=4 best at 32 heads, near-tie with N=2 at 64 heads; N=2 best at 128 heads; 8/16 never best.");
}
