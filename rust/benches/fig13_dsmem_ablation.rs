//! Fig. 13: TPOT of ClusterFusion on Llama2-7B with and without DSMEM —
//! the ablation that isolates the cluster-level primitives' contribution.
//! The fused schedule stays; collectives fall back to global memory.
//!
//! Paper: disabling DSMEM increases TPOT by up to 33 %.

use clusterfusion::clustersim::e2e::{decode_step, Engine};
use clusterfusion::clustersim::frameworks::FrameworkProfile;
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::metrics::Table;
use clusterfusion::models::ModelConfig;

fn main() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let model = ModelConfig::llama2_7b();
    let p = FrameworkProfile::clusterfusion();

    println!("== Fig. 13: TPOT with vs without DSMEM (Llama2-7B, cluster 4, batch 1) ==\n");
    let mut t = Table::new(vec!["seq", "DSMEM on (ms)", "DSMEM off (ms)", "increase (%)"]);
    let mut worst: f64 = 0.0;
    for seq in [1024usize, 2048, 4096, 8192, 16384] {
        let on =
            decode_step(&model, 1, seq, Engine::ClusterFusion { cluster_size: 4 }, &p, &hw, &noc);
        let off = decode_step(
            &model,
            1,
            seq,
            Engine::ClusterFusionNoDsmem { cluster_size: 4 },
            &p,
            &hw,
            &noc,
        );
        let inc = (off.tpot / on.tpot - 1.0) * 100.0;
        worst = worst.max(inc);
        t.row(vec![
            seq.to_string(),
            format!("{:.3}", on.tpot * 1e3),
            format!("{:.3}", off.tpot * 1e3),
            format!("{:.1}", inc),
        ]);
    }
    t.print();
    println!("\nshape check: TPOT increase up to {worst:.1}% (paper: up to 33%).");
}
