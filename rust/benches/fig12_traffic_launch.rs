//! Fig. 12 (bs=1) and Fig. 19 (bs=16): global-memory data-transfer size
//! (left) and GPU kernel-launch overhead (right), ClusterFusion vs the
//! block-isolated baselines.
//!
//! The traffic panel reports the *intermediate* transfers of the fused
//! scope (Q/K/V vectors, FlashDecoding partials, attention output) — the
//! bytes the paper's Nsight profiling attributes to inter-kernel
//! materialisation. Mandatory traffic (weights, KV cache, activations) is
//! identical across systems and listed for scale; at bs=16 it dominates,
//! which is exactly the Appendix-C observation that the relative traffic
//! gain shrinks.

use clusterfusion::clustersim::dataflow::AttnProblem;
use clusterfusion::clustersim::e2e::{attn_block_cost, decode_step, Engine};
use clusterfusion::clustersim::frameworks::FrameworkProfile;
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::metrics::Table;
use clusterfusion::models::{AttnKind, ModelConfig};

fn main() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let cf = FrameworkProfile::clusterfusion();
    let sg = FrameworkProfile::sglang();

    for batch in [1usize, 16] {
        let fig = if batch == 1 { "Fig. 12" } else { "Fig. 19 (Appendix C)" };
        println!("== {fig}: intermediate HBM traffic + kernel launches, batch {batch} ==\n");
        let mut t = Table::new(vec![
            "model",
            "seq",
            "mandatory (MB/layer)",
            "base intermed (MB/layer)",
            "CF intermed (MB/layer)",
            "base launches/step",
            "CF launches/step",
            "ratio",
        ]);
        for model in [ModelConfig::llama2_7b(), ModelConfig::deepseek_v2_lite()] {
            for seq in [1024usize, 4096, 16384] {
                let p = AttnProblem {
                    batch,
                    d_model: model.d_model,
                    n_heads: model.n_heads,
                    head_dim: model.head_dim,
                    seq,
                    kv_lora_rank: model.kv_lora_rank,
                };
                let mandatory = match model.attn {
                    AttnKind::Mha => p.mandatory_bytes_mha(),
                    AttnKind::Mla => p.mandatory_bytes_mla(),
                };
                let base = attn_block_cost(&model, batch, seq, Engine::BlockIsolated, &sg, &hw, &noc);
                let fused = attn_block_cost(
                    &model, batch, seq,
                    Engine::ClusterFusion { cluster_size: 4 },
                    &cf, &hw, &noc,
                );
                let base_e2e = decode_step(&model, batch, seq, Engine::BlockIsolated, &sg, &hw, &noc);
                let cf_e2e = decode_step(
                    &model, batch, seq,
                    Engine::ClusterFusion { cluster_size: 4 },
                    &cf, &hw, &noc,
                );
                t.row(vec![
                    model.name.clone(),
                    seq.to_string(),
                    format!("{:.1}", mandatory / 1e6),
                    format!("{:.3}", (base.hbm_bytes - mandatory).max(0.0) / 1e6),
                    format!("{:.3}", (fused.hbm_bytes - mandatory).max(0.0) / 1e6),
                    base_e2e.launches.to_string(),
                    cf_e2e.launches.to_string(),
                    format!("{:.1}x", base_e2e.launches as f64 / cf_e2e.launches as f64),
                ]);
            }
        }
        t.print();
        println!();
    }
    println!("shape checks: CF intermediates == 0 (everything on-chip) vs baseline > 0;");
    println!("launch count cut >2x vs CUDA-graph baselines (paper: ~an order of magnitude");
    println!("counting every auxiliary kernel); mandatory traffic dwarfs intermediates at bs=16.");
}
