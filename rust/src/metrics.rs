//! Serving metrics: latency recorders, percentile summaries, and the
//! paper-style table printer used by every figure bench.

use std::cell::OnceCell;
use std::collections::BTreeMap;
use std::time::Duration;


/// Online latency recorder (stores all samples; decode-scale cardinality).
///
/// Percentile queries sort **once** into a lazily-built cached view
/// (`sorted`); `record` invalidates it. A `Summary` used to clone and
/// sort the full sample vector four times (once per percentile plus
/// none for mean/max), which made report assembly O(4·n log n) per
/// metric — now it is one sort amortised over every query until the
/// next record. Rendered reports are byte-identical to the pre-cache
/// behaviour (same nearest-rank indices over the same total order).
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples: Vec<f64>,
    /// Sorted copy of `samples`, built on first percentile query after
    /// the last `record`. `OnceCell` (not `Mutex`): queries take `&self`
    /// on a single thread, records take `&mut self` and reset it.
    sorted: OnceCell<Vec<f64>>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, seconds: f64) {
        debug_assert!(seconds.is_finite() && seconds >= 0.0);
        self.samples.push(seconds);
        self.sorted.take(); // invalidate the cached sorted view
    }

    pub fn record_duration(&mut self, d: Duration) {
        self.record(d.as_secs_f64());
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Nearest-rank percentile, `q` in [0, 1].
    pub fn percentile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.samples.is_empty() {
            return 0.0;
        }
        let s = self.sorted.get_or_init(|| {
            let mut s = self.samples.clone();
            s.sort_by(f64::total_cmp);
            s
        });
        let idx = ((q * s.len() as f64).ceil() as usize).clamp(1, s.len()) - 1;
        s[idx]
    }

    pub fn summary(&self) -> Summary {
        Summary {
            count: self.len(),
            mean: self.mean(),
            p50: self.percentile(0.50),
            p90: self.percentile(0.90),
            p99: self.percentile(0.99),
            max: self.samples.iter().cloned().fold(0.0, f64::max),
        }
    }
}

/// Reduce a sample slice to a [`Summary`] (convenience for callers that
/// already hold their samples).
pub fn summarize(samples: &[f64]) -> Summary {
    let mut r = LatencyRecorder::new();
    for &s in samples {
        r.record(s);
    }
    r.summary()
}

/// Summary statistics of a latency distribution (seconds).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn fmt_ms(&self) -> String {
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p90={:.3}ms p99={:.3}ms max={:.3}ms",
            self.count,
            self.mean * 1e3,
            self.p50 * 1e3,
            self.p90 * 1e3,
            self.p99 * 1e3,
            self.max * 1e3
        )
    }

    /// Row cells (milliseconds, fixed 3-decimal format) for
    /// [`PercentileReport::render`]. The fixed format is part of the
    /// determinism contract: identical samples yield identical bytes.
    fn row_ms(&self, metric: &str) -> Vec<String> {
        vec![
            metric.to_string(),
            self.count.to_string(),
            format!("{:.3}", self.mean * 1e3),
            format!("{:.3}", self.p50 * 1e3),
            format!("{:.3}", self.p90 * 1e3),
            format!("{:.3}", self.p99 * 1e3),
            format!("{:.3}", self.max * 1e3),
        ]
    }
}

/// Percentile summaries of the four serving latency metrics the load
/// generator records per request (paper Fig. 17 methodology: latency
/// percentiles under open-loop traffic). All values in seconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PercentileReport {
    /// Submission → admission wait.
    pub queue: Summary,
    /// Submission → first generated token.
    pub ttft: Summary,
    /// Mean inter-token time after the first (per request, then
    /// summarised across requests).
    pub tpot: Summary,
    /// Submission → completion.
    pub e2e: Summary,
}

impl PercentileReport {
    pub fn from_samples(queue: &[f64], ttft: &[f64], tpot: &[f64], e2e: &[f64]) -> Self {
        Self {
            queue: summarize(queue),
            ttft: summarize(ttft),
            tpot: summarize(tpot),
            e2e: summarize(e2e),
        }
    }

    /// Fixed-format table (milliseconds). Byte-identical for identical
    /// inputs — load tests compare two runs' renders directly.
    pub fn render(&self) -> String {
        let mut t = Table::new(vec!["metric", "n", "mean", "p50", "p90", "p99", "max"]);
        t.row(self.queue.row_ms("queue"));
        t.row(self.ttft.row_ms("ttft"));
        t.row(self.tpot.row_ms("tpot"));
        t.row(self.e2e.row_ms("e2e"));
        t.render()
    }
}

/// Small-integer count histogram (retry counts, preemption depths):
/// how many observations took each value. Ordered storage so the render
/// is deterministic.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CountHistogram {
    counts: BTreeMap<u64, u64>,
}

impl CountHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, value: u64) {
        *self.counts.entry(value).or_insert(0) += 1;
    }

    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    /// Number of observations recorded.
    pub fn total(&self) -> u64 {
        self.counts.values().sum()
    }

    /// Observations of exactly `value`.
    pub fn count(&self, value: u64) -> u64 {
        self.counts.get(&value).copied().unwrap_or(0)
    }

    /// `"<value>x<count>"` pairs in ascending value order, e.g. `"1x12 2x3"`
    /// (12 observations of 1, 3 of 2); `"-"` when empty. Byte-stable.
    pub fn render(&self) -> String {
        if self.counts.is_empty() {
            return "-".to_string();
        }
        self.counts
            .iter()
            .map(|(v, c)| format!("{v}x{c}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Fixed-width table printer for the paper-figure benches: prints a header
/// and rows like the paper's tables so runs can be eyeballed against it.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Self { headers: headers.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .zip(widths.iter().copied())
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Throughput helper: tokens emitted over a wall-clock window.
#[derive(Debug, Clone, Copy, Default)]
pub struct Throughput {
    pub tokens: u64,
    pub seconds: f64,
}

impl Throughput {
    pub fn tokens_per_second(&self) -> f64 {
        if self.seconds <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.seconds
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record(i as f64);
        }
        assert_eq!(r.percentile(0.50), 50.0);
        assert_eq!(r.percentile(0.99), 99.0);
        assert_eq!(r.percentile(1.0), 100.0);
        assert_eq!(r.summary().count, 100);
    }

    #[test]
    fn percentile_cache_invalidates_on_record_and_matches_uncached() {
        let mut r = LatencyRecorder::new();
        r.record(1.0);
        assert_eq!(r.percentile(1.0), 1.0); // builds the sorted cache
        r.record(5.0); // must invalidate it
        assert_eq!(r.percentile(1.0), 5.0);
        assert_eq!(r.percentile(0.5), 1.0);
        // a cached recorder's summary equals a freshly-built one, so
        // rendered reports stay byte-identical to the pre-cache code
        assert_eq!(r.summary(), summarize(&[1.0, 5.0]));
    }

    #[test]
    fn empty_recorder_is_zeroes() {
        let r = LatencyRecorder::new();
        assert_eq!(r.mean(), 0.0);
        assert_eq!(r.percentile(0.9), 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["seq", "tpot"]);
        t.row(vec!["1024", "5.1"]);
        t.row(vec!["16384", "12.3"]);
        let s = t.render();
        assert!(s.contains("seq"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn throughput() {
        let t = Throughput { tokens: 500, seconds: 2.0 };
        assert_eq!(t.tokens_per_second(), 250.0);
    }

    #[test]
    fn summarize_matches_recorder() {
        let xs: Vec<f64> = (1..=50).map(|i| i as f64).collect();
        let s = summarize(&xs);
        assert_eq!(s.count, 50);
        assert_eq!(s.p50, 25.0);
        assert_eq!(s.max, 50.0);
    }

    #[test]
    fn percentile_report_renders_deterministically() {
        let q = [0.001, 0.002];
        let f = [0.010, 0.030];
        let p = [0.002, 0.002];
        let e = [0.050, 0.090];
        let a = PercentileReport::from_samples(&q, &f, &p, &e);
        let b = PercentileReport::from_samples(&q, &f, &p, &e);
        assert_eq!(a, b);
        let ra = a.render();
        assert_eq!(ra, b.render(), "render must be byte-identical");
        for metric in ["queue", "ttft", "tpot", "e2e"] {
            assert!(ra.contains(metric), "{metric} row missing:\n{ra}");
        }
        // 30 ms p99 TTFT formatted in ms with 3 decimals
        assert!(ra.contains("30.000"), "{ra}");
    }

    #[test]
    fn count_histogram_renders_sorted_and_stable() {
        let mut h = CountHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.render(), "-");
        for v in [2, 1, 1, 3, 1] {
            h.add(v);
        }
        assert_eq!(h.total(), 5);
        assert_eq!(h.count(1), 3);
        assert_eq!(h.count(7), 0);
        assert_eq!(h.render(), "1x3 2x1 3x1");
        let mut h2 = CountHistogram::new();
        for v in [1, 1, 1, 2, 3] {
            h2.add(v);
        }
        assert_eq!(h, h2, "insertion order must not matter");
    }

    #[test]
    fn percentile_report_empty_inputs() {
        let r = PercentileReport::from_samples(&[], &[], &[], &[]);
        assert_eq!(r.ttft.count, 0);
        assert_eq!(r.ttft.p99, 0.0);
        assert!(r.render().contains("e2e"));
    }
}
