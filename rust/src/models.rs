//! Model architecture descriptions.
//!
//! Two kinds of model are used in this repository, mirroring the paper:
//!
//! * the paper's **evaluation models** (Llama2-7B with standard MHA,
//!   DeepSeek-V2-Lite with MLA) — used by [`crate::clustersim`] for cost
//!   modelling of every table/figure; their weights are never materialised;
//! * the **live demo models** (`tiny-llama-100m`, `tiny-mla-100m`) — ~100 M
//!   parameter architectures whose decode step is AOT-compiled from JAX
//!   (see `python/compile/aot.py`) and actually executed through PJRT by
//!   the serving engine;
//! * the **micro models** (`micro-llama`, `micro-mla`) — sub-M-parameter
//!   architectures whose weights are [materialized][MaterializedWeights]
//!   from a seeded RNG and decoded *functionally* by the full-block
//!   pipeline (`clustersim::block` + `coordinator::FunctionalBackend`),
//!   so serving runs real numerics with no artifacts and no PJRT.

use crate::util::rng::Rng;

/// Attention mechanism family (paper §2.1 / Appendix B.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnKind {
    /// Standard multi-head attention (Llama-style).
    Mha,
    /// DeepSeek multi-head latent attention, weight-absorbed decode form.
    Mla,
}

/// Architectural hyper-parameters of a decoder-only transformer.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub ffn_dim: usize,
    pub max_seq: usize,
    pub attn: AttnKind,
    /// Latent dimension (kv_lora_rank); only meaningful for [`AttnKind::Mla`].
    pub kv_lora_rank: usize,
}

impl ModelConfig {
    /// Total head dimension H = n_heads * head_dim.
    pub fn total_head_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// Parameter count (must agree with `python/compile/model.py`).
    pub fn param_count(&self) -> usize {
        let (d, f, v, l) = (self.d_model, self.ffn_dim, self.vocab, self.n_layers);
        let h = self.total_head_dim();
        let attn = match self.attn {
            AttnKind::Mha => d * h * 3 + h * d,
            AttnKind::Mla => {
                let r = self.kv_lora_rank;
                d * self.n_heads * r + d * r + self.n_heads * r * self.head_dim + h * d
            }
        };
        v * d + l * (attn + 3 * d * f + 2 * d) + d
    }

    /// Bytes of KV cache per token per layer (fp16 on the paper's H100,
    /// element size passed in for generality).
    pub fn kv_bytes_per_token_layer(&self, elem: usize) -> usize {
        match self.attn {
            AttnKind::Mha => 2 * self.total_head_dim() * elem,
            AttnKind::Mla => self.kv_lora_rank * elem,
        }
    }

    /// Llama2-7B — the paper's MHA evaluation model (§4 Models).
    pub fn llama2_7b() -> Self {
        Self {
            name: "llama2-7b".into(),
            vocab: 32000,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            head_dim: 128,
            ffn_dim: 11008,
            max_seq: 16384,
            attn: AttnKind::Mha,
            kv_lora_rank: 0,
        }
    }

    /// DeepSeek-V2-Lite — the paper's MLA evaluation model (§4 Models,
    /// kv_lora_rank = 512 per Appendix B.1).
    pub fn deepseek_v2_lite() -> Self {
        Self {
            name: "deepseek-v2-lite".into(),
            vocab: 102400,
            d_model: 2048,
            n_layers: 27,
            n_heads: 16,
            head_dim: 128,
            ffn_dim: 10944,
            attn: AttnKind::Mla,
            kv_lora_rank: 512,
            max_seq: 16384,
        }
    }

    /// ~100 M-parameter Llama-style model executed live through PJRT by
    /// the end-to-end example (DESIGN.md "End-to-end validation").
    pub fn tiny_llama_100m() -> Self {
        Self {
            name: "tiny-llama-100m".into(),
            vocab: 16384,
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            head_dim: 64,
            ffn_dim: 2048,
            max_seq: 512,
            attn: AttnKind::Mha,
            kv_lora_rank: 0,
        }
    }

    /// MLA twin of [`Self::tiny_llama_100m`].
    pub fn tiny_mla_100m() -> Self {
        Self {
            name: "tiny-mla-100m".into(),
            attn: AttnKind::Mla,
            kv_lora_rank: 128,
            ..Self::tiny_llama_100m()
        }
    }

    /// ~0.2 M-parameter Llama-style model small enough to decode
    /// *functionally* (full block pipeline, `clustersim::block`) at
    /// interactive speed — the default model of `clusterfusion serve` and
    /// `examples/quickstart.rs` when no AOT artifacts are present. Every
    /// dimension divides cleanly by cluster sizes 1/2/4 (the functional
    /// dataflows' partitioning requirement).
    pub fn micro_llama() -> Self {
        Self {
            name: "micro-llama".into(),
            vocab: 256,
            d_model: 64,
            n_layers: 4,
            n_heads: 4,
            head_dim: 16,
            ffn_dim: 160,
            max_seq: 128,
            attn: AttnKind::Mha,
            kv_lora_rank: 0,
        }
    }

    /// MLA twin of [`Self::micro_llama`] (latent rank 32 divides by
    /// cluster sizes 1/2/4 too).
    pub fn micro_mla() -> Self {
        Self {
            name: "micro-mla".into(),
            attn: AttnKind::Mla,
            kv_lora_rank: 32,
            ..Self::micro_llama()
        }
    }

    /// Fig. 11 head-count sweep variants: same per-head dim, varying head
    /// count (the paper sweeps 32 / 64 / 128 heads).
    pub fn head_sweep_variant(n_heads: usize) -> Self {
        Self {
            name: format!("sweep-{n_heads}h"),
            d_model: n_heads * 128,
            n_heads,
            ..Self::llama2_7b()
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "llama2-7b" => Some(Self::llama2_7b()),
            "deepseek-v2-lite" => Some(Self::deepseek_v2_lite()),
            "tiny-llama-100m" => Some(Self::tiny_llama_100m()),
            "tiny-mla-100m" => Some(Self::tiny_mla_100m()),
            "micro-llama" => Some(Self::micro_llama()),
            "micro-mla" => Some(Self::micro_mla()),
            _ => None,
        }
    }
}

/// One layer's attention weights, raw row-major `f32` (layouts match the
/// functional dataflows; see `clustersim::dataflow`).
#[derive(Debug, Clone)]
pub enum AttnWeights {
    /// `wq`/`wk`/`wv` are `(D, nh·dh)`, `wo` is `(nh·dh, D)`.
    Mha { wq: Vec<f32>, wk: Vec<f32>, wv: Vec<f32>, wo: Vec<f32> },
    /// Weight-absorbed MLA: `wq` `(D, nh·l)`, `wkv` `(D, l)`,
    /// `w_down` `(nh, l, dh)`, `wo` `(nh·dh, D)`.
    Mla { wq: Vec<f32>, wkv: Vec<f32>, w_down: Vec<f32>, wo: Vec<f32> },
}

/// One transformer layer's full weight set.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    /// RMSNorm gain before attention, `(D,)`.
    pub attn_norm: Vec<f32>,
    pub attn: AttnWeights,
    /// RMSNorm gain before the MLP, `(D,)`.
    pub mlp_norm: Vec<f32>,
    /// SwiGLU MLP: `w_gate`/`w_up` `(D, F)`, `w_down` `(F, D)`.
    pub w_gate: Vec<f32>,
    pub w_up: Vec<f32>,
    pub w_down: Vec<f32>,
}

/// A model's weights materialized from a seeded RNG — the functional
/// serving path's parameter store (`coordinator::FunctionalBackend`).
/// The logits head is tied to the embedding (`logits = h_norm · Eᵀ`), so
/// no separate LM-head matrix exists.
///
/// Deterministic in `(config, seed)`: the same pair always yields
/// byte-identical tensors (SplitMix64 stream, fixed draw order), which is
/// what makes greedy functional decoding reproducible end to end.
#[derive(Debug, Clone)]
pub struct MaterializedWeights {
    pub config: ModelConfig,
    /// Token embedding `(vocab, D)` row-major; also the tied logits head.
    pub embedding: Vec<f32>,
    pub layers: Vec<LayerWeights>,
    /// Final RMSNorm gain, `(D,)`.
    pub final_norm: Vec<f32>,
}

impl MaterializedWeights {
    /// Draw every tensor from one SplitMix64 stream seeded with `seed`.
    /// Projection scales shrink like `1/sqrt(n_in)` so the residual
    /// stream stays O(1) across layers (greedy decode then explores a
    /// nontrivial token distribution instead of saturating).
    pub fn materialize(config: &ModelConfig, seed: u64) -> Self {
        fn tensor(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
            (0..n).map(|_| (rng.f32() - 0.5) * scale).collect()
        }
        fn norm_gain(rng: &mut Rng, n: usize) -> Vec<f32> {
            (0..n).map(|_| 1.0 + (rng.f32() - 0.5) * 0.2).collect()
        }
        let proj_scale = |n_in: usize| 2.0 / (n_in as f32).sqrt();

        let mut rng = Rng::seed_from_u64(seed);
        let (d, f, v) = (config.d_model, config.ffn_dim, config.vocab);
        let h = config.total_head_dim();
        let embedding = tensor(&mut rng, v * d, 1.0);
        let mut layers = Vec::with_capacity(config.n_layers);
        for _ in 0..config.n_layers {
            let attn_norm = norm_gain(&mut rng, d);
            let attn = match config.attn {
                AttnKind::Mha => AttnWeights::Mha {
                    wq: tensor(&mut rng, d * h, proj_scale(d)),
                    wk: tensor(&mut rng, d * h, proj_scale(d)),
                    wv: tensor(&mut rng, d * h, proj_scale(d)),
                    wo: tensor(&mut rng, h * d, proj_scale(h)),
                },
                AttnKind::Mla => {
                    let l = config.kv_lora_rank;
                    AttnWeights::Mla {
                        wq: tensor(&mut rng, d * config.n_heads * l, proj_scale(d)),
                        wkv: tensor(&mut rng, d * l, proj_scale(d)),
                        w_down: tensor(
                            &mut rng,
                            config.n_heads * l * config.head_dim,
                            proj_scale(l),
                        ),
                        wo: tensor(&mut rng, h * d, proj_scale(h)),
                    }
                }
            };
            let mlp_norm = norm_gain(&mut rng, d);
            layers.push(LayerWeights {
                attn_norm,
                attn,
                mlp_norm,
                w_gate: tensor(&mut rng, d * f, proj_scale(d)),
                w_up: tensor(&mut rng, d * f, proj_scale(d)),
                w_down: tensor(&mut rng, f * d, proj_scale(f)),
            });
        }
        let final_norm = norm_gain(&mut rng, d);
        Self { config: config.clone(), embedding, layers, final_norm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama2_7b_param_count_in_range() {
        let c = ModelConfig::llama2_7b();
        let p = c.param_count();
        assert!((6_000_000_000..7_500_000_000).contains(&p), "{p}");
    }

    #[test]
    fn tiny_llama_is_about_100m() {
        let p = ModelConfig::tiny_llama_100m().param_count();
        assert!((90_000_000..110_000_000).contains(&p), "{p}");
    }

    #[test]
    fn mla_cache_is_compressed() {
        let mha = ModelConfig::llama2_7b();
        let mla = ModelConfig::deepseek_v2_lite();
        // The latent cache must be far smaller per token than MHA's K+V.
        assert!(mla.kv_bytes_per_token_layer(2) < mha.kv_bytes_per_token_layer(2) / 4);
    }

    #[test]
    fn by_name_roundtrip() {
        for n in [
            "llama2-7b",
            "deepseek-v2-lite",
            "tiny-llama-100m",
            "tiny-mla-100m",
            "micro-llama",
            "micro-mla",
        ] {
            assert_eq!(ModelConfig::by_name(n).unwrap().name, n);
        }
        assert!(ModelConfig::by_name("nope").is_none());
    }

    #[test]
    fn micro_models_divide_by_small_cluster_sizes() {
        for c in [ModelConfig::micro_llama(), ModelConfig::micro_mla()] {
            for n in [1usize, 2, 4] {
                assert_eq!(c.head_dim % n, 0, "{}", c.name);
                assert_eq!(c.d_model % n, 0, "{}", c.name);
                assert_eq!(c.max_seq % n, 0, "{}", c.name);
                if c.attn == AttnKind::Mla {
                    assert_eq!(c.kv_lora_rank % n, 0, "{}", c.name);
                }
            }
            assert!(c.param_count() < 1_000_000, "{}: {}", c.name, c.param_count());
        }
    }

    #[test]
    fn materialized_weights_deterministic_and_shaped() {
        let cfg = ModelConfig::micro_llama();
        let a = MaterializedWeights::materialize(&cfg, 7);
        let b = MaterializedWeights::materialize(&cfg, 7);
        let c = MaterializedWeights::materialize(&cfg, 8);
        assert_eq!(a.embedding, b.embedding, "same seed -> identical tensors");
        assert_ne!(a.embedding, c.embedding, "different seed -> different tensors");
        assert_eq!(a.embedding.len(), cfg.vocab * cfg.d_model);
        assert_eq!(a.layers.len(), cfg.n_layers);
        assert_eq!(a.final_norm.len(), cfg.d_model);
        let l0 = &a.layers[0];
        assert_eq!(l0.w_gate.len(), cfg.d_model * cfg.ffn_dim);
        assert_eq!(l0.w_down.len(), cfg.ffn_dim * cfg.d_model);
        match &l0.attn {
            AttnWeights::Mha { wq, wo, .. } => {
                assert_eq!(wq.len(), cfg.d_model * cfg.total_head_dim());
                assert_eq!(wo.len(), cfg.total_head_dim() * cfg.d_model);
            }
            other => panic!("micro-llama must be MHA, got {other:?}"),
        }
        // MLA shapes too
        let mla = MaterializedWeights::materialize(&ModelConfig::micro_mla(), 7);
        match &mla.layers[0].attn {
            AttnWeights::Mla { wq, wkv, w_down, .. } => {
                let (cfg, l) = (&mla.config, mla.config.kv_lora_rank);
                assert_eq!(wq.len(), cfg.d_model * cfg.n_heads * l);
                assert_eq!(wkv.len(), cfg.d_model * l);
                assert_eq!(w_down.len(), cfg.n_heads * l * cfg.head_dim);
            }
            other => panic!("micro-mla must be MLA, got {other:?}"),
        }
    }

    #[test]
    fn head_sweep_scales_d_model() {
        let v = ModelConfig::head_sweep_variant(128);
        assert_eq!(v.n_heads, 128);
        assert_eq!(v.d_model, 128 * 128);
    }
}
