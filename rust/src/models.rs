//! Model architecture descriptions.
//!
//! Two kinds of model are used in this repository, mirroring the paper:
//!
//! * the paper's **evaluation models** (Llama2-7B with standard MHA,
//!   DeepSeek-V2-Lite with MLA) — used by [`crate::clustersim`] for cost
//!   modelling of every table/figure; their weights are never materialised;
//! * the **live demo models** (`tiny-llama-100m`, `tiny-mla-100m`) — ~100 M
//!   parameter architectures whose decode step is AOT-compiled from JAX
//!   (see `python/compile/aot.py`) and actually executed through PJRT by
//!   the serving engine.


/// Attention mechanism family (paper §2.1 / Appendix B.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnKind {
    /// Standard multi-head attention (Llama-style).
    Mha,
    /// DeepSeek multi-head latent attention, weight-absorbed decode form.
    Mla,
}

/// Architectural hyper-parameters of a decoder-only transformer.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub ffn_dim: usize,
    pub max_seq: usize,
    pub attn: AttnKind,
    /// Latent dimension (kv_lora_rank); only meaningful for [`AttnKind::Mla`].
    pub kv_lora_rank: usize,
}

impl ModelConfig {
    /// Total head dimension H = n_heads * head_dim.
    pub fn total_head_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// Parameter count (must agree with `python/compile/model.py`).
    pub fn param_count(&self) -> usize {
        let (d, f, v, l) = (self.d_model, self.ffn_dim, self.vocab, self.n_layers);
        let h = self.total_head_dim();
        let attn = match self.attn {
            AttnKind::Mha => d * h * 3 + h * d,
            AttnKind::Mla => {
                let r = self.kv_lora_rank;
                d * self.n_heads * r + d * r + self.n_heads * r * self.head_dim + h * d
            }
        };
        v * d + l * (attn + 3 * d * f + 2 * d) + d
    }

    /// Bytes of KV cache per token per layer (fp16 on the paper's H100,
    /// element size passed in for generality).
    pub fn kv_bytes_per_token_layer(&self, elem: usize) -> usize {
        match self.attn {
            AttnKind::Mha => 2 * self.total_head_dim() * elem,
            AttnKind::Mla => self.kv_lora_rank * elem,
        }
    }

    /// Llama2-7B — the paper's MHA evaluation model (§4 Models).
    pub fn llama2_7b() -> Self {
        Self {
            name: "llama2-7b".into(),
            vocab: 32000,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            head_dim: 128,
            ffn_dim: 11008,
            max_seq: 16384,
            attn: AttnKind::Mha,
            kv_lora_rank: 0,
        }
    }

    /// DeepSeek-V2-Lite — the paper's MLA evaluation model (§4 Models,
    /// kv_lora_rank = 512 per Appendix B.1).
    pub fn deepseek_v2_lite() -> Self {
        Self {
            name: "deepseek-v2-lite".into(),
            vocab: 102400,
            d_model: 2048,
            n_layers: 27,
            n_heads: 16,
            head_dim: 128,
            ffn_dim: 10944,
            attn: AttnKind::Mla,
            kv_lora_rank: 512,
            max_seq: 16384,
        }
    }

    /// ~100 M-parameter Llama-style model executed live through PJRT by
    /// the end-to-end example (DESIGN.md "End-to-end validation").
    pub fn tiny_llama_100m() -> Self {
        Self {
            name: "tiny-llama-100m".into(),
            vocab: 16384,
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            head_dim: 64,
            ffn_dim: 2048,
            max_seq: 512,
            attn: AttnKind::Mha,
            kv_lora_rank: 0,
        }
    }

    /// MLA twin of [`Self::tiny_llama_100m`].
    pub fn tiny_mla_100m() -> Self {
        Self {
            name: "tiny-mla-100m".into(),
            attn: AttnKind::Mla,
            kv_lora_rank: 128,
            ..Self::tiny_llama_100m()
        }
    }

    /// Fig. 11 head-count sweep variants: same per-head dim, varying head
    /// count (the paper sweeps 32 / 64 / 128 heads).
    pub fn head_sweep_variant(n_heads: usize) -> Self {
        Self {
            name: format!("sweep-{n_heads}h"),
            d_model: n_heads * 128,
            n_heads,
            ..Self::llama2_7b()
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "llama2-7b" => Some(Self::llama2_7b()),
            "deepseek-v2-lite" => Some(Self::deepseek_v2_lite()),
            "tiny-llama-100m" => Some(Self::tiny_llama_100m()),
            "tiny-mla-100m" => Some(Self::tiny_mla_100m()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama2_7b_param_count_in_range() {
        let c = ModelConfig::llama2_7b();
        let p = c.param_count();
        assert!((6_000_000_000..7_500_000_000).contains(&p), "{p}");
    }

    #[test]
    fn tiny_llama_is_about_100m() {
        let p = ModelConfig::tiny_llama_100m().param_count();
        assert!((90_000_000..110_000_000).contains(&p), "{p}");
    }

    #[test]
    fn mla_cache_is_compressed() {
        let mha = ModelConfig::llama2_7b();
        let mla = ModelConfig::deepseek_v2_lite();
        // The latent cache must be far smaller per token than MHA's K+V.
        assert!(mla.kv_bytes_per_token_layer(2) < mha.kv_bytes_per_token_layer(2) / 4);
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["llama2-7b", "deepseek-v2-lite", "tiny-llama-100m", "tiny-mla-100m"] {
            assert_eq!(ModelConfig::by_name(n).unwrap().name, n);
        }
        assert!(ModelConfig::by_name("nope").is_none());
    }

    #[test]
    fn head_sweep_scales_d_model() {
        let v = ModelConfig::head_sweep_variant(128);
        assert_eq!(v.n_heads, 128);
        assert_eq!(v.d_model, 128 * 128);
    }
}
