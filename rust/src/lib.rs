//! # ClusterFusion
//!
//! Reproduction of *"ClusterFusion: Expanding Operator Fusion Scope for LLM
//! Inference via Cluster-Level Collective Primitive"* (CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build time)** — the paper's fused decode dataflows as
//!   Pallas kernels inside a JAX decoder model, AOT-lowered to HLO text
//!   (`python/compile/`, `make artifacts`).
//! * **Layer 3 (this crate)** — a serving coordinator (router, continuous
//!   batcher, paged KV cache, decode engine) that executes the AOT
//!   artifacts through PJRT ([`runtime`]), plus the H100 substitute
//!   substrate ([`clustersim`]) that reproduces every table and figure of
//!   the paper's evaluation (see `DESIGN.md` at the repository root).
//!
//! Python never runs on the request path: after `make artifacts` the
//! `clusterfusion` binary is self-contained. The build itself is fully
//! offline — the only dependency is the vendored `anyhow` subset, and the
//! native PJRT runtime is stubbed by [`runtime::xla`] (DESIGN.md §PJRT).
pub mod clustersim;
pub mod util;
pub mod coordinator;
pub mod loadgen;
pub mod metrics;
pub mod models;
pub mod obs;
pub mod runtime;
pub mod workload;
