//! Trace events and the Chrome trace-event JSON exporter.
//!
//! One [`TraceEvent`] is either a *complete span* (Chrome `"ph":"X"` —
//! a named interval with a start timestamp and a duration) or an
//! *instant* (`"ph":"i"` — a point marker). Perfetto and
//! `chrome://tracing` nest `X` events on the same `(pid, tid)` track by
//! containment, so the exporter never needs begin/end pairs: the engine
//! step span and its synthetic kernel children simply share the step
//! track with nested `[ts, ts+dur]` intervals.
//!
//! All timestamps are **clock microseconds from the injected
//! [`crate::util::clock::Clock`]** — the exporter itself never reads any
//! clock (the §Observability determinism rule), so a virtual-clock
//! replay renders byte-identical JSON on every run and every host.

use crate::util::json::escape;

/// Chrome phase of a trace event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TracePhase {
    /// Complete span (`"ph":"X"`): `[ts_us, ts_us + dur_us]`.
    Span { dur_us: u64 },
    /// Point event (`"ph":"i"`, process scope).
    Instant,
}

/// One timestamped trace record.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub name: String,
    /// Taxonomy category: `"engine"`, `"kernel"`, `"request"`,
    /// `"admission"`, or `"fleet"` (DESIGN.md §Observability).
    pub cat: &'static str,
    pub phase: TracePhase,
    /// Clock µs (virtual µs on the replay path).
    pub ts_us: u64,
    /// Chrome process id — the replica index.
    pub pid: u64,
    /// Chrome thread id — the track within a replica (see the `TRACK_*`
    /// constants in the parent module).
    pub tid: u64,
    /// Ordered key/value annotations (decode slots, finish reason, ...).
    pub args: Vec<(&'static str, String)>,
}

impl TraceEvent {
    /// Span duration (0 for instants).
    pub fn dur_us(&self) -> u64 {
        match self.phase {
            TracePhase::Span { dur_us } => dur_us,
            TracePhase::Instant => 0,
        }
    }

    /// Exclusive end timestamp.
    pub fn end_us(&self) -> u64 {
        self.ts_us + self.dur_us()
    }
}

/// Render `events` as Chrome trace-event JSON (the
/// `{"traceEvents":[...]}` object form; Perfetto-loadable). Field order
/// is fixed and events are rendered in insertion order, so the output
/// is a pure function of the event list — byte-identical across runs
/// whenever the events are.
pub fn chrome_trace(events: &[TraceEvent]) -> String {
    let mut out = String::with_capacity(events.len() * 110 + 64);
    out.push_str("{\"traceEvents\":[");
    for (i, e) in events.iter().enumerate() {
        out.push_str(if i == 0 { "\n" } else { ",\n" });
        out.push_str("{\"name\":\"");
        out.push_str(&escape(&e.name));
        out.push_str("\",\"cat\":\"");
        out.push_str(&escape(e.cat));
        out.push_str("\",");
        match e.phase {
            TracePhase::Span { dur_us } => {
                out.push_str(&format!("\"ph\":\"X\",\"ts\":{},\"dur\":{dur_us}", e.ts_us));
            }
            TracePhase::Instant => {
                out.push_str(&format!("\"ph\":\"i\",\"s\":\"p\",\"ts\":{}", e.ts_us));
            }
        }
        out.push_str(&format!(",\"pid\":{},\"tid\":{}", e.pid, e.tid));
        if !e.args.is_empty() {
            out.push_str(",\"args\":{");
            for (j, (k, v)) in e.args.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push('"');
                out.push_str(&escape(k));
                out.push_str("\":\"");
                out.push_str(&escape(v));
                out.push('"');
            }
            out.push('}');
        }
        out.push('}');
    }
    out.push_str("\n],\"displayTimeUnit\":\"ms\"}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn span(name: &str, ts: u64, dur: u64) -> TraceEvent {
        TraceEvent {
            name: name.to_string(),
            cat: "engine",
            phase: TracePhase::Span { dur_us: dur },
            ts_us: ts,
            pid: 0,
            tid: 0,
            args: vec![("k", "v".to_string())],
        }
    }

    #[test]
    fn chrome_trace_parses_back() {
        let events = vec![
            span("step", 100, 50),
            TraceEvent {
                name: "crash".to_string(),
                cat: "fleet",
                phase: TracePhase::Instant,
                ts_us: 120,
                pid: 1,
                tid: 1,
                args: Vec::new(),
            },
        ];
        let text = chrome_trace(&events);
        let v = Json::parse(&text).unwrap();
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(evs[0].get("dur").unwrap().as_usize(), Some(50));
        assert_eq!(evs[0].get("args").unwrap().get("k").unwrap().as_str(), Some("v"));
        assert_eq!(evs[1].get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(evs[1].get("pid").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn rendering_is_a_pure_function_of_the_events() {
        let events = vec![span("a", 0, 10), span("b", 10, 3)];
        assert_eq!(chrome_trace(&events), chrome_trace(&events.clone()));
    }

    #[test]
    fn escapes_names() {
        let text = chrome_trace(&[span("we\"ird\n", 0, 1)]);
        assert!(Json::parse(&text).is_ok(), "{text}");
    }
}
