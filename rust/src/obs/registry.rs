//! The metrics registry: counters, gauges, fixed-bucket histograms, and
//! the Prometheus text-exposition exporter.
//!
//! One registry consolidates the counters that used to live as ad-hoc
//! struct fields (`Engine::rejected_slo`, `RouterStats.spurious_*`,
//! fleet retry/evacuation counts, ...) behind stable metric names; the
//! existing report structs stay as typed views and are synchronised
//! into the registry at well-defined points (`Fleet::replay` report
//! assembly, `loadgen::replay` return) so the two can be asserted equal
//! (`tests/integration_obs.rs`).
//!
//! Series names follow Prometheus conventions, with labels baked into
//! the series key (`engine_steps_total{replica="0"}`). Every map is a
//! `BTreeMap`, so rendering order — and therefore the exported snapshot
//! — is deterministic.

use std::collections::BTreeMap;

/// Fixed-bucket histogram (Prometheus semantics: cumulative buckets,
/// a `+Inf` overflow bucket, plus sum and count).
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    /// Upper bounds of the finite buckets, ascending.
    bounds: Vec<f64>,
    /// Per-bucket (non-cumulative) counts; `counts[bounds.len()]` is
    /// the `+Inf` bucket.
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Self {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "ascending bounds");
        Self { bounds: bounds.to_vec(), counts: vec![0; bounds.len() + 1], sum: 0.0, count: 0 }
    }

    fn observe(&mut self, v: f64) {
        let idx = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }
}

/// Counters, gauges and histograms under one deterministic namespace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

/// Series name without its label set (`a_total{x="1"}` → `a_total`).
fn base_name(series: &str) -> &str {
    series.split('{').next().unwrap_or(series)
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a counter (created at 0 on first touch).
    pub fn counter_add(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    /// Set a counter to an authoritative value — how the existing report
    /// structs are synchronised into the registry as views.
    pub fn counter_set(&mut self, name: &str, v: u64) {
        self.counters.insert(name.to_string(), v);
    }

    /// Current counter value (0 if the series does not exist).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_set(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    /// Observe `v` into the named histogram, creating it with `bounds`
    /// on first touch (later calls ignore `bounds` — fixed buckets).
    pub fn observe(&mut self, name: &str, bounds: &[f64], v: f64) {
        self.histograms.entry(name.to_string()).or_insert_with(|| Histogram::new(bounds)).observe(v)
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Prometheus text-exposition snapshot. Series render in `BTreeMap`
    /// (lexicographic) order with one `# TYPE` line per base name, so
    /// the output is byte-deterministic for a given registry state.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_type: Option<String> = None;
        let mut type_line = |out: &mut String, base: &str, kind: &str| {
            if last_type.as_deref() != Some(base) {
                out.push_str(&format!("# TYPE {base} {kind}\n"));
                last_type = Some(base.to_string());
            }
        };
        for (name, v) in &self.counters {
            type_line(&mut out, base_name(name), "counter");
            out.push_str(&format!("{name} {v}\n"));
        }
        for (name, v) in &self.gauges {
            type_line(&mut out, base_name(name), "gauge");
            out.push_str(&format!("{name} {v}\n"));
        }
        for (name, h) in &self.histograms {
            type_line(&mut out, base_name(name), "histogram");
            let mut cum = 0u64;
            for (i, b) in h.bounds.iter().enumerate() {
                cum += h.counts[i];
                out.push_str(&format!("{name}_bucket{{le=\"{b}\"}} {cum}\n"));
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
            out.push_str(&format!("{name}_sum {}\n", h.sum));
            out.push_str(&format!("{name}_count {}\n", h.count));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_add_set_and_read() {
        let mut r = MetricsRegistry::new();
        r.counter_add("a_total", 2);
        r.counter_add("a_total", 3);
        assert_eq!(r.counter("a_total"), 5);
        r.counter_set("a_total", 7);
        assert_eq!(r.counter("a_total"), 7);
        assert_eq!(r.counter("missing"), 0);
    }

    #[test]
    fn histogram_buckets_are_cumulative_in_render() {
        let mut r = MetricsRegistry::new();
        for v in [0.5, 1.5, 1.5, 99.0] {
            r.observe("lat_ms", &[1.0, 2.0, 5.0], v);
        }
        let text = r.render_prometheus();
        assert!(text.contains("lat_ms_bucket{le=\"1\"} 1\n"), "{text}");
        assert!(text.contains("lat_ms_bucket{le=\"2\"} 3\n"), "{text}");
        assert!(text.contains("lat_ms_bucket{le=\"5\"} 3\n"), "{text}");
        assert!(text.contains("lat_ms_bucket{le=\"+Inf\"} 4\n"), "{text}");
        assert!(text.contains("lat_ms_count 4\n"), "{text}");
        assert_eq!(r.histogram("lat_ms").unwrap().count(), 4);
    }

    #[test]
    fn render_is_deterministic_and_sorted() {
        let build = |order_flip: bool| {
            let mut r = MetricsRegistry::new();
            let (a, b) = if order_flip { ("b_total", "a_total") } else { ("a_total", "b_total") };
            r.counter_add(a, 1);
            r.counter_add(b, 2);
            r.gauge_set("g", 1.5);
            r.render_prometheus()
        };
        assert_eq!(build(false), build(true), "insertion order must not leak");
        let text = build(false);
        let a = text.find("a_total 1").unwrap();
        let b = text.find("b_total").unwrap();
        assert!(a < b, "lexicographic order:\n{text}");
    }

    #[test]
    fn labelled_series_share_one_type_line() {
        let mut r = MetricsRegistry::new();
        r.counter_set("engine_steps_total{replica=\"0\"}", 3);
        r.counter_set("engine_steps_total{replica=\"1\"}", 4);
        let text = r.render_prometheus();
        assert_eq!(text.matches("# TYPE engine_steps_total counter").count(), 1, "{text}");
        assert!(text.contains("engine_steps_total{replica=\"1\"} 4\n"), "{text}");
    }
}
