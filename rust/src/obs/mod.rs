//! # obs — the deterministic tracing & metrics plane
//!
//! ClusterFusion's whole argument is a *timeline* argument: where the
//! decode microseconds go across kernel launches, on-chip collectives
//! and off-chip traffic. This module turns the deterministic replay
//! stack into a producer of that timeline: one [`Obs`] handle carries
//!
//! * a **trace sink** — timestamped spans and instants
//!   ([`TraceEvent`]) emitted at every layer boundary: request
//!   lifecycle (queue wait, prefill chunks, first token, finish
//!   reason), engine steps annotated with decode-slot count and
//!   prefill rows, admission decisions, fleet events
//!   (crash/stall/detect/evacuate/retry/deadline), and synthetic
//!   **kernel-level child spans** derived from the `FusionScope`
//!   cost-model schedules ([`kernel_stages_for`]) so a step expands
//!   into its per-kernel launch timeline; and
//! * a **[`MetricsRegistry`]** — counters, gauges and fixed-bucket
//!   histograms consolidating the ad-hoc report fields behind named
//!   series, with the existing report structs kept as views that are
//!   synchronised into the registry at replay boundaries.
//!
//! Exporters: [`chrome_trace`] (Perfetto-loadable trace-event JSON)
//! and [`MetricsRegistry::render_prometheus`] (text exposition), wired
//! through `serve --trace-out PATH --metrics-out PATH`.
//!
//! **Determinism rule (DESIGN.md §Observability):** the sink never
//! reads a clock — every timestamp is handed in by the emitter, which
//! on the replay path reads only the injected virtual
//! [`crate::util::clock::Clock`]. Event *order* is the program order of
//! the single-threaded replay loop, which PR 8 made structurally
//! deterministic; exports are therefore byte-identical across runs and
//! host pool widths (`tests/integration_obs.rs`).

mod registry;
mod trace;

pub use registry::{Histogram, MetricsRegistry};
pub use trace::{chrome_trace, TraceEvent, TracePhase};

use std::sync::{Arc, Mutex, MutexGuard};

/// Track (`tid`) 0 within a replica's `pid`: engine step spans and
/// their synthetic kernel child spans.
pub const TRACK_STEPS: u64 = 0;
/// Track 1: fleet/admission lifecycle instants (crash, detect,
/// evacuate, retry, growth deferrals, ...).
pub const TRACK_FLEET: u64 = 1;
/// Per-request lifecycle tracks live at `TRACK_REQUEST_BASE + id` so
/// concurrent requests render as parallel timeline rows.
pub const TRACK_REQUEST_BASE: u64 = 1000;

/// Histogram bucket bounds for request latencies, milliseconds.
pub const LATENCY_MS_BUCKETS: [f64; 10] =
    [1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1000.0];

#[derive(Debug, Default)]
struct Inner {
    events: Vec<TraceEvent>,
    registry: MetricsRegistry,
    /// Synthetic kernel schedule: `(stage name, weight)` per engine
    /// step, from [`kernel_stages_for`]. `None` disables child spans.
    kernel_stages: Option<Vec<(String, u64)>>,
}

/// Shared handle to one observability sink. Cloning is cheap (an `Arc`
/// bump); the engine, fleet loop and replay drivers all append to the
/// same sink. The mutex makes the handle `Send` for the threaded
/// server path; on the virtual-clock replay path there is exactly one
/// thread (DESIGN.md §4), so lock order can never perturb event order.
#[derive(Debug, Clone, Default)]
pub struct Obs {
    inner: Arc<Mutex<Inner>>,
}

impl Obs {
    pub fn new() -> Self {
        Self::default()
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Install the synthetic per-step kernel schedule (see
    /// [`kernel_stages_for`]). Subsequent [`Obs::step_span`] calls emit
    /// one child span per stage, partitioning the step duration
    /// proportionally to the stage weights.
    pub fn set_kernel_stages(&self, stages: Vec<(String, u64)>) {
        self.lock().kernel_stages = if stages.is_empty() { None } else { Some(stages) };
    }

    /// Append a complete span.
    #[allow(clippy::too_many_arguments)]
    pub fn span(
        &self,
        cat: &'static str,
        name: &str,
        ts_us: u64,
        dur_us: u64,
        pid: u64,
        tid: u64,
        args: Vec<(&'static str, String)>,
    ) {
        self.lock().events.push(TraceEvent {
            name: name.to_string(),
            cat,
            phase: TracePhase::Span { dur_us },
            ts_us,
            pid,
            tid,
            args,
        });
    }

    /// Append an instant marker.
    pub fn instant(
        &self,
        cat: &'static str,
        name: &str,
        ts_us: u64,
        pid: u64,
        tid: u64,
        args: Vec<(&'static str, String)>,
    ) {
        self.lock().events.push(TraceEvent {
            name: name.to_string(),
            cat,
            phase: TracePhase::Instant,
            ts_us,
            pid,
            tid,
            args,
        });
    }

    /// Emit one engine step span `[ts_us, ts_us + dur_us]` on replica
    /// `pid`'s step track, annotated with the executed batch shape —
    /// plus, when a kernel schedule is installed, its per-kernel child
    /// spans: the step duration is split proportionally to the stage
    /// weights with integer microsecond arithmetic (the last stage
    /// absorbs the rounding remainder), so children exactly tile the
    /// parent and the partition is deterministic.
    pub fn step_span(
        &self,
        pid: u64,
        ts_us: u64,
        dur_us: u64,
        decode_slots: usize,
        prefill_rows: usize,
    ) {
        let mut g = self.lock();
        g.events.push(TraceEvent {
            name: "step".to_string(),
            cat: "engine",
            phase: TracePhase::Span { dur_us },
            ts_us,
            pid,
            tid: TRACK_STEPS,
            args: vec![
                ("decode_slots", decode_slots.to_string()),
                ("prefill_rows", prefill_rows.to_string()),
            ],
        });
        let Some(stages) = g.kernel_stages.clone() else { return };
        if dur_us == 0 {
            return;
        }
        let total: u128 = stages.iter().map(|(_, w)| *w as u128).sum::<u128>().max(1);
        let mut t = ts_us;
        let mut used = 0u64;
        for (i, (name, w)) in stages.iter().enumerate() {
            let d = if i + 1 == stages.len() {
                dur_us - used
            } else {
                (dur_us as u128 * *w as u128 / total) as u64
            };
            g.events.push(TraceEvent {
                name: name.clone(),
                cat: "kernel",
                phase: TracePhase::Span { dur_us: d },
                ts_us: t,
                pid,
                tid: TRACK_STEPS,
                args: Vec::new(),
            });
            t += d;
            used += d;
        }
    }

    pub fn counter_add(&self, name: &str, v: u64) {
        self.lock().registry.counter_add(name, v);
    }

    pub fn counter_set(&self, name: &str, v: u64) {
        self.lock().registry.counter_set(name, v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.lock().registry.counter(name)
    }

    pub fn gauge_set(&self, name: &str, v: f64) {
        self.lock().registry.gauge_set(name, v);
    }

    /// Observe into a fixed-bucket histogram (created on first touch).
    pub fn observe(&self, name: &str, bounds: &[f64], v: f64) {
        self.lock().registry.observe(name, bounds, v);
    }

    /// Snapshot of the event list (for tests and report printers).
    pub fn events(&self) -> Vec<TraceEvent> {
        self.lock().events.clone()
    }

    /// Snapshot of the registry.
    pub fn registry(&self) -> MetricsRegistry {
        self.lock().registry.clone()
    }

    /// Render the Chrome trace-event JSON export.
    pub fn chrome_trace(&self) -> String {
        chrome_trace(&self.lock().events)
    }

    /// Render the Prometheus text snapshot.
    pub fn prometheus(&self) -> String {
        self.lock().registry.render_prometheus()
    }
}

/// Derive the synthetic per-step kernel schedule for `model` decoding
/// under `scope` at `cluster_size`: the stage list of one layer's
/// [`crate::clustersim::block::cost`] report, with each stage's
/// modelled seconds quantised to an integer weight (nanoseconds,
/// floored at 1 so zero-cost stages still render). [`Obs::step_span`]
/// splits each step's service time across these stages, which is how a
/// replayed step expands into the paper's Fig. 5/12-style per-kernel
/// launch timeline — `BlockIsolated` shows 12 kernels per step,
/// `AttentionFused` 13 stages over 9 launches, `FullBlockFused` the
/// single fused launch's 5 internal phases (EXPERIMENTS.md §Trace).
pub fn kernel_stages_for(
    model: &crate::models::ModelConfig,
    seq: usize,
    scope: crate::clustersim::block::FusionScope,
    cluster_size: usize,
) -> Vec<(String, u64)> {
    use crate::clustersim::block::{cost, BlockProblem};
    use crate::clustersim::dataflow::CostEnv;
    use crate::clustersim::{Hardware, Noc};
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let p = BlockProblem::from_model(model, 1, seq.clamp(1, model.max_seq));
    let env = CostEnv::clusterfusion(&hw, &noc, cluster_size);
    cost(&p, scope, &env)
        .stages
        .iter()
        .map(|(name, secs)| (name.clone(), ((secs * 1e9).round() as u64).max(1)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustersim::block::FusionScope;
    use crate::models::ModelConfig;

    #[test]
    fn step_span_children_tile_the_parent_exactly() {
        let obs = Obs::new();
        obs.set_kernel_stages(vec![
            ("a".to_string(), 3),
            ("b".to_string(), 3),
            ("c".to_string(), 1),
        ]);
        obs.step_span(0, 1000, 100, 2, 4);
        let evs = obs.events();
        assert_eq!(evs.len(), 4, "step + 3 children");
        let step = &evs[0];
        assert_eq!((step.ts_us, step.end_us()), (1000, 1100));
        let kids = &evs[1..];
        assert_eq!(kids[0].ts_us, step.ts_us, "first child starts with the parent");
        assert_eq!(kids.last().unwrap().end_us(), step.end_us(), "children tile to the end");
        for w in kids.windows(2) {
            assert_eq!(w[0].end_us(), w[1].ts_us, "children are contiguous");
        }
        let total: u64 = kids.iter().map(TraceEvent::dur_us).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn step_span_without_schedule_has_no_children() {
        let obs = Obs::new();
        obs.step_span(0, 0, 50, 1, 0);
        assert_eq!(obs.events().len(), 1);
    }

    #[test]
    fn kernel_stage_counts_match_the_scope_schedules() {
        let m = ModelConfig::micro_llama();
        let n = |s| kernel_stages_for(&m, 64, s, 2).len();
        // 4 attention kernels + 8 rest ops / 5 fused-attention stages +
        // 8 rest ops / 5 single-launch phases — the §Trace table.
        assert_eq!(n(FusionScope::BlockIsolated), 12);
        assert_eq!(n(FusionScope::AttentionFused), 13);
        assert_eq!(n(FusionScope::FullBlockFused), 5);
    }

    #[test]
    fn kernel_stages_are_deterministic() {
        let m = ModelConfig::micro_llama();
        let a = kernel_stages_for(&m, 64, FusionScope::FullBlockFused, 2);
        let b = kernel_stages_for(&m, 64, FusionScope::FullBlockFused, 2);
        assert_eq!(a, b);
        assert!(a.iter().all(|(_, w)| *w >= 1));
    }

    #[test]
    fn obs_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<Obs>();
    }
}
