//! Hardware description: an NVIDIA H100 SXM5 80 GB as the paper's testbed.
//!
//! All constants are calibration inputs to the analytical model. Where the
//! paper states a number we use it verbatim (HBM bandwidth 2.96 TB/s,
//! global-memory latency > 470 cycles, DSMEM latency 190 cycles at cluster
//! size 2, NoC bandwidth 2.90 TB/s at cluster size 16 — §2.3 / Fig. 5);
//! the rest come from public H100 specifications.


/// Static machine parameters of the simulated GPU.
#[derive(Debug, Clone)]
pub struct Hardware {
    /// Streaming multiprocessors on the device (H100 SXM5: 132).
    pub sm_count: usize,
    /// SM clock in GHz (boost).
    pub clock_ghz: f64,
    /// Achieved HBM3 bandwidth, bytes/s (paper §2.3: 2.96 TB/s).
    pub hbm_bw: f64,
    /// Global-memory access latency, cycles (paper §2.3: "exceeding 470").
    pub gmem_latency_cycles: f64,
    /// Peak dense FP16 tensor-core throughput, FLOP/s (H100 SXM: 989e12).
    pub fp16_flops: f64,
    /// Fraction of peak actually achieved by decode GEMV/GEMM kernels
    /// (decode is memory-bound; this only caps tiny compute terms).
    pub mfu: f64,
    /// Cost of launching one kernel from a CUDA graph, seconds. Baselines
    /// in the paper all enable CUDA Graph; this is the residual per-kernel
    /// dispatch + dependency cost inside a graph replay.
    pub graph_kernel_launch: f64,
    /// Cost of one non-graph kernel launch (driver dispatch), seconds.
    pub raw_kernel_launch: f64,
    /// Device-wide barrier / kernel-boundary synchronisation cost, seconds
    /// (tail effect + write-visibility flush between dependent kernels).
    pub kernel_boundary_sync: f64,
    /// Shared-memory (intra-SM) bandwidth per SM, bytes/s.
    pub smem_bw_per_sm: f64,
    /// DSMEM capacity per SM, bytes (Hopper: 228 KB usable shared memory).
    pub smem_bytes_per_sm: usize,
}

impl Hardware {
    /// The paper's testbed: H100 SXM5 80 GB (§4 Experimental Setup).
    pub fn h100_sxm5() -> Self {
        Self {
            sm_count: 132,
            clock_ghz: 1.755,
            hbm_bw: 2.96e12,
            gmem_latency_cycles: 470.0,
            fp16_flops: 989e12,
            mfu: 0.55,
            graph_kernel_launch: 1.1e-6,
            raw_kernel_launch: 3.5e-6,
            kernel_boundary_sync: 1.4e-6,
            smem_bw_per_sm: 128.0 * 1.755e9 * 8.0, // 128 banks * 8 B/cycle-ish
            smem_bytes_per_sm: 228 * 1024,
        }
    }

    /// Seconds for one global-memory round-trip latency.
    pub fn gmem_latency(&self) -> f64 {
        self.gmem_latency_cycles / (self.clock_ghz * 1e9)
    }

    /// Seconds to move `bytes` through HBM at achieved bandwidth.
    pub fn hbm_time(&self, bytes: f64) -> f64 {
        bytes / self.hbm_bw
    }

    /// Seconds to execute `flops` at achieved tensor throughput.
    pub fn compute_time(&self, flops: f64) -> f64 {
        flops / (self.fp16_flops * self.mfu)
    }
}

impl Default for Hardware {
    fn default() -> Self {
        Self::h100_sxm5()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gmem_latency_matches_paper_cycles() {
        let hw = Hardware::h100_sxm5();
        let lat = hw.gmem_latency();
        // 470 cycles at 1.755 GHz ≈ 268 ns
        assert!((lat - 268e-9).abs() < 10e-9, "{lat}");
    }

    #[test]
    fn memory_bound_decode_sanity() {
        // Llama2-7B decode reads ~13.5 GB of weights per token; at 2.96 TB/s
        // the floor is ~4.5 ms — the order of magnitude of published TPOT.
        let hw = Hardware::h100_sxm5();
        let t = hw.hbm_time(13.5e9);
        assert!(t > 3e-3 && t < 6e-3, "{t}");
    }

    #[test]
    fn graph_launch_cheaper_than_raw() {
        let hw = Hardware::h100_sxm5();
        assert!(hw.graph_kernel_launch < hw.raw_kernel_launch);
    }
}
