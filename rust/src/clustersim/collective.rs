//! Cluster-level collective primitives — paper §3.1, Algorithms 1 and 2.
//!
//! `ClusterReduce` and `ClusterGather` are the paper's core contribution:
//! structured collectives over DSMEM that let thread blocks in a cluster
//! exchange/reduce intermediate results without touching global memory.
//!
//! Both use a binary-exchange schedule over log2(N) rounds: in round r
//! (stride = 2^r) block `b` sends to `(b + stride) mod N` and receives
//! from `(b - stride + N) mod N`. Reduce keeps the message size constant
//! and folds with ⊕; Gather doubles the message each round.
//!
//! This module executes the schedule *functionally* (real data movement
//! between per-block buffers — the simulator's DSMEM) and *charges* it
//! through the NoC cost model, so numerics and timing come from the same
//! schedule. The off-chip fallback (used by the Fig. 13 ablation and the
//! Table 1 comparison) runs the identical schedule through global memory.


use super::hw::Hardware;
use super::noc::Noc;

/// Achieved fraction of HBM bandwidth for global-memory collective
/// staging passes (small strided writes + fences between dependent
/// rounds). Calibrated so the off-chip ClusterReduce of Table 1 grows
/// with message size at the paper's rate.
pub const GMEM_STAGING_EFF: f64 = 0.10;

/// Reduction operator ⊕ (paper: "associative operators such as sum or max").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Max,
}

impl ReduceOp {
    #[inline]
    pub fn apply(self, a: f32, b: f32) -> f32 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Max => a.max(b),
        }
    }

    pub fn identity(self) -> f32 {
        match self {
            ReduceOp::Sum => 0.0,
            ReduceOp::Max => f32::NEG_INFINITY,
        }
    }
}

/// Where the exchanged messages travel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transport {
    /// DSMEM over the SM-to-SM NoC (the paper's primitives).
    Dsmem,
    /// Global-memory staging (the paper's "off-chip" baseline in Table 1
    /// and the Fig. 13 "w/o DSMEM" ablation).
    GlobalMemory,
}

/// Cost account of one collective invocation.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CollectiveCost {
    /// Wall-clock seconds for the whole cluster to finish.
    pub latency: f64,
    /// Total bytes moved over the transport, summed across blocks
    /// (comparable to the paper's analytical DSMEM-traffic model, §3.2).
    pub traffic_bytes: f64,
    /// Number of exchange rounds (= log2 N).
    pub rounds: usize,
}

fn assert_cluster_size(n: usize) {
    assert!(
        n.is_power_of_two() && (1..=16).contains(&n),
        "cluster size must be a power of two in 1..=16 (Hopper limit), got {n}"
    );
}

/// Cost of one exchange round: every block sends `bytes` concurrently.
///
/// Per round the cluster pays one transport latency (the peer write plus
/// the arrival barrier of Alg. 1 line 8) and the serialisation time of the
/// N concurrent messages through the shared crossbar / memory system.
fn round_cost(bytes_per_block: f64, n: usize, transport: Transport, hw: &Hardware, noc: &Noc) -> f64 {
    let total = bytes_per_block * n as f64;
    match transport {
        Transport::Dsmem => noc.latency(n) + total / noc.bandwidth(n),
        Transport::GlobalMemory => {
            // Staged through L2/HBM: a store pass and a load pass, each a
            // full memory round-trip, plus a device-visibility fence that
            // costs far more than a cluster-scoped barrier. The achieved
            // bandwidth of these small strided staging passes is a fraction
            // of peak (uncoalesced partial lines + fence-serialised
            // round-trips) — this is what makes the off-chip Reduce of
            // Table 1 degrade with message size while the on-chip one
            // barely moves.
            2.0 * hw.gmem_latency() + 2.0 * total / (GMEM_STAGING_EFF * hw.hbm_bw)
                + hw.kernel_boundary_sync
        }
    }
}

/// ClusterReduce (paper Alg. 1), functional + costed.
///
/// `blocks` holds each thread block's shared-memory buffer `D_b`; on return
/// every `D_b` contains the element-wise ⊕-reduction of all inputs (every
/// block ends with the full result, as in the paper where each block needs
/// the complete softmax statistics / attention output).
pub fn cluster_reduce(
    blocks: &mut [Vec<f32>],
    op: ReduceOp,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> CollectiveCost {
    let n = blocks.len();
    assert_cluster_size(n);
    let size = blocks[0].len();
    assert!(blocks.iter().all(|b| b.len() == size), "ragged block buffers");

    let elem_bytes = std::mem::size_of::<f32>() as f64;
    let mut cost = CollectiveCost::default();
    let mut stride = 1;
    // Receive staging buffers B_b (Alg. 1 line 1).
    let mut recv = vec![vec![0f32; size]; n];
    while stride < n {
        // Send D_b -> B_{(b+stride) mod N} (lines 4-7); all transfers in a
        // round are concurrent, so data movement is taken from a snapshot.
        for b in 0..n {
            let to = (b + stride) % n;
            recv[to].copy_from_slice(&blocks[b]);
        }
        // D_b <- D_b ⊕ B_b (line 9).
        for b in 0..n {
            for (d, r) in blocks[b].iter_mut().zip(&recv[b]) {
                *d = op.apply(*d, *r);
            }
        }
        cost.latency += round_cost(size as f64 * elem_bytes, n, transport, hw, noc);
        cost.traffic_bytes += size as f64 * elem_bytes * n as f64;
        cost.rounds += 1;
        stride *= 2;
    }
    cost
}

/// ClusterGather (paper Alg. 2), functional + costed.
///
/// Input: each block's local segment (`blocks[b]`, equal sizes). Output:
/// per-block gathered buffers of N * size laid out in the paper's rotated
/// order — `out[b][j*size..][..size]` holds block `(b - j + N) mod N`'s
/// segment (j = 0 is the block's own data). Use [`gathered_segment`] to
/// read it back in rank order.
pub fn cluster_gather(
    blocks: &[Vec<f32>],
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> (Vec<Vec<f32>>, CollectiveCost) {
    let n = blocks.len();
    assert_cluster_size(n);
    let size = blocks[0].len();
    assert!(blocks.iter().all(|b| b.len() == size), "ragged block buffers");

    let elem_bytes = std::mem::size_of::<f32>() as f64;
    // D_b of size N*size, first segment = local data (Alg. 2 requirement).
    let mut bufs: Vec<Vec<f32>> = blocks
        .iter()
        .map(|b| {
            let mut d = vec![0f32; n * size];
            d[..size].copy_from_slice(b);
            d
        })
        .collect();

    let mut cost = CollectiveCost::default();
    let mut stride = 1;
    while stride < n {
        let seg = size * stride;
        // Send D_b[0 : size*stride] -> D_{send_to}[stride*size : 2*stride*size]
        // (lines 5-7); snapshot for intra-round concurrency.
        let snapshot: Vec<Vec<f32>> = bufs.iter().map(|d| d[..seg].to_vec()).collect();
        for b in 0..n {
            let to = (b + stride) % n;
            bufs[to][seg..2 * seg].copy_from_slice(&snapshot[b]);
        }
        stride *= 2;
    }
    // Charge through the same cost query the analytical model uses, so the
    // functional and analytical paths cannot drift (tested below).
    let q = gather_cost(size as f64 * elem_bytes, n, transport, hw, noc);
    cost.latency = q.latency;
    cost.traffic_bytes = q.traffic_bytes;
    cost.rounds = q.rounds;
    (bufs, cost)
}

/// Read block `rank`'s segment out of a gathered buffer owned by `owner`
/// (undoes the rotated layout of [`cluster_gather`]).
pub fn gathered_segment<'a>(
    gathered: &'a [f32],
    owner: usize,
    rank: usize,
    n: usize,
    size: usize,
) -> &'a [f32] {
    let j = (owner + n - rank) % n;
    &gathered[j * size..(j + 1) * size]
}

/// Pure cost query (no data movement) for a ClusterReduce of `bytes` per
/// block — used by the dataflow cost models where the numerics are carried
/// by the functional path separately.
pub fn reduce_cost(
    bytes: f64,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> CollectiveCost {
    assert_cluster_size(n);
    let rounds = n.trailing_zeros() as usize;
    let mut cost = CollectiveCost { rounds, ..Default::default() };
    for _ in 0..rounds {
        cost.latency += round_cost(bytes, n, transport, hw, noc);
        cost.traffic_bytes += bytes * n as f64;
    }
    cost
}

/// Pure cost query for a ClusterGather whose per-block segment is `bytes`.
///
/// Off-chip gather needs no exchange rounds at all: every block stores its
/// segment once and loads the other N-1 (the natural global-memory
/// all-gather) — which is why the paper's Table 1 off-chip Gather latency
/// is flat in data size while off-chip Reduce grows.
pub fn gather_cost(
    bytes: f64,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> CollectiveCost {
    assert_cluster_size(n);
    let rounds = n.trailing_zeros() as usize;
    let mut cost = CollectiveCost { rounds, ..Default::default() };
    match transport {
        Transport::Dsmem => {
            let mut seg = bytes;
            for _ in 0..rounds {
                cost.latency += round_cost(seg, n, transport, hw, noc);
                cost.traffic_bytes += seg * n as f64;
                seg *= 2.0;
            }
        }
        Transport::GlobalMemory => {
            if n > 1 {
                let total = bytes * n as f64; // store pass
                let reads = bytes * (n as f64 - 1.0) * n as f64; // load pass
                cost.latency += 2.0 * hw.gmem_latency()
                    + (total + reads) / hw.hbm_bw
                    + hw.kernel_boundary_sync;
                cost.traffic_bytes += total + reads;
                cost.rounds = 1;
            }
        }
    }
    cost
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> (Hardware, Noc) {
        let hw = Hardware::h100_sxm5();
        let noc = Noc::h100(&hw);
        (hw, noc)
    }

    #[test]
    fn reduce_sum_all_blocks_converge() {
        let (hw, noc) = env();
        let n = 8;
        let size = 16;
        let mut blocks: Vec<Vec<f32>> =
            (0..n).map(|b| (0..size).map(|i| (b * size + i) as f32).collect()).collect();
        let expect: Vec<f32> = (0..size)
            .map(|i| (0..n).map(|b| (b * size + i) as f32).sum())
            .collect();
        let cost = cluster_reduce(&mut blocks, ReduceOp::Sum, Transport::Dsmem, &hw, &noc);
        for b in &blocks {
            assert_eq!(b, &expect);
        }
        assert_eq!(cost.rounds, 3);
    }

    #[test]
    fn reduce_max() {
        let (hw, noc) = env();
        let mut blocks = vec![vec![1.0, -5.0], vec![0.5, 7.0], vec![3.0, 0.0], vec![-1.0, 2.0]];
        cluster_reduce(&mut blocks, ReduceOp::Max, Transport::Dsmem, &hw, &noc);
        for b in &blocks {
            assert_eq!(b, &vec![3.0, 7.0]);
        }
    }

    #[test]
    fn gather_layout_rotated_and_complete() {
        let (hw, noc) = env();
        let n = 4;
        let size = 3;
        let blocks: Vec<Vec<f32>> =
            (0..n).map(|b| vec![b as f32; size]).collect();
        let (out, cost) = cluster_gather(&blocks, Transport::Dsmem, &hw, &noc);
        for owner in 0..n {
            for rank in 0..n {
                let seg = gathered_segment(&out[owner], owner, rank, n, size);
                assert_eq!(seg, &vec![rank as f32; size][..], "owner {owner} rank {rank}");
            }
        }
        assert_eq!(cost.rounds, 2);
    }

    #[test]
    fn traffic_matches_paper_formulas() {
        // Traffic_Reduce(size, N) = size * log2(N) * N
        // Traffic_Gather(size, N) = size * (N - 1) * N   (closed form of the
        // paper's 2^(log2(N/2)+1) - 1 = N - 1 doubling series)
        let (hw, noc) = env();
        for n in [2usize, 4, 8, 16] {
            let size = 64usize; // floats
            let bytes = (size * 4) as f64;
            let mut blocks = vec![vec![1.0f32; size]; n];
            let rc = cluster_reduce(&mut blocks, ReduceOp::Sum, Transport::Dsmem, &hw, &noc);
            assert_eq!(rc.traffic_bytes, bytes * (n.trailing_zeros() as f64) * n as f64);
            let blocks = vec![vec![1.0f32; size]; n];
            let (_, gc) = cluster_gather(&blocks, Transport::Dsmem, &hw, &noc);
            assert_eq!(gc.traffic_bytes, bytes * (n as f64 - 1.0) * n as f64);
        }
    }

    #[test]
    fn cost_queries_match_functional_costs() {
        let (hw, noc) = env();
        let n = 8;
        let size = 128usize;
        let mut blocks = vec![vec![0.5f32; size]; n];
        let f = cluster_reduce(&mut blocks, ReduceOp::Sum, Transport::Dsmem, &hw, &noc);
        let q = reduce_cost((size * 4) as f64, n, Transport::Dsmem, &hw, &noc);
        assert!((f.latency - q.latency).abs() < 1e-12);
        assert_eq!(f.traffic_bytes, q.traffic_bytes);

        let blocks = vec![vec![0.5f32; size]; n];
        let (_, f) = cluster_gather(&blocks, Transport::Dsmem, &hw, &noc);
        let q = gather_cost((size * 4) as f64, n, Transport::Dsmem, &hw, &noc);
        assert!((f.latency - q.latency).abs() < 1e-12);
        assert_eq!(f.traffic_bytes, q.traffic_bytes);
    }

    #[test]
    fn onchip_beats_offchip_and_gap_grows_with_size_for_reduce() {
        // Shape of paper Table 1.
        let (hw, noc) = env();
        let n = 4;
        let mut prev_speedup = 0.0;
        for kb in [32.0, 64.0, 128.0, 256.0] {
            let bytes = kb * 1024.0;
            let on = reduce_cost(bytes, n, Transport::Dsmem, &hw, &noc).latency;
            let off = reduce_cost(bytes, n, Transport::GlobalMemory, &hw, &noc).latency;
            let speedup = off / on;
            assert!(speedup > 1.0, "on-chip must win ({kb} KB: {speedup:.2})");
            assert!(speedup >= prev_speedup, "reduce speedup grows with size");
            prev_speedup = speedup;
        }
    }

    #[test]
    fn single_block_cluster_is_free() {
        let (hw, noc) = env();
        let mut blocks = vec![vec![3.0f32; 8]];
        let c = cluster_reduce(&mut blocks, ReduceOp::Sum, Transport::Dsmem, &hw, &noc);
        assert_eq!(c.rounds, 0);
        assert_eq!(c.latency, 0.0);
        assert_eq!(blocks[0], vec![3.0f32; 8]);
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_rejected() {
        let (hw, noc) = env();
        let mut blocks = vec![vec![0.0f32; 4]; 3];
        cluster_reduce(&mut blocks, ReduceOp::Sum, Transport::Dsmem, &hw, &noc);
    }
}
