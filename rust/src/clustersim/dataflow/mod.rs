//! Decoding dataflow variants — paper §3.2 and Appendix B.
//!
//! Each dataflow is implemented twice, deliberately sharing one schedule:
//!
//! * **functionally** — `execute(...)` runs the real numerics over
//!   simulated per-thread-block buffers, moving data *only* through the
//!   collective primitives (the simulator's DSMEM) or explicit
//!   global-memory staging vectors, so that data-dependency resolution is
//!   exactly the paper's. All variants must agree with
//!   [`reference::attention_block_ref`] to fp32 tolerance.
//! * **as a cost model** — `cost(...)` charges the same schedule against
//!   the hardware model and returns a [`CostReport`] (latency, HBM/DSMEM
//!   traffic, kernel launches, per-stage breakdown) used by every paper
//!   figure.
//!
//! Variants:
//! * [`block_isolated`] — the baseline (SGLang/vLLM-style FlashDecoding
//!   pipeline, Fig. 3): separate kernels, intermediates through HBM.
//! * [`split_token`]   — the paper's ClusterFusion dataflow (Alg. 3):
//!   clusters partition the KV sequence; QKV+Attention+OutProj fused.
//! * [`split_head`]    — Appendix B.2 variant (Alg. 5): clusters partition
//!   the head dimension everywhere; register-resident intermediates but
//!   DSMEM traffic ∝ sequence length.
//! * [`mla`]           — Appendix B.1 fused DeepSeek MLA dataflow (Alg. 4).

pub mod block_isolated;
pub mod mla;
pub mod reference;
pub mod split_head;
pub mod split_token;


use super::collective::Transport;
use super::hw::Hardware;
use super::noc::Noc;
use crate::util::linalg::PackedWeight;

/// One layer's MHA attention weights packed for column access
/// (`util::linalg::PackedWeight`), built **once per weight set** and
/// reused across every `execute_packed` call of a sweep — the §Perf
/// packed-weight lifetime. `execute()` wrappers pack internally (one-shot
/// convenience); dense sweeps and the hot-path bench hold one of these.
#[derive(Debug, Clone)]
pub struct PackedMhaWeights {
    /// (D, H) projections, packed.
    pub wq: PackedWeight,
    pub wk: PackedWeight,
    pub wv: PackedWeight,
    /// (H, D) output projection, packed.
    pub wo: PackedWeight,
}

impl PackedMhaWeights {
    pub fn pack(wq: &[f32], wk: &[f32], wv: &[f32], wo: &[f32], d: usize, h: usize) -> Self {
        Self {
            wq: PackedWeight::pack(wq, d, h),
            wk: PackedWeight::pack(wk, d, h),
            wv: PackedWeight::pack(wv, d, h),
            wo: PackedWeight::pack(wo, h, d),
        }
    }
}

/// MLA analogue of [`PackedMhaWeights`]: `wq` (D, nh·l), `wkv` (D, l) and
/// `wo` (nh·dh, D) packed; `w_down` stays row-major (its accesses are
/// already row-contiguous).
#[derive(Debug, Clone)]
pub struct PackedMlaWeights {
    pub wq: PackedWeight,
    pub wkv: PackedWeight,
    pub wo: PackedWeight,
}

impl PackedMlaWeights {
    pub fn pack(
        wq: &[f32],
        wkv: &[f32],
        wo: &[f32],
        d: usize,
        nh: usize,
        l: usize,
        dh: usize,
    ) -> Self {
        Self {
            wq: PackedWeight::pack(wq, d, nh * l),
            wkv: PackedWeight::pack(wkv, d, l),
            wo: PackedWeight::pack(wo, nh * dh, d),
        }
    }
}

/// Element size in bytes on the simulated device (paper: FP16 end-to-end).
pub const ELEM: f64 = 2.0;

/// Per-SM sustained load bandwidth, bytes/s. 132 SMs × 25 GB/s ≈ 3.3 TB/s
/// > HBM 2.96 TB/s, so full occupancy is HBM-bound while low occupancy is
/// SM-limited — the effect behind Fig. 11's occupancy cliff.
pub const PER_SM_BW: f64 = 25.0e9;

/// Fixed per-phase setup cost inside a fused kernel (projection /
/// attention / output-projection prologue: barrier arrival, descriptor
/// setup). With a cluster the phases pipeline across blocks (saturating at
/// two in-flight phases), so the cost is divided by min(N, 2); a
/// single-block "cluster" serialises all phases. This calibrated constant is what makes cluster size 2 edge out
/// size 1 at 128 heads (Fig. 11) — see DESIGN.md §2.
pub const PHASE_SETUP: f64 = 2.0e-6;

/// Per-block cost of a device-wide software barrier through global
/// memory (atomics + polling), seconds. Without DSMEM a fused kernel's
/// collectives must synchronise clusters via grid-wide gmem barriers whose
/// cost scales with the number of participating blocks — the dominant
/// term in the Fig. 13 ablation (the paper's "up to 33%" TPOT increase).
/// In the Table 1 microbenchmark only one 4-block cluster participates,
/// so the same constant contributes well under a microsecond there.
pub const GMEM_BARRIER_PER_BLOCK: f64 = 5.0e-8;

/// One attention-block decode problem (a single layer's QKV Projection +
/// Attention + Output Projection — the paper's "core modules").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttnProblem {
    pub batch: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    /// Valid tokens already in the KV cache.
    pub seq: usize,
    /// Latent rank for MLA (0 for MHA).
    pub kv_lora_rank: usize,
}

impl AttnProblem {
    pub fn total_head_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// HBM bytes that *must* move for one MHA decode step of this layer,
    /// regardless of dataflow: weights + KV cache + activations i/o.
    pub fn mandatory_bytes_mha(&self) -> f64 {
        let (b, d, h) = (self.batch as f64, self.d_model as f64, self.total_head_dim() as f64);
        let s = self.seq as f64;
        let weights = (d * 3.0 * h + h * d) * ELEM;
        let kv = b * s * 2.0 * h * ELEM;
        let io = 2.0 * b * d * ELEM + b * 2.0 * h * ELEM; // hidden in/out + new K,V append
        weights + kv + io
    }

    /// Same for the weight-absorbed MLA decode (latent cache, MQA-style).
    pub fn mandatory_bytes_mla(&self) -> f64 {
        let (b, d) = (self.batch as f64, self.d_model as f64);
        let (nh, dh, l) = (self.n_heads as f64, self.head_dim as f64, self.kv_lora_rank as f64);
        let s = self.seq as f64;
        let weights = (d * nh * l + d * l + nh * l * dh + nh * dh * d) * ELEM;
        let kv = b * s * l * ELEM;
        let io = 2.0 * b * d * ELEM + b * l * ELEM;
        weights + kv + io
    }

    /// FLOPs of the attention block (projections + attention), MHA.
    pub fn flops_mha(&self) -> f64 {
        let (b, d, h) = (self.batch as f64, self.d_model as f64, self.total_head_dim() as f64);
        let s = self.seq as f64 + 1.0;
        2.0 * b * d * 3.0 * h + 4.0 * b * h * s + 2.0 * b * h * d
    }

    pub fn flops_mla(&self) -> f64 {
        let (b, d) = (self.batch as f64, self.d_model as f64);
        let (nh, dh, l) = (self.n_heads as f64, self.head_dim as f64, self.kv_lora_rank as f64);
        let s = self.seq as f64 + 1.0;
        2.0 * b * d * (nh * l + l) + 4.0 * b * nh * l * s + 2.0 * b * nh * l * dh
            + 2.0 * b * nh * dh * d
    }
}

/// Cost account of one dataflow evaluation (one layer's core modules).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CostReport {
    /// Wall-clock seconds.
    pub latency: f64,
    /// Bytes moved through HBM (weights + cache + any intermediates).
    pub hbm_bytes: f64,
    /// Bytes moved over the SM-to-SM NoC (DSMEM).
    pub dsmem_bytes: f64,
    /// Kernel launches issued.
    pub launches: usize,
    /// Arithmetic work of the modelled computation, FLOPs. Filled by the
    /// block-scope cost models (`clustersim::block`), where the invariant
    /// "fusion changes traffic and launches, never arithmetic" is a
    /// tested property; the attention-only dataflow costs leave it 0.
    pub flops: f64,
    /// (stage name, seconds) breakdown.
    pub stages: Vec<(String, f64)>,
}

impl CostReport {
    pub fn stage(&mut self, name: &str, seconds: f64) {
        self.stages.push((name.to_string(), seconds));
        self.latency += seconds;
    }
}

/// Memory-side time for a wave of `blocks` thread blocks collectively
/// reading `total_bytes` from HBM when the device schedules at most
/// `active_sms` of its `sm_count` SMs (Fig. 5 right):
/// `max(HBM-bound, SM-issue-bound with wave quantisation)`.
pub fn occupancy_mem_time(total_bytes: f64, blocks: usize, active_sms: usize, hw: &Hardware) -> f64 {
    let hbm_bound = total_bytes / hw.hbm_bw;
    let waves = blocks.div_ceil(active_sms).max(1) as f64;
    let per_block = total_bytes / blocks as f64 / PER_SM_BW;
    hbm_bound.max(waves * per_block)
}

/// Execution knobs shared by the costed dataflows.
#[derive(Debug, Clone, Copy)]
pub struct CostEnv<'a> {
    pub hw: &'a Hardware,
    pub noc: &'a Noc,
    /// Cluster size N (power of two ≤ 16).
    pub cluster_size: usize,
    /// DSMEM (the paper's system) or GlobalMemory (the Fig. 13 ablation).
    pub transport: Transport,
    /// Achieved-bandwidth derate of the fused kernel (ClusterFusion is
    /// hand-tuned; baselines override per framework in `frameworks.rs`).
    pub bw_efficiency: f64,
}

impl<'a> CostEnv<'a> {
    pub fn clusterfusion(hw: &'a Hardware, noc: &'a Noc, cluster_size: usize) -> Self {
        Self { hw, noc, cluster_size, transport: Transport::Dsmem, bw_efficiency: 0.85 }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    //! Shared tensors for the functional differential tests.
    use crate::util::rng::Rng;

    pub struct MhaCase {
        pub batch: usize,
        pub d_model: usize,
        pub n_heads: usize,
        pub head_dim: usize,
        pub seq: usize,
        pub hidden: Vec<f32>,
        pub wq: Vec<f32>, // (D, nh*dh) row-major
        pub wk: Vec<f32>,
        pub wv: Vec<f32>,
        pub wo: Vec<f32>,      // (nh*dh, D)
        pub k_cache: Vec<f32>, // (B, S, nh, dh)
        pub v_cache: Vec<f32>,
        pub pos: Vec<usize>,
    }

    pub fn mha_case(seed: u64, b: usize, nh: usize, dh: usize, s: usize, d: usize) -> MhaCase {
        let mut rng = Rng::seed_from_u64(seed);
        let h = nh * dh;
        let mut v = |n: usize, scale: f32| -> Vec<f32> {
            (0..n).map(|_| (rng.f32() - 0.5) * scale).collect()
        };
        let hidden = v(b * d, 2.0);
        let wq = v(d * h, 0.4);
        let wk = v(d * h, 0.4);
        let wv = v(d * h, 0.4);
        let wo = v(h * d, 0.4);
        let k_cache = v(b * s * h, 2.0);
        let v_cache = v(b * s * h, 2.0);
        let mut rng2 = Rng::seed_from_u64(seed ^ 0xdead);
        let pos = (0..b).map(|_| rng2.range(0, s)).collect();
        MhaCase { batch: b, d_model: d, n_heads: nh, head_dim: dh, seq: s, hidden, wq, wk, wv, wo, k_cache, v_cache, pos }
    }

    pub struct MlaCase {
        pub batch: usize,
        pub d_model: usize,
        pub n_heads: usize,
        pub head_dim: usize,
        pub lora: usize,
        pub seq: usize,
        pub hidden: Vec<f32>,
        pub wq: Vec<f32>,     // (D, nh*l)
        pub wkv: Vec<f32>,    // (D, l)
        pub w_down: Vec<f32>, // (nh, l, dh)
        pub wo: Vec<f32>,     // (nh*dh, D)
        pub kv_cache: Vec<f32>, // (B, S, l)
        pub pos: Vec<usize>,
    }

    pub fn mla_case(seed: u64, b: usize, nh: usize, l: usize, dh: usize, s: usize, d: usize) -> MlaCase {
        let mut rng = Rng::seed_from_u64(seed);
        let mut v = |n: usize, scale: f32| -> Vec<f32> {
            (0..n).map(|_| (rng.f32() - 0.5) * scale).collect()
        };
        let hidden = v(b * d, 2.0);
        let wq = v(d * nh * l, 0.4);
        let wkv = v(d * l, 0.4);
        let w_down = v(nh * l * dh, 0.4);
        let wo = v(nh * dh * d, 0.4);
        let kv_cache = v(b * s * l, 2.0);
        let mut rng2 = Rng::seed_from_u64(seed ^ 0xbeef);
        let pos = (0..b).map(|_| rng2.range(0, s)).collect();
        MlaCase { batch: b, d_model: d, n_heads: nh, head_dim: dh, lora: l, seq: s, hidden, wq, wkv, w_down, wo, kv_cache, pos }
    }

    pub fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length mismatch");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let denom = 1.0f32.max(x.abs()).max(y.abs());
            assert!(
                (x - y).abs() / denom < tol,
                "{what}[{i}]: {x} vs {y} (tol {tol})"
            );
        }
    }
}
