//! SplitHead — the register-resident dataflow variant (Alg. 5, App. B.2).
//!
//! Blocks within a head-cluster partition the **head dimension** in all
//! three stages, so Q/K/V segments stay in each block's registers (no
//! gather needed). The price: the `Q·Kᵀ` score row is only *partially*
//! summed in each block and must be combined with a
//! `ClusterReduce(sum)` of size **S** (the whole sequence!), and the
//! partial output projection needs another reduce of size **D**:
//!
//! ```text
//! Traffic = Traffic_Reduce(S, N) + Traffic_Reduce(D, N)
//! ```
//!
//! which grows with sequence length and loses to SplitToken at long
//! context (Fig. 20) — the quantitative argument for the paper's final
//! dataflow choice.

use crate::clustersim::collective::{cluster_reduce, reduce_cost, ReduceOp, Transport};
use crate::clustersim::hw::Hardware;
use crate::clustersim::noc::Noc;
use crate::util::linalg::{self, PackedWeight};
use crate::util::pool::Pool;

use super::reference::AttnOut;
use super::{occupancy_mem_time, AttnProblem, CostEnv, CostReport, ELEM, PHASE_SETUP};

/// Functional execution of Alg. 5. Requires `dh % n == 0`.
///
/// Hot path: Q/K/V weights are packed once before the head loop
/// ([`PackedWeight`]) and the projections run on `linalg::matmul_rows`;
/// the output projection keeps the seed's row-major `wo` walk (already
/// contiguous) through `linalg::axpy`. Accumulation order per output is
/// the seed's, so results are byte-identical to the frozen scalar copy
/// (`tests/integration_bitexact.rs`).
#[allow(clippy::too_many_arguments)]
pub fn execute(
    hidden: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> (AttnOut, CostReport) {
    execute_on(
        &Pool::serial(),
        hidden,
        wq,
        wk,
        wv,
        wo,
        k_cache,
        v_cache,
        pos,
        b,
        d,
        nh,
        dh,
        s,
        n,
        transport,
        hw,
        noc,
    )
}

/// [`execute`] on a worker [`Pool`], coalesced over the **flattened
/// heads×blocks task grid** (DESIGN.md §Parallel): phase 1 dispatches
/// one task per (head, cluster block) computing the block's register QKV
/// segments and its partial score row; phase 2 dispatches the same grid
/// for the local softmax + partial output projection. The two
/// `ClusterReduce`s between/after them and the output merge stay on the
/// calling thread, heads ascending — one f32 add per output element per
/// head, the serial loop's exact accumulation sequence — so the result
/// is byte-identical to the serial path at every pool size
/// (`tests/integration_parallel.rs`), with 2 dispatches per call and
/// `n`-times finer task granularity than the old per-head fan-out.
#[allow(clippy::too_many_arguments)]
pub fn execute_on(
    pool: &Pool,
    hidden: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> (AttnOut, CostReport) {
    assert!(dh % n == 0, "cluster must divide head_dim");
    let h = nh * dh;
    let hs = dh / n;
    let scale = 1.0 / (dh as f32).sqrt();

    let mut out = vec![0f32; b * d];
    let mut k_new_g = vec![0f32; b * h];
    let mut v_new_g = vec![0f32; b * h];
    let mut report = CostReport { launches: 1, ..Default::default() };

    // Pack once; sliced per head/block below (no per-head re-pack).
    let wq_p = PackedWeight::pack(wq, d, h);
    let wk_p = PackedWeight::pack(wk, d, h);
    let wv_p = PackedWeight::pack(wv, d, h);

    // ---- Phase 1, one task per (head, cluster block): register QKV
    // segments (Alg. 5 lines 1-2; block r owns head-dim slice
    // [r*hs, (r+1)*hs)) and the partial scores over the *full* sequence
    // (line 3): S_b = Q_b × K_b^T summed over this block's dim slice ----
    type BlockOut = (Vec<f32>, Vec<f32>, Vec<f32>);
    let blocks: Vec<BlockOut> = pool.run_map(nh * n, |idx| {
        let (head, r) = (idx / n, idx % n);
        let project = |pw: &PackedWeight| -> Vec<f32> {
            let mut seg = vec![0f32; b * hs];
            linalg::matmul_rows(hidden, b, d, pw, 0, head * dh + r * hs, hs, &mut seg);
            seg
        };
        let q_seg = project(&wq_p);
        let k_seg = project(&wk_p);
        let v_seg = project(&wv_p);

        let mut sc = vec![0f32; b * (s + 1)];
        for bi in 0..b {
            let qseg = &q_seg[bi * hs..(bi + 1) * hs];
            // token-tiled score scan (4 in-order chains per step)
            let row_at = |t: usize| {
                let base = ((bi * s + t) * nh + head) * dh + r * hs;
                &k_cache[base..base + hs]
            };
            let valid = pos[bi];
            let mut t = 0;
            while t + 4 <= valid {
                let d4 = linalg::dot4(qseg, row_at(t), row_at(t + 1), row_at(t + 2), row_at(t + 3));
                for (k, dv) in d4.iter().enumerate() {
                    sc[bi * (s + 1) + t + k] = dv * scale;
                }
                t += 4;
            }
            while t < valid {
                sc[bi * (s + 1) + t] = linalg::dot(qseg, row_at(t)) * scale;
                t += 1;
            }
            // self token at row index s
            sc[bi * (s + 1) + s] = linalg::dot(qseg, &k_seg[bi * hs..(bi + 1) * hs]) * scale;
        }
        (k_seg, v_seg, sc)
    });
    let mut k_segs_g: Vec<Vec<f32>> = Vec::with_capacity(nh * n);
    let mut v_segs_g: Vec<Vec<f32>> = Vec::with_capacity(nh * n);
    let mut scores_g: Vec<Vec<f32>> = Vec::with_capacity(nh * n);
    for (k_seg, v_seg, sc) in blocks {
        k_segs_g.push(k_seg);
        v_segs_g.push(v_seg);
        scores_g.push(sc);
    }

    // ---- new-K/V write-back and the ClusterReduce(sum) of each head's
    // S-sized score row, serial per head in ascending order ----
    for head in 0..nh {
        for r in 0..n {
            let k_seg = &k_segs_g[head * n + r];
            let v_seg = &v_segs_g[head * n + r];
            for bi in 0..b {
                let dst = bi * h + head * dh + r * hs;
                k_new_g[dst..dst + hs].copy_from_slice(&k_seg[bi * hs..(bi + 1) * hs]);
                v_new_g[dst..dst + hs].copy_from_slice(&v_seg[bi * hs..(bi + 1) * hs]);
            }
        }
        let rc = cluster_reduce(
            &mut scores_g[head * n..(head + 1) * n],
            ReduceOp::Sum,
            transport,
            hw,
            noc,
        );
        report.dsmem_bytes += rc.traffic_bytes;
    }

    // ---- Phase 2, same grid: local softmax (identical in every block),
    // A_b over the block's V slice, partial output projection over the
    // FULL D columns (lines 3-4) ----
    let o_grid: Vec<Vec<f32>> = pool.run_map(nh * n, |idx| {
        let (head, r) = (idx / n, idx % n);
        let v_seg = &v_segs_g[head * n + r];
        let score_buf = &scores_g[head * n + r];
        let mut probs: Vec<f32> = Vec::new();
        let mut a_row = vec![0f32; hs];
        let mut o_buf = vec![0f32; b * d];
        for bi in 0..b {
            let valid = pos[bi];
            let row = &score_buf[bi * (s + 1)..(bi + 1) * (s + 1)];
            let mut m = row[s];
            for t in 0..valid {
                m = m.max(row[t]);
            }
            let mut l = 0f32;
            probs.clear();
            probs.resize(valid + 1, 0.0);
            for t in 0..valid {
                probs[t] = (row[t] - m).exp();
                l += probs[t];
            }
            probs[valid] = (row[s] - m).exp();
            l += probs[valid];
            // A_b: (hs) attention output over this block's V slice
            a_row.fill(0.0);
            for t in 0..valid {
                let base = ((bi * s + t) * nh + head) * dh + r * hs;
                linalg::axpy(probs[t], &v_cache[base..base + hs], &mut a_row);
            }
            for (j, av) in a_row.iter_mut().enumerate() {
                *av += probs[valid] * v_seg[bi * hs + j];
                *av /= l;
            }
            // partial output projection over the FULL D columns
            for (j, &av) in a_row.iter().enumerate() {
                let wrow = &wo[(head * dh + r * hs + j) * d..(head * dh + r * hs + j + 1) * d];
                linalg::axpy(av, wrow, &mut o_buf[bi * d..(bi + 1) * d]);
            }
        }
        o_buf
    });

    // ---- ClusterReduce(sum) of each head's D-sized partial output
    // (line 5) and the atomicAdd merge (line 6; rank 0 writes), serial
    // per head in ascending order — the serial loop's exact `out`
    // accumulation sequence ----
    let mut o_iter = o_grid.into_iter();
    for _head in 0..nh {
        let mut o_bufs: Vec<Vec<f32>> = Vec::with_capacity(n);
        for _ in 0..n {
            o_bufs.push(o_iter.next().expect("one task per (head, block)"));
        }
        let rc2 = cluster_reduce(&mut o_bufs, ReduceOp::Sum, transport, hw, noc);
        report.dsmem_bytes += rc2.traffic_bytes;
        linalg::axpy(1.0, &o_bufs[0], &mut out);
    }

    (AttnOut { out, k_new: k_new_g, v_new: v_new_g }, report)
}

/// Performance model: same fused mandatory HBM traffic as SplitToken, but
/// the collective schedule is Reduce(S) + Reduce(D) per cluster and the
/// register residency shaves the phase-setup term.
pub fn cost(p: &AttnProblem, env: &CostEnv) -> CostReport {
    let n = env.cluster_size;
    let (hw, noc) = (env.hw, env.noc);
    let mut rep = CostReport { launches: 1, ..Default::default() };

    let blocks = p.n_heads * n;
    let active = noc.active_sms(n);
    let bytes = p.mandatory_bytes_mha();
    rep.hbm_bytes = bytes;

    let t_mem = occupancy_mem_time(bytes, blocks, active, hw) / env.bw_efficiency;
    let t_compute = hw.compute_time(p.flops_mha());
    rep.stage("fused-mem/compute", t_mem.max(t_compute));

    let bh = p.batch as f64;
    // Reduce of the (S+1)-row of scores (fp32 accumulators) + Reduce(D)
    let red_s = reduce_cost((p.seq as f64 + 1.0) * bh * 4.0, n, env.transport, hw, noc);
    let red_d = reduce_cost(p.d_model as f64 * bh * ELEM, n, env.transport, hw, noc);
    rep.stage("collectives", red_s.latency + red_d.latency);
    rep.dsmem_bytes = (red_s.traffic_bytes + red_d.traffic_bytes) * p.n_heads as f64;
    if env.transport == Transport::Dsmem {
        rep.stage("dsmem-contention", rep.dsmem_bytes / noc.bandwidth(n));
    }
    if env.transport == Transport::GlobalMemory {
        // grid-wide software barriers replace the cluster-scoped ones
        let rounds = red_s.rounds + red_d.rounds;
        rep.stage(
            "gmem-grid-barriers",
            rounds as f64 * super::GMEM_BARRIER_PER_BLOCK * blocks as f64,
        );
    }


    // registers don't reduce the barrier count: three phases like SplitToken
    rep.stage("phase-setup", 3.0 * PHASE_SETUP / (n.min(2) as f64));
    rep.stage("launch", hw.graph_kernel_launch);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustersim::dataflow::reference::attention_block_ref;
    use crate::clustersim::dataflow::split_token;
    use crate::clustersim::dataflow::testutil::{assert_close, mha_case};
    use crate::clustersim::{Hardware, Noc};

    fn env() -> (Hardware, Noc) {
        let hw = Hardware::h100_sxm5();
        let noc = Noc::h100(&hw);
        (hw, noc)
    }

    #[test]
    fn matches_reference_all_cluster_sizes() {
        let (hw, noc) = env();
        let c = mha_case(11, 2, 2, 8, 12, 16);
        let r = attention_block_ref(
            &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
            c.batch, c.d_model, c.n_heads, c.head_dim, c.seq,
        );
        for n in [1usize, 2, 4, 8] {
            let (got, _) = execute(
                &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
                c.batch, c.d_model, c.n_heads, c.head_dim, c.seq, n,
                Transport::Dsmem, &hw, &noc,
            );
            assert_close(&got.out, &r.out, 1e-4, &format!("out n={n}"));
            assert_close(&got.k_new, &r.k_new, 1e-4, "k_new");
            assert_close(&got.v_new, &r.v_new, 1e-4, "v_new");
        }
    }

    #[test]
    fn splithead_traffic_grows_with_seq_splittoken_does_not() {
        // The Appendix B.2 argument, on executed (not analytical) traffic.
        let (hw, noc) = env();
        let mk = |s: usize| mha_case(5, 1, 1, 8, s, 8);
        let run_sh = |s: usize| {
            let c = mk(s);
            execute(
                &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
                c.batch, c.d_model, c.n_heads, c.head_dim, c.seq, 4,
                Transport::Dsmem, &hw, &noc,
            )
            .1
            .dsmem_bytes
        };
        let run_st = |s: usize| {
            let c = mk(s);
            split_token::execute(
                &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
                c.batch, c.d_model, c.n_heads, c.head_dim, c.seq, 4,
                Transport::Dsmem, &hw, &noc,
            )
            .1
            .dsmem_bytes
        };
        assert!(run_sh(64) > 2.0 * run_sh(16), "SplitHead DSMEM grows with S");
        assert_eq!(run_st(64), run_st(16), "SplitToken DSMEM independent of S");
    }

    #[test]
    fn cost_crossover_with_sequence_length() {
        // Fig. 20: near parity at short seq, SplitHead loses at long seq.
        let (hw, noc) = env();
        let env4 = CostEnv::clusterfusion(&hw, &noc, 4);
        let p = |seq| AttnProblem {
            batch: 1, d_model: 4096, n_heads: 32, head_dim: 128, seq, kv_lora_rank: 0,
        };
        let gap = |seq: usize| {
            let sh = cost(&p(seq), &env4).latency;
            let st = split_token::cost(&p(seq), &env4).latency;
            sh / st
        };
        assert!(gap(1024) < 1.1, "short-seq gap should be small: {}", gap(1024));
        assert!(gap(16384) > gap(1024), "long-seq gap must widen");
    }
}
