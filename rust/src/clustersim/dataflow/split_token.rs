//! SplitToken — the paper's ClusterFusion dataflow (Alg. 3, Fig. 7).
//!
//! One thread-block **cluster per attention head**; within a cluster the
//! N blocks partition
//!
//! * the head dimension for *QKV Projection* (each block computes an
//!   `h = dh/N` slice, then `ClusterGather` assembles the full Q/K/V),
//! * the KV-cache sequence for *Attention* (each block scans `S/N` cached
//!   tokens FlashDecoding-style; softmax statistics and the partial
//!   outputs are combined with `ClusterReduce(max)`/`ClusterReduce(sum)`),
//! * the output dimension for *Output Projection* (each block produces a
//!   `D/N` column tile and accumulates across head-clusters with
//!   atomicAdd).
//!
//! All intermediates stay on-chip: the only HBM traffic is weights, the
//! KV cache, and the activation in/out rows — which is exactly what
//! `cost()` charges and what Fig. 12 measures.

use crate::clustersim::collective::{
    cluster_gather, cluster_reduce, gather_cost, gathered_segment, reduce_cost, ReduceOp,
    Transport,
};
use crate::clustersim::hw::Hardware;
use crate::clustersim::noc::Noc;
use crate::util::linalg::{self, PackedWeight};
use crate::util::pool::Pool;

use super::reference::AttnOut;
use super::{
    occupancy_mem_time, AttnProblem, CostEnv, CostReport, PackedMhaWeights, ELEM, PHASE_SETUP,
};

/// Functional execution of Alg. 3 over simulated per-block buffers.
///
/// Layouts match [`super::reference::attention_block_ref`]; requires
/// `dh % n == 0`, `s % n == 0`, `d % n == 0` (the paper's partitioning
/// assumption). `transport` selects DSMEM or the global-memory fallback —
/// numerics are identical (the Fig. 13 ablation changes time, not values).
///
/// Hot path: the four weights are packed ([`PackedWeight`], one streaming
/// transpose each) **before** the head loop and sliced per head/block, and
/// the projection / output-projection tiles run on the blocked
/// `linalg::matmul_rows*` kernels. Per-output accumulation order is
/// unchanged from the seed's scalar loops (i ascending, one accumulator),
/// so the result is byte-identical — asserted against the frozen scalar
/// copy by `tests/integration_bitexact.rs`.
#[allow(clippy::too_many_arguments)]
pub fn execute(
    hidden: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> (AttnOut, CostReport) {
    // One-shot convenience: pack here, then run the packed path. Sweeps
    // re-evaluating with fixed weights should pack once themselves and
    // call [`execute_packed`] — packing is a full streaming transpose of
    // every weight and would otherwise dominate repeated evals.
    let weights = PackedMhaWeights::pack(wq, wk, wv, wo, d, nh * dh);
    execute_packed(hidden, &weights, k_cache, v_cache, pos, b, d, nh, dh, s, n, transport, hw, noc)
}

/// [`execute`] with the weights already packed (the dense-sweep hot
/// path; see [`PackedMhaWeights`] for the lifetime contract). Numerics
/// are identical to `execute` — packing is pure data movement.
#[allow(clippy::too_many_arguments)]
pub fn execute_packed(
    hidden: &[f32],
    weights: &PackedMhaWeights,
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> (AttnOut, CostReport) {
    execute_packed_rope(
        hidden, weights, k_cache, v_cache, pos, b, d, nh, dh, s, n, transport, hw, noc, None,
    )
}

/// [`execute_packed`] on a worker [`Pool`]: the cluster blocks — the
/// paper's unit of independent work — map onto host threads (DESIGN.md
/// §Parallel). Byte-identical to the serial path at every pool size.
#[allow(clippy::too_many_arguments)]
pub fn execute_packed_on(
    pool: &Pool,
    hidden: &[f32],
    weights: &PackedMhaWeights,
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> (AttnOut, CostReport) {
    execute_packed_rope_on(
        pool, hidden, weights, k_cache, v_cache, pos, b, d, nh, dh, s, n, transport, hw, noc, None,
    )
}

/// [`execute_packed`] with optional rotary position embedding — the
/// dataflow glue the block pipeline (`clustersim::block`) composes with:
/// after the cluster gather assembles the full per-head Q and the new K
/// row, both are rotated in place by `linalg::rope_rotate` at each batch
/// row's position before the score scan and the cache write-back (the
/// cache therefore holds *rotated* K rows, the standard decode layout).
/// `rope_base = None` is bit-identical to [`execute_packed`] — the frozen
/// scalar suite (`tests/integration_bitexact.rs`) pins that path.
#[allow(clippy::too_many_arguments)]
pub fn execute_packed_rope(
    hidden: &[f32],
    weights: &PackedMhaWeights,
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
    rope_base: Option<f32>,
) -> (AttnOut, CostReport) {
    execute_packed_rope_on(
        &Pool::serial(),
        hidden,
        weights,
        k_cache,
        v_cache,
        pos,
        b,
        d,
        nh,
        dh,
        s,
        n,
        transport,
        hw,
        noc,
        rope_base,
    )
}

/// The post-gather attention core of **every head's** cluster schedule —
/// FlashDecoding partials over each block's KV span, the three
/// `ClusterReduce`s with the online-softmax rescale between them, and the
/// per-block output-projection tiles merged into `out` with one
/// atomicAdd-equivalent add per element, in the serial `(head, r, bi)`
/// order.
///
/// Coalesced fan-out (DESIGN.md §Parallel): instead of one pool dispatch
/// per phase *per head*, each block-parallel phase dispatches **once over
/// the flattened heads×blocks task grid** — task `idx` is head `idx / n`,
/// cluster block `idx % n`. The per-task arithmetic is the per-head loop
/// body unchanged, and every serial merge walks heads (and blocks within
/// a head) in ascending order, so results stay byte-identical to the
/// per-head dispatch structure at every pool size while the persistent
/// pool sees 2 dispatches here instead of `2·nh`. The collectives
/// between the phases run on the calling thread, heads ascending.
///
/// Runs identically for decode batches and the multi-position prefill
/// path ([`prefill_packed_rope_on`], `b == 1` per prompt row): per-slot
/// results depend only on that slot's inputs (every loop is per-`bi`;
/// the butterfly reduces are element-wise across blocks), so decode
/// batches and single-row prefill calls produce byte-identical per-slot
/// bits.
///
/// `q`/`k_new`/`v_new` are the assembled, already-roped `(nh, b, dh)`
/// head-major rows; `k_cache`/`v_cache` are `(b, s, nh*dh)` dense plane
/// slices; `pos[bi]` is slot `bi`'s valid cache length (the self token
/// always comes from `k_new`/`v_new`, owned by block `n-1`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_heads_on(
    pool: &Pool,
    q: &[f32],
    k_new: &[f32],
    v_new: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
    n: usize,
    wo_p: &PackedWeight,
    scale: f32,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
    out: &mut [f32],
    report: &mut CostReport,
) {
    let (ss, ds) = (s / n, d / n);
    let hb = b * dh; // one head's (b, dh) plane in q/k_new/v_new
    {
        // ---- Stage 2: FlashDecoding partials over each block's KV span
        // (Alg. 3 line 4), one task per (head, cluster block) on the
        // flattened grid; block n-1 also owns the self token ----
        let partials: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = pool.run_map(nh * n, |idx| {
            let (head, r) = (idx / n, idx % n);
            let qh = &q[head * hb..(head + 1) * hb];
            let knh = &k_new[head * hb..(head + 1) * hb];
            let vnh = &v_new[head * hb..(head + 1) * hb];
            let mut m_row = vec![f32::NEG_INFINITY; b];
            let mut l_row = vec![0f32; b];
            let mut acc_row = vec![0f32; b * dh];
            let mut scores: Vec<(usize, f32)> = Vec::new();
            for bi in 0..b {
                let valid = pos[bi];
                let lo = r * ss;
                let hi = ((r + 1) * ss).min(valid);
                let qrow = &qh[bi * dh..(bi + 1) * dh];
                scores.clear();
                // token-tiled score scan: 4 independent in-order dot
                // chains per step (each score's accumulation order is
                // unchanged — see linalg::dot4)
                let row_at = |t: usize| {
                    let base = ((bi * s + t) * nh + head) * dh;
                    &k_cache[base..base + dh]
                };
                let end = hi.max(lo);
                let mut t = lo;
                while t + 4 <= end {
                    let d4 =
                        linalg::dot4(qrow, row_at(t), row_at(t + 1), row_at(t + 2), row_at(t + 3));
                    for (k, dv) in d4.iter().enumerate() {
                        scores.push((t + k, dv * scale));
                    }
                    t += 4;
                }
                while t < end {
                    scores.push((t, linalg::dot(qrow, row_at(t)) * scale));
                    t += 1;
                }
                let self_here = r == n - 1;
                let self_score = if self_here {
                    Some(linalg::dot(qrow, &knh[bi * dh..(bi + 1) * dh]) * scale)
                } else {
                    None
                };
                let mut m = f32::NEG_INFINITY;
                for (_, sc) in &scores {
                    m = m.max(*sc);
                }
                if let Some(sc) = self_score {
                    m = m.max(sc);
                }
                if m == f32::NEG_INFINITY {
                    continue; // nothing valid in this span
                }
                let mut l = 0f32;
                let acc = &mut acc_row[bi * dh..(bi + 1) * dh];
                for (t, sc) in &scores {
                    let p = (sc - m).exp();
                    l += p;
                    let base = ((bi * s + t) * nh + head) * dh;
                    linalg::axpy(p, &v_cache[base..base + dh], acc);
                }
                if let Some(sc) = self_score {
                    let p = (sc - m).exp();
                    l += p;
                    linalg::axpy(p, &vnh[bi * dh..(bi + 1) * dh], acc);
                }
                m_row[bi] = m;
                l_row[bi] = l;
            }
            (m_row, l_row, acc_row)
        });

        // ---- ClusterReduce of softmax stats and the attention output
        // (Alg. 3 lines 5-7), serial per head in ascending order ----
        let mut parts = partials.into_iter();
        let mut reduced: Vec<(Vec<Vec<f32>>, Vec<Vec<f32>>)> = Vec::with_capacity(nh);
        for _head in 0..nh {
            let mut m_bufs: Vec<Vec<f32>> = Vec::with_capacity(n);
            let mut l_bufs: Vec<Vec<f32>> = Vec::with_capacity(n);
            let mut acc_bufs: Vec<Vec<f32>> = Vec::with_capacity(n);
            for _ in 0..n {
                let (m_row, l_row, acc_row) = parts.next().expect("one task per (head, block)");
                m_bufs.push(m_row);
                l_bufs.push(l_row);
                acc_bufs.push(acc_row);
            }
            let m_local: Vec<Vec<f32>> = m_bufs.clone();
            let rc1 = cluster_reduce(&mut m_bufs, ReduceOp::Max, transport, hw, noc);
            report.dsmem_bytes += rc1.traffic_bytes;
            // rescale local l and acc by exp(m_local - m_global) (line 6's
            // online-softmax rescale with Reg_max)
            for r in 0..n {
                for bi in 0..b {
                    let alpha = if m_local[r][bi] == f32::NEG_INFINITY {
                        0.0
                    } else {
                        (m_local[r][bi] - m_bufs[r][bi]).exp()
                    };
                    l_bufs[r][bi] *= alpha;
                    linalg::scale(alpha, &mut acc_bufs[r][bi * dh..(bi + 1) * dh]);
                }
            }
            let rc2 = cluster_reduce(&mut l_bufs, ReduceOp::Sum, transport, hw, noc);
            report.dsmem_bytes += rc2.traffic_bytes;
            let rc3 = cluster_reduce(&mut acc_bufs, ReduceOp::Sum, transport, hw, noc);
            report.dsmem_bytes += rc3.traffic_bytes;
            reduced.push((l_bufs, acc_bufs));
        }

        // ---- Stage 3: per-block Output Projection tile + atomicAdd
        // (Alg. 3 line 8): task (head, r) computes columns
        // [r*ds, (r+1)*ds) as a grid task into a private tile; the
        // atomicAdd merge below adds each tile element once, in the
        // serial (head, r, bi, j ascending) order — the same single f32
        // add per output the serial matmul_rows_acc performed ----
        let tiles: Vec<Vec<f32>> = pool.run_map(nh * n, |idx| {
            let (head, r) = (idx / n, idx % n);
            let (l_bufs, acc_bufs) = &reduced[head];
            let mut tile = vec![0f32; b * ds];
            let mut attn_row = vec![0f32; dh];
            for bi in 0..b {
                linalg::scale_div(
                    &acc_bufs[r][bi * dh..(bi + 1) * dh],
                    l_bufs[r][bi],
                    &mut attn_row,
                );
                linalg::matmul_rows(
                    &attn_row,
                    1,
                    dh,
                    wo_p,
                    head * dh,
                    r * ds,
                    ds,
                    &mut tile[bi * ds..(bi + 1) * ds],
                );
            }
            tile
        });
        for (idx, tile) in tiles.iter().enumerate() {
            let r = idx % n;
            for bi in 0..b {
                let dst = &mut out[bi * d + r * ds..bi * d + (r + 1) * ds];
                linalg::axpy(1.0, &tile[bi * ds..(bi + 1) * ds], dst); // atomicAdd
            }
        }
    }
}

/// [`execute_packed_rope`] on a worker [`Pool`]. The three
/// block-parallel phases — QKV projection segments, FlashDecoding
/// partials over the KV spans, and the output-projection column tiles —
/// each fan **one flattened heads×blocks task grid** across the pool
/// ([`Pool::run_map`] over `nh·n` tasks, results in (head, block)
/// order): three dispatches per call instead of `3·nh`, the host analog
/// of the paper's fused-kernel launch-count cut. The collectives between
/// the phases (gather, the three reduces) and the atomicAdd merge stay
/// on the calling thread, heads ascending, in the serial code's exact
/// order. Every output element keeps its single in-order accumulation
/// chain, so the result is **byte-identical** to the serial path at
/// every pool size (`tests/integration_parallel.rs`); a serial pool runs
/// the identical loops inline.
#[allow(clippy::too_many_arguments)]
pub fn execute_packed_rope_on(
    pool: &Pool,
    hidden: &[f32],
    weights: &PackedMhaWeights,
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
    rope_base: Option<f32>,
) -> (AttnOut, CostReport) {
    assert!(dh % n == 0 && s % n == 0 && d % n == 0, "cluster must divide dh, S, D");
    let h = nh * dh;
    let hs = dh / n; // per-block head-dim slice
    let scale = 1.0 / (dh as f32).sqrt();
    let (wq_p, wk_p, wv_p, wo_p) = (&weights.wq, &weights.wk, &weights.wv, &weights.wo);
    assert!(wq_p.n_in() == d && wq_p.n_out() == h && wo_p.n_in() == h && wo_p.n_out() == d);

    let mut out = vec![0f32; b * d]; // global-memory output (atomicAdd target)
    let mut k_new_g = vec![0f32; b * h];
    let mut v_new_g = vec![0f32; b * h];
    let mut report = CostReport::default();
    report.launches = 1; // the whole block is ONE fused kernel

    // ---- Stage 1: per-block QKV projection segments (Alg. 3 line 2),
    // one task per (head, cluster block) on the flattened grid; task
    // (head, r) computes columns [head*dh + r*hs, head*dh + (r+1)*hs)
    // of all three projections ----
    let segs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = pool.run_map(nh * n, |idx| {
        let (head, r) = (idx / n, idx % n);
        let project = |pw: &PackedWeight| -> Vec<f32> {
            let mut seg = vec![0f32; b * hs];
            linalg::matmul_rows(hidden, b, d, pw, 0, head * dh + r * hs, hs, &mut seg);
            seg
        };
        (project(wq_p), project(wk_p), project(wv_p))
    });

    // ---- ClusterGather of Q/K/V (Alg. 3 line 3), serial per head in
    // ascending order: one gather of the concatenated 3h-sized segment
    // per block, then reassembly, rope, and the cache write-back ----
    let hb = b * dh;
    let mut q_all = vec![0f32; nh * hb];
    let mut kn_all = vec![0f32; nh * hb];
    let mut vn_all = vec![0f32; nh * hb];
    for head in 0..nh {
        let cat: Vec<Vec<f32>> = (0..n)
            .map(|r| {
                let (q_seg, k_seg, v_seg) = &segs[head * n + r];
                let mut c = Vec::with_capacity(3 * b * hs);
                c.extend_from_slice(q_seg);
                c.extend_from_slice(k_seg);
                c.extend_from_slice(v_seg);
                c
            })
            .collect();
        let (gathered, gc) = cluster_gather(&cat, transport, hw, noc);
        report.dsmem_bytes += gc.traffic_bytes;

        // Each block reassembles the full per-head q/k_new/v_new (B, dh).
        // All blocks end with identical copies; verify with block 0 and
        // assert agreement for block n-1 (the cluster contract).
        let assemble = |owner: usize| -> (Vec<f32>, Vec<f32>, Vec<f32>) {
            let seg_len = 3 * b * hs;
            let mut q = vec![0f32; b * dh];
            let mut kn = vec![0f32; b * dh];
            let mut vn = vec![0f32; b * dh];
            for r in 0..n {
                let seg = gathered_segment(&gathered[owner], owner, r, n, seg_len);
                for bi in 0..b {
                    q[bi * dh + r * hs..bi * dh + (r + 1) * hs]
                        .copy_from_slice(&seg[bi * hs..(bi + 1) * hs]);
                    kn[bi * dh + r * hs..bi * dh + (r + 1) * hs]
                        .copy_from_slice(&seg[b * hs + bi * hs..b * hs + (bi + 1) * hs]);
                    vn[bi * dh + r * hs..bi * dh + (r + 1) * hs]
                        .copy_from_slice(&seg[2 * b * hs + bi * hs..2 * b * hs + (bi + 1) * hs]);
                }
            }
            (q, kn, vn)
        };
        let (mut q, mut k_new, v_new) = assemble(0);
        debug_assert_eq!(assemble(n - 1), (q.clone(), k_new.clone(), v_new.clone()));

        // Rotary embedding (block-pipeline glue): every cluster block
        // holds the full per-head Q/K after the gather, so each rotates
        // its copy redundantly — no extra collective traffic.
        if let Some(base) = rope_base {
            for bi in 0..b {
                linalg::rope_rotate(&mut q[bi * dh..(bi + 1) * dh], pos[bi], base);
                linalg::rope_rotate(&mut k_new[bi * dh..(bi + 1) * dh], pos[bi], base);
            }
        }

        // write-back of the new K/V rows (cache append goes to HBM anyway)
        for bi in 0..b {
            k_new_g[bi * h + head * dh..bi * h + (head + 1) * dh]
                .copy_from_slice(&k_new[bi * dh..(bi + 1) * dh]);
            v_new_g[bi * h + head * dh..bi * h + (head + 1) * dh]
                .copy_from_slice(&v_new[bi * dh..(bi + 1) * dh]);
        }

        q_all[head * hb..(head + 1) * hb].copy_from_slice(&q);
        kn_all[head * hb..(head + 1) * hb].copy_from_slice(&k_new);
        vn_all[head * hb..(head + 1) * hb].copy_from_slice(&v_new);
    }

    // ---- Stages 2-3: FlashDecoding partials, the three reduces, and
    // the output-projection tiles + atomicAdd merge (Alg. 3 lines 4-8)
    // for every head at once — the shared attention core ----
    attend_heads_on(
        pool, &q_all, &kn_all, &vn_all, k_cache, v_cache, pos, b, d, nh, dh, s, n, wo_p, scale,
        transport, hw, noc, &mut out, &mut report,
    );

    (AttnOut { out, k_new: k_new_g, v_new: v_new_g }, report)
}

/// Multi-position (prefill) execution of the same cluster schedule:
/// `hidden` holds `T` prompt rows (slot-major across the batch), row `j`
/// belonging to cache slot `row_slot[j]` at absolute position
/// `row_pos[j]`. Per head, the QKV projections batch all `T` rows through
/// the packed-GEMM segments (one weight stream amortised over the whole
/// chunk — the prefill regime of Fig. 2), rope rotates each row at its
/// own position, and the roped K/V rows are **written into the mutable
/// dense planes** at their positions so later rows of the same chunk
/// attend to earlier ones. Attention then runs causally per row through
/// [`attend_heads_on`] with `b == 1` and `valid = row_pos[j]` — the
/// byte-identical decode core — so a chunked prefill reproduces the
/// retired decode-as-prefill token stream bit for bit
/// (`tests/integration_prefill.rs`).
///
/// `k_plane`/`v_plane` are `(bucket, s, nh*dh)` dense planes; only rows
/// `[row_pos[j]]` of slot `row_slot[j]` are written. Returns `(T, d)`
/// attention output and the `(T, nh*dh)` new K/V rows in feed order.
#[allow(clippy::too_many_arguments)]
pub fn prefill_packed_rope_on(
    pool: &Pool,
    hidden: &[f32],
    weights: &PackedMhaWeights,
    k_plane: &mut [f32],
    v_plane: &mut [f32],
    row_slot: &[usize],
    row_pos: &[usize],
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
    rope_base: Option<f32>,
) -> (AttnOut, CostReport) {
    assert!(dh % n == 0 && s % n == 0 && d % n == 0, "cluster must divide dh, S, D");
    let t_rows = row_slot.len();
    assert_eq!(row_pos.len(), t_rows);
    let h = nh * dh;
    let hs = dh / n; // per-block head-dim slice
    let scale = 1.0 / (dh as f32).sqrt();
    let (wq_p, wk_p, wv_p, wo_p) = (&weights.wq, &weights.wk, &weights.wv, &weights.wo);
    assert!(wq_p.n_in() == d && wq_p.n_out() == h && wo_p.n_in() == h && wo_p.n_out() == d);

    let mut out = vec![0f32; t_rows * d];
    let mut k_new_g = vec![0f32; t_rows * h];
    let mut v_new_g = vec![0f32; t_rows * h];
    let mut q_g = vec![0f32; t_rows * h];
    let mut report = CostReport::default();
    report.launches = 1; // one fused kernel per chunk, like decode

    // ---- Phase A: batched QKV projection + rope + cache write, every
    // head, before any attention — rows of this chunk must see each
    // other's K/V. Stage 1 runs over all T rows at once (matmul_rows is
    // row-independent, so each row's bits match the decode-as-prefill
    // projection) and over all heads at once: one task per
    // (head, cluster block) on the flattened grid ----
    let segs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = pool.run_map(nh * n, |idx| {
        let (head, r) = (idx / n, idx % n);
        let project = |pw: &PackedWeight| -> Vec<f32> {
            let mut seg = vec![0f32; t_rows * hs];
            linalg::matmul_rows(hidden, t_rows, d, pw, 0, head * dh + r * hs, hs, &mut seg);
            seg
        };
        (project(wq_p), project(wk_p), project(wv_p))
    });
    for head in 0..nh {
        let cat: Vec<Vec<f32>> = (0..n)
            .map(|r| {
                let (q_seg, k_seg, v_seg) = &segs[head * n + r];
                let mut c = Vec::with_capacity(3 * t_rows * hs);
                c.extend_from_slice(q_seg);
                c.extend_from_slice(k_seg);
                c.extend_from_slice(v_seg);
                c
            })
            .collect();
        let (gathered, gc) = cluster_gather(&cat, transport, hw, noc);
        report.dsmem_bytes += gc.traffic_bytes;
        let seg_len = 3 * t_rows * hs;
        let mut q = vec![0f32; t_rows * dh];
        let mut kn = vec![0f32; t_rows * dh];
        let mut vn = vec![0f32; t_rows * dh];
        for r in 0..n {
            let seg = gathered_segment(&gathered[0], 0, r, n, seg_len);
            for j in 0..t_rows {
                q[j * dh + r * hs..j * dh + (r + 1) * hs]
                    .copy_from_slice(&seg[j * hs..(j + 1) * hs]);
                kn[j * dh + r * hs..j * dh + (r + 1) * hs]
                    .copy_from_slice(&seg[t_rows * hs + j * hs..t_rows * hs + (j + 1) * hs]);
                vn[j * dh + r * hs..j * dh + (r + 1) * hs].copy_from_slice(
                    &seg[2 * t_rows * hs + j * hs..2 * t_rows * hs + (j + 1) * hs],
                );
            }
        }
        if let Some(base) = rope_base {
            for j in 0..t_rows {
                linalg::rope_rotate(&mut q[j * dh..(j + 1) * dh], row_pos[j], base);
                linalg::rope_rotate(&mut kn[j * dh..(j + 1) * dh], row_pos[j], base);
            }
        }
        for j in 0..t_rows {
            q_g[j * h + head * dh..j * h + (head + 1) * dh]
                .copy_from_slice(&q[j * dh..(j + 1) * dh]);
            k_new_g[j * h + head * dh..j * h + (head + 1) * dh]
                .copy_from_slice(&kn[j * dh..(j + 1) * dh]);
            v_new_g[j * h + head * dh..j * h + (head + 1) * dh]
                .copy_from_slice(&vn[j * dh..(j + 1) * dh]);
            // dense-plane write at the row's own (slot, position): the
            // same bits the decode path round-trips through the paged
            // pool between steps
            let dst = ((row_slot[j] * s + row_pos[j]) * nh + head) * dh;
            k_plane[dst..dst + dh].copy_from_slice(&kn[j * dh..(j + 1) * dh]);
            v_plane[dst..dst + dh].copy_from_slice(&vn[j * dh..(j + 1) * dh]);
        }
    }

    // ---- Phase B: causal attention per row, serial in feed order —
    // the decode core with b == 1 and valid = row_pos[j] (earlier chunk
    // rows are already in the planes). A row's `(h,)` slice of
    // q_g/k_new_g/v_new_g is exactly the core's (nh, 1, dh) head-major
    // layout, so all heads of the row go through one coalesced call ----
    let plane_stride = s * h;
    for j in 0..t_rows {
        let slot = row_slot[j];
        let kc = &k_plane[slot * plane_stride..(slot + 1) * plane_stride];
        let vc = &v_plane[slot * plane_stride..(slot + 1) * plane_stride];
        let pos_j = [row_pos[j]];
        attend_heads_on(
            pool,
            &q_g[j * h..(j + 1) * h],
            &k_new_g[j * h..(j + 1) * h],
            &v_new_g[j * h..(j + 1) * h],
            kc,
            vc,
            &pos_j,
            1,
            d,
            nh,
            dh,
            s,
            n,
            wo_p,
            scale,
            transport,
            hw,
            noc,
            &mut out[j * d..(j + 1) * d],
            &mut report,
        );
    }

    (AttnOut { out, k_new: k_new_g, v_new: v_new_g }, report)
}

/// Performance model of the fused SplitToken kernel (one layer's core
/// modules). Charges: one launch, mandatory HBM bytes at the fused
/// kernel's achieved bandwidth under Fig. 5 occupancy, the collective
/// schedule on the chosen transport, and the compute roofline term.
pub fn cost(p: &AttnProblem, env: &CostEnv) -> CostReport {
    let n = env.cluster_size;
    let (hw, noc) = (env.hw, env.noc);
    let mut rep = CostReport { launches: 1, ..Default::default() };

    let blocks = p.n_heads * n;
    let active = noc.active_sms(n);
    let bytes = p.mandatory_bytes_mha();
    rep.hbm_bytes = bytes;

    // memory: weights + cache streamed once by the fused kernel
    let t_mem = occupancy_mem_time(bytes, blocks, active, hw) / env.bw_efficiency;
    // compute roofline (matters at batch ≥ 16, Appendix C)
    let t_compute = hw.compute_time(p.flops_mha());
    rep.stage("fused-mem/compute", t_mem.max(t_compute));

    // collectives: per head-cluster, all clusters concurrent; one gather of
    // 3h plus reduces of stats (negligible) and the H-sized output
    // (per-block message = B * dh floats for acc, B floats for stats).
    let bh = p.batch as f64;
    let gather = gather_cost(3.0 * (p.head_dim / n) as f64 * bh * ELEM, n, env.transport, hw, noc);
    let red_stats = reduce_cost(2.0 * bh * 4.0, n, env.transport, hw, noc);
    let red_out = reduce_cost(p.head_dim as f64 * bh * ELEM, n, env.transport, hw, noc);
    let coll = gather.latency + red_stats.latency + red_out.latency;
    rep.stage("collectives", coll);
    rep.dsmem_bytes = (gather.traffic_bytes + red_stats.traffic_bytes + red_out.traffic_bytes)
        * p.n_heads as f64;
    // All head-clusters share the crossbar: charge the device-aggregate
    // DSMEM traffic against the Fig. 5 bandwidth (the contention the paper
    // cites for large clusters / the SplitHead comparison).
    if env.transport == Transport::Dsmem {
        rep.stage("dsmem-contention", rep.dsmem_bytes / noc.bandwidth(n));
    }
    if env.transport == Transport::GlobalMemory {
        // grid-wide software barriers replace the cluster-scoped ones
        let rounds = gather.rounds + red_stats.rounds + red_out.rounds;
        rep.stage(
            "gmem-grid-barriers",
            rounds as f64 * super::GMEM_BARRIER_PER_BLOCK * blocks as f64,
        );
    }


    // phase pipelining: three fused phases amortised across the cluster
    rep.stage("phase-setup", 3.0 * PHASE_SETUP / (n.min(2) as f64));

    rep.stage("launch", hw.graph_kernel_launch);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustersim::dataflow::reference::attention_block_ref;
    use crate::clustersim::dataflow::testutil::{assert_close, mha_case};
    use crate::clustersim::{Hardware, Noc};

    fn env() -> (Hardware, Noc) {
        let hw = Hardware::h100_sxm5();
        let noc = Noc::h100(&hw);
        (hw, noc)
    }

    #[test]
    fn matches_reference_all_cluster_sizes() {
        let (hw, noc) = env();
        let c = mha_case(7, 2, 2, 8, 16, 16);
        let r = attention_block_ref(
            &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
            c.batch, c.d_model, c.n_heads, c.head_dim, c.seq,
        );
        for n in [1usize, 2, 4, 8] {
            let (got, rep) = execute(
                &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
                c.batch, c.d_model, c.n_heads, c.head_dim, c.seq, n,
                Transport::Dsmem, &hw, &noc,
            );
            assert_close(&got.out, &r.out, 1e-4, &format!("out n={n}"));
            assert_close(&got.k_new, &r.k_new, 1e-4, "k_new");
            assert_close(&got.v_new, &r.v_new, 1e-4, "v_new");
            assert_eq!(rep.launches, 1);
            if n > 1 {
                assert!(rep.dsmem_bytes > 0.0);
            }
        }
    }

    #[test]
    fn rope_none_is_bit_identical_and_pos_zero_is_identity() {
        let (hw, noc) = env();
        let c = mha_case(21, 2, 2, 8, 16, 16);
        let w = crate::clustersim::dataflow::PackedMhaWeights::pack(
            &c.wq, &c.wk, &c.wv, &c.wo, c.d_model, c.n_heads * c.head_dim,
        );
        let run = |rope: Option<f32>, pos: &[usize]| {
            execute_packed_rope(
                &c.hidden, &w, &c.k_cache, &c.v_cache, pos, c.batch, c.d_model, c.n_heads,
                c.head_dim, c.seq, 2, Transport::Dsmem, &hw, &noc, rope,
            )
            .0
        };
        let bits = |o: &AttnOut| -> Vec<u32> {
            o.out.iter().chain(&o.k_new).chain(&o.v_new).map(|v| v.to_bits()).collect()
        };
        // rope = None must be the exact execute_packed path
        let plain = run(None, &c.pos);
        let (direct, _) = execute(
            &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
            c.batch, c.d_model, c.n_heads, c.head_dim, c.seq, 2, Transport::Dsmem, &hw, &noc,
        );
        assert_eq!(bits(&plain), bits(&direct));
        // position 0 rotates by theta = 0: identity on Q/K, so the whole
        // output is bit-identical to the un-roped run at the same pos
        let zeros = vec![0usize; c.batch];
        assert_eq!(bits(&run(Some(10000.0), &zeros)), bits(&run(None, &zeros)));
        // nonzero positions must actually change the new K row
        let roped = run(Some(10000.0), &c.pos);
        if c.pos.iter().any(|&p| p > 0) {
            assert_ne!(bits(&roped), bits(&plain));
        }
        // v is untouched by rope
        assert_eq!(
            roped.v_new.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            plain.v_new.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn offchip_transport_same_numbers() {
        let (hw, noc) = env();
        let c = mha_case(9, 1, 2, 8, 8, 16);
        let run = |t| {
            execute(
                &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
                c.batch, c.d_model, c.n_heads, c.head_dim, c.seq, 4, t, &hw, &noc,
            )
            .0
        };
        let a = run(Transport::Dsmem);
        let b = run(Transport::GlobalMemory);
        assert_close(&a.out, &b.out, 1e-6, "transport must not change numerics");
    }

    #[test]
    fn cost_prefers_cluster4_at_32_heads() {
        // Fig. 11: with 32 heads, cluster size 4 is optimal.
        let (hw, noc) = env();
        let p = AttnProblem {
            batch: 1, d_model: 4096, n_heads: 32, head_dim: 128, seq: 4096, kv_lora_rank: 0,
        };
        let lat: Vec<(usize, f64)> = Noc::cluster_sizes()
            .iter()
            .map(|&s| (s, cost(&p, &CostEnv::clusterfusion(&hw, &noc, s)).latency))
            .collect();
        let best = lat.iter().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap().0;
        assert_eq!(best, 4, "{lat:?}");
    }

    #[test]
    fn cost_prefers_cluster2_at_128_heads() {
        // Fig. 11: with 128 heads, cluster size 2 becomes optimal.
        let (hw, noc) = env();
        let p = AttnProblem {
            batch: 1, d_model: 128 * 128, n_heads: 128, head_dim: 128, seq: 4096, kv_lora_rank: 0,
        };
        let lat: Vec<(usize, f64)> = Noc::cluster_sizes()
            .iter()
            .map(|&s| (s, cost(&p, &CostEnv::clusterfusion(&hw, &noc, s)).latency))
            .collect();
        let best = lat.iter().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap().0;
        assert_eq!(best, 2, "{lat:?}");
    }

    #[test]
    fn dsmem_faster_than_gmem_fallback() {
        // Fig. 13's direction: disabling DSMEM must cost latency.
        let (hw, noc) = env();
        let p = AttnProblem {
            batch: 1, d_model: 4096, n_heads: 32, head_dim: 128, seq: 4096, kv_lora_rank: 0,
        };
        let mut on = CostEnv::clusterfusion(&hw, &noc, 4);
        let mut off = on;
        off.transport = Transport::GlobalMemory;
        assert!(cost(&p, &off).latency > cost(&p, &on).latency);
        // direction holds across seq lengths
        for seq in [1024, 16384] {
            let p2 = AttnProblem { seq, ..p };
            on.transport = Transport::Dsmem;
            assert!(cost(&p2, &off).latency > cost(&p2, &on).latency);
        }
    }
}
