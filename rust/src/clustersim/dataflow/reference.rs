//! Plain single-threaded reference for the attention block (the oracle the
//! simulated dataflows are differentially tested against — the Rust twin
//! of `python/compile/kernels/ref.py`). Inner loops run on the shared
//! `util::linalg` row primitives, which keep the same per-element op order
//! as the original explicit loops (the bit-exactness contract).

use crate::util::linalg;
use crate::util::pool::Pool;

/// Output of one attention-block decode step.
#[derive(Debug, Clone, PartialEq)]
pub struct AttnOut {
    /// (B, D) block output (after output projection).
    pub out: Vec<f32>,
    /// (B, nh*dh) new K row to append (MHA) / (B, l) latent row (MLA).
    pub k_new: Vec<f32>,
    /// (B, nh*dh) new V row (MHA only; empty for MLA).
    pub v_new: Vec<f32>,
}

/// y[b, :n_out] += x[b, :n_in] @ w  where w is (n_in, n_out) row-major.
pub fn gemm_acc(x: &[f32], w: &[f32], y: &mut [f32], b: usize, n_in: usize, n_out: usize) {
    for bi in 0..b {
        for i in 0..n_in {
            let xv = x[bi * n_in + i];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[i * n_out..(i + 1) * n_out];
            linalg::axpy(xv, wrow, &mut y[bi * n_out..(bi + 1) * n_out]);
        }
    }
}

/// Masked-softmax attention for one head over a padded cache + self token.
///
/// q: (B, dh); k_cache/v_cache laid out (B, S, nh, dh); k_new/v_new:
/// (B, dh) the freshly projected row (always attended). Returns (B, dh).
#[allow(clippy::too_many_arguments)]
pub fn head_attention(
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    k_new: &[f32],
    v_new: &[f32],
    pos: &[usize],
    b: usize,
    s: usize,
    nh: usize,
    dh: usize,
    head: usize,
) -> Vec<f32> {
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = vec![0f32; b * dh];
    for bi in 0..b {
        let qrow = &q[bi * dh..(bi + 1) * dh];
        let n = pos[bi];
        let mut scores = Vec::with_capacity(n + 1);
        for t in 0..n {
            let base = ((bi * s + t) * nh + head) * dh;
            scores.push(linalg::dot(qrow, &k_cache[base..base + dh]) * scale);
        }
        scores.push(linalg::dot(qrow, &k_new[bi * dh..(bi + 1) * dh]) * scale);

        let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut l = 0.0;
        for sc in scores.iter_mut() {
            *sc = (*sc - m).exp();
            l += *sc;
        }
        let orow = &mut out[bi * dh..(bi + 1) * dh];
        for (t, &p) in scores[..n].iter().enumerate() {
            let base = ((bi * s + t) * nh + head) * dh;
            linalg::axpy(p, &v_cache[base..base + dh], orow);
        }
        linalg::axpy(scores[n], &v_new[bi * dh..(bi + 1) * dh], orow);
        for o in orow.iter_mut() {
            *o /= l;
        }
    }
    out
}

/// Reference fused attention block (paper Alg. 3 semantics): QKV projection
/// + masked attention over the cache + output projection, all plain math.
#[allow(clippy::too_many_arguments)]
pub fn attention_block_ref(
    hidden: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
) -> AttnOut {
    attention_block_ref_on(
        &Pool::serial(),
        hidden,
        wq,
        wk,
        wv,
        wo,
        k_cache,
        v_cache,
        pos,
        b,
        d,
        nh,
        dh,
        s,
    )
}

/// [`attention_block_ref`] on a worker [`Pool`], coalesced over the
/// **flattened heads×batch task grid**: each (head, batch-row) cell of
/// the masked-softmax attention ([`head_attention`] — the dominant cost,
/// the full cache scan) is one grid task (`head_attention` is per-row
/// independent, so slicing one row's cache plane and running `b == 1`
/// reproduces the full-batch bits); the QKV projections and the per-head
/// output-projection `gemm_acc` merge stay serial **in ascending head
/// order**, preserving the serial oracle's exact `out` accumulation
/// sequence — so this is byte-identical to [`attention_block_ref`] at
/// every pool size (`tests/integration_parallel.rs`).
#[allow(clippy::too_many_arguments)]
pub fn attention_block_ref_on(
    pool: &Pool,
    hidden: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
) -> AttnOut {
    let h = nh * dh;
    let mut q = vec![0f32; b * h];
    let mut k_new = vec![0f32; b * h];
    let mut v_new = vec![0f32; b * h];
    gemm_acc(hidden, wq, &mut q, b, d, h);
    gemm_acc(hidden, wk, &mut k_new, b, d, h);
    gemm_acc(hidden, wv, &mut v_new, b, d, h);

    let plane = s * nh * dh; // one batch row's (S, nh, dh) cache plane
    let rows: Vec<Vec<f32>> = pool.run_map(nh * b, |idx| {
        let (head, bi) = (idx / b, idx % b);
        // slice this (head, row) cell's q / k_new / v_new columns
        let take = |src: &[f32]| -> Vec<f32> {
            src[bi * h + head * dh..bi * h + (head + 1) * dh].to_vec()
        };
        let (qh, knh, vnh) = (take(&q), take(&k_new), take(&v_new));
        head_attention(
            &qh,
            &k_cache[bi * plane..(bi + 1) * plane],
            &v_cache[bi * plane..(bi + 1) * plane],
            &knh,
            &vnh,
            &pos[bi..bi + 1],
            1,
            s,
            nh,
            dh,
            head,
        )
    });

    let mut out = vec![0f32; b * d];
    let mut attn = vec![0f32; b * dh];
    for head in 0..nh {
        // reassemble this head's (B, dh) attention rows — pure copies
        for bi in 0..b {
            attn[bi * dh..(bi + 1) * dh].copy_from_slice(&rows[head * b + bi]);
        }
        // out += attn_h @ wo[head*dh .. (head+1)*dh, :]
        let wo_head = &wo[head * dh * d..(head + 1) * dh * d];
        gemm_acc(&attn, wo_head, &mut out, b, dh, d);
    }
    AttnOut { out, k_new, v_new }
}

/// Reference fused MLA block (paper Alg. 4 semantics, weight-absorbed).
#[allow(clippy::too_many_arguments)]
pub fn mla_block_ref(
    hidden: &[f32],
    wq: &[f32],     // (D, nh*l)
    wkv: &[f32],    // (D, l)
    w_down: &[f32], // (nh, l, dh)
    wo: &[f32],     // (nh*dh, D)
    kv_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    l: usize,
    dh: usize,
    s: usize,
) -> AttnOut {
    let mut q = vec![0f32; b * nh * l];
    let mut kv_new = vec![0f32; b * l];
    gemm_acc(hidden, wq, &mut q, b, d, nh * l);
    gemm_acc(hidden, wkv, &mut kv_new, b, d, l);

    let scale = 1.0 / (l as f32).sqrt();
    let mut out = vec![0f32; b * d];
    for head in 0..nh {
        // attention over the shared latent cache (MQA-style)
        let mut attn = vec![0f32; b * l];
        for bi in 0..b {
            let qrow = &q[bi * nh * l + head * l..bi * nh * l + (head + 1) * l];
            let n = pos[bi];
            let mut scores = Vec::with_capacity(n + 1);
            for t in 0..n {
                let base = (bi * s + t) * l;
                scores.push(linalg::dot(qrow, &kv_cache[base..base + l]) * scale);
            }
            let kvrow = &kv_new[bi * l..(bi + 1) * l];
            scores.push(linalg::dot(qrow, kvrow) * scale);
            let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut lsum = 0.0;
            for sc in scores.iter_mut() {
                *sc = (*sc - m).exp();
                lsum += *sc;
            }
            let arow = &mut attn[bi * l..(bi + 1) * l];
            for (t, &p) in scores[..n].iter().enumerate() {
                let base = (bi * s + t) * l;
                linalg::axpy(p, &kv_cache[base..base + l], arow);
            }
            linalg::axpy(scores[n], kvrow, arow);
            for a in arow.iter_mut() {
                *a /= lsum;
            }
        }
        // z = attn @ w_down[head]  (B, dh)
        let mut z = vec![0f32; b * dh];
        gemm_acc(&attn, &w_down[head * l * dh..(head + 1) * l * dh], &mut z, b, l, dh);
        // out += z @ wo[head]
        gemm_acc(&z, &wo[head * dh * d..(head + 1) * dh * d], &mut out, b, dh, d);
    }
    AttnOut { out, k_new: kv_new, v_new: vec![] }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_identity() {
        // x (1,2) @ I2 = x
        let x = vec![3.0, -4.0];
        let w = vec![1.0, 0.0, 0.0, 1.0];
        let mut y = vec![0.0; 2];
        gemm_acc(&x, &w, &mut y, 1, 2, 2);
        assert_eq!(y, x);
    }

    #[test]
    fn attention_uniform_values_average() {
        // All V rows identical => attention output equals that row for any
        // scores (softmax weights sum to 1).
        let (b, s, nh, dh) = (1, 4, 1, 2);
        let q = vec![0.3, -0.7];
        let k_cache: Vec<f32> = (0..b * s * nh * dh).map(|i| i as f32 * 0.1).collect();
        let v_cache = vec![5.0; b * s * nh * dh];
        let k_new = vec![0.2, 0.2];
        let v_new = vec![5.0, 5.0];
        let out = head_attention(&q, &k_cache, &v_cache, &k_new, &v_new, &[4], b, s, nh, dh, 0);
        for o in out {
            assert!((o - 5.0).abs() < 1e-5);
        }
    }

    #[test]
    fn empty_cache_attends_self_only() {
        let (b, s, nh, dh) = (1, 4, 1, 2);
        let q = vec![1.0, 0.0];
        let k_cache = vec![9.0; b * s * nh * dh];
        let v_cache = vec![9.0; b * s * nh * dh];
        let k_new = vec![0.0, 0.0];
        let v_new = vec![7.0, -2.0];
        let out = head_attention(&q, &k_cache, &v_cache, &k_new, &v_new, &[0], b, s, nh, dh, 0);
        assert_eq!(out, vec![7.0, -2.0]);
    }
}
