//! Block-isolated baseline dataflow — paper §2.2, Fig. 3.
//!
//! The execution model of existing frameworks (SGLang/vLLM/TRT-LLM/MLC):
//! thread blocks are independent units, inter-block dependencies are
//! resolved by materialising intermediates to *global memory* across
//! kernel boundaries:
//!
//! 1. **QKV Projection** kernel — writes Q/K/V to HBM;
//! 2. **Attention** kernel (FlashDecoding) — each block computes a partial
//!    over a KV segment, writes partials + softmax stats to HBM;
//! 3. **Rescale** kernel — combines the partials (the "separate rescaling
//!    kernel" of §2.2);
//! 4. **Output Projection** kernel — reads the attention output from HBM.
//!
//! Four launches, three HBM round-trips of intermediates, and three
//! device-wide synchronisation barriers per layer: exactly the
//! fragmentation the paper's Fig. 12 quantifies.

use crate::clustersim::kernelmodel::{kernel_cost, KernelSpec};
use crate::util::linalg;
use crate::util::pool::Pool;

use super::reference::{gemm_acc, AttnOut};
use super::{occupancy_mem_time, AttnProblem, CostEnv, CostReport, ELEM};

/// Number of KV segments FlashDecoding splits each head's cache into
/// (fixed split count; partials are combined by the rescale kernel).
pub const FLASH_SPLITS: usize = 4;

/// Functional execution of the baseline pipeline. Intermediates go through
/// explicit staging vectors playing the role of global memory; numerics
/// must equal [`super::reference::attention_block_ref`].
#[allow(clippy::too_many_arguments)]
pub fn execute(
    hidden: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
) -> (AttnOut, CostReport) {
    execute_on(
        &Pool::serial(),
        hidden,
        wq,
        wk,
        wv,
        wo,
        k_cache,
        v_cache,
        pos,
        b,
        d,
        nh,
        dh,
        s,
    )
}

/// [`execute`] on a worker [`Pool`]: the FlashDecoding kernel (K2) fans
/// **one flattened heads×splits task grid** across the pool (task `idx`
/// = head `idx / FLASH_SPLITS`, split `idx % FLASH_SPLITS` — the same
/// (head, split) blocks a real grid launch would schedule), and the
/// rescale kernel (K3) fans one task per head. The projection kernels
/// (K1/K4) keep the seed's row-major `gemm_acc` walk serially. Each
/// task's arithmetic is unchanged and results land by per-task copy in
/// ascending grid order, so the output is byte-identical to the serial
/// path at every pool size (`tests/integration_parallel.rs`).
#[allow(clippy::too_many_arguments)]
pub fn execute_on(
    pool: &Pool,
    hidden: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
) -> (AttnOut, CostReport) {
    let h = nh * dh;
    let mut report = CostReport::default();

    // ---- Kernel 1: QKV projection -> GLOBAL MEMORY ----
    let mut q_gmem = vec![0f32; b * h];
    let mut k_gmem = vec![0f32; b * h];
    let mut v_gmem = vec![0f32; b * h];
    gemm_acc(hidden, wq, &mut q_gmem, b, d, h);
    gemm_acc(hidden, wk, &mut k_gmem, b, d, h);
    gemm_acc(hidden, wv, &mut v_gmem, b, d, h);
    report.launches += 1;
    report.hbm_bytes += 3.0 * (b * h) as f64 * ELEM; // intermediate writes

    // ---- Kernel 2: FlashDecoding partials -> GLOBAL MEMORY ----
    // One block per (head, split); partial accumulators + (m, l) stats.
    // One task per (head, split) on the flattened grid, each owning its
    // B-sized region of the partial arrays.
    let scale = 1.0 / (dh as f32).sqrt();
    let seg = s.div_ceil(FLASH_SPLITS);
    type BlockPartials = (Vec<f32>, Vec<f32>, Vec<f32>);
    let grid_parts: Vec<BlockPartials> = pool.run_map(nh * FLASH_SPLITS, |idx| {
        let (head, sp) = (idx / FLASH_SPLITS, idx % FLASH_SPLITS);
        let mut acc_b = vec![0f32; b * dh];
        let mut m_b = vec![f32::NEG_INFINITY; b];
        let mut l_b = vec![0f32; b];
        for bi in 0..b {
            let valid = pos[bi];
            let lo = sp * seg;
            let hi = ((sp + 1) * seg).min(valid);
            let qrow = &q_gmem[bi * h + head * dh..bi * h + (head + 1) * dh];
            let mut m = f32::NEG_INFINITY;
            let mut scores = Vec::new();
            // token-tiled score scan (4 in-order chains per step)
            let row_at = |t: usize| {
                let base = ((bi * s + t) * nh + head) * dh;
                &k_cache[base..base + dh]
            };
            let end = hi.max(lo);
            let mut t = lo;
            while t + 4 <= end {
                let d4 =
                    linalg::dot4(qrow, row_at(t), row_at(t + 1), row_at(t + 2), row_at(t + 3));
                for (k, dv) in d4.iter().enumerate() {
                    let sc = dv * scale;
                    m = m.max(sc);
                    scores.push((t + k, sc));
                }
                t += 4;
            }
            while t < end {
                let sc = linalg::dot(qrow, row_at(t)) * scale;
                m = m.max(sc);
                scores.push((t, sc));
                t += 1;
            }
            // the freshly projected token is handled by the last split
            if sp == FLASH_SPLITS - 1 {
                let sc = linalg::dot(
                    qrow,
                    &k_gmem[bi * h + head * dh..bi * h + (head + 1) * dh],
                ) * scale;
                m = m.max(sc);
                scores.push((usize::MAX, sc));
            }
            if m == f32::NEG_INFINITY {
                continue;
            }
            let mut l = 0f32;
            let acc = &mut acc_b[bi * dh..(bi + 1) * dh];
            for (t, sc) in scores {
                let p = (sc - m).exp();
                l += p;
                let vrow = if t == usize::MAX {
                    &v_gmem[bi * h + head * dh..bi * h + (head + 1) * dh]
                } else {
                    &v_cache
                        [((bi * s + t) * nh + head) * dh..((bi * s + t) * nh + head) * dh + dh]
                };
                linalg::axpy(p, vrow, acc);
            }
            m_b[bi] = m;
            l_b[bi] = l;
        }
        (acc_b, m_b, l_b)
    });
    // Assemble the flat global-memory partial arrays — ascending grid
    // order is exactly the blk = head * FLASH_SPLITS + sp layout.
    let mut part_acc = Vec::with_capacity(nh * FLASH_SPLITS * b * dh);
    let mut part_m = Vec::with_capacity(nh * FLASH_SPLITS * b);
    let mut part_l = Vec::with_capacity(nh * FLASH_SPLITS * b);
    for (acc_b, m_b, l_b) in &grid_parts {
        part_acc.extend_from_slice(acc_b);
        part_m.extend_from_slice(m_b);
        part_l.extend_from_slice(l_b);
    }
    report.launches += 1;
    report.hbm_bytes += (nh * FLASH_SPLITS * b) as f64 * (dh as f64 * ELEM + 2.0 * 4.0);

    // ---- Kernel 3: rescale / combine partials -> GLOBAL MEMORY ----
    // One pool task per head; results copied into the strided (B, H)
    // attention layout serially.
    let attn_heads: Vec<Vec<f32>> = pool.run_map(nh, |head| {
        let mut attn_h = vec![0f32; b * dh];
        for bi in 0..b {
            let mut m = f32::NEG_INFINITY;
            for sp in 0..FLASH_SPLITS {
                m = m.max(part_m[(head * FLASH_SPLITS + sp) * b + bi]);
            }
            let mut l = 0f32;
            let out = &mut attn_h[bi * dh..(bi + 1) * dh];
            for sp in 0..FLASH_SPLITS {
                let blk = head * FLASH_SPLITS + sp;
                let pm = part_m[blk * b + bi];
                if pm == f32::NEG_INFINITY {
                    continue;
                }
                let alpha = (pm - m).exp();
                l += part_l[blk * b + bi] * alpha;
                linalg::axpy(alpha, &part_acc[(blk * b + bi) * dh..(blk * b + bi + 1) * dh], out);
            }
            for o in out.iter_mut() {
                *o /= l;
            }
        }
        attn_h
    });
    let mut attn_gmem = vec![0f32; b * h];
    for (head, attn_h) in attn_heads.iter().enumerate() {
        for bi in 0..b {
            attn_gmem[bi * h + head * dh..bi * h + (head + 1) * dh]
                .copy_from_slice(&attn_h[bi * dh..(bi + 1) * dh]);
        }
    }
    report.launches += 1;
    report.hbm_bytes += (b * h) as f64 * ELEM
        + (nh * FLASH_SPLITS * b) as f64 * (dh as f64 * ELEM + 2.0 * 4.0);

    // ---- Kernel 4: output projection ----
    let mut out = vec![0f32; b * d];
    gemm_acc(&attn_gmem, wo, &mut out, b, h, d);
    report.launches += 1;
    report.hbm_bytes += (b * h) as f64 * ELEM; // re-read the attention output

    (AttnOut { out, k_new: k_gmem, v_new: v_gmem }, report)
}

/// Performance model of the four-kernel baseline pipeline.
///
/// `bw_efficiency` (from [`CostEnv`]) models the framework's achieved
/// bandwidth on short bs=1 decode kernels — the headroom the paper's
/// hand-fused kernel recovers (Fig. 18's per-framework gap).
pub fn cost(p: &AttnProblem, env: &CostEnv) -> CostReport {
    let hw = env.hw;
    let (b, d, h) = (p.batch as f64, p.d_model as f64, p.total_head_dim() as f64);
    let s = p.seq as f64;
    let mut rep = CostReport::default();

    let blocks = p.n_heads * FLASH_SPLITS;
    let active = env.noc.active_sms(1);

    // K1: QKV projection (weights + hidden in, QKV out)
    let k1_bytes = (d * 3.0 * h + b * d + 3.0 * b * h) * ELEM;
    let k1 = KernelSpec::new(2.0 * b * d * 3.0 * h, 0.0);
    let t1 = occupancy_mem_time(k1_bytes, p.n_heads * 4, active, hw) / env.bw_efficiency;
    rep.stage("qkv-proj", t1.max(hw.compute_time(k1.flops)) + hw.graph_kernel_launch + hw.kernel_boundary_sync);

    // K2: FlashDecoding partials (KV cache + Q in, partials out)
    let part_bytes = blocks as f64 * b * (p.head_dim as f64 * ELEM + 8.0);
    let k2_bytes = (b * s * 2.0 * h + 4.0 * b * h) * ELEM + part_bytes;
    let k2_flops = 4.0 * b * h * (s + 1.0);
    let t2 = occupancy_mem_time(k2_bytes, blocks, active, hw) / env.bw_efficiency;
    rep.stage("flash-decode", t2.max(hw.compute_time(k2_flops)) + hw.graph_kernel_launch + hw.kernel_boundary_sync);

    // K3: rescale (partials in, attention out)
    let k3_bytes = part_bytes + b * h * ELEM;
    let t3 = occupancy_mem_time(k3_bytes, p.n_heads, active, hw) / env.bw_efficiency;
    rep.stage("rescale", t3 + hw.graph_kernel_launch + hw.kernel_boundary_sync);

    // K4: output projection (weights + attention in, hidden out)
    let k4_bytes = (h * d + b * h + b * d) * ELEM;
    let t4 = occupancy_mem_time(k4_bytes, p.n_heads * 4, active, hw) / env.bw_efficiency;
    rep.stage("out-proj", t4.max(hw.compute_time(2.0 * b * h * d)) + hw.graph_kernel_launch + hw.kernel_boundary_sync);

    rep.launches = 4;
    rep.hbm_bytes = k1_bytes + k2_bytes + k3_bytes + k4_bytes;
    let _ = kernel_cost(&k1, hw); // spec retained for the criterion hot-path bench
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustersim::dataflow::reference::attention_block_ref;
    use crate::clustersim::dataflow::testutil::{assert_close, mha_case};
    use crate::clustersim::{Hardware, Noc};

    #[test]
    fn matches_reference() {
        let c = mha_case(3, 2, 3, 8, 20, 24);
        let r = attention_block_ref(
            &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
            c.batch, c.d_model, c.n_heads, c.head_dim, c.seq,
        );
        let (got, rep) = execute(
            &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
            c.batch, c.d_model, c.n_heads, c.head_dim, c.seq,
        );
        assert_close(&got.out, &r.out, 1e-4, "out");
        assert_close(&got.k_new, &r.k_new, 1e-4, "k_new");
        assert_eq!(rep.launches, 4);
        assert!(rep.hbm_bytes > 0.0);
    }

    #[test]
    fn baseline_moves_more_hbm_and_launches_more_than_fused() {
        // Fig. 12's direction: intermediates + 4 launches vs 1.
        let hw = Hardware::h100_sxm5();
        let noc = Noc::h100(&hw);
        let p = AttnProblem {
            batch: 1, d_model: 4096, n_heads: 32, head_dim: 128, seq: 4096, kv_lora_rank: 0,
        };
        let env = CostEnv::clusterfusion(&hw, &noc, 4);
        let base = cost(&p, &env);
        let fused = super::super::split_token::cost(&p, &env);
        assert!(base.launches > fused.launches);
        assert!(base.hbm_bytes > fused.hbm_bytes);
        assert!(base.latency > fused.latency);
    }

    #[test]
    fn empty_cache_is_fine() {
        let mut c = mha_case(4, 2, 2, 4, 8, 8);
        c.pos = vec![0, 0];
        let r = attention_block_ref(
            &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
            c.batch, c.d_model, c.n_heads, c.head_dim, c.seq,
        );
        let (got, _) = execute(
            &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
            c.batch, c.d_model, c.n_heads, c.head_dim, c.seq,
        );
        assert_close(&got.out, &r.out, 1e-4, "out");
    }
}
