//! Fused MLA dataflow — paper Alg. 4 / Appendix B.1, cluster-centric
//! DeepSeek Multi-head Latent Attention in its weight-absorbed decode form.
//!
//! One cluster per query head (the latent KV cache is MQA-shared). Within
//! a cluster the N blocks partition
//!
//! * the lora rank for the absorbed *Q Projection* and the *KV Projection*
//!   (segments assembled with `ClusterGather`);
//! * the latent-cache token dimension for *Attention* (FlashDecoding
//!   partials + `ClusterReduce` of stats and of the (B, l) output);
//! * the lora rank again for the *Down Projection*
//!   (`ClusterReduce(sum)` of the (B, dh) partial);
//! * the output dimension for the *Output Projection* (atomicAdd).
//!
//! Note: the paper's Alg. 4 gathers Q twice (before and after the Up
//! Projection). Our weight-absorbed `wq` folds W_Q·W_Up into one matrix, so
//! the functional path needs a single Q gather; the *cost* model still
//! charges the paper's schedule (Gather(h) + 2·Gather(l)) for fidelity to
//! the analytical traffic model it reports.

use crate::clustersim::collective::{
    cluster_gather, cluster_reduce, gather_cost, gathered_segment, reduce_cost, ReduceOp,
    Transport,
};
use crate::clustersim::hw::Hardware;
use crate::clustersim::noc::Noc;
use crate::util::linalg;
use crate::util::pool::Pool;

use super::reference::AttnOut;
use super::{
    occupancy_mem_time, AttnProblem, CostEnv, CostReport, PackedMlaWeights, ELEM, PHASE_SETUP,
};

/// Functional execution of the fused MLA dataflow. Requires
/// `l % n == 0`, `s % n == 0`, `d % n == 0`.
///
/// Hot path: `wq`/`wkv`/`wo` are packed once ([`PackedMlaWeights`]) and
/// reused across heads/blocks; `w_down` is already row-contiguous and
/// stays on `linalg::axpy`. Per-output accumulation order is the seed's,
/// so the result is byte-identical to the frozen scalar copy
/// (`tests/integration_bitexact.rs`).
#[allow(clippy::too_many_arguments)]
pub fn execute(
    hidden: &[f32],
    wq: &[f32],       // (D, nh*l)
    wkv: &[f32],      // (D, l)
    w_down: &[f32],   // (nh, l, dh)
    wo: &[f32],       // (nh*dh, D)
    kv_cache: &[f32], // (B, S, l)
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    l: usize,
    dh: usize,
    s: usize,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> (AttnOut, CostReport) {
    // One-shot convenience; sweeps pack once and call [`execute_packed`].
    let weights = PackedMlaWeights::pack(wq, wkv, wo, d, nh, l, dh);
    execute_packed(
        hidden, &weights, w_down, kv_cache, pos, b, d, nh, l, dh, s, n, transport, hw, noc,
    )
}

/// [`execute`] with `wq`/`wkv`/`wo` already packed (`w_down` stays
/// row-major — its accesses are row-contiguous). Numerics identical.
#[allow(clippy::too_many_arguments)]
pub fn execute_packed(
    hidden: &[f32],
    weights: &PackedMlaWeights,
    w_down: &[f32],   // (nh, l, dh)
    kv_cache: &[f32], // (B, S, l)
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    l: usize,
    dh: usize,
    s: usize,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> (AttnOut, CostReport) {
    execute_packed_on(
        &Pool::serial(),
        hidden,
        weights,
        w_down,
        kv_cache,
        pos,
        b,
        d,
        nh,
        l,
        dh,
        s,
        n,
        transport,
        hw,
        noc,
    )
}

/// [`execute_packed`] on a worker [`Pool`]: each block-parallel phase of
/// Alg. 4 — the KV/Q projection segments, the FlashDecoding partials
/// over the latent-cache spans, the down-projection partials over the
/// lora-rank slices and the output-projection column tiles — fans **one
/// flattened heads×blocks task grid** across the pool (the shared KV
/// projection is one `n`-task dispatch): five dispatches per call
/// instead of `4·nh + 1`. The collectives and the atomicAdd merge stay
/// serial, heads ascending, in the serial code's order. Byte-identical
/// to the serial path at every pool size
/// (`tests/integration_parallel.rs`).
#[allow(clippy::too_many_arguments)]
pub fn execute_packed_on(
    pool: &Pool,
    hidden: &[f32],
    weights: &PackedMlaWeights,
    w_down: &[f32],   // (nh, l, dh)
    kv_cache: &[f32], // (B, S, l)
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    l: usize,
    dh: usize,
    s: usize,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> (AttnOut, CostReport) {
    assert!(l % n == 0 && s % n == 0 && d % n == 0, "cluster must divide l, S, D");
    let ls = l / n; // per-block lora-rank slice
    let scale = 1.0 / (l as f32).sqrt();

    let mut out = vec![0f32; b * d];
    let mut kv_new_g = vec![0f32; b * l];
    let mut report = CostReport { launches: 1, ..Default::default() };

    let (wq_p, wkv_p, wo_p) = (&weights.wq, &weights.wkv, &weights.wo);
    assert!(wq_p.n_in() == d && wq_p.n_out() == nh * l && wo_p.n_out() == d);

    // ---- KV Projection segments + gather (shared by all heads; computed
    // by the first cluster, broadcast via the latent cache write); one
    // pool task per cluster block ----
    let kv_segs: Vec<Vec<f32>> = pool.run_map(n, |r| {
        let mut seg = vec![0f32; b * ls];
        linalg::matmul_rows(hidden, b, d, wkv_p, 0, r * ls, ls, &mut seg);
        seg
    });
    let (kv_gathered, gc_kv) = cluster_gather(&kv_segs, transport, hw, noc);
    report.dsmem_bytes += gc_kv.traffic_bytes;
    let mut kv_new = vec![0f32; b * l];
    for r in 0..n {
        let seg = gathered_segment(&kv_gathered[0], 0, r, n, b * ls);
        for bi in 0..b {
            kv_new[bi * l + r * ls..bi * l + (r + 1) * ls]
                .copy_from_slice(&seg[bi * ls..(bi + 1) * ls]);
        }
    }
    kv_new_g.copy_from_slice(&kv_new);

    // ---- absorbed Q projection segments, one task per (head, cluster
    // block) on the flattened grid; gathers serial per head ----
    let lb = b * l;
    let q_segs: Vec<Vec<f32>> = pool.run_map(nh * n, |idx| {
        let (head, r) = (idx / n, idx % n);
        let mut seg = vec![0f32; b * ls];
        linalg::matmul_rows(hidden, b, d, wq_p, 0, head * l + r * ls, ls, &mut seg);
        seg
    });
    let mut q_all = vec![0f32; nh * lb];
    for head in 0..nh {
        let head_segs = &q_segs[head * n..(head + 1) * n];
        let (q_gathered, gc_q) = cluster_gather(head_segs, transport, hw, noc);
        report.dsmem_bytes += gc_q.traffic_bytes;
        let q = &mut q_all[head * lb..(head + 1) * lb];
        for r in 0..n {
            let seg = gathered_segment(&q_gathered[0], 0, r, n, b * ls);
            for bi in 0..b {
                q[bi * l + r * ls..bi * l + (r + 1) * ls]
                    .copy_from_slice(&seg[bi * ls..(bi + 1) * ls]);
            }
        }
    }

    // ---- FlashDecoding partials through the output merge for every
    // head at once: the shared attention core ----
    attend_heads_on(
        pool, &q_all, &kv_new, kv_cache, pos, b, d, nh, l, dh, s, n, w_down, wo_p, scale,
        transport, hw, noc, &mut out, &mut report,
    );

    (AttnOut { out, k_new: kv_new_g, v_new: vec![] }, report)
}

/// The post-gather attention core of **every MLA head's** cluster
/// schedule — FlashDecoding partials over the latent-cache spans, the
/// three stat reduces with the online-softmax rescale, the
/// down-projection partials over the lora-rank slices with their
/// `ClusterReduce(sum)`, and the output-projection tiles merged into
/// `out` in the serial `(head, r, bi)` order.
///
/// Coalesced fan-out (DESIGN.md §Parallel): each block-parallel phase
/// dispatches **once over the flattened heads×blocks task grid** (task
/// `idx` = head `idx / n`, block `idx % n`) — 3 dispatches here instead
/// of `3·nh` — with the per-task arithmetic the per-head loop body
/// unchanged and every serial merge (collectives included) walking heads
/// in ascending order, so results are byte-identical to the per-head
/// dispatch structure at every pool size (see
/// `split_token::attend_heads_on` for the bit-exactness argument); the
/// multi-position prefill path calls it with `b == 1` per prompt row.
///
/// `q` holds the assembled `(nh, b, l)` head-major rows; `kv_new` is the
/// `(b, l)` shared latent row (MQA: one latent cache for all heads);
/// `kv_cache` is the `(b, s, l)` dense latent plane.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_heads_on(
    pool: &Pool,
    q: &[f32],
    kv_new: &[f32],
    kv_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    l: usize,
    dh: usize,
    s: usize,
    n: usize,
    w_down: &[f32],
    wo_p: &linalg::PackedWeight,
    scale: f32,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
    out: &mut [f32],
    report: &mut CostReport,
) {
    let (ls, ss, ds) = (l / n, s / n, d / n);
    let lb = b * l; // one head's (b, l) plane in q
    {
        // ---- FlashDecoding partials over latent-cache spans, one task
        // per (head, cluster block) on the flattened grid ----
        let partials: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = pool.run_map(nh * n, |idx| {
            let (head, r) = (idx / n, idx % n);
            let qh = &q[head * lb..(head + 1) * lb];
            let mut m_row = vec![f32::NEG_INFINITY; b];
            let mut l_row = vec![0f32; b];
            let mut acc_row = vec![0f32; b * l];
            let mut scores: Vec<(usize, f32)> = Vec::new();
            for bi in 0..b {
                let valid = pos[bi];
                let lo = r * ss;
                let hi = ((r + 1) * ss).min(valid);
                let qrow = &qh[bi * l..(bi + 1) * l];
                scores.clear();
                // token-tiled score scan (4 independent in-order chains)
                let row_at = |t: usize| {
                    let base = (bi * s + t) * l;
                    &kv_cache[base..base + l]
                };
                let end = hi.max(lo);
                let mut t = lo;
                while t + 4 <= end {
                    let d4 =
                        linalg::dot4(qrow, row_at(t), row_at(t + 1), row_at(t + 2), row_at(t + 3));
                    for (k, dv) in d4.iter().enumerate() {
                        scores.push((t + k, dv * scale));
                    }
                    t += 4;
                }
                while t < end {
                    scores.push((t, linalg::dot(qrow, row_at(t)) * scale));
                    t += 1;
                }
                let self_here = r == n - 1;
                let self_score = if self_here {
                    Some(linalg::dot(qrow, &kv_new[bi * l..(bi + 1) * l]) * scale)
                } else {
                    None
                };
                let mut m = f32::NEG_INFINITY;
                for (_, sc) in &scores {
                    m = m.max(*sc);
                }
                if let Some(sc) = self_score {
                    m = m.max(sc);
                }
                if m == f32::NEG_INFINITY {
                    continue;
                }
                let mut lsum = 0f32;
                let acc = &mut acc_row[bi * l..(bi + 1) * l];
                for (t, sc) in &scores {
                    let p = (sc - m).exp();
                    lsum += p;
                    let base = (bi * s + t) * l;
                    linalg::axpy(p, &kv_cache[base..base + l], acc);
                }
                if let Some(sc) = self_score {
                    let p = (sc - m).exp();
                    lsum += p;
                    linalg::axpy(p, &kv_new[bi * l..(bi + 1) * l], acc);
                }
                m_row[bi] = m;
                l_row[bi] = lsum;
            }
            (m_row, l_row, acc_row)
        });

        // ---- stats + output reduces, serial per head in ascending
        // order; each head's normalised attention row lands in the
        // (nh, b, l) head-major scratch the down projection reads ----
        let mut parts = partials.into_iter();
        let mut attn_all = vec![0f32; nh * lb];
        for head in 0..nh {
            let mut m_bufs: Vec<Vec<f32>> = Vec::with_capacity(n);
            let mut l_bufs: Vec<Vec<f32>> = Vec::with_capacity(n);
            let mut acc_bufs: Vec<Vec<f32>> = Vec::with_capacity(n);
            for _ in 0..n {
                let (m_row, l_row, acc_row) = parts.next().expect("one task per (head, block)");
                m_bufs.push(m_row);
                l_bufs.push(l_row);
                acc_bufs.push(acc_row);
            }
            let m_local = m_bufs.clone();
            let rc1 = cluster_reduce(&mut m_bufs, ReduceOp::Max, transport, hw, noc);
            for r in 0..n {
                for bi in 0..b {
                    let alpha = if m_local[r][bi] == f32::NEG_INFINITY {
                        0.0
                    } else {
                        (m_local[r][bi] - m_bufs[r][bi]).exp()
                    };
                    l_bufs[r][bi] *= alpha;
                    linalg::scale(alpha, &mut acc_bufs[r][bi * l..(bi + 1) * l]);
                }
            }
            let rc2 = cluster_reduce(&mut l_bufs, ReduceOp::Sum, transport, hw, noc);
            let rc3 = cluster_reduce(&mut acc_bufs, ReduceOp::Sum, transport, hw, noc);
            report.dsmem_bytes += rc1.traffic_bytes + rc2.traffic_bytes + rc3.traffic_bytes;

            // normalised attention output (identical in every block now)
            let attn = &mut attn_all[head * lb..(head + 1) * lb];
            for bi in 0..b {
                linalg::scale_div(
                    &acc_bufs[0][bi * l..(bi + 1) * l],
                    l_bufs[0][bi],
                    &mut attn[bi * l..(bi + 1) * l],
                );
            }
        }

        // ---- Down Projection: blocks partition the lora rank; partial
        // (B, dh) results combined with ClusterReduce(sum); one task per
        // (head, cluster block) on the flattened grid, reduces serial
        // per head in ascending order ----
        let z_raw: Vec<Vec<f32>> = pool.run_map(nh * n, |idx| {
            let (head, r) = (idx / n, idx % n);
            let attn = &attn_all[head * lb..(head + 1) * lb];
            let mut z = vec![0f32; b * dh];
            for bi in 0..b {
                for j in 0..ls {
                    let av = attn[bi * l + r * ls + j];
                    let wrow = &w_down
                        [head * l * dh + (r * ls + j) * dh..head * l * dh + (r * ls + j + 1) * dh];
                    linalg::axpy(av, wrow, &mut z[bi * dh..(bi + 1) * dh]);
                }
            }
            z
        });
        let mut z_iter = z_raw.into_iter();
        let mut z_heads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(nh);
        for _head in 0..nh {
            let mut z_bufs: Vec<Vec<f32>> = Vec::with_capacity(n);
            for _ in 0..n {
                z_bufs.push(z_iter.next().expect("one task per (head, block)"));
            }
            let rc4 = cluster_reduce(&mut z_bufs, ReduceOp::Sum, transport, hw, noc);
            report.dsmem_bytes += rc4.traffic_bytes;
            z_heads.push(z_bufs);
        }

        // ---- Output Projection tiles + atomicAdd: task (head, r)
        // computes its [r*ds, (r+1)*ds) column tile on the flattened
        // grid; the merge adds each tile element once, in the serial
        // (head, r, bi, j) order ----
        let tiles: Vec<Vec<f32>> = pool.run_map(nh * n, |idx| {
            let (head, r) = (idx / n, idx % n);
            let z_bufs = &z_heads[head];
            let mut tile = vec![0f32; b * ds];
            for bi in 0..b {
                linalg::matmul_rows(
                    &z_bufs[r][bi * dh..(bi + 1) * dh],
                    1,
                    dh,
                    wo_p,
                    head * dh,
                    r * ds,
                    ds,
                    &mut tile[bi * ds..(bi + 1) * ds],
                );
            }
            tile
        });
        for (idx, tile) in tiles.iter().enumerate() {
            let r = idx % n;
            for bi in 0..b {
                let dst = &mut out[bi * d + r * ds..bi * d + (r + 1) * ds];
                linalg::axpy(1.0, &tile[bi * ds..(bi + 1) * ds], dst);
            }
        }
    }
}

/// Multi-position (prefill) execution of the fused MLA schedule: `hidden`
/// holds `T` prompt rows, row `j` belonging to latent-plane slot
/// `row_slot[j]` at absolute position `row_pos[j]`. The shared KV
/// projection batches all `T` rows and **writes the new latent rows into
/// the mutable plane** at their positions (so later chunk rows attend to
/// earlier ones); each head then batches its absorbed Q projection over
/// the chunk and runs causal attention per row through
/// [`attend_heads_on`] with `b == 1` and `valid = row_pos[j]` — the
/// byte-identical decode core. `kv_plane` is `(bucket, s, l)`. Returns
/// `(T, d)` output and the `(T, l)` latent rows in feed order (`k_new`;
/// `v_new` stays empty, the latent cache is single-plane).
#[allow(clippy::too_many_arguments)]
pub fn prefill_packed_on(
    pool: &Pool,
    hidden: &[f32],
    weights: &PackedMlaWeights,
    w_down: &[f32], // (nh, l, dh)
    kv_plane: &mut [f32],
    row_slot: &[usize],
    row_pos: &[usize],
    d: usize,
    nh: usize,
    l: usize,
    dh: usize,
    s: usize,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> (AttnOut, CostReport) {
    assert!(l % n == 0 && s % n == 0 && d % n == 0, "cluster must divide l, S, D");
    let t_rows = row_slot.len();
    assert_eq!(row_pos.len(), t_rows);
    let ls = l / n;
    let scale = 1.0 / (l as f32).sqrt();

    let mut out = vec![0f32; t_rows * d];
    let mut report = CostReport { launches: 1, ..Default::default() };

    let (wq_p, wkv_p, wo_p) = (&weights.wq, &weights.wkv, &weights.wo);
    assert!(wq_p.n_in() == d && wq_p.n_out() == nh * l && wo_p.n_out() == d);

    // ---- shared KV projection over all T rows + plane write (before
    // any attention: rows of this chunk must see each other) ----
    let kv_segs: Vec<Vec<f32>> = pool.run_map(n, |r| {
        let mut seg = vec![0f32; t_rows * ls];
        linalg::matmul_rows(hidden, t_rows, d, wkv_p, 0, r * ls, ls, &mut seg);
        seg
    });
    let (kv_gathered, gc_kv) = cluster_gather(&kv_segs, transport, hw, noc);
    report.dsmem_bytes += gc_kv.traffic_bytes;
    let mut kv_new = vec![0f32; t_rows * l];
    for r in 0..n {
        let seg = gathered_segment(&kv_gathered[0], 0, r, n, t_rows * ls);
        for j in 0..t_rows {
            kv_new[j * l + r * ls..j * l + (r + 1) * ls]
                .copy_from_slice(&seg[j * ls..(j + 1) * ls]);
        }
    }
    for j in 0..t_rows {
        let dst = (row_slot[j] * s + row_pos[j]) * l;
        kv_plane[dst..dst + l].copy_from_slice(&kv_new[j * l..(j + 1) * l]);
    }

    // absorbed Q projection batched over the chunk, one task per
    // (head, cluster block) on the flattened grid; gathers serial per
    // head in ascending order
    let q_segs: Vec<Vec<f32>> = pool.run_map(nh * n, |idx| {
        let (head, r) = (idx / n, idx % n);
        let mut seg = vec![0f32; t_rows * ls];
        linalg::matmul_rows(hidden, t_rows, d, wq_p, 0, head * l + r * ls, ls, &mut seg);
        seg
    });
    let mut q_all = vec![0f32; nh * t_rows * l]; // (nh, t_rows, l)
    for head in 0..nh {
        let head_segs = &q_segs[head * n..(head + 1) * n];
        let (q_gathered, gc_q) = cluster_gather(head_segs, transport, hw, noc);
        report.dsmem_bytes += gc_q.traffic_bytes;
        let q = &mut q_all[head * t_rows * l..(head + 1) * t_rows * l];
        for r in 0..n {
            let seg = gathered_segment(&q_gathered[0], 0, r, n, t_rows * ls);
            for j in 0..t_rows {
                q[j * l + r * ls..j * l + (r + 1) * ls]
                    .copy_from_slice(&seg[j * ls..(j + 1) * ls]);
            }
        }
    }

    // causal attention per row (serial in feed order), all heads of a
    // row through one coalesced core call; the copy into the per-row
    // (nh, 1, l) head-major buffer is pure data movement
    let mut q_row = vec![0f32; nh * l];
    for j in 0..t_rows {
        let slot = row_slot[j];
        let kc = &kv_plane[slot * s * l..(slot + 1) * s * l];
        let pos_j = [row_pos[j]];
        for head in 0..nh {
            q_row[head * l..(head + 1) * l]
                .copy_from_slice(&q_all[head * t_rows * l + j * l..head * t_rows * l + (j + 1) * l]);
        }
        attend_heads_on(
            pool,
            &q_row,
            &kv_new[j * l..(j + 1) * l],
            kc,
            &pos_j,
            1,
            d,
            nh,
            l,
            dh,
            s,
            n,
            w_down,
            wo_p,
            scale,
            transport,
            hw,
            noc,
            &mut out[j * d..(j + 1) * d],
            &mut report,
        );
    }

    (AttnOut { out, k_new: kv_new, v_new: vec![] }, report)
}

/// Performance model of the fused MLA kernel — the paper's collective
/// schedule: Gather(h) + 2·Gather(l), Reduce(l) + Reduce(H) (+ stats).
pub fn cost(p: &AttnProblem, env: &CostEnv) -> CostReport {
    assert!(p.kv_lora_rank > 0, "MLA cost needs kv_lora_rank");
    let n = env.cluster_size;
    let (hw, noc) = (env.hw, env.noc);
    let mut rep = CostReport { launches: 1, ..Default::default() };

    let blocks = p.n_heads * n;
    let active = noc.active_sms(n);
    let bytes = p.mandatory_bytes_mla();
    rep.hbm_bytes = bytes;

    let t_mem = occupancy_mem_time(bytes, blocks, active, hw) / env.bw_efficiency;
    let t_compute = hw.compute_time(p.flops_mla());
    rep.stage("fused-mem/compute", t_mem.max(t_compute));

    let bh = p.batch as f64;
    let l = p.kv_lora_rank as f64;
    let g_h = gather_cost((p.head_dim / n) as f64 * bh * ELEM, n, env.transport, hw, noc);
    let g_l = gather_cost(l / n as f64 * bh * ELEM, n, env.transport, hw, noc);
    let r_l = reduce_cost(l * bh * ELEM, n, env.transport, hw, noc);
    let r_h = reduce_cost(p.head_dim as f64 * bh * ELEM, n, env.transport, hw, noc);
    let r_stats = reduce_cost(2.0 * bh * 4.0, n, env.transport, hw, noc);
    rep.stage(
        "collectives",
        g_h.latency + 2.0 * g_l.latency + r_l.latency + r_h.latency + r_stats.latency,
    );
    rep.dsmem_bytes = (g_h.traffic_bytes
        + 2.0 * g_l.traffic_bytes
        + r_l.traffic_bytes
        + r_h.traffic_bytes
        + r_stats.traffic_bytes)
        * p.n_heads as f64;
    if env.transport == Transport::Dsmem {
        rep.stage("dsmem-contention", rep.dsmem_bytes / noc.bandwidth(n));
    }
    if env.transport == Transport::GlobalMemory {
        // grid-wide software barriers replace the cluster-scoped ones
        let rounds = g_h.rounds + 2 * g_l.rounds + r_l.rounds + r_h.rounds + r_stats.rounds;
        rep.stage(
            "gmem-grid-barriers",
            rounds as f64 * super::GMEM_BARRIER_PER_BLOCK * blocks as f64,
        );
    }


    rep.stage("phase-setup", 4.0 * PHASE_SETUP / (n.min(2) as f64));
    rep.stage("launch", hw.graph_kernel_launch);
    rep
}

/// Baseline (block-isolated) cost for the MLA attention block: four
/// kernels with intermediates through HBM, mirroring
/// [`super::block_isolated::cost`] with MLA footprints.
pub fn cost_block_isolated(p: &AttnProblem, env: &CostEnv) -> CostReport {
    let hw = env.hw;
    let (b, d) = (p.batch as f64, p.d_model as f64);
    let (nh, dh, l) = (p.n_heads as f64, p.head_dim as f64, p.kv_lora_rank as f64);
    let s = p.seq as f64;
    let active = env.noc.active_sms(1);
    let mut rep = CostReport::default();

    // K1: Q + KV projections (absorbed weights + hidden in, Q/KV out)
    let k1_bytes = (d * nh * l + d * l + b * d + b * (nh * l + l)) * ELEM;
    let t1 = occupancy_mem_time(k1_bytes, p.n_heads * 4, active, hw) / env.bw_efficiency;
    rep.stage(
        "qkv-proj",
        t1.max(hw.compute_time(2.0 * b * d * (nh * l + l)))
            + hw.graph_kernel_launch
            + hw.kernel_boundary_sync,
    );

    // K2: attention over latent cache + partials
    let splits = super::block_isolated::FLASH_SPLITS as f64;
    let part_bytes = nh * splits * b * (l * ELEM + 8.0);
    let k2_bytes = (b * s * l + 2.0 * b * nh * l) * ELEM + part_bytes;
    let t2 = occupancy_mem_time(
        k2_bytes,
        p.n_heads * super::block_isolated::FLASH_SPLITS,
        active,
        hw,
    ) / env.bw_efficiency;
    rep.stage(
        "flash-decode",
        t2.max(hw.compute_time(4.0 * b * nh * l * (s + 1.0)))
            + hw.graph_kernel_launch
            + hw.kernel_boundary_sync,
    );

    // K3: rescale
    let k3_bytes = part_bytes + b * nh * l * ELEM;
    let t3 = occupancy_mem_time(k3_bytes, p.n_heads, active, hw) / env.bw_efficiency;
    rep.stage("rescale", t3 + hw.graph_kernel_launch + hw.kernel_boundary_sync);

    // K4: down + output projection
    let k4_bytes = (nh * l * dh + nh * dh * d + b * nh * l + b * d) * ELEM;
    let t4 = occupancy_mem_time(k4_bytes, p.n_heads * 4, active, hw) / env.bw_efficiency;
    rep.stage(
        "down-out-proj",
        t4.max(hw.compute_time(2.0 * b * nh * (l * dh + dh * d)))
            + hw.graph_kernel_launch
            + hw.kernel_boundary_sync,
    );

    rep.launches = 4;
    rep.hbm_bytes = k1_bytes + k2_bytes + k3_bytes + k4_bytes;
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustersim::dataflow::reference::mla_block_ref;
    use crate::clustersim::dataflow::testutil::{assert_close, mla_case};
    use crate::clustersim::{Hardware, Noc};

    fn env() -> (Hardware, Noc) {
        let hw = Hardware::h100_sxm5();
        let noc = Noc::h100(&hw);
        (hw, noc)
    }

    #[test]
    fn matches_reference_all_cluster_sizes() {
        let (hw, noc) = env();
        let c = mla_case(13, 2, 2, 16, 8, 16, 16);
        let r = mla_block_ref(
            &c.hidden, &c.wq, &c.wkv, &c.w_down, &c.wo, &c.kv_cache, &c.pos,
            c.batch, c.d_model, c.n_heads, c.lora, c.head_dim, c.seq,
        );
        for n in [1usize, 2, 4, 8] {
            let (got, rep) = execute(
                &c.hidden, &c.wq, &c.wkv, &c.w_down, &c.wo, &c.kv_cache, &c.pos,
                c.batch, c.d_model, c.n_heads, c.lora, c.head_dim, c.seq, n,
                Transport::Dsmem, &hw, &noc,
            );
            assert_close(&got.out, &r.out, 1e-4, &format!("out n={n}"));
            assert_close(&got.k_new, &r.k_new, 1e-4, "kv_new");
            assert_eq!(rep.launches, 1);
        }
    }

    #[test]
    fn fused_beats_block_isolated() {
        let (hw, noc) = env();
        let p = AttnProblem {
            batch: 1, d_model: 2048, n_heads: 16, head_dim: 128, seq: 4096, kv_lora_rank: 512,
        };
        let envc = CostEnv::clusterfusion(&hw, &noc, 4);
        let mut base_env = envc;
        base_env.bw_efficiency = 0.5; // framework-grade kernels
        let fused = cost(&p, &envc);
        let base = cost_block_isolated(&p, &base_env);
        assert!(fused.latency < base.latency);
        assert!(fused.launches < base.launches);
    }

    #[test]
    fn latent_cache_traffic_much_smaller_than_mha() {
        // MLA's point: the latent cache shrinks KV traffic vs MHA.
        let p = AttnProblem {
            batch: 1, d_model: 2048, n_heads: 16, head_dim: 128, seq: 8192, kv_lora_rank: 512,
        };
        let kv_mla = p.batch as f64 * p.seq as f64 * p.kv_lora_rank as f64 * ELEM;
        let kv_mha = p.batch as f64 * p.seq as f64 * 2.0 * p.total_head_dim() as f64 * ELEM;
        assert!(kv_mla < kv_mha / 4.0);
    }
}
