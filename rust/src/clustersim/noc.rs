//! SM-to-SM Network-on-Chip (DSMEM) model — paper §2.3 / Fig. 5.
//!
//! The paper profiles three quantities as a function of cluster size N on
//! an H100 and bases the whole dataflow design on their trade-off:
//!
//! * **latency** — improves dramatically for small clusters (190 cycles at
//!   N = 2, far below the > 470-cycle global-memory latency) and degrades
//!   as the crossbar spans more SMs;
//! * **bandwidth** — *decreases* with N because of the crossbar
//!   architecture, slightly lagging HBM at N = 16 (2.90 vs 2.96 TB/s);
//! * **active SMs** — drops at larger N due to scheduling granularity
//!   (clusters are gang-scheduled on GPCs), reducing parallelism.
//!
//! The anchor points below interpolate the paper's reported values; the
//! curves are monotone in the directions Fig. 5 shows. N must be a power
//! of two ≤ 16 (hardware maximum, paper §3.1).


use super::hw::Hardware;

/// Crossbar NoC characteristics per cluster size.
#[derive(Debug, Clone)]
pub struct Noc {
    /// (cluster_size, latency_cycles, aggregate_bw_bytes_per_s, active_sms)
    /// anchor table; queried by exact cluster size.
    anchors: Vec<(usize, f64, f64, usize)>,
    clock_ghz: f64,
}

impl Noc {
    /// H100 calibration. Latency: 190 cy @ N=2 (paper), rising with N.
    /// Bandwidth: 2.90 TB/s @ N=16 (paper), higher for smaller N.
    /// Active SMs: 132 total, gang-scheduling costs capacity at large N.
    pub fn h100(hw: &Hardware) -> Self {
        Self {
            anchors: vec![
                // N     lat_cycles   agg_bw        active SMs
                (1, 29.0, 4.80e12, 132), // intra-SM shared memory
                (2, 190.0, 3.90e12, 132),
                (4, 235.0, 3.55e12, 128),
                (8, 300.0, 3.20e12, 120),
                (16, 370.0, 2.90e12, 96),
            ],
            clock_ghz: hw.clock_ghz,
        }
    }

    fn anchor(&self, n: usize) -> &(usize, f64, f64, usize) {
        self.anchors
            .iter()
            .find(|a| a.0 == n)
            .unwrap_or_else(|| panic!("cluster size {n} not a power of two in 1..=16"))
    }

    /// SM-to-SM access latency in cycles for cluster size `n`.
    pub fn latency_cycles(&self, n: usize) -> f64 {
        self.anchor(n).1
    }

    /// SM-to-SM access latency in seconds.
    pub fn latency(&self, n: usize) -> f64 {
        self.latency_cycles(n) / (self.clock_ghz * 1e9)
    }

    /// Aggregate DSMEM bandwidth (bytes/s) available to a cluster of `n`.
    pub fn bandwidth(&self, n: usize) -> f64 {
        self.anchor(n).2
    }

    /// Number of SMs that remain schedulable device-wide when every block
    /// runs in a cluster of size `n`.
    pub fn active_sms(&self, n: usize) -> usize {
        self.anchor(n).3
    }

    /// Valid cluster sizes (powers of two up to the Hopper max of 16).
    pub fn cluster_sizes() -> [usize; 5] {
        [1, 2, 4, 8, 16]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noc() -> Noc {
        Noc::h100(&Hardware::h100_sxm5())
    }

    #[test]
    fn latency_monotone_increasing_with_cluster_size() {
        let n = noc();
        let mut prev = 0.0;
        for s in Noc::cluster_sizes() {
            let l = n.latency_cycles(s);
            assert!(l > prev, "latency must grow with cluster size");
            prev = l;
        }
    }

    #[test]
    fn paper_anchor_points() {
        let hw = Hardware::h100_sxm5();
        let n = noc();
        // 190 cycles @ N=2, below gmem latency (paper §2.3)
        assert_eq!(n.latency_cycles(2), 190.0);
        assert!(n.latency_cycles(2) < hw.gmem_latency_cycles);
        // 2.90 TB/s @ N=16, slightly lagging HBM's 2.96 TB/s
        assert_eq!(n.bandwidth(16), 2.90e12);
        assert!(n.bandwidth(16) < hw.hbm_bw);
    }

    #[test]
    fn bandwidth_monotone_decreasing() {
        let n = noc();
        let mut prev = f64::INFINITY;
        for s in Noc::cluster_sizes() {
            let b = n.bandwidth(s);
            assert!(b < prev);
            prev = b;
        }
    }

    #[test]
    fn active_sms_shrink() {
        let n = noc();
        assert_eq!(n.active_sms(1), 132);
        assert!(n.active_sms(16) < n.active_sms(4));
    }

    #[test]
    #[should_panic]
    fn invalid_cluster_size_panics() {
        noc().latency_cycles(3);
    }
}
