//! End-to-end decode model: TPOT for a whole transformer, per framework.
//!
//! Composes the per-layer attention-block dataflow cost with the FFN /
//! RMSNorm / LM-head kernels that every framework (including ClusterFusion,
//! §3.2 last paragraph) runs as separate library kernels, plus launch and
//! host overheads. This is the engine behind Figs. 2, 12, 13, 17, 18, 19
//! and the Appendix C multi-batch runs.


use crate::models::{AttnKind, ModelConfig};

use super::collective::Transport;
use super::dataflow::{
    block_isolated, mla, occupancy_mem_time, split_token, AttnProblem, CostEnv, CostReport, ELEM,
};
use super::frameworks::FrameworkProfile;
use super::hw::Hardware;
use super::noc::Noc;

/// Which attention-block dataflow the end-to-end model uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Block-isolated baseline pipeline (all four baseline frameworks).
    BlockIsolated,
    /// ClusterFusion's fused dataflow with the given cluster size.
    ClusterFusion { cluster_size: usize },
    /// ClusterFusion with DSMEM disabled (Fig. 13 ablation): the fused
    /// schedule stays, collectives fall back to global memory.
    ClusterFusionNoDsmem { cluster_size: usize },
}

/// One end-to-end decode-step estimate.
#[derive(Debug, Clone, Default)]
pub struct StepEstimate {
    /// Time per output token, seconds.
    pub tpot: f64,
    /// Attention-block ("core modules") time summed over layers.
    pub core_modules: f64,
    /// FFN + norms + LM head time.
    pub rest: f64,
    /// Host-side overhead.
    pub host: f64,
    /// Total kernel launches per decode step.
    pub launches: usize,
    /// HBM bytes moved per decode step.
    pub hbm_bytes: f64,
    /// DSMEM bytes moved per decode step.
    pub dsmem_bytes: f64,
}

fn attn_problem(model: &ModelConfig, batch: usize, seq: usize) -> AttnProblem {
    AttnProblem {
        batch,
        d_model: model.d_model,
        n_heads: model.n_heads,
        head_dim: model.head_dim,
        seq,
        kv_lora_rank: model.kv_lora_rank,
    }
}

/// Cost of one layer's attention block under the chosen engine.
pub fn attn_block_cost(
    model: &ModelConfig,
    batch: usize,
    seq: usize,
    engine: Engine,
    profile: &FrameworkProfile,
    hw: &Hardware,
    noc: &Noc,
) -> CostReport {
    let p = attn_problem(model, batch, seq);
    let eff_b = profile.bw_eff_at(batch);
    let mk_env = |cluster: usize, transport: Transport, eff: f64| CostEnv {
        hw,
        noc,
        cluster_size: cluster,
        transport,
        bw_efficiency: eff,
    };
    match (engine, model.attn) {
        (Engine::BlockIsolated, AttnKind::Mha) => {
            block_isolated::cost(&p, &mk_env(1, Transport::GlobalMemory, eff_b))
        }
        (Engine::BlockIsolated, AttnKind::Mla) => {
            mla::cost_block_isolated(&p, &mk_env(1, Transport::GlobalMemory, eff_b))
        }
        (Engine::ClusterFusion { cluster_size }, AttnKind::Mha) => {
            split_token::cost(&p, &mk_env(cluster_size, Transport::Dsmem, eff_b))
        }
        (Engine::ClusterFusion { cluster_size }, AttnKind::Mla) => {
            mla::cost(&p, &mk_env(cluster_size, Transport::Dsmem, eff_b))
        }
        (Engine::ClusterFusionNoDsmem { cluster_size }, AttnKind::Mha) => split_token::cost(
            &p,
            &mk_env(cluster_size, Transport::GlobalMemory, eff_b),
        ),
        (Engine::ClusterFusionNoDsmem { cluster_size }, AttnKind::Mla) => {
            mla::cost(&p, &mk_env(cluster_size, Transport::GlobalMemory, eff_b))
        }
    }
}

/// FFN + 2 norms for one layer (3 GEMM + 2 elementwise kernels; every
/// framework uses comparable CUTLASS-grade kernels here — the paper fuses
/// only the attention scope).
fn ffn_cost(model: &ModelConfig, batch: usize, hw: &Hardware, noc: &Noc, eff: f64) -> CostReport {
    let (b, d, f) = (batch as f64, model.d_model as f64, model.ffn_dim as f64);
    let mut rep = CostReport::default();
    let active = noc.active_sms(1);
    // W1, W2 (d x f) then W3 (f x d); activations small next to weights
    let gemm_bytes = [d * f * ELEM + b * (d + f) * ELEM,
                      d * f * ELEM + b * (d + f) * ELEM,
                      f * d * ELEM + b * (d + f) * ELEM];
    let gemm_flops = [2.0 * b * d * f, 2.0 * b * d * f, 2.0 * b * f * d];
    for (i, (&bytes, &flops)) in gemm_bytes.iter().zip(&gemm_flops).enumerate() {
        let t = occupancy_mem_time(bytes, 128, active, hw) / (eff.max(0.55));
        rep.stage(&format!("ffn-gemm{i}"), t.max(hw.compute_time(flops)) + hw.graph_kernel_launch + hw.kernel_boundary_sync);
        rep.hbm_bytes += bytes;
    }
    for i in 0..2 {
        let bytes = 2.0 * b * d * ELEM;
        let t = occupancy_mem_time(bytes, 32, active, hw);
        rep.stage(&format!("rmsnorm{i}"), t + hw.graph_kernel_launch + hw.kernel_boundary_sync);
        rep.hbm_bytes += bytes;
    }
    rep.launches = 5;
    rep
}

/// LM head (vocab projection) cost. Shared with the block-scope TPOT
/// composition (`clustersim::block::decode_tpot`) so the Fig. 17 e2e
/// numbers and the §Block tables can never disagree on the head charge.
pub(crate) fn lm_head_cost(
    model: &ModelConfig,
    batch: usize,
    hw: &Hardware,
    noc: &Noc,
) -> CostReport {
    let (b, d, v) = (batch as f64, model.d_model as f64, model.vocab as f64);
    let mut rep = CostReport::default();
    let bytes = d * v * ELEM + b * (d + v) * ELEM;
    let t = occupancy_mem_time(bytes, 132, noc.active_sms(1), hw) / 0.7;
    rep.stage("lm-head", t.max(hw.compute_time(2.0 * b * d * v)) + hw.graph_kernel_launch);
    rep.hbm_bytes = bytes;
    rep.launches = 1;
    rep
}

/// Estimate one decode step (TPOT) for `model` at context length `seq`.
pub fn decode_step(
    model: &ModelConfig,
    batch: usize,
    seq: usize,
    engine: Engine,
    profile: &FrameworkProfile,
    hw: &Hardware,
    noc: &Noc,
) -> StepEstimate {
    let attn = attn_block_cost(model, batch, seq, engine, profile, hw, noc);
    let ffn = ffn_cost(model, batch, hw, noc, profile.bw_eff_at(batch));
    let head = lm_head_cost(model, batch, hw, noc);
    let l = model.n_layers as f64;

    let extra_per_layer = profile.kernels_per_layer_extra;
    let extra_time = extra_per_layer as f64 * (hw.graph_kernel_launch + 0.5e-6);

    let core = attn.latency * l;
    let rest = (ffn.latency + extra_time) * l + head.latency;
    let launches =
        (attn.launches + ffn.launches + extra_per_layer) * model.n_layers + head.launches;
    StepEstimate {
        tpot: core + rest + profile.host_step_overhead,
        core_modules: core,
        rest,
        host: profile.host_step_overhead,
        launches,
        hbm_bytes: (attn.hbm_bytes + ffn.hbm_bytes) * l + head.hbm_bytes,
        dsmem_bytes: attn.dsmem_bytes * l,
    }
}

/// Prefill estimate (compute-bound batched GEMMs over `prompt` tokens) —
/// used only by the Fig. 2 latency-share analysis.
pub fn prefill_time(model: &ModelConfig, prompt: usize, hw: &Hardware) -> f64 {
    let params = model.param_count() as f64;
    // 2 FLOPs per param per token + attention quadratic term
    let flops = 2.0 * params * prompt as f64
        + 2.0 * (model.n_layers * prompt * prompt * model.total_head_dim()) as f64;
    // prefill achieves high MFU; weights read once
    (flops / (hw.fp16_flops * 0.6)).max(hw.hbm_time(params * ELEM))
}

/// Fig. 2: fraction of total latency spent decoding when generating
/// `gen_tokens` after a `prompt`-token prefill.
pub fn decode_latency_share(
    model: &ModelConfig,
    prompt: usize,
    gen_tokens: usize,
    profile: &FrameworkProfile,
    hw: &Hardware,
    noc: &Noc,
) -> f64 {
    let pre = prefill_time(model, prompt, hw);
    let mut dec = 0.0;
    for t in 0..gen_tokens {
        dec += decode_step(model, 1, prompt + t, Engine::BlockIsolated, profile, hw, noc).tpot;
    }
    dec / (pre + dec)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> (Hardware, Noc) {
        let hw = Hardware::h100_sxm5();
        let noc = Noc::h100(&hw);
        (hw, noc)
    }

    #[test]
    fn clusterfusion_beats_all_baselines_on_llama() {
        let (hw, noc) = env();
        let m = ModelConfig::llama2_7b();
        let cf = decode_step(
            &m, 1, 4096,
            Engine::ClusterFusion { cluster_size: 4 },
            &FrameworkProfile::clusterfusion(), &hw, &noc,
        );
        for b in FrameworkProfile::baselines() {
            let base = decode_step(&m, 1, 4096, Engine::BlockIsolated, &b, &hw, &noc);
            let speedup = base.tpot / cf.tpot;
            assert!(speedup > 1.0, "{}: {speedup}", b.name);
            assert!(speedup < 4.0, "{}: implausible {speedup}", b.name);
        }
    }

    #[test]
    fn tpot_order_of_magnitude_sane() {
        // Llama2-7B on H100 decodes in the ~5-20 ms/token range.
        let (hw, noc) = env();
        let m = ModelConfig::llama2_7b();
        let e = decode_step(
            &m, 1, 4096,
            Engine::ClusterFusion { cluster_size: 4 },
            &FrameworkProfile::clusterfusion(), &hw, &noc,
        );
        assert!(e.tpot > 2e-3 && e.tpot < 30e-3, "{}", e.tpot);
    }

    #[test]
    fn decode_dominates_latency_fig2() {
        // Paper Fig. 2: decoding > 95% of latency for 256 generated tokens.
        let (hw, noc) = env();
        let m = ModelConfig::llama2_7b();
        let share =
            decode_latency_share(&m, 256, 256, &FrameworkProfile::sglang(), &hw, &noc);
        assert!(share > 0.95, "decode share {share}");
    }

    #[test]
    fn ablation_dsmem_increases_tpot() {
        // Fig. 13: disabling DSMEM raises TPOT, up to tens of percent.
        let (hw, noc) = env();
        let m = ModelConfig::llama2_7b();
        let p = FrameworkProfile::clusterfusion();
        let mut worst = 0.0f64;
        for seq in [1024, 4096, 16384] {
            let on = decode_step(&m, 1, seq, Engine::ClusterFusion { cluster_size: 4 }, &p, &hw, &noc);
            let off = decode_step(
                &m, 1, seq, Engine::ClusterFusionNoDsmem { cluster_size: 4 }, &p, &hw, &noc,
            );
            assert!(off.tpot > on.tpot, "seq {seq}");
            worst = worst.max(off.tpot / on.tpot - 1.0);
        }
        assert!(worst > 0.05 && worst < 0.6, "ablation delta {worst}");
    }

    #[test]
    fn launch_reduction_is_large() {
        // Fig. 12 right: launch overhead cut by ~an order of magnitude.
        let (hw, noc) = env();
        let m = ModelConfig::llama2_7b();
        let cf = decode_step(
            &m, 1, 4096,
            Engine::ClusterFusion { cluster_size: 4 },
            &FrameworkProfile::clusterfusion(), &hw, &noc,
        );
        let base = decode_step(&m, 1, 4096, Engine::BlockIsolated, &FrameworkProfile::mlc_llm(), &hw, &noc);
        assert!(base.launches as f64 / cf.launches as f64 > 2.0);
    }

    #[test]
    fn multibatch_speedup_shrinks() {
        // Appendix C: at batch 16 the speedup over baselines shrinks.
        let (hw, noc) = env();
        let m = ModelConfig::llama2_7b();
        let speedup = |batch| {
            let cf = decode_step(
                &m, batch, 4096,
                Engine::ClusterFusion { cluster_size: 4 },
                &FrameworkProfile::clusterfusion(), &hw, &noc,
            );
            let sg = decode_step(&m, batch, 4096, Engine::BlockIsolated, &FrameworkProfile::sglang(), &hw, &noc);
            sg.tpot / cf.tpot
        };
        assert!(speedup(16) < speedup(1), "bs16 {} !< bs1 {}", speedup(16), speedup(1));
    }

    #[test]
    fn mla_engine_works_for_deepseek() {
        let (hw, noc) = env();
        let m = ModelConfig::deepseek_v2_lite();
        let cf = decode_step(
            &m, 1, 4096,
            Engine::ClusterFusion { cluster_size: 4 },
            &FrameworkProfile::clusterfusion(), &hw, &noc,
        );
        let sg = decode_step(&m, 1, 4096, Engine::BlockIsolated, &FrameworkProfile::sglang(), &hw, &noc);
        assert!(sg.tpot / cf.tpot > 1.0);
    }
}
