//! # clustersim — the H100 substitute substrate
//!
//! The paper's system is a CUDA execution framework exploiting NVIDIA
//! Hopper thread-block clusters and distributed shared memory (DSMEM).
//! That hardware is not available here, so — per the substitution rule in
//! DESIGN.md §2 — this module rebuilds the relevant machine as a simulator
//! with two coupled facets:
//!
//! * a **functional** facet: the cluster-level collective primitives
//!   (paper Algs. 1–2) and every dataflow variant (Algs. 3–5) are executed
//!   for real over per-thread-block buffers, so their numerics can be
//!   checked against a plain reference implementation; and
//! * a **performance** facet: an analytical cost model of the H100
//!   (SMs, the SM-to-SM crossbar NoC of Fig. 5, HBM, kernel-launch
//!   overhead) that reproduces the *shape* of every latency/traffic result
//!   in the paper's evaluation.
//!
//! The two facets share the same schedule: the cost model charges exactly
//! the rounds/messages the functional collectives perform.

pub mod block;
pub mod collective;
pub mod dataflow;
pub mod e2e;
pub mod frameworks;
pub mod hw;
pub mod kernelmodel;
pub mod noc;
pub mod scope;
pub mod traffic;

pub use collective::{
    cluster_gather, cluster_reduce, gather_cost, reduce_cost, CollectiveCost, ReduceOp, Transport,
};
pub use hw::Hardware;
pub use noc::Noc;
