//! Fusion-scope feasibility — paper §5 (Discussion on Fusion Scope).
//!
//! "Each fused scope is bounded by a fixed cluster size (up to 16 thread
//! blocks) [...] When fused operators exceed the cluster scope, the system
//! must fall back to global memory communication." This module makes that
//! planning decision explicit: given a model's attention block and a
//! cluster size, decide whether the fused SplitToken kernel fits the
//! hardware budget (cluster limit, per-block shared memory, partition
//! divisibility), and pick the execution plan — fused, fused with a
//! gmem fallback for oversized collectives, or block-isolated.

use crate::models::{AttnKind, ModelConfig};

use super::dataflow::ELEM;
use super::hw::Hardware;

/// The plan chosen for a model's attention block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusionPlan {
    /// Everything fits: single fused kernel, collectives over DSMEM.
    Fused { cluster_size: usize },
    /// The fused schedule works but a buffer exceeds the DSMEM budget;
    /// that collective falls back to global memory (paper §5's fallback,
    /// costed as `Transport::GlobalMemory`).
    FusedGmemFallback { cluster_size: usize },
    /// Fusion infeasible (e.g. partitions don't divide); run the
    /// block-isolated pipeline.
    BlockIsolated,
}

/// Why a configuration was rejected or downgraded.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScopeReport {
    pub plan: FusionPlan,
    pub reasons: Vec<String>,
    /// Per-block shared-memory bytes the fused kernel needs.
    pub smem_bytes: usize,
}

/// Hopper limit (paper §3.1: N = 2^k, k ≤ 4).
pub const MAX_CLUSTER: usize = 16;

/// Per-block shared memory the SplitToken kernel needs: gathered Q/K/V
/// tiles (3 × B × dh), softmax stats, the attention accumulator
/// (B × dh, fp32), and a staging buffer for the collective exchange.
pub fn split_token_smem(model: &ModelConfig, batch: usize, cluster: usize) -> usize {
    let dh = model.head_dim;
    let qkv = 3 * batch * dh * ELEM as usize;
    let acc = batch * dh * 4;
    let stats = 2 * batch * 4;
    let staging = (3 * batch * dh / cluster.max(1)) * ELEM as usize * cluster;
    qkv + acc + stats + staging
}

/// Per-block shared memory the multi-row prefill schedule needs: the
/// SplitToken working set with all `rows` prompt positions of a slot
/// staged through the gathered Q/K/V tiles at once (chunked prefill
/// feeds `rows` positions per slot per fused step, so the tiles, stats
/// and staging all scale with the chunk).
pub fn prefill_smem(model: &ModelConfig, batch: usize, rows: usize, cluster: usize) -> usize {
    split_token_smem(model, batch * rows.max(1), cluster)
}

/// Decide the execution plan for a prefill step feeding `rows` prompt
/// positions per slot. Same feasibility gates as decode (cluster limit,
/// partition divisibility), but the working set grows with the chunk:
/// a schedule that runs fully fused at `rows = 1` can degrade to the
/// gmem fallback at larger chunks — the planning signal a serving
/// config uses to bound `--prefill-chunk`.
pub fn plan_prefill(
    model: &ModelConfig,
    batch: usize,
    rows: usize,
    cluster: usize,
    hw: &Hardware,
) -> ScopeReport {
    plan(model, batch * rows.max(1), cluster, hw)
}

/// Largest prefill chunk (rows per slot) that still runs fully fused
/// for this model / batch / cluster on this hardware; 0 when not even a
/// single row fuses. Monotone in `rows` (the working set only grows),
/// so binary search over `[0, max_seq]`.
pub fn max_fused_prefill_rows(
    model: &ModelConfig,
    batch: usize,
    cluster: usize,
    hw: &Hardware,
) -> usize {
    let fused = |rows: usize| {
        matches!(plan_prefill(model, batch, rows, cluster, hw).plan, FusionPlan::Fused { .. })
    };
    let (mut lo, mut hi) = (0usize, model.max_seq);
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        if fused(mid) {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

/// Decide the execution plan for one model / batch / cluster size.
pub fn plan(model: &ModelConfig, batch: usize, cluster: usize, hw: &Hardware) -> ScopeReport {
    let mut reasons = Vec::new();
    if !cluster.is_power_of_two() || cluster > MAX_CLUSTER {
        return ScopeReport {
            plan: FusionPlan::BlockIsolated,
            reasons: vec![format!(
                "cluster {cluster} not a power of two <= {MAX_CLUSTER} (Hopper limit)"
            )],
            smem_bytes: 0,
        };
    }
    let divisible = match model.attn {
        AttnKind::Mha => model.head_dim % cluster == 0 && model.d_model % cluster == 0,
        AttnKind::Mla => model.kv_lora_rank % cluster == 0 && model.d_model % cluster == 0,
    };
    if !divisible {
        return ScopeReport {
            plan: FusionPlan::BlockIsolated,
            reasons: vec![format!(
                "cluster {cluster} does not divide the partitioned dimensions"
            )],
            smem_bytes: 0,
        };
    }
    let smem = split_token_smem(model, batch, cluster);
    if smem > hw.smem_bytes_per_sm {
        reasons.push(format!(
            "fused working set {smem} B exceeds {} B DSMEM budget; collectives fall back to \
             global memory (paper §5)",
            hw.smem_bytes_per_sm
        ));
        return ScopeReport { plan: FusionPlan::FusedGmemFallback { cluster_size: cluster }, reasons, smem_bytes: smem };
    }
    ScopeReport { plan: FusionPlan::Fused { cluster_size: cluster }, reasons, smem_bytes: smem }
}

/// Scan all legal cluster sizes and return the feasible ones.
pub fn feasible_clusters(model: &ModelConfig, batch: usize, hw: &Hardware) -> Vec<usize> {
    [1usize, 2, 4, 8, 16]
        .into_iter()
        .filter(|&n| matches!(plan(model, batch, n, hw).plan, FusionPlan::Fused { .. }))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn todays_models_fit_comfortably() {
        // Paper §5: "most decoding operators in today's mainstream LLMs
        // fit comfortably within this limit".
        let hw = Hardware::h100_sxm5();
        for m in [ModelConfig::llama2_7b(), ModelConfig::deepseek_v2_lite()] {
            for n in [1, 2, 4] {
                let r = plan(&m, 1, n, &hw);
                assert!(matches!(r.plan, FusionPlan::Fused { .. }), "{} N={n}: {r:?}", m.name);
            }
        }
    }

    #[test]
    fn oversized_cluster_rejected() {
        let hw = Hardware::h100_sxm5();
        let m = ModelConfig::llama2_7b();
        assert_eq!(plan(&m, 1, 32, &hw).plan, FusionPlan::BlockIsolated);
        assert_eq!(plan(&m, 1, 3, &hw).plan, FusionPlan::BlockIsolated);
    }

    #[test]
    fn indivisible_partition_falls_back() {
        let hw = Hardware::h100_sxm5();
        let mut m = ModelConfig::llama2_7b();
        m.head_dim = 96; // 96 % 16 == 0 but 96 % 8 == 0... use cluster 16 -> 96/16=6 ok; pick cluster where it fails
        m.d_model = 4096;
        // head_dim 96: cluster 16 divides? 96 % 16 = 0 -> fine; use head_dim 100
        m.head_dim = 100;
        let r = plan(&m, 1, 8, &hw);
        assert_eq!(r.plan, FusionPlan::BlockIsolated);
        assert!(!r.reasons.is_empty());
    }

    #[test]
    fn huge_future_model_triggers_gmem_fallback() {
        // Paper §5: "future models with larger hidden dimensions ... may
        // challenge this boundary".
        let hw = Hardware::h100_sxm5();
        let mut m = ModelConfig::llama2_7b();
        m.head_dim = 4096; // hypothetical giant head
        let r = plan(&m, 16, 2, &hw);
        assert_eq!(r.plan, FusionPlan::FusedGmemFallback { cluster_size: 2 });
        assert!(r.smem_bytes > hw.smem_bytes_per_sm);
    }

    #[test]
    fn prefill_chunks_are_smem_bounded() {
        let hw = Hardware::h100_sxm5();
        let m = ModelConfig::llama2_7b();
        // the working set scales with the chunk
        assert!(prefill_smem(&m, 1, 8, 4) > prefill_smem(&m, 1, 1, 4));
        assert_eq!(prefill_smem(&m, 1, 1, 4), split_token_smem(&m, 1, 4));
        // some fused chunk exists, but not an unbounded one: past the
        // limit the schedule degrades to the gmem fallback, not to
        // infeasible (partitions still divide)
        let max = max_fused_prefill_rows(&m, 1, 4, &hw);
        assert!(max >= 1, "at least one row must fuse");
        assert!(max < m.max_seq, "whole-context chunks cannot stay in smem");
        assert!(matches!(plan_prefill(&m, 1, max, 4, &hw).plan, FusionPlan::Fused { .. }));
        assert!(matches!(
            plan_prefill(&m, 1, max + 1, 4, &hw).plan,
            FusionPlan::FusedGmemFallback { .. }
        ));
        // an indivisible cluster never fuses at any chunk
        assert_eq!(max_fused_prefill_rows(&m, 1, 3, &hw), 0);
    }

    #[test]
    fn feasible_cluster_list() {
        let hw = Hardware::h100_sxm5();
        let m = ModelConfig::llama2_7b();
        let f = feasible_clusters(&m, 1, &hw);
        assert!(f.contains(&4));
        assert!(f.len() >= 4);
    }
}
