//! Baseline inference-framework profiles — paper §4 Baselines.
//!
//! The paper compares against SGLang 0.4.3, vLLM 0.6.4, TensorRT-LLM
//! 0.18.0 and MLC-LLM 0.20.dev0, all with CUDA Graph enabled. All four run
//! the *block-isolated* dataflow (§2.2); they differ in kernel quality and
//! host-side overhead. Each profile has three calibrated parameters:
//!
//! * `bw_efficiency` — achieved fraction of HBM bandwidth on short bs=1
//!   decode kernels (library GEMV/attention kernels do not reach the
//!   hand-tuned fused kernel's utilisation);
//! * `kernels_per_layer_extra` — auxiliary kernels per decoder layer
//!   beyond the 4-kernel attention pipeline and the 5 FFN/norm kernels
//!   (elementwise glue, rope, residual, quant/dequant...), driving the
//!   Fig. 12-right launch-overhead gap;
//! * `host_step_overhead` — per-decode-step scheduler/runtime cost on the
//!   host that CUDA Graph does not remove.
//!
//! Values are calibrated so that the *ratios* of Figs. 17/18 reproduce;
//! see EXPERIMENTS.md for measured-vs-paper numbers.


/// A named baseline configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct FrameworkProfile {
    pub name: &'static str,
    /// Achieved HBM fraction of the framework's decode kernels at batch 1
    /// (GEMV regime, where library kernels are weakest).
    pub bw_efficiency: f64,
    /// Auxiliary kernel launches per decoder layer.
    pub kernels_per_layer_extra: usize,
    /// Host-side per-step overhead, seconds.
    pub host_step_overhead: f64,
    /// How much of the gap to peak library efficiency closes as batch
    /// grows (1.0 = fully recovers by batch 16; the Appendix C effect that
    /// shrinks ClusterFusion's edge at large batch).
    pub batch_scaling: f64,
}

/// Library-kernel efficiency ceiling reached at large batch.
pub const PEAK_LIBRARY_EFF: f64 = 0.82;

impl FrameworkProfile {
    pub fn sglang() -> Self {
        Self {
            name: "SGLang",
            bw_efficiency: 0.56,
            kernels_per_layer_extra: 4,
            host_step_overhead: 45e-6,
            batch_scaling: 1.0,
        }
    }

    pub fn vllm() -> Self {
        Self {
            name: "vLLM",
            bw_efficiency: 0.57,
            kernels_per_layer_extra: 5,
            host_step_overhead: 50e-6,
            batch_scaling: 1.0,
        }
    }

    pub fn tensorrt_llm() -> Self {
        Self {
            name: "TensorRT-LLM",
            bw_efficiency: 0.55,
            kernels_per_layer_extra: 3,
            host_step_overhead: 30e-6,
            batch_scaling: 1.0,
        }
    }

    pub fn mlc_llm() -> Self {
        Self {
            name: "MLC-LLM",
            bw_efficiency: 0.30,
            kernels_per_layer_extra: 8,
            host_step_overhead: 60e-6,
            batch_scaling: 0.35,
        }
    }

    /// The paper's system: the fused SplitToken/MLA kernel plus the same
    /// CUTLASS/FlashInfer-grade FFN as the baselines (§3.2 last paragraph),
    /// a thin C++-grade host loop, and almost no auxiliary kernels.
    pub fn clusterfusion() -> Self {
        Self {
            name: "ClusterFusion",
            bw_efficiency: 0.85,
            kernels_per_layer_extra: 0,
            host_step_overhead: 8e-6,
            batch_scaling: 0.0, // already hand-tuned at batch 1
        }
    }

    /// Achieved bandwidth fraction at a given batch size: GEMV-regime
    /// `bw_efficiency` at batch 1, closing toward [`PEAK_LIBRARY_EFF`] as
    /// the batch grows (GEMM regime).
    pub fn bw_eff_at(&self, batch: usize) -> f64 {
        let frac = ((batch.saturating_sub(1)) as f64 / 15.0).min(1.0) * self.batch_scaling;
        let peak = PEAK_LIBRARY_EFF.max(self.bw_efficiency);
        self.bw_efficiency + (peak - self.bw_efficiency) * frac
    }

    pub fn baselines() -> Vec<Self> {
        vec![Self::sglang(), Self::vllm(), Self::tensorrt_llm(), Self::mlc_llm()]
    }

    pub fn all() -> Vec<Self> {
        let mut v = Self::baselines();
        v.push(Self::clusterfusion());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clusterfusion_has_best_efficiency_and_fewest_kernels() {
        let cf = FrameworkProfile::clusterfusion();
        for b in FrameworkProfile::baselines() {
            assert!(cf.bw_efficiency > b.bw_efficiency, "{}", b.name);
            assert!(cf.kernels_per_layer_extra < b.kernels_per_layer_extra + 1);
            assert!(cf.host_step_overhead < b.host_step_overhead);
        }
    }

    #[test]
    fn batch16_closes_most_of_the_gap() {
        // Appendix C: baseline kernels reach GEMM-grade efficiency at
        // batch 16, shrinking ClusterFusion's edge.
        let sg = FrameworkProfile::sglang();
        assert!(sg.bw_eff_at(1) < 0.6);
        assert!(sg.bw_eff_at(16) > 0.8);
        let mlc = FrameworkProfile::mlc_llm();
        assert!(mlc.bw_eff_at(16) < 0.55, "MLC stays well below peak");
        let cf = FrameworkProfile::clusterfusion();
        assert_eq!(cf.bw_eff_at(16), cf.bw_eff_at(1));
    }

    #[test]
    fn mlc_is_the_weakest_baseline() {
        // Fig. 17/18: MLC-LLM trails the other baselines by ~2x.
        let mlc = FrameworkProfile::mlc_llm();
        for b in [FrameworkProfile::sglang(), FrameworkProfile::vllm(), FrameworkProfile::tensorrt_llm()] {
            assert!(mlc.bw_efficiency < b.bw_efficiency);
        }
    }
}
