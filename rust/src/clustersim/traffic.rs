//! Analytical DSMEM-traffic model — paper §3.2 and Appendix B.
//!
//! The paper ranks dataflow variants by their total DSMEM traffic:
//!
//! ```text
//! Traffic_Reduce(size, N) = size · log2(N) · N
//! Traffic_Gather(size, N) = size · (2^(log2(N/2)+1) − 1) · N = size · (N−1) · N
//! ```
//!
//! and per dataflow (h = H/N per-block head slice, H total head dim,
//! l = kv_lora_rank slice, L total rank, S sequence length, D model dim —
//! all in *bytes* here):
//!
//! * SplitToken (Alg. 3):  Reduce(H) + Gather(3h)
//! * SplitHead  (Alg. 5):  Reduce(S) + Reduce(D)
//! * Fused MLA  (Alg. 4):  Gather(h) + 2·Gather(l) + Reduce(l) + Reduce(L→H)
//!
//! These closed forms are unit-tested against the executed collectives in
//! [`super::collective`], which is the point: the analytical model and the
//! functional simulator must agree round for round.

/// Bytes moved over DSMEM by one ClusterReduce of a `size`-byte buffer.
pub fn traffic_reduce(size: f64, n: usize) -> f64 {
    assert!(n.is_power_of_two() && n >= 1);
    size * (n.trailing_zeros() as f64) * n as f64
}

/// Bytes moved over DSMEM by one ClusterGather with `size`-byte segments.
pub fn traffic_gather(size: f64, n: usize) -> f64 {
    assert!(n.is_power_of_two() && n >= 1);
    size * (n as f64 - 1.0) * n as f64
}

/// Total DSMEM traffic of the SplitToken dataflow (paper Alg. 3) for one
/// head-cluster: gather of per-block Q/K/V segments (3h bytes each) plus
/// reduce of the attention output (H bytes). Softmax statistics (two
/// floats) are omitted exactly as the paper does.
pub fn split_token_traffic(total_head_bytes: f64, n: usize) -> f64 {
    let h = total_head_bytes / n as f64;
    traffic_reduce(total_head_bytes, n) + traffic_gather(3.0 * h, n)
}

/// Total DSMEM traffic of the SplitHead dataflow (paper Alg. 5):
/// reduce of the S-length score row plus reduce of the D-dim output.
pub fn split_head_traffic(seq_bytes: f64, d_model_bytes: f64, n: usize) -> f64 {
    traffic_reduce(seq_bytes, n) + traffic_reduce(d_model_bytes, n)
}

/// Total DSMEM traffic of the fused MLA dataflow (paper Alg. 4, App. B.1):
/// Gather(h) + 2·Gather(l) for the projections, Reduce(l) + Reduce(H) for
/// the attention output and down projection.
pub fn mla_traffic(head_bytes: f64, lora_bytes: f64, total_head_bytes: f64, n: usize) -> f64 {
    let h = head_bytes / n as f64;
    let l = lora_bytes / n as f64;
    traffic_gather(h, n)
        + 2.0 * traffic_gather(l, n)
        + traffic_reduce(l, n)
        + traffic_reduce(total_head_bytes, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustersim::collective::{
        cluster_gather, cluster_reduce, ReduceOp, Transport,
    };
    use crate::clustersim::{Hardware, Noc};

    #[test]
    fn closed_forms_match_executed_collectives() {
        let hw = Hardware::h100_sxm5();
        let noc = Noc::h100(&hw);
        for n in [2usize, 4, 8, 16] {
            let floats = 96usize;
            let bytes = (floats * 4) as f64;
            let mut blocks = vec![vec![1.0f32; floats]; n];
            let rc = cluster_reduce(&mut blocks, ReduceOp::Sum, Transport::Dsmem, &hw, &noc);
            assert_eq!(rc.traffic_bytes, traffic_reduce(bytes, n));
            let blocks = vec![vec![1.0f32; floats]; n];
            let (_, gc) = cluster_gather(&blocks, Transport::Dsmem, &hw, &noc);
            assert_eq!(gc.traffic_bytes, traffic_gather(bytes, n));
        }
    }

    #[test]
    fn split_token_beats_split_head_at_long_seq() {
        // The paper's Appendix B conclusion: SplitHead traffic is dominated
        // by S and loses at long sequences.
        let n = 4;
        let h_total = 128.0 * 2.0; // one head's dim in bytes (fp16)
        let d_model = 4096.0 * 2.0;
        for seq in [4096.0, 16384.0] {
            let st = split_token_traffic(h_total, n);
            let sh = split_head_traffic(seq * 2.0, d_model, n);
            assert!(st < sh, "seq={seq}: {st} !< {sh}");
        }
    }

    #[test]
    fn split_head_traffic_grows_with_seq() {
        // Paper Fig. 20 / App. B.2: SplitHead's DSMEM traffic is dominated
        // by the S-sized score reduce, so it grows ~linearly in S while
        // SplitToken's stays constant.
        let n = 4;
        let d_model = 4096.0 * 2.0;
        let sh_small = split_head_traffic(128.0 * 2.0, d_model, n);
        let sh_large = split_head_traffic(16384.0 * 2.0, d_model, n);
        assert!(sh_large > 3.0 * sh_small, "{sh_large} vs {sh_small}");
        let st = split_token_traffic(128.0 * 2.0, n);
        assert_eq!(st, split_token_traffic(128.0 * 2.0, n)); // S-independent
    }

    #[test]
    fn traffic_zero_for_single_block() {
        assert_eq!(traffic_reduce(1024.0, 1), 0.0);
        assert_eq!(traffic_gather(1024.0, 1), 0.0);
    }

    #[test]
    fn mla_traffic_scales_with_rank() {
        let n = 4;
        let t_small = mla_traffic(128.0, 256.0, 2048.0, n);
        let t_big = mla_traffic(128.0, 1024.0, 2048.0, n);
        assert!(t_big > t_small);
    }
}
