//! Full transformer-block decode pipeline — the ClusterFusion++ scope
//! (PAPERS.md): RMSNorm → (QKV + rotary + attention + output projection)
//! → residual → RMSNorm → SwiGLU MLP → residual, multi-layer, with a
//! tied-embedding greedy logits head on top.
//!
//! Like the attention dataflows, the block is implemented **twice over
//! one schedule**:
//!
//! * **functionally** — [`BlockModel::decode_step`] runs real numerics:
//!   the attention sub-block *is* the existing fused dataflow
//!   ([`split_token::execute_packed_rope`] for MHA,
//!   [`mla::execute_packed`] for MLA) composed with the `util::linalg`
//!   row primitives (`rmsnorm`, `rope_rotate`, `silu_mul`, blocked
//!   matmuls) that obey the PR 3 in-order-accumulation contract. Token
//!   ids in, token logits out: this is the engine behind
//!   [`crate::coordinator::FunctionalBackend`].
//! * **as a cost model** — [`cost`] charges the same block under three
//!   [`FusionScope`]s: per-op kernels (the SGLang/vLLM-style baseline),
//!   attention-scope fusion (the paper), and full-block fusion
//!   (ClusterFusion++). The scopes agree on FLOPs *by construction*
//!   ([`flops`] is shared) and differ only in HBM traffic, kernel
//!   launches, and collective schedule — the tested invariant of
//!   `tests/integration_block.rs`.
//!
//! Scope-ordering guarantee: at a geometry's *tuned* cluster size (the
//! Fig. 11 optimum — N=4 for the paper models) latency obeys
//! `FullBlockFused ≤ AttentionFused ≤ BlockIsolated`. At unsuitable
//! cluster sizes the attention-fused kernel itself can lose to the
//! baseline (too few blocks at N=1–2 with 32 heads, wave quantisation at
//! N=8 with 128 heads) — that is the paper's occupancy cliff, modelled,
//! not a bug. HBM/launch/FLOP monotonicity holds at *every* cluster
//! size. See DESIGN.md §Block.

use crate::models::{AttnKind, AttnWeights, MaterializedWeights, ModelConfig};
use crate::util::linalg::{self, PackedWeight};
use crate::util::pool::Pool;

use super::collective::{gather_cost, reduce_cost, Transport};
use super::dataflow::{
    block_isolated, mla, occupancy_mem_time, split_token, AttnProblem, CostEnv, CostReport, ELEM,
    PHASE_SETUP,
};
use super::dataflow::{PackedMhaWeights, PackedMlaWeights};
use super::hw::Hardware;
use super::noc::Noc;

/// RMSNorm epsilon of the functional pipeline (matches the frozen scalar
/// reference in `tests/integration_block.rs`).
pub const EPS: f32 = 1e-5;

/// Default rotary base of the MHA functional pipeline.
pub const ROPE_BASE: f32 = 10000.0;

/// How much of the transformer block one kernel covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusionScope {
    /// Every op its own kernel, intermediates through HBM (the baseline
    /// frameworks' execution model, §2.2): 4 attention kernels + 8
    /// norm/residual/MLP kernels per layer.
    BlockIsolated,
    /// QKV + attention + output projection fused into one cluster kernel
    /// (the paper's ClusterFusion); everything else stays per-op.
    AttentionFused,
    /// The whole block — norms, rotary, attention, residuals, SwiGLU MLP
    /// — under one fused cluster schedule (ClusterFusion++).
    FullBlockFused,
}

impl FusionScope {
    pub fn name(self) -> &'static str {
        match self {
            FusionScope::BlockIsolated => "block_isolated",
            FusionScope::AttentionFused => "attention_fused",
            FusionScope::FullBlockFused => "full_block_fused",
        }
    }

    pub fn all() -> [FusionScope; 3] {
        [FusionScope::BlockIsolated, FusionScope::AttentionFused, FusionScope::FullBlockFused]
    }
}

/// One layer's full-block decode problem: the attention sub-problem plus
/// the MLP width and the attention family.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockProblem {
    pub attn: AttnProblem,
    pub attn_kind: AttnKind,
    pub ffn_dim: usize,
}

impl BlockProblem {
    pub fn from_model(model: &ModelConfig, batch: usize, seq: usize) -> Self {
        Self {
            attn: AttnProblem {
                batch,
                d_model: model.d_model,
                n_heads: model.n_heads,
                head_dim: model.head_dim,
                seq,
                kv_lora_rank: model.kv_lora_rank,
            },
            attn_kind: model.attn,
            ffn_dim: model.ffn_dim,
        }
    }

    fn attn_mandatory_bytes(&self) -> f64 {
        match self.attn_kind {
            AttnKind::Mha => self.attn.mandatory_bytes_mha(),
            AttnKind::Mla => self.attn.mandatory_bytes_mla(),
        }
    }

    /// MLP + norm weight bytes a block decode must stream regardless of
    /// fusion scope.
    fn mlp_weight_bytes(&self) -> f64 {
        let (d, f) = (self.attn.d_model as f64, self.ffn_dim as f64);
        (3.0 * d * f + 2.0 * d) * ELEM
    }
}

/// Arithmetic work of one layer's full block, FLOPs — *identical across
/// fusion scopes* (fusion moves bytes and launches, never arithmetic).
/// Attention + rotary (MHA only) + 2 RMSNorms + 2 residual adds + SwiGLU
/// MLP (gate/up/down GEMMs + the elementwise gate).
pub fn flops(p: &BlockProblem) -> f64 {
    let (b, d, f) = (p.attn.batch as f64, p.attn.d_model as f64, p.ffn_dim as f64);
    let attn = match p.attn_kind {
        AttnKind::Mha => p.attn.flops_mha(),
        AttnKind::Mla => p.attn.flops_mla(),
    };
    let rope = match p.attn_kind {
        AttnKind::Mha => 6.0 * b * p.attn.total_head_dim() as f64,
        AttnKind::Mla => 0.0,
    };
    let norms = 2.0 * 4.0 * b * d;
    let resid = 2.0 * b * d;
    let mlp = 6.0 * b * d * f + 4.0 * b * f;
    attn + rope + norms + resid + mlp
}

/// The per-op kernels *outside* the attention scope — 2 RMSNorms, 2
/// residual adds, gate/up GEMMs, SwiGLU gate, down GEMM — shared by the
/// `BlockIsolated` and `AttentionFused` scopes (the paper fuses only the
/// attention scope; §3.2 last paragraph).
fn rest_ops_cost(p: &BlockProblem, env: &CostEnv) -> CostReport {
    let (b, d, f) = (p.attn.batch as f64, p.attn.d_model as f64, p.ffn_dim as f64);
    let hw = env.hw;
    let active = env.noc.active_sms(1);
    let eff = env.bw_efficiency.max(0.55);
    let mut rep = CostReport::default();
    let ops: [(&str, f64, f64, usize); 8] = [
        ("rmsnorm-attn", (2.0 * b * d + d) * ELEM, 4.0 * b * d, 32),
        ("residual-attn", 3.0 * b * d * ELEM, b * d, 32),
        ("rmsnorm-mlp", (2.0 * b * d + d) * ELEM, 4.0 * b * d, 32),
        ("gate-gemm", (d * f + b * d + b * f) * ELEM, 2.0 * b * d * f, 128),
        ("up-gemm", (d * f + b * d + b * f) * ELEM, 2.0 * b * d * f, 128),
        ("silu-mul", 3.0 * b * f * ELEM, 4.0 * b * f, 32),
        ("down-gemm", (f * d + b * f + b * d) * ELEM, 2.0 * b * f * d, 128),
        ("residual-mlp", 3.0 * b * d * ELEM, b * d, 32),
    ];
    for (name, bytes, flops, blocks) in ops {
        let t = occupancy_mem_time(bytes, blocks, active, hw) / eff;
        rep.stage(
            name,
            t.max(hw.compute_time(flops)) + hw.graph_kernel_launch + hw.kernel_boundary_sync,
        );
        rep.hbm_bytes += bytes;
        rep.launches += 1;
    }
    rep
}

/// Cost of one layer's full transformer block under `scope`.
///
/// All three scopes report the same [`flops`]; the baseline and
/// attention-fused scopes share [`rest_ops_cost`] verbatim, so their
/// latency difference is exactly the attention sub-block's (the already
/// tested `block_isolated` vs `split_token`/`mla` gap).
pub fn cost(p: &BlockProblem, scope: FusionScope, env: &CostEnv) -> CostReport {
    let total_flops = flops(p);
    let attn = match (scope, p.attn_kind) {
        (FusionScope::FullBlockFused, _) => return cost_full_block(p, env, total_flops),
        (FusionScope::BlockIsolated, AttnKind::Mha) => block_isolated::cost(&p.attn, env),
        (FusionScope::BlockIsolated, AttnKind::Mla) => mla::cost_block_isolated(&p.attn, env),
        (FusionScope::AttentionFused, AttnKind::Mha) => split_token::cost(&p.attn, env),
        (FusionScope::AttentionFused, AttnKind::Mla) => mla::cost(&p.attn, env),
    };
    let rest = rest_ops_cost(p, env);
    let mut rep = attn;
    rep.latency += rest.latency;
    rep.hbm_bytes += rest.hbm_bytes;
    rep.dsmem_bytes += rest.dsmem_bytes; // 0 today; carried for symmetry
    rep.launches += rest.launches;
    rep.stages.extend(rest.stages);
    rep.flops = total_flops;
    rep
}

/// The ClusterFusion++ kernel: one launch for the whole block. HBM is the
/// mandatory stream only (attention weights + KV + MLP/norm weights +
/// activation i/o — no intermediates). The MLP phase gives the kernel
/// device-filling parallelism, so the grid is at least one block per
/// schedulable SM (unlike the attention-only kernel, whose grid is
/// pinned to `n_heads × N` by the one-cluster-per-head mapping).
fn cost_full_block(p: &BlockProblem, env: &CostEnv, total_flops: f64) -> CostReport {
    let n = env.cluster_size;
    let (hw, noc) = (env.hw, env.noc);
    let a = &p.attn;
    let (b, d) = (a.batch as f64, a.d_model as f64);
    let active = noc.active_sms(n);
    let blocks = (a.n_heads * n).max(active);
    let mut rep = CostReport { launches: 1, flops: total_flops, ..Default::default() };

    let bytes = p.attn_mandatory_bytes() + p.mlp_weight_bytes();
    rep.hbm_bytes = bytes;
    let t_mem = occupancy_mem_time(bytes, blocks, active, hw) / env.bw_efficiency;
    rep.stage("fused-block-mem/compute", t_mem.max(hw.compute_time(total_flops)));

    // Attention-phase collectives: the same schedule the attention-scope
    // kernel charges (per head-cluster, all clusters concurrent).
    let (mut coll_lat, attn_cluster_traffic, mut rounds, phases) = match p.attn_kind {
        AttnKind::Mha => {
            let g = gather_cost(
                3.0 * (a.head_dim / n) as f64 * b * ELEM,
                n,
                env.transport,
                hw,
                noc,
            );
            let rs = reduce_cost(2.0 * b * 4.0, n, env.transport, hw, noc);
            let ro = reduce_cost(a.head_dim as f64 * b * ELEM, n, env.transport, hw, noc);
            (
                g.latency + rs.latency + ro.latency,
                g.traffic_bytes + rs.traffic_bytes + ro.traffic_bytes,
                g.rounds + rs.rounds + ro.rounds,
                5.0,
            )
        }
        AttnKind::Mla => {
            let l = a.kv_lora_rank as f64;
            let g_h = gather_cost((a.head_dim / n) as f64 * b * ELEM, n, env.transport, hw, noc);
            let g_l = gather_cost(l / n as f64 * b * ELEM, n, env.transport, hw, noc);
            let r_l = reduce_cost(l * b * ELEM, n, env.transport, hw, noc);
            let r_h = reduce_cost(a.head_dim as f64 * b * ELEM, n, env.transport, hw, noc);
            let r_s = reduce_cost(2.0 * b * 4.0, n, env.transport, hw, noc);
            (
                g_h.latency + 2.0 * g_l.latency + r_l.latency + r_h.latency + r_s.latency,
                g_h.traffic_bytes
                    + 2.0 * g_l.traffic_bytes
                    + r_l.traffic_bytes
                    + r_h.traffic_bytes
                    + r_s.traffic_bytes,
                g_h.rounds + 2 * g_l.rounds + r_l.rounds + r_h.rounds + r_s.rounds,
                6.0,
            )
        }
    };
    rep.dsmem_bytes = attn_cluster_traffic * a.n_heads as f64;

    // Block-scope extras, charged once device-wide: the MLP's gate/up
    // columns are partitioned across all clusters; each cluster owns a
    // disjoint f-slice, applies the SwiGLU gate locally, reduces its
    // down-projection partial intra-cluster, and atomicAdds the result
    // row (the HBM side of that is already in the activation i/o bytes).
    // Plus the two RMSNorm statistic reduces (d partitioned per cluster).
    let r_down = reduce_cost(b * d * ELEM, n, env.transport, hw, noc);
    let r_norm = reduce_cost(b * 4.0, n, env.transport, hw, noc);
    coll_lat += r_down.latency + 2.0 * r_norm.latency;
    rounds += r_down.rounds + 2 * r_norm.rounds;
    rep.dsmem_bytes += r_down.traffic_bytes + 2.0 * r_norm.traffic_bytes;
    rep.stage("collectives", coll_lat);

    match env.transport {
        Transport::Dsmem => {
            rep.stage("dsmem-contention", rep.dsmem_bytes / noc.bandwidth(n));
        }
        Transport::GlobalMemory => {
            rep.stage(
                "gmem-grid-barriers",
                rounds as f64 * super::dataflow::GMEM_BARRIER_PER_BLOCK * blocks as f64,
            );
        }
    }

    // More phases than the attention kernel (norms + MLP up/down join the
    // pipeline), still amortised over two in-flight phases per cluster.
    rep.stage("phase-setup", (phases + 2.0) * PHASE_SETUP / (n.min(2) as f64));
    rep.stage("launch", hw.graph_kernel_launch);
    rep
}

/// End-to-end decode TPOT estimate: `n_layers` blocks under `scope` plus
/// the LM head (always a separate library kernel, as in `e2e`). No
/// framework host overhead — this is the kernel-side model the serving
/// `ServiceModel` consumes (`loadgen::ServiceModel::from_block`).
pub fn decode_tpot(
    model: &ModelConfig,
    batch: usize,
    seq: usize,
    scope: FusionScope,
    cluster_size: usize,
    hw: &Hardware,
    noc: &Noc,
) -> f64 {
    let p = BlockProblem::from_model(model, batch, seq);
    let env = CostEnv::clusterfusion(hw, noc, cluster_size);
    let block = cost(&p, scope, &env);
    let head = super::e2e::lm_head_cost(model, batch, hw, noc);
    block.latency * model.n_layers as f64 + head.latency
}

/// Cost of one layer's block processing a `rows`-position prefill chunk
/// under `scope`: the same kernel schedule as decode with `rows`
/// activation rows in flight — weights stream **once** per chunk while
/// compute and attention traffic scale with `rows`, which is exactly the
/// weight amortisation chunked prefill buys (the prefill regime of
/// Fig. 2). `rows == 1` is [`cost`] itself, so the scope orderings and
/// FLOP/traffic monotonicity carry over to every chunk size.
pub fn prefill_cost(
    p: &BlockProblem,
    rows: usize,
    scope: FusionScope,
    env: &CostEnv,
) -> CostReport {
    assert!(rows >= 1, "a prefill chunk has at least one row");
    let mut rp = *p;
    rp.attn.batch = p.attn.batch * rows;
    cost(&rp, scope, env)
}

/// Whole-model prefill-step latency for a `rows`-position chunk at KV
/// length `seq` — the prefill analogue of [`decode_tpot`]. The LM head
/// prices one logits row per slot (the engine samples only when a prompt
/// completes), not one per prompt row. Feeds
/// `loadgen::ServiceModel::from_block`'s per-prefill-row slope.
pub fn prefill_tpot(
    model: &ModelConfig,
    rows: usize,
    seq: usize,
    scope: FusionScope,
    cluster_size: usize,
    hw: &Hardware,
    noc: &Noc,
) -> f64 {
    let p = BlockProblem::from_model(model, rows.max(1), seq);
    let env = CostEnv::clusterfusion(hw, noc, cluster_size);
    let block = cost(&p, scope, &env);
    let head = super::e2e::lm_head_cost(model, 1, hw, noc);
    block.latency * model.n_layers as f64 + head.latency
}

/// Can the functional pipeline run `model` at cluster size `n`? (The
/// dataflows partition `head_dim`/`d_model`/`max_seq` — and the latent
/// rank for MLA — evenly across the cluster.)
pub fn supports_cluster(model: &ModelConfig, n: usize) -> bool {
    n.is_power_of_two()
        && (1..=16).contains(&n)
        && model.head_dim % n == 0
        && model.d_model % n == 0
        && model.max_seq % n == 0
        && (model.attn == AttnKind::Mha || model.kv_lora_rank % n == 0)
}

/// One layer's weights packed for the functional pipeline.
enum PackedAttn {
    Mha(PackedMhaWeights),
    /// `w_down` stays row-major (its accesses are row-contiguous).
    Mla { w: PackedMlaWeights, w_down: Vec<f32> },
}

struct PackedLayer {
    attn_norm: Vec<f32>,
    attn: PackedAttn,
    mlp_norm: Vec<f32>,
    gate: PackedWeight,
    up: PackedWeight,
    down: PackedWeight,
}

/// The functional full-block decoder: materialized weights packed once
/// (the §Perf packed-weight lifetime — one `BlockModel` serves every
/// decode step of a serving run), token ids in, greedy-ready logits out.
pub struct BlockModel {
    cfg: ModelConfig,
    /// `(vocab, D)` row-major; also the tied logits head.
    embedding: Vec<f32>,
    final_norm: Vec<f32>,
    layers: Vec<PackedLayer>,
    pub cluster_size: usize,
    pub transport: Transport,
    /// Rotary base for MHA; `None` disables rotary. MLA is always NoPE
    /// here: the weight-absorbed latent path of Alg. 4 carries no
    /// separate rope dims in this reproduction (DESIGN.md §Block).
    pub rope_base: Option<f32>,
    hw: Hardware,
    noc: Noc,
}

impl BlockModel {
    /// Pack `weights` for decoding with the given cluster size. Takes the
    /// weights **by value**: the embedding, norm gains and the MLA down
    /// projection are moved (not copied), and each layer's raw GEMM
    /// tensors are dropped right after packing — peak memory is one raw
    /// copy plus one packed copy plus a single in-flight layer, which
    /// matters near `coordinator::functional_backend::MAX_FUNCTIONAL_PARAMS`.
    /// Callers that also need the raw weights (the differential tests)
    /// clone explicitly. Panics if the geometry does not divide by
    /// `cluster_size` (see [`supports_cluster`]).
    pub fn new(weights: MaterializedWeights, cluster_size: usize, transport: Transport) -> Self {
        let MaterializedWeights { config: cfg, embedding, layers: raw_layers, final_norm } =
            weights;
        assert!(
            supports_cluster(&cfg, cluster_size),
            "{}: cluster size {cluster_size} must divide head_dim/d_model/max_seq (and the MLA \
             latent rank)",
            cfg.name
        );
        let (d, f, h) = (cfg.d_model, cfg.ffn_dim, cfg.total_head_dim());
        let layers = raw_layers
            .into_iter()
            .map(|lw| PackedLayer {
                attn_norm: lw.attn_norm,
                attn: match lw.attn {
                    AttnWeights::Mha { wq, wk, wv, wo } => {
                        PackedAttn::Mha(PackedMhaWeights::pack(&wq, &wk, &wv, &wo, d, h))
                    }
                    AttnWeights::Mla { wq, wkv, w_down, wo } => PackedAttn::Mla {
                        w: PackedMlaWeights::pack(
                            &wq,
                            &wkv,
                            &wo,
                            d,
                            cfg.n_heads,
                            cfg.kv_lora_rank,
                            cfg.head_dim,
                        ),
                        w_down,
                    },
                },
                mlp_norm: lw.mlp_norm,
                gate: PackedWeight::pack(&lw.w_gate, d, f),
                up: PackedWeight::pack(&lw.w_up, d, f),
                down: PackedWeight::pack(&lw.w_down, f, d),
            })
            .collect();
        let hw = Hardware::h100_sxm5();
        let noc = Noc::h100(&hw);
        let rope_base = match cfg.attn {
            AttnKind::Mha => Some(ROPE_BASE),
            AttnKind::Mla => None,
        };
        Self {
            cfg,
            embedding,
            final_norm,
            layers,
            cluster_size,
            transport,
            rope_base,
            hw,
            noc,
        }
    }

    /// Materialize-and-pack in one step (seeded; see
    /// [`MaterializedWeights::materialize`]).
    pub fn from_config(cfg: &ModelConfig, seed: u64, cluster_size: usize) -> Self {
        Self::new(MaterializedWeights::materialize(cfg, seed), cluster_size, Transport::Dsmem)
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Cache planes (K and V for MHA; one latent plane for MLA).
    pub fn planes(&self) -> usize {
        match self.cfg.attn {
            AttnKind::Mha => 2,
            AttnKind::Mla => 1,
        }
    }

    /// Elements of one token's cache row per (layer, plane).
    pub fn row_elems(&self) -> usize {
        match self.cfg.attn {
            AttnKind::Mha => self.cfg.total_head_dim(),
            AttnKind::Mla => self.cfg.kv_lora_rank,
        }
    }

    /// One full-block decode step for a padded batch of `bucket` slots.
    ///
    /// `tokens`/`pos` are per-slot (padded slots compute garbage that the
    /// caller ignores — same contract as the AOT executables);
    /// `cache_planes[plane]` is the dense `(L, bucket, max_seq,
    /// row_elems)` gather the serving engine builds
    /// (`KvPool::gather_batch_into`). Returns `(logits, new_rows)` in the
    /// engine's `StepOut` layout: logits `(bucket, vocab)`, per plane
    /// `(L, bucket, row_elems)` new cache rows.
    pub fn decode_step(
        &self,
        tokens: &[i32],
        pos: &[i32],
        cache_planes: &[Vec<f32>],
        bucket: usize,
    ) -> (Vec<f32>, Vec<Vec<f32>>) {
        let (logits, new_rows, _) =
            self.decode_step_on(&Pool::serial(), tokens, pos, cache_planes, bucket);
        (logits, new_rows)
    }

    /// [`Self::decode_step`] on a worker [`Pool`] (DESIGN.md §Parallel):
    /// the attention sub-block fans its cluster blocks across the pool
    /// (`split_token::execute_packed_rope_on` / `mla::execute_packed_on`),
    /// the SwiGLU MLP's gate/up/down GEMMs partition their output columns
    /// (`linalg::matmul_rows_pooled`), and the tied-embedding logits head
    /// is sharded over contiguous vocab ranges — each shard computing its
    /// logits window plus a local argmax, merged in ascending-shard order
    /// with a strictly-greater comparison so the **lowest-index tie-break
    /// is preserved** (= `runtime::argmax` of the full row).
    ///
    /// Returns `(logits, new_rows, greedy)` where `greedy[bi]` is the
    /// merged per-shard argmax of slot `bi`'s logits row. All outputs are
    /// byte-identical across pool sizes (`tests/integration_parallel.rs`).
    /// A serial pool runs every kernel inline with no spawns; its single
    /// logits shard *becomes* the logits buffer (no extra copy), leaving
    /// only the O(vocab) argmax scan that powers `greedy` on top of the
    /// pre-pool serial path.
    pub fn decode_step_on(
        &self,
        pool: &Pool,
        tokens: &[i32],
        pos: &[i32],
        cache_planes: &[Vec<f32>],
        bucket: usize,
    ) -> (Vec<f32>, Vec<Vec<f32>>, Vec<usize>) {
        let cfg = &self.cfg;
        let (b, d, f, v) = (bucket, cfg.d_model, cfg.ffn_dim, cfg.vocab);
        let (nl, s, re) = (cfg.n_layers, cfg.max_seq, self.row_elems());
        let planes = self.planes();
        assert!(tokens.len() == b && pos.len() == b, "padded batch inputs");
        assert_eq!(cache_planes.len(), planes, "cache plane count");
        let plane_len = b * s * re;
        for p in cache_planes {
            assert_eq!(p.len(), nl * plane_len, "cache plane size");
        }
        let pos_us: Vec<usize> =
            pos.iter().map(|&p| (p.max(0) as usize).min(s)).collect();

        // Residual stream: h = embedding[token].
        let mut h = vec![0f32; b * d];
        for bi in 0..b {
            let t = tokens[bi].rem_euclid(v as i32) as usize;
            h[bi * d..(bi + 1) * d].copy_from_slice(&self.embedding[t * d..(t + 1) * d]);
        }

        let mut new_rows = vec![vec![0f32; nl * b * re]; planes];
        // Scratch reused across layers (allocation-free layer loop).
        let mut x = vec![0f32; b * d];
        let mut gate = vec![0f32; b * f];
        let mut up = vec![0f32; b * f];
        let mut act = vec![0f32; b * f];
        let mut down = vec![0f32; b * d];

        for (l, layer) in self.layers.iter().enumerate() {
            // -- attention sub-block (pre-norm) --
            for bi in 0..b {
                linalg::rmsnorm(
                    &h[bi * d..(bi + 1) * d],
                    &layer.attn_norm,
                    EPS,
                    &mut x[bi * d..(bi + 1) * d],
                );
            }
            let attn_out = match &layer.attn {
                PackedAttn::Mha(w) => {
                    let k = &cache_planes[0][l * plane_len..(l + 1) * plane_len];
                    let vc = &cache_planes[1][l * plane_len..(l + 1) * plane_len];
                    split_token::execute_packed_rope_on(
                        pool,
                        &x,
                        w,
                        k,
                        vc,
                        &pos_us,
                        b,
                        d,
                        cfg.n_heads,
                        cfg.head_dim,
                        s,
                        self.cluster_size,
                        self.transport,
                        &self.hw,
                        &self.noc,
                        self.rope_base,
                    )
                    .0
                }
                PackedAttn::Mla { w, w_down } => {
                    let kv = &cache_planes[0][l * plane_len..(l + 1) * plane_len];
                    mla::execute_packed_on(
                        pool,
                        &x,
                        w,
                        w_down,
                        kv,
                        &pos_us,
                        b,
                        d,
                        cfg.n_heads,
                        cfg.kv_lora_rank,
                        cfg.head_dim,
                        s,
                        self.cluster_size,
                        self.transport,
                        &self.hw,
                        &self.noc,
                    )
                    .0
                }
            };
            linalg::axpy(1.0, &attn_out.out, &mut h); // residual

            // New cache rows for this layer: k_new/v_new are (bucket,
            // row_elems) contiguous — exactly the (L, bucket, re) slice.
            new_rows[0][l * b * re..(l + 1) * b * re].copy_from_slice(&attn_out.k_new);
            if planes == 2 {
                new_rows[1][l * b * re..(l + 1) * b * re].copy_from_slice(&attn_out.v_new);
            }

            // -- SwiGLU MLP sub-block (pre-norm) --
            for bi in 0..b {
                linalg::rmsnorm(
                    &h[bi * d..(bi + 1) * d],
                    &layer.mlp_norm,
                    EPS,
                    &mut x[bi * d..(bi + 1) * d],
                );
            }
            linalg::matmul_rows_pooled(pool, &x, b, d, &layer.gate, 0, 0, f, &mut gate);
            linalg::matmul_rows_pooled(pool, &x, b, d, &layer.up, 0, 0, f, &mut up);
            linalg::silu_mul(&gate, &up, &mut act);
            linalg::matmul_rows_pooled(pool, &act, b, f, &layer.down, 0, 0, d, &mut down);
            linalg::axpy(1.0, &down, &mut h); // residual
        }

        // -- tied-embedding logits head on the final-normed rows --
        for bi in 0..b {
            linalg::rmsnorm(
                &h[bi * d..(bi + 1) * d],
                &self.final_norm,
                EPS,
                &mut x[bi * d..(bi + 1) * d],
            );
        }
        let (logits, greedy) = self.logits_head_on(pool, &x, b);
        (logits, new_rows, greedy)
    }

    /// The tied-embedding logits head (`x · Eᵀ` over final-normed rows),
    /// sharded over contiguous vocab ranges: the embedding rows are
    /// already column-contiguous for this product, so each shard runs
    /// the dot4 row tile over its own window (every logit keeps its
    /// single in-order dot chain — shard boundaries only change load
    /// sharing). Each shard also returns its local argmax per slot
    /// (lowest index on ties); the ascending-shard merge below keeps
    /// only strictly greater values, reproducing `runtime::argmax` of
    /// the full row bit-for-bit. Per-slot bits depend only on that
    /// slot's row, so decode batches and prefill last-row batches agree.
    fn logits_head_on(&self, pool: &Pool, x: &[f32], b: usize) -> (Vec<f32>, Vec<usize>) {
        let (d, v) = (self.cfg.d_model, self.cfg.vocab);
        let mut shards: Vec<(usize, Vec<f32>, Vec<usize>)> = pool.run_ranges(v, |t0, t1| {
            let span = t1 - t0;
            let mut chunk = vec![0f32; b * span];
            let mut local_arg = vec![0usize; b];
            for bi in 0..b {
                let hn = &x[bi * d..(bi + 1) * d];
                let row = |t: usize| &self.embedding[t * d..(t + 1) * d];
                let out = &mut chunk[bi * span..(bi + 1) * span];
                let mut t = t0;
                while t + 4 <= t1 {
                    let d4 = linalg::dot4(hn, row(t), row(t + 1), row(t + 2), row(t + 3));
                    out[t - t0..t - t0 + 4].copy_from_slice(&d4);
                    t += 4;
                }
                while t < t1 {
                    out[t - t0] = linalg::dot(hn, row(t));
                    t += 1;
                }
                local_arg[bi] = t0 + crate::runtime::argmax(out);
            }
            (t0, chunk, local_arg)
        });
        if shards.len() == 1 {
            // serial / single-worker: the lone shard IS the (b, vocab)
            // logits buffer and its local argmaxes the greedy picks
            let (_, logits, greedy) = shards.pop().expect("one shard");
            return (logits, greedy);
        }
        let mut logits = vec![0f32; b * v];
        let mut greedy = vec![0usize; b];
        for (si, (t0, chunk, local_arg)) in shards.iter().enumerate() {
            let span = chunk.len() / b;
            for bi in 0..b {
                logits[bi * v + t0..bi * v + t0 + span]
                    .copy_from_slice(&chunk[bi * span..(bi + 1) * span]);
                let cand = local_arg[bi];
                if si == 0
                    || logits[bi * v + cand].total_cmp(&logits[bi * v + greedy[bi]])
                        == std::cmp::Ordering::Greater
                {
                    greedy[bi] = cand;
                }
            }
        }
        (logits, greedy)
    }

    /// One multi-position step over `slots`: slot `i` feeds
    /// `slots[i].0` (its token rows) starting at absolute position
    /// `slots[i].1`, all slots flattened into one `T`-row chunk. Every
    /// GEMM stage — embeddings, QKV, gate/up/down — batches the whole
    /// chunk through the packed-weight kernels (one weight stream per
    /// step, the amortisation chunked prefill exists for), while
    /// attention runs causally per row through the *decode* all-heads
    /// core (`attend_heads_on`, `b == 1`), writing each roped row into
    /// the mutable planes so later rows of the chunk attend to earlier
    /// ones.
    ///
    /// Per-slot outputs are byte-identical to feeding the same rows one
    /// per step through [`Self::decode_step_on`] (the retired
    /// decode-as-prefill path): every stage is row- or slot-local, the
    /// per-row accumulation orders are unchanged, and the plane writes
    /// carry the same bits the decode path round-trips through the paged
    /// pool — pinned by `tests/integration_prefill.rs`. Decode slots are
    /// simply single-row entries, so one call serves a mixed
    /// prefill/decode batch.
    ///
    /// `cache_planes[plane]` is the dense `(L, bucket, max_seq,
    /// row_elems)` gather, mutated in place with the chunk's roped rows.
    /// Returns `(logits, new_rows, greedy)`: logits `(slots.len(),
    /// vocab)` from each slot's **last** fed row, per plane `(L, T,
    /// row_elems)` new cache rows in feed order.
    pub fn prefill_on(
        &self,
        pool: &Pool,
        slots: &[(&[i32], usize)],
        cache_planes: &mut [Vec<f32>],
        bucket: usize,
    ) -> (Vec<f32>, Vec<Vec<f32>>, Vec<usize>) {
        let cfg = &self.cfg;
        let (d, f, v) = (cfg.d_model, cfg.ffn_dim, cfg.vocab);
        let (nl, s, re) = (cfg.n_layers, cfg.max_seq, self.row_elems());
        let planes = self.planes();
        let n_slots = slots.len();
        assert!(n_slots >= 1 && n_slots <= bucket, "1..=bucket live slots");
        assert_eq!(cache_planes.len(), planes, "cache plane count");
        let plane_len = bucket * s * re;
        for p in cache_planes.iter() {
            assert_eq!(p.len(), nl * plane_len, "cache plane size");
        }
        // Row maps: flattened-chunk row j lives in plane slot
        // `row_slot[j]` at absolute position `row_pos[j]` (slot-major,
        // feed order).
        let mut row_slot = Vec::new();
        let mut row_pos = Vec::new();
        for (i, (toks, pos0)) in slots.iter().enumerate() {
            assert!(!toks.is_empty(), "slot {i}: at least one row per step");
            assert!(pos0 + toks.len() <= s, "slot {i}: rows past max_seq");
            for j in 0..toks.len() {
                row_slot.push(i);
                row_pos.push(pos0 + j);
            }
        }
        let t_rows = row_slot.len();

        // Residual stream: h = embedding[token], all chunk rows at once.
        let mut h = vec![0f32; t_rows * d];
        let mut r = 0usize;
        for (toks, _) in slots {
            for &tok in *toks {
                let t = tok.rem_euclid(v as i32) as usize;
                h[r * d..(r + 1) * d].copy_from_slice(&self.embedding[t * d..(t + 1) * d]);
                r += 1;
            }
        }

        let mut new_rows = vec![vec![0f32; nl * t_rows * re]; planes];
        // Scratch reused across layers (allocation-free layer loop).
        let mut x = vec![0f32; t_rows * d];
        let mut gate = vec![0f32; t_rows * f];
        let mut up = vec![0f32; t_rows * f];
        let mut act = vec![0f32; t_rows * f];
        let mut down = vec![0f32; t_rows * d];

        for (l, layer) in self.layers.iter().enumerate() {
            // -- attention sub-block (pre-norm), whole chunk --
            for r in 0..t_rows {
                linalg::rmsnorm(
                    &h[r * d..(r + 1) * d],
                    &layer.attn_norm,
                    EPS,
                    &mut x[r * d..(r + 1) * d],
                );
            }
            let attn_out = match &layer.attn {
                PackedAttn::Mha(w) => {
                    let (k_all, rest) = cache_planes.split_first_mut().expect("two planes");
                    split_token::prefill_packed_rope_on(
                        pool,
                        &x,
                        w,
                        &mut k_all[l * plane_len..(l + 1) * plane_len],
                        &mut rest[0][l * plane_len..(l + 1) * plane_len],
                        &row_slot,
                        &row_pos,
                        d,
                        cfg.n_heads,
                        cfg.head_dim,
                        s,
                        self.cluster_size,
                        self.transport,
                        &self.hw,
                        &self.noc,
                        self.rope_base,
                    )
                    .0
                }
                PackedAttn::Mla { w, w_down } => mla::prefill_packed_on(
                    pool,
                    &x,
                    w,
                    w_down,
                    &mut cache_planes[0][l * plane_len..(l + 1) * plane_len],
                    &row_slot,
                    &row_pos,
                    d,
                    cfg.n_heads,
                    cfg.kv_lora_rank,
                    cfg.head_dim,
                    s,
                    self.cluster_size,
                    self.transport,
                    &self.hw,
                    &self.noc,
                )
                .0,
            };
            linalg::axpy(1.0, &attn_out.out, &mut h); // residual

            // New cache rows: k_new/v_new are (T, row_elems) in feed
            // order — exactly the (L, T, re) slice.
            new_rows[0][l * t_rows * re..(l + 1) * t_rows * re]
                .copy_from_slice(&attn_out.k_new);
            if planes == 2 {
                new_rows[1][l * t_rows * re..(l + 1) * t_rows * re]
                    .copy_from_slice(&attn_out.v_new);
            }

            // -- SwiGLU MLP sub-block (pre-norm), whole chunk --
            for r in 0..t_rows {
                linalg::rmsnorm(
                    &h[r * d..(r + 1) * d],
                    &layer.mlp_norm,
                    EPS,
                    &mut x[r * d..(r + 1) * d],
                );
            }
            linalg::matmul_rows_pooled(pool, &x, t_rows, d, &layer.gate, 0, 0, f, &mut gate);
            linalg::matmul_rows_pooled(pool, &x, t_rows, d, &layer.up, 0, 0, f, &mut up);
            linalg::silu_mul(&gate, &up, &mut act);
            linalg::matmul_rows_pooled(pool, &act, t_rows, f, &layer.down, 0, 0, d, &mut down);
            linalg::axpy(1.0, &down, &mut h); // residual
        }

        // -- logits only for each slot's LAST fed row (the engine
        // samples the moment a prompt completes; intermediate prompt
        // rows never needed logits in the decode-as-prefill path either) --
        let mut xl = vec![0f32; n_slots * d];
        let mut base = 0usize;
        for (i, (toks, _)) in slots.iter().enumerate() {
            let last = base + toks.len() - 1;
            linalg::rmsnorm(
                &h[last * d..(last + 1) * d],
                &self.final_norm,
                EPS,
                &mut xl[i * d..(i + 1) * d],
            );
            base += toks.len();
        }
        let (logits, greedy) = self.logits_head_on(pool, &xl, n_slots);
        (logits, new_rows, greedy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> (Hardware, Noc) {
        let hw = Hardware::h100_sxm5();
        let noc = Noc::h100(&hw);
        (hw, noc)
    }

    #[test]
    fn scopes_agree_on_flops_and_are_traffic_monotone() {
        let (hw, noc) = env();
        for model in [
            ModelConfig::llama2_7b(),
            ModelConfig::deepseek_v2_lite(),
            ModelConfig::micro_llama(),
            ModelConfig::micro_mla(),
        ] {
            for n in [1usize, 2, 4] {
                if !supports_cluster(&model, n) {
                    continue;
                }
                let seq = model.max_seq.min(4096);
                let p = BlockProblem::from_model(&model, 1, seq);
                let e = CostEnv::clusterfusion(&hw, &noc, n);
                let iso = cost(&p, FusionScope::BlockIsolated, &e);
                let att = cost(&p, FusionScope::AttentionFused, &e);
                let ful = cost(&p, FusionScope::FullBlockFused, &e);
                assert_eq!(iso.flops, att.flops, "{} n={n}", model.name);
                assert_eq!(att.flops, ful.flops, "{} n={n}", model.name);
                assert!(ful.hbm_bytes <= att.hbm_bytes, "{} n={n}", model.name);
                assert!(att.hbm_bytes <= iso.hbm_bytes, "{} n={n}", model.name);
                assert!(ful.launches < att.launches && att.launches < iso.launches);
            }
        }
    }

    #[test]
    fn latency_monotone_at_tuned_cluster_size() {
        let (hw, noc) = env();
        for (model, n) in [
            (ModelConfig::llama2_7b(), 4usize),
            (ModelConfig::deepseek_v2_lite(), 4),
            (ModelConfig::micro_llama(), 2),
            (ModelConfig::micro_mla(), 2),
        ] {
            let seq = model.max_seq.min(4096);
            let p = BlockProblem::from_model(&model, 1, seq);
            let e = CostEnv::clusterfusion(&hw, &noc, n);
            let iso = cost(&p, FusionScope::BlockIsolated, &e).latency;
            let att = cost(&p, FusionScope::AttentionFused, &e).latency;
            let ful = cost(&p, FusionScope::FullBlockFused, &e).latency;
            assert!(ful <= att && att <= iso, "{}: {ful} / {att} / {iso}", model.name);
        }
    }

    #[test]
    fn decode_tpot_sane_and_ordered_for_llama() {
        let (hw, noc) = env();
        let m = ModelConfig::llama2_7b();
        let iso = decode_tpot(&m, 1, 4096, FusionScope::BlockIsolated, 4, &hw, &noc);
        let ful = decode_tpot(&m, 1, 4096, FusionScope::FullBlockFused, 4, &hw, &noc);
        assert!(ful < iso, "{ful} vs {iso}");
        assert!(ful > 2e-3 && ful < 30e-3, "{ful}");
    }

    #[test]
    fn functional_step_is_deterministic_and_shaped() {
        let cfg = ModelConfig::micro_llama();
        let model = BlockModel::from_config(&cfg, 42, 2);
        let (b, s, re) = (2usize, cfg.max_seq, model.row_elems());
        let planes = vec![vec![0f32; cfg.n_layers * b * s * re]; model.planes()];
        let (logits, rows) = model.decode_step(&[3, 7], &[0, 0], &planes, b);
        assert_eq!(logits.len(), b * cfg.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].len(), cfg.n_layers * b * re);
        let (logits2, rows2) = model.decode_step(&[3, 7], &[0, 0], &planes, b);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&logits), bits(&logits2), "same inputs -> same bits");
        assert_eq!(bits(&rows[0]), bits(&rows2[0]));
        // different tokens in the two slots -> different logits rows
        assert_ne!(
            bits(&logits[..cfg.vocab]),
            bits(&logits[cfg.vocab..]),
            "distinct tokens must not collide"
        );
    }

    #[test]
    fn functional_mla_single_plane() {
        let cfg = ModelConfig::micro_mla();
        let model = BlockModel::from_config(&cfg, 42, 2);
        assert_eq!(model.planes(), 1);
        assert_eq!(model.row_elems(), cfg.kv_lora_rank);
        let (b, s, re) = (1usize, cfg.max_seq, model.row_elems());
        let planes = vec![vec![0f32; cfg.n_layers * b * s * re]];
        let (logits, rows) = model.decode_step(&[11], &[0], &planes, b);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(rows.len(), 1);
        assert!(rows[0].iter().any(|&v| v != 0.0), "latent rows must be written");
    }

    #[test]
    fn cluster_size_does_not_change_greedy_token() {
        // The functional dataflows agree across cluster sizes to fp32
        // tolerance; greedy argmax over well-separated random logits must
        // therefore agree exactly.
        let cfg = ModelConfig::micro_llama();
        let (b, s) = (1usize, cfg.max_seq);
        let mut toks = Vec::new();
        for n in [1usize, 2, 4] {
            let model = BlockModel::from_config(&cfg, 42, n);
            let planes = vec![vec![0f32; cfg.n_layers * b * s * model.row_elems()]; 2];
            let (logits, _) = model.decode_step(&[5], &[0], &planes, b);
            toks.push(crate::runtime::argmax(&logits));
        }
        assert!(toks.windows(2).all(|w| w[0] == w[1]), "{toks:?}");
    }
}
