//! Per-kernel roofline cost model.
//!
//! Decode-phase kernels are scored as
//! `launch + max(compute_time, memory_time) + boundary_sync`, the standard
//! decode-latency decomposition: auto-regressive decoding is memory-bound
//! (§2.1), so HBM bytes dominate, but the compute term matters at large
//! batch (Appendix C: "overall computation intensity increases
//! significantly with larger batch sizes, leading to a reduced speedup").
//!
//! Occupancy: a kernel that can only use `active_sms` of the device's SMs
//! (clusters gang-schedule, Fig. 5 right) achieves a proportional fraction
//! of both peak bandwidth and peak compute.


use super::hw::Hardware;

/// Resource footprint of one kernel launch.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KernelSpec {
    /// Floating-point operations executed.
    pub flops: f64,
    /// Bytes read from + written to HBM.
    pub hbm_bytes: f64,
    /// Fraction of device SMs this kernel can occupy (0, 1].
    pub sm_fraction: f64,
    /// Whether the launch is a CUDA-graph replay node (cheap) or raw.
    pub graph_launch: bool,
}

impl KernelSpec {
    pub fn new(flops: f64, hbm_bytes: f64) -> Self {
        Self { flops, hbm_bytes, sm_fraction: 1.0, graph_launch: true }
    }

    pub fn with_sm_fraction(mut self, f: f64) -> Self {
        assert!(f > 0.0 && f <= 1.0);
        self.sm_fraction = f;
        self
    }
}

/// Cost breakdown of one kernel execution.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KernelCost {
    pub launch: f64,
    pub compute: f64,
    pub memory: f64,
    pub sync: f64,
}

impl KernelCost {
    /// Wall-clock seconds: launch + roofline max + boundary sync.
    pub fn total(&self) -> f64 {
        self.launch + self.compute.max(self.memory) + self.sync
    }

    /// Whether HBM bandwidth (not compute) bounds this kernel.
    pub fn memory_bound(&self) -> bool {
        self.memory >= self.compute
    }
}

/// Evaluate a kernel on the hardware model.
pub fn kernel_cost(spec: &KernelSpec, hw: &Hardware) -> KernelCost {
    let frac = spec.sm_fraction;
    KernelCost {
        launch: if spec.graph_launch { hw.graph_kernel_launch } else { hw.raw_kernel_launch },
        compute: hw.compute_time(spec.flops) / frac,
        memory: hw.hbm_time(spec.hbm_bytes) / frac + hw.gmem_latency(),
        sync: hw.kernel_boundary_sync,
    }
}

/// Aggregate cost of a *sequence* of dependent kernels (one stream): each
/// kernel pays its own launch and boundary sync — this is exactly the
/// fragmentation the paper's fusion removes.
pub fn pipeline_cost(specs: &[KernelSpec], hw: &Hardware) -> (f64, usize) {
    let total = specs.iter().map(|s| kernel_cost(s, hw).total()).sum();
    (total, specs.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_gemv_is_memory_bound() {
        // bs=1 hidden-proj GEMV: 2*D*H flops, (D*H)*2 bytes of weights.
        let hw = Hardware::h100_sxm5();
        let d = 4096.0;
        let spec = KernelSpec::new(2.0 * d * d, d * d * 2.0);
        let c = kernel_cost(&spec, &hw);
        assert!(c.memory_bound());
    }

    #[test]
    fn large_batch_becomes_compute_heavier() {
        let hw = Hardware::h100_sxm5();
        let d = 4096.0;
        let bytes = d * d * 2.0; // weights read once regardless of batch
        let c1 = kernel_cost(&KernelSpec::new(2.0 * d * d, bytes), &hw);
        let c256 = kernel_cost(&KernelSpec::new(256.0 * 2.0 * d * d, bytes), &hw);
        assert!(c256.compute / c256.memory > 10.0 * (c1.compute / c1.memory));
    }

    #[test]
    fn fewer_kernels_fewer_overheads() {
        let hw = Hardware::h100_sxm5();
        let one = vec![KernelSpec::new(1e9, 1e6)];
        let four = vec![KernelSpec::new(0.25e9, 0.25e6); 4];
        let (t1, n1) = pipeline_cost(&one, &hw);
        let (t4, n4) = pipeline_cost(&four, &hw);
        assert_eq!((n1, n4), (1, 4));
        assert!(t4 > t1, "fragmentation must cost: {t4} vs {t1}");
    }

    #[test]
    fn reduced_occupancy_slows_kernel() {
        let hw = Hardware::h100_sxm5();
        let full = kernel_cost(&KernelSpec::new(1e9, 1e8), &hw);
        let half = kernel_cost(&KernelSpec::new(1e9, 1e8).with_sm_fraction(0.5), &hw);
        assert!(half.total() > full.total());
    }
}
