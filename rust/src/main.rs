//! `clusterfusion` CLI — leader entrypoint.
//!
//! Subcommands:
//!   serve              run the serving engine on a synthetic trace
//!   simulate           TPOT estimate for a model/framework/seq grid
//!   inspect-artifacts  list AOT executables from the manifest
//!   bench --figure ID  hint to the cargo-bench target for a paper figure
//!
//! Hand-rolled argument parsing (offline build; no clap).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use clusterfusion::clustersim::block::FusionScope;
use clusterfusion::clustersim::e2e::{decode_step, Engine as SimEngine};
use clusterfusion::clustersim::frameworks::FrameworkProfile;
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::coordinator::admission::AdmissionConfig;
use clusterfusion::coordinator::config::{BackendKind, ServeConfig};
use clusterfusion::coordinator::engine::{Backend, Engine, MockBackend, ModelGeom};
use clusterfusion::coordinator::fleet::{FaultPlan, Fleet, FleetServer};
use clusterfusion::coordinator::pjrt_backend::PjrtBackend;
use clusterfusion::coordinator::request::{Event, FinishReason, Request};
use clusterfusion::coordinator::server::{Server, ServerReport};
use clusterfusion::coordinator::FunctionalBackend;
use clusterfusion::loadgen;
use clusterfusion::metrics::Table;
use clusterfusion::models::ModelConfig;
use clusterfusion::obs::{kernel_stages_for, Obs};
use clusterfusion::runtime::ArtifactManifest;
use clusterfusion::util::clock::{Clock, WallClock};
use clusterfusion::workload::{SeqlenDist, Trace};

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if let Some((k, v)) = key.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 1;
            } else {
                flags.insert(key.to_string(), "true".to_string());
            }
        } else {
            positional.push(a.clone());
        }
        i += 1;
    }
    (positional, flags)
}

fn usage() -> ! {
    eprintln!(
        "usage: clusterfusion <command> [flags]\n\
         \n\
         commands:\n\
         \x20 serve             --model NAME --requests N --rps R\n\
         \x20                   [--backend functional|pjrt|mock] [--mock]\n\
         \x20                   [--threads N]  (0 = auto; functional backend)\n\
         \x20                   [--prefill-chunk N]  (0 = one-shot prefill)\n\
         \x20                   [--slo-ttft-ms X]  (reject when projected TTFT > X; 0 = off)\n\
         \x20                   [--slo-tpot-us N]  (cap decode width to meet TPOT; 0 = off)\n\
         \x20                   [--replicas N]  (fleet of N engines behind the router)\n\
         \x20                   [--fault-plan SPEC]  (e.g. stall:0@40000+30000;crash:1@80000 —\n\
         \x20                    selects the deterministic virtual-clock fleet replay;\n\
         \x20                    fault_* keys via --set tune detection/retries)\n\
         \x20                   [--trace-out PATH]  (Chrome trace-event JSON of the run)\n\
         \x20                   [--metrics-out PATH]  (Prometheus text metrics snapshot)\n\
         \x20                   [--config FILE] [--set k=v]  (default: functional)\n\
         \x20 simulate          --model NAME [--seq N] [--batch N] [--cluster N]\n\
         \x20 inspect-artifacts [--artifacts DIR]\n\
         \x20 bench             --figure fig17|table1|... (prints the cargo command)\n"
    );
    std::process::exit(2);
}

fn cmd_inspect(flags: &HashMap<String, String>) -> Result<()> {
    let dir = flags.get("artifacts").map(String::as_str).unwrap_or("artifacts");
    let m = ArtifactManifest::load(format!("{dir}/manifest.json"))?;
    let mut t = Table::new(vec!["file", "model", "batch", "serving", "inputs", "params(M)"]);
    for e in &m.executables {
        t.row(vec![
            e.file.clone(),
            e.model.clone(),
            e.batch.to_string(),
            e.serving.to_string(),
            e.inputs.len().to_string(),
            format!("{:.1}", e.param_elems() as f64 / 1e6),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<()> {
    let model_name = flags.get("model").map(String::as_str).unwrap_or("llama2-7b");
    let model = ModelConfig::by_name(model_name)
        .with_context(|| format!("unknown model {model_name}"))?;
    let seq: usize = flags.get("seq").map(|s| s.parse()).transpose()?.unwrap_or(4096);
    let batch: usize = flags.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let cluster: usize = flags.get("cluster").map(|s| s.parse()).transpose()?.unwrap_or(4);

    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let mut t = Table::new(vec!["framework", "TPOT(ms)", "core(ms)", "launches", "HBM(GB)"]);
    for p in FrameworkProfile::baselines() {
        let e = decode_step(&model, batch, seq, SimEngine::BlockIsolated, &p, &hw, &noc);
        t.row(vec![
            p.name.to_string(),
            format!("{:.3}", e.tpot * 1e3),
            format!("{:.3}", e.core_modules * 1e3),
            e.launches.to_string(),
            format!("{:.2}", e.hbm_bytes / 1e9),
        ]);
    }
    let cf = decode_step(
        &model,
        batch,
        seq,
        SimEngine::ClusterFusion { cluster_size: cluster },
        &FrameworkProfile::clusterfusion(),
        &hw,
        &noc,
    );
    t.row(vec![
        format!("ClusterFusion(N={cluster})"),
        format!("{:.3}", cf.tpot * 1e3),
        format!("{:.3}", cf.core_modules * 1e3),
        cf.launches.to_string(),
        format!("{:.2}", cf.hbm_bytes / 1e9),
    ]);
    println!("model={} batch={batch} seq={seq}", model.name);
    t.print();
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let mut cfg = match flags.get("config") {
        Some(path) => ServeConfig::from_file(path)?,
        None => ServeConfig::default(),
    };
    if let Some(m) = flags.get("model") {
        cfg.model = m.clone();
    }
    if let Some(b) = flags.get("backend") {
        cfg.backend = BackendKind::parse(b)?;
    }
    if let Some(t) = flags.get("threads") {
        cfg.threads = t.parse().context("--threads expects an integer (0 = auto)")?;
    }
    if let Some(c) = flags.get("prefill-chunk") {
        cfg.prefill_chunk =
            c.parse().context("--prefill-chunk expects an integer (0 = one-shot)")?;
    }
    if let Some(s) = flags.get("slo-ttft-ms") {
        cfg.slo_ttft_ms = s.parse().context("--slo-ttft-ms expects a number (0 = off)")?;
    }
    if let Some(s) = flags.get("slo-tpot-us") {
        cfg.slo_tpot_us = s.parse().context("--slo-tpot-us expects an integer (0 = off)")?;
    }
    if flags.contains_key("mock") {
        cfg.backend = BackendKind::Mock;
    }
    if let Some(r) = flags.get("replicas") {
        cfg.replicas = r.parse().context("--replicas expects an integer >= 1")?;
    }
    if let Some(p) = flags.get("fault-plan") {
        cfg.fault_plan = p.clone();
    }
    if let Some(p) = flags.get("trace-out") {
        cfg.trace_out = p.clone();
    }
    if let Some(p) = flags.get("metrics-out") {
        cfg.metrics_out = p.clone();
    }
    if let Some(sets) = flags.get("set") {
        for kv in sets.split(',') {
            let (k, v) = kv.split_once('=').context("--set expects k=v[,k=v...]")?;
            cfg.set(k, v)?;
        }
    }
    cfg.validate()?;
    let n_requests: usize = flags.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let rps: f64 = flags.get("rps").map(|s| s.parse()).transpose()?.unwrap_or(4.0);

    // Backend selection is explicit and announced — nothing silently
    // degrades to the mock (it hides behind --mock / --backend mock).
    match cfg.backend {
        BackendKind::Functional => {
            // Virtual-clock fleet replay pins the functional pool serial:
            // one thread, one writer of time (DESIGN.md §4). Outputs are
            // byte-identical at every pool size, so this costs nothing.
            let threads = if cfg.fault_plan.is_empty() { cfg.threads } else { 1 };
            let mk = || {
                FunctionalBackend::from_model_name_on(
                    &cfg.model,
                    cfg.seed,
                    cfg.cluster_size,
                    threads,
                )
            };
            if !cfg.fault_plan.is_empty() {
                serve_fleet_replay(mk, &cfg, n_requests, rps)
            } else if cfg.replicas > 1 {
                serve_fleet_threaded(mk, &cfg, n_requests, rps)
            } else {
                let backend = mk()?;
                // describe() carries the active thread count (--threads N /
                // threads=N, 0 = auto; outputs byte-identical at every size)
                eprintln!("backend: {}", backend.describe());
                serve_backend(backend, &cfg, n_requests, rps)
            }
        }
        BackendKind::Pjrt => {
            // The config default (micro-llama) is a functional-path model
            // with no AOT artifacts; a PJRT run that never chose a model
            // (not via --model, --set, or a config file) gets the
            // compiled demo model instead of an unknown-model error.
            let model_chosen = flags.contains_key("model")
                || flags.contains_key("config")
                || flags
                    .get("set")
                    .is_some_and(|s| s.split(',').any(|kv| kv.trim().starts_with("model=")));
            if !model_chosen {
                eprintln!("backend pjrt: no --model given, using tiny-llama-100m");
                cfg.model = "tiny-llama-100m".into();
            }
            eprintln!("loading {} from {} ...", cfg.model, cfg.artifacts);
            let mk = || PjrtBackend::load(&cfg.artifacts, &cfg.model, cfg.seed);
            if !cfg.fault_plan.is_empty() {
                serve_fleet_replay(mk, &cfg, n_requests, rps)
            } else if cfg.replicas > 1 {
                serve_fleet_threaded(mk, &cfg, n_requests, rps)
            } else {
                let backend = mk()?;
                eprintln!("backend: PJRT, platform {}", backend.platform());
                serve_backend(backend, &cfg, n_requests, rps)
            }
        }
        BackendKind::Mock => {
            eprintln!("backend: MOCK (deterministic echo — demo only, not real decoding)");
            let mk = || Ok(MockBackend::tiny());
            if !cfg.fault_plan.is_empty() {
                serve_fleet_replay(mk, &cfg, n_requests, rps)
            } else if cfg.replicas > 1 {
                serve_fleet_threaded(mk, &cfg, n_requests, rps)
            } else {
                serve_backend(MockBackend::tiny(), &cfg, n_requests, rps)
            }
        }
    }
}

/// The step-cost model serving prices projections (and virtual-clock
/// fleet replay bills) against: the whole-block cost model when the
/// model is known to it, else a flat 1 ms TPOT.
fn service_model_for(cfg: &ServeConfig, max_seq: usize) -> loadgen::ServiceModel {
    match ModelConfig::by_name(&cfg.model) {
        Some(m) => {
            let hw = Hardware::h100_sxm5();
            let noc = Noc::h100(&hw);
            loadgen::ServiceModel::from_block(
                &m,
                max_seq,
                FusionScope::FullBlockFused,
                cfg.cluster_size,
                &hw,
                &noc,
            )
        }
        None => loadgen::ServiceModel::from_tpot_us(1_000),
    }
}

fn admission_for(cfg: &ServeConfig, service: loadgen::ServiceModel) -> AdmissionConfig {
    AdmissionConfig {
        max_batch_total_tokens: cfg.max_batch_total_tokens,
        waiting_served_ratio: cfg.waiting_served_ratio,
        max_waiting_steps: cfg.max_waiting_steps,
        slo_ttft_us: (cfg.slo_ttft_ms * 1_000.0).round() as u64,
        slo_tpot_us: cfg.slo_tpot_us,
        service,
    }
}

/// Build the run's trace/metrics sink when `--trace-out` or
/// `--metrics-out` asked for one, with the synthetic kernel schedule
/// installed for models the cost model knows (same scope the service
/// model bills: the fused whole block).
fn obs_for(cfg: &ServeConfig, max_seq: usize) -> Option<Obs> {
    if cfg.trace_out.is_empty() && cfg.metrics_out.is_empty() {
        return None;
    }
    let obs = Obs::new();
    if let Some(m) = ModelConfig::by_name(&cfg.model) {
        obs.set_kernel_stages(kernel_stages_for(
            &m,
            max_seq,
            FusionScope::FullBlockFused,
            cfg.cluster_size,
        ));
    }
    Some(obs)
}

/// Write the requested exports (no-op for empty paths).
fn write_obs_exports(obs: &Obs, cfg: &ServeConfig) -> Result<()> {
    if !cfg.trace_out.is_empty() {
        std::fs::write(&cfg.trace_out, obs.chrome_trace())
            .with_context(|| format!("writing {}", cfg.trace_out))?;
        eprintln!("trace written to {} (chrome://tracing / Perfetto)", cfg.trace_out);
    }
    if !cfg.metrics_out.is_empty() {
        std::fs::write(&cfg.metrics_out, obs.prometheus())
            .with_context(|| format!("writing {}", cfg.metrics_out))?;
        eprintln!("metrics written to {}", cfg.metrics_out);
    }
    Ok(())
}

/// The synthetic open-loop trace every serve mode replays (fixed seeds:
/// fleet replay renders must be reproducible run to run).
fn serve_trace(geom: &ModelGeom, n: usize, rps: f64) -> Vec<Request> {
    let trace = Trace::poisson(n, rps, SeqlenDist::ShareGpt, (8, 24), geom.max_seq / 4, 42);
    // Clamp generation budgets so prompt + max_new always fits max_seq:
    // the front door rejects requests that could never fit the context
    // window, and the synthetic trace must not manufacture those.
    let max_gen = 24.min(geom.max_seq.saturating_sub(geom.max_seq / 4)).max(1);
    eprintln!(
        "replaying {} requests open-loop: offered {:.2} rps over {:.2}s",
        trace.requests.len(),
        trace.achieved_rps(),
        trace.span_us() as f64 / 1e6
    );
    loadgen::synthesize_requests(&trace, geom.vocab, 64, max_gen, 7)
}

/// Deterministic multi-replica replay on one shared virtual clock,
/// executing the configured fault plan (`coordinator::fleet::Fleet`).
fn serve_fleet_replay<B: Backend>(
    mut make_backend: impl FnMut() -> Result<B>,
    cfg: &ServeConfig,
    n_requests: usize,
    rps: f64,
) -> Result<()> {
    let plan = FaultPlan::parse(&cfg.fault_plan)?;
    let opts = cfg.fleet_options()?;
    let mut backends = Vec::with_capacity(cfg.replicas);
    for _ in 0..cfg.replicas {
        backends.push(make_backend()?);
    }
    let geom = backends[0].geom();
    let service = service_model_for(cfg, geom.max_seq);
    let admission = admission_for(cfg, service);
    let mut backends = backends.into_iter();
    let mut fleet = Fleet::build(cfg.replicas, plan.clone(), opts, |clock| {
        let mut e = Engine::with_clock(
            backends.next().expect("one backend per replica"),
            cfg.pool_pages,
            cfg.page_tokens,
            cfg.admit_fraction,
            clock,
        );
        e.set_prefill_chunk(cfg.prefill_chunk);
        e.set_admission(admission);
        e
    });
    let obs = obs_for(cfg, geom.max_seq);
    if let Some(o) = &obs {
        fleet.set_obs(o.clone());
    }
    eprintln!(
        "fleet replay: {} replicas, fault plan '{}' (virtual clock, deterministic)",
        cfg.replicas,
        plan.render()
    );
    let requests = serve_trace(&geom, n_requests, rps);
    let report = fleet.replay(&requests, &service, 10_000_000)?;
    print!("{}", report.render());
    if let Some(o) = &obs {
        write_obs_exports(o, cfg)?;
    }
    Ok(())
}

/// Threaded fleet on the wall clock: one engine thread per replica behind
/// the router, with reactive failover (`coordinator::fleet::FleetServer`).
fn serve_fleet_threaded<B: Backend + Send + 'static>(
    mut make_backend: impl FnMut() -> Result<B>,
    cfg: &ServeConfig,
    n_requests: usize,
    rps: f64,
) -> Result<()> {
    let opts = cfg.fleet_options()?;
    let mut engines = Vec::with_capacity(cfg.replicas);
    let mut geom = None;
    let mut obs = None;
    for i in 0..cfg.replicas {
        let backend = make_backend()?;
        let g = *geom.get_or_insert(backend.geom());
        let mut e = Engine::new(backend, cfg.pool_pages, cfg.page_tokens, cfg.admit_fraction);
        e.set_prefill_chunk(cfg.prefill_chunk);
        e.set_admission(admission_for(cfg, service_model_for(cfg, g.max_seq)));
        if i == 0 {
            obs = obs_for(cfg, g.max_seq);
        }
        if let Some(o) = &obs {
            // Wall-clock path: timestamps are real µs, so the trace is
            // NOT byte-stable — only the virtual-clock fleet replay is.
            e.set_obs(o.clone(), i);
        }
        engines.push(e);
    }
    let geom = geom.expect("replicas >= 1");
    let fleet = FleetServer::spawn(engines, &opts);
    eprintln!("fleet: {} replicas behind the router (wall clock)", fleet.replicas());
    let requests = serve_trace(&geom, n_requests, rps);
    let clock = WallClock::new();
    let mut streams = Vec::with_capacity(requests.len());
    let mut saturated = 0u64;
    for r in &requests {
        clock.sleep_until_us(r.arrival_us);
        match fleet.submit(r.clone()) {
            Ok(rx) => streams.push((r.id, rx)),
            Err(_) => saturated += 1, // router back-pressure: no eligible replica
        }
    }
    let (mut tokens, mut failed) = (0u64, 0u64);
    for (id, rx) in streams {
        for ev in rx.iter() {
            match ev {
                Event::Token { .. } | Event::FirstToken { .. } => tokens += 1,
                Event::Finished { reason: FinishReason::Failed, .. } => failed += 1,
                Event::Finished { .. } => {}
            }
        }
        fleet.finished(id);
    }
    let wall = clock.now_us() as f64 / 1e6;
    let stats = fleet.stats();
    let reports = fleet.shutdown()?;
    let completed: usize = reports.iter().map(|r| r.timings.len()).sum();
    let steps: u64 = reports.iter().map(|r| r.steps).sum();
    println!(
        "fleet served {completed} requests ({saturated} saturated, {failed} failed, \
         {} rejected at the front door), {tokens} tokens in {wall:.2}s ({:.2} tok/s), \
         {steps} engine steps",
        reports.iter().map(|r| r.rejected).sum::<u64>(),
        tokens as f64 / wall
    );
    println!(
        "router: routed={} rejected={} failed={} (spurious {}/{}/{}/{})",
        stats.routed,
        stats.rejected,
        stats.failed,
        stats.spurious_starts,
        stats.spurious_finishes,
        stats.spurious_fails,
        stats.spurious_routes
    );
    for (i, r) in reports.iter().enumerate() {
        println!(
            "-- replica {i}: {} completed, {} steps, {} tokens, {} preemptions, \
             {} deadline-expired",
            r.timings.len(),
            r.steps,
            r.tokens_out,
            r.preemptions,
            r.deadline_expired
        );
    }
    let all: Vec<_> = reports.iter().flat_map(|r| r.timings.iter().cloned()).collect();
    println!("latency percentiles (queue / ttft / tpot / e2e):");
    print!("{}", loadgen::percentiles(&all).render());
    if let Some(o) = &obs {
        for (i, r) in reports.iter().enumerate() {
            sync_server_report(o, i, r);
        }
        write_obs_exports(o, cfg)?;
    }
    Ok(())
}

/// Fold a threaded-server report into the registry (the engines were
/// consumed by their threads, so the sync reads the report instead).
fn sync_server_report(obs: &Obs, replica: usize, r: &ServerReport) {
    let set = |name: &str, v: u64| obs.counter_set(&format!("{name}{{replica=\"{replica}\"}}"), v);
    set("engine_steps_total", r.steps);
    set("engine_tokens_out_total", r.tokens_out);
    set("engine_preemptions_total", r.preemptions);
    set("engine_deadline_expired_total", r.deadline_expired);
}

fn serve_backend<B: Backend + Send + 'static>(
    backend: B,
    cfg: &ServeConfig,
    n_requests: usize,
    rps: f64,
) -> Result<()> {
    let geom = backend.geom();
    let mut engine = Engine::new(backend, cfg.pool_pages, cfg.page_tokens, cfg.admit_fraction);
    engine.set_prefill_chunk(cfg.prefill_chunk);
    // Front door: the SLO projections price steps with the same
    // whole-block cost model replay bills (ServiceModel::from_block) when
    // the model is known to the cost model, else a flat 1 ms TPOT.
    let service = service_model_for(cfg, geom.max_seq);
    engine.set_admission(admission_for(cfg, service));
    let obs = obs_for(cfg, geom.max_seq);
    if let Some(o) = &obs {
        // Wall-clock single-engine path: request lifecycle events are
        // traced with real µs (not byte-stable; use --fault-plan for the
        // deterministic virtual-clock trace).
        engine.set_obs(o.clone(), 0);
    }
    let server = Server::spawn(engine);

    // Open-loop paced replay: submissions honour arrival_us on the wall
    // clock instead of dumping the whole trace at t=0 (loadgen::pace_submit).
    let trace =
        Trace::poisson(n_requests, rps, SeqlenDist::ShareGpt, (8, 24), geom.max_seq / 4, 42);
    // Clamp generation budgets so prompt + max_new always fits max_seq:
    // the front door rejects requests that could never fit the context
    // window, and the synthetic trace must not manufacture those.
    let max_gen = 24.min(geom.max_seq.saturating_sub(geom.max_seq / 4)).max(1);
    let requests = loadgen::synthesize_requests(&trace, geom.vocab, 64, max_gen, 7);
    eprintln!(
        "replaying {} requests open-loop: offered {:.2} rps over {:.2}s",
        requests.len(),
        trace.achieved_rps(),
        trace.span_us() as f64 / 1e6
    );
    let clock = WallClock::new();
    let paced = loadgen::pace_submit(&server, &requests, &clock)?;
    let mut tokens = 0u64;
    for (_, rx) in paced.receivers {
        for ev in rx.iter() {
            if matches!(ev, Event::Token { .. } | Event::FirstToken { .. }) {
                tokens += 1;
            }
        }
    }
    let wall = clock.now_us() as f64 / 1e6;
    let report = server.shutdown()?;
    println!(
        "served {} requests ({} rejected at the front door), {tokens} tokens in {wall:.2}s \
         ({:.2} tok/s), {} engine steps, {} preemptions",
        report.timings.len(),
        report.rejected,
        tokens as f64 / wall,
        report.steps,
        report.preemptions
    );
    println!(
        "submit span: first at {:.3}s, last at {:.3}s (trace span {:.3}s)",
        paced.first_submit_us as f64 / 1e6,
        paced.last_submit_us as f64 / 1e6,
        trace.span_us() as f64 / 1e6
    );
    println!("latency percentiles (queue / ttft / tpot / e2e):");
    print!("{}", loadgen::percentiles(&report.timings).render());
    if let Some(o) = &obs {
        sync_server_report(o, 0, &report);
        write_obs_exports(o, cfg)?;
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    let (pos, flags) = parse_flags(&args[1..]);
    let _ = pos;
    match args[0].as_str() {
        "serve" => cmd_serve(&flags),
        "simulate" => cmd_simulate(&flags),
        "inspect-artifacts" => cmd_inspect(&flags),
        "bench" => {
            let fig = flags.get("figure").map(String::as_str).unwrap_or("fig17");
            println!(
                "run: cargo bench --bench {}",
                match fig {
                    "fig2" | "fig02" => "fig02_prefill_decode",
                    "fig5" | "fig05" => "fig05_dsmem_profile",
                    "fig10" => "fig10_seqlen_dist",
                    "fig11" => "fig11_cluster_sweep",
                    "fig12" | "fig19" => "fig12_traffic_launch",
                    "fig13" => "fig13_dsmem_ablation",
                    "table1" => "table1_collectives",
                    "fig17" => "fig17_e2e_tpot",
                    "fig18" => "fig18_core_modules",
                    "fig20" => "fig20_splithead",
                    "hotpath" => "hotpath",
                    other => bail!("unknown figure {other}"),
                }
            );
            Ok(())
        }
        _ => usage(),
    }
}
