//! Artifact manifest: the JSON contract between `python/compile/aot.py`
//! and the Rust runtime. The manifest fully describes each executable's
//! flat input/output interface so the runtime never needs Python.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::Json;

/// Shape + dtype of one tensor in the flat interface.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let name = j.get("name").and_then(Json::as_str).context("tensor name")?.to_string();
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("tensor shape")?
            .iter()
            .map(|d| d.as_usize().context("shape dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j.get("dtype").and_then(Json::as_str).context("tensor dtype")?.to_string();
        Ok(Self { name, shape, dtype })
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled decode-step executable.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutableInterface {
    pub model: String,
    pub batch: usize,
    pub attn: String,
    pub max_seq: usize,
    pub vocab: usize,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub kv_lora_rank: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub serving: bool,
    pub n_cache: usize,
    pub n_params: usize,
    pub file: String,
    pub sha256: String,
}

impl ExecutableInterface {
    fn from_json(j: &Json) -> Result<Self> {
        let s = |k: &str| -> Result<String> {
            Ok(j.get(k).and_then(Json::as_str).with_context(|| format!("field {k}"))?.into())
        };
        let u = |k: &str| -> Result<usize> {
            j.get(k).and_then(Json::as_usize).with_context(|| format!("field {k}"))
        };
        let tensors = |k: &str| -> Result<Vec<TensorSpec>> {
            j.get(k)
                .and_then(Json::as_arr)
                .with_context(|| format!("field {k}"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(Self {
            model: s("model")?,
            batch: u("batch")?,
            attn: s("attn")?,
            max_seq: u("max_seq")?,
            vocab: u("vocab")?,
            n_layers: u("n_layers")?,
            d_model: u("d_model")?,
            n_heads: u("n_heads")?,
            head_dim: u("head_dim")?,
            kv_lora_rank: u("kv_lora_rank").unwrap_or(0),
            inputs: tensors("inputs")?,
            outputs: tensors("outputs")?,
            serving: j.get("serving").and_then(Json::as_bool).unwrap_or(false),
            n_cache: u("n_cache")?,
            n_params: u("n_params")?,
            file: s("file")?,
            sha256: s("sha256").unwrap_or_default(),
        })
    }

    /// Input specs for the cache tensors (after tokens and pos).
    pub fn cache_specs(&self) -> &[TensorSpec] {
        &self.inputs[2..2 + self.n_cache]
    }

    /// Input specs for the parameter tensors.
    pub fn param_specs(&self) -> &[TensorSpec] {
        &self.inputs[2 + self.n_cache..]
    }

    /// Bytes of one full cache upload (f32 host-side).
    pub fn cache_bytes(&self) -> usize {
        self.cache_specs().iter().map(|s| s.elems() * 4).sum()
    }

    /// Total parameter element count (sanity vs the model config).
    pub fn param_elems(&self) -> usize {
        self.param_specs().iter().map(TensorSpec::elems).sum()
    }
}

/// The whole `manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub format: usize,
    pub executables: Vec<ExecutableInterface>,
}

impl ArtifactManifest {
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let format = j.get("format").and_then(Json::as_usize).context("format")?;
        ensure!(format == 1, "unsupported manifest format {format}");
        let executables = j
            .get("executables")
            .and_then(Json::as_arr)
            .context("executables")?
            .iter()
            .map(ExecutableInterface::from_json)
            .collect::<Result<Vec<_>>>()?;
        for e in &executables {
            if e.inputs.len() != 2 + e.n_cache + e.n_params {
                bail!("{}: inconsistent input arity", e.file);
            }
        }
        Ok(Self { format, executables })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn find(&self, model: &str, batch: usize, serving: bool) -> Option<&ExecutableInterface> {
        self.executables
            .iter()
            .find(|e| e.model == model && e.batch == batch && e.serving == serving)
    }

    /// Batch buckets available for a model's serving executables, sorted.
    pub fn serving_buckets(&self, model: &str) -> Vec<usize> {
        let mut b: Vec<usize> = self
            .executables
            .iter()
            .filter(|e| e.model == model && e.serving)
            .map(|e| e.batch)
            .collect();
        b.sort_unstable();
        b.dedup();
        b
    }

    pub fn models(&self) -> Vec<String> {
        let mut m: Vec<String> = self.executables.iter().map(|e| e.model.clone()).collect();
        m.sort();
        m.dedup();
        m
    }

    /// Lookup failing with a helpful error.
    pub fn require(&self, model: &str, batch: usize, serving: bool) -> Result<&ExecutableInterface> {
        self.find(model, batch, serving).with_context(|| {
            format!(
                "no artifact for model={model} batch={batch} serving={serving}; available: {:?}",
                self.executables
                    .iter()
                    .map(|e| (e.model.clone(), e.batch, e.serving))
                    .collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ArtifactManifest {
        let json = r#"{
          "format": 1,
          "executables": [{
            "model": "m", "batch": 2, "attn": "mha", "max_seq": 16,
            "vocab": 64, "n_layers": 2, "d_model": 32, "n_heads": 2,
            "head_dim": 8, "kv_lora_rank": 0,
            "inputs": [
              {"name": "tokens", "shape": [2], "dtype": "int32"},
              {"name": "pos", "shape": [2], "dtype": "int32"},
              {"name": "cache_k", "shape": [2,2,16,2,8], "dtype": "float32"},
              {"name": "cache_v", "shape": [2,2,16,2,8], "dtype": "float32"},
              {"name": "param_emb", "shape": [64,32], "dtype": "float32"}
            ],
            "outputs": [{"name": "logits", "shape": [2,64], "dtype": "float32"}],
            "serving": true, "n_cache": 2, "n_params": 1,
            "file": "x.hlo.txt", "sha256": "ab"
          }]
        }"#;
        ArtifactManifest::parse(json).unwrap()
    }

    #[test]
    fn specs_partition_inputs() {
        let m = sample();
        let e = &m.executables[0];
        assert_eq!(e.cache_specs().len(), 2);
        assert_eq!(e.param_specs().len(), 1);
        assert_eq!(e.cache_specs()[0].name, "cache_k");
        assert_eq!(e.param_specs()[0].name, "param_emb");
        assert_eq!(e.cache_bytes(), 2 * 2 * 2 * 16 * 2 * 8 * 4);
        assert_eq!(e.param_elems(), 64 * 32);
    }

    #[test]
    fn find_and_buckets() {
        let m = sample();
        assert!(m.find("m", 2, true).is_some());
        assert!(m.find("m", 2, false).is_none());
        assert!(m.find("m", 4, true).is_none());
        assert_eq!(m.serving_buckets("m"), vec![2]);
        assert_eq!(m.models(), vec!["m"]);
        assert!(m.require("nope", 1, true).is_err());
    }

    #[test]
    fn arity_mismatch_rejected() {
        let bad = r#"{"format":1,"executables":[{
            "model":"m","batch":1,"attn":"mha","max_seq":4,"vocab":8,
            "n_layers":1,"d_model":4,"n_heads":1,"head_dim":4,
            "inputs":[{"name":"tokens","shape":[1],"dtype":"int32"}],
            "outputs":[],"n_cache":2,"n_params":3,"file":"f"}]}"#;
        assert!(ArtifactManifest::parse(bad).is_err());
    }

    #[test]
    fn real_manifest_loads_if_present() {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if std::path::Path::new(p).exists() {
            let m = ArtifactManifest::load(p).unwrap();
            assert!(!m.executables.is_empty());
            for e in &m.executables {
                assert_eq!(e.inputs.len(), 2 + e.n_cache + e.n_params);
                assert_eq!(e.inputs[0].name, "tokens");
                assert_eq!(e.outputs[0].name, "logits");
            }
            // serving + full variants for every model at batch 1
            for model in m.models() {
                assert!(m.find(&model, 1, true).is_some());
                assert!(m.find(&model, 1, false).is_some());
            }
        }
    }
}
