//! PJRT runtime: load AOT artifacts (HLO text produced by
//! `python/compile/aot.py`) and execute them from the serving hot path.
//!
//! Interchange format is **HLO text**, not a serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the `xla`
//! crate's xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Python never runs here — the manifest fully describes every
//! executable's flat input/output interface, and parameters are
//! re-materialised from a seeded RNG on the Rust side (the demo models are
//! random-weight by design, DESIGN.md §2).

pub mod manifest;
pub mod xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow as eyre, Context, Result};

pub use manifest::{ArtifactManifest, ExecutableInterface, TensorSpec};

/// A loaded, compiled decode-step executable plus its interface.
pub struct LoadedDecode {
    pub iface: ExecutableInterface,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client and a cache of compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: ArtifactManifest,
    loaded: HashMap<String, LoadedDecode>,
}

/// Host-side tensor (f32) with shape, the runtime's lingua franca.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

impl Runtime {
    /// Create a CPU PJRT client and read `<dir>/manifest.json`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ArtifactManifest::load(dir.join("manifest.json"))
            .context("reading artifact manifest (run `make artifacts`)")?;
        let client = xla::PjRtClient::cpu().map_err(|e| eyre!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, dir, manifest, loaded: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return the executable for `(model, batch,
    /// serving)`. Compilation is cached for the life of the runtime.
    pub fn load(&mut self, model: &str, batch: usize, serving: bool) -> Result<&LoadedDecode> {
        let iface = self
            .manifest
            .find(model, batch, serving)
            .ok_or_else(|| eyre!("no artifact for model={model} batch={batch} serving={serving}"))?
            .clone();
        let key = iface.file.clone();
        if !self.loaded.contains_key(&key) {
            let path = self.dir.join(&iface.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| eyre!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| eyre!("compiling {}: {e:?}", iface.file))?;
            self.loaded.insert(key.clone(), LoadedDecode { iface, exe });
        }
        Ok(&self.loaded[&key])
    }

    /// Immutable lookup of an already-[`Self::load`]ed executable.
    pub fn get(&self, model: &str, batch: usize, serving: bool) -> Result<&LoadedDecode> {
        let iface = self
            .manifest
            .find(model, batch, serving)
            .ok_or_else(|| eyre!("no artifact for model={model} batch={batch}"))?;
        self.loaded
            .get(&iface.file)
            .ok_or_else(|| eyre!("{} not loaded; call load() first", iface.file))
    }

    /// Upload an f32 host tensor to the device.
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            .map_err(|e| eyre!("upload f32 {:?}: {e:?}", t.shape))
    }

    /// Upload an i32 host tensor (tokens / positions).
    pub fn upload_i32(&self, data: &[i32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(data, shape, None)
            .map_err(|e| eyre!("upload i32 {shape:?}: {e:?}"))
    }

    /// Generate the model's parameter buffers from a seed, per the
    /// manifest's `param_*` specs (1/sqrt(fan_in) scaling like
    /// `python/compile/model.py`; values differ — only shapes matter for
    /// the latency demo, and norms must be ~1 for numerical stability).
    pub fn random_params(
        &self,
        iface: &ExecutableInterface,
        seed: u64,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(seed);
        let mut bufs = Vec::new();
        for spec in iface.param_specs() {
            let n: usize = spec.shape.iter().product();
            let data: Vec<f32> = if spec.name.contains("norm") {
                vec![1.0; n]
            } else {
                // fan_in = second-to-last dim product heuristic: use the
                // first axis after any layer-stack axis.
                let fan_in = *spec.shape.get(spec.shape.len().saturating_sub(2)).unwrap_or(&1);
                let scale = 1.0 / (fan_in as f32).sqrt();
                (0..n).map(|_| (rng.f32() - 0.5) * 2.0 * scale).collect()
            };
            bufs.push(
                self.client
                    .buffer_from_host_buffer::<f32>(&data, &spec.shape, None)
                    .map_err(|e| eyre!("param {}: {e:?}", spec.name))?,
            );
        }
        Ok(bufs)
    }

    /// Execute one decode step.
    ///
    /// `caches` are the padded per-model cache tensors (uploaded fresh each
    /// step — the host is authoritative, see `coordinator::kv_cache`);
    /// `params` were uploaded once via [`Self::random_params`]. Returns the
    /// flat outputs (logits first) as host tensors.
    pub fn decode_step(
        &self,
        exe: &LoadedDecode,
        tokens: &[i32],
        pos: &[i32],
        caches: &[HostTensor],
        params: &[xla::PjRtBuffer],
    ) -> Result<Vec<HostTensor>> {
        let iface = &exe.iface;
        anyhow::ensure!(tokens.len() == iface.batch, "token count != batch");
        anyhow::ensure!(pos.len() == iface.batch, "pos count != batch");
        anyhow::ensure!(caches.len() == iface.n_cache, "cache count mismatch");
        anyhow::ensure!(params.len() == iface.n_params, "param count mismatch");

        let mut args: Vec<xla::PjRtBuffer> = Vec::with_capacity(2 + caches.len());
        args.push(self.upload_i32(tokens, &[iface.batch])?);
        args.push(self.upload_i32(pos, &[iface.batch])?);
        for (c, spec) in caches.iter().zip(iface.cache_specs()) {
            anyhow::ensure!(c.shape == spec.shape, "cache shape {:?} != {:?}", c.shape, spec.shape);
            args.push(self.upload(c)?);
        }
        let arg_refs: Vec<&xla::PjRtBuffer> = args.iter().chain(params.iter()).collect();

        let results = exe
            .exe
            .execute_b(&arg_refs)
            .map_err(|e| eyre!("execute {}: {e:?}", iface.file))?;
        let tuple = results[0][0]
            .to_literal_sync()
            .map_err(|e| eyre!("fetch result: {e:?}"))?;
        let leaves = tuple.to_tuple().map_err(|e| eyre!("untuple: {e:?}"))?;
        anyhow::ensure!(
            leaves.len() == iface.outputs.len(),
            "expected {} outputs, got {}",
            iface.outputs.len(),
            leaves.len()
        );
        leaves
            .into_iter()
            .zip(&iface.outputs)
            .map(|(lit, spec)| {
                let data = lit.to_vec::<f32>().map_err(|e| eyre!("{}: {e:?}", spec.name))?;
                anyhow::ensure!(
                    data.len() == spec.shape.iter().product::<usize>(),
                    "{}: wrong element count",
                    spec.name
                );
                Ok(HostTensor { shape: spec.shape.clone(), data })
            })
            .collect()
    }
}

/// Whether a PJRT client can be constructed in this build. Cached per
/// process so availability gates (tests, examples) construct at most one
/// throwaway client; offline builds with the stubbed [`xla`] module
/// always report `false`.
pub fn pjrt_available() -> bool {
    static AVAILABLE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVAILABLE.get_or_init(|| xla::PjRtClient::cpu().is_ok())
}

/// The standard gate for real-runtime examples/tests: `dir` holds an
/// artifact manifest *and* the PJRT runtime is available.
pub fn artifacts_ready(dir: impl AsRef<Path>) -> bool {
    dir.as_ref().join("manifest.json").exists() && pjrt_available()
}

/// Greedy (argmax) sampling from a logits row. Ties break to the
/// **lowest index** (numpy convention) under `f32::total_cmp`, so the
/// result matches the sharded logits head's per-shard argmax merge
/// (`clustersim::block`) exactly — shards scan ascending vocab windows
/// and only a *strictly greater* value displaces the running best.
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, v) in logits.iter().enumerate().skip(1) {
        if v.total_cmp(&logits[best]) == std::cmp::Ordering::Greater {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0, 2.9]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn argmax_ties_break_to_lowest_index() {
        assert_eq!(argmax(&[1.0, 2.0, 2.0, 2.0, 0.5]), 1);
        assert_eq!(argmax(&[3.0, 3.0]), 0);
        // total_cmp: -0.0 < +0.0, so +0.0 at a later index still wins
        assert_eq!(argmax(&[-0.0, 0.0]), 1);
    }

    #[test]
    fn host_tensor_zeros() {
        let t = HostTensor::zeros(&[2, 3]);
        assert_eq!(t.elems(), 6);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }
}
