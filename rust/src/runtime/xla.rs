//! Offline stand-in for the `xla` (PJRT) crate.
//!
//! The real serving path executes AOT HLO through PJRT via the `xla`
//! crate's client/executable/buffer handles. That crate links the
//! `xla_extension` C++ distribution, which cannot be fetched in this
//! fully-offline build (DESIGN.md §2), so this module provides the exact
//! API surface `runtime` and `coordinator::pjrt_backend` use, with
//! [`PjRtClient::cpu`] reporting the runtime as unavailable.
//!
//! Everything downstream degrades gracefully: tests and examples gate on
//! [`crate::runtime::pjrt_available`] / [`crate::runtime::artifacts_ready`]
//! and skip or fall back to the in-memory `MockBackend` when this stub
//! answers. Swapping in `xla = "0.5"` (plus the `xla_extension` install)
//! re-enables the real path; keep `runtime::xla` as a re-export shim
//! (`pub use ::xla::*;`) so the module path callers use stays valid.

use std::fmt;
use std::path::Path;

/// Error surfaced by every stubbed PJRT entry point.
pub struct XlaError {
    what: &'static str,
}

impl XlaError {
    fn unavailable(what: &'static str) -> Self {
        XlaError { what }
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: PJRT runtime unavailable (offline build without the `xla` crate; \
             see rust/src/runtime/xla.rs)",
            self.what
        )
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

impl std::error::Error for XlaError {}

/// Device buffer handle (never constructed in the stub).
pub struct PjRtBuffer {
    _opaque: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal handle (never constructed in the stub).
pub struct Literal {
    _opaque: (),
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(XlaError::unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(XlaError::unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (never constructed in the stub).
pub struct HloModuleProto {
    _opaque: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self, XlaError> {
        Err(XlaError::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper around a parsed module.
pub struct XlaComputation {
    _opaque: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _opaque: () }
    }
}

/// Compiled executable handle (never constructed in the stub).
pub struct PjRtLoadedExecutable {
    _opaque: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle. [`Self::cpu`] is the only constructor and reports
/// the runtime as unavailable in this build.
pub struct PjRtClient {
    _opaque: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        Err(XlaError::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, XlaError> {
        Err(XlaError::unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub client must not construct");
        let msg = format!("{e:?}");
        assert!(msg.contains("unavailable"), "{msg}");
    }

    #[test]
    fn hlo_parse_is_unavailable_too() {
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
    }
}
