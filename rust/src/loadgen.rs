//! Open-loop trace replay: the load-generation subsystem.
//!
//! Replays a [`Trace`] against the serving stack honouring each request's
//! `arrival_us` (open-loop: arrivals do not wait for completions, the
//! standard methodology behind the paper's Fig. 17 latency-under-load
//! curves). Two drivers share the pacing logic:
//!
//! * [`replay`] — drives an [`Engine`] inline on the engine's own
//!   [`Clock`]. With a `VirtualClock` this is *fully deterministic*: the
//!   replay loop is the only writer of time, charging a [`ServiceModel`]
//!   cost per decode step, so two runs at the same seed produce
//!   byte-identical percentile reports (the `integration_load` contract).
//!   With a `WallClock` the same loop paces real submissions. The
//!   DESIGN.md §4 rule — virtual-clock runs are single-threaded by
//!   construction — extends to the backend's worker pool: a
//!   `FunctionalBackend` driven by virtual-clock replay keeps its
//!   default **serial** pool (`threads = 1`; `FunctionalBackend::new` /
//!   `from_model_name`). Functional outputs are byte-identical at every
//!   pool size (§Parallel), so this costs nothing but keeps the rule
//!   auditable: one thread, one writer of time.
//! * [`pace_submit`] — paces submissions to a threaded [`Server`] on the
//!   wall clock (used by `clusterfusion serve` and `examples/serve_trace`).
//!   Virtual time is never combined with the threaded server: determinism
//!   requires a single writer of the clock (DESIGN.md §4).
//!
//! Timing conventions: events are stamped at the *start* of the decode
//! step that produced them, and the step's service cost — billed for the
//! batch that actually executed, decode slots (`Engine::last_decode_slots`)
//! and prefill rows (`Engine::last_prefill_tokens`) priced separately —
//! is charged after it completes; a fixed one-step offset that cancels in
//! comparisons. Submissions are stamped when the engine observes them,
//! which is at most one step after `arrival_us` when the engine is
//! mid-step (the same mailbox-drain semantics the threaded server has).
//! Per-request event streams are discarded during replay (metrics come
//! from `Engine::timings`).
//! Per-request queue/TTFT/TPOT/e2e are reduced to p50/p90/p99 summaries
//! by [`crate::metrics::PercentileReport`].

use std::sync::mpsc::Receiver;

use anyhow::Result;

use crate::coordinator::engine::{Backend, Engine, RequestTiming};
use crate::coordinator::request::{Event, Request, RequestId};
use crate::coordinator::server::Server;
use crate::metrics::PercentileReport;
use crate::util::clock::Clock;
use crate::util::rng::Rng;
use crate::workload::Trace;

/// Simulated execution cost of one engine step on a virtual clock. On a
/// wall clock real time passes during the step and `advance_us` is a
/// no-op, so the model is inert there.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServiceModel {
    /// Fixed cost per decode step, µs (kernel launch + host loop).
    pub step_base_us: u64,
    /// Additional cost per decode (single-row) sequence in the step, µs.
    pub step_per_seq_us: u64,
    /// Additional cost per prompt row prefilled in the step, µs. Prefill
    /// rows amortize the weight pass, so this is typically far below
    /// `step_per_seq_us` — chunked prefill is what makes TTFT real in
    /// the virtual-clock suites.
    pub step_prefill_token_us: u64,
}

impl ServiceModel {
    /// Cost of one step with `decode_slots` decode sequences and
    /// `prefill_rows` prompt rows, µs. Floored at one decode slot's
    /// cost: no executed step is cheaper than a batch-1 decode step
    /// (parity with the pre-prefill model, which billed `live.max(1)`).
    pub fn step_us(&self, decode_slots: usize, prefill_rows: usize) -> u64 {
        let work = self.step_per_seq_us * decode_slots as u64
            + self.step_prefill_token_us * prefill_rows as u64;
        self.step_base_us + work.max(self.step_per_seq_us)
    }

    /// Model a backend whose step time is one flat TPOT (e.g. taken from
    /// `clustersim::e2e::decode_step` — the Fig. 17 under-load bench).
    pub fn from_tpot_us(tpot_us: u64) -> Self {
        Self { step_base_us: tpot_us, step_per_seq_us: 0, step_prefill_token_us: 0 }
    }

    /// Derive the step cost from the full-block cost model
    /// (`clustersim::block::decode_tpot` / `prefill_tpot`) at the given
    /// fusion scope: the per-sequence slope comes from the batch-1 →
    /// batch-8 TPOT delta, the base is the batch-independent remainder,
    /// and the per-prefill-row slope from the rows-1 → rows-128 prefill
    /// delta. This is what replay bills when driving an
    /// `Engine<FunctionalBackend>` — whole-block service times instead
    /// of the attention-only `decode_step` costs.
    pub fn from_block(
        model: &crate::models::ModelConfig,
        seq: usize,
        scope: crate::clustersim::block::FusionScope,
        cluster_size: usize,
        hw: &crate::clustersim::Hardware,
        noc: &crate::clustersim::Noc,
    ) -> Self {
        use crate::clustersim::block::{decode_tpot, prefill_tpot};
        let t1 = decode_tpot(model, 1, seq, scope, cluster_size, hw, noc);
        let t8 = decode_tpot(model, 8, seq, scope, cluster_size, hw, noc);
        let per_seq = ((t8 - t1) / 7.0).max(0.0);
        let base = (t1 - per_seq).max(0.0);
        let p1 = prefill_tpot(model, 1, seq, scope, cluster_size, hw, noc);
        let p128 = prefill_tpot(model, 128, seq, scope, cluster_size, hw, noc);
        let per_tok = ((p128 - p1) / 127.0).max(0.0);
        Self {
            step_base_us: (base * 1e6).round().max(1.0) as u64,
            step_per_seq_us: (per_seq * 1e6).round() as u64,
            step_prefill_token_us: (per_tok * 1e6).round().max(1.0) as u64,
        }
    }
}

/// Turn trace rows into engine requests with synthesized prompts
/// (deterministic in `seed`) and `arrival_us` carried over.
pub fn synthesize_requests(
    trace: &Trace,
    vocab: usize,
    max_prompt: usize,
    max_gen: usize,
    seed: u64,
) -> Vec<Request> {
    assert!(vocab > 0 && max_prompt >= 1 && max_gen >= 1);
    let mut rng = Rng::seed_from_u64(seed);
    trace
        .requests
        .iter()
        .map(|r| {
            let prompt: Vec<i32> =
                (0..r.prompt_len.clamp(1, max_prompt)).map(|_| rng.below(vocab) as i32).collect();
            let mut req = Request::new(r.id, prompt, r.gen_len.clamp(1, max_gen));
            req.arrival_us = r.arrival_us;
            req
        })
        .collect()
}

/// Reduce engine timings to the four percentile summaries. TTFT samples
/// only exist for requests that emitted a first token, and TPOT samples
/// for requests that generated ≥ 2 (a single-token request has no
/// inter-token gap); zero-token placeholders must not drag the tails.
pub fn percentiles(timings: &[RequestTiming]) -> PercentileReport {
    let queue: Vec<f64> = timings.iter().map(|t| t.queue).collect();
    let ttft: Vec<f64> =
        timings.iter().filter(|t| t.generated >= 1).map(|t| t.ttft).collect();
    let tpot: Vec<f64> = timings.iter().filter(|t| t.generated >= 2).map(|t| t.tpot).collect();
    let e2e: Vec<f64> = timings.iter().map(|t| t.total).collect();
    PercentileReport::from_samples(&queue, &ttft, &tpot, &e2e)
}

/// Outcome of one [`replay`] run.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    pub completed: usize,
    /// Requests refused at the engine's front door (too long for the
    /// context window or projected to breach the TTFT SLO); these never
    /// enter `completed` and leave no timing samples.
    pub rejected: u64,
    pub steps: u64,
    pub tokens_out: u64,
    pub preemptions: u64,
    /// Clock µs at which the first/last request entered the engine —
    /// paced replay spreads these over the trace span instead of t=0.
    pub first_submit_us: u64,
    pub last_submit_us: u64,
    /// Clock µs of the last completion.
    pub last_finish_us: u64,
    pub percentiles: PercentileReport,
}

impl ReplayReport {
    /// Fixed-format render; byte-identical across identically-seeded
    /// virtual-clock runs (asserted by `integration_load`).
    pub fn render(&self) -> String {
        format!(
            "completed={} rejected={} steps={} tokens={} preemptions={}\n\
             submit_span_us=[{}, {}] last_finish_us={}\n{}",
            self.completed,
            self.rejected,
            self.steps,
            self.tokens_out,
            self.preemptions,
            self.first_submit_us,
            self.last_submit_us,
            self.last_finish_us,
            self.percentiles.render()
        )
    }
}

/// Replay `requests` (sorted by `arrival_us`; [`Trace`] guarantees this)
/// open-loop against an engine, on the engine's own clock. Returns the
/// percentile report over every completed request.
pub fn replay<B: Backend>(
    engine: &mut Engine<B>,
    requests: &[Request],
    service: &ServiceModel,
    max_steps: u64,
) -> Result<ReplayReport> {
    debug_assert!(
        requests.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us),
        "replay requires arrival-sorted requests"
    );
    let clock = engine.clock();
    // Baselines so a reused engine reports only *this* replay's work.
    let base_timings = engine.timings().len();
    let (base_steps, base_tokens, base_preempt, base_rejected) =
        (engine.steps, engine.tokens_out, engine.preemptions, engine.rejected());
    let mut next = 0usize;
    let mut first_submit_us = None;
    let mut last_submit_us = 0u64;
    let mut steps = 0u64;
    loop {
        let now = clock.now_us();
        while next < requests.len() && requests[next].arrival_us <= now {
            engine.submit(requests[next].clone());
            first_submit_us.get_or_insert(now);
            last_submit_us = now;
            next += 1;
        }
        if engine.idle() {
            match requests.get(next) {
                // open-loop: jump (virtual) / sleep (wall) to the next arrival
                Some(r) => {
                    clock.sleep_until_us(r.arrival_us);
                    continue;
                }
                None => break,
            }
        }
        let did = engine.step()?;
        // Metrics come from timings; drop the event stream so a long
        // saturation sweep does not accumulate O(requests × tokens).
        engine.take_events();
        if did {
            steps += 1;
            anyhow::ensure!(steps <= max_steps, "replay exceeded {max_steps} steps");
            // bill the batch that actually executed — decode slots and
            // prefill rows priced separately — not the post-completion
            // running count
            let cost = service.step_us(engine.last_decode_slots, engine.last_prefill_tokens);
            if let Some(obs) = engine.obs() {
                // The step span covers exactly the billed service time:
                // [now, now + cost] on the replica's step track, with
                // kernel child spans when a schedule is installed.
                obs.step_span(
                    engine.obs_replica(),
                    now,
                    cost,
                    engine.last_decode_slots,
                    engine.last_prefill_tokens,
                );
            }
            clock.advance_us(cost);
        } else if engine.batcher.running().is_empty() && !engine.idle() {
            // Admission blocked with the whole pool free: the queue head's
            // worst-case footprint exceeds the pool and can never run.
            // (An *idle* no-op step is fine — deadline expiry at the step
            // boundary can empty the engine without executing anything.)
            anyhow::bail!("replay wedged: queued request cannot fit the KV pool");
        }
    }
    let timings = &engine.timings()[base_timings..];
    if let Some(obs) = engine.obs() {
        // Sync point: fold the engine's report fields into the metrics
        // registry and observe this replay's latency samples. The report
        // structs stay authoritative; the registry is the exported view.
        engine.sync_obs_counters();
        obs.counter_set("replay_completed_total", timings.len() as u64);
        obs.counter_set("replay_rejected_total", engine.rejected() - base_rejected);
        let b = &crate::obs::LATENCY_MS_BUCKETS;
        for t in timings {
            obs.observe("request_queue_ms", b, t.queue * 1e3);
            obs.observe("request_e2e_ms", b, t.total * 1e3);
            if t.generated >= 1 {
                obs.observe("request_ttft_ms", b, t.ttft * 1e3);
            }
            if t.generated >= 2 {
                obs.observe("request_tpot_ms", b, t.tpot * 1e3);
            }
        }
    }
    Ok(ReplayReport {
        completed: timings.len(),
        rejected: engine.rejected() - base_rejected,
        steps: engine.steps - base_steps,
        tokens_out: engine.tokens_out - base_tokens,
        preemptions: engine.preemptions - base_preempt,
        first_submit_us: first_submit_us.unwrap_or(0),
        last_submit_us,
        last_finish_us: timings.iter().map(|t| t.finished_us).max().unwrap_or(0),
        percentiles: percentiles(timings),
    })
}

/// Receivers plus the observed submission times of a paced server run.
pub struct PacedSubmission {
    pub receivers: Vec<(RequestId, Receiver<Event>)>,
    /// Clock µs of each submission, parallel to `receivers` (each is
    /// ≥ its request's `arrival_us`: sleeps only overshoot).
    pub submit_us: Vec<u64>,
    pub first_submit_us: u64,
    pub last_submit_us: u64,
}

/// Pace `requests` into a running [`Server`] on `clock` (wall clock in
/// practice), sleeping until each `arrival_us` before submitting — the
/// open-loop fix for the ROADMAP "whole trace at t=0" item. Returns the
/// per-request receivers in submission order; the caller drains them and
/// calls `server.shutdown()` for the timing report.
pub fn pace_submit(
    server: &Server,
    requests: &[Request],
    clock: &dyn Clock,
) -> Result<PacedSubmission> {
    let mut receivers = Vec::with_capacity(requests.len());
    let mut submit_us = Vec::with_capacity(requests.len());
    let mut first_submit_us = None;
    let mut last_submit_us = 0u64;
    for r in requests {
        clock.sleep_until_us(r.arrival_us);
        let now = clock.now_us();
        receivers.push((r.id, server.submit(r.clone())?));
        submit_us.push(now);
        first_submit_us.get_or_insert(now);
        last_submit_us = now;
    }
    Ok(PacedSubmission {
        receivers,
        submit_us,
        first_submit_us: first_submit_us.unwrap_or(0),
        last_submit_us,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{MockBackend, ModelGeom};
    use crate::util::clock::{SharedClock, VirtualClock, WallClock};
    use crate::workload::SeqlenDist;

    fn mock() -> MockBackend {
        MockBackend::new(
            ModelGeom { vocab: 64, n_layers: 2, row_elems: 4, planes: 2, max_seq: 64 },
            vec![1, 2, 4, 8],
        )
    }

    fn virtual_engine() -> Engine<MockBackend> {
        Engine::with_clock(mock(), 64, 4, 0.5, VirtualClock::shared())
    }

    #[test]
    fn synthesize_respects_trace_and_clamps() {
        let trace = Trace::poisson(32, 100.0, SeqlenDist::ShareGpt, (4, 64), 4096, 3);
        let reqs = synthesize_requests(&trace, 64, 16, 8, 7);
        assert_eq!(reqs.len(), 32);
        for (req, row) in reqs.iter().zip(&trace.requests) {
            assert_eq!(req.arrival_us, row.arrival_us);
            assert_eq!(req.id, row.id);
            assert!(req.prompt.len() <= 16 && !req.prompt.is_empty());
            assert!(req.sampling.max_new_tokens <= 8);
            assert!(req.prompt.iter().all(|&t| (0..64).contains(&t)));
        }
        // deterministic in seed
        assert_eq!(reqs, synthesize_requests(&trace, 64, 16, 8, 7));
    }

    #[test]
    fn replay_honours_arrival_us_on_virtual_clock() {
        let mut e = virtual_engine();
        let mut r1 = Request::new(0, vec![1, 2], 2);
        r1.arrival_us = 5_000;
        let mut r2 = Request::new(1, vec![3], 2);
        r2.arrival_us = 9_000;
        let service =
            ServiceModel { step_base_us: 100, step_per_seq_us: 0, step_prefill_token_us: 0 };
        let rep = replay(&mut e, &[r1, r2], &service, 1_000).unwrap();
        assert_eq!(rep.completed, 2);
        // paced: first submission at its arrival, not t=0
        assert_eq!(rep.first_submit_us, 5_000);
        assert!(rep.last_submit_us >= 9_000);
        let t0 = e.timings().iter().find(|t| t.id == 0).unwrap();
        assert_eq!(t0.submitted_us, 5_000);
    }

    #[test]
    fn replay_is_deterministic_at_fixed_seed() {
        let run = || {
            let trace = Trace::poisson(64, 400.0, SeqlenDist::Fixed(24), (8, 8), 64, 11);
            let reqs = synthesize_requests(&trace, 64, 16, 8, 5);
            let mut e = virtual_engine();
            let service =
                ServiceModel { step_base_us: 200, step_per_seq_us: 50, step_prefill_token_us: 25 };
            replay(&mut e, &reqs, &service, 1_000_000).unwrap().render()
        };
        assert_eq!(run(), run(), "virtual-clock replay must be byte-deterministic");
    }

    #[test]
    fn replay_charges_service_model_time() {
        let mut e = virtual_engine();
        // prompt 2 + gen 3 -> 3 steps at 1000 µs: the one-shot prefill
        // step already emits the first token
        let r = Request::new(0, vec![1, 2], 3);
        let service =
            ServiceModel { step_base_us: 1_000, step_per_seq_us: 0, step_prefill_token_us: 0 };
        let rep = replay(&mut e, &[r], &service, 100).unwrap();
        assert_eq!(rep.steps, 3);
        // finish is stamped at the start of the 3rd step (2 advances)
        assert_eq!(rep.last_finish_us, 2_000);
        let t = &e.timings()[0];
        assert_eq!(t.ttft, 0.0, "prefill costs one step, stamped at its start");
        assert!((t.tpot - 1e-3).abs() < 1e-9, "{}", t.tpot);
    }

    #[test]
    fn replay_bills_prefill_rows_distinct_from_decode_slots() {
        let run = |chunk: usize| {
            let mut e = virtual_engine();
            e.set_prefill_chunk(chunk);
            let service = ServiceModel {
                step_base_us: 100,
                step_per_seq_us: 50,
                step_prefill_token_us: 10,
            };
            let rep = replay(&mut e, &[Request::new(0, vec![1; 6], 2)], &service, 100).unwrap();
            (rep.steps, rep.last_finish_us)
        };
        // one-shot: step 1 bills 6 prefill rows (100 + 6*10 = 160 µs) and
        // emits the first token; step 2 is a decode slot (150 µs), so the
        // finish is stamped at its start
        assert_eq!(run(0), (2, 160));
        // chunk 3: two prefill steps of 3 rows — each floored at one
        // decode slot's cost (100 + max(30, 50) = 150 µs) — then a decode
        // step; first token at 150 µs, finish stamped at 300 µs
        assert_eq!(run(3), (3, 300));
    }

    #[test]
    fn replay_rejects_unadmittable_request() {
        // pool: 8 pages x 4 tokens = 32 slots; the request fits the
        // context window (30 + 30 = 60 ≤ max_seq 64) so the front door
        // queues it, but its worst-case footprint (15 pages) exceeds the
        // whole pool: admission can never run it and replay must bail
        // instead of spinning
        let mut e = Engine::with_clock(mock(), 8, 4, 1.0, VirtualClock::shared());
        let r = Request::new(0, vec![1; 30], 30);
        let service =
            ServiceModel { step_base_us: 100, step_per_seq_us: 0, step_prefill_token_us: 0 };
        let err = replay(&mut e, &[r], &service, 1_000).unwrap_err();
        assert!(err.to_string().contains("wedged"), "{err:#}");
    }

    #[test]
    fn replay_counts_front_door_rejections() {
        // prompt 30 + gen 60 = 90 > max_seq 64: refused at submit; the
        // admittable request completes and the report separates the two
        let mut e = virtual_engine();
        let too_long = Request::new(0, vec![1; 30], 60);
        let ok = Request::new(1, vec![1, 2], 2);
        let service =
            ServiceModel { step_base_us: 100, step_per_seq_us: 0, step_prefill_token_us: 0 };
        let rep = replay(&mut e, &[too_long, ok], &service, 1_000).unwrap();
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.rejected, 1);
        assert!(rep.render().starts_with("completed=1 rejected=1 "), "{}", rep.render());
    }

    #[test]
    fn replay_report_covers_only_the_current_call() {
        // replay takes &mut Engine, so engines can be reused: the report
        // must cover this call's work only, not lifetime totals.
        let mut e = virtual_engine();
        let service =
            ServiceModel { step_base_us: 100, step_per_seq_us: 0, step_prefill_token_us: 0 };
        let a = replay(&mut e, &[Request::new(0, vec![1], 2)], &service, 100).unwrap();
        let b = replay(&mut e, &[Request::new(1, vec![1, 2], 2)], &service, 100).unwrap();
        assert_eq!(a.completed, 1);
        assert_eq!(b.completed, 1, "second replay must not double-count");
        assert_eq!(b.percentiles.e2e.count, 1);
        assert_eq!(b.steps, 2, "one-shot prefill emits the first token");
        assert_eq!(b.tokens_out, 2);
    }

    #[test]
    fn replay_works_on_wall_clock_too() {
        let clock: SharedClock = WallClock::shared();
        let mut e = Engine::with_clock(mock(), 64, 4, 0.5, clock);
        let trace = Trace::poisson(8, 2_000.0, SeqlenDist::Fixed(12), (4, 4), 64, 2);
        let reqs = synthesize_requests(&trace, 64, 8, 4, 3);
        let service =
            ServiceModel { step_base_us: 0, step_per_seq_us: 0, step_prefill_token_us: 0 };
        let rep = replay(&mut e, &reqs, &service, 100_000).unwrap();
        assert_eq!(rep.completed, 8);
        assert!(rep.percentiles.e2e.count == 8);
    }

    #[test]
    fn replay_survives_deadline_expiry_emptying_the_engine() {
        // A running request whose deadline passes at a step boundary is
        // finished inside step(), which then returns false with nothing
        // running — that is an idle no-op, not the cannot-fit-pool wedge
        // (the wedge check used to bail here).
        let mut e = virtual_engine();
        let r = Request::new(0, vec![1, 2], 20).with_deadline_us(1_500);
        let service =
            ServiceModel { step_base_us: 1_000, step_per_seq_us: 0, step_prefill_token_us: 0 };
        let rep = replay(&mut e, &[r], &service, 1_000).unwrap();
        assert_eq!(e.deadline_expired, 1);
        assert_eq!(rep.completed, 1, "expiry records a timing with partial output");
        assert!(rep.rejected == 0);
    }

    #[test]
    fn paced_server_delivers_rejection_while_idle() {
        // Threaded regression for the wall-clock submit path: a request
        // refused at the engine's front door (prompt 30 + gen 60 > max_seq
        // 64) must deliver its terminal event to the paced client even
        // though the engine never steps for it; the admittable request
        // paced in behind it completes normally.
        use crate::coordinator::request::FinishReason;
        let clock: SharedClock = WallClock::shared();
        let engine = Engine::with_clock(mock(), 64, 4, 0.5, clock.clone());
        let server = Server::spawn(engine);
        let too_long = Request::new(0, vec![1; 30], 60);
        let mut ok = Request::new(1, vec![1, 2], 2);
        ok.arrival_us = 500;
        let paced = pace_submit(&server, &[too_long, ok], clock.as_ref()).unwrap();
        assert_eq!(paced.receivers.len(), 2);
        for ((id, rx), submit_us) in paced.receivers.iter().zip(&paced.submit_us) {
            let evs: Vec<Event> = rx.iter().collect();
            match id {
                0 => assert!(
                    matches!(
                        evs.as_slice(),
                        [Event::Finished { id: 0, reason: FinishReason::Rejected, .. }]
                    ),
                    "rejected stream must carry exactly the terminal event: {evs:?}"
                ),
                _ => {
                    assert!(matches!(
                        evs.last().unwrap(),
                        Event::Finished { reason: FinishReason::Length, .. }
                    ));
                    assert!(*submit_us >= 500, "paced at least to arrival_us");
                }
            }
        }
        let report = server.shutdown().unwrap();
        assert_eq!(report.rejected, 1);
        assert_eq!(report.timings.len(), 1);
        assert_eq!(report.dangling_subscribers, 0);
    }

    #[test]
    fn service_model_from_block_orders_by_fusion_scope() {
        use crate::clustersim::block::FusionScope;
        use crate::clustersim::{Hardware, Noc};
        use crate::models::ModelConfig;
        let hw = Hardware::h100_sxm5();
        let noc = Noc::h100(&hw);
        let m = ModelConfig::llama2_7b();
        let at = |s| ServiceModel::from_block(&m, 4096, s, 4, &hw, &noc);
        let (iso, att, ful) = (
            at(FusionScope::BlockIsolated),
            at(FusionScope::AttentionFused),
            at(FusionScope::FullBlockFused),
        );
        for live in [1usize, 4, 8] {
            assert!(
                ful.step_us(live, 0) <= att.step_us(live, 0)
                    && att.step_us(live, 0) <= iso.step_us(live, 0),
                "live={live}: {} / {} / {}",
                ful.step_us(live, 0),
                att.step_us(live, 0),
                iso.step_us(live, 0)
            );
        }
        // sanity: llama-scale TPOT lands in the single-digit-ms range
        assert!((2_000..30_000).contains(&ful.step_us(1, 0)), "{}", ful.step_us(1, 0));
        // prefill rows are priced, and far below a decode slot: the
        // weight pass is amortized across the chunk
        assert!(ful.step_prefill_token_us >= 1);
        assert!(ful.step_prefill_token_us < ful.step_per_seq_us.max(ful.step_base_us));
    }

    #[test]
    fn percentiles_skip_tpot_for_single_token_requests() {
        let mut e = virtual_engine();
        let service =
            ServiceModel { step_base_us: 500, step_per_seq_us: 0, step_prefill_token_us: 0 };
        let one = Request::new(0, vec![1], 1); // single token: no tpot sample
        let two = Request::new(1, vec![1], 3);
        let rep = replay(&mut e, &[one, two], &service, 100).unwrap();
        assert_eq!(rep.percentiles.e2e.count, 2);
        assert_eq!(rep.percentiles.tpot.count, 1);
    }
}
