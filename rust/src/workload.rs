//! Workload generation: sequence-length distributions and request traces.
//!
//! The paper motivates its evaluation range with the ShareGPT and
//! Splitwise datasets (Fig. 10: "sequence lengths in real datasets are
//! predominantly under 8K"). Those corpora are not redistributable here,
//! so we generate synthetic samples from log-normal fits matching the
//! published distribution shapes (heavy mass < 2K for ShareGPT chat,
//! wider conversational/coding mix for Splitwise) — DESIGN.md §2.

use crate::util::rng::Rng;

/// Named sequence-length distribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqlenDist {
    /// Chat-style (ShareGPT-like): median ≈ 600 tokens, long tail.
    ShareGpt,
    /// Production mix (Splitwise-like): median ≈ 1.2K, fatter tail.
    Splitwise,
    /// Fixed length (controlled experiments).
    Fixed(usize),
}

impl SeqlenDist {
    /// Draw one total sequence length (prompt + generation), clamped to
    /// `max_seq`.
    pub fn sample(&self, rng: &mut Rng, max_seq: usize) -> usize {
        let v = match self {
            // ln-median 6.4 ≈ 600, sigma 1.0 -> ~77% of mass < 2K, >99% < 8K
            SeqlenDist::ShareGpt => rng.lognormal(6.4, 1.0),
            // ln-median 7.1 ≈ 1.2K, sigma 0.9 -> ~95% < 8K
            SeqlenDist::Splitwise => rng.lognormal(7.1, 0.9),
            SeqlenDist::Fixed(n) => return (*n).min(max_seq),
        };
        (v.round() as usize).clamp(1, max_seq)
    }

    /// Empirical fraction of sampled lengths below `threshold`.
    pub fn fraction_below(&self, threshold: usize, samples: usize, seed: u64) -> f64 {
        let mut rng = Rng::seed_from_u64(seed);
        let below = (0..samples)
            .filter(|_| self.sample(&mut rng, usize::MAX / 2) < threshold)
            .count();
        below as f64 / samples as f64
    }
}

/// One inference request in a trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceRequest {
    pub id: u64,
    /// Arrival offset from trace start, microseconds.
    pub arrival_us: u64,
    pub prompt_len: usize,
    pub gen_len: usize,
}

/// Deterministic Poisson-arrival request trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    /// Generate `n` requests with exponential inter-arrivals at `rps`
    /// requests/second; prompt lengths from `dist`, generation lengths
    /// uniform in `gen_range`. Fully determined by `seed`.
    ///
    /// Convention: `rps` is passed to [`Rng::exponential`] as the rate λ,
    /// so gaps average 1/rps seconds (audited — see `offered_rate_near_target`).
    pub fn poisson(
        n: usize,
        rps: f64,
        dist: SeqlenDist,
        gen_range: (usize, usize),
        max_seq: usize,
        seed: u64,
    ) -> Self {
        assert!(rps > 0.0 && gen_range.0 >= 1 && gen_range.0 <= gen_range.1);
        let mut rng = Rng::seed_from_u64(seed);
        let mut t_us = 0u64;
        // Poisson process: exponential gaps with mean 1/rps seconds.
        let requests = (0..n as u64)
            .map(|id| {
                let gap: f64 = rng.exponential(rps);
                t_us += (gap * 1e6) as u64;
                let gen_len = rng.range(gen_range.0, gen_range.1);
                let total = dist.sample(&mut rng, max_seq);
                let prompt_len = total.saturating_sub(gen_len).max(1);
                TraceRequest { id, arrival_us: t_us, prompt_len, gen_len }
            })
            .collect();
        Self { requests }
    }

    /// Mean request rate actually realised by the sampled arrivals,
    /// requests/second (0 for traces with fewer than two distinct
    /// arrival times). This is what an open-loop replay of the trace
    /// offers the server; it differs from the requested `rps` only by
    /// sampling noise (see `achieved_rps_within_tolerance_across_seeds`).
    pub fn achieved_rps(&self) -> f64 {
        match (self.requests.first(), self.requests.last()) {
            (Some(f), Some(l)) if l.arrival_us > f.arrival_us => {
                (self.requests.len() - 1) as f64 / ((l.arrival_us - f.arrival_us) as f64 / 1e6)
            }
            _ => 0.0,
        }
    }

    /// Arrival span of the trace, microseconds (0 if < 2 requests;
    /// saturating, so a hand-built unsorted trace cannot underflow).
    pub fn span_us(&self) -> u64 {
        match (self.requests.first(), self.requests.last()) {
            (Some(f), Some(l)) => l.arrival_us.saturating_sub(f.arrival_us),
            _ => 0,
        }
    }
}

/// Draw `n` samples of a distribution (for the Fig. 10 histogram bench).
pub fn sample_lengths(dist: SeqlenDist, n: usize, max_seq: usize, seed: u64) -> Vec<usize> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n).map(|_| dist.sample(&mut rng, max_seq)).collect()
}

/// Histogram with the paper's Fig. 10 bucket edges.
pub fn histogram(lengths: &[usize], edges: &[usize]) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let mut lo = 0usize;
    for &hi in edges {
        let c = lengths.iter().filter(|&&l| l >= lo && l < hi).count();
        out.push((format!("[{lo},{hi})"), c));
        lo = hi;
    }
    out.push((format!("[{lo},inf)"), lengths.iter().filter(|&&l| l >= lo).count()));
    out
}

/// Poisson sampler reused by load generators (seeded).
pub fn poisson_count(mean: f64, rng: &mut Rng) -> usize {
    rng.poisson(mean)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharegpt_mass_under_8k() {
        // Fig. 10: sequence lengths predominantly under 8K.
        let f = SeqlenDist::ShareGpt.fraction_below(8192, 20_000, 1);
        assert!(f > 0.95, "{f}");
    }

    #[test]
    fn splitwise_mass_under_8k_but_longer_than_sharegpt() {
        let sg = SeqlenDist::ShareGpt.fraction_below(2048, 20_000, 2);
        let sw = SeqlenDist::Splitwise.fraction_below(2048, 20_000, 2);
        assert!(sw < sg, "splitwise should skew longer: {sw} vs {sg}");
        assert!(SeqlenDist::Splitwise.fraction_below(8192, 20_000, 3) > 0.9);
    }

    #[test]
    fn trace_is_deterministic_and_ordered() {
        let a = Trace::poisson(100, 10.0, SeqlenDist::ShareGpt, (8, 64), 4096, 7);
        let b = Trace::poisson(100, 10.0, SeqlenDist::ShareGpt, (8, 64), 4096, 7);
        assert_eq!(a.requests, b.requests);
        assert!(a.requests.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
        assert!(a.requests.iter().all(|r| r.prompt_len >= 1 && r.gen_len >= 8));
    }

    #[test]
    fn offered_rate_near_target() {
        let t = Trace::poisson(2000, 50.0, SeqlenDist::Fixed(128), (8, 8), 4096, 11);
        let r = t.achieved_rps();
        assert!((r - 50.0).abs() / 50.0 < 0.15, "{r}");
    }

    #[test]
    fn histogram_partitions_everything() {
        let lens = sample_lengths(SeqlenDist::ShareGpt, 5000, 16384, 5);
        let h = histogram(&lens, &[1024, 2048, 4096, 8192, 16384]);
        let total: usize = h.iter().map(|(_, c)| c).sum();
        assert_eq!(total, lens.len());
    }

    #[test]
    fn fixed_dist_clamps() {
        let mut rng = Rng::seed_from_u64(0);
        assert_eq!(SeqlenDist::Fixed(9999).sample(&mut rng, 512), 512);
    }

    // ---- pacing invariants (property-style, many seeds × rates) ----

    #[test]
    fn property_arrivals_monotone_for_all_seeds_and_rates() {
        // Open-loop replay requires arrival_us sorted; the generator must
        // guarantee it for any (seed, rps) including rates high enough
        // that gaps round to 0 µs.
        for seed in 0..25u64 {
            for &rps in &[0.5, 5.0, 50.0, 500.0, 50_000.0] {
                let t = Trace::poisson(64, rps, SeqlenDist::ShareGpt, (1, 16), 2048, seed);
                assert!(
                    t.requests.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us),
                    "non-monotone arrivals at seed {seed} rps {rps}"
                );
                assert!(
                    t.requests.windows(2).all(|w| w[0].id < w[1].id),
                    "ids must be strictly increasing"
                );
                assert!(t.requests.iter().all(|r| r.prompt_len >= 1));
                assert!(t.requests.iter().all(|r| (1..=16).contains(&r.gen_len)));
            }
        }
    }

    #[test]
    fn achieved_rps_within_tolerance_across_seeds() {
        // n = 3000 gaps: sd of the mean ≈ rate/sqrt(n) ≈ 1.8%, so the 10%
        // tolerance is a ≥5σ margin at every seed.
        for seed in [3u64, 17, 99, 2024] {
            let t = Trace::poisson(3000, 80.0, SeqlenDist::Fixed(64), (4, 4), 4096, seed);
            let r = t.achieved_rps();
            assert!((r - 80.0).abs() / 80.0 < 0.10, "seed {seed}: achieved {r}");
        }
    }

    #[test]
    fn empty_trace_edge_case() {
        let t = Trace::poisson(0, 10.0, SeqlenDist::ShareGpt, (1, 8), 1024, 1);
        assert!(t.requests.is_empty());
        assert_eq!(t.achieved_rps(), 0.0);
        assert_eq!(t.span_us(), 0);
    }

    #[test]
    fn single_request_trace_edge_case() {
        let t = Trace::poisson(1, 10.0, SeqlenDist::ShareGpt, (1, 8), 1024, 1);
        assert_eq!(t.requests.len(), 1);
        // one arrival: no measurable rate, zero span — must not divide by 0
        assert_eq!(t.achieved_rps(), 0.0);
        assert_eq!(t.span_us(), 0);
    }
}
