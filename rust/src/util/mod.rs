//! In-tree utility substrate (the build is fully offline, so RNG, JSON,
//! CLI parsing and the bench harness are implemented here rather than
//! pulled from crates.io — DESIGN.md §2 substitution table).

pub mod bench;
pub mod json;
pub mod rng;
