//! In-tree utility substrate (the build is fully offline, so RNG, JSON,
//! CLI parsing and the bench harness are implemented here rather than
//! pulled from crates.io — DESIGN.md §2 substitution table; the lone
//! external-looking dependency, `anyhow`, is likewise an in-tree subset
//! vendored at `rust/vendor/anyhow`).

pub mod bench;
pub mod clock;
pub mod json;
pub mod linalg;
pub mod pool;
pub mod rng;
