//! Deterministic scoped worker pool — the host-side analogue of the
//! paper's cluster blocks (§Parallel in DESIGN.md).
//!
//! The functional stack has exactly one parallelism story: *independent
//! output ranges* (cluster blocks over KV partitions, heads, MLP/logits
//! columns) are distributed across host threads, while every individual
//! output keeps its single in-order accumulation chain (the PR 3
//! bit-exactness contract). This module is the one place that
//! distribution is implemented; call sites only say *which axis* is
//! independent:
//!
//! * [`Pool::run`] — `ParallelFor` over `0..n_items` for side effects;
//! * [`Pool::run_map`] — the same, collecting one result per item **in
//!   item order** (how the dataflows return per-block/per-head partials
//!   that the caller merges in the serial code's order);
//! * [`Pool::run_ranges`] — one contiguous `[lo, hi)` range per worker
//!   (how the matmul/logits kernels keep their column-tile loops).
//!
//! **Determinism contract.** The partition of `0..n_items` into worker
//! ranges depends only on `(n_items, threads)` — never on scheduling —
//! and results are collected in item order, so any merge the caller
//! performs happens in the same order at every pool size. Workers never
//! share mutable state; a task that needs scratch allocates its own.
//! Consequently `f32`/`f64` results are byte-identical across pool sizes
//! 1/2/4/8/… (pinned by `tests/integration_parallel.rs`).
//!
//! **Panics** in any task propagate to the caller (the scope joins every
//! worker, then re-raises the first payload). At `threads == 1` — or
//! when `n_items` is 0 or 1 — everything runs inline on the caller's
//! thread: no spawns, the exact serial code path.
//!
//! Workers are scoped `std::thread`s spawned per call (dependency-free,
//! borrows allowed in tasks). Spawn cost is ~tens of µs per worker, so
//! parallelise work units of ≥ ~100 µs; a persistent-worker pool is the
//! documented upgrade path if profiles ever show spawn overhead
//! dominating (DESIGN.md §Parallel).

/// Per-task work (multiply-accumulates, ~50–100 µs scalar) below which
/// a scoped spawn (~10–20 µs on conventional hosts, far more on some
/// virtualised ones) cannot pay for itself. Owners that *auto*-size
/// their pool check their workload against this before going wide
/// (`FunctionalBackend::set_threads`); explicitly sized pools are never
/// second-guessed — benches and the invariance tests pick their own
/// widths.
pub const MIN_TASK_MACS: usize = 1 << 16;

/// Hard ceiling on pool width. Spawning is per `run*` call, so an
/// absurd width would attempt thousands of OS threads per kernel call
/// and abort the process when the OS refuses one; no machine this
/// simulator targets benefits beyond this. `ServeConfig::validate`
/// rejects larger `threads` values with a readable error; the
/// constructor clamps as the last line of defence.
pub const MAX_THREADS: usize = 512;

/// A fixed-width worker pool. Cheap to construct; holds no threads
/// between calls.
#[derive(Debug, Clone)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// A pool of exactly `threads` workers (clamped to
    /// `1..=`[`MAX_THREADS`]).
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.clamp(1, MAX_THREADS) }
    }

    /// The inline pool: every `run*` degrades to the serial loop.
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Pool sized by [`Self::auto_threads`] (the `CLUSTERFUSION_THREADS`
    /// override, else the host's available parallelism).
    pub fn auto() -> Self {
        Self::new(Self::auto_threads())
    }

    /// The explicit `CLUSTERFUSION_THREADS` override, when set to a
    /// positive integer (the CI matrix legs set it). An explicit env
    /// width wins over every auto heuristic, including the
    /// [`MIN_TASK_MACS`] work-size gate.
    pub fn env_threads() -> Option<usize> {
        std::env::var("CLUSTERFUSION_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1)
    }

    /// Default worker count: [`Self::env_threads`] if set, otherwise
    /// `std::thread::available_parallelism()`, otherwise 1.
    pub fn auto_threads() -> usize {
        Self::env_threads()
            .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Deterministic contiguous partition: worker `w` of `workers` owns
    /// `[w·n/workers, (w+1)·n/workers)` — a pure function of the inputs.
    #[inline]
    fn chunk(w: usize, workers: usize, n: usize) -> (usize, usize) {
        (w * n / workers, (w + 1) * n / workers)
    }

    /// Partition `0..n_items` into one contiguous range per worker and
    /// run `f(lo, hi)` on each; returns the per-worker results **in
    /// worker (= ascending range) order**. Worker 0's range runs on the
    /// calling thread, so `threads == 1` (or `n_items ≤ 1`) is the exact
    /// inline path with zero spawns.
    pub fn run_ranges<T, F>(&self, n_items: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, usize) -> T + Sync,
    {
        if n_items == 0 {
            return Vec::new();
        }
        let workers = self.threads.min(n_items);
        if workers == 1 {
            return vec![f(0, n_items)];
        }
        std::thread::scope(|s| {
            let f = &f;
            let handles: Vec<_> = (1..workers)
                .map(|w| {
                    let (lo, hi) = Self::chunk(w, workers, n_items);
                    s.spawn(move || f(lo, hi))
                })
                .collect();
            let (lo0, hi0) = Self::chunk(0, workers, n_items);
            let mut out = Vec::with_capacity(workers);
            out.push(f(lo0, hi0));
            for h in handles {
                match h.join() {
                    Ok(v) => out.push(v),
                    // first panicking worker wins; the scope joins the
                    // rest during unwind
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
            out
        })
    }

    /// `ParallelFor` with per-item results, collected **in item order**:
    /// `run_map(n, f)[i] == f(i)` for every `i`, at any pool size.
    pub fn run_map<T, F>(&self, n_items: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let chunks = self.run_ranges(n_items, |lo, hi| (lo..hi).map(&f).collect::<Vec<T>>());
        let mut out = Vec::with_capacity(n_items);
        for c in chunks {
            out.extend(c);
        }
        out
    }

    /// `ParallelFor` for side effects: run `f(i)` once for each `i` in
    /// `0..n_items`, distributed across the pool. The caller is
    /// responsible for item independence (tasks must not race on shared
    /// state); prefer [`Self::run_map`] + a serial merge when items
    /// produce data.
    pub fn run<F>(&self, n_items: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.run_map(n_items, |i| f(i));
    }
}

impl Default for Pool {
    /// Defaults to the serial pool — parallelism is always an explicit
    /// opt-in at the owner (`FunctionalBackend`, benches, tests).
    fn default() -> Self {
        Self::serial()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_map_preserves_item_order_at_every_pool_size() {
        for threads in [1usize, 2, 3, 4, 8, 16] {
            let pool = Pool::new(threads);
            let got = pool.run_map(13, |i| i * i);
            let want: Vec<usize> = (0..13).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn empty_range_is_a_no_op() {
        let pool = Pool::new(8);
        let calls = AtomicUsize::new(0);
        pool.run(0, |_| {
            calls.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 0);
        assert!(pool.run_map(0, |i| i).is_empty());
        assert!(pool.run_ranges(0, |lo, hi| (lo, hi)).is_empty());
    }

    #[test]
    fn fewer_items_than_threads_runs_each_exactly_once() {
        let pool = Pool::new(8);
        let calls = AtomicUsize::new(0);
        let got = pool.run_map(3, |i| {
            calls.fetch_add(1, Ordering::SeqCst);
            i + 100
        });
        assert_eq!(got, vec![100, 101, 102]);
        assert_eq!(calls.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn ranges_partition_exactly_and_deterministically() {
        for threads in [1usize, 2, 3, 4, 7] {
            for n in [1usize, 2, 5, 16, 33] {
                let pool = Pool::new(threads);
                let ranges = pool.run_ranges(n, |lo, hi| (lo, hi));
                // contiguous, ascending, covering 0..n exactly
                assert_eq!(ranges.first().unwrap().0, 0);
                assert_eq!(ranges.last().unwrap().1, n);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "threads={threads} n={n}");
                }
                // pure function of (n, threads)
                assert_eq!(ranges, pool.run_ranges(n, |lo, hi| (lo, hi)));
            }
        }
    }

    #[test]
    fn threads_one_runs_inline() {
        let pool = Pool::serial();
        let here = std::thread::current().id();
        let ids = pool.run_map(5, |_| std::thread::current().id());
        assert!(ids.iter().all(|id| *id == here), "serial pool must not spawn");
    }

    #[test]
    fn panic_in_a_task_propagates() {
        for threads in [1usize, 4] {
            let pool = Pool::new(threads);
            let r = catch_unwind(AssertUnwindSafe(|| {
                pool.run(8, |i| {
                    if i == 5 {
                        panic!("task 5 exploded");
                    }
                });
            }));
            let err = r.expect_err("panic must propagate to the caller");
            let msg = err
                .downcast_ref::<&str>()
                .copied()
                .or_else(|| err.downcast_ref::<String>().map(String::as_str))
                .unwrap_or("");
            assert!(msg.contains("task 5 exploded"), "threads={threads}: {msg}");
        }
    }

    #[test]
    fn auto_threads_is_at_least_one_and_width_is_capped() {
        assert!(Pool::auto_threads() >= 1);
        assert!(Pool::auto().threads() >= 1);
        assert_eq!(Pool::new(0).threads(), 1, "zero clamps to serial");
        assert_eq!(Pool::default().threads(), 1);
        assert_eq!(Pool::new(usize::MAX).threads(), MAX_THREADS, "width is capped");
    }

    #[test]
    fn f32_sums_are_byte_identical_across_pool_sizes() {
        // each item's sum is its own in-order chain; pool size must not
        // change a single bit of any item's result
        let data: Vec<f32> = (0..4096).map(|i| ((i * 2654435761usize) as f32).sin()).collect();
        let per_item = |i: usize| -> f32 {
            let mut acc = 0f32;
            for v in &data[i * 256..(i + 1) * 256] {
                acc += *v;
            }
            acc
        };
        let want: Vec<u32> =
            Pool::serial().run_map(16, per_item).iter().map(|v| v.to_bits()).collect();
        for threads in [2usize, 4, 8] {
            let got: Vec<u32> =
                Pool::new(threads).run_map(16, per_item).iter().map(|v| v.to_bits()).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }
}
