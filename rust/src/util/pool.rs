//! Deterministic persistent worker pool — the host-side analogue of the
//! paper's cluster blocks (§Parallel in DESIGN.md).
//!
//! The functional stack has exactly one parallelism story: *independent
//! output ranges* (cluster blocks over KV partitions, heads, MLP/logits
//! columns) are distributed across host threads, while every individual
//! output keeps its single in-order accumulation chain (the PR 3
//! bit-exactness contract). This module is the one place that
//! distribution is implemented; call sites only say *which axis* is
//! independent:
//!
//! * [`Pool::run`] — `ParallelFor` over `0..n_items` for side effects;
//! * [`Pool::run_map`] — the same, collecting one result per item **in
//!   item order** (how the dataflows return per-block/per-head partials
//!   that the caller merges in the serial code's order);
//! * [`Pool::run_ranges`] — one contiguous `[lo, hi)` range per worker
//!   (how the matmul/logits kernels keep their column-tile loops).
//!
//! **Determinism contract.** The partition of `0..n_items` into worker
//! ranges depends only on `(n_items, threads)` — never on scheduling —
//! and results are collected in item order, so any merge the caller
//! performs happens in the same order at every pool size. Workers never
//! share mutable state; a task that needs scratch allocates its own.
//! Consequently `f32`/`f64` results are byte-identical across pool sizes
//! 1/2/4/8/… (pinned by `tests/integration_parallel.rs`).
//!
//! **Panics** in any task propagate to the caller (the dispatch drains
//! every worker result, then re-raises the lowest-index payload). The
//! pool stays **usable** afterwards: workers catch task panics and never
//! die, so the next `run*` call behaves normally (pinned by
//! `integration_parallel::pool_stays_usable_after_task_panic`). At
//! `threads == 1` — or when `n_items` is 0 or 1 — everything runs inline
//! on the caller's thread: no worker traffic, the exact serial code
//! path.
//!
//! **Workers are persistent**: `Pool::new(t)` spawns `t − 1` OS threads
//! once, each owning a one-slot mailbox; `run*` posts one job per worker
//! and runs worker 0's range on the calling thread, then waits on a
//! per-dispatch latch. Idle workers park on their mailbox condvar; the
//! last clone's `Drop` signals shutdown and joins every worker. This
//! replaces the previous per-call `std::thread::scope` spawns (~163 µs
//! per spawn measured on the authoring container) with a
//! mutex+condvar round-trip (~1–10 µs), the host-side analogue of the
//! paper replacing per-operator kernel launches with one persistent
//! cluster-resident kernel. `Pool` is `Clone`; clones share the same
//! workers and concurrent dispatches from clones serialise on an
//! internal lock.
//!
//! Dispatch volume is observable via [`Pool::stats`]
//! (`dispatches`/`tasks` counters, current remote-job depth) so the
//! serving layer can export `pool_dispatch_total` / `pool_tasks_total` /
//! `pool_queue_depth` through `obs::MetricsRegistry`.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;

/// Per-task work (multiply-accumulates, ~50–100 µs scalar) below which
/// even a persistent-pool dispatch (~1–10 µs mailbox round-trip per
/// worker) cannot pay for itself. Owners that *auto*-size their pool
/// check their workload against this before going wide
/// (`FunctionalBackend::set_threads`); explicitly sized pools are never
/// second-guessed — benches and the invariance tests pick their own
/// widths, and an explicit `CLUSTERFUSION_THREADS` always wins.
pub const MIN_TASK_MACS: usize = 1 << 16;

/// Hard ceiling on pool width. Workers are resident for the pool's
/// lifetime, so an absurd width would pin thousands of parked OS
/// threads; no machine this simulator targets benefits beyond this.
/// `ServeConfig::validate` rejects larger `threads` values with a
/// readable error; the constructor clamps as the last line of defence.
pub const MAX_THREADS: usize = 512;

/// A job posted to one worker's mailbox: run `task(w)` then count down
/// the dispatch latch. The pointers are only valid until the latch hits
/// zero — the dispatching `run_ranges` call does not return (or unwind)
/// before that, so workers never observe them dangling.
struct Job {
    task: &'static (dyn Fn(usize) + Sync),
    w: usize,
    latch: *const Latch,
}

// SAFETY: `task` is `Sync` (shared immutably across workers) and the
// latch pointer is only dereferenced while the dispatching call keeps
// the latch alive (see `Job` docs).
unsafe impl Send for Job {}

/// Count-down latch: the dispatcher waits until every posted job has
/// signalled completion. Notification happens while the lock is held so
/// a worker never touches the latch after the dispatcher could have
/// freed it.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
}

impl Latch {
    fn new(count: usize) -> Self {
        Self { remaining: Mutex::new(count), done: Condvar::new() }
    }

    fn count_down(&self) {
        let mut g = self.remaining.lock().unwrap_or_else(PoisonError::into_inner);
        *g -= 1;
        if *g == 0 {
            // notify while holding the lock: after we release it the
            // dispatcher may free the latch
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.remaining.lock().unwrap_or_else(PoisonError::into_inner);
        while *g > 0 {
            g = self.done.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

/// One worker's single-slot inbox. The dispatch lock guarantees at most
/// one outstanding job per mailbox.
struct Mailbox {
    slot: Mutex<Option<Job>>,
    ready: Condvar,
}

impl Mailbox {
    fn post(&self, job: Job) {
        let mut slot = self.slot.lock().unwrap_or_else(PoisonError::into_inner);
        debug_assert!(slot.is_none(), "mailbox already holds a job");
        *slot = Some(job);
        self.ready.notify_one();
    }
}

/// State shared between the owning `Pool` clones and the workers.
struct Shared {
    mailboxes: Vec<Mailbox>,
    shutdown: AtomicBool,
}

fn worker_loop(shared: Arc<Shared>, idx: usize) {
    let mb = &shared.mailboxes[idx];
    loop {
        let job = {
            let mut slot = mb.slot.lock().unwrap_or_else(PoisonError::into_inner);
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match slot.take() {
                    Some(j) => break j,
                    None => slot = mb.ready.wait(slot).unwrap_or_else(PoisonError::into_inner),
                }
            }
        };
        // The task itself catches panics into its result slot; this
        // outer catch is belt-and-braces so a worker can never die and
        // the pool stays usable after any task panic.
        let _ = catch_unwind(AssertUnwindSafe(|| (job.task)(job.w)));
        // SAFETY: the dispatcher keeps the latch alive until this count
        // reaches zero (see `Job`).
        unsafe { &*job.latch }.count_down();
    }
}

/// The resident worker set: joined when the last `Pool` clone drops.
struct Inner {
    shared: Arc<Shared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    /// Serialises dispatches from clones sharing these workers (each
    /// mailbox holds at most one job).
    dispatch: Mutex<()>,
}

impl Drop for Inner {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        for mb in &self.shared.mailboxes {
            // take the mailbox lock so a worker between its shutdown
            // check and its wait cannot miss the wakeup
            let _g = mb.slot.lock().unwrap_or_else(PoisonError::into_inner);
            mb.ready.notify_all();
        }
        let handles = std::mem::take(&mut *self.handles.lock().unwrap_or_else(PoisonError::into_inner));
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Cumulative dispatch counters for one worker set (shared by clones).
#[derive(Debug, Default)]
struct Counters {
    dispatches: AtomicU64,
    tasks: AtomicU64,
    inflight: AtomicU64,
}

/// A snapshot of a pool's dispatch activity (see [`Pool::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PoolStats {
    /// `run`/`run_map`/`run_ranges` calls that fanned out (or ran
    /// inline) — one per call with `n_items > 0`.
    pub dispatches: u64,
    /// Worker ranges executed across all dispatches (1 per dispatch on
    /// the inline path, `min(threads, n_items)` otherwise).
    pub tasks: u64,
    /// Remote jobs currently posted and not yet completed. Zero between
    /// dispatches; sampled by the serving layer as `pool_queue_depth`.
    pub queue_depth: u64,
}

/// A fixed-width pool of persistent workers. `new(t)` spawns `t − 1`
/// threads once; they stay parked between calls and are joined when the
/// last clone drops. `threads == 1` holds no threads at all.
pub struct Pool {
    threads: usize,
    counters: Arc<Counters>,
    inner: Option<Arc<Inner>>,
}

impl Clone for Pool {
    /// Clones share the same resident workers and counters.
    fn clone(&self) -> Self {
        Self { threads: self.threads, counters: self.counters.clone(), inner: self.inner.clone() }
    }
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool")
            .field("threads", &self.threads)
            .field("resident_workers", &self.inner.as_ref().map_or(0, |_| self.threads - 1))
            .finish()
    }
}

impl Pool {
    /// A pool of exactly `threads` workers (clamped to
    /// `1..=`[`MAX_THREADS`]). Spawns the `threads − 1` resident worker
    /// threads immediately; worker 0 is always the calling thread.
    pub fn new(threads: usize) -> Self {
        let threads = threads.clamp(1, MAX_THREADS);
        let counters = Arc::new(Counters::default());
        if threads == 1 {
            return Self { threads, counters, inner: None };
        }
        let shared = Arc::new(Shared {
            mailboxes: (0..threads - 1)
                .map(|_| Mailbox { slot: Mutex::new(None), ready: Condvar::new() })
                .collect(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..threads - 1)
            .map(|idx| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("cf-pool-{}", idx + 1))
                    .spawn(move || worker_loop(shared, idx))
                    .expect("spawn pool worker")
            })
            .collect();
        Self {
            threads,
            counters,
            inner: Some(Arc::new(Inner {
                shared,
                handles: Mutex::new(handles),
                dispatch: Mutex::new(()),
            })),
        }
    }

    /// The inline pool: every `run*` degrades to the serial loop.
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Pool sized by [`Self::auto_threads`] (the `CLUSTERFUSION_THREADS`
    /// override, else the host's available parallelism).
    pub fn auto() -> Self {
        Self::new(Self::auto_threads())
    }

    /// The explicit `CLUSTERFUSION_THREADS` override, when set to a
    /// positive integer (the CI matrix legs set it). An explicit env
    /// width wins over every auto heuristic, including the
    /// [`MIN_TASK_MACS`] work-size gate.
    pub fn env_threads() -> Option<usize> {
        std::env::var("CLUSTERFUSION_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1)
    }

    /// Default worker count: [`Self::env_threads`] if set, otherwise
    /// `std::thread::available_parallelism()`, otherwise 1.
    pub fn auto_threads() -> usize {
        Self::env_threads()
            .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Snapshot of cumulative dispatch/task counts and the current
    /// remote-job depth. Shared by clones; never reset.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            dispatches: self.counters.dispatches.load(Ordering::Relaxed),
            tasks: self.counters.tasks.load(Ordering::Relaxed),
            queue_depth: self.counters.inflight.load(Ordering::Relaxed),
        }
    }

    /// Deterministic contiguous partition: worker `w` of `workers` owns
    /// `[w·n/workers, (w+1)·n/workers)` — a pure function of the inputs.
    #[inline]
    fn chunk(w: usize, workers: usize, n: usize) -> (usize, usize) {
        (w * n / workers, (w + 1) * n / workers)
    }

    /// Partition `0..n_items` into one contiguous range per worker and
    /// run `f(lo, hi)` on each; returns the per-worker results **in
    /// worker (= ascending range) order**. Worker 0's range runs on the
    /// calling thread, so `threads == 1` (or `n_items ≤ 1`) is the exact
    /// inline path with zero worker traffic.
    pub fn run_ranges<T, F>(&self, n_items: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, usize) -> T + Sync,
    {
        if n_items == 0 {
            return Vec::new();
        }
        let workers = self.threads.min(n_items);
        self.counters.dispatches.fetch_add(1, Ordering::Relaxed);
        self.counters.tasks.fetch_add(workers as u64, Ordering::Relaxed);
        if workers == 1 {
            return vec![f(0, n_items)];
        }
        let inner = self.inner.as_ref().expect("threads > 1 implies resident workers");
        let dispatch = inner.dispatch.lock().unwrap_or_else(PoisonError::into_inner);

        // one result slot per worker, written exactly once each
        let slots: Vec<Mutex<Option<std::thread::Result<T>>>> =
            (0..workers).map(|_| Mutex::new(None)).collect();
        let latch = Latch::new(workers - 1);
        let task = |w: usize| {
            let (lo, hi) = Self::chunk(w, workers, n_items);
            let r = catch_unwind(AssertUnwindSafe(|| f(lo, hi)));
            *slots[w].lock().unwrap_or_else(PoisonError::into_inner) = Some(r);
        };
        self.counters.inflight.store(workers as u64 - 1, Ordering::Relaxed);
        {
            let task_ref: &(dyn Fn(usize) + Sync) = &task;
            // SAFETY: the borrowed task (and everything it captures)
            // outlives every posted job — we run worker 0 inline and
            // then block on the latch until all remote jobs have
            // finished before `task` goes out of scope, even when a
            // task panicked (the panic is parked in its slot and only
            // resumed after the latch wait).
            let task_static: &'static (dyn Fn(usize) + Sync) =
                unsafe { std::mem::transmute(task_ref) };
            for w in 1..workers {
                inner.shared.mailboxes[w - 1].post(Job { task: task_static, w, latch: &latch });
            }
        }
        task(0);
        latch.wait();
        self.counters.inflight.store(0, Ordering::Relaxed);
        drop(dispatch);

        let mut out = Vec::with_capacity(workers);
        for slot in slots {
            match slot
                .into_inner()
                .unwrap_or_else(PoisonError::into_inner)
                .expect("every dispatched worker writes its result slot")
            {
                Ok(v) => out.push(v),
                // lowest-index panicking worker wins, matching the old
                // scoped-join order; remaining results are dropped
                Err(payload) => resume_unwind(payload),
            }
        }
        out
    }

    /// `ParallelFor` with per-item results, collected **in item order**:
    /// `run_map(n, f)[i] == f(i)` for every `i`, at any pool size.
    pub fn run_map<T, F>(&self, n_items: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let chunks = self.run_ranges(n_items, |lo, hi| (lo..hi).map(&f).collect::<Vec<T>>());
        let mut out = Vec::with_capacity(n_items);
        for c in chunks {
            out.extend(c);
        }
        out
    }

    /// `ParallelFor` for side effects: run `f(i)` once for each `i` in
    /// `0..n_items`, distributed across the pool. The caller is
    /// responsible for item independence (tasks must not race on shared
    /// state); prefer [`Self::run_map`] + a serial merge when items
    /// produce data.
    pub fn run<F>(&self, n_items: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.run_map(n_items, |i| f(i));
    }
}

impl Default for Pool {
    /// Defaults to the serial pool — parallelism is always an explicit
    /// opt-in at the owner (`FunctionalBackend`, benches, tests).
    fn default() -> Self {
        Self::serial()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_map_preserves_item_order_at_every_pool_size() {
        for threads in [1usize, 2, 3, 4, 8, 16] {
            let pool = Pool::new(threads);
            let got = pool.run_map(13, |i| i * i);
            let want: Vec<usize> = (0..13).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn empty_range_is_a_no_op() {
        let pool = Pool::new(8);
        let calls = AtomicUsize::new(0);
        pool.run(0, |_| {
            calls.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 0);
        assert!(pool.run_map(0, |i| i).is_empty());
        assert!(pool.run_ranges(0, |lo, hi| (lo, hi)).is_empty());
    }

    #[test]
    fn fewer_items_than_threads_runs_each_exactly_once() {
        let pool = Pool::new(8);
        let calls = AtomicUsize::new(0);
        let got = pool.run_map(3, |i| {
            calls.fetch_add(1, Ordering::SeqCst);
            i + 100
        });
        assert_eq!(got, vec![100, 101, 102]);
        assert_eq!(calls.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn ranges_partition_exactly_and_deterministically() {
        for threads in [1usize, 2, 3, 4, 7] {
            for n in [1usize, 2, 5, 16, 33] {
                let pool = Pool::new(threads);
                let ranges = pool.run_ranges(n, |lo, hi| (lo, hi));
                // contiguous, ascending, covering 0..n exactly
                assert_eq!(ranges.first().unwrap().0, 0);
                assert_eq!(ranges.last().unwrap().1, n);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "threads={threads} n={n}");
                }
                // pure function of (n, threads)
                assert_eq!(ranges, pool.run_ranges(n, |lo, hi| (lo, hi)));
            }
        }
    }

    #[test]
    fn threads_one_runs_inline() {
        let pool = Pool::serial();
        let here = std::thread::current().id();
        let ids = pool.run_map(5, |_| std::thread::current().id());
        assert!(ids.iter().all(|id| *id == here), "serial pool must not use workers");
    }

    #[test]
    fn workers_are_reused_across_calls() {
        // persistent pool: the same spawned threads serve every call
        let pool = Pool::new(3);
        let ids = |_: usize| std::thread::current().id();
        let first = pool.run_map(3, ids);
        for _ in 0..50 {
            assert_eq!(pool.run_map(3, ids), first, "worker identity must be stable");
        }
    }

    #[test]
    fn clones_share_the_same_workers_and_counters() {
        let pool = Pool::new(4);
        let clone = pool.clone();
        let a = pool.run_map(4, |_| std::thread::current().id());
        let b = clone.run_map(4, |_| std::thread::current().id());
        assert_eq!(a, b, "clones must dispatch to the same resident workers");
        assert_eq!(pool.stats(), clone.stats());
        assert_eq!(pool.stats().dispatches, 2);
        assert_eq!(pool.stats().tasks, 8);
    }

    #[test]
    fn panic_in_a_task_propagates() {
        for threads in [1usize, 4] {
            let pool = Pool::new(threads);
            let r = catch_unwind(AssertUnwindSafe(|| {
                pool.run(8, |i| {
                    if i == 5 {
                        panic!("task 5 exploded");
                    }
                });
            }));
            let err = r.expect_err("panic must propagate to the caller");
            let msg = err
                .downcast_ref::<&str>()
                .copied()
                .or_else(|| err.downcast_ref::<String>().map(String::as_str))
                .unwrap_or("");
            assert!(msg.contains("task 5 exploded"), "threads={threads}: {msg}");
        }
    }

    #[test]
    fn pool_is_usable_after_a_task_panic() {
        // pinned lifecycle choice (DESIGN.md §Parallel): usable, not
        // poisoned — workers catch task panics and never die
        let pool = Pool::new(4);
        for round in 0..3 {
            let r = catch_unwind(AssertUnwindSafe(|| {
                pool.run(8, |i| {
                    if i == 2 {
                        panic!("round {round}");
                    }
                });
            }));
            assert!(r.is_err());
            assert_eq!(pool.run_map(8, |i| i * 3), (0..8).map(|i| i * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn auto_threads_is_at_least_one_and_width_is_capped() {
        assert!(Pool::auto_threads() >= 1);
        assert!(Pool::auto().threads() >= 1);
        assert_eq!(Pool::new(0).threads(), 1, "zero clamps to serial");
        assert_eq!(Pool::default().threads(), 1);
        assert_eq!(Pool::new(usize::MAX).threads(), MAX_THREADS, "width is capped");
    }

    #[test]
    fn stats_count_dispatches_and_tasks() {
        let pool = Pool::new(4);
        assert_eq!(pool.stats(), PoolStats::default());
        pool.run_ranges(8, |lo, hi| (lo, hi)); // 4 workers
        pool.run_map(2, |i| i); // 2 workers
        pool.run_map(1, |i| i); // inline, still one dispatch
        pool.run_map(0, |i| i); // no-op, not a dispatch
        let s = pool.stats();
        assert_eq!(s.dispatches, 3);
        assert_eq!(s.tasks, 4 + 2 + 1);
        assert_eq!(s.queue_depth, 0, "idle between dispatches");
    }

    #[test]
    fn f32_sums_are_byte_identical_across_pool_sizes() {
        // each item's sum is its own in-order chain; pool size must not
        // change a single bit of any item's result
        let data: Vec<f32> = (0..4096).map(|i| ((i * 2654435761usize) as f32).sin()).collect();
        let per_item = |i: usize| -> f32 {
            let mut acc = 0f32;
            for v in &data[i * 256..(i + 1) * 256] {
                acc += *v;
            }
            acc
        };
        let want: Vec<u32> =
            Pool::serial().run_map(16, per_item).iter().map(|v| v.to_bits()).collect();
        for threads in [2usize, 4, 8] {
            let got: Vec<u32> =
                Pool::new(threads).run_map(16, per_item).iter().map(|v| v.to_bits()).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }
}
