//! Pluggable time source for the serving stack.
//!
//! Real runs measure latency on the [`WallClock`]; load tests replace it
//! with a [`VirtualClock`] whose microsecond counter is advanced
//! explicitly by the load generator (`loadgen::replay`), making every
//! queue/TTFT/TPOT measurement — and therefore every percentile report —
//! bit-for-bit deterministic across runs and machines (DESIGN.md §4).
//!
//! Determinism rule: a [`VirtualClock`] run must be single-threaded by
//! construction. The load generator drives the engine inline and is the
//! only writer of virtual time; the threaded [`crate::coordinator::server::Server`]
//! is only ever paced against the wall clock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A monotone microsecond time source.
pub trait Clock: Send + Sync {
    /// Microseconds elapsed since the clock's origin.
    fn now_us(&self) -> u64;

    /// Block (wall) or jump (virtual) until `deadline_us`; a deadline in
    /// the past returns immediately.
    fn sleep_until_us(&self, deadline_us: u64);

    /// Advance virtual time by `delta_us`. The wall clock ignores this —
    /// real time passes on its own while work executes.
    fn advance_us(&self, _delta_us: u64) {}
}

/// Shared handle: the engine and the load generator observe one timeline.
pub type SharedClock = Arc<dyn Clock>;

/// Real time, measured from construction.
#[derive(Debug)]
pub struct WallClock {
    origin: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        Self { origin: Instant::now() }
    }

    pub fn shared() -> SharedClock {
        Arc::new(Self::new())
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }

    fn sleep_until_us(&self, deadline_us: u64) {
        let now = self.now_us();
        if deadline_us > now {
            std::thread::sleep(Duration::from_micros(deadline_us - now));
        }
    }
}

/// Deterministic simulated time: starts at 0 and moves only when told to.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now_us: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn shared() -> Arc<Self> {
        Arc::new(Self::new())
    }
}

impl Clock for VirtualClock {
    fn now_us(&self) -> u64 {
        self.now_us.load(Ordering::SeqCst)
    }

    fn sleep_until_us(&self, deadline_us: u64) {
        // Monotone jump: never move backwards.
        self.now_us.fetch_max(deadline_us, Ordering::SeqCst);
    }

    fn advance_us(&self, delta_us: u64) {
        self.now_us.fetch_add(delta_us, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_starts_at_zero_and_advances() {
        let c = VirtualClock::new();
        assert_eq!(c.now_us(), 0);
        c.advance_us(250);
        assert_eq!(c.now_us(), 250);
        c.advance_us(1);
        assert_eq!(c.now_us(), 251);
    }

    #[test]
    fn virtual_sleep_jumps_forward_but_never_backward() {
        let c = VirtualClock::new();
        c.sleep_until_us(1_000);
        assert_eq!(c.now_us(), 1_000);
        c.sleep_until_us(400); // past deadline: no-op
        assert_eq!(c.now_us(), 1_000);
    }

    #[test]
    fn wall_clock_is_monotone_and_sleeps() {
        let c = WallClock::new();
        let a = c.now_us();
        c.sleep_until_us(a + 2_000); // 2 ms
        let b = c.now_us();
        assert!(b >= a + 2_000, "{a} -> {b}");
        c.sleep_until_us(0); // past deadline returns immediately
        assert!(c.now_us() >= b);
    }

    #[test]
    fn shared_virtual_clock_is_one_timeline() {
        let c: Arc<VirtualClock> = VirtualClock::shared();
        let view: SharedClock = c.clone();
        c.advance_us(42);
        assert_eq!(view.now_us(), 42);
    }
}
