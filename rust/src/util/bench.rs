//! Tiny in-tree micro-benchmark harness (offline substitute for
//! criterion): warmup + timed iterations + mean/p50/min report. Used by
//! `rust/benches/hotpath.rs` for the §Perf pass.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    /// Mean iterations per second (the §Perf throughput figure — e.g.
    /// dataflow evals/s against the DESIGN.md §5 1e5 target).
    pub fn per_sec(&self) -> f64 {
        if self.mean_ns > 0.0 {
            1e9 / self.mean_ns
        } else {
            f64::INFINITY
        }
    }

    /// [`Self::report`] plus a throughput column (`unit`/s), used by the
    /// hot-path harness's per-kernel throughput lines.
    pub fn report_rate(&self, unit: &str) -> String {
        format!("{}  {:>10.3e} {unit}/s", self.report(), self.per_sec())
    }

    pub fn report(&self) -> String {
        fn fmt(ns: f64) -> String {
            if ns < 1e3 {
                format!("{ns:.0} ns")
            } else if ns < 1e6 {
                format!("{:.2} µs", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2} ms", ns / 1e6)
            } else {
                format!("{:.2} s", ns / 1e9)
            }
        }
        format!(
            "{:<44} {:>10}/iter (p50 {:>10}, min {:>10}, {} iters)",
            self.name,
            fmt(self.mean_ns),
            fmt(self.p50_ns),
            fmt(self.min_ns),
            self.iters
        )
    }
}

/// Run `f` repeatedly for ~`budget_ms` after warmup; report per-iteration
/// stats. `f` should return something observable to keep the optimiser
/// honest; we black-box it via `std::hint::black_box`.
pub fn bench<T>(name: &str, budget_ms: u64, mut f: impl FnMut() -> T) -> BenchResult {
    // warmup: a few iterations or 10% of budget
    let warm_deadline = Instant::now() + std::time::Duration::from_millis(budget_ms / 10 + 1);
    let mut warm_iters = 0u64;
    while Instant::now() < warm_deadline || warm_iters < 3 {
        std::hint::black_box(f());
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }

    let deadline = Instant::now() + std::time::Duration::from_millis(budget_ms);
    let mut samples = Vec::new();
    while Instant::now() < deadline {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
        if samples.len() >= 1_000_000 {
            break;
        }
    }
    samples.sort_by(f64::total_cmp);
    let n = samples.len().max(1);
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: samples.iter().sum::<f64>() / n as f64,
        p50_ns: samples.get(n / 2).copied().unwrap_or(0.0),
        min_ns: samples.first().copied().unwrap_or(0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 20, || {
            let mut s = 0u64;
            for i in 0..100 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.iters > 10);
        assert!(r.min_ns <= r.mean_ns);
        assert!(r.mean_ns > 0.0);
        assert!(r.report().contains("noop-ish"));
    }
}
