//! Blocked microkernel layer for the functional dataflows (§Perf).
//!
//! Every functional dataflow (`clustersim::dataflow::*::execute`) spends
//! its time in three row-oriented primitives: projecting an activation row
//! against weight *columns*, dotting a query row against cache rows, and
//! accumulating probability-scaled value rows. The seed code walked weight
//! columns through row-major storage (`w[i * h + col]`), a stride-`h`
//! access pattern that touches a fresh cache line per multiply and
//! re-derives the same columns for every head and every cluster block —
//! the O(nh·N·B·hs·D) hot spot named in ROADMAP's "simulator perf
//! headroom" item. This module replaces it with:
//!
//! * [`PackedWeight`] — a transposed (column-major-of-original) copy built
//!   **once per weight per `execute` call** and then sliced per head/block,
//!   so every projection reads contiguous memory;
//! * [`matmul_rows`] / [`matmul_rows_acc`] — blocked row-times-columns
//!   kernels that tile output columns ([`COL_TILE`]-wide register tiles,
//!   one activation load feeding [`COL_TILE`] accumulator chains);
//! * fused row primitives [`dot`], [`axpy`], [`scale_div`] for the
//!   attention inner loops.
//!
//! **Bit-exactness contract:** the *accumulation order is part of the
//! API*. In the default build every output element is produced by one
//! scalar accumulator summing `x[i] * w[i][col]` for `i = 0..n_in` **in
//! ascending order** — exactly the order of the seed's scalar loops — so
//! the refactored dataflows return byte-identical `AttnOut` to the
//! frozen scalar reference (`tests/integration_bitexact.rs`). Column
//! tiling multiplies *independent* accumulator chains; it never
//! reassociates a single output's sum. Do not "optimise" these kernels
//! with multiple partial accumulators per output, FMA contraction, or
//! SIMD horizontal sums outside the one sanctioned variant below: that
//! trades the contract for nothing the cache blocking has not already
//! bought (DESIGN.md §Perf).
//!
//! **The `simd` cargo feature** swaps the *reduction* primitives
//! ([`dot`], [`dot4`], [`dot_seq`], and therefore [`rmsnorm`]'s sum of
//! squares) to a **fixed lane-group order**: [`SIMD_LANES`] independent
//! accumulator lanes fed by consecutive `SIMD_LANES`-wide chunks (a
//! partial final chunk fills lanes `0..len % SIMD_LANES`), reduced by
//! one fixed pairwise tree. That order is a pure function of the
//! sequence length — never of pool size, scheduling, or memory layout —
//! so `simd` builds stay byte-identical across pool widths and runs;
//! they differ from default builds only by this documented
//! reassociation, and every bitwise test re-pins against the same
//! lane-group model (DESIGN.md §Parallel). The element-wise primitives
//! ([`axpy`], [`scale`], [`scale_div`], [`silu_mul`]) get fixed-width
//! chunked bodies under the feature but compute bit-identical values in
//! both builds — per-element ops have no order to reassociate. The
//! bodies are written as fixed-width lane loops the compiler lowers to
//! vector instructions on every target; `core::arch` `target_feature`
//! intrinsics are a drop-in upgrade *only if* they preserve the same
//! lane-group tree (no FMA contraction, no wider re-blocking).

/// Output-column tile width of the blocked matmul kernels: one activation
/// element load feeds this many independent accumulator chains (ILP),
/// which is where the kernel's speedup beyond mere contiguity comes from.
pub const COL_TILE: usize = 4;

/// A weight matrix packed for column access: the transpose of a
/// `(n_in, n_out)` row-major matrix, stored row-major as `(n_out, n_in)`,
/// so the coefficients of output column `j` are one contiguous `n_in`-run.
///
/// Build it **once per weight per dataflow evaluation** (outside any
/// per-head / per-block loop — the packing cost is one streaming pass,
/// amortised over `nh × N` reuses) and slice per head with [`Self::col`].
#[derive(Debug, Clone)]
pub struct PackedWeight {
    data: Vec<f32>,
    n_in: usize,
    n_out: usize,
}

/// Transpose tile edge for [`PackedWeight::pack`]: keeps the scattered
/// writes of the transpose inside a `PACK_TILE × PACK_TILE` window
/// (cache- and TLB-resident) instead of sweeping a full `n_out`-stride
/// column per source row — at model scale (`n_out` ≥ 4K) the naive sweep
/// touches one page per write and pack time becomes the hot spot.
const PACK_TILE: usize = 64;

impl PackedWeight {
    /// Pack a `(n_in, n_out)` row-major weight: a `PACK_TILE`-blocked
    /// transpose (pure data movement — no arithmetic, so no bit-exactness
    /// concern).
    pub fn pack(w: &[f32], n_in: usize, n_out: usize) -> Self {
        assert_eq!(w.len(), n_in * n_out, "weight shape mismatch");
        let mut data = vec![0f32; n_in * n_out];
        let mut i0 = 0;
        while i0 < n_in {
            let i1 = (i0 + PACK_TILE).min(n_in);
            let mut j0 = 0;
            while j0 < n_out {
                let j1 = (j0 + PACK_TILE).min(n_out);
                for i in i0..i1 {
                    for j in j0..j1 {
                        data[j * n_in + i] = w[i * n_out + j];
                    }
                }
                j0 = j1;
            }
            i0 = i1;
        }
        Self { data, n_in, n_out }
    }

    pub fn n_in(&self) -> usize {
        self.n_in
    }

    pub fn n_out(&self) -> usize {
        self.n_out
    }

    /// The contiguous coefficient run of output column `j`
    /// (`= w[0..n_in, j]` of the original matrix).
    #[inline]
    pub fn col(&self, j: usize) -> &[f32] {
        &self.data[j * self.n_in..(j + 1) * self.n_in]
    }
}

/// Accumulator lanes of the `simd` builds' reduction order: consecutive
/// `SIMD_LANES`-wide chunks feed `SIMD_LANES` independent in-order
/// accumulator chains, reduced by [`lane_reduce`]'s fixed tree. 8 f32
/// lanes = one AVX/NEON-pair register; the value is part of the numeric
/// contract — changing it re-pins every `simd` reference.
#[cfg(feature = "simd")]
pub const SIMD_LANES: usize = 8;

/// The fixed deterministic lane-group tree:
/// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` — the one sanctioned
/// horizontal reduction, shared by every `simd` reduction primitive.
#[cfg(feature = "simd")]
#[inline]
fn lane_reduce(acc: [f32; SIMD_LANES]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Strictly in-order dot product: `Σ a[i] * b[i]`, `i` ascending, one
/// accumulator — the same reduction order as `zip().map().sum()` over the
/// same slices (the seed's idiom), kept as a named primitive so the
/// contract is visible at call sites.
#[cfg(not(feature = "simd"))]
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Lane-group dot product (`simd` builds): [`SIMD_LANES`] vertical
/// accumulator chains over consecutive chunks — lane `j` of chunk `k`
/// adds `a[k·L + j] * b[k·L + j]`, the tail fills lanes `0..len % L` —
/// then [`lane_reduce`]'s fixed tree. Identical bits to
/// [`dot_seq`] over the zipped sequence, at every length.
#[cfg(feature = "simd")]
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; SIMD_LANES];
    let mut ca = a.chunks_exact(SIMD_LANES);
    let mut cb = b.chunks_exact(SIMD_LANES);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for j in 0..SIMD_LANES {
            acc[j] += xa[j] * xb[j];
        }
    }
    for (j, (x, y)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
        acc[j] += x * y;
    }
    lane_reduce(acc)
}

/// [`dot`] over an arbitrary `(a_i, b_i)` sequence — the reduction-order
/// authority for strided or gathered access patterns that cannot form
/// slices (the frozen references in `tests/integration_bitexact.rs`
/// route their column-strided sums through this so they re-pin in
/// lockstep with the live kernels under the `simd` feature). Bitwise:
/// `dot(a, b) == dot_seq(zip(a, b))` in both builds.
#[cfg(not(feature = "simd"))]
#[inline]
pub fn dot_seq(it: impl Iterator<Item = (f32, f32)>) -> f32 {
    let mut acc = 0f32;
    for (x, y) in it {
        acc += x * y;
    }
    acc
}

/// Lane-group [`dot_seq`] (`simd` builds): element `i` lands in lane
/// `i % SIMD_LANES` — the streaming statement of the same
/// consecutive-chunk lane grouping as the slice kernels.
#[cfg(feature = "simd")]
#[inline]
pub fn dot_seq(it: impl Iterator<Item = (f32, f32)>) -> f32 {
    let mut acc = [0f32; SIMD_LANES];
    for (i, (x, y)) in it.enumerate() {
        acc[i % SIMD_LANES] += x * y;
    }
    lane_reduce(acc)
}

/// Four independent strictly in-order dot products of one row against
/// four (typically strided) cache rows: the attention-score tile. Each
/// output is its own single-accumulator chain over `i = 0..len` — the
/// same bits as four [`dot`] calls — but the four chains interleave in
/// the FP pipeline (ILP) and share each `x[i]` load, which is what makes
/// the sequence-scan phase fast without reassociating any sum.
#[cfg(not(feature = "simd"))]
#[inline]
pub fn dot4(x: &[f32], r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32]) -> [f32; 4] {
    let k = x.len();
    debug_assert!(r0.len() == k && r1.len() == k && r2.len() == k && r3.len() == k);
    let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..k {
        let xv = x[i];
        a0 += xv * r0[i];
        a1 += xv * r1[i];
        a2 += xv * r2[i];
        a3 += xv * r3[i];
    }
    [a0, a1, a2, a3]
}

/// Lane-group [`dot4`] (`simd` builds): each of the four outputs is its
/// own [`SIMD_LANES`]-lane accumulation with the shared `x[i]` loads —
/// bit-identical to four [`dot`] calls, exactly as in the default build.
#[cfg(feature = "simd")]
#[inline]
pub fn dot4(x: &[f32], r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32]) -> [f32; 4] {
    let k = x.len();
    debug_assert!(r0.len() == k && r1.len() == k && r2.len() == k && r3.len() == k);
    let mut acc = [[0f32; SIMD_LANES]; 4];
    let chunks = k / SIMD_LANES;
    for c in 0..chunks {
        let base = c * SIMD_LANES;
        for j in 0..SIMD_LANES {
            let xv = x[base + j];
            acc[0][j] += xv * r0[base + j];
            acc[1][j] += xv * r1[base + j];
            acc[2][j] += xv * r2[base + j];
            acc[3][j] += xv * r3[base + j];
        }
    }
    let base = chunks * SIMD_LANES;
    for j in 0..k - base {
        let xv = x[base + j];
        acc[0][j] += xv * r0[base + j];
        acc[1][j] += xv * r1[base + j];
        acc[2][j] += xv * r2[base + j];
        acc[3][j] += xv * r3[base + j];
    }
    [lane_reduce(acc[0]), lane_reduce(acc[1]), lane_reduce(acc[2]), lane_reduce(acc[3])]
}

/// `y[i] += alpha * x[i]`, `i` ascending (the attention accumulate /
/// output-tile update). Same per-element op order as the seed's explicit
/// loops. Element-wise: the `simd` build's chunked body computes
/// bit-identical values (each element is one mul + one add in both).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(feature = "simd")]
    {
        let n = x.len() - x.len() % SIMD_LANES;
        for (yc, xc) in y[..n].chunks_exact_mut(SIMD_LANES).zip(x[..n].chunks_exact(SIMD_LANES)) {
            for j in 0..SIMD_LANES {
                yc[j] += alpha * xc[j];
            }
        }
        for (yv, xv) in y[n..].iter_mut().zip(&x[n..]) {
            *yv += alpha * xv;
        }
    }
    #[cfg(not(feature = "simd"))]
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

/// `y[i] *= alpha` (online-softmax rescale). Element-wise; `simd` build
/// is bit-identical.
#[inline]
pub fn scale(alpha: f32, y: &mut [f32]) {
    #[cfg(feature = "simd")]
    {
        let n = y.len() - y.len() % SIMD_LANES;
        for yc in y[..n].chunks_exact_mut(SIMD_LANES) {
            for j in 0..SIMD_LANES {
                yc[j] *= alpha;
            }
        }
        for yv in y[n..].iter_mut() {
            *yv *= alpha;
        }
    }
    #[cfg(not(feature = "simd"))]
    for yv in y.iter_mut() {
        *yv *= alpha;
    }
}

/// `out[i] = x[i] / denom` (softmax normalisation into a reused buffer).
/// Element-wise; `simd` build is bit-identical.
#[inline]
pub fn scale_div(x: &[f32], denom: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    #[cfg(feature = "simd")]
    {
        let n = x.len() - x.len() % SIMD_LANES;
        for (oc, xc) in out[..n].chunks_exact_mut(SIMD_LANES).zip(x[..n].chunks_exact(SIMD_LANES))
        {
            for j in 0..SIMD_LANES {
                oc[j] = xc[j] / denom;
            }
        }
        for (o, v) in out[n..].iter_mut().zip(&x[n..]) {
            *o = v / denom;
        }
    }
    #[cfg(not(feature = "simd"))]
    for (o, v) in out.iter_mut().zip(x) {
        *o = v / denom;
    }
}

/// Inner register tile: dot `x_row` against `COL_TILE`-grouped packed
/// columns, each output owning a single in-order accumulator. The
/// 4-chain body is [`dot4`] — one copy of the load-sharing kernel keeps
/// the bit-exactness contract in one place.
#[inline]
fn col_tile_dots(
    x_row: &[f32],
    pw: &PackedWeight,
    in0: usize,
    col0: usize,
    ncols: usize,
    mut emit: impl FnMut(usize, f32),
) {
    let k = x_row.len();
    let mut j = 0;
    while j + COL_TILE <= ncols {
        let [a0, a1, a2, a3] = dot4(
            x_row,
            &pw.col(col0 + j)[in0..in0 + k],
            &pw.col(col0 + j + 1)[in0..in0 + k],
            &pw.col(col0 + j + 2)[in0..in0 + k],
            &pw.col(col0 + j + 3)[in0..in0 + k],
        );
        emit(j, a0);
        emit(j + 1, a1);
        emit(j + 2, a2);
        emit(j + 3, a3);
        j += COL_TILE;
    }
    while j < ncols {
        emit(j, dot(x_row, &pw.col(col0 + j)[in0..in0 + k]));
        j += 1;
    }
}

/// Blocked row-major matmul against a packed weight slice:
///
/// `out[bi * ncols + j] = Σ_{i=0..n_in} x[bi * n_in + i] *
///  pw.col(col0 + j)[in0 + i]`  (i ascending, fresh accumulator).
///
/// `x` is `(b, n_in)` row-major; writes a dense `(b, ncols)` block. This
/// is the QKV-projection kernel: a head/cluster segment is just a
/// `(col0, ncols)` window over the packed weight — no per-head re-pack.
pub fn matmul_rows(
    x: &[f32],
    b: usize,
    n_in: usize,
    pw: &PackedWeight,
    in0: usize,
    col0: usize,
    ncols: usize,
    out: &mut [f32],
) {
    assert!(x.len() >= b * n_in && out.len() >= b * ncols);
    assert!(in0 + n_in <= pw.n_in && col0 + ncols <= pw.n_out);
    for bi in 0..b {
        let x_row = &x[bi * n_in..(bi + 1) * n_in];
        let out_row = &mut out[bi * ncols..(bi + 1) * ncols];
        col_tile_dots(x_row, pw, in0, col0, ncols, |j, v| out_row[j] = v);
    }
}

/// Accumulating variant: `out[bi * out_stride + col0 + j] += Σ_i x_row ·
/// col` with the same in-order contract. `x` is `(b, n_in)` row-major,
/// `out` rows are `out_stride` wide and indexed by absolute column.
///
/// Since the §Parallel refactor the dataflows' output-projection
/// atomicAdd no longer calls this directly — they compute per-block
/// tiles with [`matmul_rows`] and merge with one `axpy(1.0, …)` add per
/// element, which is bit-identical (each output received exactly one add
/// of a completed dot here too). Kept as the reference accumulating
/// kernel: its unit test is the executable statement of that
/// equivalence, and one-shot callers that want fused accumulate-in-place
/// still have it.
#[allow(clippy::too_many_arguments)]
pub fn matmul_rows_acc(
    x: &[f32],
    b: usize,
    n_in: usize,
    pw: &PackedWeight,
    in0: usize,
    col0: usize,
    ncols: usize,
    out: &mut [f32],
    out_stride: usize,
) {
    assert!(x.len() >= b * n_in && out.len() >= b * out_stride);
    assert!(in0 + n_in <= pw.n_in && col0 + ncols <= pw.n_out);
    for bi in 0..b {
        let x_row = &x[bi * n_in..(bi + 1) * n_in];
        let out_row = &mut out[bi * out_stride..(bi + 1) * out_stride];
        col_tile_dots(x_row, pw, in0, col0, ncols, |j, v| out_row[col0 + j] += v);
    }
}

/// RMSNorm of one activation row into `out`:
/// `out[i] = x[i] / sqrt(mean(x²) + eps) * w[i]`.
///
/// Bit-exactness contract (same as the matmul kernels): the sum of
/// squares is `dot(x, x)` — one scalar accumulator over `i = 0..n`
/// ascending in the default build, the fixed [`SIMD_LANES`] lane-group
/// order under the `simd` feature. Routing through [`dot`] keeps one
/// reduction-order authority; do not hand-roll this sum.
#[inline]
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert!(x.len() == w.len() && x.len() == out.len());
    let ss = dot(x, x);
    let inv = 1.0 / (ss / x.len() as f32 + eps).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

/// Rotary position embedding of one head row (length `dh`, even), in
/// place, half-split pair convention (Llama/GPT-NeoX): element `i` pairs
/// with `i + dh/2`, rotated by `theta_i = pos · base^(-i/(dh/2))`.
///
/// Pure per-pair 2×2 rotation — no accumulation, so the only
/// reproducibility requirement is the fixed `sin_cos` evaluation, which
/// is deterministic within a build (the block tests compare against a
/// scalar reference using the same call).
#[inline]
pub fn rope_rotate(row: &mut [f32], pos: usize, base: f32) {
    let half = row.len() / 2;
    debug_assert_eq!(row.len(), 2 * half, "rope needs an even head dim");
    for i in 0..half {
        let theta = pos as f32 * base.powf(-(i as f32) / half as f32);
        let (sin, cos) = theta.sin_cos();
        let (a, b) = (row[i], row[half + i]);
        row[i] = a * cos - b * sin;
        row[half + i] = a * sin + b * cos;
    }
}

/// SwiGLU elementwise gate: `out[i] = silu(gate[i]) * up[i]` with
/// `silu(g) = g / (1 + e^(-g))`. Elementwise — no accumulation order to
/// preserve, but kept here so the block pipeline's nonlinearity has one
/// authoritative definition. The `simd` build chunks the loop for the
/// vectorizer; per-element values are bit-identical (`exp` stays the
/// scalar libm call in both builds).
#[inline]
pub fn silu_mul(gate: &[f32], up: &[f32], out: &mut [f32]) {
    debug_assert!(gate.len() == up.len() && gate.len() == out.len());
    #[cfg(feature = "simd")]
    {
        let n = gate.len() - gate.len() % SIMD_LANES;
        for ((oc, gc), uc) in out[..n]
            .chunks_exact_mut(SIMD_LANES)
            .zip(gate[..n].chunks_exact(SIMD_LANES))
            .zip(up[..n].chunks_exact(SIMD_LANES))
        {
            for j in 0..SIMD_LANES {
                let g = gc[j];
                oc[j] = g / (1.0 + (-g).exp()) * uc[j];
            }
        }
        for i in n..gate.len() {
            let g = gate[i];
            out[i] = g / (1.0 + (-g).exp()) * up[i];
        }
    }
    #[cfg(not(feature = "simd"))]
    for i in 0..gate.len() {
        let g = gate[i];
        out[i] = g / (1.0 + (-g).exp()) * up[i];
    }
}

/// [`matmul_rows`] distributed over a worker pool: output columns are
/// partitioned into one contiguous window per worker (the §Parallel
/// independent-output axis), each window computed by the identical
/// [`col_tile_dots`] kernel into a private block, and the blocks are
/// scattered into `out` serially.
///
/// Bit-exactness: every output column is the same single in-order
/// accumulator chain as in [`matmul_rows`] — window boundaries only
/// change which columns *share activation loads*, never any column's
/// sum — so the result is byte-identical to the serial kernel at every
/// pool size (pinned by `tests/integration_parallel.rs`). A serial pool
/// (or a single worker) takes the inline [`matmul_rows`] path directly.
#[allow(clippy::too_many_arguments)]
pub fn matmul_rows_pooled(
    pool: &crate::util::pool::Pool,
    x: &[f32],
    b: usize,
    n_in: usize,
    pw: &PackedWeight,
    in0: usize,
    col0: usize,
    ncols: usize,
    out: &mut [f32],
) {
    if pool.threads() == 1 || ncols <= 1 {
        matmul_rows(x, b, n_in, pw, in0, col0, ncols, out);
        return;
    }
    assert!(out.len() >= b * ncols);
    // Each worker runs the one serial kernel on its column sub-window —
    // a (col0 + c0, span) view is just a narrower matmul_rows call, so
    // there is exactly one copy of the tiled loop to keep correct.
    let blocks = pool.run_ranges(ncols, |c0, c1| {
        let span = c1 - c0;
        let mut block = vec![0f32; b * span];
        matmul_rows(x, b, n_in, pw, in0, col0 + c0, span, &mut block);
        (c0, block)
    });
    for (c0, block) in blocks {
        let span = block.len() / b;
        for bi in 0..b {
            out[bi * ncols + c0..bi * ncols + c0 + span]
                .copy_from_slice(&block[bi * span..(bi + 1) * span]);
        }
    }
}

/// The seed's column-strided projection loop, kept verbatim as the
/// regression baseline for `benches/hotpath.rs` (before/after pair) and
/// the unit tests below. `w` is `(n_in, ld)` row-major; output column
/// `col0 + j` reads `w[i * ld + col0 + j]` — one cache line per multiply
/// at model-scale `ld`. Never call this from a dataflow.
pub fn matmul_rows_naive_strided(
    x: &[f32],
    b: usize,
    n_in: usize,
    w: &[f32],
    ld: usize,
    col0: usize,
    ncols: usize,
    out: &mut [f32],
) {
    for bi in 0..b {
        for j in 0..ncols {
            let col = col0 + j;
            let mut acc = 0f32;
            for i in 0..n_in {
                acc += x[bi * n_in + i] * w[i * ld + col];
            }
            out[bi * ncols + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| (rng.f32() - 0.5) * scale).collect()
    }

    /// Independent scalar statement of the build's reduction order: the
    /// seed's in-order fold by default, the fixed 8-lane-group tree under
    /// `simd` (element `i` in lane `i % 8`, then `((l0+l1)+(l2+l3)) +
    /// ((l4+l5)+(l6+l7))`). Every reduction primitive must match this
    /// model bitwise — it is the executable form of the contract.
    fn model_dot_seq(it: impl Iterator<Item = (f32, f32)>) -> f32 {
        #[cfg(not(feature = "simd"))]
        {
            let mut acc = 0f32;
            for (x, y) in it {
                acc += x * y;
            }
            acc
        }
        #[cfg(feature = "simd")]
        {
            let mut acc = [0f32; 8];
            for (i, (x, y)) in it.enumerate() {
                acc[i % 8] += x * y;
            }
            ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
        }
    }

    /// Bit-exactness of the packed/tiled kernel vs the seed's strided
    /// loop, across shapes that hit every tile remainder (ncols mod
    /// COL_TILE in 0..COL_TILE) and offset windows.
    #[test]
    fn matmul_rows_bitexact_vs_naive_strided() {
        let mut rng = Rng::seed_from_u64(17);
        for &(b, n_in, n_out) in
            &[(1usize, 7usize, 5usize), (2, 16, 12), (3, 33, 9), (2, 64, 31), (1, 128, 4)]
        {
            let x = randv(&mut rng, b * n_in, 2.0);
            let w = randv(&mut rng, n_in * n_out, 0.5);
            let pw = PackedWeight::pack(&w, n_in, n_out);
            for &(col0, ncols) in &[(0usize, n_out), (1, n_out - 1), (n_out / 2, n_out / 2)] {
                let mut got = vec![0f32; b * ncols];
                matmul_rows(&x, b, n_in, &pw, 0, col0, ncols, &mut got);
                // the build's reduction model, per output column
                let mut want = vec![0f32; b * ncols];
                for bi in 0..b {
                    for j in 0..ncols {
                        want[bi * ncols + j] = model_dot_seq(
                            (0..n_in).map(|i| (x[bi * n_in + i], w[i * n_out + col0 + j])),
                        );
                    }
                }
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "b={b} n_in={n_in} n_out={n_out} col0={col0}");
                // default build only: the model *is* the seed's strided
                // loop — pin the kernel against the verbatim baseline too
                #[cfg(not(feature = "simd"))]
                {
                    let mut naive = vec![0f32; b * ncols];
                    matmul_rows_naive_strided(&x, b, n_in, &w, n_out, col0, ncols, &mut naive);
                    let nb: Vec<u32> = naive.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(gb, nb, "b={b} n_in={n_in} n_out={n_out} col0={col0} (naive)");
                }
            }
        }
    }

    /// The accumulating variant must add exactly `dot(x_row, col)` on top
    /// of whatever the output held — same bits as a manual strided loop
    /// with `+=`.
    #[test]
    fn matmul_rows_acc_bitexact_with_offset_window() {
        let mut rng = Rng::seed_from_u64(23);
        let (b, n_in_full, sub, n_out) = (2usize, 24usize, 8usize, 13usize);
        let x = randv(&mut rng, b * sub, 1.0);
        let w = randv(&mut rng, n_in_full * n_out, 0.5);
        let pw = PackedWeight::pack(&w, n_in_full, n_out);
        let in0 = 16; // dot over rows [16, 24) of the original weight
        let init = randv(&mut rng, b * n_out, 1.0);
        let (col0, ncols) = (3usize, 9usize);

        let mut got = init.clone();
        matmul_rows_acc(&x, b, sub, &pw, in0, col0, ncols, &mut got, n_out);

        let mut want = init;
        for bi in 0..b {
            for j in 0..ncols {
                let acc =
                    model_dot_seq((0..sub).map(|i| (x[bi * sub + i], w[(in0 + i) * n_out + col0 + j])));
                want[bi * n_out + col0 + j] += acc;
            }
        }
        let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
        assert_eq!(gb, wb);
    }

    #[test]
    fn matmul_rows_pooled_bitexact_at_every_pool_size() {
        use crate::util::pool::Pool;
        let mut rng = Rng::seed_from_u64(29);
        for &(b, n_in, n_out) in &[(1usize, 16usize, 9usize), (2, 33, 21), (3, 64, 5)] {
            let x = randv(&mut rng, b * n_in, 2.0);
            let w = randv(&mut rng, n_in * n_out, 0.5);
            let pw = PackedWeight::pack(&w, n_in, n_out);
            let (col0, ncols) = (1usize, n_out - 1);
            let mut want = vec![0f32; b * ncols];
            matmul_rows(&x, b, n_in, &pw, 0, col0, ncols, &mut want);
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            for threads in [1usize, 2, 4, 8] {
                let pool = Pool::new(threads);
                let mut got = vec![0f32; b * ncols];
                matmul_rows_pooled(&pool, &x, b, n_in, &pw, 0, col0, ncols, &mut got);
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "b={b} n_in={n_in} n_out={n_out} threads={threads}");
            }
        }
    }

    #[test]
    fn pack_round_trips_columns() {
        let (n_in, n_out) = (5usize, 3usize);
        let w: Vec<f32> = (0..n_in * n_out).map(|i| i as f32).collect();
        let pw = PackedWeight::pack(&w, n_in, n_out);
        assert_eq!(pw.n_in(), n_in);
        assert_eq!(pw.n_out(), n_out);
        for j in 0..n_out {
            let col: Vec<f32> = (0..n_in).map(|i| w[i * n_out + j]).collect();
            assert_eq!(pw.col(j), &col[..], "column {j}");
        }
    }

    #[test]
    fn dot_matches_reduction_model_at_every_tail_length() {
        // lengths hitting every `len % 8` tail, plus chunked ones — the
        // simd-vs-scalar-model equality pin for the reduction primitives
        let mut rng = Rng::seed_from_u64(3);
        for n in [0usize, 1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 31, 33, 61, 64, 97] {
            let a = randv(&mut rng, n, 2.0);
            let b = randv(&mut rng, n, 2.0);
            let want = model_dot_seq(a.iter().copied().zip(b.iter().copied()));
            assert_eq!(dot(&a, &b).to_bits(), want.to_bits(), "n={n}");
            // dot_seq is the same authority for non-slice access
            let seq = dot_seq(a.iter().copied().zip(b.iter().copied()));
            assert_eq!(seq.to_bits(), want.to_bits(), "n={n} (dot_seq)");
        }
    }

    #[cfg(not(feature = "simd"))]
    #[test]
    fn dot_matches_zip_sum_order() {
        // default build: the model *is* the seed's zip().sum() idiom
        let mut rng = Rng::seed_from_u64(3);
        let a = randv(&mut rng, 97, 2.0);
        let b = randv(&mut rng, 97, 2.0);
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot(&a, &b).to_bits(), want.to_bits());
    }

    #[test]
    fn dot4_matches_four_dots_bitwise() {
        let mut rng = Rng::seed_from_u64(7);
        for n in [5usize, 8, 16, 23, 61] {
            let x = randv(&mut rng, n, 2.0);
            let rows: Vec<Vec<f32>> = (0..4).map(|_| randv(&mut rng, n, 2.0)).collect();
            let got = dot4(&x, &rows[0], &rows[1], &rows[2], &rows[3]);
            for (g, r) in got.iter().zip(&rows) {
                assert_eq!(g.to_bits(), dot(&x, r).to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn rmsnorm_matches_scalar_reference_bitwise() {
        let mut rng = Rng::seed_from_u64(11);
        for n in [4usize, 31, 64, 97] {
            let x = randv(&mut rng, n, 2.0);
            let w: Vec<f32> = (0..n).map(|_| 1.0 + (rng.f32() - 0.5) * 0.2).collect();
            let mut got = vec![0f32; n];
            rmsnorm(&x, &w, 1e-5, &mut got);
            // scalar reference: sum of squares in the build's reduction
            // order (in-order by default, lane-grouped under `simd`)
            let ss = model_dot_seq(x.iter().copied().zip(x.iter().copied()));
            let inv = 1.0 / (ss / n as f32 + 1e-5).sqrt();
            for i in 0..n {
                assert_eq!(got[i].to_bits(), (x[i] * inv * w[i]).to_bits(), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn rmsnorm_unit_weights_normalise_rms_to_one() {
        let mut rng = Rng::seed_from_u64(13);
        let x = randv(&mut rng, 64, 4.0);
        let w = vec![1.0f32; 64];
        let mut y = vec![0f32; 64];
        rmsnorm(&x, &w, 0.0, &mut y);
        let rms = (y.iter().map(|v| v * v).sum::<f32>() / 64.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-4, "{rms}");
    }

    #[test]
    fn rope_at_position_zero_is_identity() {
        let mut rng = Rng::seed_from_u64(17);
        let orig = randv(&mut rng, 16, 2.0);
        let mut row = orig.clone();
        rope_rotate(&mut row, 0, 10000.0);
        // theta = 0 -> cos 1, sin 0: exact identity in f32
        assert_eq!(
            row.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            orig.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn rope_preserves_pair_norms_and_relative_angles() {
        let mut rng = Rng::seed_from_u64(19);
        let orig = randv(&mut rng, 32, 2.0);
        let mut row = orig.clone();
        rope_rotate(&mut row, 7, 10000.0);
        let half = 16;
        for i in 0..half {
            let n0 = orig[i].hypot(orig[half + i]);
            let n1 = row[i].hypot(row[half + i]);
            assert!((n0 - n1).abs() < 1e-4, "pair {i}: {n0} vs {n1}");
        }
        // relative-position property: rotating q by p and k by p leaves
        // their dot product equal to rotating both by any common shift
        let (mut q1, mut k1) = (orig.clone(), orig.clone());
        k1.reverse();
        let (mut q2, mut k2) = (q1.clone(), k1.clone());
        rope_rotate(&mut q1, 3, 10000.0);
        rope_rotate(&mut k1, 3, 10000.0);
        rope_rotate(&mut q2, 11, 10000.0);
        rope_rotate(&mut k2, 11, 10000.0);
        let d1 = dot(&q1, &k1);
        let d2 = dot(&q2, &k2);
        assert!((d1 - d2).abs() / d1.abs().max(1.0) < 1e-4, "{d1} vs {d2}");
    }

    #[test]
    fn silu_mul_matches_definition_and_saturates() {
        let mut rng = Rng::seed_from_u64(23);
        let gate = randv(&mut rng, 41, 8.0);
        let up = randv(&mut rng, 41, 2.0);
        let mut out = vec![0f32; 41];
        silu_mul(&gate, &up, &mut out);
        for i in 0..41 {
            let want = gate[i] / (1.0 + (-gate[i]).exp()) * up[i];
            assert_eq!(out[i].to_bits(), want.to_bits());
        }
        // silu(g) -> g for large g, -> 0 for very negative g
        let mut o = [0f32; 2];
        silu_mul(&[30.0, -30.0], &[1.0, 1.0], &mut o);
        assert!((o[0] - 30.0).abs() < 1e-3 && o[1].abs() < 1e-3, "{o:?}");
    }

    #[test]
    fn axpy_scale_div_elementwise() {
        let mut rng = Rng::seed_from_u64(5);
        let x = randv(&mut rng, 31, 2.0);
        let mut y = randv(&mut rng, 31, 2.0);
        let mut want = y.clone();
        for (w, xv) in want.iter_mut().zip(&x) {
            *w += 0.37 * xv;
        }
        axpy(0.37, &x, &mut y);
        assert_eq!(
            y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        let mut out = vec![0f32; 31];
        scale_div(&y, 1.7, &mut out);
        for (o, v) in out.iter().zip(&y) {
            assert_eq!(o.to_bits(), (v / 1.7).to_bits());
        }
        let mut z = y.clone();
        scale(0.25, &mut z);
        for (a, b) in z.iter().zip(&y) {
            assert_eq!(a.to_bits(), (b * 0.25).to_bits());
        }
    }
}
