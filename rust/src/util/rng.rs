//! Deterministic pseudo-random numbers and the distributions the workload
//! generator needs (uniform, normal, log-normal, exponential, Poisson).
//!
//! Core generator: SplitMix64 — tiny, fast, passes BigCrush for our
//! purposes, and trivially seedable, which is what reproducible traces
//! require (every experiment in EXPERIMENTS.md records its seed).

/// SplitMix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + (self.next_u64() % (hi - lo + 1) as u64) as usize
    }

    /// Uniform usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // (0, 1]
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given ln-space mean and sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given **rate** λ (mean 1/λ). Convention audit:
    /// `Trace::poisson` passes requests-per-second as λ, so inter-arrival
    /// gaps average 1/rps seconds — asserted by
    /// `workload::tests::offered_rate_near_target`.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Poisson-distributed count with the given **mean** (not rate ×
    /// interval — callers multiply first). Knuth for small mean, normal
    /// approximation above 30 — plenty for load generation. The seeded
    /// statistical tests below hold with ≥5σ margin at their tolerances.
    pub fn poisson(&mut self, mean: f64) -> usize {
        if mean <= 0.0 {
            return 0;
        }
        if mean > 30.0 {
            return (mean + mean.sqrt() * self.normal()).round().max(0.0) as usize;
        }
        let l = (-mean).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::seed_from_u64(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seed_from_u64(4);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "{mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::seed_from_u64(5);
        for target in [0.5, 3.0, 50.0] {
            let n = 50_000;
            let mean = (0..n).map(|_| r.poisson(target) as f64).sum::<f64>() / n as f64;
            assert!((mean - target).abs() / target < 0.05, "{target}: {mean}");
        }
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::seed_from_u64(6);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(7);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
