//! Minimal JSON parser — just enough for `artifacts/manifest.json`
//! (objects, arrays, strings, numbers, booleans, null). Hand-rolled
//! because the build is fully offline (no serde_json); ~200 lines,
//! exhaustively tested below.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{0008}'),
                    b'f' => s.push('\u{000C}'),
                    b'u' => {
                        if self.pos + 4 > self.bytes.len() {
                            return Err(self.err("short \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("bad utf-8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

/// Escape a string for JSON output (used by the report writers).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "c"}, false], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_usize(), Some(1));
        assert_eq!(a[1].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(a[2].as_bool(), Some(false));
        assert_eq!(v.get("d").unwrap(), &Json::Obj(Default::default()));
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"k\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'single': 1}").is_err());
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("3.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-2").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn escape_roundtrip() {
        let s = "a\"b\\c\nd";
        let parsed = Json::parse(&format!("\"{}\"", escape(s))).unwrap();
        assert_eq!(parsed, Json::Str(s.into()));
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let v = Json::parse(&text).unwrap();
            assert!(v.get("executables").unwrap().as_arr().unwrap().len() > 0);
        }
    }
}
