//! Serving configuration: defaults, a simple `key = value` config-file
//! format (offline build — no TOML dependency), and CLI-style overrides.
//!
//! ```text
//! # serve.conf
//! model = tiny-llama-100m
//! artifacts = artifacts
//! pool_pages = 256
//! page_tokens = 16
//! ```

use anyhow::{bail, Context, Result};
use std::path::Path;

/// Which executable backs the serving engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Real functional decoding through the full-block pipeline
    /// (`coordinator::FunctionalBackend`) — the default: runs on a fresh
    /// checkout with no artifacts and no PJRT.
    Functional,
    /// AOT executables through PJRT (needs `make artifacts` + the native
    /// runtime; DESIGN.md §PJRT).
    Pjrt,
    /// The deterministic in-memory mock (tests / demos only; kept behind
    /// an explicit flag so it is never silently the thing being served).
    Mock,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "functional" => Ok(Self::Functional),
            "pjrt" => Ok(Self::Pjrt),
            "mock" => Ok(Self::Mock),
            other => bail!("unknown backend '{other}' (functional | pjrt | mock)"),
        }
    }
}

/// Engine + server configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    pub model: String,
    pub artifacts: String,
    /// KV pool capacity in pages.
    pub pool_pages: usize,
    /// Tokens per KV page.
    pub page_tokens: usize,
    /// Admission headroom fraction (see `Batcher`).
    pub admit_fraction: f64,
    /// Parameter RNG seed.
    pub seed: u64,
    /// Router queue bound per replica.
    pub max_queue: usize,
    /// Backend selection (`functional` default; `pjrt` needs artifacts,
    /// `mock` is demo-only).
    pub backend: BackendKind,
    /// Cluster size of the functional full-block pipeline (must divide
    /// the model geometry; `clustersim::block::supports_cluster`).
    pub cluster_size: usize,
    /// Host worker threads of the functional pipeline's pool
    /// (DESIGN.md §Parallel). `0` = auto: the `CLUSTERFUSION_THREADS`
    /// override, else the host's available parallelism. Token streams
    /// are byte-identical at every value — this is a wall-clock knob.
    /// Virtual-clock replay runs pin 1 (the §4 determinism rule).
    pub threads: usize,
    /// Per-step prefill-token budget (Sarathi-style chunked prefill;
    /// DESIGN.md §Prefill). `0` = unbounded: each admitted prompt
    /// prefills in one step. Replayed traces must pin this — a different
    /// chunk changes step boundaries and every timestamp downstream.
    pub prefill_chunk: usize,
    /// Token-budget bound on the running set: sum of worst-case
    /// footprints (`prompt + max_new`) across concurrently running
    /// sequences (TGI `max_batch_total_tokens`). `0` = unbounded.
    pub max_batch_total_tokens: usize,
    /// Growth gate: waiting requests may grow a non-empty batch only
    /// when `waiting >= ratio * running` (TGI `waiting_served_ratio`).
    /// `0` = off: admission never defers.
    pub waiting_served_ratio: f64,
    /// Force batch growth after this many steps without it, bounding the
    /// ratio gate's worst-case deferral. `0` = never force.
    pub max_waiting_steps: u64,
    /// TTFT SLO target, milliseconds: submit rejects requests whose
    /// projected TTFT behind the current backlog exceeds this
    /// (`coordinator::admission`). `0` = off.
    pub slo_ttft_ms: f64,
    /// TPOT SLO target, microseconds: caps the decode batch at the
    /// largest width whose modelled step cost still meets it. `0` = off.
    pub slo_tpot_us: u64,
    /// Replica count of the serving fleet (`coordinator::fleet`). `1`
    /// (the default) is the plain single-engine path.
    pub replicas: usize,
    /// Fault-plan spec (`FaultPlan::parse` format, e.g.
    /// `"stall:0@40000+30000;crash:1@80000"`). Empty = no faults. A
    /// non-empty plan selects the deterministic virtual-clock fleet
    /// replay (faults are scheduled in virtual time).
    pub fault_plan: String,
    /// Mark a replica Unhealthy after this long without step progress
    /// while work is stuck on it, µs. `0` = stall detection off.
    pub fault_stall_threshold_us: u64,
    /// Failovers a request may consume before it is counted Failed.
    pub fault_max_retries: u32,
    /// Delay between evacuation and the re-route attempt, µs.
    pub fault_retry_backoff_us: u64,
    /// What stall detection does with a stuck replica:
    /// `failover` (evacuate + re-route) or `drain` (finish inflight).
    pub fault_stall_policy: String,
    /// Write a Chrome trace-event JSON export of the run here (`obs`
    /// module). Empty = tracing off. On virtual-clock replays the file
    /// is byte-identical across runs (`integration_obs`).
    pub trace_out: String,
    /// Write a Prometheus text metrics snapshot here. Empty = off.
    pub metrics_out: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            // micro-llama decodes functionally at interactive speed on a
            // fresh checkout; PJRT runs pass --model tiny-llama-100m.
            model: "micro-llama".into(),
            artifacts: "artifacts".into(),
            pool_pages: 256,
            page_tokens: 16,
            admit_fraction: 0.5,
            seed: 0,
            max_queue: 1024,
            backend: BackendKind::Functional,
            cluster_size: 2,
            threads: 0,
            prefill_chunk: 0,
            max_batch_total_tokens: 0,
            waiting_served_ratio: 0.0,
            max_waiting_steps: 0,
            slo_ttft_ms: 0.0,
            slo_tpot_us: 0,
            replicas: 1,
            fault_plan: String::new(),
            fault_stall_threshold_us: 0,
            fault_max_retries: 2,
            fault_retry_backoff_us: 0,
            fault_stall_policy: "failover".into(),
            trace_out: String::new(),
            metrics_out: String::new(),
        }
    }
}

impl ServeConfig {
    /// Apply one `key = value` assignment (config file line or CLI
    /// `--set key=value`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let v = value.trim();
        match key.trim() {
            "model" => self.model = v.into(),
            "artifacts" => self.artifacts = v.into(),
            "pool_pages" => self.pool_pages = v.parse().context("pool_pages")?,
            "page_tokens" => self.page_tokens = v.parse().context("page_tokens")?,
            "admit_fraction" => self.admit_fraction = v.parse().context("admit_fraction")?,
            "seed" => self.seed = v.parse().context("seed")?,
            "max_queue" => self.max_queue = v.parse().context("max_queue")?,
            "backend" => self.backend = BackendKind::parse(v)?,
            "cluster_size" => self.cluster_size = v.parse().context("cluster_size")?,
            "threads" => self.threads = v.parse().context("threads")?,
            "prefill_chunk" => self.prefill_chunk = v.parse().context("prefill_chunk")?,
            "max_batch_total_tokens" => {
                self.max_batch_total_tokens = v.parse().context("max_batch_total_tokens")?
            }
            "waiting_served_ratio" => {
                self.waiting_served_ratio = v.parse().context("waiting_served_ratio")?
            }
            "max_waiting_steps" => {
                self.max_waiting_steps = v.parse().context("max_waiting_steps")?
            }
            "slo_ttft_ms" => self.slo_ttft_ms = v.parse().context("slo_ttft_ms")?,
            "slo_tpot_us" => self.slo_tpot_us = v.parse().context("slo_tpot_us")?,
            "replicas" => self.replicas = v.parse().context("replicas")?,
            "fault_plan" => self.fault_plan = v.into(),
            "fault_stall_threshold_us" => {
                self.fault_stall_threshold_us = v.parse().context("fault_stall_threshold_us")?
            }
            "fault_max_retries" => {
                self.fault_max_retries = v.parse().context("fault_max_retries")?
            }
            "fault_retry_backoff_us" => {
                self.fault_retry_backoff_us = v.parse().context("fault_retry_backoff_us")?
            }
            "fault_stall_policy" => self.fault_stall_policy = v.into(),
            "trace_out" => self.trace_out = v.into(),
            "metrics_out" => self.metrics_out = v.into(),
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Parse a config file: `key = value` lines, `#` comments, blank lines.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let mut cfg = Self::default();
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        cfg.apply_text(&text)?;
        Ok(cfg)
    }

    pub fn apply_text(&mut self, text: &str) -> Result<()> {
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            self.set(k, v).with_context(|| format!("line {}", lineno + 1))?;
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.pool_pages > 0, "pool_pages must be positive");
        anyhow::ensure!(self.page_tokens > 0, "page_tokens must be positive");
        anyhow::ensure!(
            self.admit_fraction > 0.0 && self.admit_fraction <= 1.0,
            "admit_fraction in (0, 1]"
        );
        anyhow::ensure!(
            self.cluster_size.is_power_of_two() && (1..=16).contains(&self.cluster_size),
            "cluster_size must be a power of two in 1..=16"
        );
        // the pool spawns per call; an absurd width would ask the OS for
        // thousands of threads per kernel (Pool::new also clamps)
        anyhow::ensure!(
            self.threads <= crate::util::pool::MAX_THREADS,
            "threads must be 0 (auto) or at most {}",
            crate::util::pool::MAX_THREADS
        );
        anyhow::ensure!(
            self.waiting_served_ratio.is_finite() && self.waiting_served_ratio >= 0.0,
            "waiting_served_ratio must be finite and >= 0 (0 = off)"
        );
        anyhow::ensure!(
            self.slo_ttft_ms.is_finite() && self.slo_ttft_ms >= 0.0,
            "slo_ttft_ms must be finite and >= 0 (0 = off)"
        );
        anyhow::ensure!(self.replicas >= 1, "replicas must be at least 1");
        let plan = super::fleet::FaultPlan::parse(&self.fault_plan).context("fault_plan")?;
        if let Some(max) = plan.max_replica() {
            anyhow::ensure!(
                max < self.replicas,
                "fault_plan names replica {max}, but replicas = {}",
                self.replicas
            );
        }
        super::fleet::StallPolicy::parse(&self.fault_stall_policy)
            .context("fault_stall_policy")?;
        Ok(())
    }

    /// The fleet policy knobs this config selects (`coordinator::fleet`).
    pub fn fleet_options(&self) -> Result<super::fleet::FleetOptions> {
        Ok(super::fleet::FleetOptions {
            stall_threshold_us: self.fault_stall_threshold_us,
            max_retries: self.fault_max_retries,
            retry_backoff_us: self.fault_retry_backoff_us,
            stall_policy: super::fleet::StallPolicy::parse(&self.fault_stall_policy)?,
            max_queue_per_replica: self.max_queue,
            max_tokens_per_replica: self.max_batch_total_tokens,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ServeConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_config_text() {
        let mut c = ServeConfig::default();
        c.apply_text(
            "# demo\nmodel = tiny-mla-100m\npool_pages=64 # inline comment\n\npage_tokens = 8\n",
        )
        .unwrap();
        assert_eq!(c.model, "tiny-mla-100m");
        assert_eq!(c.pool_pages, 64);
        assert_eq!(c.page_tokens, 8);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_lines() {
        let mut c = ServeConfig::default();
        assert!(c.apply_text("nope = 3").is_err());
        assert!(c.apply_text("just-a-word").is_err());
        assert!(c.set("pool_pages", "not-a-number").is_err());
    }

    #[test]
    fn validate_bounds() {
        let mut c = ServeConfig::default();
        c.admit_fraction = 1.5;
        assert!(c.validate().is_err());
        c.admit_fraction = 0.5;
        c.pool_pages = 0;
        assert!(c.validate().is_err());
        c.pool_pages = 16;
        c.cluster_size = 3;
        assert!(c.validate().is_err());
    }

    #[test]
    fn threads_key_round_trips_and_flags_take_precedence() {
        // default is auto (0)
        assert_eq!(ServeConfig::default().threads, 0);
        // config-file text sets it ...
        let mut c = ServeConfig::default();
        c.apply_text("threads = 2\n").unwrap();
        assert_eq!(c.threads, 2);
        c.validate().unwrap();
        // ... and a later CLI-style assignment (the serve flag path
        // applies file first, then flags) overrides the file value.
        c.set("threads", "8").unwrap();
        assert_eq!(c.threads, 8);
        assert!(c.set("threads", "not-a-number").is_err());
        // 0 stays valid: auto-sizing
        c.set("threads", "0").unwrap();
        c.validate().unwrap();
        // absurd widths are rejected with a readable error, not by
        // exhausting OS threads mid-serve
        c.threads = 500_000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn prefill_chunk_key_round_trips() {
        // default is one-shot prefill (0 = unbounded budget)
        assert_eq!(ServeConfig::default().prefill_chunk, 0);
        let mut c = ServeConfig::default();
        c.apply_text("prefill_chunk = 4\n").unwrap();
        assert_eq!(c.prefill_chunk, 4);
        c.validate().unwrap();
        // CLI-style override wins, 0 restores one-shot
        c.set("prefill_chunk", "0").unwrap();
        assert_eq!(c.prefill_chunk, 0);
        c.validate().unwrap();
        assert!(c.set("prefill_chunk", "four").is_err());
    }

    #[test]
    fn admission_keys_round_trip_and_flags_take_precedence() {
        // all front-door knobs default to off: an unconfigured serve is
        // byte-identical to the pre-admission engine
        let d = ServeConfig::default();
        assert_eq!(d.max_batch_total_tokens, 0);
        assert_eq!(d.waiting_served_ratio, 0.0);
        assert_eq!(d.max_waiting_steps, 0);
        assert_eq!(d.slo_ttft_ms, 0.0);
        assert_eq!(d.slo_tpot_us, 0);
        // config-file text sets them ...
        let mut c = ServeConfig::default();
        c.apply_text(
            "max_batch_total_tokens = 4096\nwaiting_served_ratio = 1.2\n\
             max_waiting_steps = 20\nslo_ttft_ms = 25\nslo_tpot_us = 500\n",
        )
        .unwrap();
        assert_eq!(c.max_batch_total_tokens, 4096);
        assert_eq!(c.waiting_served_ratio, 1.2);
        assert_eq!(c.max_waiting_steps, 20);
        assert_eq!(c.slo_ttft_ms, 25.0);
        assert_eq!(c.slo_tpot_us, 500);
        c.validate().unwrap();
        // ... and a later CLI-style assignment (file first, then flags —
        // the same precedence `clusterfusion serve` applies) wins
        c.set("slo_ttft_ms", "12.5").unwrap();
        assert_eq!(c.slo_ttft_ms, 12.5);
        c.set("slo_tpot_us", "750").unwrap();
        assert_eq!(c.slo_tpot_us, 750);
        assert!(c.set("slo_ttft_ms", "soon").is_err());
        assert!(c.set("max_batch_total_tokens", "-1").is_err());
        // negative or non-finite targets are rejected at validate
        c.waiting_served_ratio = -0.5;
        assert!(c.validate().is_err());
        c.waiting_served_ratio = 0.0;
        c.slo_ttft_ms = f64::NAN;
        assert!(c.validate().is_err());
        c.slo_ttft_ms = 0.0;
        c.validate().unwrap();
    }

    #[test]
    fn fleet_keys_round_trip_and_validate() {
        // defaults: one replica, no faults — the fleet layer is inert
        let d = ServeConfig::default();
        assert_eq!(d.replicas, 1);
        assert!(d.fault_plan.is_empty());
        assert_eq!(d.fault_stall_threshold_us, 0);
        assert_eq!(d.fault_max_retries, 2);
        assert_eq!(d.fault_retry_backoff_us, 0);
        assert_eq!(d.fault_stall_policy, "failover");
        // config-file text sets them ...
        let mut c = ServeConfig::default();
        c.apply_text(
            "replicas = 4\nfault_plan = stall:0@40000+30000;crash:1@80000\n\
             fault_stall_threshold_us = 20000\nfault_max_retries = 3\n\
             fault_retry_backoff_us = 500\nfault_stall_policy = drain\n",
        )
        .unwrap();
        assert_eq!(c.replicas, 4);
        assert_eq!(c.fault_plan, "stall:0@40000+30000;crash:1@80000");
        assert_eq!(c.fault_stall_threshold_us, 20_000);
        assert_eq!(c.fault_max_retries, 3);
        assert_eq!(c.fault_retry_backoff_us, 500);
        c.validate().unwrap();
        let opts = c.fleet_options().unwrap();
        assert_eq!(opts.stall_threshold_us, 20_000);
        assert_eq!(opts.max_retries, 3);
        assert_eq!(opts.stall_policy, crate::coordinator::fleet::StallPolicy::Drain);
        assert_eq!(opts.max_queue_per_replica, c.max_queue);
        // ... and a later CLI-style assignment (file first, then flags) wins
        c.set("replicas", "2").unwrap();
        assert_eq!(c.replicas, 2);
        c.validate().unwrap(); // plan names replicas 0 and 1: still in range
        c.set("replicas", "1").unwrap();
        assert!(c.validate().is_err(), "plan now names a replica outside the fleet");
    }

    #[test]
    fn fleet_validation_rejects_bad_plans_and_policies() {
        let mut c = ServeConfig::default();
        c.replicas = 0;
        assert!(c.validate().is_err(), "zero replicas");
        c.replicas = 2;
        c.fault_plan = "crash:5@100".into();
        assert!(c.validate().is_err(), "plan names replica 5 of 2");
        c.fault_plan = "crash:1@100".into();
        c.validate().unwrap();
        c.fault_plan = "freeze:0@1".into();
        assert!(c.validate().is_err(), "unknown fault kind");
        c.fault_plan.clear();
        c.fault_stall_policy = "panic".into();
        assert!(c.validate().is_err(), "unknown stall policy");
        assert!(c.fleet_options().is_err());
        c.fault_stall_policy = "failover".into();
        c.validate().unwrap();
    }

    #[test]
    fn obs_keys_round_trip() {
        let d = ServeConfig::default();
        assert!(d.trace_out.is_empty() && d.metrics_out.is_empty(), "tracing defaults off");
        let mut c = ServeConfig::default();
        c.apply_text("trace_out = target/run.trace.json\nmetrics_out = target/run.prom\n")
            .unwrap();
        assert_eq!(c.trace_out, "target/run.trace.json");
        assert_eq!(c.metrics_out, "target/run.prom");
        c.validate().unwrap();
    }

    #[test]
    fn backend_and_cluster_keys() {
        let mut c = ServeConfig::default();
        assert_eq!(c.backend, BackendKind::Functional, "functional is the default");
        c.apply_text("backend = pjrt\ncluster_size = 4\n").unwrap();
        assert_eq!(c.backend, BackendKind::Pjrt);
        assert_eq!(c.cluster_size, 4);
        c.set("backend", "mock").unwrap();
        assert_eq!(c.backend, BackendKind::Mock);
        assert!(c.set("backend", "tpu").is_err());
    }
}
