//! Request/response types of the serving coordinator.

/// Unique request identifier.
pub type RequestId = u64;

/// Why a sequence stopped generating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated `max_new_tokens`.
    Length,
    /// Hit the model's KV-cache capacity (max_seq).
    CacheFull,
    /// Sampler produced the EOS token.
    Eos,
    /// Evicted by the scheduler and not resumable (shutdown).
    Aborted,
    /// Refused at the front door before any work ran: the request could
    /// never fit the context window, or admitting it would breach the
    /// configured latency SLO (`coordinator::admission`). `generated` is
    /// always empty and no `RequestTiming` is recorded.
    Rejected,
    /// The request's `deadline_us` passed — at submit (the projected TTFT
    /// could never land in time; no `RequestTiming`) or at a later step
    /// boundary (queued or mid-generation; a `RequestTiming` is recorded
    /// with whatever was generated).
    DeadlineExceeded,
    /// Failover exhausted: the request was evacuated from a crashed or
    /// stalled replica more than `max_retries` times
    /// (`coordinator::fleet`). Terminal — the client will not see tokens.
    Failed,
}

/// Sampling configuration. The demo engine is greedy by default; a
/// temperature of 0 means argmax.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingParams {
    pub temperature: f32,
    /// Token id treated as end-of-sequence (None = never stop early).
    pub eos_token: Option<i32>,
    pub max_new_tokens: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 0.0, eos_token: None, max_new_tokens: 32 }
    }
}

/// One inference request as submitted to the router.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub sampling: SamplingParams,
    /// Arrival time offset (µs from engine start) for trace replay; 0 for
    /// interactive submissions.
    pub arrival_us: u64,
    /// Absolute clock deadline in µs (same origin as `arrival_us`); 0 =
    /// none. Enforced at submit (projection) and at step boundaries —
    /// see [`FinishReason::DeadlineExceeded`].
    pub deadline_us: u64,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        assert!(!prompt.is_empty(), "empty prompt");
        Self {
            id,
            prompt,
            sampling: SamplingParams { max_new_tokens, ..Default::default() },
            arrival_us: 0,
            deadline_us: 0,
        }
    }

    /// Builder-style absolute deadline (clock µs; 0 clears it).
    pub fn with_deadline_us(mut self, deadline_us: u64) -> Self {
        self.deadline_us = deadline_us;
        self
    }

    /// Total KV slots this request may need.
    pub fn max_total_len(&self) -> usize {
        self.prompt.len() + self.sampling.max_new_tokens
    }
}

/// Lifecycle of a request inside the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Waiting in the admission queue.
    Queued,
    /// Prompt tokens being fed (prefill via the decode path).
    Prefill,
    /// Auto-regressive generation.
    Decode,
    /// Done; see [`FinishReason`].
    Finished(FinishReason),
}

/// Event stream emitted per request. Every variant carries `at_us`,
/// the emitting engine's clock microseconds at emission (virtual µs on
/// the replay path, wall µs on the threaded server), so event streams
/// are self-describing without a side-channel clock.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Prefill finished; time-to-first-token is measured from
    /// *submission* (queue wait included — see `RequestTiming::ttft`).
    FirstToken { id: RequestId, token: i32, at_us: u64 },
    /// One generated token.
    Token { id: RequestId, token: i32, at_us: u64 },
    /// Generation finished.
    Finished { id: RequestId, reason: FinishReason, generated: Vec<i32>, at_us: u64 },
}

impl Event {
    /// The request this event belongs to.
    pub fn id(&self) -> RequestId {
        match self {
            Event::FirstToken { id, .. } | Event::Token { id, .. } | Event::Finished { id, .. } => {
                *id
            }
        }
    }

    /// Emission timestamp, clock µs.
    pub fn at_us(&self) -> u64 {
        match self {
            Event::FirstToken { at_us, .. }
            | Event::Token { at_us, .. }
            | Event::Finished { at_us, .. } => *at_us,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_total_len() {
        let r = Request::new(1, vec![1, 2, 3], 10);
        assert_eq!(r.max_total_len(), 13);
    }

    #[test]
    #[should_panic]
    fn empty_prompt_rejected() {
        Request::new(1, vec![], 4);
    }
}
