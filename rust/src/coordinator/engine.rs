//! Decode engine: the per-step loop that turns admitted requests into
//! tokens. Generic over a [`Backend`] so the whole coordinator is testable
//! without PJRT (see [`MockBackend`]); the real backend lives in
//! `pjrt_backend.rs`.
//!
//! One `step()` = one fused step for the current continuous batch: gather
//! pages → execute the AOT executable → sample → append new KV rows →
//! emit events. Each running slot contributes a *row range* per step —
//! one row for decode slots, up to `Batcher::prefill_chunk` prompt rows
//! for prefilling slots — so long prompts chunk across steps and mix
//! with decode traffic in a single batch (Sarathi/TGI-style chunked
//! prefill). Logits are produced per slot from its last fed row; prompt
//! logits before the final prompt row are never materialised.
//!
//! All request timing (queue wait, TTFT, TPOT, end-to-end) is measured on
//! a pluggable [`Clock`]: real runs use the wall clock, load tests inject
//! a deterministic virtual clock (`util::clock`, `loadgen`).

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::obs::{Obs, TRACK_FLEET, TRACK_REQUEST_BASE};
use crate::util::clock::{SharedClock, WallClock};
use crate::util::rng::Rng;

use super::admission::{AdmissionConfig, SubmitOutcome};
use super::batcher::Batcher;
use super::kv_cache::{CacheGeometry, KvPool, SeqId};
use super::request::{Event, FinishReason, Phase, Request, RequestId};
use super::scheduler::pick_victim;

/// Model geometry a backend exposes (mirrors the artifact manifest).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelGeom {
    pub vocab: usize,
    pub n_layers: usize,
    pub row_elems: usize,
    pub planes: usize,
    pub max_seq: usize,
}

impl ModelGeom {
    pub fn cache_geometry(&self) -> CacheGeometry {
        CacheGeometry {
            n_layers: self.n_layers,
            row_elems: self.row_elems,
            planes: self.planes,
            max_seq: self.max_seq,
        }
    }
}

/// One slot's contribution to a step: a contiguous run of input rows.
/// Decode slots carry exactly one row (the last sampled token); a
/// prefilling slot carries its next prompt chunk. `pos0` is the absolute
/// position of the first row (== the slot's current KV length).
#[derive(Debug, Clone)]
pub struct SlotRows {
    pub tokens: Vec<i32>,
    pub pos0: usize,
}

impl SlotRows {
    pub fn rows(&self) -> usize {
        self.tokens.len()
    }
}

/// Output of one backend step over `n_slots` slot row-ranges totalling
/// `total_rows` rows.
#[derive(Debug, Clone)]
pub struct StepOut {
    /// (n_slots, vocab) row-major: one logits row per slot, taken from
    /// that slot's *last* fed row.
    pub logits: Vec<f32>,
    /// Per plane: (n_layers, total_rows, row_elems) row-major new cache
    /// rows, slot-major within a layer (slot 0's rows first, in position
    /// order).
    pub new_rows: Vec<Vec<f32>>,
}

/// Something that can execute one fused multi-position step for a batch
/// bucket. `slots` holds between 1 and `bucket` entries; `cache_planes`
/// are the gathered dense KV planes (`(n_layers, bucket, max_seq,
/// row_elems)` each) and are mutable so backends may write the new roped
/// rows in place — the engine re-gathers from the pool every step, so
/// such writes never leak between steps.
pub trait Backend {
    fn geom(&self) -> ModelGeom;
    fn buckets(&self) -> Vec<usize>;
    fn step(
        &mut self,
        bucket: usize,
        slots: &[SlotRows],
        cache_planes: &mut [Vec<f32>],
    ) -> Result<StepOut>;
    /// Cumulative dispatch counters of the backend's worker pool, if it
    /// runs one (`None` for pool-less backends). The engine publishes a
    /// `Some` snapshot into the metrics registry at sync points as
    /// `pool_dispatch_total` / `pool_tasks_total` / `pool_queue_depth`.
    fn pool_stats(&self) -> Option<crate::util::pool::PoolStats> {
        None
    }
}

#[derive(Debug)]
struct SeqState {
    req: Request,
    fed: usize,
    generated: Vec<i32>,
    phase: Phase,
    /// Clock µs of the original submission (survives preemption requeues).
    submitted_us: u64,
    /// Clock µs of (the latest) admission into the running set.
    admitted_us: u64,
    /// Total queue wait accumulated across all admission attempts, µs
    /// (time spent *running* before a preemption is not queueing).
    queue_us: u64,
    /// Clock µs of the first generated token, if any.
    first_us: Option<u64>,
}

impl SeqState {
    fn next_input(&self) -> i32 {
        if self.fed < self.req.prompt.len() {
            self.req.prompt[self.fed]
        } else {
            *self.generated.last().unwrap_or(&0)
        }
    }
}

/// Per-request timing summary for metrics. All timestamps are clock
/// microseconds; derived latencies are seconds.
#[derive(Debug, Clone, Copy)]
pub struct RequestTiming {
    pub id: RequestId,
    /// Clock µs at submission.
    pub submitted_us: u64,
    /// Clock µs at completion.
    pub finished_us: u64,
    /// Total time spent waiting for admission, seconds (accumulated
    /// across preemption requeues; excludes time spent executing).
    pub queue: f64,
    /// Submission → first generated token, seconds (includes queue time).
    pub ttft: f64,
    /// Mean time per generated token after the first, seconds
    /// (0 when fewer than two tokens were generated).
    pub tpot: f64,
    /// Submission → completion, seconds.
    pub total: f64,
    pub prompt_len: usize,
    pub generated: usize,
}

fn us_delta_secs(later: u64, earlier: u64) -> f64 {
    later.saturating_sub(earlier) as f64 * 1e-6
}

/// A request pulled off an engine by fleet failover ([`Engine::evacuate`]):
/// everything needed to resubmit it elsewhere with recompute semantics —
/// prefill progress is discarded; the original submission time and the
/// queue wait accumulated so far ride along, exactly like a preemption
/// requeue but across replicas.
#[derive(Debug, Clone, PartialEq)]
pub struct Evacuated {
    pub req: Request,
    pub submitted_us: u64,
    pub queued_us: u64,
}

/// The decode engine.
pub struct Engine<B: Backend> {
    backend: B,
    pub pool: KvPool,
    pub batcher: Batcher,
    clock: SharedClock,
    seqs: HashMap<SeqId, SeqState>,
    /// persistent gather buffers per batch bucket (hot-path reuse; never
    /// zeroed — see KvPool::gather_batch_into)
    plane_bufs: HashMap<usize, Vec<Vec<f32>>>,
    events: Vec<Event>,
    timings: Vec<RequestTiming>,
    rng: Rng,
    /// decode steps executed (each = one fused kernel invocation batch).
    pub steps: u64,
    /// live sequences in the most recent executed step (0 if the last
    /// `step()` was a no-op).
    pub last_batch: usize,
    /// decode slots (single-row) in the most recent executed step — what
    /// a service-time model bills per sequence.
    pub last_decode_slots: usize,
    /// prompt rows fed in the most recent executed step — what a
    /// service-time model bills per prefill token.
    pub last_prefill_tokens: usize,
    /// prompt rows fed in total across all steps.
    pub prefill_tokens: u64,
    /// tokens generated in total.
    pub tokens_out: u64,
    /// preemptions performed under cache pressure.
    pub preemptions: u64,
    /// front-door configuration (off by default: no behaviour change).
    admission: AdmissionConfig,
    /// requests refused at submit: could never fit the context window.
    pub rejected_too_long: u64,
    /// requests refused at submit: projected TTFT breached the SLO.
    pub rejected_slo: u64,
    /// requests refused at submit: their `deadline_us` had already passed
    /// (or the TTFT projection provably lands past it).
    pub rejected_deadline: u64,
    /// requests expired at a step boundary after entering the queue or
    /// the running set (`FinishReason::DeadlineExceeded`, timing kept).
    pub deadline_expired: u64,
    /// admission attempts deferred by the growth gate (telemetry).
    pub growth_deferrals: u64,
    /// step counter value at the last successful batch growth.
    last_growth_step: u64,
    /// Trace sink (`obs::Obs`), off by default. The engine only emits
    /// trace events here — timestamps always come from `self.clock`,
    /// never the wall clock directly (DESIGN.md §Observability), so
    /// attaching a sink cannot perturb virtual-clock determinism.
    obs: Option<Obs>,
    /// Replica index used as the Chrome `pid` of emitted events.
    obs_replica: u64,
}

impl<B: Backend> Engine<B> {
    /// Engine on the wall clock (interactive / production path).
    pub fn new(backend: B, pool_pages: usize, page_tokens: usize, admit_fraction: f64) -> Self {
        Self::with_clock(backend, pool_pages, page_tokens, admit_fraction, WallClock::shared())
    }

    /// Engine on an explicit clock (load tests inject a `VirtualClock`).
    pub fn with_clock(
        backend: B,
        pool_pages: usize,
        page_tokens: usize,
        admit_fraction: f64,
        clock: SharedClock,
    ) -> Self {
        let geom = backend.geom().cache_geometry();
        let buckets = backend.buckets();
        Self {
            backend,
            pool: KvPool::new(geom, page_tokens, pool_pages),
            batcher: Batcher::new(buckets, admit_fraction),
            clock,
            seqs: HashMap::new(),
            plane_bufs: HashMap::new(),
            events: Vec::new(),
            timings: Vec::new(),
            rng: Rng::seed_from_u64(0xC1A5),
            steps: 0,
            last_batch: 0,
            last_decode_slots: 0,
            last_prefill_tokens: 0,
            prefill_tokens: 0,
            tokens_out: 0,
            preemptions: 0,
            admission: AdmissionConfig::off(),
            rejected_too_long: 0,
            rejected_slo: 0,
            rejected_deadline: 0,
            deadline_expired: 0,
            growth_deferrals: 0,
            last_growth_step: 0,
            obs: None,
            obs_replica: 0,
        }
    }

    /// Attach a trace sink; `replica` becomes the `pid` of every event
    /// this engine emits (0 for single-engine deployments).
    pub fn set_obs(&mut self, obs: Obs, replica: usize) {
        self.obs = Some(obs);
        self.obs_replica = replica as u64;
    }

    /// The attached trace sink, if any (replay drivers use this to emit
    /// step spans without a second plumbing path).
    pub fn obs(&self) -> Option<Obs> {
        self.obs.clone()
    }

    /// Replica index (`pid`) the sink was attached with.
    pub fn obs_replica(&self) -> u64 {
        self.obs_replica
    }

    /// Publish this engine's cumulative counters into the attached
    /// registry as `engine_*_total{replica="N"}` series (no-op without
    /// a sink). Replay drivers call this at sync points; `counter_set`
    /// keeps the existing report fields authoritative and the registry
    /// a consolidated view of them.
    pub fn sync_obs_counters(&self) {
        let Some(o) = &self.obs else { return };
        let r = self.obs_replica;
        let set = |name: &str, v: u64| o.counter_set(&format!("{name}{{replica=\"{r}\"}}"), v);
        set("engine_steps_total", self.steps);
        set("engine_tokens_out_total", self.tokens_out);
        set("engine_prefill_tokens_total", self.prefill_tokens);
        set("engine_preemptions_total", self.preemptions);
        set("engine_growth_deferrals_total", self.growth_deferrals);
        set("engine_deadline_expired_total", self.deadline_expired);
        set("engine_rejected_too_long_total", self.rejected_too_long);
        set("engine_rejected_slo_total", self.rejected_slo);
        set("engine_rejected_deadline_total", self.rejected_deadline);
        if let Some(ps) = self.backend.pool_stats() {
            set("pool_dispatch_total", ps.dispatches);
            set("pool_tasks_total", ps.tasks);
            o.gauge_set(&format!("pool_queue_depth{{replica=\"{r}\"}}"), ps.queue_depth as f64);
        }
    }

    /// The Chrome track id of a request's lifecycle row.
    fn req_track(id: RequestId) -> u64 {
        TRACK_REQUEST_BASE + id
    }

    /// The engine's time source (shared with the load generator).
    pub fn clock(&self) -> SharedClock {
        self.clock.clone()
    }

    /// Install the front door. [`AdmissionConfig::off`] (the default)
    /// restores pre-admission behaviour exactly.
    pub fn set_admission(&mut self, cfg: AdmissionConfig) {
        self.admission = cfg;
    }

    pub fn admission(&self) -> &AdmissionConfig {
        &self.admission
    }

    /// Total requests refused at the front door.
    pub fn rejected(&self) -> u64 {
        self.rejected_too_long + self.rejected_slo + self.rejected_deadline
    }

    /// Outstanding prompt rows the prefill budget must clear before a new
    /// arrival sees its first token: every waiting prompt plus the unfed
    /// remainder of running prompts.
    fn backlog_rows(&self) -> usize {
        let running: usize = self
            .batcher
            .running()
            .iter()
            .filter_map(|id| self.seqs.get(id))
            .map(|st| st.req.prompt.len().saturating_sub(st.fed))
            .sum();
        self.batcher.waiting_prompt_rows() + running
    }

    /// Prompts in that backlog (waiting + running-but-still-prefilling) —
    /// the step count under one-shot prefill.
    fn backlog_prompts(&self) -> usize {
        let running = self
            .batcher
            .running()
            .iter()
            .filter_map(|id| self.seqs.get(id))
            .filter(|st| st.fed < st.req.prompt.len())
            .count();
        self.batcher.queued() + running
    }

    /// Submit through the front door. Rejections emit a `Finished` event
    /// (empty `generated`, no timing) so subscribers always hear back;
    /// SLO outcomes are decided purely from engine-visible state, and the
    /// deadline check reads only the *injected* clock, so virtual-clock
    /// replay stays deterministic.
    pub fn submit(&mut self, req: Request) -> SubmitOutcome {
        if req.max_total_len() > self.pool.geometry().max_seq {
            self.rejected_too_long += 1;
            let now = self.clock.now_us();
            if let Some(o) = &self.obs {
                o.instant(
                    "admission",
                    "reject-too-long",
                    now,
                    self.obs_replica,
                    Self::req_track(req.id),
                    vec![("id", req.id.to_string())],
                );
            }
            self.events.push(Event::Finished {
                id: req.id,
                reason: FinishReason::Rejected,
                generated: Vec::new(),
                at_us: now,
            });
            return SubmitOutcome::RejectedTooLong;
        }
        if req.deadline_us > 0 {
            // A request whose deadline already passed — or whose projected
            // TTFT lands past it under the active service model — could
            // only ever expire in the queue; refuse it up front. With the
            // off-config the projection is 0 and only the first clause
            // can trip.
            let now = self.clock.now_us();
            let projected = self.admission.projected_ttft_us(
                self.backlog_rows(),
                self.backlog_prompts(),
                req.prompt.len(),
                self.batcher.max_batch(),
                self.batcher.prefill_chunk(),
            );
            if now >= req.deadline_us || now.saturating_add(projected) > req.deadline_us {
                self.rejected_deadline += 1;
                if let Some(o) = &self.obs {
                    o.instant(
                        "admission",
                        "reject-deadline",
                        now,
                        self.obs_replica,
                        Self::req_track(req.id),
                        vec![("id", req.id.to_string())],
                    );
                }
                self.events.push(Event::Finished {
                    id: req.id,
                    reason: FinishReason::DeadlineExceeded,
                    generated: Vec::new(),
                    at_us: now,
                });
                return SubmitOutcome::RejectedDeadline;
            }
        }
        if self.admission.slo_ttft_us > 0 {
            let projected = self.admission.projected_ttft_us(
                self.backlog_rows(),
                self.backlog_prompts(),
                req.prompt.len(),
                self.batcher.max_batch(),
                self.batcher.prefill_chunk(),
            );
            if projected > self.admission.slo_ttft_us {
                self.rejected_slo += 1;
                let now = self.clock.now_us();
                if let Some(o) = &self.obs {
                    o.instant(
                        "admission",
                        "reject-slo",
                        now,
                        self.obs_replica,
                        Self::req_track(req.id),
                        vec![("id", req.id.to_string()), ("projected_us", projected.to_string())],
                    );
                }
                self.events.push(Event::Finished {
                    id: req.id,
                    reason: FinishReason::Rejected,
                    generated: Vec::new(),
                    at_us: now,
                });
                return SubmitOutcome::RejectedSlo;
            }
        }
        let now = self.clock.now_us();
        self.batcher.submit(req, now);
        SubmitOutcome::Queued
    }

    /// Cap on prompt rows fed per step across the batch (0 = unlimited).
    pub fn set_prefill_chunk(&mut self, chunk: usize) {
        self.batcher.set_prefill_chunk(chunk);
    }

    /// Drain accumulated events.
    pub fn take_events(&mut self) -> Vec<Event> {
        std::mem::take(&mut self.events)
    }

    pub fn timings(&self) -> &[RequestTiming] {
        &self.timings
    }

    pub fn idle(&self) -> bool {
        self.batcher.idle()
    }

    fn sample(&mut self, logits: &[f32], temperature: f32) -> i32 {
        if temperature <= 0.0 {
            return crate::runtime::argmax(logits) as i32;
        }
        // softmax sampling with temperature
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = logits.iter().map(|l| ((l - m) / temperature).exp()).collect();
        let sum: f32 = exps.iter().sum();
        let mut u = self.rng.f32() * sum;
        for (i, e) in exps.iter().enumerate() {
            u -= e;
            if u <= 0.0 {
                return i as i32;
            }
        }
        (logits.len() - 1) as i32
    }

    fn finish(&mut self, id: SeqId, reason: FinishReason) {
        if let Some(mut st) = self.seqs.remove(&id) {
            st.phase = Phase::Finished(reason);
            let now = self.clock.now_us();
            let generated = st.generated.len();
            let tpot = match (st.first_us, generated) {
                (Some(first), n) if n >= 2 => us_delta_secs(now, first) / (n - 1) as f64,
                _ => 0.0,
            };
            self.timings.push(RequestTiming {
                id,
                submitted_us: st.submitted_us,
                finished_us: now,
                queue: st.queue_us as f64 * 1e-6,
                ttft: st.first_us.map(|f| us_delta_secs(f, st.submitted_us)).unwrap_or_default(),
                tpot,
                total: us_delta_secs(now, st.submitted_us),
                prompt_len: st.req.prompt.len(),
                generated,
            });
            if let Some(o) = &self.obs {
                // The request lifecycle span: submission → finish, with
                // the terminal reason. Queue spans (emitted at each
                // admission) nest inside it on the same track.
                o.span(
                    "request",
                    "request",
                    st.submitted_us,
                    now.saturating_sub(st.submitted_us),
                    self.obs_replica,
                    Self::req_track(id),
                    vec![
                        ("id", id.to_string()),
                        ("reason", format!("{reason:?}")),
                        ("generated", generated.to_string()),
                    ],
                );
            }
            self.events.push(Event::Finished {
                id,
                reason,
                generated: st.generated.clone(),
                at_us: now,
            });
        }
        self.pool.free_seq(id);
        self.batcher.release(id);
    }

    /// Finish every queued or running request whose absolute deadline has
    /// passed at `now_us`. Queued casualties never ran, so their timing is
    /// synthesised here (pure queue wait, nothing generated); running ones
    /// go through [`Self::finish`] and keep whatever they generated.
    fn expire_deadlines(&mut self, now_us: u64) {
        for entry in self.batcher.take_expired(now_us) {
            self.deadline_expired += 1;
            let queue_us = entry.queued_us + now_us.saturating_sub(entry.enqueued_us);
            self.timings.push(RequestTiming {
                id: entry.req.id,
                submitted_us: entry.submitted_us,
                finished_us: now_us,
                queue: queue_us as f64 * 1e-6,
                ttft: 0.0,
                tpot: 0.0,
                total: us_delta_secs(now_us, entry.submitted_us),
                prompt_len: entry.req.prompt.len(),
                generated: 0,
            });
            if let Some(o) = &self.obs {
                let track = Self::req_track(entry.req.id);
                o.instant(
                    "fleet",
                    "deadline-expired",
                    now_us,
                    self.obs_replica,
                    TRACK_FLEET,
                    vec![("id", entry.req.id.to_string())],
                );
                // Queued casualties never reach finish(): synthesise
                // their lifecycle span here (pure queue wait).
                o.span(
                    "request",
                    "request",
                    entry.submitted_us,
                    now_us.saturating_sub(entry.submitted_us),
                    self.obs_replica,
                    track,
                    vec![
                        ("id", entry.req.id.to_string()),
                        ("reason", "DeadlineExceeded".to_string()),
                        ("generated", "0".to_string()),
                    ],
                );
            }
            self.events.push(Event::Finished {
                id: entry.req.id,
                reason: FinishReason::DeadlineExceeded,
                generated: Vec::new(),
                at_us: now_us,
            });
        }
        for id in self.batcher.running().to_vec() {
            let expired = self
                .seqs
                .get(&id)
                .is_some_and(|st| st.req.deadline_us > 0 && st.req.deadline_us <= now_us);
            if expired {
                self.deadline_expired += 1;
                if let Some(o) = &self.obs {
                    o.instant(
                        "fleet",
                        "deadline-expired",
                        now_us,
                        self.obs_replica,
                        TRACK_FLEET,
                        vec![("id", id.to_string())],
                    );
                }
                self.finish(id, FinishReason::DeadlineExceeded);
            }
        }
    }

    /// Pull every queued *and* running request off this engine for fleet
    /// failover (the replica crashed or stalled): recompute semantics as
    /// in preemption — prefill progress and generated tokens are
    /// discarded, KV pages freed, and each request leaves with its
    /// original submission time plus the queue wait accumulated so far
    /// (waiting entries also bill the wait ending now). No events or
    /// timings are recorded here; the fleet decides retry vs `Failed`.
    /// Sorted by (submitted_us, id) so downstream re-routing is
    /// deterministic and FCFS-fair.
    pub fn evacuate(&mut self) -> Vec<Evacuated> {
        let now = self.clock.now_us();
        let mut out: Vec<Evacuated> = self
            .batcher
            .drain_waiting()
            .into_iter()
            .map(|e| Evacuated {
                submitted_us: e.submitted_us,
                queued_us: e.queued_us + now.saturating_sub(e.enqueued_us),
                req: e.req,
            })
            .collect();
        for id in self.batcher.running().to_vec() {
            if let Some(st) = self.seqs.remove(&id) {
                out.push(Evacuated {
                    req: st.req,
                    submitted_us: st.submitted_us,
                    queued_us: st.queue_us,
                });
            }
            self.pool.free_seq(id);
            self.batcher.release(id);
        }
        out.sort_by_key(|e| (e.submitted_us, e.req.id));
        out
    }

    /// Re-enqueue a request evacuated from another replica, preserving
    /// its original submission time and accumulated queue wait. Bypasses
    /// the front door on purpose (same recompute semantics as a
    /// preemption requeue): a retry the router already accepted must not
    /// be re-rejected here — its deadline, if any, still applies at step
    /// boundaries.
    pub fn resubmit(&mut self, req: Request, submitted_us: u64, queued_us: u64) {
        let now = self.clock.now_us();
        self.batcher.submit_carried(req, submitted_us, queued_us, now);
    }

    /// Preempt sequences until the pool can absorb the next step's
    /// appends: `plan` maps each running sequence to the rows it intends
    /// to append this step, and the pages those rows require must all be
    /// free up front (vLLM-style recompute preemption: the youngest
    /// victim loses its pages, leaves the plan, and re-enters the queue
    /// from the front). A lone sequence shrinks its prefill chunk to
    /// whatever still fits before giving up at its current length.
    fn relieve_pressure(&mut self, plan: &mut HashMap<SeqId, usize>) {
        // sequences at the hard context limit finish rather than preempt
        for id in self.batcher.running().to_vec() {
            if self.pool.seq_len(id).is_some_and(|l| l >= self.pool.geometry().max_seq) {
                plan.remove(&id);
                self.finish(id, FinishReason::CacheFull);
            }
        }
        loop {
            let running = self.batcher.running().to_vec();
            let needed: usize = running
                .iter()
                .map(|id| self.pool.pages_needed(*id, plan.get(id).copied().unwrap_or(0)))
                .sum();
            if self.pool.free_pages() >= needed {
                return;
            }
            if running.len() <= 1 {
                // nothing left to evict: shrink the lone sequence's chunk
                // to the rows that still fit; if not even one row fits it
                // can never get more pages and finishes where it stands
                if let Some(&id) = running.first() {
                    let free = self.pool.free_pages();
                    let mut fit = plan.get(&id).copied().unwrap_or(0);
                    while fit > 0 && self.pool.pages_needed(id, fit) > free {
                        fit -= 1;
                    }
                    if fit >= 1 {
                        plan.insert(id, fit);
                    } else {
                        plan.remove(&id);
                        self.finish(id, FinishReason::CacheFull);
                    }
                }
                return;
            }
            let victim = pick_victim(&running, |id| {
                self.seqs.get(&id).map(|s| s.admitted_us).unwrap_or(u64::MAX)
            });
            self.preemptions += 1;
            if let Some(o) = &self.obs {
                o.instant(
                    "engine",
                    "preempt",
                    self.clock.now_us(),
                    self.obs_replica,
                    TRACK_FLEET,
                    vec![("victim", victim.to_string())],
                );
            }
            plan.remove(&victim);
            if let Some(st) = self.seqs.remove(&victim) {
                let now = self.clock.now_us();
                self.batcher.requeue_front(st.req, st.submitted_us, st.queue_us, now);
            }
            self.pool.free_seq(victim);
            self.batcher.release(victim);
        }
    }

    /// Run one engine iteration. Returns false when there was nothing to do.
    pub fn step(&mut self) -> Result<bool> {
        // 1. admission, through the front door: the TPOT SLO caps the
        // batch width, the growth gate batches queue drains into
        // worthwhile prefills, and the token budget bounds the running
        // set's worst-case KV footprint. With the default off-config this
        // reduces to exactly the unbounded `Batcher::admit`.
        let now = self.clock.now_us();
        // 0. deadline enforcement at the step boundary: queued and running
        // requests whose absolute deadline passed finish now (no-op when
        // no request carries a deadline)
        self.expire_deadlines(now);
        let max_batch = self.batcher.max_batch();
        let slot_cap = self
            .admission
            .decode_slot_cap(max_batch, self.batcher.prefill_chunk())
            .min(max_batch);
        let admitted = if self.admission.growth_allowed(
            self.batcher.queued(),
            self.batcher.running().len(),
            self.steps - self.last_growth_step,
        ) {
            let run_tokens: usize = self
                .batcher
                .running()
                .iter()
                .filter_map(|id| self.seqs.get(id))
                .map(|st| st.req.max_total_len())
                .sum();
            self.batcher.admit_bounded(
                &self.pool,
                slot_cap,
                self.admission.max_batch_total_tokens,
                run_tokens,
            )
        } else {
            self.growth_deferrals += 1;
            if let Some(o) = &self.obs {
                o.instant(
                    "admission",
                    "growth-deferral",
                    now,
                    self.obs_replica,
                    TRACK_FLEET,
                    vec![("queued", self.batcher.queued().to_string())],
                );
            }
            Vec::new()
        };
        if !admitted.is_empty() {
            self.last_growth_step = self.steps;
        }
        for entry in admitted {
            self.pool.alloc_seq(entry.req.id).context("alloc admitted seq")?;
            if let Some(o) = &self.obs {
                // Queue-wait span for this admission round; re-queued
                // (preempted) requests get one span per round, all nested
                // inside the request lifecycle span.
                o.span(
                    "request",
                    "queue",
                    entry.enqueued_us,
                    now.saturating_sub(entry.enqueued_us),
                    self.obs_replica,
                    Self::req_track(entry.req.id),
                    vec![("id", entry.req.id.to_string())],
                );
            }
            self.seqs.insert(
                entry.req.id,
                SeqState {
                    req: entry.req,
                    fed: 0,
                    generated: Vec::new(),
                    phase: Phase::Prefill,
                    submitted_us: entry.submitted_us,
                    admitted_us: now,
                    queue_us: entry.queued_us + now.saturating_sub(entry.enqueued_us),
                    first_us: None,
                },
            );
        }
        // 2. plan this step's rows per running slot: decode slots always
        // get one row; prefilling slots split the batcher's per-step
        // prefill token budget FCFS (chunked prefill), clamped to the
        // context limit
        let running = self.batcher.running().to_vec();
        if running.is_empty() {
            self.last_batch = 0;
            self.last_decode_slots = 0;
            self.last_prefill_tokens = 0;
            return Ok(false);
        }
        let remaining: Vec<usize> = running
            .iter()
            .map(|id| {
                let st = &self.seqs[id];
                st.req.prompt.len().saturating_sub(st.fed)
            })
            .collect();
        let alloc = self.batcher.allocate_prefill(&remaining);
        let max_seq = self.pool.geometry().max_seq;
        let mut plan: HashMap<SeqId, usize> = HashMap::new();
        for (i, id) in running.iter().enumerate() {
            let len = self.pool.seq_len(*id).unwrap_or(0);
            plan.insert(*id, alloc[i].min(max_seq.saturating_sub(len)));
        }

        // 3. cache pressure (victims and finished sequences leave the plan)
        self.relieve_pressure(&mut plan);
        let active: Vec<SeqId> = self
            .batcher
            .running()
            .iter()
            .copied()
            .filter(|id| plan.get(id).copied().unwrap_or(0) >= 1)
            .collect();
        if active.is_empty() {
            self.last_batch = 0;
            self.last_decode_slots = 0;
            self.last_prefill_tokens = 0;
            return Ok(false);
        }
        let bucket = self
            .batcher
            .bucket_for(active.len())
            .context("active set exceeds largest bucket")?;

        // 4. build per-slot row ranges
        let mut slots_in: Vec<SlotRows> = Vec::with_capacity(active.len());
        let mut decode_slots = 0usize;
        let mut prefill_rows = 0usize;
        for id in &active {
            let st = &self.seqs[id];
            let r = plan[id];
            let pos0 = self.pool.seq_len(*id).unwrap_or(0);
            let tokens: Vec<i32> = if st.fed < st.req.prompt.len() {
                prefill_rows += r;
                if let Some(o) = &self.obs {
                    o.instant(
                        "request",
                        "prefill-chunk",
                        now,
                        self.obs_replica,
                        Self::req_track(*id),
                        vec![("id", id.to_string()), ("rows", r.to_string())],
                    );
                }
                st.req.prompt[st.fed..st.fed + r].to_vec()
            } else {
                decode_slots += 1;
                debug_assert_eq!(r, 1, "decode slots step one row");
                vec![st.next_input()]
            };
            slots_in.push(SlotRows { tokens, pos0 });
        }
        self.last_batch = active.len();
        self.last_decode_slots = decode_slots;
        self.last_prefill_tokens = prefill_rows;
        self.prefill_tokens += prefill_rows as u64;

        let g0 = self.pool.geometry();
        let planes = self.plane_bufs.entry(bucket).or_insert_with(|| {
            vec![vec![0.0f32; g0.n_layers * bucket * g0.max_seq * g0.row_elems]; g0.planes]
        });
        self.pool.gather_batch_into(&active, bucket, planes)?;

        // 5. execute
        let out = self.backend.step(bucket, &slots_in, planes)?;
        self.steps += 1;

        // 6. scatter results: new_rows is (L, total_rows, re) slot-major
        let g = self.backend.geom();
        let re = g.row_elems;
        let total_rows: usize = slots_in.iter().map(SlotRows::rows).sum();
        let mut row_base = 0usize;
        for (i, id) in active.iter().enumerate() {
            let r = slots_in[i].rows();
            let rows: Vec<Vec<f32>> = out
                .new_rows
                .iter()
                .map(|plane| {
                    let mut buf = Vec::with_capacity(g.n_layers * r * re);
                    for l in 0..g.n_layers {
                        let o = (l * total_rows + row_base) * re;
                        buf.extend_from_slice(&plane[o..o + r * re]);
                    }
                    buf
                })
                .collect();
            let row_refs: Vec<&[f32]> = rows.iter().map(|b| b.as_slice()).collect();
            self.pool.append_rows(*id, &row_refs, r).context("append new KV rows")?;
            row_base += r;

            let logits_row = &out.logits[i * g.vocab..(i + 1) * g.vocab];
            let st = self.seqs.get_mut(id).expect("running seq has state");
            st.fed += r;
            let prompt_done = st.fed >= st.req.prompt.len();
            if !prompt_done {
                continue; // still prefilling: discard logits
            }
            // sample the next token
            let temperature = st.req.sampling.temperature;
            let max_new = st.req.sampling.max_new_tokens;
            let eos = st.req.sampling.eos_token;
            let tok = {
                let st_phase_first = st.generated.is_empty();
                let t = self.sample(logits_row, temperature);
                let t_now = self.clock.now_us();
                let st = self.seqs.get_mut(id).unwrap();
                st.generated.push(t);
                if st_phase_first {
                    st.first_us = Some(t_now);
                    st.phase = Phase::Decode;
                    self.events.push(Event::FirstToken { id: *id, token: t, at_us: t_now });
                } else {
                    self.events.push(Event::Token { id: *id, token: t, at_us: t_now });
                }
                t
            };
            self.tokens_out += 1;
            let st = &self.seqs[id];
            let done_len = st.generated.len() >= max_new;
            let done_eos = eos == Some(tok);
            let done_cache = self.pool.seq_len(*id).unwrap_or(0) >= g.max_seq;
            if done_len {
                self.finish(*id, FinishReason::Length);
            } else if done_eos {
                self.finish(*id, FinishReason::Eos);
            } else if done_cache {
                self.finish(*id, FinishReason::CacheFull);
            }
        }
        Ok(true)
    }

    /// Drive until all submitted work completes (or `max_steps` safety cap).
    pub fn run_to_completion(&mut self, max_steps: u64) -> Result<()> {
        let mut steps = 0u64;
        while !self.idle() {
            let did = self.step()?;
            anyhow::ensure!(did || !self.idle(), "engine wedged");
            steps += 1;
            anyhow::ensure!(steps <= max_steps, "exceeded {max_steps} steps");
        }
        Ok(())
    }
}

/// Deterministic in-memory backend for coordinator tests: the "model"
/// echoes `(last_token + its_pos) % vocab` as each slot's argmax and
/// encodes `(token, pos)` into every new KV row so tests can verify
/// multi-row appends. Identical token streams to the single-row mock —
/// only the step count changes under chunking.
pub struct MockBackend {
    pub geom: ModelGeom,
    pub buckets: Vec<usize>,
    pub steps: u64,
}

impl MockBackend {
    pub fn new(geom: ModelGeom, buckets: Vec<usize>) -> Self {
        Self { geom, buckets, steps: 0 }
    }

    pub fn tiny() -> Self {
        Self::new(
            ModelGeom { vocab: 32, n_layers: 2, row_elems: 4, planes: 2, max_seq: 16 },
            vec![1, 2, 4],
        )
    }
}

impl Backend for MockBackend {
    fn geom(&self) -> ModelGeom {
        self.geom
    }

    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn step(
        &mut self,
        bucket: usize,
        slots: &[SlotRows],
        cache_planes: &mut [Vec<f32>],
    ) -> Result<StepOut> {
        anyhow::ensure!(!slots.is_empty() && slots.len() <= bucket);
        anyhow::ensure!(cache_planes.len() == self.geom.planes);
        let g = self.geom;
        for p in cache_planes.iter() {
            anyhow::ensure!(p.len() == g.n_layers * bucket * g.max_seq * g.row_elems);
        }
        self.steps += 1;
        let n_slots = slots.len();
        let total_rows: usize = slots.iter().map(SlotRows::rows).sum();
        let mut logits = vec![0.0f32; n_slots * g.vocab];
        for (i, s) in slots.iter().enumerate() {
            anyhow::ensure!(!s.tokens.is_empty(), "slot {i} fed no rows");
            let last = s.tokens.len() - 1;
            let t = ((s.tokens[last] + (s.pos0 + last) as i32) as usize) % g.vocab;
            logits[i * g.vocab + t] = 1.0;
        }
        let new_rows: Vec<Vec<f32>> = (0..g.planes)
            .map(|plane| {
                let mut rows = vec![0.0f32; g.n_layers * total_rows * g.row_elems];
                for l in 0..g.n_layers {
                    let mut r = 0usize;
                    for s in slots {
                        for (j, &tok) in s.tokens.iter().enumerate() {
                            let o = (l * total_rows + r) * g.row_elems;
                            rows[o] = tok as f32;
                            if g.row_elems > 1 {
                                rows[o + 1] = (s.pos0 + j) as f32;
                            }
                            if g.row_elems > 2 {
                                rows[o + 2] = plane as f32;
                            }
                            r += 1;
                        }
                    }
                }
                rows
            })
            .collect();
        Ok(StepOut { logits, new_rows })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::{Clock, VirtualClock};
    use std::sync::Arc;

    fn engine() -> Engine<MockBackend> {
        Engine::new(MockBackend::tiny(), 64, 4, 1.0)
    }

    #[test]
    fn single_request_generates_expected_tokens() {
        let mut e = engine();
        e.submit(Request::new(1, vec![3, 5], 3));
        e.run_to_completion(100).unwrap();
        let events = e.take_events();
        // prefill feeds [3, 5] in one step; logits from the last prompt
        // row: (5+1)%32=6, then (6+2)%32=8, then (8+3)%32=11
        let toks: Vec<i32> = events
            .iter()
            .filter_map(|ev| match ev {
                Event::FirstToken { token, .. } | Event::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        assert_eq!(toks, vec![6, 8, 11]);
        match events.last().unwrap() {
            Event::Finished { reason, generated, .. } => {
                assert_eq!(*reason, FinishReason::Length);
                assert_eq!(generated, &vec![6, 8, 11]);
            }
            other => panic!("expected Finished, got {other:?}"),
        }
        assert_eq!(e.tokens_out, 3);
        // the whole prompt prefills in one step (which already yields the
        // first generated token), then one step per remaining token
        assert_eq!(e.steps, 3);
        assert_eq!(e.prefill_tokens, 2);
    }

    #[test]
    fn prompt_prefills_in_ceil_p_over_chunk_steps() {
        // P=5, chunk=2 -> chunks of 2,2,1: first token on step 3, then
        // 2 more decode steps
        let mut e = engine();
        e.set_prefill_chunk(2);
        e.submit(Request::new(1, vec![1, 1, 1, 1, 1], 3));
        e.step().unwrap();
        assert_eq!((e.last_prefill_tokens, e.last_decode_slots), (2, 0));
        assert_eq!(e.pool.seq_len(1), Some(2));
        e.step().unwrap();
        assert_eq!((e.last_prefill_tokens, e.last_decode_slots), (2, 0));
        e.step().unwrap();
        assert_eq!((e.last_prefill_tokens, e.last_decode_slots), (1, 0));
        assert_eq!(e.tokens_out, 1, "first token sampled on the final chunk");
        e.run_to_completion(100).unwrap();
        assert_eq!(e.steps, 5); // ceil(5/2)=3 prefill + 2 decode
        assert_eq!(e.prefill_tokens, 5);
    }

    #[test]
    fn chunked_stream_matches_unchunked_byte_for_byte() {
        let run = |chunk: usize| {
            let mut e = engine();
            e.set_prefill_chunk(chunk);
            e.submit(Request::new(1, vec![3, 5, 9, 2], 4));
            e.run_to_completion(100).unwrap();
            let toks: Vec<i32> = e
                .take_events()
                .iter()
                .filter_map(|ev| match ev {
                    Event::FirstToken { token, .. } | Event::Token { token, .. } => Some(*token),
                    _ => None,
                })
                .collect();
            (toks, e.steps)
        };
        let (base, base_steps) = run(0); // unlimited: one prefill step
        assert_eq!(base_steps, 4); // 1 prefill + 3 decode
        for chunk in [1, 2, 3, 4, 7] {
            let (toks, steps) = run(chunk);
            assert_eq!(toks, base, "chunk={chunk}");
            let c = chunk.min(4);
            let prefill_steps = (4 + c - 1) / c;
            assert_eq!(steps as usize, prefill_steps + 3, "chunk={chunk}");
        }
    }

    #[test]
    fn prefill_budget_is_shared_fcfs_and_decode_slots_ride_free() {
        // slot A decodes while B and C prefill under a 3-row budget:
        // B (first in running order among prefills) gets its rows first
        let mut e = engine();
        e.submit(Request::new(1, vec![4], 8)); // A: prompt 1, decodes early
        e.step().unwrap(); // A prefills its single row
        e.set_prefill_chunk(3);
        e.submit(Request::new(2, vec![1; 5], 2)); // B
        e.submit(Request::new(3, vec![2; 4], 2)); // C
        e.step().unwrap();
        // A decode (1 slot) + B rows min(5,3)=3 + C rows 0 (budget spent)
        assert_eq!(e.last_decode_slots, 1);
        assert_eq!(e.last_prefill_tokens, 3);
        assert_eq!(e.pool.seq_len(2), Some(3));
        assert_eq!(e.pool.seq_len(3), Some(0));
        e.step().unwrap();
        // A decode + B's last 2 rows + C gets the leftover 1
        assert_eq!(e.last_decode_slots, 1);
        assert_eq!(e.last_prefill_tokens, 3);
        assert_eq!(e.pool.seq_len(2), Some(5));
        assert_eq!(e.pool.seq_len(3), Some(1));
        e.run_to_completion(100).unwrap();
    }

    #[test]
    fn kv_rows_recorded_per_token() {
        let mut e = engine();
        e.submit(Request::new(9, vec![7], 2));
        e.run_to_completion(100).unwrap();
        // the engine freed the seq at finish; run again with longer gen to
        // inspect mid-flight state instead
        let mut e = engine();
        e.submit(Request::new(9, vec![7], 10));
        for _ in 0..3 {
            e.step().unwrap();
        }
        // 3 tokens appended: prompt 7 at pos 0, then generated at pos 1, 2
        assert_eq!(e.pool.seq_len(9), Some(3));
        let row = e.pool.peek(9, 0, 0, 0).unwrap();
        assert_eq!(row[0], 7.0); // token
        assert_eq!(row[1], 0.0); // pos
        let row = e.pool.peek(9, 2, 1, 1).unwrap();
        assert_eq!(row[1], 2.0); // pos 2, plane 1
        assert_eq!(row[2], 1.0);
    }

    #[test]
    fn batches_multiple_requests() {
        let mut e = engine();
        for id in 0..4 {
            e.submit(Request::new(id, vec![1, 2, 3], 4));
        }
        e.run_to_completion(200).unwrap();
        let finished: Vec<_> = e
            .take_events()
            .into_iter()
            .filter(|ev| matches!(ev, Event::Finished { .. }))
            .collect();
        assert_eq!(finished.len(), 4);
        // batching + one-shot prefill means far fewer steps than
        // sequential decode-as-prefill (4 * (3 + 4) = 28); expected 4
        assert!(e.steps <= 10, "steps = {}", e.steps);
        assert_eq!(e.tokens_out, 16);
    }

    #[test]
    fn eos_stops_generation() {
        let mut e = engine();
        let mut req = Request::new(1, vec![3, 5], 14);
        req.sampling.eos_token = Some(8); // second generated token (see above)
        e.submit(req);
        e.run_to_completion(100).unwrap();
        match e.take_events().last().unwrap() {
            Event::Finished { reason, generated, .. } => {
                assert_eq!(*reason, FinishReason::Eos);
                assert_eq!(generated.len(), 2);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn cache_capacity_finishes_request() {
        // max_seq 16; prompt 4 + gen budget 100 would be rejected at the
        // front door, so inject straight into the batcher to exercise the
        // in-flight backstop: the sequence finishes at the cache limit
        // instead of stalling there.
        let mut e = engine();
        e.batcher.submit(Request::new(1, vec![1, 1, 1, 1], 100), 0);
        e.run_to_completion(200).unwrap();
        match e.take_events().last().unwrap() {
            Event::Finished { reason, .. } => assert_eq!(*reason, FinishReason::CacheFull),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn too_long_request_is_rejected_at_submit() {
        // prompt 4 + gen 100 > max_seq 16: refused before any work, with
        // a Finished(Rejected) event and no timing recorded
        let mut e = engine();
        assert_eq!(
            e.submit(Request::new(1, vec![1, 1, 1, 1], 100)),
            SubmitOutcome::RejectedTooLong
        );
        assert!(e.idle(), "rejected request never enters the queue");
        assert_eq!(e.rejected_too_long, 1);
        assert_eq!(e.rejected(), 1);
        match e.take_events().as_slice() {
            [Event::Finished { id: 1, reason: FinishReason::Rejected, generated, .. }] => {
                assert!(generated.is_empty());
            }
            other => panic!("{other:?}"),
        }
        assert!(e.timings().is_empty());
        // the boundary case (== max_seq) is admitted
        assert!(e.submit(Request::new(2, vec![1, 1, 1, 1], 12)).is_queued());
        e.run_to_completion(100).unwrap();
    }

    #[test]
    fn slo_submit_rejects_when_projection_breaches_ttft() {
        use crate::loadgen::ServiceModel;
        let mut e = engine();
        e.set_prefill_chunk(4);
        let service =
            ServiceModel { step_base_us: 200, step_per_seq_us: 50, step_prefill_token_us: 50 };
        e.set_admission(AdmissionConfig { slo_ttft_us: 1_000, service, ..AdmissionConfig::off() });
        // empty engine, prompt 4, chunk 4, max_batch 4:
        // 1 step × step_us(3, 4) = 550 µs ≤ 1000 → queued
        assert!(e.submit(Request::new(1, vec![1; 4], 4)).is_queued());
        // backlog now 4 rows: 2 steps × 550 = 1100 > 1000 → rejected
        assert_eq!(e.submit(Request::new(2, vec![1; 4], 4)), SubmitOutcome::RejectedSlo);
        assert_eq!(e.rejected_slo, 1);
        // drain the backlog and the same request is welcome again
        e.run_to_completion(100).unwrap();
        assert!(e.submit(Request::new(3, vec![1; 4], 4)).is_queued());
        e.run_to_completion(100).unwrap();
        assert_eq!(e.timings().len(), 2, "rejected request left no timing");
    }

    #[test]
    fn tpot_slo_caps_decode_width() {
        use crate::loadgen::ServiceModel;
        let mut e = engine();
        e.set_prefill_chunk(4);
        let service =
            ServiceModel { step_base_us: 200, step_per_seq_us: 50, step_prefill_token_us: 50 };
        // step_us(d, 4) = 400 + 50·d caps at d = 2
        e.set_admission(AdmissionConfig { slo_tpot_us: 500, service, ..AdmissionConfig::off() });
        for id in 0..4 {
            e.submit(Request::new(id, vec![1, 2], 4));
        }
        e.step().unwrap();
        assert_eq!(e.last_batch, 2, "TPOT SLO holds the batch at 2 slots");
        e.run_to_completion(100).unwrap();
        assert_eq!(e.timings().len(), 4, "capped batch still drains the queue");
    }

    #[test]
    fn growth_gate_defers_small_dribbles() {
        let mut e = engine();
        e.set_admission(AdmissionConfig {
            waiting_served_ratio: 2.0,
            max_waiting_steps: 3,
            ..AdmissionConfig::off()
        });
        e.submit(Request::new(0, vec![1, 2], 8));
        e.step().unwrap(); // first admission: empty batch always grows
        assert_eq!(e.last_batch, 1);
        e.submit(Request::new(1, vec![1, 2], 4));
        e.step().unwrap(); // 1 waiting < 2.0 × 1 running: deferred
        assert_eq!(e.last_batch, 1);
        assert_eq!(e.growth_deferrals, 1);
        e.submit(Request::new(2, vec![1, 2], 4));
        e.step().unwrap(); // 2 waiting ≥ 2.0 × 1 running: admitted
        assert_eq!(e.last_batch, 3);
        e.run_to_completion(100).unwrap();
        assert_eq!(e.timings().len(), 3);
    }

    #[test]
    fn preemption_under_pool_pressure_everyone_finishes() {
        // tiny pool: 6 pages of 4 tokens = 24 slots; 4 requests of up to
        // 12 tokens each cannot all fit -> preemption must kick in and
        // everything must still complete.
        let mut e = Engine::new(MockBackend::tiny(), 6, 4, 0.3);
        for id in 0..4 {
            e.submit(Request::new(id, vec![2; 4], 8));
        }
        e.run_to_completion(500).unwrap();
        let finished = e
            .take_events()
            .iter()
            .filter(|ev| matches!(ev, Event::Finished { .. }))
            .count();
        assert_eq!(finished, 4);
        assert!(e.preemptions > 0, "expected cache-pressure preemptions");
        assert_eq!(e.pool.used_pages(), 0, "all pages returned");
    }

    #[test]
    fn timings_recorded() {
        let mut e = engine();
        e.submit(Request::new(1, vec![1, 2], 2));
        e.run_to_completion(100).unwrap();
        let t = e.timings();
        assert_eq!(t.len(), 1);
        assert!(t[0].ttft >= 0.0 && t[0].total >= t[0].ttft);
        assert!(t[0].queue <= t[0].ttft, "queue wait is part of TTFT");
        assert!(t[0].finished_us >= t[0].submitted_us);
        assert_eq!(t[0].prompt_len, 2);
        assert_eq!(t[0].generated, 2);
    }

    #[test]
    fn virtual_clock_timings_are_exact() {
        // On a virtual clock the engine's timing fields are fully
        // determined by when the driver advances time.
        let clock = VirtualClock::shared();
        let shared: SharedClock = clock.clone();
        let mut e = Engine::with_clock(MockBackend::tiny(), 64, 4, 1.0, shared);
        // prompt 2 + gen 3 -> 3 steps (the one-shot prefill step emits
        // the first token)
        e.submit(Request::new(1, vec![3, 5], 3));
        while !e.idle() {
            e.step().unwrap();
            clock.advance_us(1_000); // 1 ms per decode step
        }
        let t = e.timings()[0];
        assert_eq!(t.submitted_us, 0);
        // events are stamped at the *start* of the step that produced
        // them: the first token falls in step 1, which begins at t=0 —
        // prefill no longer costs one step per prompt token
        assert_eq!(t.ttft, 0.0);
        // tokens 2 and 3 arrive one step (1 ms) apart
        assert!((t.tpot - 1e-3).abs() < 1e-9, "{}", t.tpot);
        assert_eq!(t.finished_us, 2_000);
        assert!((t.total - 2e-3).abs() < 1e-9, "{}", t.total);
        assert_eq!(t.queue, 0.0);
    }

    #[test]
    fn queue_time_measured_on_virtual_clock() {
        let clock = Arc::new(VirtualClock::new());
        let shared: SharedClock = clock.clone();
        let mut e = Engine::with_clock(MockBackend::tiny(), 64, 4, 1.0, shared);
        clock.advance_us(500);
        e.submit(Request::new(1, vec![1], 1));
        clock.advance_us(2_500); // request waits 2.5 ms before first step
        e.run_to_completion(10).unwrap();
        let t = e.timings()[0];
        assert_eq!(t.submitted_us, 500);
        assert!((t.queue - 2.5e-3).abs() < 1e-9, "{}", t.queue);
    }

    #[test]
    fn past_deadline_is_rejected_at_submit() {
        let clock = VirtualClock::shared();
        let mut e = Engine::with_clock(MockBackend::tiny(), 64, 4, 1.0, clock.clone());
        clock.advance_us(5_000);
        // deadline 4000 < now 5000: refused with the distinct reason
        assert_eq!(
            e.submit(Request::new(1, vec![1, 2], 2).with_deadline_us(4_000)),
            SubmitOutcome::RejectedDeadline
        );
        assert_eq!((e.rejected_deadline, e.rejected()), (1, 1));
        match e.take_events().as_slice() {
            [Event::Finished { id: 1, reason: FinishReason::DeadlineExceeded, generated, .. }] => {
                assert!(generated.is_empty());
            }
            other => panic!("{other:?}"),
        }
        assert!(e.timings().is_empty(), "submit-time rejection records no timing");
        // a future deadline is admitted and (deadline never reached) fully served
        assert!(e.submit(Request::new(2, vec![1, 2], 2).with_deadline_us(1_000_000)).is_queued());
        e.run_to_completion(100).unwrap();
        assert_eq!(e.timings().len(), 1);
    }

    #[test]
    fn submit_rejects_when_projected_ttft_lands_past_the_deadline() {
        use crate::loadgen::ServiceModel;
        let clock = VirtualClock::shared();
        let mut e = Engine::with_clock(MockBackend::tiny(), 64, 4, 1.0, clock.clone());
        e.set_prefill_chunk(4);
        let service =
            ServiceModel { step_base_us: 200, step_per_seq_us: 50, step_prefill_token_us: 50 };
        e.set_admission(AdmissionConfig { service, ..AdmissionConfig::off() });
        // empty engine, prompt 4, chunk 4, max_batch 4: projection is
        // 1 step × step_us(3, 4) = 550 µs. Deadline at 500 µs is
        // provably unmeetable even though it hasn't passed yet.
        assert_eq!(
            e.submit(Request::new(1, vec![1; 4], 2).with_deadline_us(500)),
            SubmitOutcome::RejectedDeadline
        );
        // deadline at 600 µs clears the projection
        assert!(e.submit(Request::new(2, vec![1; 4], 2).with_deadline_us(600)).is_queued());
    }

    #[test]
    fn deadlines_expire_queued_and_running_requests_at_step_boundaries() {
        let clock = VirtualClock::shared();
        let mut e = Engine::with_clock(MockBackend::tiny(), 64, 4, 1.0, clock.clone());
        // two requests: one with a deadline mid-generation, one without
        assert!(e.submit(Request::new(1, vec![3, 5], 10).with_deadline_us(2_500)).is_queued());
        assert!(e.submit(Request::new(2, vec![3, 5], 4)).is_queued());
        e.step().unwrap(); // both admitted and prefilled at t=0
        clock.advance_us(1_000);
        e.step().unwrap(); // t=1000 < 2500: both still running
        assert_eq!(e.last_batch, 2);
        clock.advance_us(2_000);
        e.step().unwrap(); // boundary at t=3000 ≥ 2500: request 1 expires
        assert_eq!(e.deadline_expired, 1);
        assert_eq!(e.last_batch, 1, "survivor decodes alone");
        e.run_to_completion(100).unwrap();
        let expired = e.timings().iter().find(|t| t.id == 1).unwrap();
        assert!(expired.generated >= 1, "mid-flight expiry keeps generated tokens");
        assert_eq!(expired.finished_us, 3_000, "expired at the step boundary");
        let events = e.take_events();
        assert!(events.iter().any(|ev| matches!(
            ev,
            Event::Finished { id: 1, reason: FinishReason::DeadlineExceeded, .. }
        )));
        // queued-only expiry: deadline passes before first admission
        let clock = VirtualClock::shared();
        let mut e = Engine::with_clock(MockBackend::tiny(), 64, 4, 1.0, clock.clone());
        assert!(e.submit(Request::new(7, vec![1], 1).with_deadline_us(100)).is_queued());
        clock.advance_us(200);
        e.step().unwrap();
        assert_eq!(e.deadline_expired, 1);
        let t = e.timings()[0];
        assert_eq!((t.id, t.generated), (7, 0));
        assert!((t.queue - 2e-4).abs() < 1e-12, "expiry bills the full queue wait");
    }

    #[test]
    fn evacuate_returns_queued_and_running_with_carried_timestamps() {
        let clock = VirtualClock::shared();
        let mut e = Engine::with_clock(MockBackend::tiny(), 16, 4, 1.0, clock.clone());
        clock.advance_us(100);
        e.submit(Request::new(1, vec![1, 2], 4)); // will run
        e.step().unwrap();
        clock.advance_us(400);
        e.submit(Request::new(2, vec![1, 2], 4)); // waits at t=500
        clock.advance_us(500);
        let evac = e.evacuate();
        assert_eq!(evac.len(), 2);
        assert!(e.idle() && e.pool.used_pages() == 0, "evacuation frees everything");
        // sorted by (submitted_us, id): request 1 first
        assert_eq!(evac[0].req.id, 1);
        assert_eq!(evac[0].submitted_us, 100);
        assert_eq!(evac[0].queued_us, 0, "execution time is not queueing");
        assert_eq!(evac[1].req.id, 2);
        assert_eq!(evac[1].submitted_us, 500);
        assert_eq!(evac[1].queued_us, 500, "waiting entry bills its wait up to now");
        assert!(e.timings().is_empty(), "evacuation records no timings");
        // resubmit elsewhere: timestamps survive, generation restarts
        let mut e2 = Engine::with_clock(MockBackend::tiny(), 16, 4, 1.0, clock.clone());
        for ev in evac {
            e2.resubmit(ev.req, ev.submitted_us, ev.queued_us);
        }
        e2.run_to_completion(100).unwrap();
        let t1 = e2.timings().iter().find(|t| t.id == 1).unwrap();
        assert_eq!(t1.submitted_us, 100, "original submit time survives failover");
        assert_eq!(e2.timings().len(), 2);
    }

    #[test]
    fn temperature_sampling_stays_in_vocab() {
        let mut e = engine();
        let mut req = Request::new(1, vec![1], 15);
        req.sampling.temperature = 1.0;
        e.submit(req);
        e.run_to_completion(100).unwrap();
        for ev in e.take_events() {
            if let Event::Token { token, .. } | Event::FirstToken { token, .. } = ev {
                assert!((0..32).contains(&token));
            }
        }
    }
}
