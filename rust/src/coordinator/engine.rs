//! Decode engine: the per-step loop that turns admitted requests into
//! tokens. Generic over a [`Backend`] so the whole coordinator is testable
//! without PJRT (see [`MockBackend`]); the real backend lives in
//! `pjrt_backend.rs`.
//!
//! One `step()` = one fused decode step for the current continuous batch:
//! gather pages → execute the AOT executable → sample → append new KV rows
//! → emit events. Prefill is fed through the same decode path token by
//! token (decode-as-prefill; prompt logits are discarded until the last
//! prompt token).
//!
//! All request timing (queue wait, TTFT, TPOT, end-to-end) is measured on
//! a pluggable [`Clock`]: real runs use the wall clock, load tests inject
//! a deterministic virtual clock (`util::clock`, `loadgen`).

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::util::clock::{SharedClock, WallClock};
use crate::util::rng::Rng;

use super::batcher::Batcher;
use super::kv_cache::{CacheGeometry, KvPool, SeqId};
use super::request::{Event, FinishReason, Phase, Request, RequestId};
use super::scheduler::pick_victim;

/// Model geometry a backend exposes (mirrors the artifact manifest).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelGeom {
    pub vocab: usize,
    pub n_layers: usize,
    pub row_elems: usize,
    pub planes: usize,
    pub max_seq: usize,
}

impl ModelGeom {
    pub fn cache_geometry(&self) -> CacheGeometry {
        CacheGeometry {
            n_layers: self.n_layers,
            row_elems: self.row_elems,
            planes: self.planes,
            max_seq: self.max_seq,
        }
    }
}

/// Output of one backend step.
#[derive(Debug, Clone)]
pub struct StepOut {
    /// (bucket, vocab) row-major.
    pub logits: Vec<f32>,
    /// Per plane: (n_layers, bucket, row_elems) row-major new cache rows.
    pub new_rows: Vec<Vec<f32>>,
}

/// Something that can execute one fused decode step for a batch bucket.
pub trait Backend {
    fn geom(&self) -> ModelGeom;
    fn buckets(&self) -> Vec<usize>;
    fn step(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        cache_planes: &[Vec<f32>],
    ) -> Result<StepOut>;
}

#[derive(Debug)]
struct SeqState {
    req: Request,
    fed: usize,
    generated: Vec<i32>,
    phase: Phase,
    /// Clock µs of the original submission (survives preemption requeues).
    submitted_us: u64,
    /// Clock µs of (the latest) admission into the running set.
    admitted_us: u64,
    /// Total queue wait accumulated across all admission attempts, µs
    /// (time spent *running* before a preemption is not queueing).
    queue_us: u64,
    /// Clock µs of the first generated token, if any.
    first_us: Option<u64>,
}

impl SeqState {
    fn next_input(&self) -> i32 {
        if self.fed < self.req.prompt.len() {
            self.req.prompt[self.fed]
        } else {
            *self.generated.last().unwrap_or(&0)
        }
    }
}

/// Per-request timing summary for metrics. All timestamps are clock
/// microseconds; derived latencies are seconds.
#[derive(Debug, Clone, Copy)]
pub struct RequestTiming {
    pub id: RequestId,
    /// Clock µs at submission.
    pub submitted_us: u64,
    /// Clock µs at completion.
    pub finished_us: u64,
    /// Total time spent waiting for admission, seconds (accumulated
    /// across preemption requeues; excludes time spent executing).
    pub queue: f64,
    /// Submission → first generated token, seconds (includes queue time).
    pub ttft: f64,
    /// Mean time per generated token after the first, seconds
    /// (0 when fewer than two tokens were generated).
    pub tpot: f64,
    /// Submission → completion, seconds.
    pub total: f64,
    pub prompt_len: usize,
    pub generated: usize,
}

fn us_delta_secs(later: u64, earlier: u64) -> f64 {
    later.saturating_sub(earlier) as f64 * 1e-6
}

/// The decode engine.
pub struct Engine<B: Backend> {
    backend: B,
    pub pool: KvPool,
    pub batcher: Batcher,
    clock: SharedClock,
    seqs: HashMap<SeqId, SeqState>,
    /// persistent gather buffers per batch bucket (hot-path reuse; never
    /// zeroed — see KvPool::gather_batch_into)
    plane_bufs: HashMap<usize, Vec<Vec<f32>>>,
    events: Vec<Event>,
    timings: Vec<RequestTiming>,
    rng: Rng,
    /// decode steps executed (each = one fused kernel invocation batch).
    pub steps: u64,
    /// live sequences in the most recent executed step (0 if the last
    /// `step()` was a no-op) — what a service-time model should bill.
    pub last_batch: usize,
    /// tokens generated in total.
    pub tokens_out: u64,
    /// preemptions performed under cache pressure.
    pub preemptions: u64,
}

impl<B: Backend> Engine<B> {
    /// Engine on the wall clock (interactive / production path).
    pub fn new(backend: B, pool_pages: usize, page_tokens: usize, admit_fraction: f64) -> Self {
        Self::with_clock(backend, pool_pages, page_tokens, admit_fraction, WallClock::shared())
    }

    /// Engine on an explicit clock (load tests inject a `VirtualClock`).
    pub fn with_clock(
        backend: B,
        pool_pages: usize,
        page_tokens: usize,
        admit_fraction: f64,
        clock: SharedClock,
    ) -> Self {
        let geom = backend.geom().cache_geometry();
        let buckets = backend.buckets();
        Self {
            backend,
            pool: KvPool::new(geom, page_tokens, pool_pages),
            batcher: Batcher::new(buckets, admit_fraction),
            clock,
            seqs: HashMap::new(),
            plane_bufs: HashMap::new(),
            events: Vec::new(),
            timings: Vec::new(),
            rng: Rng::seed_from_u64(0xC1A5),
            steps: 0,
            last_batch: 0,
            tokens_out: 0,
            preemptions: 0,
        }
    }

    /// The engine's time source (shared with the load generator).
    pub fn clock(&self) -> SharedClock {
        self.clock.clone()
    }

    pub fn submit(&mut self, req: Request) {
        let now = self.clock.now_us();
        self.batcher.submit(req, now);
    }

    /// Drain accumulated events.
    pub fn take_events(&mut self) -> Vec<Event> {
        std::mem::take(&mut self.events)
    }

    pub fn timings(&self) -> &[RequestTiming] {
        &self.timings
    }

    pub fn idle(&self) -> bool {
        self.batcher.idle()
    }

    fn sample(&mut self, logits: &[f32], temperature: f32) -> i32 {
        if temperature <= 0.0 {
            return crate::runtime::argmax(logits) as i32;
        }
        // softmax sampling with temperature
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = logits.iter().map(|l| ((l - m) / temperature).exp()).collect();
        let sum: f32 = exps.iter().sum();
        let mut u = self.rng.f32() * sum;
        for (i, e) in exps.iter().enumerate() {
            u -= e;
            if u <= 0.0 {
                return i as i32;
            }
        }
        (logits.len() - 1) as i32
    }

    fn finish(&mut self, id: SeqId, reason: FinishReason) {
        if let Some(mut st) = self.seqs.remove(&id) {
            st.phase = Phase::Finished(reason);
            let now = self.clock.now_us();
            let generated = st.generated.len();
            let tpot = match (st.first_us, generated) {
                (Some(first), n) if n >= 2 => us_delta_secs(now, first) / (n - 1) as f64,
                _ => 0.0,
            };
            self.timings.push(RequestTiming {
                id,
                submitted_us: st.submitted_us,
                finished_us: now,
                queue: st.queue_us as f64 * 1e-6,
                ttft: st.first_us.map(|f| us_delta_secs(f, st.submitted_us)).unwrap_or_default(),
                tpot,
                total: us_delta_secs(now, st.submitted_us),
                prompt_len: st.req.prompt.len(),
                generated,
            });
            self.events.push(Event::Finished { id, reason, generated: st.generated.clone() });
        }
        self.pool.free_seq(id);
        self.batcher.release(id);
    }

    /// Preempt sequences until the pool can absorb the next step's
    /// appends: every running sequence sitting on a page boundary needs a
    /// fresh page *this* step, so that many pages must be free (vLLM-style
    /// recompute preemption: the youngest victim loses its pages and
    /// re-enters the queue from the front).
    fn relieve_pressure(&mut self) {
        // sequences at the hard context limit finish rather than preempt
        for id in self.batcher.running().to_vec() {
            if self.pool.seq_len(id).is_some_and(|l| l >= self.pool.geometry().max_seq) {
                self.finish(id, FinishReason::CacheFull);
            }
        }
        loop {
            let running = self.batcher.running().to_vec();
            let needed = running.iter().filter(|id| self.pool.needs_new_page(**id)).count();
            if self.pool.free_pages() >= needed {
                return;
            }
            if running.len() <= 1 {
                // nothing left to evict: the lone sequence can never get
                // more pages, so it finishes at its current length
                if let Some(&id) = running.first() {
                    self.finish(id, FinishReason::CacheFull);
                }
                return;
            }
            let victim = pick_victim(&running, |id| {
                self.seqs.get(&id).map(|s| s.admitted_us).unwrap_or(u64::MAX)
            });
            self.preemptions += 1;
            if let Some(st) = self.seqs.remove(&victim) {
                let now = self.clock.now_us();
                self.batcher.requeue_front(st.req, st.submitted_us, st.queue_us, now);
            }
            self.pool.free_seq(victim);
            self.batcher.release(victim);
        }
    }

    /// Run one engine iteration. Returns false when there was nothing to do.
    pub fn step(&mut self) -> Result<bool> {
        // 1. admission
        let now = self.clock.now_us();
        for entry in self.batcher.admit(&self.pool) {
            self.pool.alloc_seq(entry.req.id).context("alloc admitted seq")?;
            self.seqs.insert(
                entry.req.id,
                SeqState {
                    req: entry.req,
                    fed: 0,
                    generated: Vec::new(),
                    phase: Phase::Prefill,
                    submitted_us: entry.submitted_us,
                    admitted_us: now,
                    queue_us: entry.queued_us + now.saturating_sub(entry.enqueued_us),
                    first_us: None,
                },
            );
        }
        // 2. cache pressure
        self.relieve_pressure();
        let running = self.batcher.running().to_vec();
        if running.is_empty() {
            self.last_batch = 0;
            return Ok(false);
        }
        self.last_batch = running.len();
        let bucket = self
            .batcher
            .bucket_for(running.len())
            .context("running set exceeds largest bucket")?;

        // 3. build step inputs
        let mut tokens = vec![0i32; bucket];
        let mut pos = vec![0i32; bucket];
        for (i, id) in running.iter().enumerate() {
            let st = &self.seqs[id];
            tokens[i] = st.next_input();
            pos[i] = self.pool.seq_len(*id).unwrap_or(0) as i32;
        }
        let g0 = self.pool.geometry();
        let planes = self.plane_bufs.entry(bucket).or_insert_with(|| {
            vec![vec![0.0f32; g0.n_layers * bucket * g0.max_seq * g0.row_elems]; g0.planes]
        });
        self.pool.gather_batch_into(&running, bucket, planes)?;

        // 4. execute
        let out = self.backend.step(bucket, &tokens, &pos, planes)?;
        self.steps += 1;

        // 5. scatter results
        let g = self.backend.geom();
        let re = g.row_elems;
        for (i, id) in running.iter().enumerate() {
            // append this slot's new KV rows: plane layout (L, bucket, re)
            let rows: Vec<Vec<f32>> = out
                .new_rows
                .iter()
                .map(|plane| {
                    let mut row = Vec::with_capacity(g.n_layers * re);
                    for l in 0..g.n_layers {
                        let o = (l * bucket + i) * re;
                        row.extend_from_slice(&plane[o..o + re]);
                    }
                    row
                })
                .collect();
            let row_refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            self.pool.append(*id, &row_refs).context("append new KV rows")?;

            let logits_row = &out.logits[i * g.vocab..(i + 1) * g.vocab];
            let st = self.seqs.get_mut(id).expect("running seq has state");
            st.fed += 1;
            let prompt_done = st.fed >= st.req.prompt.len();
            if !prompt_done {
                continue; // still prefilling: discard logits
            }
            // sample the next token
            let temperature = st.req.sampling.temperature;
            let max_new = st.req.sampling.max_new_tokens;
            let eos = st.req.sampling.eos_token;
            let tok = {
                let st_phase_first = st.generated.is_empty();
                let t = self.sample(logits_row, temperature);
                let t_now = self.clock.now_us();
                let st = self.seqs.get_mut(id).unwrap();
                st.generated.push(t);
                if st_phase_first {
                    st.first_us = Some(t_now);
                    st.phase = Phase::Decode;
                    self.events.push(Event::FirstToken { id: *id, token: t });
                } else {
                    self.events.push(Event::Token { id: *id, token: t });
                }
                t
            };
            self.tokens_out += 1;
            let st = &self.seqs[id];
            let done_len = st.generated.len() >= max_new;
            let done_eos = eos == Some(tok);
            let done_cache = self.pool.seq_len(*id).unwrap_or(0) >= g.max_seq;
            if done_len {
                self.finish(*id, FinishReason::Length);
            } else if done_eos {
                self.finish(*id, FinishReason::Eos);
            } else if done_cache {
                self.finish(*id, FinishReason::CacheFull);
            }
        }
        Ok(true)
    }

    /// Drive until all submitted work completes (or `max_steps` safety cap).
    pub fn run_to_completion(&mut self, max_steps: u64) -> Result<()> {
        let mut steps = 0u64;
        while !self.idle() {
            let did = self.step()?;
            anyhow::ensure!(did || !self.idle(), "engine wedged");
            steps += 1;
            anyhow::ensure!(steps <= max_steps, "exceeded {max_steps} steps");
        }
        Ok(())
    }
}

/// Deterministic in-memory backend for coordinator tests: the "model"
/// echoes `(input_token + pos) % vocab` as the argmax and encodes
/// `(token, pos)` into the new KV rows so tests can verify appends.
pub struct MockBackend {
    pub geom: ModelGeom,
    pub buckets: Vec<usize>,
    pub steps: u64,
}

impl MockBackend {
    pub fn new(geom: ModelGeom, buckets: Vec<usize>) -> Self {
        Self { geom, buckets, steps: 0 }
    }

    pub fn tiny() -> Self {
        Self::new(
            ModelGeom { vocab: 32, n_layers: 2, row_elems: 4, planes: 2, max_seq: 16 },
            vec![1, 2, 4],
        )
    }
}

impl Backend for MockBackend {
    fn geom(&self) -> ModelGeom {
        self.geom
    }

    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn step(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        cache_planes: &[Vec<f32>],
    ) -> Result<StepOut> {
        anyhow::ensure!(tokens.len() == bucket && pos.len() == bucket);
        anyhow::ensure!(cache_planes.len() == self.geom.planes);
        let g = self.geom;
        for p in cache_planes {
            anyhow::ensure!(p.len() == g.n_layers * bucket * g.max_seq * g.row_elems);
        }
        self.steps += 1;
        let mut logits = vec![0.0f32; bucket * g.vocab];
        for i in 0..bucket {
            let t = ((tokens[i] + pos[i]) as usize) % g.vocab;
            logits[i * g.vocab + t] = 1.0;
        }
        let new_rows: Vec<Vec<f32>> = (0..g.planes)
            .map(|plane| {
                let mut rows = vec![0.0f32; g.n_layers * bucket * g.row_elems];
                for l in 0..g.n_layers {
                    for i in 0..bucket {
                        let o = (l * bucket + i) * g.row_elems;
                        rows[o] = tokens[i] as f32;
                        if g.row_elems > 1 {
                            rows[o + 1] = pos[i] as f32;
                        }
                        if g.row_elems > 2 {
                            rows[o + 2] = plane as f32;
                        }
                    }
                }
                rows
            })
            .collect();
        Ok(StepOut { logits, new_rows })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::{Clock, VirtualClock};
    use std::sync::Arc;

    fn engine() -> Engine<MockBackend> {
        Engine::new(MockBackend::tiny(), 64, 4, 1.0)
    }

    #[test]
    fn single_request_generates_expected_tokens() {
        let mut e = engine();
        e.submit(Request::new(1, vec![3, 5], 3));
        e.run_to_completion(100).unwrap();
        let events = e.take_events();
        // prefill feeds 3 then 5; logits after last prompt token: (5+1)%32=6
        // then (6+2)%32=8, then (8+3)%32=11
        let toks: Vec<i32> = events
            .iter()
            .filter_map(|ev| match ev {
                Event::FirstToken { token, .. } | Event::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        assert_eq!(toks, vec![6, 8, 11]);
        match events.last().unwrap() {
            Event::Finished { reason, generated, .. } => {
                assert_eq!(*reason, FinishReason::Length);
                assert_eq!(generated, &vec![6, 8, 11]);
            }
            other => panic!("expected Finished, got {other:?}"),
        }
        assert_eq!(e.tokens_out, 3);
        // prompt(2) + generated(3) steps, minus 1: the last prompt step
        // already yields the first generated token
        assert_eq!(e.steps, 4);
    }

    #[test]
    fn kv_rows_recorded_per_token() {
        let mut e = engine();
        e.submit(Request::new(9, vec![7], 2));
        e.run_to_completion(100).unwrap();
        // the engine freed the seq at finish; run again with longer gen to
        // inspect mid-flight state instead
        let mut e = engine();
        e.submit(Request::new(9, vec![7], 50));
        for _ in 0..3 {
            e.step().unwrap();
        }
        // 3 tokens appended: prompt 7 at pos 0, then generated at pos 1, 2
        assert_eq!(e.pool.seq_len(9), Some(3));
        let row = e.pool.peek(9, 0, 0, 0).unwrap();
        assert_eq!(row[0], 7.0); // token
        assert_eq!(row[1], 0.0); // pos
        let row = e.pool.peek(9, 2, 1, 1).unwrap();
        assert_eq!(row[1], 2.0); // pos 2, plane 1
        assert_eq!(row[2], 1.0);
    }

    #[test]
    fn batches_multiple_requests() {
        let mut e = engine();
        for id in 0..4 {
            e.submit(Request::new(id, vec![1, 2, 3], 4));
        }
        e.run_to_completion(200).unwrap();
        let finished: Vec<_> = e
            .take_events()
            .into_iter()
            .filter(|ev| matches!(ev, Event::Finished { .. }))
            .collect();
        assert_eq!(finished.len(), 4);
        // batching means far fewer steps than sequential: sequential would
        // be 4 * (3 + 4) = 28; batched should be ~7
        assert!(e.steps <= 10, "steps = {}", e.steps);
        assert_eq!(e.tokens_out, 16);
    }

    #[test]
    fn eos_stops_generation() {
        let mut e = engine();
        let mut req = Request::new(1, vec![3, 5], 100);
        req.sampling.eos_token = Some(8); // second generated token (see above)
        e.submit(req);
        e.run_to_completion(100).unwrap();
        match e.take_events().last().unwrap() {
            Event::Finished { reason, generated, .. } => {
                assert_eq!(*reason, FinishReason::Eos);
                assert_eq!(generated.len(), 2);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn cache_capacity_finishes_request() {
        // max_seq 16; prompt 4 + gen budget 100 -> finishes at cache limit
        let mut e = engine();
        e.submit(Request::new(1, vec![1, 1, 1, 1], 100));
        e.run_to_completion(200).unwrap();
        match e.take_events().last().unwrap() {
            Event::Finished { reason, .. } => assert_eq!(*reason, FinishReason::CacheFull),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn preemption_under_pool_pressure_everyone_finishes() {
        // tiny pool: 6 pages of 4 tokens = 24 slots; 4 requests of up to
        // 12 tokens each cannot all fit -> preemption must kick in and
        // everything must still complete.
        let mut e = Engine::new(MockBackend::tiny(), 6, 4, 0.3);
        for id in 0..4 {
            e.submit(Request::new(id, vec![2; 4], 8));
        }
        e.run_to_completion(500).unwrap();
        let finished = e
            .take_events()
            .iter()
            .filter(|ev| matches!(ev, Event::Finished { .. }))
            .count();
        assert_eq!(finished, 4);
        assert!(e.preemptions > 0, "expected cache-pressure preemptions");
        assert_eq!(e.pool.used_pages(), 0, "all pages returned");
    }

    #[test]
    fn timings_recorded() {
        let mut e = engine();
        e.submit(Request::new(1, vec![1, 2], 2));
        e.run_to_completion(100).unwrap();
        let t = e.timings();
        assert_eq!(t.len(), 1);
        assert!(t[0].ttft >= 0.0 && t[0].total >= t[0].ttft);
        assert!(t[0].queue <= t[0].ttft, "queue wait is part of TTFT");
        assert!(t[0].finished_us >= t[0].submitted_us);
        assert_eq!(t[0].prompt_len, 2);
        assert_eq!(t[0].generated, 2);
    }

    #[test]
    fn virtual_clock_timings_are_exact() {
        // On a virtual clock the engine's timing fields are fully
        // determined by when the driver advances time.
        let clock = VirtualClock::shared();
        let shared: SharedClock = clock.clone();
        let mut e = Engine::with_clock(MockBackend::tiny(), 64, 4, 1.0, shared);
        // prompt 2 + gen 3 -> 4 steps (last prompt step emits first token)
        e.submit(Request::new(1, vec![3, 5], 3));
        while !e.idle() {
            e.step().unwrap();
            clock.advance_us(1_000); // 1 ms per decode step
        }
        let t = e.timings()[0];
        assert_eq!(t.submitted_us, 0);
        // events are stamped at the *start* of the step that produced
        // them: the first token falls in step 2, which begins at 1 ms
        assert!((t.ttft - 1e-3).abs() < 1e-9, "{}", t.ttft);
        // tokens 2 and 3 arrive one step (1 ms) apart
        assert!((t.tpot - 1e-3).abs() < 1e-9, "{}", t.tpot);
        assert_eq!(t.finished_us, 3_000);
        assert!((t.total - 3e-3).abs() < 1e-9, "{}", t.total);
        assert_eq!(t.queue, 0.0);
    }

    #[test]
    fn queue_time_measured_on_virtual_clock() {
        let clock = Arc::new(VirtualClock::new());
        let shared: SharedClock = clock.clone();
        let mut e = Engine::with_clock(MockBackend::tiny(), 64, 4, 1.0, shared);
        clock.advance_us(500);
        e.submit(Request::new(1, vec![1], 1));
        clock.advance_us(2_500); // request waits 2.5 ms before first step
        e.run_to_completion(10).unwrap();
        let t = e.timings()[0];
        assert_eq!(t.submitted_us, 500);
        assert!((t.queue - 2.5e-3).abs() < 1e-9, "{}", t.queue);
    }

    #[test]
    fn temperature_sampling_stays_in_vocab() {
        let mut e = engine();
        let mut req = Request::new(1, vec![1], 20);
        req.sampling.temperature = 1.0;
        e.submit(req);
        e.run_to_completion(100).unwrap();
        for ev in e.take_events() {
            if let Event::Token { token, .. } | Event::FirstToken { token, .. } = ev {
                assert!((0..32).contains(&token));
            }
        }
    }
}
