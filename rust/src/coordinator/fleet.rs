//! Replicated serving fleet: N engines behind the [`Router`], with
//! deterministic fault injection, health-gated routing, bounded failover,
//! and per-request deadlines.
//!
//! Two drivers share the policy layer, mirroring the `loadgen` split:
//!
//! * [`Fleet`] — a discrete-event simulation that drives every replica
//!   *inline* on **one shared [`VirtualClock`]**. The DESIGN.md §4 rule —
//!   a virtual-clock run has exactly one writer of time — forbids one
//!   thread per replica here, so replicas are simulated with per-replica
//!   `busy_until` watermarks instead: the event loop always advances to
//!   the globally earliest event (arrival, retry, crash, stall detection,
//!   or a replica becoming ready), which makes a multi-replica run with
//!   an active [`FaultPlan`] byte-deterministic (`integration_fleet`).
//!   At one replica with no faults, the loop reduces *exactly* to
//!   `loadgen::replay` — same submission stamps, same step boundaries,
//!   same service billing, same wedge rule — so the robustness layer is
//!   provably inert when off.
//! * [`FleetServer`] — the threaded deployment shape: one
//!   [`Server`] (engine thread) per replica behind a mutexed [`Router`],
//!   on the wall clock. A dead engine thread is detected at submit,
//!   marked [`ReplicaHealth::Unhealthy`], and the request is re-routed
//!   with the same bounded-retry policy; exhaustion surfaces as a
//!   terminal [`FinishReason::Failed`] event rather than a hang.
//!
//! Fault model ([`FaultPlan`], decided entirely from virtual timestamps —
//! never the wall clock — so replay stays byte-stable):
//!
//! * `Stall {replica, from_us, dur_us}` — the replica freezes: no steps,
//!   no mailbox delivery, for the window. Step-progress watermarks detect
//!   it after `stall_threshold_us` without progress and the
//!   [`StallPolicy`] decides: **Failover** evacuates inflight work and
//!   re-routes it; **Drain** stops new admissions but lets the replica
//!   finish inflight work when it wakes. Either way the replica Recovers
//!   (becomes routable) once it is idle and the stall window has passed.
//! * `Crash {replica, at_us}` — the replica dies permanently; inflight
//!   and mailbox work is evacuated and failed over.
//! * `SlowStep {replica, factor}` — every step on the replica is billed
//!   at `factor ×` the [`ServiceModel`] cost (degraded, not dead).
//!
//! Failover uses recompute semantics, exactly like preemption but across
//! replicas ([`Engine::evacuate`] / [`Engine::resubmit`]): prefill
//! progress and generated tokens are discarded, the original submission
//! time and accumulated queue wait ride along, and after `max_retries`
//! failovers the request is counted [`FinishReason::Failed`] — never
//! silently lost (`completed + failed + rejected == routed`, asserted by
//! `integration_fleet`).

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::loadgen::{percentiles, ReplayReport, ServiceModel};
use crate::metrics::{CountHistogram, PercentileReport};
use crate::obs::{Obs, LATENCY_MS_BUCKETS, TRACK_FLEET};
use crate::util::clock::{Clock, SharedClock, VirtualClock};
use crate::util::rng::Rng;

use super::engine::{Backend, Engine, Evacuated, RequestTiming};
use super::request::{Event, FinishReason, Request, RequestId};
use super::router::{ReplicaHealth, Router, RouterStats};
use super::server::{Server, ServerReport};

/// One injected fault. All times are virtual-clock microseconds (same
/// origin as `Request::arrival_us`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// The replica freezes for `[from_us, from_us + dur_us)`: no steps
    /// execute and no mailbox delivery happens inside the window.
    Stall { replica: usize, from_us: u64, dur_us: u64 },
    /// The replica dies permanently at `at_us`.
    Crash { replica: usize, at_us: u64 },
    /// Every step on the replica costs `factor ×` the service model.
    SlowStep { replica: usize, factor: f64 },
}

/// A deterministic schedule of faults. Parsed from the CLI/config spec
/// format (`"stall:0@40000+30000;crash:1@80000;slow:2@1.50"`), generated
/// from a seed ([`FaultPlan::seeded`]), or built directly.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    /// No faults: the fleet behaves as a plain replicated deployment.
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Earliest crash scheduled for `replica`, if any.
    pub fn crash_at(&self, replica: usize) -> Option<u64> {
        self.faults
            .iter()
            .filter_map(|f| match f {
                Fault::Crash { replica: r, at_us } if *r == replica => Some(*at_us),
                _ => None,
            })
            .min()
    }

    /// The stall window covering `t_us` on `replica`, as
    /// `(from_us, end_us)` with `end_us` exclusive.
    pub fn stall_covering(&self, replica: usize, t_us: u64) -> Option<(u64, u64)> {
        self.faults
            .iter()
            .filter_map(|f| match f {
                Fault::Stall { replica: r, from_us, dur_us }
                    if *r == replica && *from_us <= t_us && t_us < from_us + dur_us =>
                {
                    Some((*from_us, from_us + dur_us))
                }
                _ => None,
            })
            .min()
    }

    /// Combined slow-step factor for `replica` (product; 1.0 = nominal).
    pub fn slow_factor(&self, replica: usize) -> f64 {
        self.faults
            .iter()
            .filter_map(|f| match f {
                Fault::SlowStep { replica: r, factor } if *r == replica => Some(*factor),
                _ => None,
            })
            .product()
    }

    /// Largest replica index any fault names (plans are validated against
    /// the actual replica count at fleet build).
    pub fn max_replica(&self) -> Option<usize> {
        self.faults
            .iter()
            .map(|f| match f {
                Fault::Stall { replica, .. }
                | Fault::Crash { replica, .. }
                | Fault::SlowStep { replica, .. } => *replica,
            })
            .max()
    }

    /// Parse the semicolon-separated spec format:
    /// `stall:<replica>@<from_us>+<dur_us>`, `crash:<replica>@<at_us>`,
    /// `slow:<replica>@<factor>`. Whitespace around parts is ignored;
    /// an empty spec is an empty plan.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut faults = Vec::new();
        for part in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            let (kind, rest) =
                part.split_once(':').with_context(|| format!("fault '{part}': want kind:args"))?;
            let (replica, arg) = rest
                .split_once('@')
                .with_context(|| format!("fault '{part}': want {kind}:<replica>@..."))?;
            let replica: usize =
                replica.trim().parse().with_context(|| format!("fault '{part}': replica"))?;
            let arg = arg.trim();
            match kind.trim() {
                "stall" => {
                    let (from, dur) = arg.split_once('+').with_context(|| {
                        format!("fault '{part}': want stall:<replica>@<from_us>+<dur_us>")
                    })?;
                    let from_us: u64 =
                        from.trim().parse().with_context(|| format!("fault '{part}': from_us"))?;
                    let dur_us: u64 =
                        dur.trim().parse().with_context(|| format!("fault '{part}': dur_us"))?;
                    anyhow::ensure!(dur_us > 0, "fault '{part}': zero-length stall");
                    faults.push(Fault::Stall { replica, from_us, dur_us });
                }
                "crash" => {
                    let at_us: u64 =
                        arg.parse().with_context(|| format!("fault '{part}': at_us"))?;
                    faults.push(Fault::Crash { replica, at_us });
                }
                "slow" => {
                    let factor: f64 =
                        arg.parse().with_context(|| format!("fault '{part}': factor"))?;
                    anyhow::ensure!(
                        factor.is_finite() && factor > 0.0,
                        "fault '{part}': factor must be finite and > 0"
                    );
                    faults.push(Fault::SlowStep { replica, factor });
                }
                other => bail!("unknown fault kind '{other}' (stall | crash | slow)"),
            }
        }
        Ok(Self { faults })
    }

    /// Canonical spec render (round-trips through [`FaultPlan::parse`];
    /// slow factors are canonicalised to two decimals).
    pub fn render(&self) -> String {
        self.faults
            .iter()
            .map(|f| match f {
                Fault::Stall { replica, from_us, dur_us } => {
                    format!("stall:{replica}@{from_us}+{dur_us}")
                }
                Fault::Crash { replica, at_us } => format!("crash:{replica}@{at_us}"),
                Fault::SlowStep { replica, factor } => format!("slow:{replica}@{factor:.2}"),
            })
            .collect::<Vec<_>>()
            .join(";")
    }

    /// Seeded random plan over `replicas` replicas and a trace of roughly
    /// `span_us` microseconds: each replica independently draws nothing,
    /// a stall, a crash, or a slow-down (uniform kinds). Deterministic in
    /// the seed, and slow factors are drawn at two decimals so the plan
    /// round-trips through `render`/`parse`.
    pub fn seeded(seed: u64, replicas: usize, span_us: u64) -> Self {
        let span = span_us.max(8) as usize;
        let mut rng = Rng::seed_from_u64(seed);
        let mut faults = Vec::new();
        for replica in 0..replicas {
            match rng.below(4) {
                0 => {}
                1 => {
                    let from_us = rng.below(span / 2) as u64;
                    let dur_us = (span / 8 + rng.below(span / 4)) as u64;
                    faults.push(Fault::Stall { replica, from_us, dur_us });
                }
                2 => faults.push(Fault::Crash { replica, at_us: rng.below(span) as u64 }),
                _ => faults.push(Fault::SlowStep {
                    replica,
                    factor: 1.0 + rng.below(151) as f64 / 100.0,
                }),
            }
        }
        Self { faults }
    }
}

/// What stall detection does with a replica that stopped making progress.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StallPolicy {
    /// Mark Unhealthy, evacuate inflight + mailbox work, re-route it.
    #[default]
    Failover,
    /// Mark Draining: admit nothing new, keep inflight work (it resumes
    /// when the stall ends).
    Drain,
}

impl StallPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "failover" => Ok(Self::Failover),
            "drain" => Ok(Self::Drain),
            other => bail!("unknown stall policy '{other}' (failover | drain)"),
        }
    }
}

/// Fleet policy knobs. The defaults run a plain replicated deployment:
/// no stall detection (`stall_threshold_us = 0`), two failover retries,
/// immediate retry, unbounded token budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetOptions {
    /// Mark a replica Unhealthy after this long without step progress
    /// while work is stuck on it, µs. 0 = detection off (crashes still
    /// fail over — only *stall* detection is gated).
    pub stall_threshold_us: u64,
    /// Failovers a request may consume before it is counted
    /// [`FinishReason::Failed`].
    pub max_retries: u32,
    /// Delay between evacuation and the re-route attempt, µs.
    pub retry_backoff_us: u64,
    pub stall_policy: StallPolicy,
    /// Router queue bound per replica (routed-but-undelivered backlog).
    pub max_queue_per_replica: usize,
    /// Router token budget per replica (0 = unbounded).
    pub max_tokens_per_replica: usize,
}

impl Default for FleetOptions {
    fn default() -> Self {
        Self {
            stall_threshold_us: 0,
            max_retries: 2,
            retry_backoff_us: 0,
            stall_policy: StallPolicy::Failover,
            max_queue_per_replica: 1024,
            max_tokens_per_replica: 0,
        }
    }
}

/// A routed request in flight to a replica. `route_us` is when the router
/// accepted it; `carried` holds `(submitted_us, queued_us)` for failover
/// retries (recompute semantics — see [`Engine::resubmit`]).
#[derive(Debug, Clone)]
struct Inbound {
    req: Request,
    route_us: u64,
    carried: Option<(u64, u64)>,
}

/// A failed-over request waiting for its re-route attempt.
#[derive(Debug, Clone)]
struct RetryEntry {
    due_us: u64,
    /// Tie-break so same-instant retries fire in scheduling order.
    seq: u64,
    req: Request,
    submitted_us: u64,
    queued_us: u64,
    /// When the request was evacuated: the wait until the successful
    /// re-route is billed as queue time.
    evac_us: u64,
    /// Replica the request was evacuated from (trace `pid` for the
    /// retry/failed instants of this request).
    from: usize,
}

/// Per-replica simulation state.
struct Replica<B: Backend> {
    engine: Engine<B>,
    /// Routed but not yet delivered (the engine observes a submission at
    /// its next step boundary — the same mailbox-drain semantics the
    /// threaded server has, and exactly `loadgen::replay`'s behaviour).
    mailbox: VecDeque<Inbound>,
    /// The replica is mid-step (or mid-stall) until this virtual time.
    busy_until_us: u64,
    /// End of the last executed step: the step-progress watermark stall
    /// detection compares against.
    last_progress_us: u64,
    /// Pending stall-detection check, if one is scheduled.
    detection_at: Option<u64>,
    crashed: bool,
    first_submit_us: Option<u64>,
    last_submit_us: u64,
}

impl<B: Backend> Replica<B> {
    fn new(engine: Engine<B>) -> Self {
        Self {
            engine,
            mailbox: VecDeque::new(),
            busy_until_us: 0,
            last_progress_us: 0,
            detection_at: None,
            crashed: false,
            first_submit_us: None,
            last_submit_us: 0,
        }
    }
}

/// Outcome of one [`Fleet::replay`] run: per-replica [`ReplayReport`]s,
/// the aggregate latency percentiles, and the robustness counters.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub replicas: Vec<ReplayReport>,
    /// Percentiles over every completed request fleet-wide.
    pub aggregate: PercentileReport,
    /// Successful routes, including failover re-routes.
    pub routed: u64,
    /// Fresh arrivals the router refused (back-pressure, not loss).
    pub router_rejected: u64,
    /// Failover retries scheduled.
    pub retries: u64,
    /// Requests pulled off crashed/stalled replicas.
    pub evacuated: u64,
    /// Requests that exhausted `max_retries`, with their failover count
    /// — the only way admitted work leaves without completing. Sorted by
    /// request id.
    pub failed: Vec<(RequestId, u32)>,
    /// Requests that expired at a step boundary (queued or running),
    /// fleet-wide (`FinishReason::DeadlineExceeded`; submit-time deadline
    /// rejections count in each replica's `rejected` instead).
    pub deadline_expired: u64,
    /// Replicas that crashed, in crash order.
    pub crashed: Vec<usize>,
    /// Healthy → Unhealthy/Draining transitions from stall detection.
    pub unhealthy_transitions: u64,
    /// Unhealthy/Draining → Healthy recoveries.
    pub recovered: u64,
    /// Failover counts per failed-over request (requests never evacuated
    /// do not appear).
    pub retry_attempts: CountHistogram,
    /// Router lifecycle counters (spurious_* must be 0 — asserted by
    /// `integration_fleet`).
    pub router_stats: RouterStats,
}

impl FleetReport {
    pub fn completed(&self) -> usize {
        self.replicas.iter().map(|r| r.completed).sum()
    }

    pub fn rejected(&self) -> u64 {
        self.replicas.iter().map(|r| r.rejected).sum()
    }

    pub fn steps(&self) -> u64 {
        self.replicas.iter().map(|r| r.steps).sum()
    }

    pub fn tokens_out(&self) -> u64 {
        self.replicas.iter().map(|r| r.tokens_out).sum()
    }

    /// Fixed-format render: one fleet counter line, the retry histogram,
    /// per-replica [`ReplayReport::render`] sections, and the aggregate
    /// percentiles. Byte-identical across identically-seeded runs
    /// (`integration_fleet` compares renders directly).
    pub fn render(&self) -> String {
        let mut out = format!(
            "fleet replicas={} routed={} router_rejected={} retries={} evacuated={} \
             failed={} deadline_expired={} unhealthy_transitions={} recovered={} crashed={:?}\n\
             retry_attempts: {}\n",
            self.replicas.len(),
            self.routed,
            self.router_rejected,
            self.retries,
            self.evacuated,
            self.failed.len(),
            self.deadline_expired,
            self.unhealthy_transitions,
            self.recovered,
            self.crashed,
            self.retry_attempts.render(),
        );
        if !self.failed.is_empty() {
            out.push_str(&format!("failed_ids: {:?}\n", self.failed));
        }
        for (i, r) in self.replicas.iter().enumerate() {
            out.push_str(&format!("-- replica {i} --\n{}", r.render()));
        }
        out.push_str(&format!(
            "-- aggregate --\ncompleted={} rejected={} steps={} tokens={}\n{}",
            self.completed(),
            self.rejected(),
            self.steps(),
            self.tokens_out(),
            self.aggregate.render()
        ));
        out
    }
}

/// The deterministic replicated fleet: N inline engines on one shared
/// virtual clock, a [`Router`] front door, and a [`FaultPlan`].
pub struct Fleet<B: Backend> {
    clock: Arc<VirtualClock>,
    replicas: Vec<Replica<B>>,
    router: Router,
    plan: FaultPlan,
    opts: FleetOptions,
    obs: Option<Obs>,
}

impl<B: Backend> Fleet<B> {
    /// Build `replicas` engines via `make`, every one on **the same**
    /// fresh virtual clock (the single-writer rule): `make` must
    /// construct each engine with `Engine::with_clock(..., clock)` using
    /// the handle it is given.
    pub fn build(
        replicas: usize,
        plan: FaultPlan,
        opts: FleetOptions,
        mut make: impl FnMut(SharedClock) -> Engine<B>,
    ) -> Self {
        assert!(replicas > 0, "need at least one replica");
        if let Some(max) = plan.max_replica() {
            assert!(max < replicas, "fault plan names replica {max}, fleet has {replicas}");
        }
        let clock = VirtualClock::shared();
        let reps = (0..replicas)
            .map(|_| {
                let handle: SharedClock = clock.clone();
                Replica::new(make(handle))
            })
            .collect();
        let router = Router::new(replicas, opts.max_queue_per_replica)
            .with_token_budget(opts.max_tokens_per_replica);
        Self { clock, replicas: reps, router, plan, opts, obs: None }
    }

    /// The fleet's shared time source.
    pub fn clock(&self) -> SharedClock {
        self.clock.clone()
    }

    /// Attach one shared trace sink: every replica engine emits into it
    /// with its replica index as the Chrome `pid`, and the fleet event
    /// loop adds crash/detect/evacuate/retry/recover instants plus step
    /// spans. Counter increments are co-located with the instants, so
    /// trace event counts and `FleetReport` fields agree by construction.
    pub fn set_obs(&mut self, obs: Obs) {
        for (i, r) in self.replicas.iter_mut().enumerate() {
            r.engine.set_obs(obs.clone(), i);
        }
        self.obs = Some(obs);
    }

    /// The attached sink, if any.
    pub fn obs(&self) -> Option<Obs> {
        self.obs.clone()
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Replay `requests` (arrival-sorted) open-loop through the router
    /// into the replicas, executing the fault plan. One replay per fleet
    /// (reports read absolute engine counters). `max_steps` bounds the
    /// fleet-wide executed step count.
    ///
    /// Event-loop invariant: the globally earliest pending event fires
    /// next; ties break crash < detect < arrival < retry < replica-ready
    /// (by replica index), so the schedule — and therefore every
    /// timestamp — is a pure function of inputs.
    pub fn replay(
        &mut self,
        requests: &[Request],
        service: &ServiceModel,
        max_steps: u64,
    ) -> Result<FleetReport> {
        debug_assert!(
            requests.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us),
            "fleet replay requires arrival-sorted requests"
        );
        let Fleet { clock, replicas, router, plan, opts, obs } = self;
        let obs = obs.as_ref();
        let n = replicas.len();
        let mut crash_pending: Vec<Option<u64>> = (0..n).map(|i| plan.crash_at(i)).collect();
        let mut next = 0usize;
        let mut retries: Vec<RetryEntry> = Vec::new();
        let mut retry_seq = 0u64;
        let mut attempts: HashMap<RequestId, u32> = HashMap::new();
        let mut failed: Vec<(RequestId, u32)> = Vec::new();
        let mut crashed_list: Vec<usize> = Vec::new();
        let mut fleet_steps = 0u64;
        let (mut routed, mut router_rejected) = (0u64, 0u64);
        let (mut retries_total, mut evacuated) = (0u64, 0u64);
        let (mut unhealthy_transitions, mut recovered) = (0u64, 0u64);

        // event classes, in tie-break priority order at equal times
        const CRASH: u8 = 0;
        const DETECT: u8 = 1;
        const ARRIVAL: u8 = 2;
        const RETRY: u8 = 3;
        const READY: u8 = 4;
        fn consider(best: &mut Option<(u64, u8, usize)>, t: u64, class: u8, sub: usize) {
            let cand = (t, class, sub);
            if best.map_or(true, |b| cand < b) {
                *best = Some(cand);
            }
        }

        loop {
            // Work pending? Crash/detect events alone keep nothing alive:
            // a fault scheduled after the work ends never fires.
            let has_ready = replicas
                .iter()
                .any(|r| !r.crashed && (!r.engine.idle() || !r.mailbox.is_empty()));
            if next >= requests.len() && retries.is_empty() && !has_ready {
                break;
            }

            let mut best: Option<(u64, u8, usize)> = None;
            for (i, r) in replicas.iter().enumerate() {
                if r.crashed {
                    continue;
                }
                if let Some(at) = crash_pending[i] {
                    consider(&mut best, at, CRASH, i);
                }
                if let Some(at) = r.detection_at {
                    consider(&mut best, at, DETECT, i);
                }
                let ready = if !r.engine.idle() {
                    Some(r.busy_until_us)
                } else {
                    r.mailbox.front().map(|inb| r.busy_until_us.max(inb.route_us))
                };
                if let Some(at) = ready {
                    consider(&mut best, at, READY, i);
                }
            }
            if let Some(req) = requests.get(next) {
                consider(&mut best, req.arrival_us, ARRIVAL, 0);
            }
            if let Some((idx, e)) =
                retries.iter().enumerate().min_by_key(|(_, e)| (e.due_us, e.seq))
            {
                consider(&mut best, e.due_us, RETRY, idx);
            }
            let Some((t, class, sub)) = best else { break };
            clock.sleep_until_us(t);

            match class {
                CRASH => {
                    crash_pending[sub] = None;
                    let r = &mut replicas[sub];
                    r.crashed = true;
                    r.detection_at = None;
                    router.set_health(sub, ReplicaHealth::Unhealthy);
                    crashed_list.push(sub);
                    if let Some(o) = obs {
                        o.instant(
                            "fleet",
                            "crash",
                            t,
                            sub as u64,
                            TRACK_FLEET,
                            vec![("replica", sub.to_string())],
                        );
                        o.counter_add("fleet_crashes_total", 1);
                    }
                    for e in evacuate_replica(r, t) {
                        evacuated += 1;
                        router.on_failed(e.req.id);
                        if let Some(o) = obs {
                            o.instant(
                                "fleet",
                                "evacuate",
                                t,
                                sub as u64,
                                TRACK_FLEET,
                                vec![("id", e.req.id.to_string())],
                            );
                            o.counter_add("fleet_evacuated_total", 1);
                        }
                        fail_over(
                            e,
                            t,
                            opts,
                            &mut attempts,
                            &mut retries,
                            &mut retry_seq,
                            &mut failed,
                            &mut retries_total,
                            obs,
                            sub,
                        );
                    }
                }
                DETECT => {
                    replicas[sub].detection_at = None;
                    unhealthy_transitions += 1;
                    if let Some(o) = obs {
                        o.instant(
                            "fleet",
                            "detect",
                            t,
                            sub as u64,
                            TRACK_FLEET,
                            vec![("replica", sub.to_string())],
                        );
                        o.counter_add("fleet_unhealthy_transitions_total", 1);
                    }
                    match opts.stall_policy {
                        StallPolicy::Drain => router.set_health(sub, ReplicaHealth::Draining),
                        StallPolicy::Failover => {
                            router.set_health(sub, ReplicaHealth::Unhealthy);
                            for e in evacuate_replica(&mut replicas[sub], t) {
                                evacuated += 1;
                                router.on_failed(e.req.id);
                                if let Some(o) = obs {
                                    o.instant(
                                        "fleet",
                                        "evacuate",
                                        t,
                                        sub as u64,
                                        TRACK_FLEET,
                                        vec![("id", e.req.id.to_string())],
                                    );
                                    o.counter_add("fleet_evacuated_total", 1);
                                }
                                fail_over(
                                    e,
                                    t,
                                    opts,
                                    &mut attempts,
                                    &mut retries,
                                    &mut retry_seq,
                                    &mut failed,
                                    &mut retries_total,
                                    obs,
                                    sub,
                                );
                            }
                        }
                    }
                }
                ARRIVAL => {
                    probe_recovery(router, replicas, plan, t, &mut recovered, obs);
                    let req = requests[next].clone();
                    next += 1;
                    match router.route(&req) {
                        Ok(route) => {
                            routed += 1;
                            replicas[route.replica]
                                .mailbox
                                .push_back(Inbound { req, route_us: t, carried: None });
                        }
                        // back-pressure on a fresh arrival is a
                        // rejection, not a loss
                        Err(_) => router_rejected += 1,
                    }
                }
                RETRY => {
                    probe_recovery(router, replicas, plan, t, &mut recovered, obs);
                    let entry = retries.swap_remove(sub);
                    match router.route(&entry.req) {
                        Ok(route) => {
                            routed += 1;
                            let queued = entry.queued_us + t.saturating_sub(entry.evac_us);
                            replicas[route.replica].mailbox.push_back(Inbound {
                                route_us: t,
                                carried: Some((entry.submitted_us, queued)),
                                req: entry.req,
                            });
                        }
                        Err(_) => {
                            // no eligible replica right now: consume an
                            // attempt and back off (floored so a zero
                            // backoff cannot spin at one instant)
                            let a = attempts.entry(entry.req.id).or_insert(0);
                            *a += 1;
                            if *a > opts.max_retries {
                                failed.push((entry.req.id, *a));
                                if let Some(o) = obs {
                                    o.instant(
                                        "fleet",
                                        "failed",
                                        t,
                                        entry.from as u64,
                                        TRACK_FLEET,
                                        vec![("id", entry.req.id.to_string())],
                                    );
                                    o.counter_add("fleet_failed_total", 1);
                                }
                            } else {
                                retries_total += 1;
                                retry_seq += 1;
                                if let Some(o) = obs {
                                    o.instant(
                                        "fleet",
                                        "retry",
                                        t,
                                        entry.from as u64,
                                        TRACK_FLEET,
                                        vec![("id", entry.req.id.to_string())],
                                    );
                                    o.counter_add("fleet_retries_total", 1);
                                }
                                retries.push(RetryEntry {
                                    due_us: t + opts.retry_backoff_us.max(1_000),
                                    seq: retry_seq,
                                    ..entry
                                });
                            }
                        }
                    }
                }
                READY => {
                    let i = sub;
                    if let Some((_, end)) = plan.stall_covering(i, t) {
                        // frozen: no delivery, no step; wake at stall end
                        // and schedule the watermark check if progress
                        // will have been absent long enough before then
                        let r = &mut replicas[i];
                        r.busy_until_us = r.busy_until_us.max(end);
                        if opts.stall_threshold_us > 0 && r.detection_at.is_none() {
                            let fire = r.last_progress_us + opts.stall_threshold_us;
                            if fire < end {
                                r.detection_at = Some(fire.max(t));
                            }
                        }
                        continue;
                    }
                    let r = &mut replicas[i];
                    while let Some(inb) = r.mailbox.pop_front() {
                        r.first_submit_us.get_or_insert(t);
                        r.last_submit_us = t;
                        let id = inb.req.id;
                        match inb.carried {
                            Some((s, q)) => {
                                r.engine.resubmit(inb.req, s, q + t.saturating_sub(inb.route_us));
                                router.on_started(id);
                            }
                            None => {
                                // a front-door rejection finishes via the
                                // event drain below
                                if r.engine.submit(inb.req).is_queued() {
                                    router.on_started(id);
                                }
                            }
                        }
                    }
                    if r.engine.idle() {
                        // every delivery was rejected at the front door
                        r.busy_until_us = t;
                        notify_finished(&mut r.engine, router);
                        continue;
                    }
                    let did =
                        r.engine.step().with_context(|| format!("fleet replica {i} step"))?;
                    notify_finished(&mut r.engine, router);
                    if did {
                        fleet_steps += 1;
                        anyhow::ensure!(
                            fleet_steps <= max_steps,
                            "fleet replay exceeded {max_steps} steps"
                        );
                        let base = service
                            .step_us(r.engine.last_decode_slots, r.engine.last_prefill_tokens);
                        let factor = plan.slow_factor(i);
                        let cost = if factor == 1.0 {
                            base
                        } else {
                            ((base as f64) * factor).round().max(1.0) as u64
                        };
                        if let Some(o) = obs {
                            // Step span over the billed (possibly slowed)
                            // service time, after the engine's own inline
                            // request events for this step.
                            o.step_span(
                                i as u64,
                                t,
                                cost,
                                r.engine.last_decode_slots,
                                r.engine.last_prefill_tokens,
                            );
                        }
                        r.busy_until_us = t + cost;
                        r.last_progress_us = t + cost;
                    } else if r.engine.idle() {
                        // deadline expiry at the boundary can empty the
                        // engine without executing a step
                        r.busy_until_us = t;
                    } else {
                        bail!("fleet replica {i} wedged: queued request cannot fit the KV pool");
                    }
                }
                _ => unreachable!(),
            }
        }

        failed.sort_by_key(|(id, _)| *id);
        let mut retry_attempts = CountHistogram::new();
        for &a in attempts.values() {
            retry_attempts.add(a as u64);
        }
        let mut all_timings: Vec<RequestTiming> = Vec::new();
        let mut reps = Vec::with_capacity(n);
        let mut deadline_expired = 0u64;
        for r in replicas.iter() {
            let timings = r.engine.timings();
            all_timings.extend_from_slice(timings);
            deadline_expired += r.engine.deadline_expired;
            reps.push(ReplayReport {
                completed: timings.len(),
                rejected: r.engine.rejected(),
                steps: r.engine.steps,
                tokens_out: r.engine.tokens_out,
                preemptions: r.engine.preemptions,
                first_submit_us: r.first_submit_us.unwrap_or(0),
                last_submit_us: r.last_submit_us,
                last_finish_us: timings.iter().map(|t| t.finished_us).max().unwrap_or(0),
                percentiles: percentiles(timings),
            });
        }
        if let Some(o) = obs {
            // Sync point: per-replica engine counters, fleet/router gauges
            // that have no inline increment site, and latency histograms.
            // Inline-incremented fleet_* counters (crash/evacuate/retry/
            // failed/detect/recover) are deliberately NOT re-set here so
            // the obs tests genuinely verify their co-location with the
            // report counters.
            for r in replicas.iter() {
                r.engine.sync_obs_counters();
            }
            o.counter_set("fleet_routed_total", routed);
            o.counter_set("fleet_router_rejected_total", router_rejected);
            o.counter_set("fleet_deadline_expired_total", deadline_expired);
            let rs = router.stats();
            o.counter_set("router_routed_total", rs.routed);
            o.counter_set("router_rejected_total", rs.rejected);
            o.counter_set("router_failed_total", rs.failed);
            o.counter_set("router_spurious_starts_total", rs.spurious_starts);
            o.counter_set("router_spurious_finishes_total", rs.spurious_finishes);
            o.counter_set("router_spurious_fails_total", rs.spurious_fails);
            o.counter_set("router_spurious_routes_total", rs.spurious_routes);
            let b = &LATENCY_MS_BUCKETS;
            for t in &all_timings {
                o.observe("request_queue_ms", b, t.queue * 1e3);
                o.observe("request_e2e_ms", b, t.total * 1e3);
                if t.generated >= 1 {
                    o.observe("request_ttft_ms", b, t.ttft * 1e3);
                }
                if t.generated >= 2 {
                    o.observe("request_tpot_ms", b, t.tpot * 1e3);
                }
            }
        }
        Ok(FleetReport {
            replicas: reps,
            aggregate: percentiles(&all_timings),
            routed,
            router_rejected,
            retries: retries_total,
            evacuated,
            failed,
            deadline_expired,
            crashed: crashed_list,
            unhealthy_transitions,
            recovered,
            retry_attempts,
            router_stats: router.stats(),
        })
    }
}

/// Pull everything off a crashed/stalled replica: the engine's queued and
/// running requests plus the undelivered mailbox, merged and sorted by
/// `(submitted_us, id)` so downstream re-routing is deterministic and
/// FCFS-fair.
fn evacuate_replica<B: Backend>(r: &mut Replica<B>, now_us: u64) -> Vec<Evacuated> {
    let mut evac = r.engine.evacuate();
    for inb in r.mailbox.drain(..) {
        let transit = now_us.saturating_sub(inb.route_us);
        evac.push(match inb.carried {
            Some((s, q)) => Evacuated { submitted_us: s, queued_us: q + transit, req: inb.req },
            None => Evacuated { submitted_us: inb.route_us, queued_us: transit, req: inb.req },
        });
    }
    evac.sort_by_key(|e| (e.submitted_us, e.req.id));
    evac
}

/// Consume one failover attempt for an evacuated request: schedule a
/// retry after the backoff, or — past `max_retries` — count it Failed.
#[allow(clippy::too_many_arguments)]
fn fail_over(
    e: Evacuated,
    now_us: u64,
    opts: &FleetOptions,
    attempts: &mut HashMap<RequestId, u32>,
    retries: &mut Vec<RetryEntry>,
    retry_seq: &mut u64,
    failed: &mut Vec<(RequestId, u32)>,
    retries_total: &mut u64,
    obs: Option<&Obs>,
    from: usize,
) {
    let a = attempts.entry(e.req.id).or_insert(0);
    *a += 1;
    if *a > opts.max_retries {
        failed.push((e.req.id, *a));
        if let Some(o) = obs {
            o.instant(
                "fleet",
                "failed",
                now_us,
                from as u64,
                TRACK_FLEET,
                vec![("id", e.req.id.to_string())],
            );
            o.counter_add("fleet_failed_total", 1);
        }
        return;
    }
    *retries_total += 1;
    *retry_seq += 1;
    if let Some(o) = obs {
        o.instant(
            "fleet",
            "retry",
            now_us,
            from as u64,
            TRACK_FLEET,
            vec![("id", e.req.id.to_string())],
        );
        o.counter_add("fleet_retries_total", 1);
    }
    retries.push(RetryEntry {
        due_us: now_us + opts.retry_backoff_us,
        seq: *retry_seq,
        req: e.req,
        submitted_us: e.submitted_us,
        queued_us: e.queued_us,
        evac_us: now_us,
        from,
    });
}

/// Recovery probe, run at routing decisions: a non-crashed replica that
/// is Unhealthy/Draining, out of any stall window, and fully idle takes
/// traffic again.
fn probe_recovery<B: Backend>(
    router: &mut Router,
    replicas: &mut [Replica<B>],
    plan: &FaultPlan,
    now_us: u64,
    recovered: &mut u64,
    obs: Option<&Obs>,
) {
    for (i, r) in replicas.iter_mut().enumerate() {
        if r.crashed || router.health(i) == ReplicaHealth::Healthy {
            continue;
        }
        if plan.stall_covering(i, now_us).is_none() && r.engine.idle() && r.mailbox.is_empty() {
            router.set_health(i, ReplicaHealth::Healthy);
            r.last_progress_us = now_us;
            *recovered += 1;
            if let Some(o) = obs {
                o.instant(
                    "fleet",
                    "recover",
                    now_us,
                    i as u64,
                    TRACK_FLEET,
                    vec![("replica", i.to_string())],
                );
                o.counter_add("fleet_recovered_total", 1);
            }
        }
    }
}

/// Feed the engine's Finished events back into the router ledger (the
/// "driven by engine events" half of the lifecycle protocol).
fn notify_finished<B: Backend>(engine: &mut Engine<B>, router: &mut Router) {
    for ev in engine.take_events() {
        if let Event::Finished { id, .. } = ev {
            router.on_finished(id);
        }
    }
}

/// The threaded deployment shape: one engine thread per replica behind a
/// mutexed router, on the wall clock (never combined with virtual time —
/// DESIGN.md §4). Failover here is reactive: a dead engine thread is
/// detected when a submit to it fails, the replica is marked Unhealthy,
/// and the request re-routes up to `max_retries` times before the client
/// sees a terminal [`FinishReason::Failed`] event.
pub struct FleetServer {
    servers: Vec<Server>,
    router: Mutex<Router>,
    max_retries: u32,
}

impl FleetServer {
    /// Spawn one [`Server`] per engine. All engines must be on the wall
    /// clock.
    pub fn spawn<B: Backend + Send + 'static>(
        engines: Vec<Engine<B>>,
        opts: &FleetOptions,
    ) -> Self {
        assert!(!engines.is_empty(), "need at least one replica");
        let router = Router::new(engines.len(), opts.max_queue_per_replica)
            .with_token_budget(opts.max_tokens_per_replica);
        Self {
            servers: engines.into_iter().map(Server::spawn).collect(),
            router: Mutex::new(router),
            max_retries: opts.max_retries,
        }
    }

    pub fn replicas(&self) -> usize {
        self.servers.len()
    }

    pub fn stats(&self) -> RouterStats {
        self.router.lock().expect("router lock").stats()
    }

    pub fn health(&self, replica: usize) -> ReplicaHealth {
        self.router.lock().expect("router lock").health(replica)
    }

    /// Route and submit with bounded failover. `Err` means back-pressure
    /// (no eligible replica); a replica whose engine thread died is
    /// marked Unhealthy and the request retries elsewhere, and when
    /// retries are exhausted the returned stream carries a single
    /// terminal `Finished(Failed)` event instead of hanging the client.
    pub fn submit(&self, req: Request) -> Result<Receiver<Event>> {
        let mut attempt = 0u32;
        loop {
            let route = self
                .router
                .lock()
                .expect("router lock")
                .route(&req)
                .context("fleet saturated")?;
            match self.servers[route.replica].submit(req.clone()) {
                Ok(rx) => {
                    self.router.lock().expect("router lock").on_started(req.id);
                    return Ok(rx);
                }
                Err(_) => {
                    // engine thread gone: release the ledger, gate the
                    // replica out of routing, try the survivors
                    let mut router = self.router.lock().expect("router lock");
                    router.on_failed(req.id);
                    router.set_health(route.replica, ReplicaHealth::Unhealthy);
                    attempt += 1;
                    if attempt > self.max_retries {
                        let (tx, rx) = channel();
                        let _ = tx.send(Event::Finished {
                            id: req.id,
                            reason: FinishReason::Failed,
                            generated: Vec::new(),
                            // Threaded wall-clock path: no injected clock
                            // handle here, and no determinism promise.
                            at_us: 0,
                        });
                        return Ok(rx);
                    }
                }
            }
        }
    }

    /// Client acknowledgement that `id`'s event stream ended (Finished
    /// received or the stream died with its replica): releases the
    /// router ledger so load counters return to zero.
    pub fn finished(&self, id: RequestId) {
        self.router.lock().expect("router lock").on_finished(id);
    }

    /// Finish outstanding work and join every engine thread.
    pub fn shutdown(self) -> Result<Vec<ServerReport>> {
        self.servers.into_iter().map(Server::shutdown).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{MockBackend, ModelGeom, SlotRows, StepOut};
    use crate::workload::{SeqlenDist, Trace};

    fn geom() -> ModelGeom {
        ModelGeom { vocab: 64, n_layers: 2, row_elems: 4, planes: 2, max_seq: 64 }
    }

    fn svc() -> ServiceModel {
        ServiceModel { step_base_us: 200, step_per_seq_us: 50, step_prefill_token_us: 50 }
    }

    fn mk_fleet(n: usize, plan: FaultPlan, opts: FleetOptions) -> Fleet<MockBackend> {
        Fleet::build(n, plan, opts, |clock| {
            let mut e = Engine::with_clock(
                MockBackend::new(geom(), vec![1, 2, 4, 8]),
                40,
                4,
                0.5,
                clock,
            );
            e.set_prefill_chunk(4);
            e
        })
    }

    fn paced_requests(count: u64, gap_us: u64) -> Vec<Request> {
        (0..count)
            .map(|i| {
                let mut r = Request::new(i, vec![1 + (i % 5) as i32; 8], 6);
                r.arrival_us = i * gap_us;
                r
            })
            .collect()
    }

    #[test]
    fn fault_plan_parse_round_trips() {
        let spec = "stall:0@40000+30000;crash:1@80000;slow:2@1.50";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.faults.len(), 3);
        assert_eq!(plan.render(), spec);
        assert_eq!(plan.crash_at(1), Some(80_000));
        assert_eq!(plan.crash_at(0), None);
        assert_eq!(plan.stall_covering(0, 39_999), None);
        assert_eq!(plan.stall_covering(0, 40_000), Some((40_000, 70_000)));
        assert_eq!(plan.stall_covering(0, 69_999), Some((40_000, 70_000)));
        assert_eq!(plan.stall_covering(0, 70_000), None, "stall end is exclusive");
        assert_eq!(plan.slow_factor(2), 1.5);
        assert_eq!(plan.slow_factor(0), 1.0, "no slow fault = nominal");
        assert_eq!(plan.max_replica(), Some(2));
        // whitespace and empty parts are tolerated
        let ws = FaultPlan::parse(" crash:0@5 ; ").unwrap();
        assert_eq!(ws.faults, vec![Fault::Crash { replica: 0, at_us: 5 }]);
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn fault_plan_parse_rejects_garbage() {
        assert!(FaultPlan::parse("nope:0@1").is_err());
        assert!(FaultPlan::parse("stall:0@5").is_err(), "stall needs from+dur");
        assert!(FaultPlan::parse("stall:0@5+0").is_err(), "zero-length stall");
        assert!(FaultPlan::parse("crash:x@5").is_err());
        assert!(FaultPlan::parse("slow:0@-1").is_err());
        assert!(FaultPlan::parse("crash:0").is_err());
    }

    #[test]
    fn seeded_plans_are_deterministic_and_round_trip() {
        let a = FaultPlan::seeded(9, 4, 1_000_000);
        assert_eq!(a, FaultPlan::seeded(9, 4, 1_000_000));
        assert!(a.max_replica().map_or(true, |m| m < 4));
        let reparsed = FaultPlan::parse(&a.render()).unwrap();
        assert_eq!(reparsed, a, "seeded plan round-trips through the spec format");
        // different seeds differ somewhere across a few draws
        let plans: Vec<_> = (0..8).map(|s| FaultPlan::seeded(s, 4, 1_000_000)).collect();
        assert!(plans.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn fleet_without_faults_completes_everything_deterministically() {
        let run = || {
            let trace = Trace::poisson(24, 400.0, SeqlenDist::Fixed(24), (8, 8), 64, 42);
            let reqs = crate::loadgen::synthesize_requests(&trace, 64, 16, 8, 7);
            let mut fleet = mk_fleet(2, FaultPlan::none(), FleetOptions::default());
            let rep = fleet.replay(&reqs, &svc(), 100_000).unwrap();
            assert_eq!(rep.completed(), 24);
            assert_eq!(rep.routed, 24);
            assert_eq!(rep.router_rejected, 0);
            assert!(rep.failed.is_empty());
            assert_eq!(rep.evacuated, 0);
            assert!(rep.crashed.is_empty());
            let s = rep.router_stats;
            assert_eq!(
                (s.spurious_starts, s.spurious_finishes, s.spurious_fails, s.spurious_routes),
                (0, 0, 0, 0),
                "lifecycle protocol stays exact"
            );
            rep.render()
        };
        assert_eq!(run(), run(), "fleet replay must be byte-deterministic");
    }

    #[test]
    fn crash_fails_over_without_losing_requests() {
        let run = || {
            let plan = FaultPlan::parse("crash:0@2000").unwrap();
            let mut fleet = mk_fleet(2, plan, FleetOptions::default());
            let rep = fleet.replay(&paced_requests(16, 500), &svc(), 100_000).unwrap();
            assert_eq!(rep.crashed, vec![0]);
            assert!(rep.evacuated >= 1, "replica 0 had work at the crash");
            assert!(rep.retries >= 1);
            assert!(rep.failed.is_empty(), "one healthy survivor absorbs every retry");
            assert_eq!(rep.completed(), 16, "zero lost requests");
            assert_eq!(rep.replicas[0].completed + rep.replicas[1].completed, 16);
            assert!(rep.retry_attempts.total() >= 1);
            rep.render()
        };
        assert_eq!(run(), run(), "crash schedule must be byte-deterministic");
    }

    #[test]
    fn stall_failover_detects_evacuates_and_recovers() {
        let plan = FaultPlan::parse("stall:0@1000+8000").unwrap();
        let opts = FleetOptions {
            stall_threshold_us: 2_000,
            stall_policy: StallPolicy::Failover,
            ..FleetOptions::default()
        };
        let mut reqs = paced_requests(8, 500);
        // a late arrival probes recovery after the stall window closes
        let mut late = Request::new(8, vec![3; 8], 6);
        late.arrival_us = 20_000;
        reqs.push(late);
        let mut fleet = mk_fleet(2, plan, opts);
        let rep = fleet.replay(&reqs, &svc(), 100_000).unwrap();
        assert_eq!(rep.unhealthy_transitions, 1, "watermark detection fired once");
        assert!(rep.evacuated >= 1, "failover pulled inflight work off the stalled replica");
        assert_eq!(rep.recovered, 1, "the stalled replica takes traffic again");
        assert!(rep.crashed.is_empty());
        assert!(rep.failed.is_empty());
        assert_eq!(rep.completed(), 9, "zero lost requests across stall + recovery");
    }

    #[test]
    fn stall_drain_policy_keeps_inflight_work_on_the_replica() {
        let plan = FaultPlan::parse("stall:0@1000+8000").unwrap();
        let opts = FleetOptions {
            stall_threshold_us: 2_000,
            stall_policy: StallPolicy::Drain,
            ..FleetOptions::default()
        };
        let mut reqs = paced_requests(8, 500);
        let mut late = Request::new(8, vec![3; 8], 6);
        late.arrival_us = 20_000;
        reqs.push(late);
        let mut fleet = mk_fleet(2, plan, opts);
        let rep = fleet.replay(&reqs, &svc(), 100_000).unwrap();
        assert_eq!(rep.unhealthy_transitions, 1);
        assert_eq!(rep.evacuated, 0, "drain never evacuates");
        assert_eq!(rep.retries, 0);
        assert_eq!(rep.recovered, 1, "drained replica recovers once idle");
        assert_eq!(rep.completed(), 9, "inflight work finishes after the stall ends");
        assert!(rep.replicas[0].completed >= 1, "the stalled replica kept its work");
    }

    #[test]
    fn slow_step_factor_inflates_the_slow_replicas_service_time() {
        let run = |spec: &str| {
            let plan = FaultPlan::parse(spec).unwrap();
            let mut fleet = mk_fleet(2, plan, FleetOptions::default());
            let rep = fleet.replay(&paced_requests(12, 400), &svc(), 100_000).unwrap();
            assert_eq!(rep.completed(), 12);
            (rep.replicas[0].last_finish_us, rep.replicas[1].last_finish_us)
        };
        let (nom0, _) = run("");
        let (slow0, _) = run("slow:0@3.00");
        assert!(slow0 > nom0, "3× steps on replica 0 must finish later ({nom0} -> {slow0})");
    }

    /// Wall-clock failover test double: replica 0's backend errors on its
    /// first step, killing the engine thread, while replica 1 is a plain
    /// mock.
    enum TestBackend {
        Ok(MockBackend),
        Doomed(MockBackend),
    }

    impl Backend for TestBackend {
        fn geom(&self) -> ModelGeom {
            match self {
                TestBackend::Ok(b) | TestBackend::Doomed(b) => b.geom,
            }
        }
        fn buckets(&self) -> Vec<usize> {
            match self {
                TestBackend::Ok(b) | TestBackend::Doomed(b) => b.buckets.clone(),
            }
        }
        fn step(
            &mut self,
            bucket: usize,
            slots: &[SlotRows],
            cache_planes: &mut [Vec<f32>],
        ) -> Result<StepOut> {
            match self {
                TestBackend::Ok(b) => b.step(bucket, slots, cache_planes),
                TestBackend::Doomed(_) => bail!("injected replica fault"),
            }
        }
    }

    #[test]
    fn threaded_fleet_fails_over_to_the_surviving_replica() {
        let engines = vec![
            Engine::new(TestBackend::Doomed(MockBackend::tiny()), 64, 4, 1.0),
            Engine::new(TestBackend::Ok(MockBackend::tiny()), 64, 4, 1.0),
        ];
        let fleet = FleetServer::spawn(engines, &FleetOptions::default());
        assert_eq!(fleet.replicas(), 2);
        // least-loaded routes the first request to replica 0, whose first
        // step kills its engine thread: the stream dies with no Finished
        let rx = fleet.submit(Request::new(1, vec![3, 5], 3)).unwrap();
        let evs: Vec<Event> = rx.iter().collect();
        assert!(
            !evs.iter().any(|e| matches!(e, Event::Finished { .. })),
            "stream died mid-flight: {evs:?}"
        );
        fleet.finished(1); // client releases the dead stream's ledger
        // the dead thread is now detected at submit, the replica gated
        // out, and the retry lands on the survivor
        let rx = fleet.submit(Request::new(1, vec![3, 5], 3)).unwrap();
        let evs: Vec<Event> = rx.iter().collect();
        assert!(matches!(
            evs.last().unwrap(),
            Event::Finished { reason: FinishReason::Length, .. }
        ));
        fleet.finished(1);
        assert_eq!(fleet.health(0), ReplicaHealth::Unhealthy);
        assert_eq!(fleet.health(1), ReplicaHealth::Healthy);
        let s = fleet.stats();
        assert_eq!(s.failed, 1, "one failover recorded");
        assert_eq!(s.spurious_fails, 0);
        let reports = fleet.shutdown().unwrap();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[1].tokens_out, 3, "the survivor served the retry");
    }

    #[test]
    fn threaded_fleet_exhausted_retries_surface_as_failed_event() {
        // every replica is doomed: after max_retries failovers the client
        // receives a terminal Failed event instead of hanging
        let mk = || {
            let e = Engine::new(TestBackend::Doomed(MockBackend::tiny()), 64, 4, 1.0);
            let s = Server::spawn(e);
            // kill the thread deterministically before the fleet routes
            // to it: a throwaway request whose stream must die
            let rx = s.submit(Request::new(999, vec![1], 1)).unwrap();
            let _ = rx.iter().count();
            s
        };
        let fleet = FleetServer {
            servers: vec![mk(), mk()],
            router: Mutex::new(Router::new(2, 1024)),
            max_retries: 2,
        };
        let rx = fleet.submit(Request::new(7, vec![1, 2], 2)).unwrap();
        let evs: Vec<Event> = rx.iter().collect();
        assert!(matches!(
            evs.as_slice(),
            [Event::Finished { id: 7, reason: FinishReason::Failed, generated, .. }] if generated.is_empty()
        ));
        assert_eq!(fleet.stats().failed, 3, "initial attempt + 2 retries all failed over");
        assert_eq!(fleet.health(0), ReplicaHealth::Unhealthy);
        assert_eq!(fleet.health(1), ReplicaHealth::Unhealthy);
    }
}
