//! Continuous batcher: admission control + per-step batch composition.
//!
//! vLLM/Orca-style iteration-level scheduling: every decode step the
//! batcher re-derives the running set — finished sequences leave, queued
//! requests join as long as (a) a batch-bucket slot is free and (b) the
//! paged KV pool can hold their worst-case footprint. The engine executes
//! whichever AOT batch bucket is the smallest that fits the running set.

use std::collections::VecDeque;

use super::kv_cache::KvPool;
use super::request::{Request, RequestId};

/// Admission + batch composition policy.
#[derive(Debug)]
pub struct Batcher {
    /// Available AOT batch buckets, ascending (e.g. [1, 4, 8]).
    buckets: Vec<usize>,
    waiting: VecDeque<Request>,
    running: Vec<RequestId>,
    /// Admission headroom: fraction of a request's worst-case pages that
    /// must be free to admit it (1.0 = fully conservative).
    admit_fraction: f64,
}

impl Batcher {
    pub fn new(mut buckets: Vec<usize>, admit_fraction: f64) -> Self {
        assert!(!buckets.is_empty(), "need at least one batch bucket");
        assert!(admit_fraction > 0.0 && admit_fraction <= 1.0);
        buckets.sort_unstable();
        buckets.dedup();
        Self { buckets, waiting: VecDeque::new(), running: Vec::new(), admit_fraction }
    }

    pub fn max_batch(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Smallest bucket that fits `n` live sequences.
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= n)
    }

    pub fn submit(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    pub fn queued(&self) -> usize {
        self.waiting.len()
    }

    pub fn running(&self) -> &[RequestId] {
        &self.running
    }

    /// Remove a finished/preempted id from the running set.
    pub fn release(&mut self, id: RequestId) {
        self.running.retain(|&r| r != id);
    }

    /// Put a preempted request back at the *front* of the queue (it
    /// re-prefills from scratch — FCFS without starvation).
    pub fn requeue_front(&mut self, req: Request) {
        self.waiting.push_front(req);
    }

    /// Admit queued requests while capacity allows; returns newly admitted
    /// requests (caller must alloc_seq + start prefill).
    pub fn admit(&mut self, pool: &KvPool) -> Vec<Request> {
        let mut admitted = Vec::new();
        let mut reserved = 0usize; // pages promised to requests admitted now
        while self.running.len() < self.max_batch() {
            let Some(front) = self.waiting.front() else { break };
            let worst_pages = pool.pages_for(front.max_total_len());
            let need = ((worst_pages as f64) * self.admit_fraction).ceil() as usize;
            if pool.free_pages() < reserved + need.max(1) {
                break; // FCFS: do not skip ahead of the blocked head
            }
            let req = self.waiting.pop_front().unwrap();
            reserved += need.max(1);
            self.running.push(req.id);
            admitted.push(req);
        }
        admitted
    }

    /// True when nothing is queued or running.
    pub fn idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv_cache::CacheGeometry;
    use crate::util::rng::Rng;

    fn pool(pages: usize) -> KvPool {
        KvPool::new(
            CacheGeometry { n_layers: 1, row_elems: 2, planes: 2, max_seq: 64 },
            4,
            pages,
        )
    }

    fn req(id: u64, prompt: usize, gen: usize) -> Request {
        Request::new(id, vec![1; prompt], gen)
    }

    #[test]
    fn bucket_selection() {
        let b = Batcher::new(vec![8, 1, 4], 1.0);
        assert_eq!(b.bucket_for(1), Some(1));
        assert_eq!(b.bucket_for(2), Some(4));
        assert_eq!(b.bucket_for(5), Some(8));
        assert_eq!(b.bucket_for(9), None);
        assert_eq!(b.max_batch(), 8);
    }

    #[test]
    fn admits_up_to_bucket_and_capacity() {
        let mut b = Batcher::new(vec![1, 4], 1.0);
        let p = pool(6); // 24 token slots
        for i in 0..6 {
            b.submit(req(i, 4, 4)); // 8 tokens = 2 pages each
        }
        let admitted = b.admit(&p);
        // capacity: 6 pages / 2 per req = 3 admitted (bucket would allow 4)
        assert_eq!(admitted.len(), 3);
        assert_eq!(b.running().len(), 3);
        assert_eq!(b.queued(), 3);
    }

    #[test]
    fn fcfs_head_blocks_queue() {
        let mut b = Batcher::new(vec![4], 1.0);
        let p = pool(2); // 8 token slots
        b.submit(req(1, 30, 10)); // 10 pages — can never fit
        b.submit(req(2, 2, 2)); // would fit, but FCFS must not bypass
        assert!(b.admit(&p).is_empty());
        assert_eq!(b.queued(), 2);
    }

    #[test]
    fn release_and_requeue() {
        let mut b = Batcher::new(vec![2], 1.0);
        let p = pool(16);
        b.submit(req(1, 2, 2));
        b.submit(req(2, 2, 2));
        b.submit(req(3, 2, 2));
        assert_eq!(b.admit(&p).len(), 2);
        b.release(1);
        assert_eq!(b.running(), &[2]);
        b.requeue_front(req(1, 2, 2));
        let again = b.admit(&p);
        assert_eq!(again[0].id, 1, "preempted request resumes first");
    }

    #[test]
    fn property_running_never_exceeds_max_batch_nor_duplicates() {
        let mut rng = Rng::seed_from_u64(5);
        let mut b = Batcher::new(vec![1, 2, 4], 0.5);
        let p = pool(32);
        let mut next = 0u64;
        for _ in 0..300 {
            match rng.below(3) {
                0 => {
                    next += 1;
                    b.submit(req(next, 1 + rng.below(6), 1 + rng.below(6)));
                }
                1 => {
                    let _ = b.admit(&p);
                }
                _ => {
                    if let Some(&id) = b.running().first() {
                        b.release(id);
                    }
                }
            }
            assert!(b.running().len() <= b.max_batch());
            let mut ids: Vec<_> = b.running().to_vec();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), b.running().len(), "duplicate running id");
        }
    }
}
