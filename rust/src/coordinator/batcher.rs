//! Continuous batcher: admission control + per-step batch composition.
//!
//! vLLM/Orca-style iteration-level scheduling: every decode step the
//! batcher re-derives the running set — finished sequences leave, queued
//! requests join as long as (a) a batch-bucket slot is free and (b) the
//! paged KV pool can hold their worst-case footprint. The engine executes
//! whichever AOT batch bucket is the smallest that fits the running set.
//!
//! Queue entries carry the clock timestamp at which they were submitted
//! (`util::clock` microseconds) so the engine can attribute queue wait to
//! each request; a preempted request keeps its original timestamp across
//! the requeue, so its eventual TTFT includes the whole detour.

use std::collections::VecDeque;

use super::kv_cache::KvPool;
use super::request::{Request, RequestId};

/// A request waiting for admission, stamped with its submission time.
#[derive(Debug, Clone, PartialEq)]
pub struct QueuedRequest {
    pub req: Request,
    /// Clock microseconds at submission (first arrival, not requeue).
    pub submitted_us: u64,
    /// Clock microseconds this entry was pushed (submission or requeue):
    /// the start of the *current* wait.
    pub enqueued_us: u64,
    /// Queue wait accumulated on earlier admission attempts, microseconds
    /// (execution time between admission and preemption is not queueing).
    pub queued_us: u64,
}

/// Admission + batch composition policy.
#[derive(Debug)]
pub struct Batcher {
    /// Available AOT batch buckets, ascending (e.g. [1, 4, 8]).
    buckets: Vec<usize>,
    waiting: VecDeque<QueuedRequest>,
    running: Vec<RequestId>,
    /// Admission headroom: fraction of a request's worst-case pages that
    /// must be free to admit it (1.0 = fully conservative).
    admit_fraction: f64,
    /// Per-step budget of prompt rows across the whole batch (chunked
    /// prefill, Sarathi/TGI-style). 0 = unlimited: a prompt prefills in
    /// one step.
    prefill_chunk: usize,
}

impl Batcher {
    pub fn new(mut buckets: Vec<usize>, admit_fraction: f64) -> Self {
        assert!(!buckets.is_empty(), "need at least one batch bucket");
        assert!(admit_fraction > 0.0 && admit_fraction <= 1.0);
        buckets.sort_unstable();
        buckets.dedup();
        Self {
            buckets,
            waiting: VecDeque::new(),
            running: Vec::new(),
            admit_fraction,
            prefill_chunk: 0,
        }
    }

    /// Cap prompt rows fed per step across the batch (0 = unlimited).
    pub fn set_prefill_chunk(&mut self, chunk: usize) {
        self.prefill_chunk = chunk;
    }

    pub fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    /// Split this step's prefill-token budget over the running set.
    /// `remaining[i]` is slot i's outstanding prompt rows (0 for decode
    /// slots, which always get exactly one row and cost no budget).
    /// Prefilling slots draw from the budget FCFS in running order; a
    /// slot allocated 0 rows sits the step out. With a non-zero chunk the
    /// first prefilling slot always gets at least one row, so prefill
    /// can never starve behind decode traffic.
    pub fn allocate_prefill(&self, remaining: &[usize]) -> Vec<usize> {
        let mut budget = if self.prefill_chunk == 0 { usize::MAX } else { self.prefill_chunk };
        remaining
            .iter()
            .map(|&rem| {
                if rem == 0 {
                    1
                } else {
                    let r = rem.min(budget);
                    budget -= r;
                    r
                }
            })
            .collect()
    }

    pub fn max_batch(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Smallest bucket that fits `n` live sequences.
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= n)
    }

    /// Enqueue a request submitted at clock time `now_us`.
    pub fn submit(&mut self, req: Request, now_us: u64) {
        self.waiting.push_back(QueuedRequest {
            req,
            submitted_us: now_us,
            enqueued_us: now_us,
            queued_us: 0,
        });
    }

    /// Enqueue at the *back* while preserving timestamps from an earlier
    /// life on another replica (fleet failover — recompute semantics like
    /// [`Self::requeue_front`], but the retry queues behind work the new
    /// replica already holds rather than jumping it).
    pub fn submit_carried(
        &mut self,
        req: Request,
        submitted_us: u64,
        queued_us: u64,
        now_us: u64,
    ) {
        self.waiting.push_back(QueuedRequest { req, submitted_us, enqueued_us: now_us, queued_us });
    }

    /// Remove and return every waiting entry (fleet evacuation of a
    /// crashed/stalled replica). Running sequences are the engine's to
    /// evacuate — see `Engine::evacuate`.
    pub fn drain_waiting(&mut self) -> Vec<QueuedRequest> {
        self.waiting.drain(..).collect()
    }

    /// Remove and return waiting entries whose deadline has passed at
    /// `now_us` (FCFS order preserved for the survivors). Entries without
    /// a deadline (`deadline_us == 0`) are never expired; the common
    /// no-deadline queue takes one scan and no allocation.
    pub fn take_expired(&mut self, now_us: u64) -> Vec<QueuedRequest> {
        let hit = |e: &QueuedRequest| e.req.deadline_us > 0 && e.req.deadline_us <= now_us;
        if !self.waiting.iter().any(hit) {
            return Vec::new();
        }
        let mut expired = Vec::new();
        let mut keep = VecDeque::with_capacity(self.waiting.len());
        for e in self.waiting.drain(..) {
            if hit(&e) {
                expired.push(e);
            } else {
                keep.push_back(e);
            }
        }
        self.waiting = keep;
        expired
    }

    pub fn queued(&self) -> usize {
        self.waiting.len()
    }

    /// Total prompt rows across the waiting queue — the queue's share of
    /// the prefill backlog the admission controller projects TTFT from
    /// (`coordinator::admission`).
    pub fn waiting_prompt_rows(&self) -> usize {
        self.waiting.iter().map(|e| e.req.prompt.len()).sum()
    }

    pub fn running(&self) -> &[RequestId] {
        &self.running
    }

    /// Remove a finished/preempted id from the running set.
    pub fn release(&mut self, id: RequestId) {
        self.running.retain(|&r| r != id);
    }

    /// Put a preempted request back at the *front* of the queue (it
    /// re-prefills from scratch — FCFS without starvation). The original
    /// submission timestamp and the queue wait already accumulated are
    /// preserved; the current wait restarts at `now_us`.
    pub fn requeue_front(&mut self, req: Request, submitted_us: u64, queued_us: u64, now_us: u64) {
        self.waiting.push_front(QueuedRequest {
            req,
            submitted_us,
            enqueued_us: now_us,
            queued_us,
        });
    }

    /// Admit queued requests while capacity allows; returns newly admitted
    /// entries (caller must alloc_seq + start prefill).
    pub fn admit(&mut self, pool: &KvPool) -> Vec<QueuedRequest> {
        self.admit_bounded(pool, self.max_batch(), 0, 0)
    }

    /// [`Self::admit`] under the front door's bounds: `slot_cap` caps the
    /// running set below `max_batch` (TPOT SLO), and a non-zero
    /// `token_budget` stops growth once the worst-case token footprints
    /// (`prompt + max_new`) of running sequences — `run_tokens` for the
    /// already-running set, accumulated here for new admits — would
    /// exceed it. The budget never blocks admission into an *empty*
    /// batch: a lone oversized request still runs rather than starving.
    pub fn admit_bounded(
        &mut self,
        pool: &KvPool,
        slot_cap: usize,
        token_budget: usize,
        mut run_tokens: usize,
    ) -> Vec<QueuedRequest> {
        let mut admitted = Vec::new();
        let mut reserved = 0usize; // pages promised to requests admitted now
        while self.running.len() < slot_cap {
            let Some(front) = self.waiting.front() else { break };
            let tokens = front.req.max_total_len();
            let worst_pages = pool.pages_for(tokens);
            let need = ((worst_pages as f64) * self.admit_fraction).ceil() as usize;
            if pool.free_pages() < reserved + need.max(1) {
                break; // FCFS: do not skip ahead of the blocked head
            }
            if token_budget > 0 && !self.running.is_empty() && run_tokens + tokens > token_budget
            {
                break; // token budget: growth stops, drain continues
            }
            let entry = self.waiting.pop_front().unwrap();
            reserved += need.max(1);
            run_tokens += tokens;
            self.running.push(entry.req.id);
            admitted.push(entry);
        }
        admitted
    }

    /// True when nothing is queued or running.
    pub fn idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv_cache::CacheGeometry;
    use crate::util::rng::Rng;

    fn pool(pages: usize) -> KvPool {
        KvPool::new(CacheGeometry { n_layers: 1, row_elems: 2, planes: 2, max_seq: 64 }, 4, pages)
    }

    fn req(id: u64, prompt: usize, gen: usize) -> Request {
        Request::new(id, vec![1; prompt], gen)
    }

    #[test]
    fn bucket_selection() {
        let b = Batcher::new(vec![8, 1, 4], 1.0);
        assert_eq!(b.bucket_for(1), Some(1));
        assert_eq!(b.bucket_for(2), Some(4));
        assert_eq!(b.bucket_for(5), Some(8));
        assert_eq!(b.bucket_for(9), None);
        assert_eq!(b.max_batch(), 8);
    }

    #[test]
    fn admits_up_to_bucket_and_capacity() {
        let mut b = Batcher::new(vec![1, 4], 1.0);
        let p = pool(6); // 24 token slots
        for i in 0..6 {
            b.submit(req(i, 4, 4), i * 10); // 8 tokens = 2 pages each
        }
        let admitted = b.admit(&p);
        // capacity: 6 pages / 2 per req = 3 admitted (bucket would allow 4)
        assert_eq!(admitted.len(), 3);
        assert_eq!(b.running().len(), 3);
        assert_eq!(b.queued(), 3);
        // submission timestamps ride along
        assert_eq!(admitted[0].submitted_us, 0);
        assert_eq!(admitted[2].submitted_us, 20);
    }

    #[test]
    fn prefill_allocation_is_fcfs_within_budget() {
        let mut b = Batcher::new(vec![8], 1.0);
        // unlimited by default: everyone prefills whole
        assert_eq!(b.allocate_prefill(&[5, 0, 3]), vec![5, 1, 3]);
        b.set_prefill_chunk(4);
        assert_eq!(b.prefill_chunk(), 4);
        // decode slots ride free; prefill budget drains in order
        assert_eq!(b.allocate_prefill(&[0, 5, 3]), vec![1, 4, 0]);
        assert_eq!(b.allocate_prefill(&[2, 3, 1]), vec![2, 2, 0]);
        // first prefill slot always progresses, even with chunk 1
        b.set_prefill_chunk(1);
        assert_eq!(b.allocate_prefill(&[0, 9]), vec![1, 1]);
        assert_eq!(b.allocate_prefill(&[]), Vec::<usize>::new());
    }

    #[test]
    fn fcfs_head_blocks_queue() {
        let mut b = Batcher::new(vec![4], 1.0);
        let p = pool(2); // 8 token slots
        b.submit(req(1, 30, 10), 0); // 10 pages — can never fit
        b.submit(req(2, 2, 2), 0); // would fit, but FCFS must not bypass
        assert!(b.admit(&p).is_empty());
        assert_eq!(b.queued(), 2);
    }

    #[test]
    fn release_and_requeue_preserves_submit_time() {
        let mut b = Batcher::new(vec![2], 1.0);
        let p = pool(16);
        b.submit(req(1, 2, 2), 5);
        b.submit(req(2, 2, 2), 6);
        b.submit(req(3, 2, 2), 7);
        assert_eq!(b.admit(&p).len(), 2);
        b.release(1);
        assert_eq!(b.running(), &[2]);
        b.requeue_front(req(1, 2, 2), 5, 40, 100);
        let again = b.admit(&p);
        assert_eq!(again[0].req.id, 1, "preempted request resumes first");
        assert_eq!(again[0].submitted_us, 5, "original submit time survives requeue");
        assert_eq!(again[0].queued_us, 40, "accumulated queue wait survives requeue");
        assert_eq!(again[0].enqueued_us, 100, "current wait restarts at requeue time");
    }

    #[test]
    fn bounded_admission_honours_slot_cap_and_token_budget() {
        let mut b = Batcher::new(vec![8], 1.0);
        let p = pool(64);
        for i in 0..5 {
            b.submit(req(i, 4, 4), 0); // 8-token worst case each
        }
        assert_eq!(b.waiting_prompt_rows(), 20);
        // slot cap 2 binds below the bucket's 8
        assert_eq!(b.admit_bounded(&p, 2, 0, 0).len(), 2);
        // token budget 20 with 16 already running: +8 would overshoot
        assert!(b.admit_bounded(&p, 8, 20, 16).is_empty());
        // the budget never blocks admission into an empty batch
        b.release(0);
        b.release(1);
        assert_eq!(b.admit_bounded(&p, 8, 4, 0).len(), 1, "lone oversized request still runs");
        assert_eq!(b.waiting_prompt_rows(), 8);
    }

    #[test]
    fn submit_carried_queues_behind_local_work_with_old_timestamps() {
        let mut b = Batcher::new(vec![4], 1.0);
        let p = pool(16);
        b.submit(req(1, 2, 2), 50);
        b.submit_carried(req(2, 2, 2), 5, 30, 60); // failed over from elsewhere
        let admitted = b.admit(&p);
        assert_eq!(admitted.len(), 2);
        assert_eq!(admitted[0].req.id, 1, "retry does not jump local FCFS order");
        assert_eq!(admitted[1].submitted_us, 5, "original submit time survives failover");
        assert_eq!(admitted[1].queued_us, 30, "accumulated queue wait survives failover");
        assert_eq!(admitted[1].enqueued_us, 60, "current wait restarts at the new replica");
    }

    #[test]
    fn drain_waiting_empties_the_queue_in_order() {
        let mut b = Batcher::new(vec![4], 1.0);
        b.submit(req(1, 2, 2), 0);
        b.submit(req(2, 2, 2), 1);
        let drained = b.drain_waiting();
        assert_eq!(drained.iter().map(|e| e.req.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(b.queued(), 0);
        assert!(b.drain_waiting().is_empty());
    }

    #[test]
    fn take_expired_removes_only_past_deadline_entries() {
        let mut b = Batcher::new(vec![4], 1.0);
        b.submit(req(1, 2, 2), 0); // no deadline: never expires
        b.submit(req(2, 2, 2).with_deadline_us(100), 0);
        b.submit(req(3, 2, 2).with_deadline_us(500), 0);
        assert!(b.take_expired(99).is_empty(), "deadline not yet reached");
        let expired = b.take_expired(100);
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].req.id, 2, "deadline is inclusive at now");
        assert_eq!(b.queued(), 2, "survivors keep their FCFS order");
        assert!(b.take_expired(400).is_empty());
        assert_eq!(b.take_expired(10_000).len(), 1);
    }

    #[test]
    fn property_running_never_exceeds_max_batch_nor_duplicates() {
        let mut rng = Rng::seed_from_u64(5);
        let mut b = Batcher::new(vec![1, 2, 4], 0.5);
        let p = pool(32);
        let mut next = 0u64;
        for step in 0..300 {
            match rng.below(3) {
                0 => {
                    next += 1;
                    b.submit(req(next, 1 + rng.below(6), 1 + rng.below(6)), step);
                }
                1 => {
                    let _ = b.admit(&p);
                }
                _ => {
                    if let Some(&id) = b.running().first() {
                        b.release(id);
                    }
                }
            }
            assert!(b.running().len() <= b.max_batch());
            let mut ids: Vec<_> = b.running().to_vec();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), b.running().len(), "duplicate running id");
        }
    }
}
