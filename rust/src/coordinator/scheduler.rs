//! Scheduling policies: preemption victim selection and (for the
//! simulator) decode-step ordering.
//!
//! Recompute-style preemption as in vLLM: under cache pressure the
//! *youngest* running sequence (most recently admitted) is evicted and
//! re-queued at the front, preserving FCFS completion order for the older
//! sequences that have already accumulated KV state.

use std::time::Instant;

use super::kv_cache::SeqId;

/// Choose the preemption victim among `running`: the most recently
/// admitted sequence (`admit_time` accessor avoids borrowing whole
/// engine state).
pub fn pick_victim(running: &[SeqId], admit_time: impl Fn(SeqId) -> Instant) -> SeqId {
    assert!(!running.is_empty());
    *running
        .iter()
        .max_by_key(|id| admit_time(**id))
        .expect("non-empty running set")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn youngest_is_victim() {
        let base = Instant::now();
        let times = [base, base + Duration::from_secs(2), base + Duration::from_secs(1)];
        let running = vec![10, 20, 30];
        let victim = pick_victim(&running, |id| times[(id / 10 - 1) as usize]);
        assert_eq!(victim, 20);
    }

    #[test]
    fn single_running_is_victim() {
        let now = Instant::now();
        assert_eq!(pick_victim(&[7], |_| now), 7);
    }
}
