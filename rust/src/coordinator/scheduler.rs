//! Scheduling policies: preemption victim selection and (for the
//! simulator) decode-step ordering.
//!
//! Recompute-style preemption as in vLLM: under cache pressure the
//! *youngest* running sequence (most recently admitted) is evicted and
//! re-queued at the front, preserving FCFS completion order for the older
//! sequences that have already accumulated KV state.

use super::kv_cache::SeqId;

/// Choose the preemption victim among `running`: the most recently
/// admitted sequence. `admit_time` returns the admission timestamp in
/// clock microseconds (see `util::clock`); the accessor form avoids
/// borrowing whole engine state. Ties (same-step admissions on a virtual
/// clock) break toward the higher sequence id, which is the later
/// submission, so the choice stays deterministic.
pub fn pick_victim(running: &[SeqId], admit_time: impl Fn(SeqId) -> u64) -> SeqId {
    assert!(!running.is_empty());
    *running
        .iter()
        .max_by_key(|id| (admit_time(**id), **id))
        .expect("non-empty running set")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn youngest_is_victim() {
        let times = [0u64, 2_000_000, 1_000_000];
        let running = vec![10, 20, 30];
        let victim = pick_victim(&running, |id| times[(id / 10 - 1) as usize]);
        assert_eq!(victim, 20);
    }

    #[test]
    fn single_running_is_victim() {
        assert_eq!(pick_victim(&[7], |_| 5), 7);
    }

    #[test]
    fn ties_break_toward_latest_submission() {
        // Virtual-clock runs can admit several sequences at the same
        // microsecond; the victim must still be unique and deterministic.
        assert_eq!(pick_victim(&[3, 9, 4], |_| 100), 9);
    }
}
