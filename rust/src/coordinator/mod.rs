//! # Layer-3 serving coordinator
//!
//! The framework around the fused kernels — what a team would actually
//! deploy. Mirrors the vLLM-router shape:
//!
//! * [`admission`] — the latency-targeted front door: token-budget and
//!   SLO-projected admission control (TGI-style);
//! * [`router`] — least-loaded replica selection under queue and
//!   token-budget bounds;
//! * [`batcher`] — continuous (iteration-level) batching into the AOT
//!   batch buckets;
//! * [`kv_cache`] — paged, host-authoritative KV-cache pool;
//! * [`scheduler`] — preemption policy under cache pressure;
//! * [`engine`] — the decode-step loop (generic over [`engine::Backend`]);
//! * [`fleet`] — replicated serving behind the router: deterministic
//!   fault injection, health-gated routing, failover, deadlines;
//! * [`functional_backend`] — the artifact-free backend decoding real
//!   numerics through the full-block pipeline (`clustersim::block`);
//! * [`pjrt_backend`] — the real backend executing AOT artifacts on PJRT;
//! * [`server`] — threaded front-end with per-request event streams;
//! * [`config`] — the serving configuration system.
//!
//! Python never runs on this path: the engine consumes `artifacts/*.hlo.txt`
//! through the [`crate::runtime`] PJRT wrapper.
pub mod admission;
pub mod batcher;
pub mod config;
pub mod engine;
pub mod fleet;
pub mod functional_backend;
pub mod kv_cache;
pub mod pjrt_backend;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use functional_backend::FunctionalBackend;
