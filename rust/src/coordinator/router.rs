//! Request router: replica selection under queue and token-budget bounds.
//!
//! Mirrors the vLLM/TGI router architecture: a front door that (a) rejects
//! work beyond per-replica queue and token budgets, (b) picks the
//! least-loaded *eligible* engine replica, and (c) tracks each request's
//! lifecycle in a ledger so load counters can never drift. The demo
//! deployment runs one replica per process, but the policy is
//! replica-count generic and is exercised with many simulated replicas in
//! tests (`integration_router`).
//!
//! Three historical bugs shaped this module (regression-tested):
//!
//! * `route` used to pick the least-total replica first and then reject
//!   if *that* replica's queue was full — even when another replica had
//!   headroom. Eligibility is now filtered before the min.
//! * `on_started` used to `debug_assert` + `saturating_sub` on a
//!   double-start, which silently corrupted the queued/running split in
//!   release builds. Transitions are now ledger-driven: a spurious
//!   start/finish is an explicit no-op, counted and surfaced in
//!   [`RouterStats`], never a corruption.
//! * `route` used to blind-`insert` into the ledger, so re-routing a
//!   still-open id (a retry raced with its failure notification) leaked
//!   the old entry's queued/token counters forever. A re-route now
//!   releases the stale ledger first and counts in `spurious_routes`.
//!
//! The fleet layer (`coordinator::fleet`) adds two lifecycle inputs: a
//! per-replica [`ReplicaHealth`] gate (Unhealthy/Draining replicas take
//! no new work) and [`Router::on_failed`], which returns an evacuated
//! request's counters from whichever phase it was in so it can be
//! re-routed with exact accounting.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::request::{Request, RequestId};

/// Load snapshot the router keeps per replica.
#[derive(Debug, Clone, Default)]
pub struct ReplicaLoad {
    pub queued: usize,
    pub running: usize,
    /// Worst-case token footprint (`prompt + max_new`) of every request
    /// currently routed here (queued + running) — the TGI
    /// `max_batch_total_tokens` analogue at the routing layer.
    pub tokens: usize,
}

impl ReplicaLoad {
    pub fn total(&self) -> usize {
        self.queued + self.running
    }
}

/// Routing decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Route {
    pub replica: usize,
}

/// Health gate the fleet layer sets per replica. Only `Healthy` replicas
/// are eligible for new work; the distinction between the other two is
/// what happens to work already on the replica (evacuated vs drained) —
/// the router treats both as "route nothing here".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplicaHealth {
    #[default]
    Healthy,
    /// Stalled or crashed: no new work; inflight is evacuated.
    Unhealthy,
    /// Finishing inflight work, admitting nothing new.
    Draining,
}

/// Lifecycle counters. `spurious_starts` / `spurious_finishes` /
/// `spurious_fails` count out-of-protocol transition calls (double-start,
/// finish-without-route); each was a no-op, but a non-zero value means a
/// caller is broken. `spurious_routes` counts re-routes of a still-open
/// id — the stale ledger was released first, so counters stay exact.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RouterStats {
    pub routed: u64,
    pub rejected: u64,
    /// Requests returned via [`Router::on_failed`] (failover events).
    pub failed: u64,
    pub spurious_starts: u64,
    pub spurious_finishes: u64,
    pub spurious_fails: u64,
    pub spurious_routes: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReqPhase {
    Queued,
    Running,
}

#[derive(Debug, Clone, Copy)]
struct Ledger {
    replica: usize,
    phase: ReqPhase,
    tokens: usize,
}

/// Least-loaded router over eligible replicas, with per-replica queue and
/// token-budget bounds.
#[derive(Debug)]
pub struct Router {
    loads: Vec<ReplicaLoad>,
    health: Vec<ReplicaHealth>,
    max_queue_per_replica: usize,
    /// Worst-case token budget per replica (0 = unbounded). A replica
    /// with nothing in flight is always eligible — one oversized request
    /// must not deadlock the deployment.
    max_tokens_per_replica: usize,
    inflight: HashMap<RequestId, Ledger>,
    stats: RouterStats,
}

impl Router {
    pub fn new(replicas: usize, max_queue_per_replica: usize) -> Self {
        assert!(replicas > 0);
        Self {
            loads: vec![ReplicaLoad::default(); replicas],
            health: vec![ReplicaHealth::Healthy; replicas],
            max_queue_per_replica,
            max_tokens_per_replica: 0,
            inflight: HashMap::new(),
            stats: RouterStats::default(),
        }
    }

    /// Bound each replica's in-flight worst-case token footprint
    /// (0 = unbounded).
    pub fn with_token_budget(mut self, max_tokens_per_replica: usize) -> Self {
        self.max_tokens_per_replica = max_tokens_per_replica;
        self
    }

    pub fn replicas(&self) -> usize {
        self.loads.len()
    }

    pub fn load(&self, replica: usize) -> &ReplicaLoad {
        &self.loads[replica]
    }

    pub fn stats(&self) -> RouterStats {
        self.stats
    }

    pub fn health(&self, replica: usize) -> ReplicaHealth {
        self.health[replica]
    }

    /// Set the fleet-layer health gate for `replica`. Affects routing of
    /// *future* requests only; inflight ledgers are untouched (the fleet
    /// evacuates them through [`Self::on_failed`] if it wants them back).
    pub fn set_health(&mut self, replica: usize, health: ReplicaHealth) {
        self.health[replica] = health;
    }

    fn eligible(&self, replica: usize, tokens: usize) -> bool {
        if self.health[replica] != ReplicaHealth::Healthy {
            return false;
        }
        let l = &self.loads[replica];
        if l.queued >= self.max_queue_per_replica {
            return false;
        }
        if self.max_tokens_per_replica > 0
            && l.total() > 0
            && l.tokens + tokens > self.max_tokens_per_replica
        {
            return false;
        }
        true
    }

    /// Route a request to the least-loaded replica *with headroom*, or
    /// reject when no replica is eligible (back-pressure to the client).
    /// A full queue on the globally least-loaded replica does not reject
    /// while any other replica still has room.
    pub fn route(&mut self, req: &Request) -> Result<Route> {
        let tokens = req.max_total_len();
        let pick = (0..self.loads.len())
            .filter(|&i| self.eligible(i, tokens))
            .min_by_key(|&i| self.loads[i].total());
        let Some(idx) = pick else {
            self.stats.rejected += 1;
            bail!(
                "all replicas saturated (queue bound {}, token budget {})",
                self.max_queue_per_replica,
                self.max_tokens_per_replica
            );
        };
        // Re-routing a still-open id must release the stale ledger first,
        // or its queued/token counters leak forever (regression-tested).
        if let Some(stale) = self.inflight.remove(&req.id) {
            self.release_counters(stale);
            self.stats.spurious_routes += 1;
        }
        self.inflight
            .insert(req.id, Ledger { replica: idx, phase: ReqPhase::Queued, tokens });
        self.loads[idx].queued += 1;
        self.loads[idx].tokens += tokens;
        self.stats.routed += 1;
        Ok(Route { replica: idx })
    }

    fn release_counters(&mut self, entry: Ledger) {
        let l = &mut self.loads[entry.replica];
        match entry.phase {
            ReqPhase::Queued => l.queued -= 1,
            ReqPhase::Running => l.running -= 1,
        }
        l.tokens -= entry.tokens;
    }

    /// Replica picked up the request (queued → running). A start for an
    /// unknown or already-running request is a counted no-op — the load
    /// split stays exact instead of silently corrupting.
    pub fn on_started(&mut self, id: RequestId) {
        match self.inflight.get_mut(&id) {
            Some(entry) if entry.phase == ReqPhase::Queued => {
                entry.phase = ReqPhase::Running;
                let l = &mut self.loads[entry.replica];
                l.queued -= 1;
                l.running += 1;
            }
            _ => self.stats.spurious_starts += 1,
        }
    }

    /// Replica finished (or refused) the request: it leaves the ledger
    /// from whichever phase it was in. A finish for an unknown request is
    /// a counted no-op.
    pub fn on_finished(&mut self, id: RequestId) {
        match self.inflight.remove(&id) {
            Some(entry) => self.release_counters(entry),
            None => self.stats.spurious_finishes += 1,
        }
    }

    /// The request's replica crashed, stalled, or was otherwise unable to
    /// complete it: the ledger entry is released from whichever phase it
    /// was in (the fleet layer then decides retry vs `Failed`). A fail
    /// for an unknown request is a counted no-op.
    pub fn on_failed(&mut self, id: RequestId) {
        match self.inflight.remove(&id) {
            Some(entry) => {
                self.release_counters(entry);
                self.stats.failed += 1;
            }
            None => self.stats.spurious_fails += 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1], 1)
    }

    #[test]
    fn routes_to_least_loaded() {
        let mut r = Router::new(3, 10);
        let a = r.route(&req(1)).unwrap();
        let b = r.route(&req(2)).unwrap();
        let c = r.route(&req(3)).unwrap();
        let mut seen = vec![a.replica, b.replica, c.replica];
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2], "spreads across replicas");
    }

    #[test]
    fn rejects_when_saturated() {
        let mut r = Router::new(2, 1);
        r.route(&req(1)).unwrap();
        r.route(&req(2)).unwrap();
        assert!(r.route(&req(3)).is_err());
        let s = r.stats();
        assert_eq!((s.routed, s.rejected), (2, 1));
    }

    #[test]
    fn full_queue_on_least_total_replica_does_not_reject() {
        // Regression: replica 0 has a full queue but the smaller total
        // (queued = cap, running = 0); replica 1 is queue-empty but busy
        // (running = cap + 1). The old min-by-total-then-check picked
        // replica 0 and rejected; the request must route to replica 1.
        let cap = 2;
        let mut r = Router::new(2, cap);
        // route-and-start 6 requests: least-loaded alternates 0,1,0,1,0,1
        for id in 0..6 {
            let route = r.route(&req(id)).unwrap();
            assert_eq!(route.replica, id as usize % 2);
            r.on_started(id);
        }
        // drain replica 0 and queue fresh work there (it is now idle, so
        // least-loaded sends both its way without starting them)
        for id in [0, 2, 4] {
            r.on_finished(id);
        }
        r.route(&req(6)).unwrap();
        r.route(&req(7)).unwrap();
        assert_eq!((r.load(0).queued, r.load(0).running), (cap, 0));
        assert_eq!((r.load(1).queued, r.load(1).running), (0, cap + 1));
        // replica 0 has the smaller total (2 < 3) but a full queue
        let route = r.route(&req(999)).unwrap();
        assert_eq!(route.replica, 1, "queue headroom beats smaller total");
        assert_eq!(r.stats().rejected, 0);
    }

    #[test]
    fn lifecycle_counts() {
        let mut r = Router::new(1, 8);
        r.route(&req(1)).unwrap();
        assert_eq!(r.load(0).queued, 1);
        r.on_started(1);
        assert_eq!((r.load(0).queued, r.load(0).running), (0, 1));
        r.on_finished(1);
        assert_eq!(r.load(0).running, 0);
        assert_eq!(r.load(0).tokens, 0, "token footprint returned");
    }

    #[test]
    fn double_start_and_double_finish_are_counted_noops() {
        // Regression: a double on_started used to decrement queued twice
        // (saturating to 0) while incrementing running twice — permanent
        // load-counter drift. Now: explicit no-op + telemetry.
        let mut r = Router::new(1, 8);
        r.route(&req(1)).unwrap();
        r.on_started(1);
        r.on_started(1); // duplicate
        assert_eq!((r.load(0).queued, r.load(0).running), (0, 1));
        r.on_finished(1);
        r.on_finished(1); // duplicate
        assert_eq!((r.load(0).queued, r.load(0).running), (0, 0));
        r.on_started(42); // never routed
        let s = r.stats();
        assert_eq!(s.spurious_starts, 2);
        assert_eq!(s.spurious_finishes, 1);
        assert_eq!(r.load(0).tokens, 0);
    }

    #[test]
    fn finish_from_queued_phase_releases_the_queue_slot() {
        // A request the replica refuses (front-door rejection) finishes
        // without ever starting; its queue slot and tokens must free.
        let mut r = Router::new(1, 1);
        r.route(&req(1)).unwrap();
        assert!(r.route(&req(2)).is_err(), "queue bound 1");
        r.on_finished(1);
        assert_eq!((r.load(0).queued, r.load(0).tokens), (0, 0));
        r.route(&req(3)).unwrap();
    }

    #[test]
    fn token_budget_bounds_inflight_footprint() {
        // budget 16 per replica; each request's worst case is 10 tokens
        let big = |id| Request::new(id, vec![1; 4], 6);
        let mut r = Router::new(2, 100).with_token_budget(16);
        assert_eq!(r.route(&big(1)).unwrap().replica, 0);
        assert_eq!(r.route(&big(2)).unwrap().replica, 1);
        // both replicas at 10/16: +10 would overshoot everywhere
        assert!(r.route(&big(3)).is_err());
        assert_eq!(r.stats().rejected, 1);
        r.on_finished(1);
        assert_eq!(r.route(&big(4)).unwrap().replica, 0);
        // an oversized lone request still routes to an empty replica
        r.on_finished(2);
        let huge = Request::new(9, vec![1; 20], 20);
        assert_eq!(r.route(&huge).unwrap().replica, 1, "empty replica never starves");
    }

    #[test]
    fn unhealthy_and_draining_replicas_take_no_new_work() {
        let mut r = Router::new(2, 10);
        r.set_health(0, ReplicaHealth::Unhealthy);
        assert_eq!(r.route(&req(1)).unwrap().replica, 1);
        assert_eq!(r.route(&req(2)).unwrap().replica, 1, "never the unhealthy one");
        r.set_health(1, ReplicaHealth::Draining);
        assert!(r.route(&req(3)).is_err(), "no healthy replica left");
        r.set_health(0, ReplicaHealth::Healthy);
        assert_eq!(r.route(&req(4)).unwrap().replica, 0, "recovery restores eligibility");
        assert_eq!(r.health(1), ReplicaHealth::Draining);
    }

    #[test]
    fn on_failed_releases_counters_from_either_phase() {
        let mut r = Router::new(1, 8);
        r.route(&req(1)).unwrap(); // fails from Queued
        r.route(&req(2)).unwrap();
        r.on_started(2); // fails from Running
        r.on_failed(1);
        r.on_failed(2);
        r.on_failed(99); // never routed
        let l = r.load(0);
        assert_eq!((l.queued, l.running, l.tokens), (0, 0, 0));
        let s = r.stats();
        assert_eq!((s.failed, s.spurious_fails), (2, 1));
    }

    #[test]
    fn rerouting_an_open_id_releases_the_stale_ledger() {
        // Regression: `route` blind-inserted into the ledger, so routing
        // an id that was still inflight leaked the old entry's queued and
        // token counters permanently.
        let mut r = Router::new(1, 8);
        r.route(&req(1)).unwrap();
        r.on_started(1);
        r.route(&req(1)).unwrap(); // re-route without on_failed/on_finished
        let l = r.load(0);
        assert_eq!((l.queued, l.running), (1, 0), "stale running slot released");
        assert_eq!(l.tokens, req(1).max_total_len(), "tokens counted once");
        r.on_finished(1);
        let l = r.load(0);
        assert_eq!((l.queued, l.running, l.tokens), (0, 0, 0));
        assert_eq!(r.stats().spurious_routes, 1);
    }

    #[test]
    fn property_load_is_balanced() {
        // After routing N requests with immediate pickup, replica loads
        // differ by at most 1.
        let mut r = Router::new(4, 1000);
        let mut rng = Rng::seed_from_u64(3);
        for id in 0..200 {
            let route = r.route(&req(id)).unwrap();
            r.on_started(id);
            // randomly finish some work
            if rng.bool() {
                r.on_finished(id);
            }
            let _ = route;
        }
        let loads: Vec<usize> = (0..4).map(|i| r.load(i).total()).collect();
        let (lo, hi) = (loads.iter().min().unwrap(), loads.iter().max().unwrap());
        assert!(hi - lo <= 2, "{loads:?}");
    }
}
