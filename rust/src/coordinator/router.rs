//! Request router: admission control and replica selection.
//!
//! Mirrors the vLLM router architecture: a front door that (a) rejects
//! work beyond a queue bound, (b) picks the least-loaded engine replica,
//! and (c) tracks per-replica in-flight counts. The demo deployment runs
//! one replica per process, but the policy is replica-count generic and is
//! exercised with many simulated replicas in tests.

use anyhow::{bail, Result};

use super::request::{Request, RequestId};

/// Load snapshot the router keeps per replica.
#[derive(Debug, Clone, Default)]
pub struct ReplicaLoad {
    pub queued: usize,
    pub running: usize,
}

impl ReplicaLoad {
    pub fn total(&self) -> usize {
        self.queued + self.running
    }
}

/// Routing decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Route {
    pub replica: usize,
}

/// Least-loaded router with a global queue bound.
#[derive(Debug)]
pub struct Router {
    loads: Vec<ReplicaLoad>,
    max_queue_per_replica: usize,
    routed: u64,
    rejected: u64,
}

impl Router {
    pub fn new(replicas: usize, max_queue_per_replica: usize) -> Self {
        assert!(replicas > 0);
        Self {
            loads: vec![ReplicaLoad::default(); replicas],
            max_queue_per_replica,
            routed: 0,
            rejected: 0,
        }
    }

    pub fn replicas(&self) -> usize {
        self.loads.len()
    }

    pub fn load(&self, replica: usize) -> &ReplicaLoad {
        &self.loads[replica]
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.routed, self.rejected)
    }

    /// Route a request to the least-loaded replica, or reject when every
    /// replica's queue is full (back-pressure to the client).
    pub fn route(&mut self, _req: &Request) -> Result<Route> {
        let (idx, load) = self
            .loads
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| l.total())
            .expect("at least one replica");
        if load.queued >= self.max_queue_per_replica {
            self.rejected += 1;
            bail!("all replicas saturated (queue bound {})", self.max_queue_per_replica);
        }
        self.loads[idx].queued += 1;
        self.routed += 1;
        Ok(Route { replica: idx })
    }

    /// Replica picked up the request (queued -> running).
    pub fn on_started(&mut self, replica: usize) {
        let l = &mut self.loads[replica];
        debug_assert!(l.queued > 0);
        l.queued = l.queued.saturating_sub(1);
        l.running += 1;
    }

    /// Replica finished a request.
    pub fn on_finished(&mut self, replica: usize, _id: RequestId) {
        let l = &mut self.loads[replica];
        l.running = l.running.saturating_sub(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1], 1)
    }

    #[test]
    fn routes_to_least_loaded() {
        let mut r = Router::new(3, 10);
        let a = r.route(&req(1)).unwrap();
        let b = r.route(&req(2)).unwrap();
        let c = r.route(&req(3)).unwrap();
        let mut seen = vec![a.replica, b.replica, c.replica];
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2], "spreads across replicas");
    }

    #[test]
    fn rejects_when_saturated() {
        let mut r = Router::new(2, 1);
        r.route(&req(1)).unwrap();
        r.route(&req(2)).unwrap();
        assert!(r.route(&req(3)).is_err());
        assert_eq!(r.stats(), (2, 1));
    }

    #[test]
    fn lifecycle_counts() {
        let mut r = Router::new(1, 8);
        let route = r.route(&req(1)).unwrap();
        assert_eq!(r.load(0).queued, 1);
        r.on_started(route.replica);
        assert_eq!((r.load(0).queued, r.load(0).running), (0, 1));
        r.on_finished(route.replica, 1);
        assert_eq!(r.load(0).running, 0);
    }

    #[test]
    fn property_load_is_balanced() {
        // After routing N requests with immediate pickup, replica loads
        // differ by at most 1.
        let mut r = Router::new(4, 1000);
        let mut rng = Rng::seed_from_u64(3);
        for id in 0..200 {
            let route = r.route(&req(id)).unwrap();
            r.on_started(route.replica);
            // randomly finish some work
            if rng.bool() {
                r.on_finished(route.replica, id);
            }
        }
        let loads: Vec<usize> = (0..4).map(|i| r.load(i).total()).collect();
        let (lo, hi) = (loads.iter().min().unwrap(), loads.iter().max().unwrap());
        assert!(hi - lo <= 2, "{loads:?}");
    }
}
