//! The real serving backend: AOT decode-step executables on PJRT.
//!
//! Holds one compiled executable per batch bucket (all sharing one
//! parameter upload) and adapts between the engine's flat plane layout and
//! the manifest's tensor shapes (identical memory layout, only the shape
//! metadata differs).

use anyhow::{Context, Result};

use crate::runtime::xla;
use crate::runtime::{HostTensor, Runtime};

use super::engine::{Backend, ModelGeom, StepOut};

/// PJRT-backed [`Backend`] for one model.
pub struct PjrtBackend {
    rt: Runtime,
    model: String,
    buckets: Vec<usize>,
    params: Vec<xla::PjRtBuffer>,
    geom: ModelGeom,
}

// SAFETY: the xla crate's client/executable/buffer handles are internally
// `Rc` + raw PJRT pointers, hence `!Send`. A `PjrtBackend` owns its
// `Runtime` (the client and every executable/buffer clone of it) entirely —
// no handle ever escapes this struct — so moving the *whole backend* to the
// server thread transfers exclusive ownership of every Rc clone at once,
// which is sound. The engine/server never share a backend across threads
// (the engine loop is single-threaded by design).
unsafe impl Send for PjrtBackend {}

impl PjrtBackend {
    /// Load every serving bucket of `model` from `artifacts_dir`, compile,
    /// and upload one random parameter set (seeded).
    pub fn load(artifacts_dir: &str, model: &str, seed: u64) -> Result<Self> {
        let mut rt = Runtime::open(artifacts_dir)?;
        let buckets = rt.manifest.serving_buckets(model);
        anyhow::ensure!(!buckets.is_empty(), "no serving artifacts for {model}");
        for &b in &buckets {
            rt.load(model, b, true).with_context(|| format!("loading bucket {b}"))?;
        }
        let iface = rt.manifest.require(model, buckets[0], true)?.clone();
        let planes = iface.n_cache;
        let row_elems = match iface.attn.as_str() {
            "mha" => iface.n_heads * iface.head_dim,
            "mla" => iface.kv_lora_rank,
            other => anyhow::bail!("unknown attn kind {other}"),
        };
        let geom = ModelGeom {
            vocab: iface.vocab,
            n_layers: iface.n_layers,
            row_elems,
            planes,
            max_seq: iface.max_seq,
        };
        let params = rt.random_params(&iface, seed)?;
        Ok(Self { rt, model: model.to_string(), buckets, params, geom })
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn platform(&self) -> String {
        self.rt.platform()
    }
}

impl Backend for PjrtBackend {
    fn geom(&self) -> ModelGeom {
        self.geom
    }

    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn step(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        cache_planes: &[Vec<f32>],
    ) -> Result<StepOut> {
        let exe = self.rt.get(&self.model, bucket, true)?;
        let iface = exe.iface.clone();
        // engine plane layout (L, B, S, row_elems) has the same memory
        // layout as the manifest's cache spec; only shape metadata differs.
        let caches: Vec<HostTensor> = cache_planes
            .iter()
            .zip(iface.cache_specs())
            .map(|(data, spec)| {
                anyhow::ensure!(
                    data.len() == spec.elems(),
                    "plane has {} elems, spec {:?} wants {}",
                    data.len(),
                    spec.shape,
                    spec.elems()
                );
                Ok(HostTensor { shape: spec.shape.clone(), data: data.clone() })
            })
            .collect::<Result<_>>()?;
        let exe = self.rt.get(&self.model, bucket, true)?;
        let outs = self.rt.decode_step(exe, tokens, pos, &caches, &self.params)?;
        let mut it = outs.into_iter();
        let logits = it.next().context("missing logits output")?;
        let new_rows: Vec<Vec<f32>> = it.map(|t| t.data).collect();
        anyhow::ensure!(new_rows.len() == self.geom.planes, "plane count mismatch");
        Ok(StepOut { logits: logits.data, new_rows })
    }
}
