//! The real serving backend: AOT decode-step executables on PJRT.
//!
//! Holds one compiled executable per batch bucket (all sharing one
//! parameter upload) and adapts between the engine's flat plane layout and
//! the manifest's tensor shapes (identical memory layout, only the shape
//! metadata differs).

use anyhow::{Context, Result};

use crate::runtime::xla;
use crate::runtime::{HostTensor, Runtime};

use super::engine::{Backend, ModelGeom, SlotRows, StepOut};

/// PJRT-backed [`Backend`] for one model.
pub struct PjrtBackend {
    rt: Runtime,
    model: String,
    buckets: Vec<usize>,
    params: Vec<xla::PjRtBuffer>,
    geom: ModelGeom,
}

// SAFETY: the xla crate's client/executable/buffer handles are internally
// `Rc` + raw PJRT pointers, hence `!Send`. A `PjrtBackend` owns its
// `Runtime` (the client and every executable/buffer clone of it) entirely —
// no handle ever escapes this struct — so moving the *whole backend* to the
// server thread transfers exclusive ownership of every Rc clone at once,
// which is sound. The engine/server never share a backend across threads
// (the engine loop is single-threaded by design).
unsafe impl Send for PjrtBackend {}

impl PjrtBackend {
    /// Load every serving bucket of `model` from `artifacts_dir`, compile,
    /// and upload one random parameter set (seeded).
    pub fn load(artifacts_dir: &str, model: &str, seed: u64) -> Result<Self> {
        let mut rt = Runtime::open(artifacts_dir)?;
        let buckets = rt.manifest.serving_buckets(model);
        anyhow::ensure!(!buckets.is_empty(), "no serving artifacts for {model}");
        for &b in &buckets {
            rt.load(model, b, true).with_context(|| format!("loading bucket {b}"))?;
        }
        let iface = rt.manifest.require(model, buckets[0], true)?.clone();
        let planes = iface.n_cache;
        let row_elems = match iface.attn.as_str() {
            "mha" => iface.n_heads * iface.head_dim,
            "mla" => iface.kv_lora_rank,
            other => anyhow::bail!("unknown attn kind {other}"),
        };
        let geom = ModelGeom {
            vocab: iface.vocab,
            n_layers: iface.n_layers,
            row_elems,
            planes,
            max_seq: iface.max_seq,
        };
        let params = rt.random_params(&iface, seed)?;
        Ok(Self { rt, model: model.to_string(), buckets, params, geom })
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn platform(&self) -> String {
        self.rt.platform()
    }
}

impl Backend for PjrtBackend {
    fn geom(&self) -> ModelGeom {
        self.geom
    }

    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn step(
        &mut self,
        bucket: usize,
        slots: &[SlotRows],
        cache_planes: &mut [Vec<f32>],
    ) -> Result<StepOut> {
        let iface = self.rt.get(&self.model, bucket, true)?.iface.clone();
        let g = self.geom;
        let n_slots = slots.len();
        let total_rows: usize = slots.iter().map(SlotRows::rows).sum();
        let max_rows = slots.iter().map(SlotRows::rows).max().unwrap_or(0);
        let mut row_base = Vec::with_capacity(n_slots);
        let mut acc = 0usize;
        for s in slots {
            row_base.push(acc);
            acc += s.rows();
        }
        let mut logits = vec![0.0f32; n_slots * g.vocab];
        let mut new_rows: Vec<Vec<f32>> =
            vec![vec![0.0f32; g.n_layers * total_rows * g.row_elems]; g.planes];

        // The AOT artifacts are single-position decode steps, so a
        // multi-row chunk runs as `max_rows` inner calls: after each call
        // the fresh KV rows are written back into the gathered planes
        // (engine layout (L, B, S, row_elems)) so later prompt rows
        // attend over them. Slots shorter than `max_rows` re-feed their
        // last row as a padding lane; its outputs are not scattered.
        for r in 0..max_rows {
            let mut tokens = vec![0i32; iface.batch];
            let mut pos = vec![0i32; iface.batch];
            for (i, s) in slots.iter().enumerate() {
                let rr = r.min(s.rows() - 1);
                tokens[i] = s.tokens[rr];
                pos[i] = (s.pos0 + rr) as i32;
            }
            // engine plane layout has the same memory layout as the
            // manifest's cache spec; only shape metadata differs.
            let caches: Vec<HostTensor> = cache_planes
                .iter()
                .zip(iface.cache_specs())
                .map(|(data, spec)| {
                    anyhow::ensure!(
                        data.len() == spec.elems(),
                        "plane has {} elems, spec {:?} wants {}",
                        data.len(),
                        spec.shape,
                        spec.elems()
                    );
                    Ok(HostTensor { shape: spec.shape.clone(), data: data.clone() })
                })
                .collect::<Result<_>>()?;
            let exe = self.rt.get(&self.model, bucket, true)?;
            let outs = self.rt.decode_step(exe, &tokens, &pos, &caches, &self.params)?;
            let mut it = outs.into_iter();
            let step_logits = it.next().context("missing logits output")?;
            let step_rows: Vec<Vec<f32>> = it.map(|t| t.data).collect();
            anyhow::ensure!(step_rows.len() == g.planes, "plane count mismatch");
            for (i, s) in slots.iter().enumerate() {
                if r >= s.rows() {
                    continue; // padding lane
                }
                if r == s.rows() - 1 {
                    let o = i * g.vocab;
                    logits[o..o + g.vocab].copy_from_slice(&step_logits.data[o..o + g.vocab]);
                }
                for (plane, rows) in step_rows.iter().enumerate() {
                    for l in 0..g.n_layers {
                        let src = (l * iface.batch + i) * g.row_elems;
                        let row = &rows[src..src + g.row_elems];
                        let dst = (l * total_rows + row_base[i] + r) * g.row_elems;
                        new_rows[plane][dst..dst + g.row_elems].copy_from_slice(row);
                        let cp = ((l * bucket + i) * g.max_seq + s.pos0 + r) * g.row_elems;
                        cache_planes[plane][cp..cp + g.row_elems].copy_from_slice(row);
                    }
                }
            }
        }
        Ok(StepOut { logits, new_rows })
    }
}
