//! Latency-targeted admission control: the serving front door.
//!
//! The batcher and scheduler react *after* saturation (preemption, FCFS
//! head blocking); this module shapes load *before* it enters the engine,
//! TGI-router style (`waiting_served_ratio` / `max_batch_total_tokens` in
//! `router/src/infer.rs`). Three independent knobs, all off by default so
//! an unconfigured engine behaves exactly as before:
//!
//! * **Token budget** (`max_batch_total_tokens`): admission stops growing
//!   the running set once the sum of worst-case token footprints
//!   (`prompt + max_new`) of running sequences would exceed the budget —
//!   KV-footprint admission by tokens, not request count. A lone request
//!   larger than the whole budget still runs (the batch is never starved
//!   to zero).
//! * **Growth gate** (`waiting_served_ratio` + `max_waiting_steps`):
//!   between decode steps, waiting requests may force batch growth only
//!   when the queue is at least `ratio × running` deep — small dribbles
//!   wait for a worthwhile prefill batch instead of repeatedly disturbing
//!   decode cadence. `max_waiting_steps` bounds the wait: after that many
//!   steps without growth, admission is forced regardless of the ratio.
//! * **SLO projection** (`slo_ttft_us` / `slo_tpot_us`): `submit` projects
//!   the marginal TTFT of the queue head from [`ServiceModel`] step costs
//!   and rejects requests whose projection breaches the TTFT target
//!   (back-pressure instead of an unbounded queue); the TPOT target caps
//!   the decode batch at the largest width whose step cost still meets it.
//!
//! Determinism rule (DESIGN.md §4): every decision here is a pure function
//! of engine-visible state (queue depths, fed counts, step counter) and
//! the static config — no wall-clock reads, no randomness — so
//! virtual-clock replay through the front door stays single-writer and
//! byte-deterministic.

use crate::loadgen::ServiceModel;

/// Outcome of [`crate::coordinator::engine::Engine::submit`] with the
/// front door active. Rejections emit a `Finished` event with
/// [`crate::coordinator::request::FinishReason::Rejected`] and record no
/// timing (latency percentiles cover admitted requests only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitOutcome {
    /// Accepted into the waiting queue.
    Queued,
    /// `prompt + max_new_tokens` exceeds the model context window
    /// (`CacheGeometry::max_seq`): the request could only ever end in a
    /// truncated `CacheFull` stop, so it is refused up front.
    RejectedTooLong,
    /// Projected TTFT of serving this request behind the current backlog
    /// breaches `slo_ttft_us`.
    RejectedSlo,
    /// The request carried a `deadline_us` that has already passed, or
    /// whose projected TTFT lands past it — it could only ever expire in
    /// the queue, so it is refused up front.
    RejectedDeadline,
}

impl SubmitOutcome {
    pub fn is_queued(&self) -> bool {
        matches!(self, Self::Queued)
    }
}

/// Front-door configuration. [`AdmissionConfig::off`] (the `Default`)
/// disables every check: submit always queues, admission fills the batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionConfig {
    /// Token-budget bound on the running set: sum of worst-case footprints
    /// (`prompt + max_new`) of concurrently running sequences. 0 = off.
    pub max_batch_total_tokens: usize,
    /// Waiting requests may grow a non-empty batch only when
    /// `waiting >= ratio * running` (TGI `waiting_served_ratio`).
    /// 0.0 = off: admission never defers.
    pub waiting_served_ratio: f64,
    /// Force growth after this many steps without it, bounding the
    /// ratio gate's worst-case deferral. 0 = never force.
    pub max_waiting_steps: u64,
    /// Reject at submit when projected TTFT exceeds this, µs. 0 = off.
    pub slo_ttft_us: u64,
    /// Cap decode batch width so one step stays within this, µs. 0 = off.
    pub slo_tpot_us: u64,
    /// Step-cost model the projections price against (the same model
    /// `loadgen::replay` bills, so projection and virtual clock agree).
    pub service: ServiceModel,
}

impl AdmissionConfig {
    /// Everything disabled: byte-identical behaviour to an engine without
    /// a front door.
    pub fn off() -> Self {
        Self {
            max_batch_total_tokens: 0,
            waiting_served_ratio: 0.0,
            max_waiting_steps: 0,
            slo_ttft_us: 0,
            slo_tpot_us: 0,
            service: ServiceModel {
                step_base_us: 0,
                step_per_seq_us: 0,
                step_prefill_token_us: 0,
            },
        }
    }

    /// True when no knob is active (submit/admission take the fast path).
    pub fn is_off(&self) -> bool {
        self.max_batch_total_tokens == 0
            && self.waiting_served_ratio <= 0.0
            && self.slo_ttft_us == 0
            && self.slo_tpot_us == 0
    }

    /// Largest decode batch width (in 1..=`max_batch`) whose worst-case
    /// step cost — decode slots plus a full `chunk`-row prefill budget —
    /// still meets the TPOT SLO. Never below 1 (a lone sequence must be
    /// allowed to decode even when the SLO is unmeetable); `max_batch`
    /// when the TPOT SLO is off.
    pub fn decode_slot_cap(&self, max_batch: usize, chunk: usize) -> usize {
        if self.slo_tpot_us == 0 {
            return max_batch;
        }
        let mut cap = 1;
        for cand in 1..=max_batch {
            if self.service.step_us(cand, chunk) <= self.slo_tpot_us {
                cap = cand;
            }
        }
        cap
    }

    /// Projected time for `backlog_rows` outstanding prompt rows (queue +
    /// partially-fed running prompts + the candidate) to clear the shared
    /// prefill budget, priced at the worst mixed step (`max_batch - 1`
    /// decode slots riding along with each chunk), µs. With one-shot
    /// prefill (`chunk == 0`) each backlogged prompt costs one step
    /// billed at its own row count.
    pub fn projected_ttft_us(
        &self,
        backlog_rows: usize,
        backlog_prompts: usize,
        prompt_rows: usize,
        max_batch: usize,
        chunk: usize,
    ) -> u64 {
        let decode_ride = max_batch.saturating_sub(1);
        if chunk > 0 {
            let steps = (backlog_rows + prompt_rows).div_ceil(chunk) as u64;
            steps * self.service.step_us(decode_ride, chunk)
        } else {
            let steps = (backlog_prompts + 1) as u64;
            steps * self.service.step_us(decode_ride, prompt_rows)
        }
    }

    /// Growth gate: may this step admit from a non-empty queue into a
    /// non-empty batch? (An empty batch or empty queue always passes —
    /// the gate only defers *growth*, never first admission or drain.)
    /// `steps_since_growth` is the current step count minus the step of
    /// the last successful admission.
    pub fn growth_allowed(
        &self,
        waiting: usize,
        running: usize,
        steps_since_growth: u64,
    ) -> bool {
        if self.waiting_served_ratio <= 0.0 || running == 0 || waiting == 0 {
            return true;
        }
        if self.max_waiting_steps > 0 && steps_since_growth >= self.max_waiting_steps {
            return true;
        }
        waiting as f64 >= self.waiting_served_ratio * running as f64
    }
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self::off()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The load-suite service model: 200 + 50·decode + 50·prefill µs,
    /// floored at one decode slot (step_us(d, 4) = 400 + 50·d).
    fn svc() -> ServiceModel {
        ServiceModel { step_base_us: 200, step_per_seq_us: 50, step_prefill_token_us: 50 }
    }

    fn with_slo(slo_ttft_us: u64, slo_tpot_us: u64) -> AdmissionConfig {
        AdmissionConfig { slo_ttft_us, slo_tpot_us, service: svc(), ..AdmissionConfig::off() }
    }

    #[test]
    fn off_config_gates_nothing() {
        let a = AdmissionConfig::off();
        assert!(a.is_off());
        assert_eq!(a.decode_slot_cap(8, 4), 8);
        assert!(a.growth_allowed(100, 8, 0));
        // zero service model projects zero: nothing could ever breach
        assert_eq!(a.projected_ttft_us(1000, 10, 16, 8, 4), 0);
    }

    #[test]
    fn decode_slot_cap_tracks_the_tpot_target() {
        // step_us(d, chunk=4) = 400 + 50·d
        assert_eq!(with_slo(0, 500).decode_slot_cap(8, 4), 2);
        assert_eq!(with_slo(0, 600).decode_slot_cap(8, 4), 4);
        assert_eq!(with_slo(0, 750).decode_slot_cap(8, 4), 7);
        // unmeetable target still leaves one slot
        assert_eq!(with_slo(0, 1).decode_slot_cap(8, 4), 1);
        // off = full batch
        assert_eq!(with_slo(0, 0).decode_slot_cap(8, 4), 8);
    }

    #[test]
    fn projected_ttft_prices_the_worst_mixed_step() {
        let a = with_slo(25_000, 0);
        // empty engine, prompt 16, chunk 4, max_batch 8:
        // ceil(16/4) = 4 steps × step_us(7, 4) = 4 × 750 = 3000 µs
        assert_eq!(a.projected_ttft_us(0, 0, 16, 8, 4), 3_000);
        // 16 backlogged rows ahead double it
        assert_eq!(a.projected_ttft_us(16, 1, 16, 8, 4), 6_000);
        // one-shot prefill: (backlog_prompts + 1) steps at the candidate's
        // own row count: 2 × (200 + max(7·50 + 16·50, 50)) = 2 × 1350
        assert_eq!(a.projected_ttft_us(16, 1, 16, 8, 0), 2_700);
    }

    #[test]
    fn growth_gate_defers_until_ratio_or_timeout() {
        let a = AdmissionConfig {
            waiting_served_ratio: 2.0,
            max_waiting_steps: 16,
            ..AdmissionConfig::off()
        };
        // empty batch or empty queue: always allowed
        assert!(a.growth_allowed(5, 0, 0));
        assert!(a.growth_allowed(0, 5, 0));
        // 3 waiting vs 2 running: 3 < 2·2 = deferred
        assert!(!a.growth_allowed(3, 2, 0));
        assert!(a.growth_allowed(4, 2, 0), "ratio met");
        // timeout forces growth past the ratio
        assert!(a.growth_allowed(1, 8, 16));
        assert!(!a.growth_allowed(1, 8, 15));
        // ratio 0 = gate off
        let off = AdmissionConfig { waiting_served_ratio: 0.0, ..a };
        assert!(off.growth_allowed(1, 8, 0));
    }
}
