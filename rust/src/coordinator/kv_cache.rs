//! Paged KV-cache manager (vLLM-style, host-authoritative).
//!
//! The serving engine keeps the KV cache on the host in fixed-size pages
//! so the continuous batcher can recompose batches between steps; each
//! step the engine gathers the active sequences' pages into the padded
//! dense cache tensors the AOT executable expects, and appends the new
//! per-layer rows the device returns (see `python/compile/aot.py`,
//! `serving=True` interface).
//!
//! Page layout: `[layer][plane][slot][row_elems]` — token-major *within*
//! each (layer, plane), so gathering a page into the dense `(L, B, S, re)`
//! executable layout is a handful of large contiguous memcpys per page
//! (the §Perf fix that took gather_batch from ~155 ms to the low
//! milliseconds; see EXPERIMENTS.md §Perf). The per-(layer, plane) offset
//! arithmetic of those memcpys depends only on `(geometry, page_tokens,
//! batch)` and is precomputed into a [`GatherPlan`] cached across decode
//! steps; [`KvPool::gather_plan_runs`] exposes the exact span list so
//! tests can assert the one-memcpy-per-(page, layer, plane) contract.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Sequence identifier (the coordinator uses request ids).
pub type SeqId = u64;

/// Per-model geometry the pool needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheGeometry {
    pub n_layers: usize,
    /// Elements of one token's cache row in one layer for one of the K/V
    /// planes: nh*dh for MHA; r (latent) for MLA.
    pub row_elems: usize,
    /// K and V planes for MHA (2); single latent plane for MLA (1).
    pub planes: usize,
    /// Model context limit (padded dense-cache S).
    pub max_seq: usize,
}

impl CacheGeometry {
    /// Elements one token occupies across all layers and planes.
    pub fn token_elems(&self) -> usize {
        self.n_layers * self.planes * self.row_elems
    }
}

#[derive(Debug, Clone, Default)]
struct SeqEntry {
    pages: Vec<usize>,
    len: usize,
}

/// One contiguous memcpy span of a gather (see
/// [`KvPool::gather_plan_runs`]): `plane[dst..dst + len] <-
/// pool.data[src..src + len]`. Every run stays inside a single page — the
/// page-contiguity property the §Perf layout buys.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CopyRun {
    pub plane: usize,
    pub src: usize,
    pub dst: usize,
    pub len: usize,
}

/// Precomputed offset table for [`KvPool::gather_batch_into`]: the
/// per-(layer, plane) source offset within a page and destination base
/// offset depend only on `(geometry, page_tokens, batch)`, so the plan is
/// built once per batch bucket and reused across steps while the actual
/// page lists churn (the serving engine re-gathers every decode step).
#[derive(Debug, Clone)]
struct GatherPlan {
    batch: usize,
    /// `per_plane[plane]` = per layer: (src offset within the page,
    /// destination offset of the layer block in the plane buffer).
    per_plane: Vec<Vec<(usize, usize)>>,
}

impl GatherPlan {
    fn build(geom: &CacheGeometry, page_tokens: usize, batch: usize) -> Self {
        let per_plane = (0..geom.planes)
            .map(|plane| {
                (0..geom.n_layers)
                    .map(|l| {
                        let src_off = ((l * geom.planes + plane) * page_tokens) * geom.row_elems;
                        let dst_off = l * batch * geom.max_seq * geom.row_elems;
                        (src_off, dst_off)
                    })
                    .collect()
            })
            .collect();
        Self { batch, per_plane }
    }
}

/// Fixed-capacity paged pool.
#[derive(Debug)]
pub struct KvPool {
    geom: CacheGeometry,
    page_tokens: usize,
    data: Vec<f32>,
    free: Vec<usize>,
    seqs: HashMap<SeqId, SeqEntry>,
    n_pages: usize,
    /// Cached gather plan for the last batch bucket (hot-path reuse).
    plan: Option<GatherPlan>,
}

impl KvPool {
    pub fn new(geom: CacheGeometry, page_tokens: usize, n_pages: usize) -> Self {
        assert!(page_tokens > 0 && n_pages > 0);
        let page_elems = page_tokens * geom.token_elems();
        Self {
            geom,
            page_tokens,
            data: vec![0.0; page_elems * n_pages],
            free: (0..n_pages).rev().collect(),
            seqs: HashMap::new(),
            n_pages,
            plan: None,
        }
    }

    pub fn geometry(&self) -> CacheGeometry {
        self.geom
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.n_pages - self.free.len()
    }

    pub fn seq_len(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.len)
    }

    pub fn contains(&self, id: SeqId) -> bool {
        self.seqs.contains_key(&id)
    }

    /// Pages needed to hold `tokens`.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Register a new (empty) sequence.
    pub fn alloc_seq(&mut self, id: SeqId) -> Result<()> {
        if self.seqs.contains_key(&id) {
            bail!("sequence {id} already allocated");
        }
        self.seqs.insert(id, SeqEntry::default());
        Ok(())
    }

    /// Release a sequence and all its pages.
    pub fn free_seq(&mut self, id: SeqId) {
        if let Some(e) = self.seqs.remove(&id) {
            self.free.extend(e.pages);
        }
    }

    /// Will the next append to `id` require a fresh page?
    pub fn needs_new_page(&self, id: SeqId) -> bool {
        match self.seqs.get(&id) {
            Some(e) => e.len == e.pages.len() * self.page_tokens,
            None => false,
        }
    }

    /// Can one more token be appended to `id` without allocation failure?
    pub fn can_append(&self, id: SeqId) -> bool {
        match self.seqs.get(&id) {
            Some(e) => {
                e.len < self.geom.max_seq
                    && (e.len < e.pages.len() * self.page_tokens || !self.free.is_empty())
            }
            None => false,
        }
    }

    fn page_elems(&self) -> usize {
        self.page_tokens * self.geom.token_elems()
    }

    /// Pages a multi-row append of `n_rows` tokens to `id` would have to
    /// allocate (0 for unknown sequences). The engine's pressure loop
    /// sums this over its planned row counts before a step;
    /// `pages_needed(id, 1)` is `needs_new_page` as a count.
    pub fn pages_needed(&self, id: SeqId, n_rows: usize) -> usize {
        match self.seqs.get(&id) {
            Some(e) => self.pages_for(e.len + n_rows).saturating_sub(e.pages.len()),
            None => 0,
        }
    }

    /// Append one token's rows for every (layer, plane).
    ///
    /// `rows[plane]` must be laid out `(n_layers, row_elems)` — exactly the
    /// `k_new` / `v_new` (or `kv_new`) row of one batch slot as returned by
    /// the serving executable. The `n_rows == 1` case of
    /// [`Self::append_rows`].
    pub fn append(&mut self, id: SeqId, rows: &[&[f32]]) -> Result<()> {
        self.append_rows(id, rows, 1)
    }

    /// Append `n_rows` tokens' rows for every (layer, plane) in one call,
    /// allocating pages as boundaries are crossed (a chunked-prefill step
    /// may span several).
    ///
    /// `rows[plane]` is laid out `(n_layers, n_rows, row_elems)` in feed
    /// order — the multi-row generalisation of the single-token layout.
    /// Capacity is validated up front (`max_seq` and free pages), so a
    /// failed call appends nothing.
    pub fn append_rows(&mut self, id: SeqId, rows: &[&[f32]], n_rows: usize) -> Result<()> {
        let g = self.geom;
        anyhow::ensure!(n_rows >= 1, "append_rows needs at least one row");
        anyhow::ensure!(rows.len() == g.planes, "expected {} planes", g.planes);
        for r in rows {
            anyhow::ensure!(r.len() == g.n_layers * n_rows * g.row_elems, "bad row length");
        }
        let page_elems = self.page_elems();
        let page_tokens = self.page_tokens;
        {
            let entry = self.seqs.get(&id).ok_or_else(|| anyhow::anyhow!("unknown seq {id}"))?;
            if entry.len + n_rows > g.max_seq {
                bail!("sequence {id} at max_seq {}", g.max_seq);
            }
            let new_pages = self.pages_for(entry.len + n_rows).saturating_sub(entry.pages.len());
            if new_pages > self.free.len() {
                bail!("kv pool exhausted");
            }
        }
        for r in 0..n_rows {
            let entry = self.seqs.get_mut(&id).expect("checked above");
            if entry.len == entry.pages.len() * page_tokens {
                let page = self.free.pop().expect("capacity checked above");
                entry.pages.push(page);
            }
            let t = entry.len;
            let page = entry.pages[t / page_tokens];
            let slot = t % page_tokens;
            // page layout: [layer][plane][slot][re]
            for (plane, row) in rows.iter().enumerate() {
                for l in 0..g.n_layers {
                    let dst = page * page_elems
                        + ((l * g.planes + plane) * page_tokens + slot) * g.row_elems;
                    let src = &row[(l * n_rows + r) * g.row_elems..(l * n_rows + r + 1) * g.row_elems];
                    self.data[dst..dst + g.row_elems].copy_from_slice(src);
                }
            }
            entry.len += 1;
        }
        Ok(())
    }

    /// Gather a batch of sequences into dense padded cache tensors shaped
    /// `(L, B, S, row_elems)` per plane (the AOT executable's layout).
    /// Allocates fresh zeroed buffers and delegates to
    /// [`Self::gather_batch_into`] (single copy path — the engine hot path
    /// passes persistent buffers instead).
    pub fn gather_batch(&mut self, seq_ids: &[SeqId], batch: usize) -> Result<Vec<Vec<f32>>> {
        let g = self.geom;
        let mut planes =
            vec![vec![0.0f32; g.n_layers * batch * g.max_seq * g.row_elems]; g.planes];
        self.gather_batch_into(seq_ids, batch, &mut planes)?;
        Ok(planes)
    }

    /// Gather into caller-owned buffers without zeroing.
    ///
    /// Padding slots and positions >= the sequence length are left with
    /// whatever they contained — sound because the fused kernels mask all
    /// cache positions >= pos[b], and every value ever written is finite.
    /// Copies execute the cached [`GatherPlan`]: one contiguous
    /// `(ntok * row_elems)` memcpy per (page, layer, plane), with the
    /// per-(layer, plane) offsets precomputed per batch bucket and reused
    /// across steps while batches churn (`&mut self` only refreshes that
    /// cache). [`Self::gather_plan_runs`] enumerates the same spans for
    /// inspection.
    pub fn gather_batch_into(
        &mut self,
        seq_ids: &[SeqId],
        batch: usize,
        planes: &mut [Vec<f32>],
    ) -> Result<()> {
        let g = self.geom;
        anyhow::ensure!(seq_ids.len() <= batch, "batch overflow");
        anyhow::ensure!(planes.len() == g.planes, "plane count");
        let (l_, s, re) = (g.n_layers, g.max_seq, g.row_elems);
        for p in planes.iter() {
            anyhow::ensure!(p.len() == l_ * batch * s * re, "plane buffer size");
        }
        if self.plan.as_ref().map_or(true, |p| p.batch != batch) {
            self.plan = Some(GatherPlan::build(&g, self.page_tokens, batch));
        }
        let plan = self.plan.as_ref().expect("plan built above");
        let data = &self.data;
        Self::for_each_run(&self.seqs, self.page_elems(), self.page_tokens, g, plan, seq_ids, |r| {
            planes[r.plane][r.dst..r.dst + r.len].copy_from_slice(&data[r.src..r.src + r.len]);
        })
    }

    /// Enumerate the exact contiguous memcpy spans
    /// [`Self::gather_batch_into`] executes for this batch composition,
    /// without copying — both drive the same [`Self::for_each_run`]
    /// walk, so this inspection surface cannot drift from the copies.
    /// Test/debug surface for the §Perf contract: the span count equals
    /// `pages touched × n_layers × planes` (one memcpy per (page, layer,
    /// plane)) and every span stays inside one page.
    pub fn gather_plan_runs(&self, seq_ids: &[SeqId], batch: usize) -> Result<Vec<CopyRun>> {
        anyhow::ensure!(seq_ids.len() <= batch, "batch overflow");
        let plan = GatherPlan::build(&self.geom, self.page_tokens, batch);
        let mut runs = Vec::new();
        Self::for_each_run(
            &self.seqs,
            self.page_elems(),
            self.page_tokens,
            self.geom,
            &plan,
            seq_ids,
            |r| runs.push(r),
        )?;
        Ok(runs)
    }

    /// The single span walk behind [`Self::gather_batch_into`] and
    /// [`Self::gather_plan_runs`]: one [`CopyRun`] per (page, layer,
    /// plane) of every listed sequence, in copy order. Associated fn
    /// (not `&self`) so callers can hold disjoint borrows of `data`
    /// alongside the walk.
    fn for_each_run(
        seqs: &HashMap<SeqId, SeqEntry>,
        page_elems: usize,
        page_tokens: usize,
        geom: CacheGeometry,
        plan: &GatherPlan,
        seq_ids: &[SeqId],
        mut f: impl FnMut(CopyRun),
    ) -> Result<()> {
        let (s, re) = (geom.max_seq, geom.row_elems);
        for (b, id) in seq_ids.iter().enumerate() {
            let entry = seqs.get(id).ok_or_else(|| anyhow::anyhow!("unknown seq {id}"))?;
            for (pi, &page) in entry.pages.iter().enumerate() {
                let tok0 = pi * page_tokens;
                let ntok = (entry.len - tok0).min(page_tokens);
                if ntok == 0 {
                    break;
                }
                let page_base = page * page_elems;
                let dst_row = (b * s + tok0) * re;
                for (plane, offs) in plan.per_plane.iter().enumerate() {
                    for &(src_off, dst_off) in offs {
                        f(CopyRun {
                            plane,
                            src: page_base + src_off,
                            dst: dst_off + dst_row,
                            len: ntok * re,
                        });
                    }
                }
            }
        }
        Ok(())
    }

    /// Read back one token's row (for tests / debugging).
    pub fn peek(&self, id: SeqId, token: usize, layer: usize, plane: usize) -> Option<&[f32]> {
        let g = self.geom;
        let e = self.seqs.get(&id)?;
        if token >= e.len {
            return None;
        }
        let page = e.pages[token / self.page_tokens];
        let base = page * self.page_elems()
            + ((layer * g.planes + plane) * self.page_tokens + token % self.page_tokens)
                * g.row_elems;
        Some(&self.data[base..base + g.row_elems])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn geom() -> CacheGeometry {
        CacheGeometry { n_layers: 2, row_elems: 4, planes: 2, max_seq: 8 }
    }

    fn rows(val: f32, g: &CacheGeometry) -> (Vec<f32>, Vec<f32>) {
        let k: Vec<f32> = (0..g.n_layers * g.row_elems).map(|i| val + i as f32).collect();
        let v: Vec<f32> = k.iter().map(|x| -x).collect();
        (k, v)
    }

    #[test]
    fn append_and_peek_roundtrip() {
        let g = geom();
        let mut pool = KvPool::new(g, 2, 4);
        pool.alloc_seq(7).unwrap();
        for t in 0..5 {
            let (k, v) = rows(t as f32 * 100.0, &g);
            pool.append(7, &[&k, &v]).unwrap();
        }
        assert_eq!(pool.seq_len(7), Some(5));
        assert_eq!(pool.used_pages(), 3); // ceil(5/2)
        // token 3, layer 1, plane K
        let (k, _) = rows(300.0, &g);
        assert_eq!(pool.peek(7, 3, 1, 0).unwrap(), &k[4..8]);
        // plane V
        let (_, v) = rows(300.0, &g);
        assert_eq!(pool.peek(7, 3, 1, 1).unwrap(), &v[4..8]);
    }

    #[test]
    fn gather_matches_appends_with_padding() {
        let g = geom();
        let mut pool = KvPool::new(g, 2, 8);
        pool.alloc_seq(1).unwrap();
        pool.alloc_seq(2).unwrap();
        for t in 0..3 {
            let (k, v) = rows(t as f32, &g);
            pool.append(1, &[&k, &v]).unwrap();
        }
        let (k, v) = rows(50.0, &g);
        pool.append(2, &[&k, &v]).unwrap();

        let batch = 4;
        let planes = pool.gather_batch(&[1, 2], batch).unwrap();
        let (l_, s, re) = (g.n_layers, g.max_seq, g.row_elems);
        // seq 1, token 2, layer 0, plane k
        let (k2, _) = rows(2.0, &g);
        let idx = ((0 * batch + 0) * s + 2) * re;
        assert_eq!(&planes[0][idx..idx + re], &k2[0..re]);
        // seq 2 in slot 1, token 0, layer 1, plane v
        let (_, v50) = rows(50.0, &g);
        let idx = ((1 * batch + 1) * s + 0) * re;
        assert_eq!(&planes[1][idx..idx + re], &v50[re..2 * re]);
        // padded slots stay zero
        let idx = ((0 * batch + 3) * s) * re;
        assert!(planes[0][idx..idx + s * re].iter().all(|&x| x == 0.0));
        let _ = l_;
    }

    #[test]
    fn gather_plan_page_contiguous_runs_interleaved_allocation() {
        // Interleaved appends across three sequences of different lengths,
        // so their pages alternate through the pool (a non-trivial
        // allocation pattern): the gather plan must still be exactly one
        // contiguous memcpy span per (page, layer, plane), each span
        // confined to a single page.
        let g = geom(); // 2 layers, 4 row elems, 2 planes, page = 2 tokens
        let mut pool = KvPool::new(g, 2, 16);
        let lens = [5usize, 3, 4];
        for id in [1u64, 2, 3] {
            pool.alloc_seq(id).unwrap();
        }
        for t in 0..5 {
            for id in [1u64, 2, 3] {
                if t < lens[(id - 1) as usize] {
                    let (k, v) = rows(id as f32 * 100.0 + t as f32, &g);
                    pool.append(id, &[&k, &v]).unwrap();
                }
            }
        }
        let pages_touched: usize = lens.iter().map(|l| l.div_ceil(2)).sum(); // 3 + 2 + 2
        assert_eq!(pool.used_pages(), pages_touched);

        let batch = 4;
        let runs = pool.gather_plan_runs(&[1, 2, 3], batch).unwrap();
        // count of distinct memcpy spans == pages touched (per layer/plane)
        assert_eq!(runs.len(), pages_touched * g.n_layers * g.planes);
        let page_elems = 2 * g.token_elems();
        for r in &runs {
            assert_eq!(
                r.src / page_elems,
                (r.src + r.len - 1) / page_elems,
                "run crosses a page boundary: {r:?}"
            );
            assert!(r.len % g.row_elems == 0 && r.len <= 2 * g.row_elems);
        }
        // executing the plan verbatim reproduces the gather byte-for-byte
        let mut via_plan =
            vec![vec![0.0f32; g.n_layers * batch * g.max_seq * g.row_elems]; g.planes];
        for r in &runs {
            let src: Vec<f32> = pool.data[r.src..r.src + r.len].to_vec();
            via_plan[r.plane][r.dst..r.dst + r.len].copy_from_slice(&src);
        }
        let direct = pool.gather_batch(&[1, 2, 3], batch).unwrap();
        assert_eq!(via_plan, direct);
    }

    #[test]
    fn gather_plan_cached_across_steps_and_rebuilt_per_bucket() {
        let g = geom();
        let mut pool = KvPool::new(g, 2, 8);
        pool.alloc_seq(1).unwrap();
        let (k, v) = rows(1.0, &g);
        pool.append(1, &[&k, &v]).unwrap();
        let mut planes =
            vec![vec![0.0f32; g.n_layers * 2 * g.max_seq * g.row_elems]; g.planes];
        pool.gather_batch_into(&[1], 2, &mut planes).unwrap();
        assert_eq!(pool.plan.as_ref().unwrap().batch, 2);
        // same bucket across churned state: plan survives
        pool.append(1, &[&k, &v]).unwrap();
        pool.gather_batch_into(&[1], 2, &mut planes).unwrap();
        assert_eq!(pool.plan.as_ref().unwrap().batch, 2);
        // bucket change rebuilds
        let mut planes4 =
            vec![vec![0.0f32; g.n_layers * 4 * g.max_seq * g.row_elems]; g.planes];
        pool.gather_batch_into(&[1], 4, &mut planes4).unwrap();
        assert_eq!(pool.plan.as_ref().unwrap().batch, 4);
    }

    #[test]
    fn pool_exhaustion_and_free() {
        let g = geom();
        let mut pool = KvPool::new(g, 2, 2); // 4 token capacity
        pool.alloc_seq(1).unwrap();
        let (k, v) = rows(0.0, &g);
        for _ in 0..4 {
            pool.append(1, &[&k, &v]).unwrap();
        }
        assert!(!pool.can_append(1));
        assert!(pool.append(1, &[&k, &v]).is_err());
        pool.free_seq(1);
        assert_eq!(pool.free_pages(), 2);
        pool.alloc_seq(2).unwrap();
        assert!(pool.can_append(2));
        pool.append(2, &[&k, &v]).unwrap();
    }

    #[test]
    fn max_seq_enforced() {
        let g = CacheGeometry { max_seq: 3, ..geom() };
        let mut pool = KvPool::new(g, 2, 8);
        pool.alloc_seq(1).unwrap();
        let (k, v) = rows(0.0, &g);
        for _ in 0..3 {
            pool.append(1, &[&k, &v]).unwrap();
        }
        assert!(!pool.can_append(1));
        assert!(pool.append(1, &[&k, &v]).is_err());
    }

    /// `(n_layers, n_rows, re)` buffer whose row `r` equals the
    /// single-token layout of `rows(vals[r])`.
    fn multirow(vals: &[f32], g: &CacheGeometry) -> (Vec<f32>, Vec<f32>) {
        let n = vals.len();
        let mut k = vec![0f32; g.n_layers * n * g.row_elems];
        let mut v = vec![0f32; g.n_layers * n * g.row_elems];
        for (r, &val) in vals.iter().enumerate() {
            let (kr, vr) = rows(val, g);
            for l in 0..g.n_layers {
                let dst = (l * n + r) * g.row_elems;
                k[dst..dst + g.row_elems]
                    .copy_from_slice(&kr[l * g.row_elems..(l + 1) * g.row_elems]);
                v[dst..dst + g.row_elems]
                    .copy_from_slice(&vr[l * g.row_elems..(l + 1) * g.row_elems]);
            }
        }
        (k, v)
    }

    #[test]
    fn append_rows_matches_repeated_append_across_page_boundaries() {
        let g = geom();
        let vals = [0.0f32, 100.0, 200.0, 300.0, 400.0]; // 5 rows, page = 2 tokens
        // one multi-row append ...
        let mut multi = KvPool::new(g, 2, 4);
        multi.alloc_seq(7).unwrap();
        let (k, v) = multirow(&vals, &g);
        multi.append_rows(7, &[&k, &v], vals.len()).unwrap();
        // ... against the token-by-token path
        let mut single = KvPool::new(g, 2, 4);
        single.alloc_seq(7).unwrap();
        for &val in &vals {
            let (k1, v1) = rows(val, &g);
            single.append(7, &[&k1, &v1]).unwrap();
        }
        assert_eq!(multi.seq_len(7), Some(5));
        assert_eq!(multi.used_pages(), single.used_pages());
        for t in 0..5 {
            for l in 0..g.n_layers {
                for p in 0..g.planes {
                    assert_eq!(multi.peek(7, t, l, p), single.peek(7, t, l, p), "t={t} l={l} p={p}");
                }
            }
        }
    }

    #[test]
    fn pages_needed_counts_the_allocation_a_multi_append_performs() {
        let g = geom();
        let mut pool = KvPool::new(g, 2, 8);
        pool.alloc_seq(1).unwrap();
        assert_eq!(pool.pages_needed(1, 1), 1, "empty seq: first row allocates");
        assert_eq!(pool.pages_needed(1, 5), 3, "5 rows at 2 tokens/page");
        assert_eq!(pool.pages_needed(99, 4), 0, "unknown seq");
        let (k, v) = rows(0.0, &g);
        pool.append(1, &[&k, &v]).unwrap();
        assert_eq!(pool.pages_needed(1, 1), 0, "second row fits the open page");
        assert_eq!(pool.pages_needed(1, 2), 1);
        // agreement with needs_new_page on the single-row case
        assert_eq!(pool.pages_needed(1, 1), usize::from(pool.needs_new_page(1)));
    }

    #[test]
    fn append_rows_failure_appends_nothing() {
        let g = geom(); // max_seq 8
        let mut pool = KvPool::new(g, 2, 2); // 4-token capacity
        pool.alloc_seq(1).unwrap();
        let vals = [0.0f32, 1.0, 2.0, 3.0, 4.0];
        let (k, v) = multirow(&vals, &g);
        // 5 rows need 3 pages but only 2 exist: all-or-nothing
        assert!(pool.append_rows(1, &[&k, &v], 5).is_err());
        assert_eq!(pool.seq_len(1), Some(0));
        assert_eq!(pool.used_pages(), 0);
        // max_seq violation also validated up front
        let g9 = CacheGeometry { max_seq: 3, ..g };
        let mut small = KvPool::new(g9, 2, 8);
        small.alloc_seq(1).unwrap();
        assert!(small.append_rows(1, &[&k, &v], 5).is_err());
        assert_eq!(small.seq_len(1), Some(0));
    }

    #[test]
    fn double_alloc_rejected() {
        let mut pool = KvPool::new(geom(), 2, 2);
        pool.alloc_seq(1).unwrap();
        assert!(pool.alloc_seq(1).is_err());
    }

    #[test]
    fn property_no_page_shared_between_sequences() {
        // Randomised invariant check (in-tree property test): after any
        // interleaving of alloc/append/free, no page is owned twice and
        // free + owned == total.
        let g = geom();
        let mut pool = KvPool::new(g, 2, 16);
        let mut rng = Rng::seed_from_u64(99);
        let mut live: Vec<SeqId> = vec![];
        let mut next_id = 0u64;
        for _ in 0..500 {
            match rng.below(10) {
                0..=2 => {
                    next_id += 1;
                    if pool.alloc_seq(next_id).is_ok() {
                        live.push(next_id);
                    }
                }
                3..=7 if !live.is_empty() => {
                    let id = live[rng.below(live.len())];
                    let (k, v) = rows(rng.f32(), &g);
                    let _ = pool.append(id, &[&k, &v]);
                }
                8 if !live.is_empty() => {
                    let idx = rng.below(live.len());
                    let id = live.swap_remove(idx);
                    pool.free_seq(id);
                }
                _ => {}
            }
            // invariant: page ownership is a partition
            let mut seen = std::collections::HashSet::new();
            let mut owned = 0;
            for id in &live {
                for t in 0..pool.seq_len(*id).unwrap() {
                    let _ = t;
                }
            }
            for (_, e) in pool.seqs.iter() {
                for p in &e.pages {
                    assert!(seen.insert(*p), "page {p} double-owned");
                    owned += 1;
                }
            }
            assert_eq!(owned + pool.free_pages(), pool.n_pages);
        }
    }
}
