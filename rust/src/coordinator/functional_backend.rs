//! The functional serving backend: real full-block decoding with no
//! artifacts and no PJRT.
//!
//! Wraps [`clustersim::block::BlockModel`] — the fused transformer-block
//! pipeline running real numerics over the engine's gathered cache
//! planes — behind the [`Backend`] trait, so `clusterfusion serve`,
//! `examples/quickstart.rs` and `loadgen::replay` produce genuine
//! greedy-decoded token streams on a fresh checkout. Weights are
//! materialized from a seeded RNG ([`MaterializedWeights`]), so the same
//! `(model, seed)` always serves byte-identical tokens — the determinism
//! the `integration_block` suite pins.
//!
//! This is the runnable stand-in for the PJRT path (DESIGN.md §2
//! substitution rule): same engine, same paged KV cache, same batched
//! gather (`KvPool::gather_batch_into`) — only the executable differs.

use anyhow::{Context, Result};

use crate::clustersim::block::{supports_cluster, BlockModel};
use crate::clustersim::collective::Transport;
use crate::models::{MaterializedWeights, ModelConfig};
use crate::util::pool::Pool;

use super::engine::{Backend, ModelGeom, SlotRows, StepOut};

/// Default batch buckets (powers of two, like the AOT serving artifacts).
pub const DEFAULT_BUCKETS: [usize; 4] = [1, 2, 4, 8];

/// Largest model the functional path will materialize (f32 weights +
/// one packed copy ≈ 8 bytes/param of host RAM, and every decode step
/// runs the full parameter set through scalar kernels). The paper-scale
/// cost-model geometries (llama2-7b ≈ 6.5 B params) must never be
/// materialized by a default `serve` invocation — use the PJRT backend
/// for anything bigger than this.
pub const MAX_FUNCTIONAL_PARAMS: usize = 250_000_000;

/// [`Backend`] implementation decoding functionally through the
/// full-block pipeline.
pub struct FunctionalBackend {
    model: BlockModel,
    buckets: Vec<usize>,
    /// The worker pool every decode step runs on (DESIGN.md §Parallel).
    /// Serial by default; sized via [`Self::from_model_name_on`] /
    /// [`Self::set_threads`]. All functional outputs are byte-identical
    /// at every pool size, so threading changes wall-clock only.
    pool: Pool,
    /// Decode steps executed (observability parity with `MockBackend`).
    pub steps: u64,
    /// Per-slot merged per-shard argmax of the last step's logits
    /// (`BlockModel::prefill_on`, from each slot's last fed row): what a
    /// greedy sampler will pick, exposed for observability and the
    /// speculative-decode direction.
    pub last_greedy: Vec<usize>,
}

impl FunctionalBackend {
    /// Serial-pool backend — the deterministic default. Virtual-clock
    /// replay (`loadgen::replay`) constructs its backends through this
    /// path: the DESIGN.md §4 determinism rule pins `threads = 1` there.
    pub fn new(model: BlockModel, buckets: Vec<usize>) -> Self {
        assert!(!buckets.is_empty(), "need at least one batch bucket");
        Self { model, buckets, pool: Pool::serial(), steps: 0, last_greedy: Vec::new() }
    }

    /// Materialize `model_name`'s weights from `seed` and pack them for
    /// `cluster_size` (must divide the model's geometry —
    /// [`supports_cluster`]). Default buckets 1/2/4/8, serial pool.
    pub fn from_model_name(model_name: &str, seed: u64, cluster_size: usize) -> Result<Self> {
        let cfg = ModelConfig::by_name(model_name)
            .with_context(|| format!("unknown model '{model_name}'"))?;
        anyhow::ensure!(
            cfg.param_count() <= MAX_FUNCTIONAL_PARAMS,
            "{model_name} has {} params — too large to materialize functionally (limit {}); \
             use `--backend pjrt` with AOT artifacts, or a micro-* model",
            cfg.param_count(),
            MAX_FUNCTIONAL_PARAMS
        );
        anyhow::ensure!(
            supports_cluster(&cfg, cluster_size),
            "{model_name}: cluster size {cluster_size} must divide head_dim/d_model/max_seq \
             (and the MLA latent rank)"
        );
        let weights = MaterializedWeights::materialize(&cfg, seed);
        let model = BlockModel::new(weights, cluster_size, Transport::Dsmem);
        Ok(Self::new(model, DEFAULT_BUCKETS.to_vec()))
    }

    /// [`Self::from_model_name`] with an explicit worker count: the
    /// `serve --threads` path. `threads == 0` means auto
    /// ([`Pool::auto_threads`]: the `CLUSTERFUSION_THREADS` override,
    /// else the host's available parallelism).
    pub fn from_model_name_on(
        model_name: &str,
        seed: u64,
        cluster_size: usize,
        threads: usize,
    ) -> Result<Self> {
        let mut backend = Self::from_model_name(model_name, seed, cluster_size)?;
        backend.set_threads(threads);
        Ok(backend)
    }

    /// Resize the worker pool (`0` = auto). Purely a wall-clock knob:
    /// token streams are byte-identical at every size.
    ///
    /// Auto-sizing gates on the model's per-task work
    /// (`pool::MIN_TASK_MACS`): the micro models' cluster-block tasks
    /// are a few thousand MACs, far below the cost of a thread spawn,
    /// so a default `serve`/quickstart on them stays serial instead of
    /// regressing behind spawn overhead. Both explicit widths win over
    /// the gate: `--threads N` and a set `CLUSTERFUSION_THREADS` are
    /// honoured verbatim (the CI matrix legs rely on the latter).
    pub fn set_threads(&mut self, threads: usize) {
        self.pool = if threads == 0 {
            match Pool::env_threads() {
                Some(n) => Pool::new(n),
                None if self.parallel_worthwhile() => Pool::auto(),
                None => Pool::serial(),
            }
        } else {
            Pool::new(threads)
        };
    }

    /// Whether one cluster-block task of this model's attention fan-out
    /// (projection + cache-span scan + output tile, batch 1 — the
    /// worst case) carries enough work to amortise a spawn.
    fn parallel_worthwhile(&self) -> bool {
        let cfg = self.model.config();
        let n = self.model.cluster_size;
        let (d, dh, s) = (cfg.d_model, cfg.head_dim, cfg.max_seq);
        let per_block = 3 * d * (dh / n) + 2 * (s / n) * self.model.row_elems() + dh * (d / n);
        per_block >= crate::util::pool::MIN_TASK_MACS
    }

    /// Active host worker threads (what serve/quickstart banners report).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    pub fn config(&self) -> &ModelConfig {
        self.model.config()
    }

    /// One-line description for serve/quickstart banners ("which backend
    /// is live").
    pub fn describe(&self) -> String {
        let cfg = self.model.config();
        format!(
            "functional full-block pipeline: {} ({:?}, {} layers, d_model {}, vocab {}, \
             cluster {}, {}, {} host thread{})",
            cfg.name,
            cfg.attn,
            cfg.n_layers,
            cfg.d_model,
            cfg.vocab,
            self.model.cluster_size,
            if self.model.rope_base.is_some() { "rope" } else { "nope" },
            self.pool.threads(),
            if self.pool.threads() == 1 { "" } else { "s" },
        )
    }
}

impl Backend for FunctionalBackend {
    fn geom(&self) -> ModelGeom {
        let cfg = self.model.config();
        ModelGeom {
            vocab: cfg.vocab,
            n_layers: cfg.n_layers,
            row_elems: self.model.row_elems(),
            planes: self.model.planes(),
            max_seq: cfg.max_seq,
        }
    }

    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn step(
        &mut self,
        bucket: usize,
        slots: &[SlotRows],
        cache_planes: &mut [Vec<f32>],
    ) -> Result<StepOut> {
        anyhow::ensure!(!slots.is_empty() && slots.len() <= bucket, "slot count fits bucket");
        // the multi-position entry point covers decode too: a decode slot
        // is a one-row range, and `prefill_on` is bit-identical to the
        // retired per-token path at every row count (integration_prefill)
        let rows: Vec<(&[i32], usize)> =
            slots.iter().map(|s| (s.tokens.as_slice(), s.pos0)).collect();
        let (logits, new_rows, greedy) = self.model.prefill_on(&self.pool, &rows, cache_planes, bucket);
        self.steps += 1;
        self.last_greedy = greedy;
        Ok(StepOut { logits, new_rows })
    }

    fn pool_stats(&self) -> Option<crate::util::pool::PoolStats> {
        Some(self.pool.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Engine;
    use crate::coordinator::request::{Event, Request};

    #[test]
    fn engine_decodes_real_tokens_end_to_end() {
        let backend = FunctionalBackend::from_model_name("micro-llama", 42, 2).unwrap();
        let vocab = backend.geom().vocab;
        let mut engine = Engine::new(backend, 64, 8, 1.0);
        engine.submit(Request::new(1, vec![3, 5], 4));
        engine.run_to_completion(64).unwrap();
        let toks: Vec<i32> = engine
            .take_events()
            .iter()
            .filter_map(|e| match e {
                Event::FirstToken { token, .. } | Event::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        assert_eq!(toks.len(), 4);
        assert!(toks.iter().all(|&t| (0..vocab as i32).contains(&t)));
        assert_eq!(engine.pool.used_pages(), 0, "pages returned at finish");
    }

    #[test]
    fn same_seed_same_tokens_different_seed_differs() {
        let run = |seed: u64| -> Vec<i32> {
            let backend = FunctionalBackend::from_model_name("micro-llama", seed, 2).unwrap();
            let mut engine = Engine::new(backend, 64, 8, 1.0);
            engine.submit(Request::new(1, vec![9, 2, 4], 6));
            engine.run_to_completion(64).unwrap();
            engine
                .take_events()
                .iter()
                .filter_map(|e| match e {
                    Event::FirstToken { token, .. } | Event::Token { token, .. } => Some(*token),
                    _ => None,
                })
                .collect()
        };
        assert_eq!(run(42), run(42), "seeded weights -> reproducible stream");
        assert_ne!(run(42), run(43), "seed must matter");
    }

    #[test]
    fn mla_backend_serves_single_plane_cache() {
        let backend = FunctionalBackend::from_model_name("micro-mla", 7, 2).unwrap();
        assert_eq!(backend.geom().planes, 1);
        let mut engine = Engine::new(backend, 64, 8, 1.0);
        engine.submit(Request::new(1, vec![1, 2], 3));
        engine.run_to_completion(64).unwrap();
        assert_eq!(engine.tokens_out, 3);
    }

    #[test]
    fn step_exposes_sharded_greedy_matching_argmax_at_every_pool_size() {
        let geom_of = |b: &FunctionalBackend| b.geom();
        let mut want: Option<(Vec<u32>, Vec<usize>)> = None;
        for threads in [1usize, 2, 4, 8] {
            let mut backend = FunctionalBackend::from_model_name_on("micro-llama", 7, 2, threads)
                .unwrap();
            assert_eq!(backend.threads(), threads);
            let g = geom_of(&backend);
            let bucket = 2usize;
            let mut planes =
                vec![vec![0f32; g.n_layers * bucket * g.max_seq * g.row_elems]; g.planes];
            let slots = [
                SlotRows { tokens: vec![3], pos0: 0 },
                SlotRows { tokens: vec![9], pos0: 0 },
            ];
            let out = backend.step(bucket, &slots, &mut planes).unwrap();
            // last_greedy is the sharded-argmax merge — must equal the
            // full-row argmax, and both must be pool-size invariant
            let greedy: Vec<usize> = (0..bucket)
                .map(|bi| crate::runtime::argmax(&out.logits[bi * g.vocab..(bi + 1) * g.vocab]))
                .collect();
            assert_eq!(backend.last_greedy, greedy, "threads={threads}");
            let bits: Vec<u32> = out.logits.iter().map(|v| v.to_bits()).collect();
            match &want {
                None => want = Some((bits, greedy)),
                Some((wb, wg)) => {
                    assert_eq!(&bits, wb, "logits must be byte-identical, threads={threads}");
                    assert_eq!(&greedy, wg, "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn auto_threads_stay_serial_on_micro_models_but_explicit_wins() {
        // micro-llama's cluster-block tasks are ~KMACs — far below a
        // spawn's cost — so auto (0) resolves to the serial pool,
        // unless CLUSTERFUSION_THREADS explicitly asks for a width
        // (the CI matrix legs do; both overrides beat the gate).
        let auto = FunctionalBackend::from_model_name_on("micro-llama", 42, 2, 0).unwrap();
        match crate::util::pool::Pool::env_threads() {
            None => assert_eq!(auto.threads(), 1, "auto must not pool a micro model"),
            Some(n) => assert_eq!(auto.threads(), n, "env width must win over the gate"),
        }
        // ... and an explicit width is honoured verbatim.
        let forced = FunctionalBackend::from_model_name_on("micro-llama", 42, 2, 4).unwrap();
        assert_eq!(forced.threads(), 4);
    }

    #[test]
    fn pool_counters_reach_the_metrics_registry() {
        // The Backend::pool_stats hook: a functional engine with a sink
        // attached must publish its pool's cumulative dispatch counters
        // (serial pools dispatch too — every run_map is one dispatch).
        let backend = FunctionalBackend::from_model_name("micro-llama", 42, 2).unwrap();
        let obs = crate::obs::Obs::new();
        let mut engine = Engine::new(backend, 64, 8, 1.0);
        engine.set_obs(obs.clone(), 3);
        engine.submit(Request::new(1, vec![3, 5], 4));
        engine.run_to_completion(64).unwrap();
        engine.sync_obs_counters();
        let d = obs.counter("pool_dispatch_total{replica=\"3\"}");
        let t = obs.counter("pool_tasks_total{replica=\"3\"}");
        assert!(d > 0, "decode steps must count pool dispatches");
        assert!(t >= d, "every dispatch runs at least one task");
    }

    #[test]
    fn rejects_bad_cluster_and_unknown_model() {
        assert!(FunctionalBackend::from_model_name("micro-llama", 0, 3).is_err());
        assert!(FunctionalBackend::from_model_name("no-such-model", 0, 2).is_err());
    }

    #[test]
    fn refuses_to_materialize_paper_scale_models() {
        // llama2-7b would be ~26 GB of f32 weights: the functional path
        // must fail fast instead of materializing (its cluster geometry
        // otherwise divides cleanly, so only the size guard stops it).
        let err = FunctionalBackend::from_model_name("llama2-7b", 0, 2).unwrap_err();
        assert!(err.to_string().contains("too large"), "{err:#}");
    }
}
