//! Threaded serving front-end: a request loop around the engine.
//!
//! `Server::spawn` moves the engine onto a worker thread; clients submit
//! requests through a channel and receive per-request event streams. The
//! build is offline (no tokio), so concurrency is std::thread + mpsc —
//! the engine loop itself is single-threaded by design (one device).
//!
//! A client may drop its event `Receiver` at any time ("hang-up"); the
//! engine still runs the request to completion, but the dead subscriber
//! entry is pruned on the first failed send so the map cannot accumulate
//! garbage across long serving runs. `ServerReport` exposes the counters
//! the hang-up tests assert on.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::Result;

use super::engine::{Backend, Engine, RequestTiming};
use super::request::{Event, Request, RequestId};

enum Msg {
    Submit(Request, Sender<Event>),
    Shutdown,
}

/// Drain the engine's event buffer into per-request subscriber channels.
/// Called after every step *and* after every mailbox drain: front-door
/// rejections emit their `Finished` event at submit time, possibly while
/// the engine is otherwise idle, and must still reach the client.
fn forward<B: Backend>(
    engine: &mut Engine<B>,
    subscribers: &mut HashMap<RequestId, Sender<Event>>,
    send_failures: &mut u64,
) {
    for ev in engine.take_events() {
        let id = match &ev {
            Event::FirstToken { id, .. }
            | Event::Token { id, .. }
            | Event::Finished { id, .. } => *id,
        };
        let done = matches!(ev, Event::Finished { .. });
        if let Some(tx) = subscribers.get(&id) {
            if tx.send(ev).is_err() {
                // receiver hung up: prune immediately so the map does
                // not grow with dead senders
                *send_failures += 1;
                subscribers.remove(&id);
            }
        }
        if done {
            subscribers.remove(&id);
        }
    }
}

/// Handle to a running engine thread.
pub struct Server {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<ServerReport>>,
}

/// Final statistics returned at shutdown.
#[derive(Debug, Clone, Default)]
pub struct ServerReport {
    pub steps: u64,
    pub tokens_out: u64,
    pub preemptions: u64,
    /// Event sends that failed because the client dropped its receiver.
    pub send_failures: u64,
    /// Requests refused at the front door (too long for the context
    /// window, projected to breach the TTFT SLO, or already past their
    /// deadline).
    pub rejected: u64,
    /// Requests whose `deadline_us` passed at a step boundary after
    /// admission (queued or mid-generation).
    pub deadline_expired: u64,
    /// Subscriber entries still registered when the engine thread exited
    /// (0 unless the server loop leaked — asserted by tests).
    pub dangling_subscribers: usize,
    pub timings: Vec<RequestTiming>,
}

impl Server {
    /// Spawn the engine loop on a worker thread.
    pub fn spawn<B: Backend + Send + 'static>(mut engine: Engine<B>) -> Self {
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        let handle = std::thread::spawn(move || {
            let mut subscribers: HashMap<RequestId, Sender<Event>> = HashMap::new();
            let mut send_failures = 0u64;
            let mut shutdown = false;
            loop {
                // drain the mailbox (non-blocking while busy, blocking when idle)
                loop {
                    let msg = if engine.idle() && !shutdown {
                        match rx.recv() {
                            Ok(m) => Some(m),
                            Err(_) => {
                                shutdown = true;
                                None
                            }
                        }
                    } else {
                        match rx.try_recv() {
                            Ok(m) => Some(m),
                            Err(_) => None,
                        }
                    };
                    match msg {
                        Some(Msg::Submit(req, events)) => {
                            subscribers.insert(req.id, events);
                            engine.submit(req);
                            // a front-door rejection emits its Finished
                            // event right here, while the engine may stay
                            // idle: deliver it before blocking on the
                            // mailbox with the client still waiting
                            forward(&mut engine, &mut subscribers, &mut send_failures);
                        }
                        Some(Msg::Shutdown) => shutdown = true,
                        None => break,
                    }
                }
                if engine.idle() {
                    if shutdown {
                        break;
                    }
                    continue;
                }
                if let Err(e) = engine.step() {
                    eprintln!("engine step failed: {e:#}");
                    break;
                }
                forward(&mut engine, &mut subscribers, &mut send_failures);
            }
            ServerReport {
                steps: engine.steps,
                tokens_out: engine.tokens_out,
                preemptions: engine.preemptions,
                send_failures,
                rejected: engine.rejected(),
                deadline_expired: engine.deadline_expired,
                dangling_subscribers: subscribers.len(),
                timings: engine.timings().to_vec(),
            }
        });
        Self { tx, handle: Some(handle) }
    }

    /// Submit a request; returns the event stream receiver.
    pub fn submit(&self, req: Request) -> Result<Receiver<Event>> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Submit(req, tx))
            .map_err(|_| anyhow::anyhow!("server thread gone"))?;
        Ok(rx)
    }

    /// Finish outstanding work and join the engine thread.
    pub fn shutdown(mut self) -> Result<ServerReport> {
        let _ = self.tx.send(Msg::Shutdown);
        let handle = self.handle.take().expect("shutdown called once");
        handle.join().map_err(|_| anyhow::anyhow!("engine thread panicked"))
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{MockBackend, ModelGeom};
    use crate::coordinator::request::FinishReason;

    #[test]
    fn serves_concurrent_clients() {
        let engine = Engine::new(MockBackend::tiny(), 64, 4, 1.0);
        let server = Server::spawn(engine);
        let rx1 = server.submit(Request::new(1, vec![3, 5], 3)).unwrap();
        let rx2 = server.submit(Request::new(2, vec![1], 2)).unwrap();

        let evs1: Vec<Event> = rx1.iter().collect();
        let evs2: Vec<Event> = rx2.iter().collect();
        assert!(matches!(
            evs1.last().unwrap(),
            Event::Finished { reason: FinishReason::Length, .. }
        ));
        assert_eq!(
            evs2.iter().filter(|e| matches!(e, Event::Token { .. } | Event::FirstToken { .. })).count(),
            2
        );
        let report = server.shutdown().unwrap();
        assert_eq!(report.tokens_out, 5);
        assert_eq!(report.timings.len(), 2);
        assert_eq!(report.dangling_subscribers, 0);
    }

    #[test]
    fn shutdown_waits_for_inflight_work() {
        let engine = Engine::new(MockBackend::tiny(), 64, 4, 1.0);
        let server = Server::spawn(engine);
        let rx = server.submit(Request::new(7, vec![2, 2], 4)).unwrap();
        let report = server.shutdown().unwrap();
        assert_eq!(report.tokens_out, 4);
        // events were still delivered
        let evs: Vec<Event> = rx.iter().collect();
        assert!(matches!(evs.last().unwrap(), Event::Finished { .. }));
    }

    #[test]
    fn subscriber_hangup_mid_stream_finishes_request_without_leak() {
        // A client that drops its Receiver mid-stream must not wedge the
        // engine, lose the request, or leak a subscriber entry. The
        // dropped request generates 400 tokens so the drop lands while
        // sends are still outgoing; the outer loop absorbs the (very
        // unlikely) schedule where the engine outruns the drop.
        let attempt = || {
            let geom =
                ModelGeom { vocab: 32, n_layers: 2, row_elems: 4, planes: 2, max_seq: 512 };
            let engine = Engine::new(MockBackend::new(geom, vec![1, 2, 4]), 256, 4, 1.0);
            let server = Server::spawn(engine);
            let rx_dropped = server.submit(Request::new(1, vec![1, 2], 400)).unwrap();
            drop(rx_dropped);
            // a well-behaved client sharing the engine
            let rx_live = server.submit(Request::new(2, vec![3], 4)).unwrap();
            let evs: Vec<Event> = rx_live.iter().collect();
            assert!(matches!(evs.last().unwrap(), Event::Finished { .. }));

            let report = server.shutdown().unwrap();
            // both requests ran to completion on the engine
            assert_eq!(report.timings.len(), 2);
            assert_eq!(report.tokens_out, 400 + 4);
            // nothing may remain registered at exit, hang-up or not
            assert_eq!(report.dangling_subscribers, 0, "dead subscriber entry leaked");
            report.send_failures
        };
        let saw_failed_send = (0..5).any(|_| attempt() >= 1);
        assert!(saw_failed_send, "drop never hit an in-flight send in 5 attempts");
    }

    #[test]
    fn rejection_event_reaches_client_while_engine_is_idle() {
        // prompt 4 + gen 100 > max_seq 16: refused at submit. No step
        // ever runs, so the event must be forwarded from the mailbox
        // drain, not the post-step path — a client blocked on its stream
        // would otherwise deadlock against the idle engine loop.
        let engine = Engine::new(MockBackend::tiny(), 64, 4, 1.0);
        let server = Server::spawn(engine);
        let rx = server.submit(Request::new(9, vec![1; 4], 100)).unwrap();
        let evs: Vec<Event> = rx.iter().collect();
        assert!(matches!(
            evs.as_slice(),
            [Event::Finished { id: 9, reason: FinishReason::Rejected, .. }]
        ));
        let report = server.shutdown().unwrap();
        assert_eq!(report.rejected, 1);
        assert_eq!(report.steps, 0);
        assert_eq!(report.timings.len(), 0);
        assert_eq!(report.dangling_subscribers, 0);
    }

    #[test]
    fn hangup_after_finish_is_clean() {
        // Dropping the receiver after the request already finished must
        // also leave no dangling entries (Finished prunes the map).
        let engine = Engine::new(MockBackend::tiny(), 64, 4, 1.0);
        let server = Server::spawn(engine);
        let rx = server.submit(Request::new(5, vec![1], 2)).unwrap();
        let evs: Vec<Event> = rx.iter().collect();
        assert!(matches!(evs.last().unwrap(), Event::Finished { .. }));
        drop(evs);
        let report = server.shutdown().unwrap();
        assert_eq!(report.send_failures, 0);
        assert_eq!(report.dangling_subscribers, 0);
    }
}
