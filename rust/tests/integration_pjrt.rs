//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have run; they are skipped (with a
//! note) when the manifest is absent so `cargo test` stays green on a
//! fresh checkout.

use clusterfusion::coordinator::engine::{Backend, Engine, SlotRows};
use clusterfusion::coordinator::pjrt_backend::PjrtBackend;
use clusterfusion::coordinator::request::{Event, Request};
use clusterfusion::runtime::{HostTensor, Runtime};

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    // Artifacts may exist while the build still ships the offline `xla`
    // stub (DESIGN.md §PJRT) — skip rather than fail in that case.
    if !clusterfusion::runtime::pjrt_available() {
        eprintln!("skipping: PJRT runtime unavailable in this build");
        return None;
    }
    Some(dir)
}

#[test]
fn runtime_loads_and_runs_full_decode_step() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    rt.load("tiny-llama-100m", 1, false).unwrap();
    let exe_iface = rt.get("tiny-llama-100m", 1, false).unwrap().iface.clone();
    let params = rt.random_params(&exe_iface, 0).unwrap();
    let caches: Vec<HostTensor> =
        exe_iface.cache_specs().iter().map(|s| HostTensor::zeros(&s.shape)).collect();
    let exe = rt.get("tiny-llama-100m", 1, false).unwrap();
    let outs = rt.decode_step(exe, &[5], &[0], &caches, &params).unwrap();
    // full (non-serving) interface returns logits + the whole updated cache
    assert_eq!(outs.len(), 3);
    assert_eq!(outs[0].shape, vec![1, exe_iface.vocab]);
    assert!(outs[0].data.iter().all(|x| x.is_finite()), "logits finite");
    // cache written at position 0 of layer 0
    let k_cache = &outs[1];
    assert_eq!(k_cache.shape, exe_iface.cache_specs()[0].shape);
    let row0: f32 = k_cache.data[..64].iter().map(|x| x.abs()).sum();
    assert!(row0 > 0.0, "K row appended at pos 0");
}

#[test]
fn serving_interface_returns_new_rows_and_is_position_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let mut backend = PjrtBackend::load(&dir, "tiny-llama-100m", 0).unwrap();
    let g = backend.geom();
    let planes: Vec<Vec<f32>> = (0..g.planes)
        .map(|_| vec![0.0; g.n_layers * g.max_seq * g.row_elems])
        .collect();
    let slot = |tok: i32| vec![SlotRows { tokens: vec![tok], pos0: 0 }];
    let out = backend.step(1, &slot(7), &mut planes.clone()).unwrap();
    assert_eq!(out.logits.len(), g.vocab);
    assert_eq!(out.new_rows.len(), 2);
    assert_eq!(out.new_rows[0].len(), g.n_layers * g.row_elems);
    assert!(out.new_rows[0].iter().any(|&x| x != 0.0), "k_new non-trivial");

    // Determinism: same inputs -> same logits.
    let out2 = backend.step(1, &slot(7), &mut planes.clone()).unwrap();
    assert_eq!(out.logits, out2.logits);

    // Different token -> different logits (the model actually depends on
    // its input).
    let out3 = backend.step(1, &slot(9), &mut planes.clone()).unwrap();
    assert_ne!(out.logits, out3.logits);

    // Multi-row prefill: feeding [7, 9] as one two-row chunk produces
    // per-layer rows for both positions, and its logits (from the last
    // fed row) match feeding row 9 after writing row 7's KV back — the
    // single-position equivalence the engine relies on.
    let mut chunk_planes = planes.clone();
    let chunked = backend
        .step(1, &[SlotRows { tokens: vec![7, 9], pos0: 0 }], &mut chunk_planes)
        .unwrap();
    assert_eq!(chunked.logits.len(), g.vocab);
    assert_eq!(chunked.new_rows[0].len(), g.n_layers * 2 * g.row_elems);
}

#[test]
fn engine_generates_autoregressively_on_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let backend = PjrtBackend::load(&dir, "tiny-llama-100m", 0).unwrap();
    let mut engine = Engine::new(backend, 128, 16, 1.0);
    engine.submit(Request::new(1, vec![10, 20, 30], 4));
    engine.run_to_completion(64).unwrap();
    let events = engine.take_events();
    let toks: Vec<i32> = events
        .iter()
        .filter_map(|e| match e {
            Event::FirstToken { token, .. } | Event::Token { token, .. } => Some(*token),
            _ => None,
        })
        .collect();
    assert_eq!(toks.len(), 4);
    assert!(toks.iter().all(|&t| (0..16384).contains(&t)));

    // Greedy decoding is deterministic: a second run reproduces the tokens.
    let backend = PjrtBackend::load(&dir, "tiny-llama-100m", 0).unwrap();
    let mut engine2 = Engine::new(backend, 128, 16, 1.0);
    engine2.submit(Request::new(1, vec![10, 20, 30], 4));
    engine2.run_to_completion(64).unwrap();
    let toks2: Vec<i32> = engine2
        .take_events()
        .iter()
        .filter_map(|e| match e {
            Event::FirstToken { token, .. } | Event::Token { token, .. } => Some(*token),
            _ => None,
        })
        .collect();
    assert_eq!(toks, toks2);
}

#[test]
fn batched_bucket_matches_single_stream() {
    // The same prompt decoded alone (bucket 1) and inside a batch of 4
    // (bucket 4) must yield identical greedy tokens — the continuous
    // batcher must not change results.
    let Some(dir) = artifacts_dir() else { return };
    let backend = PjrtBackend::load(&dir, "tiny-llama-100m", 0).unwrap();
    let mut solo = Engine::new(backend, 256, 16, 1.0);
    solo.submit(Request::new(1, vec![42, 7], 3));
    solo.run_to_completion(64).unwrap();
    let solo_toks: Vec<i32> = solo
        .take_events()
        .iter()
        .filter_map(|e| match e {
            Event::FirstToken { token, .. } | Event::Token { token, .. } => Some(*token),
            _ => None,
        })
        .collect();

    let backend = PjrtBackend::load(&dir, "tiny-llama-100m", 0).unwrap();
    let mut batched = Engine::new(backend, 256, 16, 1.0);
    batched.submit(Request::new(1, vec![42, 7], 3));
    batched.submit(Request::new(2, vec![100, 200, 300], 3));
    batched.submit(Request::new(3, vec![5], 3));
    batched.submit(Request::new(4, vec![9, 9], 3));
    batched.run_to_completion(128).unwrap();
    let batched_toks: Vec<i32> = batched
        .take_events()
        .iter()
        .filter_map(|e| match e {
            Event::FirstToken { id: 1, token, .. } | Event::Token { id: 1, token, .. } => {
                Some(*token)
            }
            _ => None,
        })
        .collect();
    assert_eq!(solo_toks, batched_toks, "batching changed request 1's tokens");
}

#[test]
fn mla_model_serves_too() {
    let Some(dir) = artifacts_dir() else { return };
    let backend = PjrtBackend::load(&dir, "tiny-mla-100m", 0).unwrap();
    assert_eq!(backend.geom().planes, 1, "MLA has a single latent plane");
    let mut engine = Engine::new(backend, 128, 16, 1.0);
    engine.submit(Request::new(1, vec![3, 1, 4], 3));
    engine.run_to_completion(64).unwrap();
    let n_tokens = engine
        .take_events()
        .iter()
        .filter(|e| matches!(e, Event::FirstToken { .. } | Event::Token { .. }))
        .count();
    assert_eq!(n_tokens, 3);
}
