//! Saturation load tests on the deterministic virtual clock (the tentpole
//! of the paced-trace-replay PR): the same MockBackend engine is driven
//! through under-load, at-capacity, and overload Poisson traces by
//! `loadgen::replay`, and the percentile reports must be
//!
//! * **deterministic** — two runs at the same seed are byte-identical;
//! * **physical** — p99 TTFT grows monotonically across the knee, chunked
//!   prefill keeps the main pool free of preemptions at every rate, and a
//!   tight-pool scenario still exercises recompute preemption;
//! * **paced** — the wall-clock `Server` path spreads submissions over
//!   the trace span instead of dumping everything at t=0.
//!
//! Scenario capacity math (see EXPERIMENTS.md §Load saturation): requests
//! are 16 prompt + 8 generated tokens; at the pinned prefill chunk of 4 a
//! request needs 4 prefill steps + 7 decode steps (the last chunk emits
//! the first token). The service model costs 200 + 50·decode_slots +
//! 50·prefill_rows µs per step, floored at one decode slot: a full decode
//! batch of 8 steps in 600 µs, the worst mixed step (7 decode slots + one
//! 4-row chunk) in 750 µs. The shared 4-row prefill budget is what bounds
//! throughput — one 16-token prompt enters service every 4 steps — and the
//! overload steady state averages ≈484 µs/step, so the knee lands near
//! ≈520 req/s: 100 rps is far under it, 450 rps just below, 1500 rps
//! ~2.9× past it.
//!
//! Chunked prefill changes the cache-pressure story: serializing prompt
//! rows through the FCFS budget staggers KV growth across slots, so the
//! 40-page pool that the retired decode-as-prefill engine thrashed at
//! overload (63 preemptions in the PR 2 suite) now never sees more than
//! 13 concurrent pages — the main scenarios assert *zero* preemptions at
//! every rate. A second, deliberately tight 9-page pool scenario keeps
//! the vLLM-style recompute-preemption machinery under test at overload.
//! Byte-determinism requires pinning the chunk size (DESIGN.md §Prefill):
//! this suite fixes `prefill_chunk = 4`.

use clusterfusion::coordinator::admission::AdmissionConfig;
use clusterfusion::coordinator::engine::{Engine, MockBackend, ModelGeom};
use clusterfusion::coordinator::server::Server;
use clusterfusion::loadgen::{self, ReplayReport, ServiceModel};
use clusterfusion::util::clock::{VirtualClock, WallClock};
use clusterfusion::workload::{SeqlenDist, Trace};

const N_REQUESTS: usize = 160;
const TRACE_SEED: u64 = 42;
const SYNTH_SEED: u64 = 7;

fn load_mock() -> MockBackend {
    MockBackend::new(
        ModelGeom { vocab: 64, n_layers: 2, row_elems: 4, planes: 2, max_seq: 64 },
        vec![1, 2, 4, 8],
    )
}

/// One saturation scenario at the given offered rate, on a fresh virtual
/// clock. Fully determined by (rps, TRACE_SEED, SYNTH_SEED).
fn run_scenario(rps: f64) -> ReplayReport {
    let mut engine = Engine::with_clock(load_mock(), 40, 4, 0.5, VirtualClock::shared());
    engine.set_prefill_chunk(4); // pinned: chunking must be deterministic
    let trace = Trace::poisson(N_REQUESTS, rps, SeqlenDist::Fixed(24), (8, 8), 64, TRACE_SEED);
    let requests = loadgen::synthesize_requests(&trace, 64, 16, 8, SYNTH_SEED);
    let service =
        ServiceModel { step_base_us: 200, step_per_seq_us: 50, step_prefill_token_us: 50 };
    loadgen::replay(&mut engine, &requests, &service, 1_000_000).expect("replay")
}

const UNDER_RPS: f64 = 100.0;
const AT_CAPACITY_RPS: f64 = 450.0;
const OVERLOAD_RPS: f64 = 1500.0;

#[test]
fn all_scenarios_complete_every_request() {
    for rps in [UNDER_RPS, AT_CAPACITY_RPS, OVERLOAD_RPS] {
        let rep = run_scenario(rps);
        assert_eq!(rep.completed, N_REQUESTS, "rps {rps}");
        // every request generates its full 8 tokens; preempted requests
        // regenerate, so tokens_out can only exceed the floor
        assert!(rep.tokens_out >= (N_REQUESTS * 8) as u64, "rps {rps}: {}", rep.tokens_out);
        assert!(rep.percentiles.e2e.count == N_REQUESTS);
    }
}

#[test]
fn percentile_reports_are_seed_stable_and_byte_identical() {
    for rps in [UNDER_RPS, OVERLOAD_RPS] {
        let a = run_scenario(rps).render();
        let b = run_scenario(rps).render();
        assert_eq!(a, b, "rps {rps}: virtual-clock replay must be deterministic");
    }
}

/// The tight-pool pressure scenario: same traffic and service model as
/// `run_scenario`, but a 9-page pool (36 token slots for up to 8 running
/// sequences that each want 24) so the preemption machinery stays under
/// test now that chunked prefill keeps the 40-page pool pressure-free.
fn run_pressure_scenario(rps: f64) -> ReplayReport {
    let mut engine = Engine::with_clock(load_mock(), 9, 4, 0.5, VirtualClock::shared());
    engine.set_prefill_chunk(4);
    let trace = Trace::poisson(N_REQUESTS, rps, SeqlenDist::Fixed(24), (8, 8), 64, TRACE_SEED);
    let requests = loadgen::synthesize_requests(&trace, 64, 16, 8, SYNTH_SEED);
    let service =
        ServiceModel { step_base_us: 200, step_per_seq_us: 50, step_prefill_token_us: 50 };
    loadgen::replay(&mut engine, &requests, &service, 1_000_000).expect("replay")
}

#[test]
fn chunked_prefill_staggers_kv_growth_so_the_pool_never_pressures() {
    // The serialized prefill budget admits one prompt into service every 4
    // steps, so concurrent KV footprints are staggered: peak demand on the
    // 40-page pool is 13 pages at every rate, and the recompute preemption
    // the decode-as-prefill engine paid at overload (63 in the PR 2 suite)
    // disappears entirely.
    for rps in [UNDER_RPS, AT_CAPACITY_RPS, OVERLOAD_RPS] {
        let rep = run_scenario(rps);
        assert_eq!(rep.preemptions, 0, "rps {rps}: staggered prefill must not thrash the pool");
        // no preemption => no token is ever regenerated
        assert_eq!(rep.tokens_out, (N_REQUESTS * 8) as u64, "rps {rps}");
    }
    // ... and no prompt row is ever re-fed: total prefill rows == sum of
    // prompt lengths, exactly once each
    let mut engine = Engine::with_clock(load_mock(), 40, 4, 0.5, VirtualClock::shared());
    engine.set_prefill_chunk(4);
    let trace =
        Trace::poisson(N_REQUESTS, OVERLOAD_RPS, SeqlenDist::Fixed(24), (8, 8), 64, TRACE_SEED);
    let requests = loadgen::synthesize_requests(&trace, 64, 16, 8, SYNTH_SEED);
    let service =
        ServiceModel { step_base_us: 200, step_per_seq_us: 50, step_prefill_token_us: 50 };
    loadgen::replay(&mut engine, &requests, &service, 1_000_000).expect("replay");
    assert_eq!(engine.prefill_tokens, (N_REQUESTS * 16) as u64);
}

#[test]
fn tight_pool_still_preempts_at_overload() {
    // 9 pages cannot hold 8 staggered 24-token sequences, so overload
    // thrashes: preempted requests restart prefill from row 0 (recompute
    // preemption discards fed progress) and regenerate their tokens.
    let rep = run_pressure_scenario(OVERLOAD_RPS);
    assert!(rep.preemptions > 0, "9-page pool must thrash at 1500 rps");
    assert_eq!(rep.completed, N_REQUESTS, "every request still finishes");
    assert!(
        rep.tokens_out > (N_REQUESTS * 8) as u64,
        "recompute preemption regenerates tokens: {}",
        rep.tokens_out
    );
    // preemption churn must not break byte-determinism
    let again = run_pressure_scenario(OVERLOAD_RPS);
    assert_eq!(rep.render(), again.render());
}

#[test]
fn p99_ttft_grows_monotonically_across_the_knee() {
    let under = run_scenario(UNDER_RPS);
    let at = run_scenario(AT_CAPACITY_RPS);
    let over = run_scenario(OVERLOAD_RPS);
    let (u, a, o) =
        (under.percentiles.ttft.p99, at.percentiles.ttft.p99, over.percentiles.ttft.p99);
    assert!(u < a && a < o, "p99 TTFT not monotone across the knee: {u} {a} {o}");
    // the overload tail is queue-dominated: far beyond a 10x step budget
    assert!(o > 10.0 * a, "overload p99 TTFT should explode: {a} -> {o}");
    // queue wait: invisible under load, dominant past saturation
    assert_eq!(under.percentiles.queue.p50, 0.0);
    assert!(over.percentiles.queue.p50 > 0.050, "{}", over.percentiles.queue.p50);
}

#[test]
fn decode_rate_stays_bounded_while_queues_grow() {
    // TPOT measures pure decode cadence: even far past saturation it is
    // bounded by the worst mixed step cost (750 µs: 7 decode slots plus
    // a 4-row prefill chunk), while TTFT/e2e absorb the queueing. This
    // is the TPOT-vs-load flattening of Fig. 17.
    let over = run_scenario(OVERLOAD_RPS);
    assert!(over.percentiles.tpot.p99 <= 0.0008, "{}", over.percentiles.tpot.p99);
    assert!(over.percentiles.ttft.p99 > 0.1, "{}", over.percentiles.ttft.p99);
}

#[test]
fn paced_server_submissions_spread_over_trace_span() {
    // The wall-clock Server path (clusterfusion serve / serve_trace):
    // pace_submit must honour arrival_us instead of submitting at t=0.
    let engine = Engine::new(load_mock(), 64, 4, 0.5);
    let server = Server::spawn(engine);
    // 60 rps for a ~290 ms span (seed 9): the span/2 margin below then
    // tolerates ~145 ms of scheduler jitter on a loaded CI host.
    let trace = Trace::poisson(16, 60.0, SeqlenDist::Fixed(16), (4, 4), 64, 9);
    let requests = loadgen::synthesize_requests(&trace, 64, 12, 4, 3);
    let clock = WallClock::new();
    let paced = loadgen::pace_submit(&server, &requests, &clock).expect("paced submit");

    for (_, rx) in &paced.receivers {
        while rx.recv().is_ok() {}
    }
    let report = server.shutdown().expect("shutdown");
    assert_eq!(report.timings.len(), 16);
    assert_eq!(report.dangling_subscribers, 0);

    // deterministic, jitter-proof: every submission happened at or after
    // its own arrival offset (sleeps only overshoot)
    assert_eq!(paced.submit_us.len(), 16);
    for (sub, req) in paced.submit_us.iter().zip(&trace.requests) {
        assert!(
            *sub >= req.arrival_us,
            "request {} submitted at {sub}µs before its arrival {}µs",
            req.id,
            req.arrival_us
        );
    }
    let span = trace.span_us();
    assert!(span > 100_000, "trace must have a real span: {span}");
    let spread = paced.last_submit_us - paced.first_submit_us;
    // aggregate shape: the spread can shrink only by the first
    // submission's scheduling jitter, never collapse toward t=0
    assert!(
        spread >= span / 2,
        "submissions not paced: spread {spread}µs vs trace span {span}µs"
    );
}

/// `run_scenario` with the latency-targeted front door active: a 25 ms
/// TTFT SLO priced by the same service model replay bills.
fn run_front_door_scenario(rps: f64) -> ReplayReport {
    let mut engine = Engine::with_clock(load_mock(), 40, 4, 0.5, VirtualClock::shared());
    engine.set_prefill_chunk(4);
    engine.set_admission(AdmissionConfig {
        slo_ttft_us: 25_000,
        service: ServiceModel { step_base_us: 200, step_per_seq_us: 50, step_prefill_token_us: 50 },
        ..AdmissionConfig::off()
    });
    let trace = Trace::poisson(N_REQUESTS, rps, SeqlenDist::Fixed(24), (8, 8), 64, TRACE_SEED);
    let requests = loadgen::synthesize_requests(&trace, 64, 16, 8, SYNTH_SEED);
    let service =
        ServiceModel { step_base_us: 200, step_per_seq_us: 50, step_prefill_token_us: 50 };
    loadgen::replay(&mut engine, &requests, &service, 1_000_000).expect("replay")
}

#[test]
fn front_door_sheds_overload_and_keeps_admitted_ttft_under_the_slo() {
    // 1500 rps is ~2.9x past the knee. Unbounded, every request is
    // eventually served but the p99 TTFT explodes two orders of
    // magnitude past any interactive target; with the 25 ms front door
    // the engine sheds the un-servable tail at submit and every admitted
    // request still meets the SLO. All numbers are pure functions of
    // (rate, seeds, SLO) on the virtual clock.
    let rep = run_front_door_scenario(OVERLOAD_RPS);
    assert_eq!(rep.completed + rep.rejected as usize, N_REQUESTS);
    assert_eq!(rep.rejected, 92, "57.5% of offered load is beyond the SLO at 1500 rps");
    assert_eq!(rep.completed, 68);
    assert_eq!(rep.preemptions, 0);
    // admitted p99 TTFT: 15.6 ms, within the 25 ms target …
    assert!(rep.percentiles.ttft.p99 <= 0.025, "{}", rep.percentiles.ttft.p99);
    assert!((rep.percentiles.ttft.p99 - 0.0156).abs() < 1e-9, "{}", rep.percentiles.ttft.p99);
    // … which the unbounded baseline breaches by ~8x
    let baseline = run_scenario(OVERLOAD_RPS);
    assert_eq!(baseline.rejected, 0);
    assert!(
        baseline.percentiles.ttft.p99 > 0.1,
        "unbounded overload must breach the target: {}",
        baseline.percentiles.ttft.p99
    );
    // rejection decisions are part of the §4 determinism contract:
    // byte-stable across two runs at the pinned seeds
    assert_eq!(rep.render(), run_front_door_scenario(OVERLOAD_RPS).render());
}

#[test]
fn front_door_is_inert_below_saturation() {
    // Under and at capacity the projection never breaches 25 ms, so the
    // front door must be byte-invisible against the unbounded baseline.
    for rps in [UNDER_RPS, AT_CAPACITY_RPS] {
        let front = run_front_door_scenario(rps);
        assert_eq!(front.rejected, 0, "rps {rps}");
        assert_eq!(front.render(), run_scenario(rps).render(), "rps {rps}");
    }
}

#[test]
fn virtual_and_wall_clock_agree_on_token_accounting() {
    // The same trace replayed on the virtual clock and against the
    // threaded wall-clock server produces the same completion counts and
    // token totals (timing differs, accounting must not).
    let virt = run_scenario(UNDER_RPS);

    let engine = Engine::new(load_mock(), 40, 4, 0.5);
    let server = Server::spawn(engine);
    // same trace shape, compressed 50x so the wall test stays fast
    let trace =
        Trace::poisson(N_REQUESTS, 5_000.0, SeqlenDist::Fixed(24), (8, 8), 64, TRACE_SEED);
    let requests = loadgen::synthesize_requests(&trace, 64, 16, 8, SYNTH_SEED);
    let clock = WallClock::new();
    let paced = loadgen::pace_submit(&server, &requests, &clock).expect("paced submit");
    for (_, rx) in &paced.receivers {
        while rx.recv().is_ok() {}
    }
    let report = server.shutdown().expect("shutdown");
    assert_eq!(report.timings.len(), virt.completed);
    let wall_generated: usize = report.timings.iter().map(|t| t.generated).sum();
    assert_eq!(wall_generated, N_REQUESTS * 8);
}
