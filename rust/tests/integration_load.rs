//! Saturation load tests on the deterministic virtual clock (the tentpole
//! of the paced-trace-replay PR): the same MockBackend engine is driven
//! through under-load, at-capacity, and overload Poisson traces by
//! `loadgen::replay`, and the percentile reports must be
//!
//! * **deterministic** — two runs at the same seed are byte-identical;
//! * **physical** — preemptions appear only past saturation, and p99 TTFT
//!   grows monotonically across the knee;
//! * **paced** — the wall-clock `Server` path spreads submissions over
//!   the trace span instead of dumping everything at t=0.
//!
//! Scenario capacity math (see EXPERIMENTS.md §Load saturation): requests
//! are 16 prompt + 8 generated tokens = 23 steps; the service model costs
//! 200 + 50·batch µs per step, so a full batch of 8 serves ≈ 580 req/s.
//! 100 rps is far under the knee, 450 rps sits just below it, 1500 rps is
//! ~2.6× past it. The KV pool (40 pages × 4 tokens) fits 6 concurrent
//! worst-case requests, so only the saturated scenario preempts.

use clusterfusion::coordinator::engine::{Engine, MockBackend, ModelGeom};
use clusterfusion::coordinator::server::Server;
use clusterfusion::loadgen::{self, ReplayReport, ServiceModel};
use clusterfusion::util::clock::{VirtualClock, WallClock};
use clusterfusion::workload::{SeqlenDist, Trace};

const N_REQUESTS: usize = 160;
const TRACE_SEED: u64 = 42;
const SYNTH_SEED: u64 = 7;

fn load_mock() -> MockBackend {
    MockBackend::new(
        ModelGeom { vocab: 64, n_layers: 2, row_elems: 4, planes: 2, max_seq: 64 },
        vec![1, 2, 4, 8],
    )
}

/// One saturation scenario at the given offered rate, on a fresh virtual
/// clock. Fully determined by (rps, TRACE_SEED, SYNTH_SEED).
fn run_scenario(rps: f64) -> ReplayReport {
    let mut engine = Engine::with_clock(load_mock(), 40, 4, 0.5, VirtualClock::shared());
    let trace = Trace::poisson(N_REQUESTS, rps, SeqlenDist::Fixed(24), (8, 8), 64, TRACE_SEED);
    let requests = loadgen::synthesize_requests(&trace, 64, 16, 8, SYNTH_SEED);
    let service = ServiceModel { step_base_us: 200, step_per_seq_us: 50 };
    loadgen::replay(&mut engine, &requests, &service, 1_000_000).expect("replay")
}

const UNDER_RPS: f64 = 100.0;
const AT_CAPACITY_RPS: f64 = 450.0;
const OVERLOAD_RPS: f64 = 1500.0;

#[test]
fn all_scenarios_complete_every_request() {
    for rps in [UNDER_RPS, AT_CAPACITY_RPS, OVERLOAD_RPS] {
        let rep = run_scenario(rps);
        assert_eq!(rep.completed, N_REQUESTS, "rps {rps}");
        // every request generates its full 8 tokens; preempted requests
        // regenerate, so tokens_out can only exceed the floor
        assert!(rep.tokens_out >= (N_REQUESTS * 8) as u64, "rps {rps}: {}", rep.tokens_out);
        assert!(rep.percentiles.e2e.count == N_REQUESTS);
    }
}

#[test]
fn percentile_reports_are_seed_stable_and_byte_identical() {
    for rps in [UNDER_RPS, OVERLOAD_RPS] {
        let a = run_scenario(rps).render();
        let b = run_scenario(rps).render();
        assert_eq!(a, b, "rps {rps}: virtual-clock replay must be deterministic");
    }
}

#[test]
fn preemptions_only_past_saturation() {
    let under = run_scenario(UNDER_RPS);
    let at = run_scenario(AT_CAPACITY_RPS);
    let over = run_scenario(OVERLOAD_RPS);
    assert_eq!(
        under.preemptions, 0,
        "under-load run must not hit cache pressure (pool fits its concurrency)"
    );
    assert_eq!(
        at.preemptions, 0,
        "the knee scenario queues but must not yet thrash the KV pool"
    );
    assert!(
        over.preemptions > 0,
        "overload must preempt: 8 running × 6 worst-case pages > 40-page pool"
    );
    // recompute preemption regenerates tokens: only the overload pays it
    assert_eq!(under.tokens_out, (N_REQUESTS * 8) as u64);
    assert!(over.tokens_out > (N_REQUESTS * 8) as u64);
}

#[test]
fn p99_ttft_grows_monotonically_across_the_knee() {
    let under = run_scenario(UNDER_RPS);
    let at = run_scenario(AT_CAPACITY_RPS);
    let over = run_scenario(OVERLOAD_RPS);
    let (u, a, o) =
        (under.percentiles.ttft.p99, at.percentiles.ttft.p99, over.percentiles.ttft.p99);
    assert!(u < a && a < o, "p99 TTFT not monotone across the knee: {u} {a} {o}");
    // the overload tail is queue-dominated: far beyond a 10x step budget
    assert!(o > 10.0 * a, "overload p99 TTFT should explode: {a} -> {o}");
    // queue wait: invisible under load, dominant past saturation
    assert_eq!(under.percentiles.queue.p50, 0.0);
    assert!(over.percentiles.queue.p50 > 0.050, "{}", over.percentiles.queue.p50);
}

#[test]
fn decode_rate_stays_bounded_while_queues_grow() {
    // TPOT measures pure decode cadence: even 2.6x past saturation it is
    // bounded by the full-batch step cost (600 µs), while TTFT/e2e absorb
    // the queueing. This is the TPOT-vs-load flattening of Fig. 17.
    let over = run_scenario(OVERLOAD_RPS);
    assert!(over.percentiles.tpot.p99 <= 0.0008, "{}", over.percentiles.tpot.p99);
    assert!(over.percentiles.ttft.p99 > 0.1, "{}", over.percentiles.ttft.p99);
}

#[test]
fn paced_server_submissions_spread_over_trace_span() {
    // The wall-clock Server path (clusterfusion serve / serve_trace):
    // pace_submit must honour arrival_us instead of submitting at t=0.
    let engine = Engine::new(load_mock(), 64, 4, 0.5);
    let server = Server::spawn(engine);
    // 60 rps for a ~290 ms span (seed 9): the span/2 margin below then
    // tolerates ~145 ms of scheduler jitter on a loaded CI host.
    let trace = Trace::poisson(16, 60.0, SeqlenDist::Fixed(16), (4, 4), 64, 9);
    let requests = loadgen::synthesize_requests(&trace, 64, 12, 4, 3);
    let clock = WallClock::new();
    let paced = loadgen::pace_submit(&server, &requests, &clock).expect("paced submit");

    for (_, rx) in &paced.receivers {
        while rx.recv().is_ok() {}
    }
    let report = server.shutdown().expect("shutdown");
    assert_eq!(report.timings.len(), 16);
    assert_eq!(report.dangling_subscribers, 0);

    // deterministic, jitter-proof: every submission happened at or after
    // its own arrival offset (sleeps only overshoot)
    assert_eq!(paced.submit_us.len(), 16);
    for (sub, req) in paced.submit_us.iter().zip(&trace.requests) {
        assert!(
            *sub >= req.arrival_us,
            "request {} submitted at {sub}µs before its arrival {}µs",
            req.id,
            req.arrival_us
        );
    }
    let span = trace.span_us();
    assert!(span > 100_000, "trace must have a real span: {span}");
    let spread = paced.last_submit_us - paced.first_submit_us;
    // aggregate shape: the spread can shrink only by the first
    // submission's scheduling jitter, never collapse toward t=0
    assert!(
        spread >= span / 2,
        "submissions not paced: spread {spread}µs vs trace span {span}µs"
    );
}

#[test]
fn virtual_and_wall_clock_agree_on_token_accounting() {
    // The same trace replayed on the virtual clock and against the
    // threaded wall-clock server produces the same completion counts and
    // token totals (timing differs, accounting must not).
    let virt = run_scenario(UNDER_RPS);

    let engine = Engine::new(load_mock(), 40, 4, 0.5);
    let server = Server::spawn(engine);
    // same trace shape, compressed 50x so the wall test stays fast
    let trace =
        Trace::poisson(N_REQUESTS, 5_000.0, SeqlenDist::Fixed(24), (8, 8), 64, TRACE_SEED);
    let requests = loadgen::synthesize_requests(&trace, 64, 16, 8, SYNTH_SEED);
    let clock = WallClock::new();
    let paced = loadgen::pace_submit(&server, &requests, &clock).expect("paced submit");
    for (_, rx) in &paced.receivers {
        while rx.recv().is_ok() {}
    }
    let report = server.shutdown().expect("shutdown");
    assert_eq!(report.timings.len(), virt.completed);
    let wall_generated: usize = report.timings.iter().map(|t| t.generated).sum();
    assert_eq!(wall_generated, N_REQUESTS * 8);
}
