//! Simulator integration tests: cross-dataflow functional equivalence at
//! larger randomised sizes and the paper's headline *shape* invariants,
//! asserted end-to-end (these are the claims EXPERIMENTS.md reports).

use clusterfusion::clustersim::collective::Transport;
use clusterfusion::clustersim::dataflow::reference::{attention_block_ref, mla_block_ref};
use clusterfusion::clustersim::dataflow::{block_isolated, mla, split_head, split_token};
use clusterfusion::clustersim::e2e::{decode_step, Engine};
use clusterfusion::clustersim::frameworks::FrameworkProfile;
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::models::ModelConfig;
use clusterfusion::util::rng::Rng;

fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let d = (x - y).abs() / 1.0f32.max(x.abs()).max(y.abs());
        assert!(d < tol, "{what}[{i}]: {x} vs {y}");
    }
}

#[test]
fn all_mha_dataflows_agree_on_randomised_problems() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let mut rng = Rng::seed_from_u64(11);
    for case in 0..8 {
        let b = 1 + rng.below(3);
        let nh = [1, 2, 4][rng.below(3)];
        let dh = [8, 16][rng.below(2)];
        let n = [1, 2, 4, 8][rng.below(4)];
        let s = n * (1 + rng.below(6)) * 4;
        let d = n * (2 + rng.below(4)) * 4;
        let h = nh * dh;
        let mut v = |len: usize, sc: f32| -> Vec<f32> {
            (0..len).map(|_| (rng.f32() - 0.5) * sc).collect()
        };
        let hidden = v(b * d, 2.0);
        let wq = v(d * h, 0.3);
        let wk = v(d * h, 0.3);
        let wv = v(d * h, 0.3);
        let wo = v(h * d, 0.3);
        let kc = v(b * s * h, 2.0);
        let vc = v(b * s * h, 2.0);
        let mut rng2 = Rng::seed_from_u64(case as u64);
        let pos: Vec<usize> = (0..b).map(|_| rng2.below(s + 1)).collect();

        let r = attention_block_ref(&hidden, &wq, &wk, &wv, &wo, &kc, &vc, &pos, b, d, nh, dh, s);
        for transport in [Transport::Dsmem, Transport::GlobalMemory] {
            if dh % n == 0 {
                let (st, _) = split_token::execute(
                    &hidden, &wq, &wk, &wv, &wo, &kc, &vc, &pos, b, d, nh, dh, s, n, transport,
                    &hw, &noc,
                );
                close(&st.out, &r.out, 2e-3, &format!("split_token case {case}"));
            }
        }
        if dh % n == 0 {
            let (sh, _) = split_head::execute(
                &hidden, &wq, &wk, &wv, &wo, &kc, &vc, &pos, b, d, nh, dh, s, n,
                Transport::Dsmem, &hw, &noc,
            );
            close(&sh.out, &r.out, 2e-3, &format!("split_head case {case}"));
        }
        let (bi, _) = block_isolated::execute(
            &hidden, &wq, &wk, &wv, &wo, &kc, &vc, &pos, b, d, nh, dh, s,
        );
        close(&bi.out, &r.out, 2e-3, &format!("block_isolated case {case}"));
    }
}

#[test]
fn mla_dataflow_agrees_on_randomised_problems() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let mut rng = Rng::seed_from_u64(13);
    for case in 0..6 {
        let b = 1 + rng.below(2);
        let nh = [1, 2, 4][rng.below(3)];
        let n = [1, 2, 4][rng.below(3)];
        let l = n * 8;
        let dh = 8;
        let s = n * (1 + rng.below(4)) * 4;
        let d = n * (2 + rng.below(3)) * 4;
        let mut v = |len: usize, sc: f32| -> Vec<f32> {
            (0..len).map(|_| (rng.f32() - 0.5) * sc).collect()
        };
        let hidden = v(b * d, 2.0);
        let wq = v(d * nh * l, 0.3);
        let wkv = v(d * l, 0.3);
        let wd = v(nh * l * dh, 0.3);
        let wo = v(nh * dh * d, 0.3);
        let kvc = v(b * s * l, 2.0);
        let mut rng2 = Rng::seed_from_u64(100 + case as u64);
        let pos: Vec<usize> = (0..b).map(|_| rng2.below(s + 1)).collect();

        let r = mla_block_ref(&hidden, &wq, &wkv, &wd, &wo, &kvc, &pos, b, d, nh, l, dh, s);
        let (got, rep) = mla::execute(
            &hidden, &wq, &wkv, &wd, &wo, &kvc, &pos, b, d, nh, l, dh, s, n,
            Transport::Dsmem, &hw, &noc,
        );
        close(&got.out, &r.out, 2e-3, &format!("mla case {case}"));
        close(&got.k_new, &r.k_new, 2e-3, "kv_new");
        assert_eq!(rep.launches, 1);
    }
}

#[test]
fn headline_speedup_shape_holds_across_grid() {
    // Fig. 17's qualitative content: CF wins at every (model, seq) cell at
    // batch 1, by a plausible factor, with MLC trailing the most.
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    for model in [ModelConfig::llama2_7b(), ModelConfig::deepseek_v2_lite()] {
        for seq in [1024usize, 4096, 16384] {
            let cf = decode_step(
                &model, 1, seq,
                Engine::ClusterFusion { cluster_size: 4 },
                &FrameworkProfile::clusterfusion(), &hw, &noc,
            )
            .tpot;
            let mut speedups = Vec::new();
            for b in FrameworkProfile::baselines() {
                let tp = decode_step(&model, 1, seq, Engine::BlockIsolated, &b, &hw, &noc).tpot;
                let s = tp / cf;
                assert!(s > 1.0 && s < 4.0, "{} seq {seq}: {s}", b.name);
                speedups.push((b.name, s));
            }
            let mlc = speedups.iter().find(|(n, _)| *n == "MLC-LLM").unwrap().1;
            for (name, s) in &speedups {
                if *name != "MLC-LLM" {
                    assert!(mlc > *s, "MLC must trail ({name}: {s} vs {mlc})");
                }
            }
        }
    }
}

#[test]
fn appendix_c_batch16_shrinks_speedups_on_both_models() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    for model in [ModelConfig::llama2_7b(), ModelConfig::deepseek_v2_lite()] {
        let speedup = |batch: usize| {
            let cf = decode_step(
                &model, batch, 4096,
                Engine::ClusterFusion { cluster_size: 4 },
                &FrameworkProfile::clusterfusion(), &hw, &noc,
            )
            .tpot;
            decode_step(
                &model, batch, 4096, Engine::BlockIsolated,
                &FrameworkProfile::sglang(), &hw, &noc,
            )
            .tpot
                / cf
        };
        let (s1, s16) = (speedup(1), speedup(16));
        assert!(s16 < s1, "{}: {s16} !< {s1}", model.name);
        assert!(s16 > 1.0, "{}: still ahead at bs16", model.name);
    }
}

#[test]
fn fused_traffic_gap_is_seq_invariant() {
    // Fig. 12's content: the baseline-vs-fused HBM gap is the intermediate
    // traffic, which does not grow with seq (KV/weights move identically).
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let model = ModelConfig::llama2_7b();
    let gap = |seq: usize| {
        let base = decode_step(
            &model, 1, seq, Engine::BlockIsolated, &FrameworkProfile::sglang(), &hw, &noc,
        );
        let fused = decode_step(
            &model, 1, seq,
            Engine::ClusterFusion { cluster_size: 4 },
            &FrameworkProfile::clusterfusion(), &hw, &noc,
        );
        base.hbm_bytes - fused.hbm_bytes
    };
    let g1 = gap(1024);
    let g16 = gap(16384);
    assert!(g1 > 0.0);
    assert!((g16 - g1).abs() / g1 < 0.05, "gap ~constant: {g1} vs {g16}");
}
