//! Bit-exactness suite for the `util::linalg` microkernel refactor.
//!
//! The blocked/packed dataflow kernels promise **byte-identical**
//! `AttnOut` to the seed's scalar triple loops (DESIGN.md §Perf: the
//! per-output accumulation order — `i = 0..d`, ascending, one accumulator
//! — is part of the contract). This suite keeps *frozen verbatim copies*
//! of the pre-refactor `execute` bodies (and the pre-refactor reference
//! oracle) and asserts `f32::to_bits` equality against the live
//! implementations across geometries varying every shape parameter
//! (b, d, nh, dh, s, n; plus the MLA latent path), at every legal cluster
//! size.
//!
//! If a future change to `linalg` or a dataflow trips this suite, it
//! reassociated a sum. Fix the kernel, not the test: tolerance-based
//! comparisons live in the unit tests; this file is the exact contract.
//!
//! **`--features simd` re-pin:** the frozen copies keep every loop order
//! verbatim but route their *reductions* (projection column sums, score
//! dots, output-projection column sums) through the `linalg::dot` /
//! `linalg::dot_seq` authorities — bit-identical to the original inline
//! loops in the default build (in-order single accumulator), and the
//! same fixed lane-group order as the live kernels under the `simd`
//! feature. The rank-1 / element-wise accumulations (gemm_acc-style
//! `y += x·w_row`, probability-scaled value adds) stay as explicit
//! loops: per-element ops have no order to reassociate, so they match
//! `linalg::axpy` in both builds. The suite therefore pins byte-identity
//! under both builds without tolerating any *undocumented* drift.

use clusterfusion::clustersim::collective::{
    cluster_gather, cluster_reduce, gathered_segment, ReduceOp, Transport,
};
use clusterfusion::clustersim::dataflow::reference::AttnOut;
use clusterfusion::clustersim::dataflow::{block_isolated, mla, reference, split_head, split_token};
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::util::linalg;
use clusterfusion::util::rng::Rng;

// ---------------------------------------------------------------------------
// Seeded cases (mirrors the in-crate `dataflow::testutil` generators, which
// are not exported to integration tests).
// ---------------------------------------------------------------------------

struct MhaCase {
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
    hidden: Vec<f32>,
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    k_cache: Vec<f32>,
    v_cache: Vec<f32>,
    pos: Vec<usize>,
}

fn mha_case(seed: u64, b: usize, nh: usize, dh: usize, s: usize, d: usize) -> MhaCase {
    let mut rng = Rng::seed_from_u64(seed);
    let h = nh * dh;
    let mut v = |n: usize, scale: f32| -> Vec<f32> {
        (0..n).map(|_| (rng.f32() - 0.5) * scale).collect()
    };
    let hidden = v(b * d, 2.0);
    let wq = v(d * h, 0.4);
    let wk = v(d * h, 0.4);
    let wv = v(d * h, 0.4);
    let wo = v(h * d, 0.4);
    let k_cache = v(b * s * h, 2.0);
    let v_cache = v(b * s * h, 2.0);
    let mut rng2 = Rng::seed_from_u64(seed ^ 0xdead);
    let pos = (0..b).map(|_| rng2.range(0, s)).collect();
    MhaCase { b, d, nh, dh, s, hidden, wq, wk, wv, wo, k_cache, v_cache, pos }
}

struct MlaCase {
    b: usize,
    d: usize,
    nh: usize,
    l: usize,
    dh: usize,
    s: usize,
    hidden: Vec<f32>,
    wq: Vec<f32>,
    wkv: Vec<f32>,
    w_down: Vec<f32>,
    wo: Vec<f32>,
    kv_cache: Vec<f32>,
    pos: Vec<usize>,
}

fn mla_case(seed: u64, b: usize, nh: usize, l: usize, dh: usize, s: usize, d: usize) -> MlaCase {
    let mut rng = Rng::seed_from_u64(seed);
    let mut v = |n: usize, scale: f32| -> Vec<f32> {
        (0..n).map(|_| (rng.f32() - 0.5) * scale).collect()
    };
    let hidden = v(b * d, 2.0);
    let wq = v(d * nh * l, 0.4);
    let wkv = v(d * l, 0.4);
    let w_down = v(nh * l * dh, 0.4);
    let wo = v(nh * dh * d, 0.4);
    let kv_cache = v(b * s * l, 2.0);
    let mut rng2 = Rng::seed_from_u64(seed ^ 0xbeef);
    let pos = (0..b).map(|_| rng2.range(0, s)).collect();
    MlaCase { b, d, nh, l, dh, s, hidden, wq, wkv, w_down, wo, kv_cache, pos }
}

fn assert_bits(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}[{i}]: {x:?} ({:#010x}) vs {y:?} ({:#010x})",
            x.to_bits(),
            y.to_bits()
        );
    }
}

fn assert_out_bits(got: &AttnOut, want: &AttnOut, what: &str) {
    assert_bits(&got.out, &want.out, &format!("{what}.out"));
    assert_bits(&got.k_new, &want.k_new, &format!("{what}.k_new"));
    assert_bits(&got.v_new, &want.v_new, &format!("{what}.v_new"));
}

// ---------------------------------------------------------------------------
// Frozen pre-refactor scalar implementations (seed commit b63f1d4).
// Verbatim copies minus the cost bookkeeping they shared with the live
// code; every arithmetic statement and loop order is untouched, except
// that reductions call the `linalg::dot`/`dot_seq` authorities (see the
// header: identical bits in the default build, lockstep lane-group
// re-pin under `--features simd`).
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn frozen_split_token(
    hidden: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> AttnOut {
    assert!(dh % n == 0 && s % n == 0 && d % n == 0, "cluster must divide dh, S, D");
    let h = nh * dh;
    let (hs, ss, ds) = (dh / n, s / n, d / n);
    let scale = 1.0 / (dh as f32).sqrt();

    let mut out = vec![0f32; b * d];
    let mut k_new_g = vec![0f32; b * h];
    let mut v_new_g = vec![0f32; b * h];

    for head in 0..nh {
        let project = |w: &[f32]| -> Vec<Vec<f32>> {
            (0..n)
                .map(|r| {
                    let mut seg = vec![0f32; b * hs];
                    for bi in 0..b {
                        for (j, sj) in seg[bi * hs..(bi + 1) * hs].iter_mut().enumerate() {
                            let col = head * dh + r * hs + j;
                            *sj = linalg::dot_seq(
                                (0..d).map(|i| (hidden[bi * d + i], w[i * h + col])),
                            );
                        }
                    }
                    seg
                })
                .collect()
        };
        let q_segs = project(wq);
        let k_segs = project(wk);
        let v_segs = project(wv);

        let cat: Vec<Vec<f32>> = (0..n)
            .map(|r| {
                let mut c = Vec::with_capacity(3 * b * hs);
                c.extend_from_slice(&q_segs[r]);
                c.extend_from_slice(&k_segs[r]);
                c.extend_from_slice(&v_segs[r]);
                c
            })
            .collect();
        let (gathered, _gc) = cluster_gather(&cat, transport, hw, noc);

        let assemble = |owner: usize| -> (Vec<f32>, Vec<f32>, Vec<f32>) {
            let seg_len = 3 * b * hs;
            let mut q = vec![0f32; b * dh];
            let mut kn = vec![0f32; b * dh];
            let mut vn = vec![0f32; b * dh];
            for r in 0..n {
                let seg = gathered_segment(&gathered[owner], owner, r, n, seg_len);
                for bi in 0..b {
                    q[bi * dh + r * hs..bi * dh + (r + 1) * hs]
                        .copy_from_slice(&seg[bi * hs..(bi + 1) * hs]);
                    kn[bi * dh + r * hs..bi * dh + (r + 1) * hs]
                        .copy_from_slice(&seg[b * hs + bi * hs..b * hs + (bi + 1) * hs]);
                    vn[bi * dh + r * hs..bi * dh + (r + 1) * hs]
                        .copy_from_slice(&seg[2 * b * hs + bi * hs..2 * b * hs + (bi + 1) * hs]);
                }
            }
            (q, kn, vn)
        };
        let (q, k_new, v_new) = assemble(0);

        for bi in 0..b {
            k_new_g[bi * h + head * dh..bi * h + (head + 1) * dh]
                .copy_from_slice(&k_new[bi * dh..(bi + 1) * dh]);
            v_new_g[bi * h + head * dh..bi * h + (head + 1) * dh]
                .copy_from_slice(&v_new[bi * dh..(bi + 1) * dh]);
        }

        let mut m_bufs: Vec<Vec<f32>> = vec![vec![f32::NEG_INFINITY; b]; n];
        let mut l_bufs: Vec<Vec<f32>> = vec![vec![0f32; b]; n];
        let mut acc_bufs: Vec<Vec<f32>> = vec![vec![0f32; b * dh]; n];
        for r in 0..n {
            for bi in 0..b {
                let valid = pos[bi];
                let lo = r * ss;
                let hi = ((r + 1) * ss).min(valid);
                let qrow = &q[bi * dh..(bi + 1) * dh];
                let mut scores: Vec<(usize, f32)> = Vec::new();
                for t in lo..hi.max(lo) {
                    if t >= valid {
                        break;
                    }
                    let base = ((bi * s + t) * nh + head) * dh;
                    let dot = linalg::dot(qrow, &k_cache[base..base + dh]);
                    scores.push((t, dot * scale));
                }
                let self_here = r == n - 1;
                let self_score = if self_here {
                    let dot = linalg::dot(qrow, &k_new[bi * dh..(bi + 1) * dh]);
                    Some(dot * scale)
                } else {
                    None
                };
                let mut m = f32::NEG_INFINITY;
                for (_, sc) in &scores {
                    m = m.max(*sc);
                }
                if let Some(sc) = self_score {
                    m = m.max(sc);
                }
                if m == f32::NEG_INFINITY {
                    continue;
                }
                let mut l = 0f32;
                let acc = &mut acc_bufs[r][bi * dh..(bi + 1) * dh];
                for (t, sc) in &scores {
                    let p = (sc - m).exp();
                    l += p;
                    let base = ((bi * s + t) * nh + head) * dh;
                    for (a, vv) in acc.iter_mut().zip(&v_cache[base..base + dh]) {
                        *a += p * vv;
                    }
                }
                if let Some(sc) = self_score {
                    let p = (sc - m).exp();
                    l += p;
                    for (a, vv) in acc.iter_mut().zip(&v_new[bi * dh..(bi + 1) * dh]) {
                        *a += p * vv;
                    }
                }
                m_bufs[r][bi] = m;
                l_bufs[r][bi] = l;
            }
        }

        let m_local: Vec<Vec<f32>> = m_bufs.clone();
        let _ = cluster_reduce(&mut m_bufs, ReduceOp::Max, transport, hw, noc);
        for r in 0..n {
            for bi in 0..b {
                let alpha = if m_local[r][bi] == f32::NEG_INFINITY {
                    0.0
                } else {
                    (m_local[r][bi] - m_bufs[r][bi]).exp()
                };
                l_bufs[r][bi] *= alpha;
                for a in &mut acc_bufs[r][bi * dh..(bi + 1) * dh] {
                    *a *= alpha;
                }
            }
        }
        let _ = cluster_reduce(&mut l_bufs, ReduceOp::Sum, transport, hw, noc);
        let _ = cluster_reduce(&mut acc_bufs, ReduceOp::Sum, transport, hw, noc);

        for r in 0..n {
            for bi in 0..b {
                let attn: Vec<f32> = acc_bufs[r][bi * dh..(bi + 1) * dh]
                    .iter()
                    .map(|a| a / l_bufs[r][bi])
                    .collect();
                for c in 0..ds {
                    let col = r * ds + c;
                    let acc = linalg::dot_seq(
                        attn.iter().enumerate().map(|(j, &av)| (av, wo[(head * dh + j) * d + col])),
                    );
                    out[bi * d + col] += acc;
                }
            }
        }
    }

    AttnOut { out, k_new: k_new_g, v_new: v_new_g }
}

#[allow(clippy::too_many_arguments)]
fn frozen_split_head(
    hidden: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> AttnOut {
    assert!(dh % n == 0, "cluster must divide head_dim");
    let h = nh * dh;
    let hs = dh / n;
    let scale = 1.0 / (dh as f32).sqrt();

    let mut out = vec![0f32; b * d];
    let mut k_new_g = vec![0f32; b * h];
    let mut v_new_g = vec![0f32; b * h];

    for head in 0..nh {
        let project = |w: &[f32], r: usize| -> Vec<f32> {
            let mut seg = vec![0f32; b * hs];
            for bi in 0..b {
                for (j, sj) in seg[bi * hs..(bi + 1) * hs].iter_mut().enumerate() {
                    let col = head * dh + r * hs + j;
                    *sj = linalg::dot_seq((0..d).map(|i| (hidden[bi * d + i], w[i * h + col])));
                }
            }
            seg
        };
        let q_segs: Vec<Vec<f32>> = (0..n).map(|r| project(wq, r)).collect();
        let k_segs: Vec<Vec<f32>> = (0..n).map(|r| project(wk, r)).collect();
        let v_segs: Vec<Vec<f32>> = (0..n).map(|r| project(wv, r)).collect();
        for r in 0..n {
            for bi in 0..b {
                let dst = bi * h + head * dh + r * hs;
                k_new_g[dst..dst + hs].copy_from_slice(&k_segs[r][bi * hs..(bi + 1) * hs]);
                v_new_g[dst..dst + hs].copy_from_slice(&v_segs[r][bi * hs..(bi + 1) * hs]);
            }
        }

        let mut score_bufs: Vec<Vec<f32>> = (0..n)
            .map(|r| {
                let mut sc = vec![0f32; b * (s + 1)];
                for bi in 0..b {
                    let qseg = &q_segs[r][bi * hs..(bi + 1) * hs];
                    for t in 0..pos[bi] {
                        let base = ((bi * s + t) * nh + head) * dh + r * hs;
                        let acc = linalg::dot(qseg, &k_cache[base..base + hs]);
                        sc[bi * (s + 1) + t] = acc * scale;
                    }
                    let acc = linalg::dot(qseg, &k_segs[r][bi * hs..(bi + 1) * hs]);
                    sc[bi * (s + 1) + s] = acc * scale;
                }
                sc
            })
            .collect();

        let _ = cluster_reduce(&mut score_bufs, ReduceOp::Sum, transport, hw, noc);

        let mut o_bufs: Vec<Vec<f32>> = vec![vec![0f32; b * d]; n];
        for r in 0..n {
            for bi in 0..b {
                let valid = pos[bi];
                let row = &score_bufs[r][bi * (s + 1)..(bi + 1) * (s + 1)];
                let mut m = row[s];
                for t in 0..valid {
                    m = m.max(row[t]);
                }
                let mut l = 0f32;
                let mut probs = vec![0f32; valid + 1];
                for t in 0..valid {
                    probs[t] = (row[t] - m).exp();
                    l += probs[t];
                }
                probs[valid] = (row[s] - m).exp();
                l += probs[valid];
                let mut a = vec![0f32; hs];
                for t in 0..valid {
                    let base = ((bi * s + t) * nh + head) * dh + r * hs;
                    for (j, av) in a.iter_mut().enumerate() {
                        *av += probs[t] * v_cache[base + j];
                    }
                }
                for (j, av) in a.iter_mut().enumerate() {
                    *av += probs[valid] * v_segs[r][bi * hs + j];
                    *av /= l;
                }
                for (j, av) in a.iter().enumerate() {
                    let wrow = &wo[(head * dh + r * hs + j) * d..(head * dh + r * hs + j + 1) * d];
                    let orow = &mut o_bufs[r][bi * d..(bi + 1) * d];
                    for (o, w) in orow.iter_mut().zip(wrow) {
                        *o += av * w;
                    }
                }
            }
        }

        let _ = cluster_reduce(&mut o_bufs, ReduceOp::Sum, transport, hw, noc);

        for bi in 0..b * d {
            out[bi] += o_bufs[0][bi];
        }
    }

    AttnOut { out, k_new: k_new_g, v_new: v_new_g }
}

#[allow(clippy::too_many_arguments)]
fn frozen_mla(
    hidden: &[f32],
    wq: &[f32],
    wkv: &[f32],
    w_down: &[f32],
    wo: &[f32],
    kv_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    l: usize,
    dh: usize,
    s: usize,
    n: usize,
    transport: Transport,
    hw: &Hardware,
    noc: &Noc,
) -> AttnOut {
    assert!(l % n == 0 && s % n == 0 && d % n == 0, "cluster must divide l, S, D");
    let (ls, ss, ds) = (l / n, s / n, d / n);
    let scale = 1.0 / (l as f32).sqrt();

    let mut out = vec![0f32; b * d];
    let mut kv_new_g = vec![0f32; b * l];

    let kv_segs: Vec<Vec<f32>> = (0..n)
        .map(|r| {
            let mut seg = vec![0f32; b * ls];
            for bi in 0..b {
                for (j, sj) in seg[bi * ls..(bi + 1) * ls].iter_mut().enumerate() {
                    let col = r * ls + j;
                    *sj = linalg::dot_seq((0..d).map(|i| (hidden[bi * d + i], wkv[i * l + col])));
                }
            }
            seg
        })
        .collect();
    let (kv_gathered, _) = cluster_gather(&kv_segs, transport, hw, noc);
    let mut kv_new = vec![0f32; b * l];
    for r in 0..n {
        let seg = gathered_segment(&kv_gathered[0], 0, r, n, b * ls);
        for bi in 0..b {
            kv_new[bi * l + r * ls..bi * l + (r + 1) * ls]
                .copy_from_slice(&seg[bi * ls..(bi + 1) * ls]);
        }
    }
    kv_new_g.copy_from_slice(&kv_new);

    for head in 0..nh {
        let q_segs: Vec<Vec<f32>> = (0..n)
            .map(|r| {
                let mut seg = vec![0f32; b * ls];
                for bi in 0..b {
                    for (j, sj) in seg[bi * ls..(bi + 1) * ls].iter_mut().enumerate() {
                        let col = head * l + r * ls + j;
                        *sj = linalg::dot_seq(
                            (0..d).map(|i| (hidden[bi * d + i], wq[i * nh * l + col])),
                        );
                    }
                }
                seg
            })
            .collect();
        let (q_gathered, _) = cluster_gather(&q_segs, transport, hw, noc);
        let mut q = vec![0f32; b * l];
        for r in 0..n {
            let seg = gathered_segment(&q_gathered[0], 0, r, n, b * ls);
            for bi in 0..b {
                q[bi * l + r * ls..bi * l + (r + 1) * ls]
                    .copy_from_slice(&seg[bi * ls..(bi + 1) * ls]);
            }
        }

        let mut m_bufs: Vec<Vec<f32>> = vec![vec![f32::NEG_INFINITY; b]; n];
        let mut l_bufs: Vec<Vec<f32>> = vec![vec![0f32; b]; n];
        let mut acc_bufs: Vec<Vec<f32>> = vec![vec![0f32; b * l]; n];
        for r in 0..n {
            for bi in 0..b {
                let valid = pos[bi];
                let lo = r * ss;
                let hi = ((r + 1) * ss).min(valid);
                let qrow = &q[bi * l..(bi + 1) * l];
                let mut scores: Vec<(usize, f32)> = Vec::new();
                for t in lo..hi.max(lo) {
                    let base = (bi * s + t) * l;
                    let dot = linalg::dot(qrow, &kv_cache[base..base + l]);
                    scores.push((t, dot * scale));
                }
                let self_here = r == n - 1;
                let self_score = if self_here {
                    let dot = linalg::dot(qrow, &kv_new[bi * l..(bi + 1) * l]);
                    Some(dot * scale)
                } else {
                    None
                };
                let mut m = f32::NEG_INFINITY;
                for (_, sc) in &scores {
                    m = m.max(*sc);
                }
                if let Some(sc) = self_score {
                    m = m.max(sc);
                }
                if m == f32::NEG_INFINITY {
                    continue;
                }
                let mut lsum = 0f32;
                let acc = &mut acc_bufs[r][bi * l..(bi + 1) * l];
                for (t, sc) in &scores {
                    let p = (sc - m).exp();
                    lsum += p;
                    let base = (bi * s + t) * l;
                    for (a, kv) in acc.iter_mut().zip(&kv_cache[base..base + l]) {
                        *a += p * kv;
                    }
                }
                if let Some(sc) = self_score {
                    let p = (sc - m).exp();
                    lsum += p;
                    for (a, kv) in acc.iter_mut().zip(&kv_new[bi * l..(bi + 1) * l]) {
                        *a += p * kv;
                    }
                }
                m_bufs[r][bi] = m;
                l_bufs[r][bi] = lsum;
            }
        }

        let m_local = m_bufs.clone();
        let _ = cluster_reduce(&mut m_bufs, ReduceOp::Max, transport, hw, noc);
        for r in 0..n {
            for bi in 0..b {
                let alpha = if m_local[r][bi] == f32::NEG_INFINITY {
                    0.0
                } else {
                    (m_local[r][bi] - m_bufs[r][bi]).exp()
                };
                l_bufs[r][bi] *= alpha;
                for a in &mut acc_bufs[r][bi * l..(bi + 1) * l] {
                    *a *= alpha;
                }
            }
        }
        let _ = cluster_reduce(&mut l_bufs, ReduceOp::Sum, transport, hw, noc);
        let _ = cluster_reduce(&mut acc_bufs, ReduceOp::Sum, transport, hw, noc);

        let attn: Vec<f32> = (0..b * l).map(|i| acc_bufs[0][i] / l_bufs[0][i / l]).collect();

        let mut z_bufs: Vec<Vec<f32>> = (0..n)
            .map(|r| {
                let mut z = vec![0f32; b * dh];
                for bi in 0..b {
                    for j in 0..ls {
                        let av = attn[bi * l + r * ls + j];
                        let wrow = &w_down[head * l * dh + (r * ls + j) * dh
                            ..head * l * dh + (r * ls + j + 1) * dh];
                        for (zv, wv) in z[bi * dh..(bi + 1) * dh].iter_mut().zip(wrow) {
                            *zv += av * wv;
                        }
                    }
                }
                z
            })
            .collect();
        let _ = cluster_reduce(&mut z_bufs, ReduceOp::Sum, transport, hw, noc);

        for r in 0..n {
            for bi in 0..b {
                for c in 0..ds {
                    let col = r * ds + c;
                    let acc = linalg::dot_seq(
                        (0..dh).map(|j| (z_bufs[r][bi * dh + j], wo[(head * dh + j) * d + col])),
                    );
                    out[bi * d + col] += acc;
                }
            }
        }
    }

    AttnOut { out, k_new: kv_new_g, v_new: vec![] }
}

/// Frozen pre-refactor reference oracle (gemm_acc + zip-sum attention).
#[allow(clippy::too_many_arguments)]
fn frozen_attention_block_ref(
    hidden: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
) -> AttnOut {
    fn gemm_acc(x: &[f32], w: &[f32], y: &mut [f32], b: usize, n_in: usize, n_out: usize) {
        for bi in 0..b {
            for i in 0..n_in {
                let xv = x[bi * n_in + i];
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[i * n_out..(i + 1) * n_out];
                let yrow = &mut y[bi * n_out..(bi + 1) * n_out];
                for (yo, wo) in yrow.iter_mut().zip(wrow) {
                    *yo += xv * wo;
                }
            }
        }
    }
    let h = nh * dh;
    let mut q = vec![0f32; b * h];
    let mut k_new = vec![0f32; b * h];
    let mut v_new = vec![0f32; b * h];
    gemm_acc(hidden, wq, &mut q, b, d, h);
    gemm_acc(hidden, wk, &mut k_new, b, d, h);
    gemm_acc(hidden, wv, &mut v_new, b, d, h);

    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = vec![0f32; b * d];
    for head in 0..nh {
        let take = |src: &[f32]| -> Vec<f32> {
            let mut t = vec![0f32; b * dh];
            for bi in 0..b {
                t[bi * dh..(bi + 1) * dh]
                    .copy_from_slice(&src[bi * h + head * dh..bi * h + (head + 1) * dh]);
            }
            t
        };
        let (qh, knh, vnh) = (take(&q), take(&k_new), take(&v_new));
        let mut attn = vec![0f32; b * dh];
        for bi in 0..b {
            let qrow = &qh[bi * dh..(bi + 1) * dh];
            let nvalid = pos[bi];
            let mut scores = Vec::with_capacity(nvalid + 1);
            for t in 0..nvalid {
                let base = ((bi * s + t) * nh + head) * dh;
                let dot = linalg::dot(qrow, &k_cache[base..base + dh]);
                scores.push(dot * scale);
            }
            let self_dot = linalg::dot(qrow, &knh[bi * dh..(bi + 1) * dh]);
            scores.push(self_dot * scale);

            let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut l = 0.0;
            for sc in scores.iter_mut() {
                *sc = (*sc - m).exp();
                l += *sc;
            }
            let orow = &mut attn[bi * dh..(bi + 1) * dh];
            for (t, p) in scores[..nvalid].iter().enumerate() {
                let base = ((bi * s + t) * nh + head) * dh;
                for (o, vv) in orow.iter_mut().zip(&v_cache[base..base + dh]) {
                    *o += p * vv;
                }
            }
            let p_self = scores[nvalid];
            for (o, vv) in orow.iter_mut().zip(&vnh[bi * dh..(bi + 1) * dh]) {
                *o += p_self * vv;
            }
            for o in orow.iter_mut() {
                *o /= l;
            }
        }
        let wo_head = &wo[head * dh * d..(head + 1) * dh * d];
        gemm_acc(&attn, wo_head, &mut out, b, dh, d);
    }
    AttnOut { out, k_new, v_new }
}

/// Frozen pre-refactor block-isolated baseline pipeline.
#[allow(clippy::too_many_arguments)]
fn frozen_block_isolated(
    hidden: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    pos: &[usize],
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
) -> AttnOut {
    const FLASH_SPLITS: usize = 4;
    fn gemm_acc(x: &[f32], w: &[f32], y: &mut [f32], b: usize, n_in: usize, n_out: usize) {
        for bi in 0..b {
            for i in 0..n_in {
                let xv = x[bi * n_in + i];
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[i * n_out..(i + 1) * n_out];
                let yrow = &mut y[bi * n_out..(bi + 1) * n_out];
                for (yo, wo) in yrow.iter_mut().zip(wrow) {
                    *yo += xv * wo;
                }
            }
        }
    }
    let h = nh * dh;
    let mut q_gmem = vec![0f32; b * h];
    let mut k_gmem = vec![0f32; b * h];
    let mut v_gmem = vec![0f32; b * h];
    gemm_acc(hidden, wq, &mut q_gmem, b, d, h);
    gemm_acc(hidden, wk, &mut k_gmem, b, d, h);
    gemm_acc(hidden, wv, &mut v_gmem, b, d, h);

    let scale = 1.0 / (dh as f32).sqrt();
    let seg = s.div_ceil(FLASH_SPLITS);
    let mut part_acc = vec![0f32; nh * FLASH_SPLITS * b * dh];
    let mut part_m = vec![f32::NEG_INFINITY; nh * FLASH_SPLITS * b];
    let mut part_l = vec![0f32; nh * FLASH_SPLITS * b];
    for head in 0..nh {
        for sp in 0..FLASH_SPLITS {
            let blk = head * FLASH_SPLITS + sp;
            for bi in 0..b {
                let valid = pos[bi];
                let lo = sp * seg;
                let hi = ((sp + 1) * seg).min(valid);
                let qrow = &q_gmem[bi * h + head * dh..bi * h + (head + 1) * dh];
                let mut m = f32::NEG_INFINITY;
                let mut scores = Vec::new();
                for t in lo..hi.max(lo) {
                    let base = ((bi * s + t) * nh + head) * dh;
                    let dot = linalg::dot(qrow, &k_cache[base..base + dh]);
                    let sc = dot * scale;
                    m = m.max(sc);
                    scores.push((t, sc));
                }
                if sp == FLASH_SPLITS - 1 {
                    let dot =
                        linalg::dot(qrow, &k_gmem[bi * h + head * dh..bi * h + (head + 1) * dh]);
                    let sc = dot * scale;
                    m = m.max(sc);
                    scores.push((usize::MAX, sc));
                }
                if m == f32::NEG_INFINITY {
                    continue;
                }
                let mut l = 0f32;
                let acc = &mut part_acc[(blk * b + bi) * dh..(blk * b + bi + 1) * dh];
                for (t, sc) in scores {
                    let p = (sc - m).exp();
                    l += p;
                    let vrow = if t == usize::MAX {
                        &v_gmem[bi * h + head * dh..bi * h + (head + 1) * dh]
                    } else {
                        &v_cache
                            [((bi * s + t) * nh + head) * dh..((bi * s + t) * nh + head) * dh + dh]
                    };
                    for (a, vv) in acc.iter_mut().zip(vrow) {
                        *a += p * vv;
                    }
                }
                part_m[blk * b + bi] = m;
                part_l[blk * b + bi] = l;
            }
        }
    }

    let mut attn_gmem = vec![0f32; b * h];
    for head in 0..nh {
        for bi in 0..b {
            let mut m = f32::NEG_INFINITY;
            for sp in 0..FLASH_SPLITS {
                m = m.max(part_m[(head * FLASH_SPLITS + sp) * b + bi]);
            }
            let mut l = 0f32;
            let out = &mut attn_gmem[bi * h + head * dh..bi * h + (head + 1) * dh];
            for sp in 0..FLASH_SPLITS {
                let blk = head * FLASH_SPLITS + sp;
                let pm = part_m[blk * b + bi];
                if pm == f32::NEG_INFINITY {
                    continue;
                }
                let alpha = (pm - m).exp();
                l += part_l[blk * b + bi] * alpha;
                for (o, a) in out
                    .iter_mut()
                    .zip(&part_acc[(blk * b + bi) * dh..(blk * b + bi + 1) * dh])
                {
                    *o += a * alpha;
                }
            }
            for o in out.iter_mut() {
                *o /= l;
            }
        }
    }

    let mut out = vec![0f32; b * d];
    gemm_acc(&attn_gmem, wo, &mut out, b, h, d);
    AttnOut { out, k_new: k_gmem, v_new: v_gmem }
}

// ---------------------------------------------------------------------------
// The suite: ≥6 geometries varying every parameter, all legal cluster
// sizes, both transports where numerics could plausibly diverge.
// ---------------------------------------------------------------------------

/// (seed, b, nh, dh, s, d, cluster sizes) — every n divides dh, s and d.
const MHA_GEOMETRIES: &[(u64, usize, usize, usize, usize, usize, &[usize])] = &[
    (7, 1, 1, 4, 8, 8, &[1, 2, 4]),
    (11, 2, 2, 8, 16, 16, &[1, 2, 4, 8]),
    (13, 3, 2, 8, 12, 24, &[1, 2, 4]),
    (17, 1, 4, 16, 32, 32, &[1, 2, 4, 8]),
    (19, 2, 3, 8, 24, 48, &[1, 2, 4]),
    (23, 2, 2, 4, 8, 16, &[1, 2, 4]),
];

/// (seed, b, nh, l, dh, s, d, cluster sizes) — every n divides l, s and d.
const MLA_GEOMETRIES: &[(u64, usize, usize, usize, usize, usize, usize, &[usize])] = &[
    (29, 2, 2, 16, 8, 16, 16, &[1, 2, 4, 8]),
    (31, 1, 3, 8, 4, 8, 8, &[1, 2, 4]),
    (37, 2, 1, 4, 8, 12, 4, &[1, 2, 4]),
];

fn env() -> (Hardware, Noc) {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    (hw, noc)
}

#[test]
fn split_token_bitexact_vs_frozen_scalar() {
    let (hw, noc) = env();
    for &(seed, b, nh, dh, s, d, ns) in MHA_GEOMETRIES {
        let c = mha_case(seed, b, nh, dh, s, d);
        for &n in ns {
            for transport in [Transport::Dsmem, Transport::GlobalMemory] {
                let want = frozen_split_token(
                    &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
                    b, d, nh, dh, s, n, transport, &hw, &noc,
                );
                let (got, rep) = split_token::execute(
                    &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
                    b, d, nh, dh, s, n, transport, &hw, &noc,
                );
                assert_out_bits(&got, &want, &format!("split_token seed={seed} n={n}"));
                assert_eq!(rep.launches, 1, "schedule unchanged");
            }
        }
    }
}

#[test]
fn split_head_bitexact_vs_frozen_scalar() {
    let (hw, noc) = env();
    for &(seed, b, nh, dh, s, d, ns) in MHA_GEOMETRIES {
        let c = mha_case(seed, b, nh, dh, s, d);
        for &n in ns {
            let want = frozen_split_head(
                &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
                b, d, nh, dh, s, n, Transport::Dsmem, &hw, &noc,
            );
            let (got, _) = split_head::execute(
                &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
                b, d, nh, dh, s, n, Transport::Dsmem, &hw, &noc,
            );
            assert_out_bits(&got, &want, &format!("split_head seed={seed} n={n}"));
        }
    }
}

#[test]
fn mla_bitexact_vs_frozen_scalar() {
    let (hw, noc) = env();
    for &(seed, b, nh, l, dh, s, d, ns) in MLA_GEOMETRIES {
        let c = mla_case(seed, b, nh, l, dh, s, d);
        for &n in ns {
            let want = frozen_mla(
                &c.hidden, &c.wq, &c.wkv, &c.w_down, &c.wo, &c.kv_cache, &c.pos,
                b, d, nh, l, dh, s, n, Transport::Dsmem, &hw, &noc,
            );
            let (got, _) = mla::execute(
                &c.hidden, &c.wq, &c.wkv, &c.w_down, &c.wo, &c.kv_cache, &c.pos,
                b, d, nh, l, dh, s, n, Transport::Dsmem, &hw, &noc,
            );
            assert_out_bits(&got, &want, &format!("mla seed={seed} n={n}"));
        }
    }
}

#[test]
fn reference_and_block_isolated_bitexact_vs_frozen_scalar() {
    for &(seed, b, nh, dh, s, d, _) in MHA_GEOMETRIES {
        let c = mha_case(seed, b, nh, dh, s, d);
        let want = frozen_attention_block_ref(
            &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
            b, d, nh, dh, s,
        );
        let got = reference::attention_block_ref(
            &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
            b, d, nh, dh, s,
        );
        assert_out_bits(&got, &want, &format!("reference seed={seed}"));

        let want_bi = frozen_block_isolated(
            &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
            b, d, nh, dh, s,
        );
        let (got_bi, _) = block_isolated::execute(
            &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
            b, d, nh, dh, s,
        );
        assert_out_bits(&got_bi, &want_bi, &format!("block_isolated seed={seed}"));
    }
}

#[test]
fn transports_agree_bit_for_bit() {
    // The Fig. 13 ablation changes time, never values: DSMEM and the
    // global-memory fallback must produce identical bytes now that both
    // run through the packed kernels.
    let (hw, noc) = env();
    let c = mha_case(41, 2, 2, 8, 16, 16);
    let run = |t| {
        split_token::execute(
            &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
            c.b, c.d, c.nh, c.dh, c.s, 4, t, &hw, &noc,
        )
        .0
    };
    let a = run(Transport::Dsmem);
    let b = run(Transport::GlobalMemory);
    assert_out_bits(&a, &b, "transport");
}
